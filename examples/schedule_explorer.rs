//! Schedule explorer — sweep the whole scheduler zoo across both
//! networks and SPE counts; prints balance ratio and simulated FPS for
//! every combination (the design-space exploration behind Fig. 7 and the
//! DESIGN.md ablations).
//!
//! ```bash
//! cargo run --release --example schedule_explorer [frames]
//! ```

use anyhow::Result;
use skydiver::coordinator::default_input_rates;
use skydiver::metrics::Table;
use skydiver::schedule::{all_schedulers, AprcPredictor};
use skydiver::sim::{sweep, ArchConfig, RunSummary, Simulator};
use skydiver::snn::{encode_phased_u8, NetworkWeights, SpikeMap};

fn frames_for(net: &NetworkWeights, n: usize) -> Vec<Vec<SpikeMap>> {
    let t = net.meta.timesteps;
    if net.meta.in_shape[0] == 1 {
        let (imgs, _) = skydiver::data::gen_digits(0xE8104E, n);
        imgs.chunks(28 * 28)
            .map(|i| encode_phased_u8(i, 1, 28, 28, t)).collect()
    } else {
        let (imgs, _) = skydiver::data::gen_road_scenes(0xE8104E, n);
        let (h, w) = (skydiver::data::ROAD_H, skydiver::data::ROAD_W);
        imgs.chunks(h * w * 3).map(|img| {
            let mut chw = vec![0u8; 3 * h * w];
            for y in 0..h {
                for x in 0..w {
                    for c in 0..3 {
                        chw[c * h * w + y * w + x] =
                            img[(y * w + x) * 3 + c];
                    }
                }
            }
            encode_phased_u8(&chw, 3, h, w, t)
        }).collect()
    }
}

fn main() -> Result<()> {
    let n_frames: usize = std::env::args().nth(1)
        .and_then(|a| a.parse().ok()).unwrap_or(2);
    let dir = skydiver::artifacts_dir();

    for name in ["classifier_aprc", "segmenter_aprc"] {
        let net = NetworkWeights::load(&dir, name)?;
        let inputs = frames_for(&net, n_frames);
        let rates = default_input_rates(&net);
        let predictor = AprcPredictor::from_network(&net, &rates);

        let mut table = Table::new(
            format!("{name}: scheduler x N sweep ({n_frames} frames)"),
            &["scheduler", "N=4", "N=8", "N=16"]);
        for s in all_schedulers() {
            let mut row = vec![s.name().to_string()];
            for n in [4usize, 8, 16] {
                let mut arch = ArchConfig::default();
                arch.n_spes = n;
                let sim = Simulator::new(arch, &net, s.as_ref(),
                                         &predictor);
                let reports = sweep::run_frames_functional(
                    &sim, &inputs, sweep::default_threads())?;
                let sum = RunSummary::from_frames(&reports, arch.clock_hz,
                                                  n);
                row.push(format!("{:.1}% @{:.0}fps",
                                 100.0 * sum.mean_balance_weighted,
                                 sum.mean_fps));
            }
            table.row(&row);
        }
        table.print();
        println!();
    }
    Ok(())
}
