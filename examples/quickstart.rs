//! Quickstart: classify a digit end-to-end through all three layers.
//!
//! 1. generate a synthetic digit (rust port of the python dataset);
//! 2. encode it to a spike train (phased rate coding);
//! 3. run the AOT-compiled JAX/Pallas step function via PJRT (L2+L1);
//! 4. feed the golden trace to the cycle-level Skydiver simulator with
//!    the APRC+CBWS schedule (L3) and report cycles/energy/prediction.
//!
//! ```bash
//! make artifacts && cargo run --release --example quickstart
//! ```

use anyhow::Result;
use skydiver::coordinator::default_input_rates;
use skydiver::power::EnergyModel;
use skydiver::runtime::{Runtime, SnnRunner};
use skydiver::schedule::cbws::Cbws;
use skydiver::schedule::AprcPredictor;
use skydiver::sim::{ArchConfig, Simulator, TraceSource};
use skydiver::snn::{encode_phased_u8, NetworkWeights};

fn main() -> Result<()> {
    let dir = skydiver::artifacts_dir();
    let net = NetworkWeights::load(&dir, "classifier_aprc")?;
    println!("loaded {} ({} layers, T={})", net.meta.name,
             net.num_layers(), net.meta.timesteps);

    // A digit frame.
    let (imgs, labels) = skydiver::data::gen_digits(0xD1617, 1);
    println!("ground-truth label: {}", labels[0]);

    // Encode.
    let inputs = encode_phased_u8(&imgs, 1, 28, 28, net.meta.timesteps);
    let spikes_in: usize = inputs.iter().map(|m| m.nnz()).sum();
    println!("encoded {} input spikes over {} timesteps", spikes_in,
             inputs.len());

    // Golden execution through PJRT (the AOT-compiled JAX/Pallas HLO).
    let rt = Runtime::cpu()?;
    println!("PJRT platform: {}", rt.platform());
    let step = rt.load_step(&dir, &net)?;
    let mut runner = SnnRunner::new(&step)?;
    let trace = runner.run_frame(&inputs)?;

    // Simulate the accelerator processing the same workload.
    let arch = ArchConfig::default();
    let rates = default_input_rates(&net);
    let predictor = AprcPredictor::from_network(&net, &rates);
    let sim = Simulator::new(arch, &net, &Cbws::default(), &predictor);
    let report = sim.run_frame(&inputs, &TraceSource::Golden(trace))?;

    let pred = report.output_counts.iter().enumerate()
        .max_by_key(|(_, &c)| c).map(|(i, _)| i).unwrap();
    let energy = EnergyModel::default()
        .frame_energy(&report, arch.clock_hz);
    println!("\npredicted: {pred} (counts {:?})", report.output_counts);
    println!("simulated: {} cycles -> {:.1} KFPS @200MHz",
             report.total_cycles, report.fps(arch.clock_hz) / 1e3);
    println!("balance  : {:.2}%  energy: {:.1} uJ  power: {:.2} W",
             100.0 * report.balance_weighted(arch.n_spes),
             energy.total_j * 1e6, energy.mean_w);
    assert_eq!(pred, labels[0] as usize, "misclassified!");
    println!("\nquickstart OK");
    Ok(())
}
