//! Serving demo — the L3 coordinator under load: submit a burst of
//! classification frames to the shared work queue + pull-based worker
//! pool and report host throughput, latency percentiles, host-side
//! workload balance, and the simulated accelerator's FPS/energy (the
//! paper's Table I view of the same run).
//!
//! The submit loop uses `try_submit`, so the demo also shows the
//! backpressure path: when the bounded queue fills, the submitter
//! falls back to a blocking `submit` and counts the stall.
//!
//! With `tcp` as an argument, the same frames travel over a loopback
//! TCP gateway instead (wire protocol + admission control + router),
//! ending with a Prometheus metrics scrape and a graceful drain. The
//! gateway is registry-backed: when segmenter artifacts are present
//! next to the classifier's, both nets are mounted behind the one
//! port and the demo addresses the classifier *by model name*
//! (protocol v2), the way a multi-model client would.
//!
//! ```bash
//! cargo run --release --example serve_demo [frames] [workers] [tcp]
//! ```

use std::time::Duration;

use anyhow::Result;
use skydiver::coordinator::{DispatchMode, ModelRegistry, ModelSpec,
                            Policy, Service, ServiceConfig, SubmitError,
                            WorkerConfig};
use skydiver::power::EnergyModel;
use skydiver::server::protocol::NET_ANY;
use skydiver::server::{Client, Gateway, GatewayConfig, RequestBody,
                       ResponseBody, WirePayload, WireRequest};
use skydiver::sim::ArchConfig;
use skydiver::snn::{NetKind, NetworkWeights};

/// Stream the digit frames through a loopback TCP gateway with
/// window-8 pipelining — addressed to the `classifier` model by name —
/// then scrape metrics and drain.
fn serve_over_tcp(frames: usize, wcfg: WorkerConfig,
                  scfg: ServiceConfig) -> Result<()> {
    // Registry: always the classifier; the segmenter rides along when
    // its artifacts exist (multi-model serving from one process).
    let mut specs = vec![ModelSpec {
        name: NetKind::Classifier.as_str().to_string(),
        scfg: scfg.clone(),
        wcfg: wcfg.clone(),
    }];
    let seg_wcfg = WorkerConfig { kind: NetKind::Segmenter, ..wcfg };
    if NetworkWeights::load(&seg_wcfg.artifacts,
                            seg_wcfg.variant_name()).is_ok() {
        specs.push(ModelSpec {
            name: NetKind::Segmenter.as_str().to_string(),
            scfg,
            wcfg: seg_wcfg,
        });
    }
    let registry = ModelRegistry::start(specs)?;
    let gw = Gateway::start(GatewayConfig::default(), registry)?;
    let addr = gw.local_addr().to_string();
    println!("gateway on {addr} (models: {:?}); streaming {frames} \
              digit frames over TCP...", gw.model_names());
    let (imgs, labels) = skydiver::data::gen_digits(0x5E12E, frames);
    let pixel_frames: Vec<Vec<u8>> =
        imgs.chunks(28 * 28).map(|c| c.to_vec()).collect();
    let mut client = Client::connect(&addr)?;
    let info = client.info_model("classifier")?;
    println!("classifier contract: {}x{}x{}, {} timesteps ({} model(s) \
              mounted)", info.c, info.h, info.w, info.timesteps,
             info.nmodels);
    let (mut next, mut inflight, mut done, mut correct) =
        (0usize, 0usize, 0usize, 0usize);
    while done < pixel_frames.len() {
        while inflight < 8 && next < pixel_frames.len() {
            client.send(&WireRequest {
                id: next as u64,
                body: RequestBody::Infer {
                    net: NET_ANY,
                    model: "classifier".to_string(),
                    payload: WirePayload::Pixels(
                        pixel_frames[next].clone()),
                },
            })?;
            next += 1;
            inflight += 1;
        }
        let resp = client.recv()?;
        inflight -= 1;
        done += 1;
        if let ResponseBody::Infer { prediction, .. } = resp.body {
            if prediction as usize == labels[resp.id as usize] as usize {
                correct += 1;
            }
        }
    }
    println!("accuracy over TCP : {:.1}% ({}/{})",
             100.0 * correct as f64 / frames as f64, correct, frames);
    println!("\n--- metrics scrape ---\n{}", client.metrics()?);
    client.shutdown_server()?;
    drop(client);
    let report = gw.wait()?;
    for m in &report.models {
        println!("model '{}'      : fps {:.1}, p50/p95 {}/{} us, \
                  balance {:.1}%",
                 m.name, m.serving.served_fps, m.serving.p50_us,
                 m.serving.p95_us,
                 100.0 * m.serving.host_balance_ratio);
    }
    Ok(())
}

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let tcp = args.iter().any(|a| a == "tcp");
    let nums: Vec<usize> =
        args.iter().filter_map(|a| a.parse().ok()).collect();
    let frames: usize = nums.first().copied().unwrap_or(64);
    let workers: usize = nums.get(1).copied().unwrap_or(2);

    let wcfg = WorkerConfig {
        artifacts: skydiver::artifacts_dir(),
        kind: NetKind::Classifier,
        aprc: true,
        policy: Policy::Cbws,
        arch: ArchConfig::default(),
        energy: EnergyModel::default(),
        use_runtime: false, // functional model: no PJRT needed per worker
        timesteps: None,
        sweep_threads: 1, // worker pool is the parallel grain here
    };
    let scfg = ServiceConfig {
        workers,
        batch_max: 8,
        // Small on purpose so the burst exercises backpressure.
        queue_cap: 32,
        batch_wait: Duration::from_millis(2),
        dispatch: DispatchMode::WorkQueue,
        cost_cap: None,
    };

    if tcp {
        return serve_over_tcp(frames, wcfg, scfg);
    }

    println!("spinning up {} workers; submitting {} frames...", workers,
             frames);
    let service = Service::start(scfg, wcfg)?;
    let (imgs, labels) = skydiver::data::gen_digits(0x5E12E, frames);
    let mut stalls = 0usize;
    for (i, img) in imgs.chunks(28 * 28).enumerate() {
        match service.try_submit(i as u64, img.to_vec()) {
            Ok(()) => {}
            Err(SubmitError::Full { .. }) => {
                // Queue full: fall back to the blocking (backpressured)
                // path and remember we were throttled.
                stalls += 1;
                service.submit(i as u64, img.to_vec())?;
            }
            Err(e) => return Err(e.into()),
        }
    }
    let (responses, report) = service.collect(frames, skydiver::CLOCK_HZ)?;
    service.shutdown()?;

    let correct = responses.iter().filter(|r| {
        let pred = r.output_counts.iter().enumerate()
            .max_by_key(|(_, &c)| c).map(|(i, _)| i).unwrap();
        pred == labels[r.id as usize] as usize
    }).count();

    println!("\nframes           : {}", report.frames);
    println!("accuracy         : {:.1}% ({}/{})",
             100.0 * correct as f64 / frames as f64, correct, frames);
    println!("host throughput  : {:.1} frames/s", report.served_fps);
    println!("latency p50/p95  : {} / {} us", report.p50_us,
             report.p95_us);
    println!("sim cycles/frame : {:.0}", report.mean_sim_cycles);
    println!("sim accel FPS    : {:.1} (paper: 22.6 KFPS @ fewer steps)",
             report.sim_fps);
    println!("sim energy/frame : {:.1} uJ (paper: 42.4 uJ)",
             report.mean_energy_uj);
    println!("per-worker load  : {:?}", report.per_worker);
    println!("per-worker busy  : {:?} us", report.per_worker_busy_us);
    println!("host balance     : {:.1}% (total_busy / workers*max_busy)",
             100.0 * report.host_balance_ratio);
    println!("queue depth max  : {}/{} (submit stalled {} times)",
             report.queue_max_depth, report.queue_capacity, stalls);
    Ok(())
}
