//! Serving demo — the L3 coordinator under load: submit a burst of
//! classification frames to the shared work queue + pull-based worker
//! pool and report host throughput, latency percentiles, host-side
//! workload balance, and the simulated accelerator's FPS/energy (the
//! paper's Table I view of the same run).
//!
//! The submit loop uses `try_submit`, so the demo also shows the
//! backpressure path: when the bounded queue fills, the submitter
//! falls back to a blocking `submit` and counts the stall.
//!
//! ```bash
//! cargo run --release --example serve_demo [frames] [workers]
//! ```

use std::time::Duration;

use anyhow::Result;
use skydiver::coordinator::{DispatchMode, Policy, Service, ServiceConfig,
                            SubmitError, WorkerConfig};
use skydiver::power::EnergyModel;
use skydiver::sim::ArchConfig;
use skydiver::snn::NetKind;

fn main() -> Result<()> {
    let frames: usize = std::env::args().nth(1)
        .and_then(|a| a.parse().ok()).unwrap_or(64);
    let workers: usize = std::env::args().nth(2)
        .and_then(|a| a.parse().ok()).unwrap_or(2);

    let wcfg = WorkerConfig {
        artifacts: skydiver::artifacts_dir(),
        kind: NetKind::Classifier,
        aprc: true,
        policy: Policy::Cbws,
        arch: ArchConfig::default(),
        energy: EnergyModel::default(),
        use_runtime: false, // functional model: no PJRT needed per worker
        timesteps: None,
        sweep_threads: 1, // worker pool is the parallel grain here
    };
    let scfg = ServiceConfig {
        workers,
        batch_max: 8,
        // Small on purpose so the burst exercises backpressure.
        queue_cap: 32,
        batch_wait: Duration::from_millis(2),
        dispatch: DispatchMode::WorkQueue,
    };

    println!("spinning up {} workers; submitting {} frames...", workers,
             frames);
    let service = Service::start(scfg, wcfg)?;
    let (imgs, labels) = skydiver::data::gen_digits(0x5E12E, frames);
    let mut stalls = 0usize;
    for (i, img) in imgs.chunks(28 * 28).enumerate() {
        match service.try_submit(i as u64, img.to_vec()) {
            Ok(()) => {}
            Err(SubmitError::Full { .. }) => {
                // Queue full: fall back to the blocking (backpressured)
                // path and remember we were throttled.
                stalls += 1;
                service.submit(i as u64, img.to_vec())?;
            }
            Err(e) => return Err(e.into()),
        }
    }
    let (responses, report) = service.collect(frames, skydiver::CLOCK_HZ)?;
    service.shutdown()?;

    let correct = responses.iter().filter(|r| {
        let pred = r.output_counts.iter().enumerate()
            .max_by_key(|(_, &c)| c).map(|(i, _)| i).unwrap();
        pred == labels[r.id as usize] as usize
    }).count();

    println!("\nframes           : {}", report.frames);
    println!("accuracy         : {:.1}% ({}/{})",
             100.0 * correct as f64 / frames as f64, correct, frames);
    println!("host throughput  : {:.1} frames/s", report.served_fps);
    println!("latency p50/p95  : {} / {} us", report.p50_us,
             report.p95_us);
    println!("sim cycles/frame : {:.0}", report.mean_sim_cycles);
    println!("sim accel FPS    : {:.1} (paper: 22.6 KFPS @ fewer steps)",
             report.sim_fps);
    println!("sim energy/frame : {:.1} uJ (paper: 42.4 uJ)",
             report.mean_energy_uj);
    println!("per-worker load  : {:?}", report.per_worker);
    println!("per-worker busy  : {:?} us", report.per_worker_busy_us);
    println!("host balance     : {:.1}% (total_busy / workers*max_busy)",
             100.0 * report.host_balance_ratio);
    println!("queue depth max  : {}/{} (submit stalled {} times)",
             report.queue_max_depth, report.queue_capacity, stalls);
    Ok(())
}
