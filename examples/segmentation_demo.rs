//! Segmentation demo — the paper's motivating workload (§I, Fig. 2):
//! segment a synthetic road scene, render the mask as ASCII art, and
//! show the per-layer spikerates + channel imbalance that motivate
//! APRC/CBWS.
//!
//! ```bash
//! cargo run --release --example segmentation_demo
//! ```

use anyhow::Result;
use skydiver::coordinator::default_input_rates;
use skydiver::power::EnergyModel;
use skydiver::schedule::cbws::Cbws;
use skydiver::schedule::AprcPredictor;
use skydiver::sim::{ArchConfig, Simulator, TraceSource};
use skydiver::snn::{encode_phased_u8, FunctionalNet, NetworkWeights};

fn main() -> Result<()> {
    let dir = skydiver::artifacts_dir();
    let net = NetworkWeights::load(&dir, "segmenter_aprc")?;
    let (h, w) = (skydiver::data::ROAD_H, skydiver::data::ROAD_W);
    let (imgs, masks) = skydiver::data::gen_road_scenes(0xD3140, 1);

    // HWC -> CHW, encode.
    let mut chw = vec![0u8; 3 * h * w];
    for y in 0..h {
        for x in 0..w {
            for c in 0..3 {
                chw[c * h * w + y * w + x] = imgs[(y * w + x) * 3 + c];
            }
        }
    }
    let inputs = encode_phased_u8(&chw, 3, h, w, net.meta.timesteps);

    // Per-layer spikerates (Fig. 2a shape) from the functional model.
    let mut f = FunctionalNet::new(&net);
    let trace = f.run_frame(&inputs);
    println!("per-layer spikerates (paper Fig. 2a: ~2-18%, avg <8%):");
    for l in 0..net.num_layers() {
        let spikes: usize = trace.iter()
            .map(|s| s[l].spikes.nnz()).sum();
        let neurons = trace[0][l].spikes.len() * inputs.len();
        println!("  conv{}: {:.2}%", l + 1,
                 100.0 * spikes as f64 / neurons as f64);
    }

    // Channel imbalance of the 16-channel layer (Fig. 2b shape).
    let rep = 4;
    let sums: Vec<usize> = (0..trace[0][rep].spikes.c)
        .map(|c| trace.iter()
            .map(|s| s[rep].spikes.nnz_channel(c)).sum())
        .collect();
    println!("channel spike sums (layer {}, Fig. 2b): {:?}", rep + 1, sums);
    println!("max/min = {:.1}x",
             *sums.iter().max().unwrap() as f64
                 / (*sums.iter().min().unwrap() as f64).max(1.0));

    // Simulate + decode the mask.
    let arch = ArchConfig::default();
    let rates = default_input_rates(&net);
    let predictor = AprcPredictor::from_network(&net, &rates);
    let sim = Simulator::new(arch, &net, &Cbws::default(), &predictor);
    let golden: Vec<Vec<_>> = trace.into_iter()
        .map(|s| s.into_iter().map(|o| o.spikes).collect())
        .collect();
    let report = sim.run_frame(&inputs, &TraceSource::Golden(golden))?;

    let thr = net.meta.seg_rate_threshold.unwrap_or(0.5);
    let t = net.meta.timesteps as f64;
    let (_, oh, ow) = net.layer_output_shape(net.num_layers() - 1);
    let (dh, dw) = ((oh - h) / 2, (ow - w) / 2);
    let (mut inter, mut union) = (0usize, 0usize);
    println!("\npredicted road mask (every 4th row/col; #=road):");
    for y in (0..h).step_by(4) {
        let mut line = String::new();
        for x in (0..w).step_by(4) {
            let rate = report.output_counts[(y + dh) * ow + (x + dw)]
                as f64 / t;
            line.push(if rate >= thr { '#' } else { '.' });
        }
        println!("  {line}");
    }
    for y in 0..h {
        for x in 0..w {
            let p = report.output_counts[(y + dh) * ow + (x + dw)]
                as f64 / t >= thr;
            let g = masks[y * w + x] == 1;
            inter += (p && g) as usize;
            union += (p || g) as usize;
        }
    }
    let energy = EnergyModel::default()
        .frame_energy(&report, arch.clock_hz);
    println!("\nIoU vs ground truth: {:.4}",
             inter as f64 / union.max(1) as f64);
    println!("simulated: {} cycles -> {:.1} FPS, {:.2} mJ/frame, balance {:.2}%",
             report.total_cycles, report.fps(arch.clock_hz),
             energy.total_j * 1e3,
             100.0 * report.balance_weighted(arch.n_spes));
    Ok(())
}
