//! Integration: scheduling on the real trained networks — prediction
//! quality (Fig. 6's correlation) and schedule quality (Fig. 7's
//! ordering) measured end-to-end.

use skydiver::coordinator::default_input_rates;
use skydiver::schedule::baselines::{Contiguous, Oracle};
use skydiver::schedule::cbws::Cbws;
use skydiver::schedule::{AprcPredictor, Scheduler};
use skydiver::snn::{encode_phased_u8, FunctionalNet, NetworkWeights};

fn load(name: &str) -> NetworkWeights {
    NetworkWeights::load(&skydiver::artifacts_dir(), name)
        .expect("run `make artifacts` first")
}

/// Actual per-input-channel workloads of one layer over a digit frame.
fn actual_workload(net: &NetworkWeights, layer: usize) -> Vec<f64> {
    let (imgs, _) = skydiver::data::gen_digits(0x77, 4);
    let (c, _, _) = net.layer_input_shape(layer);
    let mut wl = vec![0.0f64; c];
    for img in imgs.chunks(28 * 28) {
        let inputs = encode_phased_u8(img, 1, 28, 28, net.meta.timesteps);
        let mut f = FunctionalNet::new(net);
        for step in f.run_frame(&inputs) {
            let map = &step[layer - 1].spikes;
            for (ch, w) in wl.iter_mut().enumerate() {
                *w += map.nnz_channel(ch) as f64;
            }
        }
    }
    wl
}

fn pearson(a: &[f64], b: &[f64]) -> f64 {
    let n = a.len() as f64;
    let ma = a.iter().sum::<f64>() / n;
    let mb = b.iter().sum::<f64>() / n;
    let (mut cov, mut va, mut vb) = (0.0, 0.0, 0.0);
    for (&x, &y) in a.iter().zip(b) {
        cov += (x - ma) * (y - mb);
        va += (x - ma) * (x - ma);
        vb += (y - mb) * (y - mb);
    }
    cov / (va.sqrt() * vb.sqrt() + 1e-12)
}

#[test]
fn aprc_prediction_correlates_on_aprc_net() {
    let net = load("classifier_aprc");
    let rates = default_input_rates(&net);
    let pred = AprcPredictor::from_network(&net, &rates);
    // Layer 2's input channels = layer 1's outputs (16 channels).
    let predicted = pred.layer(1).to_vec();
    let actual = actual_workload(&net, 1);
    let r = pearson(&predicted, &actual);
    assert!(r > 0.6, "APRC prediction correlation too low: {r}");
}

#[test]
fn aprc_prediction_stronger_than_plain() {
    let aprc = load("classifier_aprc");
    let plain = load("classifier_plain");
    let corr = |net: &NetworkWeights| {
        let rates = default_input_rates(net);
        let pred = AprcPredictor::from_network(net, &rates);
        pearson(&pred.layer(2).to_vec(), &actual_workload(net, 2))
    };
    let (ra, rp) = (corr(&aprc), corr(&plain));
    // Fig. 6 shape: APRC proportional, plain irregular.
    assert!(ra > rp,
            "APRC correlation {ra} not better than plain {rp}");
}

#[test]
fn cbws_schedule_near_oracle_on_real_workload() {
    let net = load("segmenter_aprc");
    let rates = default_input_rates(&net);
    let pred = AprcPredictor::from_network(&net, &rates);

    // Real workload of a mid layer on one road frame.
    let (imgs, _) = skydiver::data::gen_road_scenes(0x5EED5, 1);
    let (h, w) = (skydiver::data::ROAD_H, skydiver::data::ROAD_W);
    let mut chw = vec![0u8; 3 * h * w];
    for y in 0..h {
        for x in 0..w {
            for c in 0..3 {
                chw[c * h * w + y * w + x] = imgs[(y * w + x) * 3 + c];
            }
        }
    }
    let inputs = encode_phased_u8(&chw, 3, h, w, net.meta.timesteps);
    let mut f = FunctionalNet::new(&net);
    let layer = 3usize; // input channels = layer 2's 32 outputs
    let (c, _, _) = net.layer_input_shape(layer);
    let mut workload = vec![0.0f64; c];
    for step in f.run_frame(&inputs) {
        for (ch, wv) in workload.iter_mut().enumerate() {
            *wv += step[layer - 1].spikes.nnz_channel(ch) as f64;
        }
    }

    let n = 4;
    // Deployment prediction: offline profile on a separate calibration
    // frame (APRC weight-only prediction is weaker on ANN-converted
    // weights; see EXPERIMENTS.md fig7 notes).
    let calib = {
        let (imgs, _) = skydiver::data::gen_road_scenes(0xCA11B0, 1);
        let mut chw2 = vec![0u8; 3 * h * w];
        for y in 0..h {
            for x in 0..w {
                for c in 0..3 {
                    chw2[c * h * w + y * w + x] = imgs[(y * w + x) * 3 + c];
                }
            }
        }
        vec![encode_phased_u8(&chw2, 3, h, w, net.meta.timesteps)]
    };
    let prof = AprcPredictor::from_profile(&net, &calib);
    let cbws = Cbws::default().assign(prof.layer(layer), n);
    let cont = Contiguous.assign(pred.layer(layer), n);
    let oracle = Oracle.assign(&workload, n);

    let b_cbws = cbws.balance_ratio(&workload);
    let b_cont = cont.balance_ratio(&workload);
    let b_oracle = oracle.balance_ratio(&workload);

    assert!(b_cbws > b_cont,
            "CBWS {b_cbws} not better than contiguous {b_cont}");
    assert!(b_oracle >= b_cbws - 1e-9, "oracle must upper-bound");
    assert!(b_cbws > 0.8 * b_oracle,
            "CBWS {b_cbws} too far from oracle {b_oracle}");
}

#[test]
fn schedules_cover_every_layer_of_every_variant() {
    for name in ["classifier_aprc", "classifier_plain", "segmenter_aprc",
                 "segmenter_plain"] {
        let net = load(name);
        let rates = default_input_rates(&net);
        let pred = AprcPredictor::from_network(&net, &rates);
        for s in skydiver::schedule::all_schedulers() {
            for l in 0..net.num_layers() {
                let k = pred.layer(l).len();
                let p = s.assign(pred.layer(l), 8);
                assert!(p.validate(k),
                        "{name} layer {l}: {} invalid", s.name());
            }
        }
    }
}
