//! Hermetic loopback integration for the network serving subsystem:
//! a real `Gateway` on 127.0.0.1, synthetic artifacts in a temp dir,
//! real TCP clients. Covers the acceptance criteria: ≥4 concurrent
//! connections streaming ≥1k frames with predictions byte-identical
//! to the in-process `Service` path, BUSY shedding under a tiny
//! queue (counted in metrics), malformed-frame rejection, the
//! reserved-request-id rejection, connection capping, the spikes
//! payload path, and graceful drain-shutdown — no hangs, no panics.
//! (Multi-model routing has its own suite:
//! `integration_multimodel.rs`.)

use std::collections::HashMap;
use std::io::{BufReader, Write};
use std::net::TcpStream;
use std::path::PathBuf;
use std::thread;
use std::time::Duration;

use skydiver::coordinator::{DispatchMode, Policy, Service,
                            ServiceConfig, WorkerConfig};
use skydiver::data::SplitMix64;
use skydiver::power::EnergyModel;
use skydiver::server::protocol::{read_frame, KIND_REQUEST, MAGIC,
                                 NET_ANY, VERSION};
use skydiver::server::{Client, ErrorCode, Gateway, GatewayConfig,
                       RequestBody, ResponseBody, WirePayload,
                       WireRequest, WireResponse};
use skydiver::sim::ArchConfig;
use skydiver::snn::{encode_phased_u8, NetKind};

const SIDE: usize = 24; // tiny: 1k frames must stay fast in debug

fn artifacts(label: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(
        format!("skydiver-gateway-{label}-{}", std::process::id()));
    skydiver::data::write_synthetic_classifier(&dir, SIDE).unwrap();
    dir
}

fn worker_cfg(artifacts: PathBuf) -> WorkerConfig {
    WorkerConfig {
        artifacts,
        kind: NetKind::Classifier,
        aprc: true,
        policy: Policy::Cbws,
        arch: ArchConfig::default(),
        energy: EnergyModel::default(),
        use_runtime: false,
        timesteps: None, // meta timesteps (6)
        sweep_threads: 1,
        temporal: true,
    }
}

fn service_cfg(workers: usize, queue_cap: usize) -> ServiceConfig {
    ServiceConfig {
        workers,
        workers_max: 0,
        batch_max: 8,
        queue_cap,
        batch_wait: Duration::from_millis(2),
        dispatch: DispatchMode::WorkQueue,
        cost_cap: None,
    }
}

fn start_gateway(label: &str, workers: usize, queue_cap: usize,
                 max_conns: usize) -> (Gateway, String) {
    let gcfg = GatewayConfig {
        addr: "127.0.0.1:0".into(),
        max_conns,
        drain_timeout: Duration::from_secs(30),
        ..GatewayConfig::default()
    };
    let gw = Gateway::start_single(gcfg, service_cfg(workers, queue_cap),
                                   worker_cfg(artifacts(label)))
        .expect("gateway start");
    let addr = gw.local_addr().to_string();
    (gw, addr)
}

/// Deterministic mixed workload, regenerable from (seed, id): every
/// 4th frame dense-random (expensive), the rest sparse (cheap).
fn frame_pixels(seed: u64, id: u64, n: usize) -> Vec<u8> {
    let mut rng = SplitMix64::new(seed ^ id.wrapping_mul(0x9E37));
    if id % 4 == 0 {
        (0..n).map(|_| rng.next_below(256) as u8).collect()
    } else {
        (0..n)
            .map(|_| if rng.next_below(100) < 5 { 255 } else { 0 })
            .collect()
    }
}

/// Acceptance: 4 concurrent connections stream 1000 frames through
/// the gateway with window-8 pipelining; every prediction is
/// byte-identical to the in-process `Service` on the same inputs.
#[test]
fn loopback_1k_frames_match_in_process_service() {
    const CONNS: usize = 4;
    const PER_CONN: u64 = 250;
    let (gw, addr) = start_gateway("parity", 4, 256, 64);

    let results: Vec<HashMap<u64, Vec<u32>>> = thread::scope(|s| {
        let handles: Vec<_> = (0..CONNS)
            .map(|ci| {
                let addr = addr.clone();
                s.spawn(move || {
                    let mut client = Client::connect(&addr).unwrap();
                    client.set_read_timeout(
                        Some(Duration::from_secs(120))).unwrap();
                    let info = client.info().unwrap();
                    let n = info.pixels_len();
                    let mut out: HashMap<u64, Vec<u32>> =
                        HashMap::new();
                    let (mut next, mut inflight) = (0u64, 0usize);
                    while out.len() < PER_CONN as usize {
                        while inflight < 8 && next < PER_CONN {
                            let gid = ci as u64 * 1_000 + next;
                            client.send(&WireRequest {
                                id: gid,
                                body: RequestBody::Infer {
                                    net: info.net,
                                    model: String::new(),
                                    payload: WirePayload::Pixels(
                                        frame_pixels(0xF00D, gid, n)),
                                },
                            }).unwrap();
                            inflight += 1;
                            next += 1;
                        }
                        let resp = client.recv().unwrap();
                        inflight -= 1;
                        match resp.body {
                            ResponseBody::Infer {
                                output_counts, ..
                            } => {
                                out.insert(resp.id, output_counts);
                            }
                            other => panic!("unexpected: {other:?}"),
                        }
                    }
                    out
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });

    // Graceful drain through the wire.
    Client::connect(&addr).unwrap().shutdown_server().unwrap();
    let report = gw.wait().expect("gateway wait");
    assert_eq!(report.counters.served, (CONNS as u64) * PER_CONN);
    assert_eq!(report.counters.bad_request, 0);
    assert_eq!(report.counters.internal, 0);
    let serving = &report.default_model().serving;
    assert!(serving.worker_failures.is_empty(),
            "{:?}", serving.worker_failures);
    assert!(serving.per_worker.iter().all(|&c| c > 0),
            "1k pipelined frames must reach all 4 workers: {:?}",
            serving.per_worker);
    // The single mounted model accounts for every gateway-level serve.
    assert_eq!(report.default_model().counters.served,
               report.counters.served);
    assert_eq!(report.default_model().name, "classifier");

    // The same 1000 frames through the in-process Service.
    let service = Service::start(service_cfg(2, 256),
                                 worker_cfg(artifacts("parity-ref")))
        .unwrap();
    let n = service.frame_spec().pixels_len();
    for ci in 0..CONNS as u64 {
        for i in 0..PER_CONN {
            let gid = ci * 1_000 + i;
            service.submit(gid, frame_pixels(0xF00D, gid, n)).unwrap();
        }
    }
    let (resps, _) = service
        .collect_within(CONNS * PER_CONN as usize, skydiver::CLOCK_HZ,
                        Duration::from_secs(300))
        .unwrap();
    service.shutdown().unwrap();
    let expected: HashMap<u64, Vec<u32>> =
        resps.into_iter().map(|r| (r.id, r.output_counts)).collect();

    let mut total = 0usize;
    for out in &results {
        for (gid, counts) in out {
            assert_eq!(counts, expected.get(gid).unwrap(),
                       "frame {gid}: wire path diverged from \
                        in-process path");
            total += 1;
        }
    }
    assert_eq!(total, CONNS * PER_CONN as usize);
}

/// The spikes payload path: pre-encoding client-side must produce the
/// exact same predictions as sending raw pixels.
#[test]
fn spike_payload_matches_pixel_payload() {
    let (gw, addr) = start_gateway("spikes", 2, 64, 16);
    let mut client = Client::connect(&addr).unwrap();
    let info = client.info().unwrap();
    let n = info.pixels_len();
    for id in 0..12u64 {
        let pixels = frame_pixels(0x5EED, id, n);
        let via_pixels = client
            .infer_pixels(id, "", pixels.clone())
            .unwrap();
        let train = encode_phased_u8(&pixels, info.c, info.h, info.w,
                                     info.timesteps);
        let mut words = Vec::new();
        for map in &train {
            for ch in 0..info.c {
                words.extend_from_slice(map.channel_words(ch));
            }
        }
        let via_spikes = client
            .infer_spikes(1000 + id, "", info.timesteps as u32, words)
            .unwrap();
        match (via_pixels.body, via_spikes.body) {
            (ResponseBody::Infer { output_counts: a, .. },
             ResponseBody::Infer { output_counts: b, .. }) => {
                assert_eq!(a, b, "frame {id}: spikes diverged");
            }
            other => panic!("unexpected: {other:?}"),
        }
    }
    drop(client);
    gw.stop_and_wait().unwrap();
}

/// Overload with a deliberately tiny queue: BUSY responses surface
/// (and are counted in metrics), then the server drains and shuts
/// down cleanly — no hang, no panic.
#[test]
fn overload_sheds_busy_counts_it_and_drains() {
    let (gw, addr) = start_gateway("overload", 1, 1, 8);
    let mut client = Client::connect(&addr).unwrap();
    client.set_read_timeout(Some(Duration::from_secs(120))).unwrap();
    let info = client.info().unwrap();
    let n = info.pixels_len();

    // Burst far past the cap-1 queue without reading responses.
    let burst = 64u64;
    for id in 0..burst {
        client.send(&WireRequest {
            id,
            body: RequestBody::Infer {
                net: NET_ANY,
                model: String::new(),
                payload: WirePayload::Pixels(
                    frame_pixels(0xB057, id, n)),
            },
        }).unwrap();
    }
    let (mut ok, mut busy) = (0u64, 0u64);
    for _ in 0..burst {
        let resp = client.recv().unwrap();
        match resp.body {
            ResponseBody::Infer { .. } => ok += 1,
            ResponseBody::Error { code: ErrorCode::Busy, .. } => {
                busy += 1;
            }
            other => panic!("unexpected: {other:?}"),
        }
    }
    assert!(busy > 0,
            "64 pipelined frames against a cap-1 queue and a 1-worker \
             pool must shed");
    assert!(ok > 0, "some frames must still be served");
    assert_eq!(ok + busy, burst);

    // Shed load is visible in the metrics exposition — both the
    // gateway-wide counter and the per-model labelled series.
    let text = client.metrics().unwrap();
    let busy_line = text.lines()
        .find(|l| l.starts_with("skydiver_busy_total "))
        .expect("metrics must expose skydiver_busy_total");
    let v: f64 = busy_line.rsplit(' ').next().unwrap().parse().unwrap();
    assert!(v >= busy as f64, "metrics busy {v} < observed {busy}");
    assert!(text.contains(
        "skydiver_model_busy_total{model=\"classifier\"}"));
    assert!(text.contains(
        "skydiver_queue_capacity{model=\"classifier\"}"));
    assert!(text.contains(
        "skydiver_latency_us{model=\"classifier\",quantile=\"0.99\"}"));

    // Connection-lifecycle + reactor series. This client is the only
    // connection, so active is exactly 1 and accepted at least 1.
    let metric = |name: &str| -> f64 {
        text.lines()
            .find(|l| l.starts_with(name)
                  && l.as_bytes().get(name.len()) == Some(&b' '))
            .unwrap_or_else(|| panic!("metrics must expose {name}"))
            .rsplit(' ').next().unwrap().parse().unwrap()
    };
    assert!(metric("skydiver_connections_accepted_total") >= 1.0);
    assert_eq!(metric("skydiver_connections_active"), 1.0);
    assert_eq!(metric("skydiver_connections_shed_total"), 0.0);
    assert_eq!(metric("skydiver_connections_backpressure_shed_total"),
               0.0);
    let shards = metric("skydiver_reactor_shards");
    assert!(shards >= 1.0);
    // One wakeups series and one connections gauge per shard, and
    // this connection's shard has polled at least once to serve us.
    let wakeups: Vec<f64> = text.lines()
        .filter(|l| l.starts_with(
            "skydiver_reactor_wakeups_total{shard="))
        .map(|l| l.rsplit(' ').next().unwrap().parse().unwrap())
        .collect();
    assert_eq!(wakeups.len(), shards as usize);
    assert!(wakeups.iter().sum::<f64>() >= 1.0);
    let shard_conns: Vec<f64> = text.lines()
        .filter(|l| l.starts_with("skydiver_reactor_connections{shard="))
        .map(|l| l.rsplit(' ').next().unwrap().parse().unwrap())
        .collect();
    assert_eq!(shard_conns.len(), shards as usize);
    assert_eq!(shard_conns.iter().sum::<f64>(), 1.0);

    client.shutdown_server().unwrap();
    drop(client);
    let report = gw.wait().expect("drain-then-shutdown must not hang");
    assert_eq!(report.counters.served, ok);
    assert_eq!(report.counters.busy, busy);
    assert_eq!(report.counters.served + report.counters.busy,
               report.counters.requests);
    assert_eq!(report.default_model().serving.queue_capacity, 1);
    assert_eq!(report.default_model().counters.busy, busy);
}

/// Malformed frames: framing damage answers with BAD_REQUEST and
/// disconnects; body damage answers with BAD_REQUEST and keeps the
/// connection; the server survives all of it.
#[test]
fn malformed_frames_are_rejected_cleanly() {
    use skydiver::server::protocol::KIND_RESPONSE;
    let (gw, addr) = start_gateway("malformed", 1, 16, 8);

    let expect_bad_request = |r: &mut BufReader<TcpStream>| {
        let (ver, body) = read_frame(r, KIND_RESPONSE).unwrap().unwrap();
        let resp = WireResponse::decode_body(ver, &body).unwrap();
        // Connection-level errors answer on the reserved id, so they
        // can never be confused with a pipelined request's response.
        assert_eq!(resp.id, u64::MAX);
        match resp.body {
            ResponseBody::Error { code, .. } => {
                assert_eq!(code, ErrorCode::BadRequest);
            }
            other => panic!("expected BAD_REQUEST, got {other:?}"),
        }
    };

    // (a) Bad magic: typed error, then clean disconnect.
    {
        let mut s = TcpStream::connect(&addr).unwrap();
        s.write_all(b"XXXXJUNKJUNKJUNKJUNK").unwrap();
        s.flush().unwrap();
        let mut r = BufReader::new(s.try_clone().unwrap());
        expect_bad_request(&mut r);
        assert!(matches!(read_frame(&mut r, KIND_RESPONSE), Ok(None)),
                "server must close after framing damage");
    }
    // (b) Truncated header then close: server must survive.
    {
        let mut s = TcpStream::connect(&addr).unwrap();
        s.write_all(&MAGIC[..2]).unwrap();
        s.flush().unwrap();
    }
    // (c) Oversized length: typed error, then disconnect.
    {
        let mut s = TcpStream::connect(&addr).unwrap();
        let mut hdr = Vec::new();
        hdr.extend_from_slice(&MAGIC);
        hdr.push(VERSION);
        hdr.push(KIND_REQUEST);
        hdr.extend_from_slice(&u32::MAX.to_le_bytes());
        s.write_all(&hdr).unwrap();
        s.flush().unwrap();
        let mut r = BufReader::new(s.try_clone().unwrap());
        expect_bad_request(&mut r);
        assert!(matches!(read_frame(&mut r, KIND_RESPONSE), Ok(None)));
    }
    // (d) Valid frame, garbage body: BAD_REQUEST and the connection
    // stays usable.
    {
        let mut s = TcpStream::connect(&addr).unwrap();
        let mut f = Vec::new();
        f.extend_from_slice(&MAGIC);
        f.push(VERSION);
        f.push(KIND_REQUEST);
        f.extend_from_slice(&12u32.to_le_bytes());
        f.extend_from_slice(&[0xFF; 12]);
        s.write_all(&f).unwrap();
        s.flush().unwrap();
        let mut r = BufReader::new(s.try_clone().unwrap());
        expect_bad_request(&mut r);
        // Same connection, now a valid request:
        s.write_all(&WireRequest {
            id: 9,
            body: RequestBody::Info { model: String::new() },
        }.encode().unwrap()).unwrap();
        s.flush().unwrap();
        let (ver, body) =
            read_frame(&mut r, KIND_RESPONSE).unwrap().unwrap();
        let resp = WireResponse::decode_body(ver, &body).unwrap();
        assert_eq!(resp.id, 9);
        assert!(matches!(resp.body, ResponseBody::Info { .. }));
    }
    // (e) After all the abuse, normal service continues; a wrong-size
    // payload is a per-request BAD_REQUEST, not a dead worker.
    let mut client = Client::connect(&addr).unwrap();
    let info = client.info().unwrap();
    let good = vec![0u8; info.pixels_len()];
    let resp = client.infer_pixels(1, "", good.clone()).unwrap();
    assert!(matches!(resp.body, ResponseBody::Infer { .. }));
    let resp = client.infer_pixels(2, "", vec![0u8; 3]).unwrap();
    match resp.body {
        ResponseBody::Error { code, .. } => {
            assert_eq!(code, ErrorCode::BadRequest);
        }
        other => panic!("expected BAD_REQUEST, got {other:?}"),
    }
    let resp = client.infer_pixels(3, "", good).unwrap();
    assert!(matches!(resp.body, ResponseBody::Infer { .. }),
            "worker pool must survive bad payloads");
    drop(client);

    let report = gw.stop_and_wait().unwrap();
    assert!(report.counters.bad_request >= 4);
    assert!(report.default_model().serving.worker_failures.is_empty(),
            "bad requests must never kill workers: {:?}",
            report.default_model().serving.worker_failures);
}

/// The reserved connection-error id (`u64::MAX`) cannot name a
/// request: the gateway must answer `BAD_REQUEST` instead of serving
/// it — a served response with that id would be indistinguishable
/// from a connection-level failure. The connection survives and no
/// worker ever sees the frame.
#[test]
fn reserved_request_id_is_rejected_with_bad_request() {
    use skydiver::server::protocol::KIND_RESPONSE;
    let (gw, addr) = start_gateway("reserved-id", 1, 16, 8);

    // Client::send refuses the reserved id, so craft the frame
    // directly — exactly what a buggy or hostile client would put on
    // the wire.
    let mut s = TcpStream::connect(&addr).unwrap();
    let mut r = BufReader::new(s.try_clone().unwrap());

    // A well-formed Infer with the reserved id (correct payload size,
    // so only the id check can reject it).
    let n = SIDE * SIDE;
    let evil = WireRequest {
        id: u64::MAX,
        body: RequestBody::Infer {
            net: NET_ANY,
            model: String::new(),
            payload: WirePayload::Pixels(vec![7u8; n]),
        },
    }.encode().unwrap();
    s.write_all(&evil).unwrap();
    s.flush().unwrap();
    let (ver, body) = read_frame(&mut r, KIND_RESPONSE).unwrap().unwrap();
    let resp = WireResponse::decode_body(ver, &body).unwrap();
    assert_eq!(resp.id, u64::MAX);
    match resp.body {
        ResponseBody::Error { code, detail } => {
            assert_eq!(code, ErrorCode::BadRequest);
            assert!(detail.contains("reserved"), "{detail}");
        }
        other => panic!("expected BAD_REQUEST, got {other:?}"),
    }

    // The connection is still usable: a normal request on it serves.
    let ok = WireRequest {
        id: 1,
        body: RequestBody::Infer {
            net: NET_ANY,
            model: String::new(),
            payload: WirePayload::Pixels(vec![7u8; n]),
        },
    }.encode().unwrap();
    s.write_all(&ok).unwrap();
    s.flush().unwrap();
    let (ver, body) = read_frame(&mut r, KIND_RESPONSE).unwrap().unwrap();
    let resp = WireResponse::decode_body(ver, &body).unwrap();
    assert_eq!(resp.id, 1);
    assert!(matches!(resp.body, ResponseBody::Infer { .. }));
    drop((s, r));

    let report = gw.stop_and_wait().unwrap();
    assert!(report.counters.bad_request >= 1);
    // The rejected frame never counted as an admitted request and
    // never reached a worker.
    assert_eq!(report.counters.requests, 1);
    assert_eq!(report.counters.served, 1);
    assert!(report.default_model().serving.worker_failures.is_empty());
}

/// Connections beyond `max_conns` get a typed BUSY frame and a close;
/// existing connections keep working.
#[test]
fn connection_cap_sheds_with_typed_busy() {
    use skydiver::server::protocol::KIND_RESPONSE;
    let (gw, addr) = start_gateway("conncap", 1, 16, 1);
    let mut first = Client::connect(&addr).unwrap();
    let info = first.info().unwrap(); // the one allowed connection

    // Give the accept loop a moment to have registered the first
    // connection before probing the cap.
    thread::sleep(Duration::from_millis(100));
    let second = TcpStream::connect(&addr).unwrap();
    let mut r = BufReader::new(second);
    let (ver, body) = read_frame(&mut r, KIND_RESPONSE).unwrap().unwrap();
    let resp = WireResponse::decode_body(ver, &body).unwrap();
    assert_eq!(resp.id, u64::MAX, "shed is a connection-level error");
    match resp.body {
        ResponseBody::Error { code, .. } => {
            assert_eq!(code, ErrorCode::Busy);
        }
        other => panic!("expected BUSY shed, got {other:?}"),
    }
    assert!(matches!(read_frame(&mut r, KIND_RESPONSE), Ok(None)));

    // The first connection is unaffected.
    let resp = first
        .infer_pixels(1, "", vec![0u8; info.pixels_len()])
        .unwrap();
    assert!(matches!(resp.body, ResponseBody::Infer { .. }));
    drop(first);

    let report = gw.stop_and_wait().unwrap();
    assert!(report.counters.conns_rejected >= 1);
}
