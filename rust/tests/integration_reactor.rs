//! Transport-layer integration for the sharded reactor gateway:
//! adversarial clients (slow-loris, stalled reader, mid-frame
//! disconnect) and the c10k acceptance test — thousands of concurrent
//! multiplexed connections with responses equivalent to the
//! in-process `Service` path and thread count independent of
//! connection count.

use std::io::{BufReader, Write};
use std::net::TcpStream;
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Arc;
use std::thread;
use std::time::Duration;

use skydiver::coordinator::{DispatchMode, Policy, Service,
                            ServiceConfig, WorkerConfig};
use skydiver::power::EnergyModel;
use skydiver::server::loadgen::{self, LoadGenConfig, TrafficMode};
use skydiver::server::protocol::{read_frame, KIND_RESPONSE, NET_ANY};
use skydiver::server::reactor;
use skydiver::server::{Client, Gateway, GatewayConfig, RequestBody,
                       ResponseBody, WirePayload, WireRequest,
                       WireResponse};
use skydiver::sim::ArchConfig;
use skydiver::snn::NetKind;

const SIDE: usize = 16; // small frames: c10k must stay fast in debug

fn artifacts(label: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(
        format!("skydiver-reactor-{label}-{}", std::process::id()));
    skydiver::data::write_synthetic_classifier(&dir, SIDE).unwrap();
    dir
}

fn worker_cfg(artifacts: PathBuf) -> WorkerConfig {
    WorkerConfig {
        artifacts,
        kind: NetKind::Classifier,
        aprc: true,
        policy: Policy::Cbws,
        arch: ArchConfig::default(),
        energy: EnergyModel::default(),
        use_runtime: false,
        timesteps: None,
        sweep_threads: 1,
        temporal: true,
    }
}

fn service_cfg(workers: usize, queue_cap: usize) -> ServiceConfig {
    ServiceConfig {
        workers,
        workers_max: 0,
        batch_max: 8,
        queue_cap,
        batch_wait: Duration::from_millis(2),
        dispatch: DispatchMode::WorkQueue,
        cost_cap: None,
    }
}

fn start_gateway(label: &str, gcfg: GatewayConfig, workers: usize,
                 queue_cap: usize) -> (Gateway, String) {
    let gw = Gateway::start_single(gcfg, service_cfg(workers, queue_cap),
                                   worker_cfg(artifacts(label)))
        .expect("gateway start");
    let addr = gw.local_addr().to_string();
    (gw, addr)
}

/// Live thread count of this process (Linux); `None` elsewhere.
fn thread_count() -> Option<usize> {
    std::fs::read_dir("/proc/self/task").ok().map(|d| d.count())
}

/// Slow-loris: a valid request trickled in one byte at a time across
/// many poll rounds must decode incrementally and serve normally —
/// and must not stall any other connection while it drips.
#[test]
fn slow_loris_single_bytes_decode_and_serve() {
    let (gw, addr) = start_gateway(
        "loris", GatewayConfig::default(), 1, 16);
    let mut fast = Client::connect(&addr).unwrap();
    let n = fast.info().unwrap().pixels_len();

    let frame = WireRequest {
        id: 7,
        body: RequestBody::Infer {
            net: NET_ANY,
            model: String::new(),
            payload: WirePayload::Pixels(vec![9u8; n]),
        },
    }.encode().unwrap();

    let mut slow = TcpStream::connect(&addr).unwrap();
    let mut r = BufReader::new(slow.try_clone().unwrap());
    for (i, b) in frame.iter().enumerate() {
        slow.write_all(std::slice::from_ref(b)).unwrap();
        slow.flush().unwrap();
        if i % 16 == 0 {
            // Spread the drip across poll rounds, and interleave a
            // full request on the fast connection: the loris must not
            // block anyone else.
            thread::sleep(Duration::from_millis(2));
            let resp = fast
                .infer_pixels(i as u64, "", vec![3u8; n]).unwrap();
            assert!(matches!(resp.body, ResponseBody::Infer { .. }));
        }
    }
    let (ver, body) = read_frame(&mut r, KIND_RESPONSE).unwrap().unwrap();
    let resp = WireResponse::decode_body(ver, &body).unwrap();
    assert_eq!(resp.id, 7);
    assert!(matches!(resp.body, ResponseBody::Infer { .. }),
            "byte-at-a-time frame must decode and serve: {:?}",
            resp.body);
    drop((slow, r, fast));

    let report = gw.stop_and_wait().unwrap();
    assert_eq!(report.counters.bad_request, 0);
    assert_eq!(report.counters.internal, 0);
}

/// A reader that stops reading while responses pile up gets shed once
/// its outbound queue crosses `write_buf_cap` — counted, bounded,
/// and the gateway survives.
#[test]
fn stalled_reader_is_shed_by_write_backpressure() {
    let gcfg = GatewayConfig {
        write_buf_cap: 64 * 1024,
        ..GatewayConfig::default()
    };
    let (gw, addr) = start_gateway("backpressure", gcfg, 1, 16);

    // Flood metrics requests (each response is a few KB) and read
    // nothing back. The count is sized so the responses far exceed
    // what loopback kernel buffers can absorb — past that, unwritten
    // frames pile up in the outbound queue and cross the 64 KiB cap.
    let mut s = TcpStream::connect(&addr).unwrap();
    for id in 0..8192u64 {
        let req = WireRequest { id, body: RequestBody::Metrics }
            .encode().unwrap();
        // The gateway may shed (close) the connection while the flood
        // is still being written; that write error IS the expected
        // outcome, not a test failure.
        if s.write_all(&req).is_err() {
            break;
        }
    }
    let _ = s.flush();

    // Wait until the gateway registers the shed.
    let stop_handle = gw.stop_handle();
    let mut shed = 0;
    for _ in 0..200 {
        shed = gw.counters().conns_shed;
        if shed > 0 {
            break;
        }
        thread::sleep(Duration::from_millis(25));
    }
    assert!(shed >= 1,
            "a stalled reader must trip write backpressure");

    // A fresh, well-behaved connection still serves.
    let mut client = Client::connect(&addr).unwrap();
    let n = client.info().unwrap().pixels_len();
    let resp = client.infer_pixels(1, "", vec![1u8; n]).unwrap();
    assert!(matches!(resp.body, ResponseBody::Infer { .. }));
    drop(client);
    drop(s);

    stop_handle.trigger();
    let report = gw.wait().unwrap();
    assert!(report.counters.conns_shed >= 1);
    // Backpressure sheds are not accept-cap rejections.
    assert_eq!(report.counters.conns_rejected, 0);
}

/// Disconnecting mid-frame kills only that connection: its completed
/// requests still run (responses are dropped), other connections are
/// untouched, and shutdown does not hang on the orphaned requests.
#[test]
fn mid_frame_disconnect_fails_only_that_connection() {
    let (gw, addr) = start_gateway(
        "midframe", GatewayConfig::default(), 1, 16);
    let mut healthy = Client::connect(&addr).unwrap();
    let n = healthy.info().unwrap().pixels_len();

    {
        let mut s = TcpStream::connect(&addr).unwrap();
        // One complete request (will be admitted and served), then
        // half of a second frame, then an abrupt close.
        let full = WireRequest {
            id: 1,
            body: RequestBody::Infer {
                net: NET_ANY,
                model: String::new(),
                payload: WirePayload::Pixels(vec![5u8; n]),
            },
        }.encode().unwrap();
        s.write_all(&full).unwrap();
        s.write_all(&full[..full.len() / 2]).unwrap();
        s.flush().unwrap();
    } // dropped: RST/EOF mid-frame

    // The healthy connection keeps serving while and after the other
    // one dies.
    for id in 0..8u64 {
        let resp = healthy.infer_pixels(id, "", vec![2u8; n]).unwrap();
        assert!(matches!(resp.body, ResponseBody::Infer { .. }));
    }
    drop(healthy);

    // Shutdown must not wait on the dead connection's orphans.
    let report = gw.stop_and_wait().unwrap();
    assert_eq!(report.counters.internal, 0);
    assert_eq!(report.counters.bad_request, 0);
    assert!(report.counters.served >= 8);
    assert!(report.default_model().serving.worker_failures.is_empty());
}

/// Idle connections cost fds, not threads: parking many connections
/// on the gateway must not change the process thread count.
#[test]
fn idle_connections_add_no_threads() {
    if thread_count().is_none() {
        eprintln!("skipping: /proc/self/task unavailable");
        return;
    }
    let gcfg = GatewayConfig {
        max_conns: 256,
        ..GatewayConfig::default()
    };
    let (gw, addr) = start_gateway("idle", gcfg, 1, 16);
    let baseline = thread_count().unwrap();

    let conns: Vec<TcpStream> = (0..64)
        .map(|_| TcpStream::connect(&addr).unwrap())
        .collect();
    thread::sleep(Duration::from_millis(300));
    let with_conns = thread_count().unwrap();
    // Other tests in this binary run concurrently and may spawn a few
    // threads of their own; the margin is far below the 128 threads
    // a 2-threads-per-connection design would add here.
    assert!(with_conns <= baseline + 16,
            "64 idle connections changed thread count {baseline} -> \
             {with_conns}");
    drop(conns);
    gw.stop_and_wait().unwrap();
}

/// The c10k acceptance test: ≥4096 concurrent pipelined connections
/// through one gateway, every response equivalent (same bytes for the
/// deterministic fields) to the in-process `Service` path on the same
/// frames, and thread count independent of connection count.
#[test]
fn c10k_connections_serve_byte_identical_to_in_process() {
    const CONNS: usize = 4096;
    if !reactor::HAVE_POLL_SYSCALL {
        eprintln!("skipping c10k: no poll syscall on this target");
        return;
    }
    // Client + server ends live in this one process: ~2 fds per
    // connection plus slack.
    match reactor::raise_nofile_limit(32 * 1024) {
        Ok(limit) if limit >= (CONNS as u64) * 2 + 512 => {}
        Ok(limit) => {
            eprintln!("skipping c10k: fd limit {limit} too low");
            return;
        }
        Err(e) => {
            eprintln!("skipping c10k: cannot raise fd limit: {e}");
            return;
        }
    }

    let gcfg = GatewayConfig {
        max_conns: 8192,
        drain_timeout: Duration::from_secs(60),
        ..GatewayConfig::default()
    };
    let (gw, addr) = start_gateway("c10k", gcfg, 4, 8192);
    let shards = gw.shard_count();
    let baseline = thread_count();

    // Sample the process thread count while all connections are live.
    let peak = Arc::new(AtomicUsize::new(0));
    let done = Arc::new(AtomicBool::new(false));
    let sampler = {
        let (peak, done) = (peak.clone(), done.clone());
        thread::spawn(move || {
            while !done.load(Ordering::Relaxed) {
                if let Some(n) = thread_count() {
                    peak.fetch_max(n, Ordering::Relaxed);
                }
                thread::sleep(Duration::from_millis(25));
            }
        })
    };

    let cfg = LoadGenConfig {
        addr: addr.clone(),
        conns: CONNS,
        frames: CONNS, // one pipelined frame per connection
        window: 1,
        traffic: TrafficMode::Skewed,
        seed: 0xC10C,
        ..LoadGenConfig::default()
    };
    let (report, collected) =
        loadgen::run_collect(&cfg).expect("c10k loadgen");
    done.store(true, Ordering::Relaxed);
    sampler.join().unwrap();

    assert_eq!(report.ok, CONNS as u64,
               "every frame must serve (busy={}, errors={})",
               report.busy, report.errors);
    assert_eq!(report.errors, 0);
    assert_eq!(collected.len(), CONNS);
    assert_eq!(report.per_conn_ok.len(), CONNS);
    assert!(report.per_conn_ok.iter().all(|&ok| ok == 1),
            "each of the {CONNS} connections must serve its frame");

    // Thread count stayed O(shards + models), nowhere near
    // O(connections): a thread-per-connection design would sit at
    // 2*4096 here.
    if let (Some(base), peak) = (baseline,
                                 peak.load(Ordering::Relaxed)) {
        assert!(peak > 0, "sampler never ran");
        assert!(peak <= base + 64,
                "thread count grew with connections: baseline {base}, \
                 peak {peak} ({shards} shards)");
    }

    let gw_report = gw.stop_and_wait().unwrap();
    assert_eq!(gw_report.counters.internal, 0);
    assert_eq!(gw_report.counters.bad_request, 0);
    assert!(gw_report.counters.conns_accepted >= CONNS as u64);

    // Reference: the exact same frames through the in-process
    // Service. The loadgen workload is a pure function of
    // (seed, conn, id) — regenerate it and compare the deterministic
    // response bytes.
    let service = Service::start(service_cfg(4, 8192),
                                 worker_cfg(artifacts("c10k-ref")))
        .unwrap();
    let n = service.frame_spec().pixels_len();
    for c in &collected {
        // Same per-connection seed derivation as loadgen::run.
        let seed = cfg.seed.wrapping_add(0xC0FF_EE00 * c.conn as u64);
        let pixels =
            loadgen::gen_pixels(n, seed, c.id, TrafficMode::Skewed);
        let gid = ((c.conn as u64) << 32) | c.id;
        service.submit(gid, pixels).unwrap();
    }
    let (resps, _) = service
        .collect_within(collected.len(), skydiver::CLOCK_HZ,
                        Duration::from_secs(600))
        .unwrap();
    service.shutdown().unwrap();
    let expected: std::collections::HashMap<u64, Vec<u32>> =
        resps.into_iter().map(|r| (r.id, r.output_counts)).collect();

    for c in &collected {
        let gid = ((c.conn as u64) << 32) | c.id;
        let want = expected.get(&gid).unwrap();
        // Byte-level comparison of the deterministic response fields.
        let wire_bytes: Vec<u8> = c.output_counts.iter()
            .flat_map(|v| v.to_le_bytes()).collect();
        let ref_bytes: Vec<u8> = want.iter()
            .flat_map(|v| v.to_le_bytes()).collect();
        assert_eq!(wire_bytes, ref_bytes,
                   "conn {} frame {}: wire path diverged from \
                    in-process path", c.conn, c.id);
        let argmax = want.iter().enumerate()
            .max_by_key(|&(_, v)| *v).map(|(i, _)| i as u32).unwrap();
        assert_eq!(c.prediction, argmax);
    }
}
