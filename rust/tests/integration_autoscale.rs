//! Self-driving serving tier acceptance: worker-pool autoscaling,
//! priority-class validation, and graceful reduced-T degradation —
//! hermetic per-piece tests plus the headline skewed-burst scenario
//! (hot model scales up, cold model is not starved, overload degrades
//! instead of dropping, the pool decays back to the floor).
//!
//! Everything runs against synthetic artifacts on loopback; the only
//! wall-clock assertions compare the cold model against its own
//! unloaded baseline with generous slack, so the tests stay stable on
//! loaded CI machines.

use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread;
use std::time::{Duration, Instant};

use skydiver::coordinator::{AutoscaleConfig, DispatchMode,
                            ModelRegistry, ModelSpec, Policy,
                            ServiceConfig, WorkerConfig};
use skydiver::power::EnergyModel;
use skydiver::server::protocol::NET_ANY;
use skydiver::server::{Client, DegradeInfo, ErrorCode, Gateway,
                       GatewayConfig, RequestBody, RequestExts,
                       ResponseBody, WirePayload, WireRequest};
use skydiver::sim::ArchConfig;
use skydiver::snn::NetKind;

const CLS_SIDE: usize = 24; // classifier: 1 x 24 x 24, 6 timesteps
const SEG_SIDE: usize = 12; // segmenter: 3 x 12 x 12, 4 timesteps

fn artifacts(label: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(
        format!("skydiver-autoscale-{label}-{}", std::process::id()));
    skydiver::data::write_synthetic_classifier(&dir, CLS_SIDE).unwrap();
    skydiver::data::write_synthetic_segmenter(&dir, SEG_SIDE).unwrap();
    dir
}

fn worker_cfg(artifacts: PathBuf, kind: NetKind) -> WorkerConfig {
    WorkerConfig {
        artifacts,
        kind,
        aprc: true,
        policy: Policy::Cbws,
        arch: ArchConfig::default(),
        energy: EnergyModel::default(),
        use_runtime: false,
        timesteps: None,
        sweep_threads: 1,
        temporal: true,
    }
}

/// A pool that may grow: 1 worker at start, `workers_max` slots
/// reserved for the autoscaler.
fn elastic_scfg(queue_cap: usize, workers_max: usize) -> ServiceConfig {
    ServiceConfig {
        workers: 1,
        workers_max,
        batch_max: 2,
        queue_cap,
        batch_wait: Duration::from_millis(1),
        dispatch: DispatchMode::WorkQueue,
        cost_cap: None,
    }
}

/// A fast control loop for tests: 5 ms ticks, scale up after 2 hot
/// ticks, decay one step after 4 quiet ticks.
fn fast_autoscale(max: usize) -> AutoscaleConfig {
    AutoscaleConfig {
        min: 1,
        max,
        tick: Duration::from_millis(5),
        sustain_ticks: 2,
        cooldown_ticks: 1,
        idle_ticks: 4,
        ..AutoscaleConfig::default()
    }
}

/// Parse one `{model="..."}`-labelled sample out of a metrics scrape.
fn labelled(text: &str, name: &str, model: &str) -> f64 {
    let prefix = format!("{name}{{model=\"{model}\"}} ");
    text.lines()
        .find_map(|l| l.strip_prefix(prefix.as_str()))
        .unwrap_or_else(|| panic!("metrics must expose {prefix}"))
        .trim()
        .parse()
        .unwrap()
}

/// Poll the metrics endpoint until `pred` holds for the named series.
fn wait_metric(mon: &mut Client, name: &str, model: &str,
               pred: impl Fn(f64) -> bool, what: &str,
               timeout: Duration) -> f64 {
    let deadline = Instant::now() + timeout;
    loop {
        let v = labelled(&mon.metrics().unwrap(), name, model);
        if pred(v) {
            return v;
        }
        assert!(Instant::now() < deadline,
                "timed out waiting for {what}: \
                 {name}{{model=\"{model}\"}} = {v}");
        thread::sleep(Duration::from_millis(10));
    }
}

struct SatResult {
    sent: u64,
    ok: u64,
    busy: u64,
    degraded: u64,
    notices: Vec<DegradeInfo>,
}

/// Saturate `model` with dense frames for `run_for`, keeping up to
/// `window` requests pipelined, then drain what is in flight. Every
/// response must be a served `Infer` (possibly degraded) or a typed
/// `BUSY` — anything else is a lost request and panics, which is
/// exactly the "zero lost non-BUSY requests" acceptance property.
fn saturate(client: &mut Client, model: &str, n: usize, window: usize,
            run_for: Duration) -> SatResult {
    let started = Instant::now();
    let (mut sent, mut ok, mut busy, mut degraded) = (0u64, 0, 0, 0);
    let mut inflight = 0usize;
    let mut notices = Vec::new();
    loop {
        while inflight < window && started.elapsed() < run_for {
            client.send(&WireRequest {
                id: sent,
                body: RequestBody::Infer {
                    net: NET_ANY,
                    model: model.to_string(),
                    payload: WirePayload::Pixels(vec![255u8; n]),
                },
            }).unwrap();
            sent += 1;
            inflight += 1;
        }
        if inflight == 0 {
            break;
        }
        let (resp, notice) = client.recv_ext().unwrap();
        inflight -= 1;
        match resp.body {
            ResponseBody::Infer { .. } => {
                ok += 1;
                if let Some(d) = notice {
                    degraded += 1;
                    notices.push(d);
                }
            }
            ResponseBody::Error { code: ErrorCode::Busy, .. } => {
                busy += 1;
            }
            other => panic!("request {} lost: {other:?}", resp.id),
        }
    }
    SatResult { sent, ok, busy, degraded, notices }
}

fn sparse_frame(n: usize) -> Vec<u8> {
    (0..n).map(|i| if i % 16 == 0 { 255 } else { 0 }).collect()
}

/// One sequential cold-model probe; returns the client-observed RTT.
fn probe_once(c: &mut Client, id: u64, n: usize) -> Duration {
    let t = Instant::now();
    let resp = c.infer_pixels(id, "segmenter", sparse_frame(n)).unwrap();
    assert!(matches!(&resp.body, ResponseBody::Infer { .. }),
            "cold-model probe {id} failed: {:?}", resp.body);
    t.elapsed()
}

fn p99(samples: &mut [Duration]) -> Duration {
    samples.sort();
    samples[((samples.len() - 1) as f64 * 0.99).round() as usize]
}

/// Sustained saturation of a 1-worker elastic pool must scale it up
/// (first event can only be `Up`: the pool starts at the floor), the
/// gauge must show the larger pool, and after the burst the pool must
/// decay back to `min` — all visible through the metrics endpoint.
#[test]
fn sustained_burst_scales_pool_up_then_decays_to_min() {
    let gcfg = GatewayConfig {
        addr: "127.0.0.1:0".into(),
        max_conns: 8,
        drain_timeout: Duration::from_secs(60),
        autoscale: fast_autoscale(4),
        ..GatewayConfig::default()
    };
    let gw = Gateway::start_single(gcfg, elastic_scfg(64, 4),
                                   worker_cfg(artifacts("scale"),
                                              NetKind::Classifier))
        .unwrap();
    let addr = gw.local_addr().to_string();

    let mut driver = Client::connect(&addr).unwrap();
    driver.set_read_timeout(Some(Duration::from_secs(120))).unwrap();
    let n = driver.info().unwrap().pixels_len();
    let mut mon = Client::connect(&addr).unwrap();
    mon.set_read_timeout(Some(Duration::from_secs(120))).unwrap();

    // Saturate from a background thread; watch the scrape live.
    let load = thread::spawn(move || {
        let r = saturate(&mut driver, "", n, 128,
                         Duration::from_millis(1500));
        (r, driver)
    });
    wait_metric(&mut mon, "skydiver_autoscale_events_total",
                "classifier", |v| v >= 1.0,
                "a scale event under sustained saturation",
                Duration::from_secs(120));
    // The grown pool shows in the gauge. If scheduling delayed this
    // poll past the whole burst *and* decay, a second (down) event
    // with the gauge back at the floor proves the same round trip.
    let deadline = Instant::now() + Duration::from_secs(120);
    let mut peak = 1.0f64;
    loop {
        let text = mon.metrics().unwrap();
        let w = labelled(&text, "skydiver_autoscale_workers",
                         "classifier");
        let ev = labelled(&text, "skydiver_autoscale_events_total",
                          "classifier");
        peak = peak.max(w);
        if peak >= 2.0 || (ev >= 2.0 && w <= 1.0) {
            break;
        }
        assert!(Instant::now() < deadline,
                "pool gauge never left the floor (events {ev})");
        thread::sleep(Duration::from_millis(2));
    }

    let (r, driver) = load.join().unwrap();
    assert_eq!(r.ok + r.busy, r.sent, "every request must be answered");
    assert!(r.ok > 0, "saturation must still serve");
    assert_eq!(r.degraded, 0, "degradation is off in this test");

    // After the burst: one-at-a-time decay back to the floor.
    wait_metric(&mut mon, "skydiver_autoscale_workers", "classifier",
                |v| v == 1.0, "post-burst decay to --workers-min",
                Duration::from_secs(120));
    let events = labelled(&mon.metrics().unwrap(),
                          "skydiver_autoscale_events_total",
                          "classifier");
    assert!(events >= 2.0,
            "up + down is at least two scale events, got {events}");

    drop((driver, mon));
    let report = gw.stop_and_wait().unwrap();
    assert_eq!(report.counters.served, r.ok);
    assert_eq!(report.counters.busy, r.busy);
    assert!(report.default_model().serving.worker_failures.is_empty(),
            "{:?}", report.default_model().serving.worker_failures);
}

/// Overload against a tiny queue with `--degrade reduce-t`: admissions
/// past the pressure knee serve at reduced T (flagged and
/// energy-priced on the wire, counted in metrics and the report)
/// instead of everything past the cap shedding as `BUSY`.
#[test]
fn overload_degrades_to_reduced_t_instead_of_pure_busy() {
    let gcfg = GatewayConfig {
        addr: "127.0.0.1:0".into(),
        max_conns: 8,
        drain_timeout: Duration::from_secs(60),
        degrade_reduce_t: true,
        degrade_floor_t: 2,
        ..GatewayConfig::default()
    };
    let scfg = ServiceConfig {
        workers: 1,
        workers_max: 0,
        batch_max: 1,
        queue_cap: 8,
        batch_wait: Duration::from_millis(1),
        dispatch: DispatchMode::WorkQueue,
        cost_cap: None,
    };
    let gw = Gateway::start_single(gcfg, scfg,
                                   worker_cfg(artifacts("degrade"),
                                              NetKind::Classifier))
        .unwrap();
    let addr = gw.local_addr().to_string();

    let mut client = Client::connect(&addr).unwrap();
    client.set_read_timeout(Some(Duration::from_secs(120))).unwrap();
    let info = client.info().unwrap();
    let n = info.pixels_len();
    let t_full = info.timesteps as u32;

    let r = saturate(&mut client, "", n, 64,
                     Duration::from_millis(800));
    assert_eq!(r.ok + r.busy, r.sent, "every request must be answered");
    assert!(r.degraded > 0,
            "a saturated cap-8 queue must push admissions past the \
             50% pressure knee (ok {} busy {} of {})",
            r.ok, r.busy, r.sent);
    assert_eq!(r.degraded as usize, r.notices.len());
    for d in &r.notices {
        assert_eq!(d.t_full, t_full);
        assert!(d.t_served >= 2 && d.t_served < d.t_full,
                "served T {} must sit in [--degrade-floor-t, T)",
                d.t_served);
        assert!(d.energy_uj > 0.0,
                "degraded responses are energy-priced");
    }

    let text = client.metrics().unwrap();
    assert!(labelled(&text, "skydiver_model_degraded_total",
                     "classifier") >= r.degraded as f64);
    drop(client);
    let report = gw.stop_and_wait().unwrap();
    assert_eq!(report.default_model().counters.degraded, r.degraded);
    assert_eq!(report.counters.served, r.ok);
    assert_eq!(report.counters.busy, r.busy);
    assert!(report.default_model().serving.worker_failures.is_empty());
}

/// The priority extension: all three known classes serve; an unknown
/// class byte is a per-request `BAD_REQUEST` naming the valid classes
/// (a class changes scheduling, so it must never be silently
/// defaulted) and the connection stays usable.
#[test]
fn priority_classes_serve_and_unknown_byte_is_rejected() {
    let gcfg = GatewayConfig {
        addr: "127.0.0.1:0".into(),
        max_conns: 8,
        drain_timeout: Duration::from_secs(60),
        ..GatewayConfig::default()
    };
    let scfg = ServiceConfig {
        workers: 1,
        workers_max: 0,
        batch_max: 8,
        queue_cap: 16,
        batch_wait: Duration::from_millis(1),
        dispatch: DispatchMode::WorkQueue,
        cost_cap: None,
    };
    let gw = Gateway::start_single(gcfg, scfg,
                                   worker_cfg(artifacts("priority"),
                                              NetKind::Classifier))
        .unwrap();
    let addr = gw.local_addr().to_string();

    let mut client = Client::connect(&addr).unwrap();
    client.set_read_timeout(Some(Duration::from_secs(120))).unwrap();
    let n = client.info().unwrap().pixels_len();

    for (id, pri) in [(0u64, 0u8), (1, 1), (2, 2)] {
        client.send_with_exts(&WireRequest {
            id,
            body: RequestBody::Infer {
                net: NET_ANY,
                model: String::new(),
                payload: WirePayload::Pixels(sparse_frame(n)),
            },
        }, &RequestExts { priority: Some(pri),
                          ..RequestExts::default() }).unwrap();
        let (resp, notice) = client.recv_ext().unwrap();
        assert_eq!(resp.id, id);
        assert!(matches!(&resp.body, ResponseBody::Infer { .. }),
                "priority class {pri} must serve: {:?}", resp.body);
        assert!(notice.is_none(), "no overload, no degradation");
    }

    // Unknown class byte: typed rejection, not a silent default.
    client.send_with_exts(&WireRequest {
        id: 9,
        body: RequestBody::Infer {
            net: NET_ANY,
            model: String::new(),
            payload: WirePayload::Pixels(sparse_frame(n)),
        },
    }, &RequestExts { priority: Some(9),
                      ..RequestExts::default() }).unwrap();
    let (resp, _) = client.recv_ext().unwrap();
    assert_eq!(resp.id, 9);
    match resp.body {
        ResponseBody::Error { code, detail } => {
            assert_eq!(code, ErrorCode::BadRequest);
            assert!(detail.contains("priority"), "{detail}");
        }
        other => panic!("expected BAD_REQUEST, got {other:?}"),
    }

    // The connection survives the rejection.
    let resp = client.infer_pixels(10, "", sparse_frame(n)).unwrap();
    assert!(matches!(resp.body, ResponseBody::Infer { .. }));
    drop(client);

    let report = gw.stop_and_wait().unwrap();
    assert_eq!(report.counters.served, 4);
    assert!(report.counters.bad_request >= 1);
}

/// The headline acceptance scenario from the issue: a skewed burst on
/// a two-model gateway. The hot model's elastic pool scales up (and
/// only its pool — the cold model's gauge stays at its fixed size),
/// overload on the hot model degrades instead of dropping, the cold
/// model's p99 stays within 2x its unloaded baseline (plus fixed
/// scheduler slack), and the hot pool decays back to the floor once
/// the burst ends.
#[test]
fn skewed_burst_scales_hot_model_without_starving_cold() {
    let dir = artifacts("headline");
    let cold_scfg = ServiceConfig {
        workers: 1,
        workers_max: 0,
        batch_max: 8,
        queue_cap: 64,
        batch_wait: Duration::from_millis(1),
        dispatch: DispatchMode::WorkQueue,
        cost_cap: None,
    };
    let registry = ModelRegistry::start(vec![
        ModelSpec {
            name: "classifier".into(),
            scfg: elastic_scfg(64, 4),
            wcfg: worker_cfg(dir.clone(), NetKind::Classifier),
        },
        ModelSpec {
            name: "segmenter".into(),
            scfg: cold_scfg,
            wcfg: worker_cfg(dir, NetKind::Segmenter),
        },
    ]).expect("registry start");
    let gcfg = GatewayConfig {
        addr: "127.0.0.1:0".into(),
        max_conns: 8,
        drain_timeout: Duration::from_secs(60),
        autoscale: fast_autoscale(4),
        degrade_reduce_t: true,
        degrade_floor_t: 0, // auto: T/4
        ..GatewayConfig::default()
    };
    let gw = Gateway::start(gcfg, registry).expect("gateway start");
    let addr = gw.local_addr().to_string();

    // Unloaded cold-model baseline, measured through the same stack.
    let mut probe = Client::connect(&addr).unwrap();
    probe.set_read_timeout(Some(Duration::from_secs(120))).unwrap();
    let seg_n = probe.info_model("segmenter").unwrap().pixels_len();
    let mut baseline: Vec<Duration> =
        (0..24).map(|i| probe_once(&mut probe, i, seg_n)).collect();
    let base_p99 = p99(&mut baseline);

    // Skewed burst: saturate the classifier from a background thread.
    let mut driver = Client::connect(&addr).unwrap();
    driver.set_read_timeout(Some(Duration::from_secs(120))).unwrap();
    let cls_n = driver.info_model("classifier").unwrap().pixels_len();
    let done = Arc::new(AtomicBool::new(false));
    let done2 = done.clone();
    let load = thread::spawn(move || {
        let r = saturate(&mut driver, "classifier", cls_n, 128,
                         Duration::from_millis(2000));
        done2.store(true, Ordering::SeqCst);
        (r, driver)
    });

    // While the burst runs: keep probing the cold model and sampling
    // the scrape. The cold model's fixed pool must never move.
    let mut during = Vec::new();
    let mut peak_hot = 1.0f64;
    let mut hot_events = 0.0f64;
    let mut probe_id = 1000u64;
    while !done.load(Ordering::SeqCst) {
        during.push(probe_once(&mut probe, probe_id, seg_n));
        probe_id += 1;
        let text = probe.metrics().unwrap();
        peak_hot = peak_hot.max(
            labelled(&text, "skydiver_autoscale_workers", "classifier"));
        hot_events = hot_events.max(
            labelled(&text, "skydiver_autoscale_events_total",
                     "classifier"));
        assert_eq!(labelled(&text, "skydiver_autoscale_workers",
                            "segmenter"), 1.0,
                   "the cold model's fixed pool must never resize");
    }
    let (r, driver) = load.join().unwrap();

    // Hot model: scaled up, nothing lost, overload degraded.
    assert!(hot_events >= 1.0,
            "the hot model must scale up under the skewed burst");
    assert!(peak_hot >= 2.0,
            "the scale-up must be visible in \
             skydiver_autoscale_workers (peak {peak_hot})");
    assert_eq!(r.ok + r.busy, r.sent, "zero lost non-BUSY requests");
    assert!(r.degraded > 0,
            "sustained overload with --degrade reduce-t must serve \
             reduced-T responses (ok {} busy {} of {})",
            r.ok, r.busy, r.sent);
    for d in &r.notices {
        assert!(d.t_served < d.t_full);
        assert!(d.energy_uj > 0.0);
    }

    // Cold model: never starved. The bound is 2x its own unloaded
    // p99 plus fixed slack for scheduler noise on shared CI cores.
    assert!(during.len() >= 4,
            "probes must keep flowing during the burst");
    let during_p99 = p99(&mut during);
    assert!(during_p99 <= base_p99 * 2 + Duration::from_millis(200),
            "cold-model p99 under the skewed burst ({during_p99:?}) \
             must stay within 2x its unloaded baseline ({base_p99:?})");

    // After the burst: the hot pool decays back to the floor.
    wait_metric(&mut probe, "skydiver_autoscale_workers", "classifier",
                |v| v == 1.0, "hot-pool decay to --workers-min",
                Duration::from_secs(120));

    drop((probe, driver));
    let report = gw.stop_and_wait().unwrap();
    let cls = report.model("classifier").unwrap();
    let seg = report.model("segmenter").unwrap();
    assert_eq!(cls.counters.served, r.ok);
    assert_eq!(cls.counters.busy, r.busy);
    assert_eq!(cls.counters.degraded, r.degraded);
    assert_eq!(seg.counters.served,
               24 + during.len() as u64);
    assert_eq!(seg.counters.degraded, 0,
               "an unloaded model must never degrade");
    assert!(cls.serving.worker_failures.is_empty());
    assert!(seg.serving.worker_failures.is_empty());
}
