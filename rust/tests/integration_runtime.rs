//! Integration: PJRT runtime executes the AOT step functions and agrees
//! with the in-crate functional model — the L1/L2 <-> L3 contract.
//!
//! Requires `make artifacts` (skips loudly otherwise is NOT allowed:
//! these tests are the core correctness signal of the AOT bridge).

use skydiver::runtime::{Runtime, SnnRunner};
use skydiver::snn::{encode_phased_u8, FunctionalNet, NetworkWeights};

fn load(name: &str) -> NetworkWeights {
    NetworkWeights::load(&skydiver::artifacts_dir(), name)
        .expect("run `make artifacts` first")
}

#[test]
fn classifier_golden_matches_functional() {
    let net = load("classifier_aprc");
    let rt = Runtime::cpu().expect("PJRT cpu client");
    let step = rt.load_step(&skydiver::artifacts_dir(), &net).unwrap();

    let (imgs, _) = skydiver::data::gen_digits(0x17E57, 4);
    let t = net.meta.timesteps;
    let mut total = 0usize;
    let mut mismatched = 0usize;
    for img in imgs.chunks(28 * 28) {
        let inputs = encode_phased_u8(img, 1, 28, 28, t);
        let golden = SnnRunner::new(&step).unwrap()
            .run_frame(&inputs).unwrap();
        let functional = FunctionalNet::new(&net).run_frame(&inputs);
        assert_eq!(golden.len(), functional.len());
        for (g_step, f_step) in golden.iter().zip(&functional) {
            for (l, (g, f)) in g_step.iter()
                .zip(f_step.iter().map(|o| &o.spikes)).enumerate() {
                assert_eq!((g.c, g.h, g.w), (f.c, f.h, f.w),
                           "layer {l} shape");
                total += g.len();
                for ch in 0..g.c {
                    for i in 0..g.h * g.w {
                        if g.get(ch, i) != f.get(ch, i) {
                            mismatched += 1;
                        }
                    }
                }
            }
        }
    }
    // f32 summation-order differences may flip neurons sitting exactly
    // at threshold; must be a vanishing fraction.
    let frac = mismatched as f64 / total as f64;
    assert!(frac < 1e-3,
            "golden vs functional spike mismatch {frac} ({mismatched}/{total})");
}

#[test]
fn classifier_golden_predictions_correct() {
    let net = load("classifier_aprc");
    let rt = Runtime::cpu().unwrap();
    let step = rt.load_step(&skydiver::artifacts_dir(), &net).unwrap();
    let (imgs, labels) = skydiver::data::gen_digits(0x7E57D161, 16);
    let t = net.meta.timesteps;
    let mut correct = 0;
    for (img, &label) in imgs.chunks(28 * 28).zip(&labels) {
        let inputs = encode_phased_u8(img, 1, 28, 28, t);
        let counts = SnnRunner::new(&step).unwrap()
            .run_frame_counts(&inputs).unwrap();
        let pred = counts.iter().enumerate()
            .max_by_key(|(_, &c)| c).map(|(i, _)| i).unwrap();
        correct += (pred == label as usize) as usize;
    }
    // Paper claims 98.5%; on 16 easy synthetic digits demand >= 14.
    assert!(correct >= 14, "only {correct}/16 correct via PJRT");
}

#[test]
fn segmenter_golden_runs_and_masks() {
    let net = load("segmenter_aprc");
    let rt = Runtime::cpu().unwrap();
    let step = rt.load_step(&skydiver::artifacts_dir(), &net).unwrap();
    let (imgs, masks) = skydiver::data::gen_road_scenes(0x7E570AD5, 1);
    let (h, w) = (skydiver::data::ROAD_H, skydiver::data::ROAD_W);
    let mut chw = vec![0u8; 3 * h * w];
    for y in 0..h {
        for x in 0..w {
            for c in 0..3 {
                chw[c * h * w + y * w + x] = imgs[(y * w + x) * 3 + c];
            }
        }
    }
    let inputs = encode_phased_u8(&chw, 3, h, w, net.meta.timesteps);
    let counts = SnnRunner::new(&step).unwrap()
        .run_frame_counts(&inputs).unwrap();

    // IoU of thresholded rates vs ground truth must be high (~0.99 at
    // calibration; demand > 0.8 here).
    let thr = net.meta.seg_rate_threshold.unwrap_or(0.5);
    let t = net.meta.timesteps as f64;
    let (_, oh, ow) = net.layer_output_shape(net.num_layers() - 1);
    let (dh, dw) = ((oh - h) / 2, (ow - w) / 2);
    let mut inter = 0usize;
    let mut union = 0usize;
    for y in 0..h {
        for x in 0..w {
            let pred = counts[(y + dh) * ow + (x + dw)] as f64 / t >= thr;
            let gt = masks[y * w + x] == 1;
            inter += (pred && gt) as usize;
            union += (pred || gt) as usize;
        }
    }
    let iou = inter as f64 / union.max(1) as f64;
    assert!(iou > 0.8, "segmentation IoU via PJRT too low: {iou}");
}

#[test]
fn runner_reset_between_frames() {
    let net = load("classifier_aprc");
    let rt = Runtime::cpu().unwrap();
    let step = rt.load_step(&skydiver::artifacts_dir(), &net).unwrap();
    let (imgs, _) = skydiver::data::gen_digits(0xAB, 1);
    let inputs = encode_phased_u8(&imgs[..28 * 28], 1, 28, 28,
                                  net.meta.timesteps);
    let mut runner = SnnRunner::new(&step).unwrap();
    let a = runner.run_frame_counts(&inputs).unwrap();
    let b = runner.run_frame_counts(&inputs).unwrap();
    assert_eq!(a, b, "state leaked across frames");
}
