//! Parity + determinism tests for the frame-parallel sweep engine and
//! the allocation-free (scratch-reuse) stepping path: the fast paths
//! must be bit-identical to the simple serial/fresh ones. Hermetic —
//! all networks are synthetic, no `make artifacts` needed.

use skydiver::schedule::cbws::Cbws;
use skydiver::schedule::AprcPredictor;
use skydiver::sim::{sweep, ArchConfig, FrameJob, Simulator, TraceSource};
use skydiver::snn::{encode_phased, ConvGeom, FunctionalNet,
                    LayerWeights, NetworkWeights, SpikeMap, WeightsMeta};

/// Two-conv-layer synthetic net with mixed padding (full-pad layer 0 is
/// all-interior; same-pad layer 1 exercises the border path).
fn synthetic_net() -> NetworkWeights {
    let (h, w) = (12usize, 14usize);
    let eh0 = h + 2 * 2 - 3 + 1; // pad 2
    let ew0 = w + 2 * 2 - 3 + 1;
    let eh1 = eh0 + 2 * 1 - 3 + 1; // pad 1
    let ew1 = ew0 + 2 * 1 - 3 + 1;
    let meta = WeightsMeta::parse(&format!(r#"{{
        "name": "sweep-test", "aprc": true, "pad": 2, "vth": 0.35,
        "timesteps": 6, "in_shape": [2, {h}, {w}],
        "feature_sizes": [[4, {eh0}, {ew0}], [3, {eh1}, {ew1}]],
        "dense_out": null, "total_floats": 0, "lambdas": [],
        "layers": [], "blob_fnv1a64": "0"
    }}"#)).unwrap();
    let w0: Vec<f32> = (0..4 * 2 * 9)
        .map(|i| 0.02 + 0.005 * ((i * 7 % 23) as f32)).collect();
    let w1: Vec<f32> = (0..3 * 4 * 9)
        .map(|i| 0.01 + 0.004 * ((i * 5 % 19) as f32)).collect();
    NetworkWeights {
        meta,
        layers: vec![
            LayerWeights::Conv {
                geom: ConvGeom { cin: 2, cout: 4, r: 3, pad: 2, h, w,
                                 eh: eh0, ew: ew0 },
                w: w0,
            },
            LayerWeights::Conv {
                geom: ConvGeom { cin: 4, cout: 3, r: 3, pad: 1, h: eh0,
                                 w: ew0, eh: eh1, ew: ew1 },
                w: w1,
            },
        ],
    }
}

/// Encoded frames with per-frame distinct content.
fn frames(net: &NetworkWeights, n: usize) -> Vec<Vec<SpikeMap>> {
    let (c, h, w) = (net.meta.in_shape[0], net.meta.in_shape[1],
                     net.meta.in_shape[2]);
    (0..n).map(|f| {
        let img: Vec<f32> = (0..c * h * w)
            .map(|i| (((i * 13 + f * 29) % 97) as f32) / 97.0 * 0.8)
            .collect();
        encode_phased(&img, c, h, w, net.meta.timesteps)
    }).collect()
}

fn simulator(net: &NetworkWeights) -> Simulator<'_> {
    let rates = vec![0.3f64; net.meta.in_shape[0]];
    let predictor = AprcPredictor::from_network(net, &rates);
    Simulator::new(ArchConfig::default(), net, &Cbws::default(),
                   &predictor)
}

#[test]
fn parallel_sweep_bit_identical_to_serial() {
    let net = synthetic_net();
    let sim = simulator(&net);
    let trains = frames(&net, 9);
    let serial =
        sweep::run_frames_functional(&sim, &trains, 1).unwrap();
    let parallel =
        sweep::run_frames_functional(&sim, &trains, 4).unwrap();
    assert_eq!(serial.len(), parallel.len());
    for (i, (a, b)) in serial.iter().zip(&parallel).enumerate() {
        assert_eq!(a, b, "frame {i} diverged between serial and \
                          4-thread sweep");
    }
    // Frames are genuinely distinct, so order preservation is visible.
    assert!(serial.windows(2).any(|w| w[0] != w[1]),
            "test frames should differ");
}

#[test]
fn parallel_sweep_deterministic_across_runs() {
    let net = synthetic_net();
    let sim = simulator(&net);
    let trains = frames(&net, 8);
    let a = sweep::run_frames_functional(&sim, &trains, 4).unwrap();
    let b = sweep::run_frames_functional(&sim, &trains, 4).unwrap();
    let c = sweep::run_frames_functional(&sim, &trains, 7).unwrap();
    assert_eq!(a, b, "same thread count must reproduce exactly");
    assert_eq!(a, c, "thread count must not affect results");
}

#[test]
fn golden_jobs_through_sweep_match_functional() {
    let net = synthetic_net();
    let sim = simulator(&net);
    let trains = frames(&net, 5);
    // Golden traces produced by the functional model itself.
    let mut f = FunctionalNet::new(&net);
    let traces: Vec<TraceSource> = trains.iter().map(|inputs| {
        f.reset();
        TraceSource::Golden(inputs.iter()
            .map(|s| f.step(s).into_iter().map(|o| o.spikes).collect())
            .collect())
    }).collect();
    let jobs: Vec<FrameJob> = trains.iter().zip(&traces)
        .map(|(t, tr)| FrameJob { inputs: t, trace: tr })
        .collect();
    let golden = sweep::run_frames(&sim, &jobs, 4).unwrap();
    let functional =
        sweep::run_frames_functional(&sim, &trains, 4).unwrap();
    assert_eq!(golden, functional);
}

#[test]
fn scratch_reuse_traces_match_fresh_instances() {
    // A single FunctionalNet stepped over many frames (reset between)
    // must reproduce per-frame fresh instances bit-for-bit, spikes and
    // counts alike.
    let net = synthetic_net();
    let trains = frames(&net, 4);
    let mut reused = FunctionalNet::new(&net);
    for inputs in &trains {
        let trace_reused = reused.run_frame(inputs);
        let mut fresh = FunctionalNet::new(&net);
        let trace_fresh = fresh.run_frame(inputs);
        for (a, b) in trace_reused.iter().flatten()
            .zip(trace_fresh.iter().flatten()) {
            assert_eq!(a.spikes, b.spikes);
        }
        let mut reused2 = FunctionalNet::new(&net);
        assert_eq!(reused2.run_frame_counts(inputs),
                   reused.run_frame_counts(inputs));
    }
}

#[test]
fn sweep_error_propagates() {
    // A trace-length mismatch inside one job must fail the whole sweep.
    let net = synthetic_net();
    let sim = simulator(&net);
    let trains = frames(&net, 3);
    let bad = TraceSource::Golden(Vec::new());
    let good: Vec<TraceSource> =
        (0..2).map(|_| TraceSource::Functional).collect();
    let jobs: Vec<FrameJob> = vec![
        FrameJob { inputs: &trains[0], trace: &good[0] },
        FrameJob { inputs: &trains[1], trace: &bad },
        FrameJob { inputs: &trains[2], trace: &good[1] },
    ];
    assert!(sweep::run_frames(&sim, &jobs, 4).is_err());
}
