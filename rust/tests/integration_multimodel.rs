//! Multi-model serving acceptance: one gateway process mounts the
//! classifier *and* the segmenter (synthetic artifacts, hermetic),
//! a single TCP connection drives interleaved pipelined requests
//! against both by model name, and every response is byte-identical
//! to the corresponding single-model in-process `Service` path.
//! Protocol-v1 requests against the same gateway still succeed via
//! default-model routing, misaddressed net codes fail loudly, and
//! the per-model metrics/report views add up.

use std::collections::HashMap;
use std::io::{BufReader, Write};
use std::net::TcpStream;
use std::path::PathBuf;
use std::time::Duration;

use skydiver::coordinator::{DispatchMode, ModelRegistry, ModelSpec,
                            Policy, Service, ServiceConfig,
                            WorkerConfig};
use skydiver::data::SplitMix64;
use skydiver::power::EnergyModel;
use skydiver::server::protocol::{read_frame, KIND_RESPONSE, NET_ANY};
use skydiver::server::{Client, ErrorCode, Gateway, GatewayConfig,
                       RequestBody, ResponseBody, WirePayload,
                       WireRequest, WireResponse};
use skydiver::sim::ArchConfig;
use skydiver::snn::NetKind;

const CLS_SIDE: usize = 24; // classifier: 1 x 24 x 24, 6 timesteps
const SEG_SIDE: usize = 12; // segmenter: 3 x 12 x 12, 4 timesteps

fn artifacts(label: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(
        format!("skydiver-multimodel-{label}-{}", std::process::id()));
    skydiver::data::write_synthetic_classifier(&dir, CLS_SIDE).unwrap();
    skydiver::data::write_synthetic_segmenter(&dir, SEG_SIDE).unwrap();
    dir
}

fn worker_cfg(artifacts: PathBuf, kind: NetKind) -> WorkerConfig {
    WorkerConfig {
        artifacts,
        kind,
        aprc: true,
        policy: Policy::Cbws,
        arch: ArchConfig::default(),
        energy: EnergyModel::default(),
        use_runtime: false,
        timesteps: None,
        sweep_threads: 1,
        temporal: true,
    }
}

fn service_cfg() -> ServiceConfig {
    ServiceConfig {
        workers: 2,
        workers_max: 0,
        batch_max: 8,
        queue_cap: 256,
        batch_wait: Duration::from_millis(2),
        dispatch: DispatchMode::WorkQueue,
        cost_cap: None,
    }
}

fn start_two_model_gateway(label: &str) -> (Gateway, String) {
    let dir = artifacts(label);
    let registry = ModelRegistry::start(vec![
        ModelSpec {
            name: "classifier".into(),
            scfg: service_cfg(),
            wcfg: worker_cfg(dir.clone(), NetKind::Classifier),
        },
        ModelSpec {
            name: "segmenter".into(),
            scfg: service_cfg(),
            wcfg: worker_cfg(dir, NetKind::Segmenter),
        },
    ]).expect("registry start");
    let gcfg = GatewayConfig {
        addr: "127.0.0.1:0".into(),
        max_conns: 16,
        drain_timeout: Duration::from_secs(30),
        ..GatewayConfig::default()
    };
    let gw = Gateway::start(gcfg, registry).expect("gateway start");
    let addr = gw.local_addr().to_string();
    (gw, addr)
}

/// Deterministic mixed workload, regenerable from (seed, id).
fn frame_pixels(seed: u64, id: u64, n: usize) -> Vec<u8> {
    let mut rng = SplitMix64::new(seed ^ id.wrapping_mul(0x9E37));
    if id % 4 == 0 {
        (0..n).map(|_| rng.next_below(256) as u8).collect()
    } else {
        (0..n)
            .map(|_| if rng.next_below(100) < 5 { 255 } else { 0 })
            .collect()
    }
}

/// Run `ids`' frames through a fresh single-model in-process Service
/// and return id -> output_counts — the byte-equality reference.
fn in_process_reference(label: &str, kind: NetKind, seed: u64,
                        ids: &[u64]) -> HashMap<u64, Vec<u32>> {
    let service = Service::start(
        service_cfg(), worker_cfg(artifacts(label), kind)).unwrap();
    let n = service.frame_spec().pixels_len();
    for &id in ids {
        service.submit(id, frame_pixels(seed, id, n)).unwrap();
    }
    let (resps, _) = service
        .collect_within(ids.len(), skydiver::CLOCK_HZ,
                        Duration::from_secs(300))
        .unwrap();
    service.shutdown().unwrap();
    resps.into_iter().map(|r| (r.id, r.output_counts)).collect()
}

/// Acceptance: interleaved classifier/segmenter traffic over ONE
/// pipelined connection; every response byte-identical to the
/// single-model in-process path for its model.
#[test]
fn interleaved_two_model_traffic_matches_in_process_paths() {
    const FRAMES: u64 = 120; // even ids -> classifier, odd -> segmenter
    const SEED: u64 = 0x2A0D;
    let (gw, addr) = start_two_model_gateway("interleave");

    let mut client = Client::connect(&addr).unwrap();
    client.set_read_timeout(Some(Duration::from_secs(120))).unwrap();
    let cls = client.info_model("classifier").unwrap();
    let seg = client.info_model("segmenter").unwrap();
    assert_eq!(cls.nmodels, 2);
    assert_eq!(seg.model, "segmenter");
    assert_eq!((cls.c, cls.h, cls.w), (1, CLS_SIDE, CLS_SIDE));
    assert_eq!((seg.c, seg.h, seg.w), (3, SEG_SIDE, SEG_SIDE));
    assert_ne!(cls.timesteps, seg.timesteps,
               "the two synthetic nets must be genuinely different");
    // The empty selector resolves to the default model (entry 0).
    let def = client.info().unwrap();
    assert_eq!(def.model, "classifier");

    // Interleave both models in one pipelined stream, window 8.
    let mut out: HashMap<u64, Vec<u32>> = HashMap::new();
    let (mut next, mut inflight) = (0u64, 0usize);
    while (out.len() as u64) < FRAMES {
        while inflight < 8 && next < FRAMES {
            let (model, n) = if next % 2 == 0 {
                ("classifier", cls.pixels_len())
            } else {
                ("segmenter", seg.pixels_len())
            };
            client.send(&WireRequest {
                id: next,
                body: RequestBody::Infer {
                    net: NET_ANY,
                    model: model.to_string(),
                    payload: WirePayload::Pixels(
                        frame_pixels(SEED, next, n)),
                },
            }).unwrap();
            inflight += 1;
            next += 1;
        }
        let resp = client.recv().unwrap();
        inflight -= 1;
        match resp.body {
            ResponseBody::Infer { output_counts, .. } => {
                out.insert(resp.id, output_counts);
            }
            other => panic!("unexpected: {other:?}"),
        }
    }

    // v1 compatibility on the same gateway: a legacy client (no model
    // selector on the wire) routes to the default model and gets the
    // exact same bytes the classifier path produces.
    let mut v1 = Client::connect_v1(&addr).unwrap();
    v1.set_read_timeout(Some(Duration::from_secs(120))).unwrap();
    let v1_info = v1.info().unwrap();
    assert_eq!(v1_info.model, "", "v1 Info cannot carry a model name");
    assert_eq!(v1_info.nmodels, 1);
    assert_eq!((v1_info.c, v1_info.h, v1_info.w),
               (1, CLS_SIDE, CLS_SIDE),
               "v1 info must describe the default model");
    let mut v1_out: HashMap<u64, Vec<u32>> = HashMap::new();
    let v1_ids: Vec<u64> = (0..10).map(|i| 10_000 + 2 * i).collect();
    for &id in &v1_ids {
        let resp = v1
            .infer_pixels(id, "",
                          frame_pixels(SEED, id, cls.pixels_len()))
            .unwrap();
        match resp.body {
            ResponseBody::Infer { output_counts, .. } => {
                v1_out.insert(resp.id, output_counts);
            }
            other => panic!("v1 infer failed: {other:?}"),
        }
    }
    // A v1 client addressing the wrong net code fails loudly instead
    // of running the wrong network.
    let resp = v1.send(&WireRequest {
        id: 77,
        body: RequestBody::Infer {
            net: 1, // segmenter code, but default model is classifier
            model: String::new(),
            payload: WirePayload::Pixels(
                frame_pixels(SEED, 77, cls.pixels_len())),
        },
    }).and_then(|_| v1.recv()).unwrap();
    match resp.body {
        ResponseBody::Error { code, .. } => {
            assert_eq!(code, ErrorCode::BadRequest);
        }
        other => panic!("expected BAD_REQUEST, got {other:?}"),
    }
    drop(v1);

    // Unknown model: per-request BAD_REQUEST naming the mounted set.
    let resp = client
        .infer_pixels(9999, "resnet",
                      frame_pixels(SEED, 9999, cls.pixels_len()))
        .unwrap();
    match resp.body {
        ResponseBody::Error { code, detail } => {
            assert_eq!(code, ErrorCode::BadRequest);
            assert!(detail.contains("classifier")
                    && detail.contains("segmenter"), "{detail}");
        }
        other => panic!("expected BAD_REQUEST, got {other:?}"),
    }

    // Per-model metrics are exposed with model labels.
    let text = client.metrics().unwrap();
    assert!(text.contains("skydiver_models_mounted"));
    assert!(text.contains(
        "skydiver_model_served_total{model=\"classifier\"}"));
    assert!(text.contains(
        "skydiver_model_served_total{model=\"segmenter\"}"));
    assert!(text.contains(
        "skydiver_latency_us{model=\"segmenter\",quantile=\"0.99\"}"));

    client.shutdown_server().unwrap();
    drop(client);
    let report = gw.wait().expect("gateway drain");

    // Reference runs: the same frames through fresh single-model
    // in-process services.
    let cls_ids: Vec<u64> = (0..FRAMES).filter(|i| i % 2 == 0)
        .chain(v1_ids.iter().copied())
        .collect();
    let seg_ids: Vec<u64> = (0..FRAMES).filter(|i| i % 2 == 1).collect();
    let cls_ref = in_process_reference("cls-ref", NetKind::Classifier,
                                       SEED, &cls_ids);
    let seg_ref = in_process_reference("seg-ref", NetKind::Segmenter,
                                       SEED, &seg_ids);

    assert_eq!(out.len() as u64, FRAMES);
    for (id, counts) in &out {
        let expected = if id % 2 == 0 {
            cls_ref.get(id)
        } else {
            seg_ref.get(id)
        };
        assert_eq!(Some(counts), expected,
                   "frame {id}: gateway diverged from the single-model \
                    in-process path");
    }
    for (id, counts) in &v1_out {
        assert_eq!(Some(counts), cls_ref.get(id),
                   "v1 frame {id}: default-model routing diverged");
    }

    // Report plumbing: two models, counters add up, names resolve.
    assert_eq!(report.models.len(), 2);
    assert_eq!(report.default_model().name, "classifier");
    let cls_rep = report.model("classifier").unwrap();
    let seg_rep = report.model("segmenter").unwrap();
    assert_eq!(cls_rep.counters.served,
               FRAMES / 2 + v1_out.len() as u64);
    assert_eq!(seg_rep.counters.served, FRAMES / 2);
    assert_eq!(report.counters.served,
               cls_rep.counters.served + seg_rep.counters.served);
    assert!(report.counters.bad_request >= 2); // wrong net + unknown model
    assert_eq!(report.counters.internal, 0);
    assert!(cls_rep.serving.worker_failures.is_empty());
    assert!(seg_rep.serving.worker_failures.is_empty());
    // The two models really ran different pipelines.
    assert!(cls_rep.serving.frames > 0 && seg_rep.serving.frames > 0);
    assert_ne!(cls_rep.serving.mean_sim_cycles,
               seg_rep.serving.mean_sim_cycles,
               "distinct nets should not simulate identically");
}

/// A raw v1 frame crafted byte-by-byte (not via the Client) decodes,
/// routes to the default model, and serves — the lowest-level
/// compatibility guarantee.
#[test]
fn raw_v1_bytes_route_to_default_model() {
    use skydiver::server::protocol::{KIND_REQUEST, MAGIC, V1};
    let (gw, addr) = start_two_model_gateway("rawv1");

    let n = CLS_SIDE * CLS_SIDE;
    let pixels = frame_pixels(0xBEEF, 3, n);
    // Hand-built v1 Infer body: id u64, op 0, net 0, payload_kind 0,
    // len u32, pixels.
    let mut body = Vec::new();
    body.extend_from_slice(&3u64.to_le_bytes());
    body.push(0); // op Infer
    body.push(0); // net classifier
    body.push(0); // payload kind pixels
    body.extend_from_slice(&(n as u32).to_le_bytes());
    body.extend_from_slice(&pixels);
    let mut frame = Vec::new();
    frame.extend_from_slice(&MAGIC);
    frame.push(V1);
    frame.push(KIND_REQUEST);
    frame.extend_from_slice(&(body.len() as u32).to_le_bytes());
    frame.extend_from_slice(&body);

    let mut s = TcpStream::connect(&addr).unwrap();
    s.write_all(&frame).unwrap();
    s.flush().unwrap();
    let mut r = BufReader::new(s.try_clone().unwrap());
    let (ver, resp_body) =
        read_frame(&mut r, KIND_RESPONSE).unwrap().unwrap();
    assert_eq!(ver, V1, "a v1 request must be answered in v1");
    let resp = WireResponse::decode_body(ver, &resp_body).unwrap();
    assert_eq!(resp.id, 3);
    let counts = match resp.body {
        ResponseBody::Infer { output_counts, .. } => output_counts,
        other => panic!("unexpected: {other:?}"),
    };
    drop((s, r));

    let expected = in_process_reference("rawv1-ref",
                                        NetKind::Classifier, 0xBEEF,
                                        &[3]);
    assert_eq!(&counts, expected.get(&3).unwrap());

    let report = gw.stop_and_wait().unwrap();
    assert_eq!(report.model("classifier").unwrap().counters.served, 1);
    assert_eq!(report.model("segmenter").unwrap().counters.served, 0);
}
