//! Integration: simulator + schedulers + real trained networks.
//! Asserts the paper's *shapes*: who wins, in which direction, with
//! sensible magnitudes — not absolute cycle counts.

use skydiver::coordinator::default_input_rates;
use skydiver::schedule::baselines::Contiguous;
use skydiver::schedule::cbws::Cbws;
use skydiver::schedule::AprcPredictor;
use skydiver::sim::{ArchConfig, RunSummary, Simulator, TraceSource};
use skydiver::snn::{encode_phased_u8, NetworkWeights};

fn load(name: &str) -> NetworkWeights {
    NetworkWeights::load(&skydiver::artifacts_dir(), name)
        .expect("run `make artifacts` first")
}

fn seg_inputs(net: &NetworkWeights, n: usize)
              -> Vec<Vec<skydiver::snn::SpikeMap>> {
    let (imgs, _) = skydiver::data::gen_road_scenes(0x51AB, n);
    let (h, w) = (skydiver::data::ROAD_H, skydiver::data::ROAD_W);
    imgs.chunks(h * w * 3).map(|img| {
        let mut chw = vec![0u8; 3 * h * w];
        for y in 0..h {
            for x in 0..w {
                for c in 0..3 {
                    chw[c * h * w + y * w + x] = img[(y * w + x) * 3 + c];
                }
            }
        }
        encode_phased_u8(&chw, 3, h, w, net.meta.timesteps)
    }).collect()
}

#[test]
fn aprc_cbws_beats_baseline_on_segmentation() {
    let plain = load("segmenter_plain");
    let aprc = load("segmenter_aprc");
    let arch = ArchConfig::default();
    let inputs = seg_inputs(&aprc, 1);

    let calib = seg_inputs(&aprc, 1);
    let run = |net: &NetworkWeights, cbws: bool| -> RunSummary {
        // Balanced config uses the offline profiled predictor (the
        // deployment schedule, see fig7); baseline uses APRC weights.
        let pred = if cbws {
            AprcPredictor::from_profile(net, &calib)
        } else {
            let rates = default_input_rates(net);
            AprcPredictor::from_network(net, &rates)
        };
        let frames: Vec<_> = if cbws {
            let sim = Simulator::new(arch, net, &Cbws::default(), &pred);
            inputs.iter()
                .map(|i| sim.run_frame(i, &TraceSource::Functional).unwrap())
                .collect()
        } else {
            let sim = Simulator::new(arch, net, &Contiguous, &pred);
            inputs.iter()
                .map(|i| sim.run_frame(i, &TraceSource::Functional).unwrap())
                .collect()
        };
        RunSummary::from_frames(&frames, arch.clock_hz, arch.n_spes)
    };

    let neither = run(&plain, false);
    let both = run(&aprc, true);

    // Paper: 69.19% -> 95.69% balance; 1.4x throughput. Demand the
    // direction and a solid margin.
    assert!(both.mean_balance_weighted > neither.mean_balance_weighted,
            "balance did not improve: {} vs {}",
            both.mean_balance_weighted, neither.mean_balance_weighted);
    assert!(both.mean_balance_weighted > 0.85,
            "APRC+CBWS balance too low: {}", both.mean_balance_weighted);
}

#[test]
fn classifier_balance_improves() {
    let plain = load("classifier_plain");
    let aprc = load("classifier_aprc");
    let arch = ArchConfig::default();
    let (imgs, _) = skydiver::data::gen_digits(0x51AB2, 4);
    let mk = |net: &NetworkWeights| -> Vec<Vec<skydiver::snn::SpikeMap>> {
        imgs.chunks(28 * 28)
            .map(|img| encode_phased_u8(img, 1, 28, 28, net.meta.timesteps))
            .collect()
    };

    let rates_p = default_input_rates(&plain);
    let pred_p = AprcPredictor::from_network(&plain, &rates_p);
    let sim_p = Simulator::new(arch, &plain, &Contiguous, &pred_p);
    let f_p: Vec<_> = mk(&plain).iter()
        .map(|i| sim_p.run_frame(i, &TraceSource::Functional).unwrap())
        .collect();
    let neither = RunSummary::from_frames(&f_p, arch.clock_hz, arch.n_spes);

    let calib = mk(&aprc);
    let pred_a = AprcPredictor::from_profile(&aprc, &calib);
    let sim_a = Simulator::new(arch, &aprc, &Cbws::default(), &pred_a);
    let f_a: Vec<_> = mk(&aprc).iter()
        .map(|i| sim_a.run_frame(i, &TraceSource::Functional).unwrap())
        .collect();
    let both = RunSummary::from_frames(&f_a, arch.clock_hz, arch.n_spes);

    // Paper: 79.63% -> 94.14%.
    assert!(both.mean_balance_weighted > neither.mean_balance_weighted);
    assert!(both.mean_balance_weighted > 0.80,
            "classifier APRC+CBWS balance {}",
            both.mean_balance_weighted);
}

#[test]
fn sim_output_classifies_correctly() {
    // The simulator's functional path IS the accelerator's arithmetic:
    // its output counts must classify digits correctly too.
    let net = load("classifier_aprc");
    let arch = ArchConfig::default();
    let rates = default_input_rates(&net);
    let pred = AprcPredictor::from_network(&net, &rates);
    let sim = Simulator::new(arch, &net, &Cbws::default(), &pred);
    let (imgs, labels) = skydiver::data::gen_digits(0x7E57D161, 8);
    let mut correct = 0;
    for (img, &label) in imgs.chunks(28 * 28).zip(&labels) {
        let inputs = encode_phased_u8(img, 1, 28, 28, net.meta.timesteps);
        let rep = sim.run_frame(&inputs, &TraceSource::Functional).unwrap();
        let pred_label = rep.output_counts.iter().enumerate()
            .max_by_key(|(_, &c)| c).map(|(i, _)| i).unwrap();
        correct += (pred_label == label as usize) as usize;
    }
    assert!(correct >= 7, "{correct}/8 correct through the simulator");
}

#[test]
fn throughput_gain_direction_and_magnitude() {
    let plain = load("segmenter_plain");
    let aprc = load("segmenter_aprc");
    let arch = ArchConfig::default();
    let inputs = seg_inputs(&aprc, 1);

    let calib = seg_inputs(&aprc, 1);
    let fps = |net: &NetworkWeights, balanced: bool| -> f64 {
        let pred = if balanced {
            AprcPredictor::from_profile(net, &calib)
        } else {
            let rates = default_input_rates(net);
            AprcPredictor::from_network(net, &rates)
        };
        let frames: Vec<_> = if balanced {
            let sim = Simulator::new(arch, net, &Cbws::default(), &pred);
            inputs.iter()
                .map(|i| sim.run_frame(i, &TraceSource::Functional).unwrap())
                .collect()
        } else {
            let sim = Simulator::new(arch, net, &Contiguous, &pred);
            inputs.iter()
                .map(|i| sim.run_frame(i, &TraceSource::Functional).unwrap())
                .collect()
        };
        RunSummary::from_frames(&frames, arch.clock_hz, arch.n_spes)
            .mean_fps
    };

    let gain = fps(&aprc, true) / fps(&plain, false);
    // Paper: 1.4x. Accept anything meaningfully > 1 and < 4 (sanity).
    assert!(gain > 1.05, "segmentation gain {gain} <= 1.05");
    assert!(gain < 4.0, "segmentation gain {gain} implausible");
}

#[test]
fn energy_scales_with_imbalance() {
    // More imbalance = longer frames = more static energy at equal work.
    let net = load("segmenter_aprc");
    let arch = ArchConfig::default();
    let energy = skydiver::power::EnergyModel::default();
    let inputs = &seg_inputs(&net, 1)[0];

    let rates = default_input_rates(&net);
    let pred = AprcPredictor::from_network(&net, &rates);
    let sim_bal = Simulator::new(arch, &net, &Cbws::default(), &pred);
    let sim_imb = Simulator::new(arch, &net, &Contiguous, &pred);
    let r_bal = sim_bal.run_frame(inputs, &TraceSource::Functional).unwrap();
    let r_imb = sim_imb.run_frame(inputs, &TraceSource::Functional).unwrap();
    assert_eq!(r_bal.synops, r_imb.synops, "same arithmetic work");
    let e_bal = energy.frame_energy(&r_bal, arch.clock_hz);
    let e_imb = energy.frame_energy(&r_imb, arch.clock_hz);
    assert!(e_imb.total_j >= e_bal.total_j,
            "imbalanced frame cannot cost less energy");
}
