//! End-to-end tracing integration: the span timeline a live system
//! actually emits, fetched over the wire with the `Trace` op.
//!
//! Two layers:
//!
//! * In-process gateway — every stage span (admission, cost_predict,
//!   queue, batch, compute, encode, write) shows up for a served
//!   request, with monotonic intervals in pipeline order.
//! * Cross-process cluster — a real `route` process over two real
//!   `serve` processes (spawned from the built binary), each with its
//!   own flight recorder. One trace id must appear in BOTH the
//!   router's and the surviving backend's dumps with parent links
//!   stitching across the process boundary, and a SIGKILLed backend
//!   must leave failover attempts as sibling spans under one route
//!   root.

use std::path::{Path, PathBuf};
use std::process::{Child, Command, Stdio};
use std::thread;
use std::time::{Duration, Instant};

use skydiver::coordinator::{DispatchMode, Policy, ServiceConfig,
                            WorkerConfig};
use skydiver::obs::trace;
use skydiver::power::EnergyModel;
use skydiver::server::loadgen::{self, LoadGenConfig, TrafficMode};
use skydiver::server::{Client, Gateway, GatewayConfig, ResponseBody};
use skydiver::sim::ArchConfig;
use skydiver::snn::NetKind;
use skydiver::util::Json;

const SIDE: usize = 16;

fn artifacts(label: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(
        format!("skydiver-trace-{label}-{}", std::process::id()));
    skydiver::data::write_synthetic_classifier(&dir, SIDE).unwrap();
    dir
}

// ------------------------------------------------------- dump model

/// One `"ph":"X"` event from a Chrome trace-event dump, flattened to
/// the fields the assertions below care about.
#[derive(Debug, Clone)]
struct Ev {
    trace: String,
    name: String,
    span: u64,
    parent: u64,
    error: bool,
    ts: f64,
    dur: f64,
    a: f64,
}

impl Ev {
    fn end(&self) -> f64 {
        self.ts + self.dur
    }
}

fn parse_events(json: &str) -> Vec<Ev> {
    let doc = Json::parse(json).expect("dump must be valid JSON");
    let events = doc
        .field("traceEvents")
        .and_then(|e| e.as_arr())
        .expect("dump must carry a traceEvents array");
    let mut out = Vec::new();
    for ev in events {
        if ev.get("ph").and_then(|p| p.as_str().ok()) != Some("X") {
            continue;
        }
        let args = ev.field("args").unwrap();
        out.push(Ev {
            trace: args.field("trace").unwrap().as_str().unwrap()
                .to_string(),
            name: ev.field("name").unwrap().as_str().unwrap()
                .to_string(),
            span: args.field("span").unwrap().as_f64().unwrap() as u64,
            parent: args.field("parent").unwrap().as_f64().unwrap()
                as u64,
            error: args.field("error").unwrap().as_bool().unwrap(),
            ts: ev.field("ts").unwrap().as_f64().unwrap(),
            dur: ev.field("dur").unwrap().as_f64().unwrap(),
            a: args.field("a").unwrap().as_f64().unwrap(),
        });
    }
    out
}

fn trace_ids(events: &[Ev]) -> Vec<String> {
    let mut ids: Vec<String> = Vec::new();
    for e in events {
        if !ids.contains(&e.trace) {
            ids.push(e.trace.clone());
        }
    }
    ids
}

fn of<'a>(events: &'a [Ev], trace: &str, name: &str) -> Vec<&'a Ev> {
    events
        .iter()
        .filter(|e| e.trace == trace && e.name == name)
        .collect()
}

/// Pipeline stage order a direct-to-gateway request flows through.
const GATEWAY_STAGES: [&str; 7] = [
    "admission", "cost_predict", "queue", "batch", "compute",
    "encode", "write",
];

// ------------------------------------------------- in-process layer

/// Serve a dozen frames on a traced in-process gateway, fetch the
/// flight recorder over the wire, and hold the dump to the stage
/// contract: every served request shows the full 7-stage timeline,
/// intervals ordered by the pipeline, sim cycles attached to compute.
#[test]
fn gateway_dump_has_full_stage_timelines() {
    const FRAMES: u64 = 12;
    trace::set_enabled(true);
    let gw = Gateway::start_single(
        GatewayConfig::default(),
        ServiceConfig {
            workers: 1,
            workers_max: 0,
            batch_max: 8,
            queue_cap: 256,
            batch_wait: Duration::from_millis(2),
            dispatch: DispatchMode::WorkQueue,
            cost_cap: None,
        },
        WorkerConfig {
            artifacts: artifacts("inproc"),
            kind: NetKind::Classifier,
            aprc: true,
            policy: Policy::Cbws,
            arch: ArchConfig::default(),
            energy: EnergyModel::default(),
            use_runtime: false,
            timesteps: None,
            sweep_threads: 1,
            temporal: true,
        },
    )
    .expect("gateway start");

    let mut c = Client::connect(gw.local_addr().to_string()).unwrap();
    let n = c.info().unwrap().pixels_len();
    for id in 0..FRAMES {
        let resp = c.infer_pixels(id, "", vec![id as u8 + 1; n])
            .unwrap();
        assert!(matches!(resp.body, ResponseBody::Infer { .. }),
                "traced inference failed: {:?}", resp.body);
    }
    let dump = c.trace_dump().unwrap();
    drop(c);
    trace::set_enabled(false);
    gw.stop_and_wait().unwrap();

    let events = parse_events(&dump);
    assert!(!events.is_empty(), "dump carried no span events");

    // Every stage span of one request is a sibling: same trace id,
    // same parent (0 here — the client sent no trace context, so the
    // gateway originated a root-less timeline).
    let mut full = 0usize;
    for id in trace_ids(&events) {
        if GATEWAY_STAGES
            .iter()
            .any(|s| of(&events, &id, s).is_empty())
        {
            continue; // partial trace (seqlock drop) — not graded
        }
        full += 1;
        let stage = |s: &str| of(&events, &id, s)[0].clone();
        for s in GATEWAY_STAGES {
            let e = stage(s);
            assert!(e.dur >= 0.0, "{s} has negative duration: {e:?}");
            assert!(!e.error, "{s} errored on a served frame: {e:?}");
            assert_eq!(e.parent, 0,
                       "no wire context means root-level siblings");
        }
        // Monotonic pipeline order: each stage ends no earlier than
        // the one before it starts, in hot-path order. (Float slack
        // covers the ns -> us rounding in the dump.)
        const EPS: f64 = 0.01;
        for w in GATEWAY_STAGES.windows(2) {
            let (prev, next) = (stage(w[0]), stage(w[1]));
            assert!(prev.ts <= next.ts + EPS,
                    "{} starts after {}: {prev:?} vs {next:?}",
                    w[0], w[1]);
            assert!(prev.end() <= next.end() + EPS,
                    "{} ends after {}: {prev:?} vs {next:?}",
                    w[0], w[1]);
        }
        // Admission precedes queue residency which precedes compute.
        assert!(stage("admission").end()
                <= stage("queue").end() + EPS);
        assert!(stage("queue").end() <= stage("compute").end() + EPS);
        // Sim cycles ride on the compute span.
        assert!(stage("compute").a > 0.0,
                "compute span must carry sim cycles: {:?}",
                stage("compute"));
    }
    assert!(full >= FRAMES as usize - 2,
            "want >= {} complete stage timelines, got {full} in:\n\
             {dump}", FRAMES - 2);
}

// ---------------------------------------------- cross-process layer

/// Kills the child on drop so a failing assertion never leaks
/// processes.
struct Proc(Child);

impl Drop for Proc {
    fn drop(&mut self) {
        let _ = self.0.kill();
        let _ = self.0.wait();
    }
}

fn wait_port_file(path: &Path) -> String {
    let deadline = Instant::now() + Duration::from_secs(60);
    loop {
        if let Ok(s) = std::fs::read_to_string(path) {
            if !s.trim().is_empty() {
                return s.trim().to_string();
            }
        }
        assert!(Instant::now() < deadline,
                "child never wrote {}", path.display());
        thread::sleep(Duration::from_millis(10));
    }
}

fn spawn(label: &str, args: &[&str]) -> (Proc, String) {
    let pf = std::env::temp_dir().join(format!(
        "skydiver-trace-port-{label}-{}", std::process::id()));
    let _ = std::fs::remove_file(&pf);
    let child = Command::new(env!("CARGO_BIN_EXE_skydiver"))
        .args(args)
        .arg("--port-file")
        .arg(&pf)
        .args(["--trace", "--log-level", "error"])
        .stdin(Stdio::null())
        .stdout(Stdio::null())
        .stderr(Stdio::null())
        .spawn()
        .expect("spawn skydiver child");
    let addr = wait_port_file(&pf);
    let _ = std::fs::remove_file(&pf);
    (Proc(child), addr)
}

fn spawn_backend(artifacts: &Path, label: &str) -> (Proc, String) {
    let dir = artifacts.to_str().unwrap();
    spawn(label, &[
        "--artifacts", dir, "serve", "--addr", "127.0.0.1:0",
        "--net", "classifier", "--workers", "1", "--queue-cap", "256",
        // A wide grouping window keeps a backlog alive long enough
        // for the SIGKILL below to land mid-traffic.
        "--batch-wait-ms", "20",
    ])
}

fn cluster_metric(c: &mut Client, series: &str) -> u64 {
    let text = c.metrics().expect("router metrics");
    text.lines()
        .find_map(|l| l.strip_prefix(series)
            .and_then(|v| v.trim().parse().ok()))
        .unwrap_or(0)
}

/// The headline acceptance test: real processes, real SIGKILL, one
/// trace id spanning the router and a backend.
///
/// Two `serve` children behind a `route` child, all started with
/// `--trace`. Mid-traffic one backend takes a SIGKILL; the router
/// must finish every frame via the survivor. Afterwards the router's
/// dump must show a trace whose route root holds >= 2 attempt
/// siblings (the dead try errored), and a trace id fetched from the
/// router must also appear in the surviving backend's own dump with
/// its stage spans parented under the router's attempt span.
#[test]
fn sigkill_failover_stitches_one_trace_across_processes() {
    const FRAMES: usize = 128;
    let dir = artifacts("cluster");
    let (backend0, addr0) = spawn_backend(&dir, "b0");
    let (backend1, addr1) = spawn_backend(&dir, "b1");
    let (router, raddr) = spawn("router", &[
        "route", "--backend", &addr0, "--backend", &addr1,
        "--addr", "127.0.0.1:0", "--heartbeat-ms", "50",
        "--eject-after", "2", "--readmit-after", "2",
        "--retry-max", "16",
    ]);

    let gen = {
        let cfg = LoadGenConfig {
            addr: raddr.clone(),
            conns: 4,
            frames: FRAMES,
            window: 6,
            traffic: TrafficMode::Mixed,
            retry_busy: true,
            seed: 0x7121CE,
            ..LoadGenConfig::default()
        };
        thread::spawn(move || loadgen::run_collect(&cfg))
    };

    // Yank backend 0 only once traffic is demonstrably flowing, so
    // its queue still holds frames whose in-flight attempts must
    // fail over.
    let mut ctl = Client::connect(&raddr).unwrap();
    let deadline = Instant::now() + Duration::from_secs(60);
    while cluster_metric(&mut ctl, "skydiver_cluster_served_total")
        < 16
    {
        assert!(Instant::now() < deadline,
                "router never served the warm-up traffic");
        thread::sleep(Duration::from_millis(5));
    }
    drop(backend0); // SIGKILL, mid-traffic

    let (report, _) = gen.join().unwrap().expect("loadgen");
    assert_eq!(report.ok, FRAMES as u64,
               "every frame must survive the SIGKILL (busy={}, \
                errors={})", report.busy, report.errors);
    assert_eq!(report.errors, 0);

    // The outage must have been observed and survived.
    let deadline = Instant::now() + Duration::from_secs(15);
    while cluster_metric(&mut ctl, "skydiver_cluster_backends_live")
        != 1
    {
        assert!(Instant::now() < deadline,
                "router never ejected the killed backend");
        thread::sleep(Duration::from_millis(25));
    }

    // A few fresh frames AFTER the dust settles: the newest
    // completions on both survivors, so both flight recorders
    // retain them for the stitching assertion.
    let n = ctl.info().unwrap().pixels_len();
    for id in 0..3u64 {
        let resp =
            ctl.infer_pixels(1000 + id, "", vec![id as u8; n])
                .unwrap();
        assert!(matches!(resp.body, ResponseBody::Infer { .. }));
    }

    let router_events = parse_events(&ctl.trace_dump().unwrap());
    let backend_events = parse_events(
        &Client::connect(&addr1).unwrap().trace_dump().unwrap());
    assert!(!router_events.is_empty());
    assert!(!backend_events.is_empty());

    // 1. Failover shape: some trace holds >= 2 attempt spans that
    //    are siblings (same parent = the route root), at least one
    //    errored (the SIGKILLed try) and one clean.
    let failover = trace_ids(&router_events).into_iter().find(|id| {
        let attempts = of(&router_events, id, "attempt");
        attempts.len() >= 2
            && attempts.iter().any(|a| a.error)
            && attempts.iter().any(|a| !a.error)
            && attempts.iter()
                .all(|a| a.parent == attempts[0].parent)
            && of(&router_events, id, "route")
                .iter()
                .any(|r| r.span == attempts[0].parent)
    });
    assert!(failover.is_some(),
            "no trace with errored + clean sibling attempts under \
             one route root in the router dump");

    // 2. Cross-process stitching: a trace id in the router's dump
    //    also appears in the surviving backend's dump, and the
    //    backend's stage spans hang off the router's attempt span.
    let stitched = trace_ids(&router_events).into_iter().find(|id| {
        let attempts = of(&router_events, id, "attempt");
        ["queue", "compute", "write"].iter().all(|s| {
            of(&backend_events, id, s).iter().any(|e| {
                attempts.iter().any(|a| a.span == e.parent)
            })
        })
    });
    assert!(stitched.is_some(),
            "no trace id is shared between the router dump ({} \
             traces) and the surviving backend dump ({} traces) \
             with stitched parent links",
            trace_ids(&router_events).len(),
            trace_ids(&backend_events).len());

    // The shared timeline is renderable as one tree.
    let tree = skydiver::obs::recorder::render_tree(
        &ctl.trace_dump().unwrap()).unwrap();
    assert!(tree.contains("route"), "tree must show route spans");
    assert!(tree.contains("attempt"),
            "tree must show attempt spans");

    ctl.shutdown_server().unwrap();
    drop(ctl);
    drop(router);
    drop(backend1);
}
