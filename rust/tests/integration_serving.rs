//! Integration: the serving coordinator under load and under failure.
//!
//! These tests use a small synthetic network written to a temp
//! artifacts dir (no `make artifacts` needed), so they exercise the
//! full Service path — shared pipeline load, bounded queue, pull-based
//! workers, failure propagation — hermetically.

use std::path::{Path, PathBuf};
use std::time::{Duration, Instant};

use skydiver::coordinator::{DispatchMode, Policy, Response, Service,
                            ServiceConfig, ServingReport, SubmitError,
                            WorkerConfig};
use skydiver::power::EnergyModel;
use skydiver::server::loadgen::{gen_pixels, TrafficMode};
use skydiver::sim::ArchConfig;
use skydiver::snn::NetKind;

const SIDE: usize = 32; // synthetic net input is 1 x SIDE x SIDE
const TIMESTEPS: usize = 20;

/// Write `classifier_aprc.weights.{bin,json}` for a tiny single-conv
/// net into a fresh temp dir and return the dir (shared helper:
/// `data::write_synthetic_classifier`, also behind `skydiver synth`).
fn write_tiny_artifacts(label: &str) -> PathBuf {
    let dir = std::env::temp_dir()
        .join(format!("skydiver-serving-{label}-{}", std::process::id()));
    skydiver::data::write_synthetic_classifier(&dir, SIDE).unwrap();
    dir
}

fn worker_cfg(artifacts: PathBuf, use_runtime: bool) -> WorkerConfig {
    WorkerConfig {
        artifacts,
        kind: NetKind::Classifier,
        aprc: true,
        policy: Policy::Cbws,
        arch: ArchConfig::default(),
        energy: EnergyModel::default(),
        use_runtime,
        timesteps: Some(TIMESTEPS),
        sweep_threads: 1,
        temporal: true,
    }
}

/// Bright frame: near-full spike rate -> lots of event-driven work.
fn expensive_frame() -> Vec<u8> {
    vec![255u8; SIDE * SIDE]
}

/// Silent frame: zero spikes -> almost free.
fn cheap_frame() -> Vec<u8> {
    vec![0u8; SIDE * SIDE]
}

#[test]
fn bad_artifacts_fail_fast_at_start() {
    let wcfg = worker_cfg(PathBuf::from("/nonexistent/skydiver-nowhere"),
                          false);
    let t0 = Instant::now();
    let res = Service::start(ServiceConfig::default(), wcfg);
    assert!(res.is_err(), "missing weights must fail Service::start");
    assert!(t0.elapsed() < Duration::from_secs(30));
}

#[test]
fn zero_workers_rejected() {
    let dir = write_tiny_artifacts("zerow");
    let scfg = ServiceConfig { workers: 0, ..Default::default() };
    assert!(Service::start(scfg, worker_cfg(dir, false)).is_err());
}

/// The headline bugfix: a worker whose pipeline build fails (here: the
/// PJRT step artifact is absent while `use_runtime: true`) must surface
/// an error through `collect`/`shutdown` in bounded time — the old
/// coordinator left `collect` blocked forever.
fn assert_build_failure_surfaces(dispatch: DispatchMode) {
    let dir = write_tiny_artifacts("fail");
    let scfg = ServiceConfig {
        workers: 2,
        workers_max: 0,
        batch_max: 2,
        queue_cap: 16,
        batch_wait: Duration::from_millis(2),
        dispatch,
        cost_cap: None,
    };
    // Weights exist, so start() succeeds; the runtime half of the
    // pipeline is built per-worker, inside the worker threads.
    let service = Service::start(scfg, worker_cfg(dir, true))
        .expect("weights are valid; runtime build failure is per-worker");
    let t0 = Instant::now();
    let mut submit_err = false;
    for i in 0..4u64 {
        // Submits may themselves start failing once every worker has
        // died (NoWorkers) — that is an acceptable, observable outcome.
        if service.submit(i, expensive_frame()).is_err() {
            submit_err = true;
            break;
        }
    }
    let collected =
        service.collect_within(4, skydiver::CLOCK_HZ,
                               Duration::from_secs(30));
    assert!(submit_err || collected.is_err(),
            "worker build failure must surface, not hang");
    let shut = service.shutdown();
    assert!(shut.is_err(), "shutdown must report the worker failure");
    assert!(t0.elapsed() < Duration::from_secs(60),
            "failure took unboundedly long to surface");
}

#[test]
fn worker_build_failure_surfaces_work_queue() {
    assert_build_failure_surfaces(DispatchMode::WorkQueue);
}

#[test]
fn worker_build_failure_surfaces_round_robin() {
    assert_build_failure_surfaces(DispatchMode::RoundRobinBatch);
}

/// Skewed load used for the balance comparison: bursts of expensive
/// frames alternating with bursts of cheap ones, sized to whole
/// batches so the legacy dispatcher deals all-expensive batches to one
/// worker and all-cheap ones to the other.
fn run_skewed(dir: &Path, dispatch: DispatchMode) -> ServingReport {
    let scfg = ServiceConfig {
        workers: 2,
        workers_max: 0,
        batch_max: 4,
        queue_cap: 64,
        // Generous fill window so the legacy batcher forms full
        // batches deterministically.
        batch_wait: Duration::from_millis(100),
        dispatch,
        cost_cap: None,
    };
    let service =
        Service::start(scfg, worker_cfg(dir.to_path_buf(), false)).unwrap();
    let mut id = 0u64;
    for _burst in 0..2 {
        for _ in 0..4 {
            service.submit(id, expensive_frame()).unwrap();
            id += 1;
        }
        for _ in 0..4 {
            service.submit(id, cheap_frame()).unwrap();
            id += 1;
        }
    }
    let (resps, report) = service
        .collect_within(16, skydiver::CLOCK_HZ, Duration::from_secs(120))
        .unwrap();
    service.shutdown().unwrap();
    assert_eq!(resps.len(), 16);
    report
}

/// Acceptance: under a skewed load every worker serves frames and the
/// pull-based work queue beats the old whole-batch round-robin dispatch
/// on the host-side balance ratio.
#[test]
fn work_queue_balances_better_than_round_robin_on_skewed_load() {
    let dir = write_tiny_artifacts("balance");
    let rr = run_skewed(&dir, DispatchMode::RoundRobinBatch);
    let wq = run_skewed(&dir, DispatchMode::WorkQueue);

    assert!(wq.per_worker.iter().all(|&c| c > 0),
            "every worker must serve at least one frame: {:?}",
            wq.per_worker);
    assert!(wq.host_balance_ratio > 0.0
            && wq.host_balance_ratio <= 1.0 + 1e-9,
            "balance ratio out of range: {}", wq.host_balance_ratio);
    assert!(wq.host_balance_ratio > rr.host_balance_ratio,
            "work-queue dispatch ({:.3}, busy {:?}) must beat \
             round-robin whole-batch ({:.3}, busy {:?}) on skewed load",
            wq.host_balance_ratio, wq.per_worker_busy_us,
            rr.host_balance_ratio, rr.per_worker_busy_us);
}

/// Bursty submit: all frames at once, pool of 4 — every worker must
/// get a share (pull dispatch is work-conserving).
#[test]
fn all_workers_serve_under_bursty_load() {
    let dir = write_tiny_artifacts("bursty");
    let scfg = ServiceConfig {
        workers: 4,
        workers_max: 0,
        batch_max: 2,
        queue_cap: 128,
        batch_wait: Duration::from_millis(2),
        dispatch: DispatchMode::WorkQueue,
        cost_cap: None,
    };
    let service =
        Service::start(scfg, worker_cfg(dir, false)).unwrap();
    let n = 64u64;
    for i in 0..n {
        // Mixed burst: every 4th frame is expensive.
        let px = if i % 4 == 0 { expensive_frame() } else { cheap_frame() };
        service.submit(i, px).unwrap();
    }
    let (resps, report) = service
        .collect_within(n as usize, skydiver::CLOCK_HZ,
                        Duration::from_secs(120))
        .unwrap();
    service.shutdown().unwrap();

    assert_eq!(resps.len(), n as usize);
    let mut ids: Vec<u64> = resps.iter().map(|r| r.id).collect();
    ids.sort_unstable();
    assert_eq!(ids, (0..n).collect::<Vec<_>>(), "every frame answered");
    assert_eq!(report.per_worker.len(), 4);
    assert!(report.per_worker.iter().all(|&c| c > 0),
            "bursty load must reach all 4 workers: {:?}",
            report.per_worker);
    assert!(report.worker_failures.is_empty());
    assert!(report.queue_max_depth <= 128);
}

/// try_submit reports queue-full (backpressure) instead of buffering
/// without bound; blocking submit then absorbs the overflow.
#[test]
fn backpressure_reports_queue_full() {
    let dir = write_tiny_artifacts("backpressure");
    let scfg = ServiceConfig {
        workers: 1,
        workers_max: 0,
        batch_max: 1,
        queue_cap: 2,
        batch_wait: Duration::from_millis(2),
        dispatch: DispatchMode::WorkQueue,
        cost_cap: None,
    };
    let service =
        Service::start(scfg, worker_cfg(dir, false)).unwrap();
    let n = 8u64;
    let mut saw_full = false;
    for i in 0..n {
        match service.try_submit(i, expensive_frame()) {
            Ok(()) => {}
            Err(SubmitError::Full { capacity, .. }) => {
                assert_eq!(capacity, 2);
                saw_full = true;
                service.submit(i, expensive_frame()).unwrap();
            }
            Err(e) => panic!("unexpected submit error: {e}"),
        }
    }
    assert!(saw_full,
            "8 instant submits against a cap-2 queue and a 1-worker \
             pool chewing multi-ms frames must hit backpressure");
    let (resps, report) = service
        .collect_within(n as usize, skydiver::CLOCK_HZ,
                        Duration::from_secs(120))
        .unwrap();
    service.shutdown().unwrap();
    assert_eq!(resps.len(), n as usize);
    assert!(report.queue_max_depth <= 2);
    assert_eq!(report.per_worker, vec![n]);
}

/// Run one dispatch mode over a fixed frame list: submit everything,
/// collect, shut down, return responses sorted by id plus the report.
fn run_frames(dir: &Path, dispatch: DispatchMode,
              frames: &[Vec<u8>]) -> (Vec<Response>, ServingReport) {
    run_frames_with(dir, dispatch, frames, true)
}

fn run_frames_with(dir: &Path, dispatch: DispatchMode,
                   frames: &[Vec<u8>], temporal: bool)
                   -> (Vec<Response>, ServingReport) {
    let scfg = ServiceConfig {
        workers: 2,
        workers_max: 0,
        // Large enough that FIFO's first free worker can pull the
        // whole dense half of the burst as ONE batch — maximising the
        // imbalance cost-aware assembly must beat, which also keeps
        // the >= comparison below far from timing noise.
        batch_max: 16,
        queue_cap: 64,
        // Cost-aware mode's batch grouping window; FIFO pull ignores
        // it. Generous enough that the queued burst is fully visible
        // to the first LPT fill.
        batch_wait: Duration::from_millis(25),
        dispatch,
        cost_cap: None,
    };
    let wcfg = WorkerConfig {
        temporal,
        ..worker_cfg(dir.to_path_buf(), false)
    };
    let service = Service::start(scfg, wcfg).unwrap();
    for (i, px) in frames.iter().enumerate() {
        service.submit(i as u64, px.clone()).unwrap();
    }
    let (mut resps, report) = service
        .collect_within(frames.len(), skydiver::CLOCK_HZ,
                        Duration::from_secs(120))
        .unwrap();
    service.shutdown().unwrap();
    resps.sort_by_key(|r| r.id);
    (resps, report)
}

/// The skewed-density loadgen workload, arranged adversarially: two
/// expensive "plug" frames occupy both workers while the burst queues
/// behind them, and the burst itself arrives densest-first — so FIFO
/// count-based batch assembly hands one worker the heavy tail in a
/// single batch, while cost-aware LPT assembly splits it by predicted
/// cost.
fn skewed_burst() -> Vec<Vec<u8>> {
    let mut burst: Vec<Vec<u8>> = (0..32u64)
        .map(|id| gen_pixels(SIDE * SIDE, 0x5EED, id,
                             TrafficMode::Skewed))
        .collect();
    // Densest first (deterministic proxy for predicted cost).
    burst.sort_by_key(|px| {
        std::cmp::Reverse(px.iter().map(|&v| v as u64).sum::<u64>())
    });
    let mut frames = vec![expensive_frame(), expensive_frame()];
    frames.extend(burst);
    frames
}

/// Acceptance (tentpole): under the skewed-density loadgen workload,
/// cost-aware dispatch answers every request byte-identically to the
/// FIFO baseline *and* reports a host balance ratio at least as good.
#[test]
fn cost_aware_matches_fifo_outputs_and_balance_on_skewed_load() {
    let dir = write_tiny_artifacts("costparity");
    let frames = skewed_burst();
    let (fifo, fifo_rep) =
        run_frames(&dir, DispatchMode::WorkQueue, &frames);
    let (cost, cost_rep) =
        run_frames(&dir, DispatchMode::CostAware, &frames);

    // Byte-identical per-request outputs: dispatch order must never
    // change what a frame computes.
    assert_eq!(fifo.len(), cost.len());
    for (a, b) in fifo.iter().zip(&cost) {
        assert_eq!(a.id, b.id);
        assert_eq!(a.output_counts, b.output_counts,
                   "cost-aware dispatch changed frame {} output", a.id);
        assert_eq!(a.sim_cycles, b.sim_cycles);
        assert_eq!(a.predicted_cost, b.predicted_cost,
                   "cost model must tag identically across modes");
        assert!((a.energy_j - b.energy_j).abs() < 1e-15);
    }

    // Balance: the whole point of predicting request cost.
    assert!(cost_rep.host_balance_ratio >= fifo_rep.host_balance_ratio,
            "cost-aware balance {:.3} (busy {:?}) must be >= FIFO \
             {:.3} (busy {:?}) on the skewed burst",
            cost_rep.host_balance_ratio, cost_rep.per_worker_busy_us,
            fifo_rep.host_balance_ratio, fifo_rep.per_worker_busy_us);
    // And the predicted-cost split itself must be near-even (timing-
    // noise-free check of the LPT assembly).
    assert!(cost_rep.cost_balance_ratio > 0.7,
            "LPT assembly should spread predicted cost evenly, got \
             {:.3} ({:?})", cost_rep.cost_balance_ratio,
            cost_rep.per_worker_cost);
    // The calibration metric is populated and finite.
    assert!(cost_rep.mean_predicted_cost > 0.0);
    assert!(cost_rep.cost_calibration_error.is_finite());
}

/// The request-cost model's calibration must hold unchanged under the
/// bit-parallel temporal kernels: the same skewed burst served with
/// the per-timestep path (`temporal: false`) and the time-major path
/// answers byte-identically — same outputs, same `sim_cycles` (the
/// actuals the cost model is scored against), same predicted cost —
/// so the cost -> sim-cycles fit and its calibration error carry over
/// exactly, with no recalibration.
#[test]
fn temporal_kernels_preserve_outputs_and_cost_calibration() {
    let dir = write_tiny_artifacts("temporalcal");
    let frames = skewed_burst();
    let (on, on_rep) =
        run_frames_with(&dir, DispatchMode::CostAware, &frames, true);
    let (off, off_rep) =
        run_frames_with(&dir, DispatchMode::CostAware, &frames, false);
    assert_eq!(on.len(), off.len());
    for (a, b) in on.iter().zip(&off) {
        assert_eq!(a.id, b.id);
        assert_eq!(a.output_counts, b.output_counts,
                   "temporal kernels changed frame {} output", a.id);
        assert_eq!(a.sim_cycles, b.sim_cycles,
                   "temporal kernels changed frame {} sim cycles \
                    (the cost model's calibration target)", a.id);
        assert_eq!(a.predicted_cost, b.predicted_cost);
        assert!((a.energy_j - b.energy_j).abs() < 1e-15);
    }
    // Identical actuals + identical predictions => the calibration
    // error is the same number on both paths (it is computed from
    // predicted cost vs sim_cycles only, no wall time involved).
    assert!(on_rep.cost_calibration_error.is_finite());
    assert!(off_rep.cost_calibration_error.is_finite());
    assert!((on_rep.cost_calibration_error
             - off_rep.cost_calibration_error).abs() < 1e-12,
            "calibration error moved under temporal kernels: \
             {} vs {}", on_rep.cost_calibration_error,
            off_rep.cost_calibration_error);
    assert!(on_rep.mean_predicted_cost > 0.0);
}

/// Cost-denominated admission: the real pipeline's cost model prices
/// a dense frame far above a silent one, cost-aware services run a
/// cost-capped queue, and a dense burst sheds on predicted cost long
/// before the request-count cap is reached.
#[test]
fn cost_cap_sheds_dense_bursts_before_count_cap() {
    use skydiver::coordinator::{FramePayload, NOMINAL_FRAME_COST};
    let dir = write_tiny_artifacts("costcap");
    let cap = NOMINAL_FRAME_COST * 3 / 2;
    let scfg = ServiceConfig {
        workers: 1,
        workers_max: 0,
        batch_max: 1,
        queue_cap: 64,
        batch_wait: Duration::from_millis(2),
        dispatch: DispatchMode::CostAware,
        cost_cap: Some(cap),
    };
    let service =
        Service::start(scfg, worker_cfg(dir.to_path_buf(), false))
            .unwrap();
    // The calibrated model must price density, with a non-zero floor.
    let dense_cost = service.cost_model()
        .predict(&FramePayload::Pixels(expensive_frame()));
    let silent_cost = service.cost_model()
        .predict(&FramePayload::Pixels(cheap_frame()));
    assert!(silent_cost >= 1);
    assert!(dense_cost > 5 * silent_cost,
            "dense {dense_cost} vs silent {silent_cost}: the cost \
             model must separate the skew");
    assert!(dense_cost > cap,
            "an all-255 frame must exceed a 1.5x-nominal cap \
             (got {dense_cost} <= {cap})");
    // The service wired the cap into its queue.
    assert_eq!(service.queue_stats().cost_capacity, cap);

    // A dense burst: the queue can hold at most one above-cap frame
    // at a time (the empty-queue exemption), so with a single slow
    // worker most of the burst sheds on cost — far below the 64-slot
    // count cap.
    let mut shed = 0;
    let mut admitted = 0usize;
    for i in 0..8u64 {
        match service.try_submit(i, expensive_frame()) {
            Ok(()) => admitted += 1,
            Err(SubmitError::Full { .. }) => shed += 1,
            Err(e) => panic!("unexpected submit error: {e}"),
        }
    }
    assert!(shed >= 4,
            "dense burst must shed on predicted cost (admitted \
             {admitted}, shed {shed})");
    let (resps, report) = service
        .collect_within(admitted, skydiver::CLOCK_HZ,
                        Duration::from_secs(120))
        .unwrap();
    assert_eq!(resps.len(), admitted);
    assert!(report.mean_predicted_cost > 0.0);
    service.shutdown().unwrap();
}

/// Zero-frame runs produce a finite, all-zero report (regression for
/// the sim_fps inf/NaN).
#[test]
fn zero_frames_collect_is_finite_and_clean() {
    let dir = write_tiny_artifacts("zero");
    let service = Service::start(ServiceConfig::default(),
                                 worker_cfg(dir, false))
        .unwrap();
    let (resps, report) =
        service.collect(0, skydiver::CLOCK_HZ).unwrap();
    service.shutdown().unwrap();
    assert!(resps.is_empty());
    assert_eq!(report.frames, 0);
    assert_eq!(report.sim_fps, 0.0);
    assert!(report.served_fps.is_finite());
    assert!(report.host_balance_ratio.is_finite());
}

/// The in-worker frame-parallel sweep (`sweep_threads > 1`) must
/// produce exactly the same responses as the serial worker loop —
/// same ids, same output counts, same simulated cycles/energy.
///
/// Uses the round-robin *batching* dispatcher with a generous fill
/// window: all 12 frames are submitted up front, so the dispatcher
/// deterministically forms multi-frame batches (8 + 4) and the worker
/// is guaranteed to take the `serve_batch_sweep` path — a pull-based
/// worker draining fast could otherwise see only 1-frame batches and
/// make this parity check vacuous.
#[test]
fn worker_sweep_matches_serial_outputs() {
    let dir = write_tiny_artifacts("sweep");
    let run = |sweep_threads: usize| {
        let scfg = ServiceConfig {
            workers: 1,
            workers_max: 0,
            batch_max: 8,
            queue_cap: 64,
            batch_wait: Duration::from_millis(300),
            dispatch: DispatchMode::RoundRobinBatch,
            cost_cap: None,
        };
        let wcfg = WorkerConfig {
            sweep_threads,
            ..worker_cfg(dir.clone(), false)
        };
        let service = Service::start(scfg, wcfg).unwrap();
        for i in 0..12u64 {
            let px =
                if i % 3 == 0 { expensive_frame() } else { cheap_frame() };
            service.submit(i, px).unwrap();
        }
        let (mut resps, _) = service
            .collect_within(12, skydiver::CLOCK_HZ,
                            Duration::from_secs(120))
            .unwrap();
        service.shutdown().unwrap();
        resps.sort_by_key(|r| r.id);
        resps
    };
    let serial = run(1);
    let swept = run(4);
    assert_eq!(serial.len(), swept.len());
    for (a, b) in serial.iter().zip(&swept) {
        assert_eq!(a.id, b.id);
        assert_eq!(a.output_counts, b.output_counts,
                   "sweep diverged on frame {}", a.id);
        assert_eq!(a.sim_cycles, b.sim_cycles);
        assert!((a.energy_j - b.energy_j).abs() < 1e-15);
    }
}
