//! Cluster-tier integration: a front router over real gateway
//! backends, with the fault-injection proxy standing in for network
//! failures. The headline test kills a backend mid-traffic and
//! asserts the failure costs latency, never a lost request — every
//! response byte-identical to the in-process `Service` path.

use std::path::PathBuf;
use std::thread;
use std::time::{Duration, Instant};

use skydiver::cluster::{FaultPlan, FaultProxy, Router, RouterConfig};
use skydiver::coordinator::{DispatchMode, Policy, Service,
                            ServiceConfig, WorkerConfig};
use skydiver::power::EnergyModel;
use skydiver::server::loadgen::{self, LoadGenConfig, TrafficMode};
use skydiver::server::{Client, ErrorCode, Gateway, GatewayConfig,
                       ProtoError, RequestBody, ResponseBody,
                       WirePayload, WireRequest};
use skydiver::sim::ArchConfig;
use skydiver::snn::NetKind;

const SIDE: usize = 16;

fn artifacts(label: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(
        format!("skydiver-cluster-{label}-{}", std::process::id()));
    skydiver::data::write_synthetic_classifier(&dir, SIDE).unwrap();
    dir
}

fn worker_cfg(artifacts: PathBuf) -> WorkerConfig {
    WorkerConfig {
        artifacts,
        kind: NetKind::Classifier,
        aprc: true,
        policy: Policy::Cbws,
        arch: ArchConfig::default(),
        energy: EnergyModel::default(),
        use_runtime: false,
        timesteps: None,
        sweep_threads: 1,
        temporal: true,
    }
}

fn service_cfg(workers: usize, queue_cap: usize) -> ServiceConfig {
    ServiceConfig {
        workers,
        workers_max: 0,
        batch_max: 8,
        queue_cap,
        batch_wait: Duration::from_millis(2),
        dispatch: DispatchMode::WorkQueue,
        cost_cap: None,
    }
}

fn start_backend(label: &str) -> (Gateway, String) {
    let gw = Gateway::start_single(GatewayConfig::default(),
                                   service_cfg(1, 256),
                                   worker_cfg(artifacts(label)))
        .expect("backend start");
    let addr = gw.local_addr().to_string();
    (gw, addr)
}

/// The chaos acceptance test: three backends behind a router, one of
/// them reachable only through a fault proxy. Mid-traffic the proxy
/// simulates a SIGKILL (every connection severed, new ones refused);
/// the router must eject it, fail its in-flight requests over to the
/// survivors, and readmit it after the outage — with zero client-
/// visible errors and responses byte-identical to the in-process
/// `Service` on the same frames.
#[test]
fn killed_backend_costs_latency_not_requests() {
    const FRAMES: usize = 1200;
    let (gw0, addr0) = start_backend("chaos-b0");
    let (gw1, addr1) = start_backend("chaos-b1");
    let (gw2, addr2) = start_backend("chaos-b2");
    let proxy = FaultProxy::start("127.0.0.1:0", &addr2,
                                  FaultPlan::none())
        .expect("fault proxy");

    let router = Router::start(RouterConfig {
        addr: "127.0.0.1:0".into(),
        backends: vec![addr0, addr1, proxy.addr().to_string()],
        heartbeat_every: Duration::from_millis(50),
        eject_after: 2,
        readmit_after: 2,
        retry_max: 16,
        ..RouterConfig::default()
    }).expect("router start");
    let raddr = router.local_addr().to_string();

    let cfg = LoadGenConfig {
        addr: raddr,
        conns: 8,
        frames: FRAMES,
        window: 6,
        traffic: TrafficMode::Skewed,
        retry_busy: true,
        seed: 0xC1A0,
        ..LoadGenConfig::default()
    };
    let gen = {
        let cfg = cfg.clone();
        thread::spawn(move || loadgen::run_collect(&cfg))
    };

    // Let traffic reach all three backends, then yank one.
    thread::sleep(Duration::from_millis(100));
    proxy.kill();
    thread::sleep(Duration::from_millis(400));
    proxy.revive();

    // The backend must be readmitted (two consecutive probe
    // successes at a 50ms period — well under this deadline).
    let deadline = Instant::now() + Duration::from_secs(15);
    loop {
        if router.snapshot().backends[2].live {
            break;
        }
        assert!(Instant::now() < deadline,
                "backend never readmitted: {:?}",
                router.snapshot().backends[2]);
        thread::sleep(Duration::from_millis(25));
    }

    let (report, collected) =
        gen.join().unwrap().expect("loadgen through router");
    assert_eq!(report.ok, FRAMES as u64,
               "every frame must serve across the outage \
                (busy={}, errors={})", report.busy, report.errors);
    assert_eq!(report.errors, 0, "a killed backend must never cost \
                a non-BUSY request");
    assert_eq!(collected.len(), FRAMES);

    let rr = router.stop_and_wait().expect("router report");
    let b2 = &rr.backends[2];
    assert_eq!(b2.ejections, 1, "exactly one outage: {b2:?}");
    assert_eq!(b2.readmissions, 1, "exactly one recovery: {b2:?}");
    assert!(b2.live);
    assert_eq!(rr.failed, 0,
               "no admitted request may terminally fail: {rr:?}");
    assert!(rr.backends[0].dispatched > 0);
    assert!(rr.backends[1].dispatched > 0);
    // Heartbeats kept flowing to the live backends throughout.
    assert!(rr.backends[0].heartbeats_ok > 0);
    assert!(rr.backends[1].heartbeats_ok > 0);
    assert!(b2.heartbeat_failures > 0,
            "the outage must have been observed: {b2:?}");

    for gw in [gw0, gw1, gw2] {
        let r = gw.stop_and_wait().unwrap();
        assert_eq!(r.counters.internal, 0);
    }

    // Reference: identical frames through the in-process Service.
    // The loadgen workload is a pure function of (seed, conn, id) —
    // regenerate and byte-compare the deterministic response fields,
    // which also proves failover re-dispatch never duplicated or
    // crossed responses between requests.
    let service = Service::start(service_cfg(2, 1024),
                                 worker_cfg(artifacts("chaos-ref")))
        .unwrap();
    let n = service.frame_spec().pixels_len();
    for c in &collected {
        let seed = cfg.seed.wrapping_add(0xC0FF_EE00 * c.conn as u64);
        let pixels =
            loadgen::gen_pixels(n, seed, c.id, TrafficMode::Skewed);
        let gid = ((c.conn as u64) << 32) | c.id;
        service.submit(gid, pixels).unwrap();
    }
    let (resps, _) = service
        .collect_within(collected.len(), skydiver::CLOCK_HZ,
                        Duration::from_secs(600))
        .unwrap();
    service.shutdown().unwrap();
    let expected: std::collections::HashMap<u64, Vec<u32>> =
        resps.into_iter().map(|r| (r.id, r.output_counts)).collect();
    for c in &collected {
        let gid = ((c.conn as u64) << 32) | c.id;
        let want = expected.get(&gid).unwrap();
        let wire: Vec<u8> = c.output_counts.iter()
            .flat_map(|v| v.to_le_bytes()).collect();
        let oracle: Vec<u8> = want.iter()
            .flat_map(|v| v.to_le_bytes()).collect();
        assert_eq!(wire, oracle,
                   "conn {} frame {}: cluster path diverged from \
                    in-process path", c.conn, c.id);
        let argmax = want.iter().enumerate()
            .max_by_key(|&(_, v)| *v).map(|(i, _)| i as u32).unwrap();
        assert_eq!(c.prediction, argmax);
    }
}

/// Satellite: the gateway drain deadline. With `drain_timeout` at
/// zero, whatever is still queued when shutdown triggers is failed
/// with `SHUTTING_DOWN` ("gateway drain timeout") instead of being
/// waited on — shutdown time is bounded by the deadline, not by the
/// queue.
#[test]
fn drain_deadline_fails_stragglers_instead_of_waiting() {
    const PIPELINED: usize = 128;
    let gw = Gateway::start_single(
        GatewayConfig {
            drain_timeout: Duration::ZERO,
            ..GatewayConfig::default()
        },
        service_cfg(1, PIPELINED),
        worker_cfg(artifacts("drain")))
        .expect("gateway start");
    let addr = gw.local_addr().to_string();

    let mut client = Client::connect(&addr).unwrap();
    let n = client.info().unwrap().pixels_len();
    for id in 0..PIPELINED as u64 {
        client.send(&WireRequest {
            id,
            body: RequestBody::Infer {
                net: skydiver::server::protocol::NET_ANY,
                model: String::new(),
                payload: WirePayload::Pixels(vec![7u8; n]),
            },
        }).unwrap();
    }
    client.flush().unwrap();

    // Wait until every frame has been read and routed (admitted or
    // answered) — from here each request gets exactly one response —
    // then stop while the single worker still has a backlog.
    let deadline = Instant::now() + Duration::from_secs(30);
    while gw.counters().requests < PIPELINED as u64 {
        assert!(Instant::now() < deadline, "gateway never read the \
                 pipelined backlog: {:?}", gw.counters());
        thread::sleep(Duration::from_millis(1));
    }
    let t0 = Instant::now();
    gw.stop_handle().trigger();

    // Every pipelined request still gets exactly one response: served
    // if it beat the shutdown, SHUTTING_DOWN otherwise.
    let mut served = 0u64;
    let mut drained = 0u64;
    for _ in 0..PIPELINED {
        match client.recv() {
            Ok(resp) => match resp.body {
                ResponseBody::Infer { .. } => served += 1,
                ResponseBody::Error {
                    code: ErrorCode::ShuttingDown, ..
                } => drained += 1,
                other => panic!("unexpected response: {other:?}"),
            },
            // The gateway may close the connection after the final
            // flush; by then all frames must have been answered.
            Err(_) => break,
        }
    }
    drop(client);

    let report = gw.wait().expect("gateway report");
    let elapsed = t0.elapsed();
    assert!(elapsed < Duration::from_secs(30),
            "zero drain deadline must bound shutdown, took \
             {elapsed:?}");
    assert!(report.counters.shutting_down > 0,
            "a zero drain window must fail the backlog: {:?}",
            report.counters);
    assert_eq!(served, report.counters.served);
    assert_eq!(drained, report.counters.shutting_down);
    assert_eq!(served + drained, PIPELINED as u64,
               "each pipelined request needs exactly one answer");
}

/// The fault plans used by the chaos harness, pinned one by one
/// against a real gateway: a BUSY storm surfaces as typed `BUSY`
/// errors, a response blackhole surfaces as a client read timeout
/// (`ProtoError::TimedOut`), and truncation kills the connection.
#[test]
fn fault_plans_inject_what_they_say() {
    let (gw, addr) = start_backend("faults");
    // Frame contract straight from the gateway — the proxies below
    // mangle the data path.
    let n = Client::connect(&addr).unwrap()
        .info().unwrap().pixels_len();

    // BUSY storm: every Infer answered locally with BUSY; non-Infer
    // ops (the Info above went direct) pass through untouched.
    let storm = FaultProxy::start(
        "127.0.0.1:0", &addr,
        FaultPlan::parse("busy=1.0,seed=7").unwrap()).unwrap();
    let mut c = Client::connect(storm.addr().to_string()).unwrap();
    let resp = c.infer_pixels(1, "", vec![1u8; n]).unwrap();
    match resp.body {
        ResponseBody::Error { code: ErrorCode::Busy, .. } => {}
        other => panic!("busy storm must answer BUSY: {other:?}"),
    }
    // Heartbeats are not Infer ops: they reach the gateway.
    assert!(!c.heartbeat().unwrap().is_empty());
    drop(c);
    storm.shutdown();

    // Blackhole: requests forward, responses vanish — exactly the
    // shape a client read timeout exists for.
    let hole = FaultProxy::start(
        "127.0.0.1:0", &addr,
        FaultPlan::parse("blackhole=1.0").unwrap()).unwrap();
    let mut c = Client::connect(hole.addr().to_string()).unwrap();
    c.set_read_timeout(Some(Duration::from_millis(200))).unwrap();
    let err = c.infer_pixels(2, "", vec![2u8; n])
        .expect_err("blackholed response must time out");
    assert!(matches!(err.downcast_ref::<ProtoError>(),
                     Some(ProtoError::TimedOut)),
            "want ProtoError::TimedOut, got: {err:?}");
    drop(c);
    hole.shutdown();

    // Truncation: half a frame then a hard close — the client must
    // see an error, not a clean result.
    let cut = FaultProxy::start(
        "127.0.0.1:0", &addr,
        FaultPlan::parse("truncate=1.0").unwrap()).unwrap();
    let mut c = Client::connect(cut.addr().to_string()).unwrap();
    assert!(c.infer_pixels(3, "", vec![3u8; n]).is_err(),
            "truncated frame must surface as an error");
    drop(c);
    cut.shutdown();

    gw.stop_and_wait().unwrap();
}

/// Router observability plumbing: `Metrics` renders the cluster
/// exposition, `Heartbeat` aggregates live-backend loads, inference
/// proxies end-to-end, and a wire `Shutdown` stops the router (and
/// only the router — backends keep their own lifecycle).
#[test]
fn router_metrics_heartbeat_and_wire_shutdown() {
    let (gw0, addr0) = start_backend("obs-b0");
    let (gw1, addr1) = start_backend("obs-b1");
    let router = Router::start(RouterConfig {
        addr: "127.0.0.1:0".into(),
        backends: vec![addr0.clone(), addr1.clone()],
        heartbeat_every: Duration::from_millis(50),
        ..RouterConfig::default()
    }).expect("router start");

    let mut c = Client::connect(router.local_addr().to_string())
        .unwrap();
    let n = c.info().unwrap().pixels_len();
    for id in 0..16u64 {
        let resp = c.infer_pixels(id, "", vec![id as u8; n]).unwrap();
        assert!(matches!(resp.body, ResponseBody::Infer { .. }),
                "routed inference failed: {:?}", resp.body);
    }

    // Heartbeat through the router sums per-model load over live
    // backends; both mount the synthetic classifier.
    let loads = c.heartbeat().unwrap();
    assert_eq!(loads.len(), 1, "one merged model entry: {loads:?}");
    assert_eq!(loads[0].name, NetKind::Classifier.as_str());
    assert_eq!(loads[0].capacity, 256 * 2,
               "capacity must sum across both backends");

    let text = c.metrics().unwrap();
    for series in [
        "skydiver_backend_state",
        "skydiver_backend_ejections_total",
        "skydiver_backend_failovers_total",
        "skydiver_backend_heartbeat_latency_us",
        "skydiver_cluster_backends_live 2",
        "skydiver_cluster_served_total 16",
        "skydiver_cluster_failed_total 0",
        "skydiver_cluster_model_cost_depth{model=\"classifier\"}",
    ] {
        assert!(text.contains(series),
                "metrics must expose {series}:\n{text}");
    }
    for addr in [&addr0, &addr1] {
        assert!(text.contains(
            &format!("skydiver_backend_state{{backend=\"{addr}\"}} 1")),
            "both backends live in:\n{text}");
    }

    // Wire shutdown: acked, router stops, backends stay up.
    c.shutdown_server().unwrap();
    drop(c);
    let rr = router.wait().expect("router report");
    assert_eq!(rr.served, 16);
    assert_eq!(rr.failed, 0);

    // Backends are independent processes conceptually — still alive
    // and serving after the router is gone.
    let mut direct = Client::connect(&addr0).unwrap();
    assert!(matches!(direct.infer_pixels(99, "", vec![9u8; n])
                         .unwrap().body,
                     ResponseBody::Infer { .. }));
    drop(direct);
    gw0.stop_and_wait().unwrap();
    gw1.stop_and_wait().unwrap();
}
