//! Wire-protocol property tests: randomized round-trips (payload
//! sizes from 0 to near the frame cap) and malformed-frame handling —
//! truncation, bad magic, oversized length, garbage — must always
//! produce typed errors, never panics.

use std::io::Cursor;

use skydiver::data::SplitMix64;
use skydiver::server::protocol::{read_frame, ErrorCode, ProtoError,
                                 RequestBody, ResponseBody, WirePayload,
                                 WireRequest, WireResponse, HEADER_LEN,
                                 KIND_REQUEST, KIND_RESPONSE, MAGIC,
                                 MAX_BODY, VERSION};

fn rt_req(req: &WireRequest) {
    let f = req.encode();
    let body = read_frame(&mut Cursor::new(&f), KIND_REQUEST)
        .expect("frame read").expect("not eof");
    assert_eq!(&WireRequest::decode_body(&body).expect("decode"), req);
}

fn rt_resp(resp: &WireResponse) {
    let f = resp.encode();
    let body = read_frame(&mut Cursor::new(&f), KIND_RESPONSE)
        .expect("frame read").expect("not eof");
    assert_eq!(&WireResponse::decode_body(&body).expect("decode"),
               resp);
}

#[test]
fn random_pixel_payloads_roundtrip() {
    let mut rng = SplitMix64::new(0x50F7);
    // 0, 1, word boundaries, a big one close to (but under) the body
    // cap — the largest payload a frame can legally carry.
    let sizes = [0usize, 1, 63, 64, 65, 1000, 1 << 16, MAX_BODY - 64];
    for (k, &n) in sizes.iter().enumerate() {
        let px: Vec<u8> =
            (0..n).map(|_| rng.next_below(256) as u8).collect();
        rt_req(&WireRequest {
            id: rng.next_u64(),
            body: RequestBody::Infer {
                net: (k % 2) as u8,
                payload: WirePayload::Pixels(px),
            },
        });
    }
}

#[test]
fn random_spike_payloads_roundtrip() {
    let mut rng = SplitMix64::new(0x5A1C);
    for &nwords in &[0usize, 1, 7, 64, 2048] {
        let words: Vec<u64> =
            (0..nwords).map(|_| rng.next_u64()).collect();
        rt_req(&WireRequest {
            id: rng.next_u64(),
            body: RequestBody::Infer {
                net: 0,
                payload: WirePayload::Spikes {
                    timesteps: 1 + rng.next_below(32) as u32,
                    words,
                },
            },
        });
    }
}

#[test]
fn random_responses_roundtrip() {
    let mut rng = SplitMix64::new(0xF00D);
    for &n in &[0usize, 1, 10, 1000] {
        let counts: Vec<u32> =
            (0..n).map(|_| rng.next_u64() as u32).collect();
        rt_resp(&WireResponse {
            id: rng.next_u64(),
            body: ResponseBody::Infer {
                prediction: rng.next_u64() as u32,
                output_counts: counts,
                latency_us: rng.next_u64(),
                worker: rng.next_below(64) as u32,
            },
        });
    }
    for code in [ErrorCode::Busy, ErrorCode::BadRequest,
                 ErrorCode::ShuttingDown, ErrorCode::Internal] {
        rt_resp(&WireResponse {
            id: rng.next_u64(),
            body: ResponseBody::Error {
                code,
                detail: format!("detail {} — unicode ✓", code.as_str()),
            },
        });
    }
    rt_resp(&WireResponse {
        id: 1,
        body: ResponseBody::Metrics {
            text: "skydiver_busy_total 3\n".repeat(100),
        },
    });
}

#[test]
fn every_truncation_of_a_real_frame_is_a_typed_error() {
    let f = WireRequest {
        id: 77,
        body: RequestBody::Infer {
            net: 0,
            payload: WirePayload::Spikes {
                timesteps: 4,
                words: vec![0xDEAD_BEEF; 32],
            },
        },
    }.encode();
    for cut in 0..f.len() {
        match read_frame(&mut Cursor::new(&f[..cut]), KIND_REQUEST) {
            Ok(None) => assert_eq!(cut, 0, "clean EOF only at 0 bytes"),
            Ok(Some(_)) => panic!("prefix of {cut} bytes decoded"),
            Err(ProtoError::Truncated) => {}
            Err(e) => panic!("unexpected error at cut {cut}: {e}"),
        }
    }
}

#[test]
fn bad_magic_is_fatal() {
    let mut f = WireRequest { id: 1, body: RequestBody::Metrics }
        .encode();
    f[2] = b'?';
    let err = read_frame(&mut Cursor::new(&f), KIND_REQUEST)
        .unwrap_err();
    assert!(matches!(err, ProtoError::BadMagic(_)), "{err}");
    assert!(err.is_fatal());
}

#[test]
fn oversized_length_is_fatal_and_allocates_nothing() {
    let mut hdr = Vec::new();
    hdr.extend_from_slice(&MAGIC);
    hdr.push(VERSION);
    hdr.push(KIND_REQUEST);
    hdr.extend_from_slice(&(u32::MAX).to_le_bytes());
    assert_eq!(hdr.len(), HEADER_LEN);
    let err =
        read_frame(&mut Cursor::new(&hdr), KIND_REQUEST).unwrap_err();
    match err {
        ProtoError::Oversized(n) => {
            assert!(n > MAX_BODY);
        }
        e => panic!("expected Oversized, got {e}"),
    }
}

#[test]
fn random_garbage_never_panics() {
    let mut rng = SplitMix64::new(0xBAD);
    for _ in 0..500 {
        let n = rng.next_below(64) as usize;
        let mut buf: Vec<u8> =
            (0..n).map(|_| rng.next_below(256) as u8).collect();
        // Half the time, start with valid magic so deeper decode paths
        // are reached too.
        if rng.next_below(2) == 0 && buf.len() >= 4 {
            buf[..4].copy_from_slice(&MAGIC);
        }
        // Must return, not panic; success is fine if the bytes happen
        // to form a frame.
        let _ = read_frame(&mut Cursor::new(&buf), KIND_REQUEST);
        let _ = WireRequest::decode_body(&buf);
        let _ = WireResponse::decode_body(&buf);
    }
}

#[test]
fn trailing_bytes_rejected_but_recoverable() {
    let f = WireRequest { id: 3, body: RequestBody::Info }.encode();
    let mut body = read_frame(&mut Cursor::new(&f), KIND_REQUEST)
        .unwrap().unwrap();
    body.push(0x00);
    let err = WireRequest::decode_body(&body).unwrap_err();
    assert!(matches!(err, ProtoError::Malformed(_)));
    assert!(!err.is_fatal(), "body-level damage keeps the connection");
}

#[test]
fn pipelined_frames_parse_in_sequence() {
    // Several frames back to back on one stream — the reader must
    // consume exactly one frame per call.
    let reqs: Vec<WireRequest> = (0..10u64)
        .map(|i| WireRequest {
            id: i,
            body: RequestBody::Infer {
                net: 0,
                payload: WirePayload::Pixels(vec![i as u8; i as usize]),
            },
        })
        .collect();
    let mut stream = Vec::new();
    for r in &reqs {
        stream.extend_from_slice(&r.encode());
    }
    let mut cur = Cursor::new(&stream);
    for want in &reqs {
        let body =
            read_frame(&mut cur, KIND_REQUEST).unwrap().unwrap();
        assert_eq!(&WireRequest::decode_body(&body).unwrap(), want);
    }
    assert!(matches!(read_frame(&mut cur, KIND_REQUEST), Ok(None)));
}
