//! Wire-protocol property tests: randomized round-trips (payload
//! sizes from 0 to near the frame cap, random model selectors) and
//! malformed-frame handling — truncation, bad magic, oversized
//! length, garbage — must always produce typed errors, never panics.
//! Plus the v1↔v2 compatibility properties: every valid v1 frame
//! still decodes under the v2-capable reader (and carries the empty
//! selector, i.e. routes to the default model), and the v2-only
//! fields fuzz clean.

use std::io::Cursor;

use skydiver::data::SplitMix64;
use skydiver::server::protocol::{read_frame, ErrorCode, ModelLoad,
                                 ProtoError, RequestBody, ResponseBody,
                                 TraceContext, WirePayload, WireRequest,
                                 WireResponse, EXT_TRACE, HEADER_LEN,
                                 KIND_REQUEST, KIND_RESPONSE, MAGIC,
                                 MAX_BODY, MAX_MODEL_NAME, NET_ANY, V1,
                                 V2};

fn rt_req(req: &WireRequest) {
    let f = req.encode().expect("encode");
    let (ver, body) = read_frame(&mut Cursor::new(&f), KIND_REQUEST)
        .expect("frame read").expect("not eof");
    assert_eq!(ver, V2);
    assert_eq!(&WireRequest::decode_body(ver, &body).expect("decode"),
               req);
}

fn rt_resp(resp: &WireResponse) {
    let f = resp.encode(V2);
    let (ver, body) = read_frame(&mut Cursor::new(&f), KIND_RESPONSE)
        .expect("frame read").expect("not eof");
    assert_eq!(ver, V2);
    assert_eq!(&WireResponse::decode_body(ver, &body).expect("decode"),
               resp);
}

/// Random model selector: empty (default routing) half the time.
fn rand_model(rng: &mut SplitMix64) -> String {
    let n = rng.next_below(2 * MAX_MODEL_NAME as u64 + 2) as usize;
    if n > MAX_MODEL_NAME {
        return String::new();
    }
    (0..n)
        .map(|_| (b'a' + rng.next_below(26) as u8) as char)
        .collect()
}

#[test]
fn random_pixel_payloads_roundtrip() {
    let mut rng = SplitMix64::new(0x50F7);
    // 0, 1, word boundaries, a big one close to (but under) the body
    // cap — the largest payload a frame can legally carry.
    let sizes = [0usize, 1, 63, 64, 65, 1000, 1 << 16, MAX_BODY - 512];
    for (k, &n) in sizes.iter().enumerate() {
        let px: Vec<u8> =
            (0..n).map(|_| rng.next_below(256) as u8).collect();
        rt_req(&WireRequest {
            id: rng.next_u64() >> 1, // never the reserved id
            body: RequestBody::Infer {
                net: if k % 2 == 0 { (k % 2) as u8 } else { NET_ANY },
                model: rand_model(&mut rng),
                payload: WirePayload::Pixels(px),
            },
        });
    }
}

#[test]
fn random_spike_payloads_roundtrip() {
    let mut rng = SplitMix64::new(0x5A1C);
    for &nwords in &[0usize, 1, 7, 64, 2048] {
        let words: Vec<u64> =
            (0..nwords).map(|_| rng.next_u64()).collect();
        rt_req(&WireRequest {
            id: rng.next_u64() >> 1,
            body: RequestBody::Infer {
                net: 0,
                model: rand_model(&mut rng),
                payload: WirePayload::Spikes {
                    timesteps: 1 + rng.next_below(32) as u32,
                    words,
                },
            },
        });
    }
}

#[test]
fn random_responses_roundtrip() {
    let mut rng = SplitMix64::new(0xF00D);
    for &n in &[0usize, 1, 10, 1000] {
        let counts: Vec<u32> =
            (0..n).map(|_| rng.next_u64() as u32).collect();
        rt_resp(&WireResponse {
            id: rng.next_u64(),
            body: ResponseBody::Infer {
                prediction: rng.next_u64() as u32,
                output_counts: counts,
                latency_us: rng.next_u64(),
                worker: rng.next_below(64) as u32,
            },
        });
    }
    for code in [ErrorCode::Busy, ErrorCode::BadRequest,
                 ErrorCode::ShuttingDown, ErrorCode::Internal] {
        rt_resp(&WireResponse {
            id: rng.next_u64(),
            body: ResponseBody::Error {
                code,
                detail: format!("detail {} — unicode ✓", code.as_str()),
            },
        });
    }
    rt_resp(&WireResponse {
        id: 1,
        body: ResponseBody::Metrics {
            text: "skydiver_busy_total 3\n".repeat(100),
        },
    });
    rt_resp(&WireResponse {
        id: 2,
        body: ResponseBody::Info {
            net: 1,
            c: 3,
            h: 80,
            w: 160,
            timesteps: 8,
            model: "segmenter".into(),
            nmodels: 7,
        },
    });
}

// ------------------------------------------------- v1 <-> v2 compat

/// Every model-less request encodes in both versions, and BOTH
/// encodings decode back (at their own version) to the identical
/// value — the property that lets a v2 gateway serve v1 clients.
#[test]
fn every_valid_v1_frame_decodes_under_v2_reader() {
    let mut rng = SplitMix64::new(0xC0DA);
    for i in 0..200u64 {
        let req = match i % 4 {
            0 => WireRequest {
                id: rng.next_u64() >> 1,
                body: RequestBody::Infer {
                    net: (i % 2) as u8,
                    model: String::new(),
                    payload: WirePayload::Pixels(
                        (0..rng.next_below(512) as usize)
                            .map(|_| rng.next_below(256) as u8)
                            .collect()),
                },
            },
            1 => WireRequest {
                id: rng.next_u64() >> 1,
                body: RequestBody::Infer {
                    net: (i % 2) as u8,
                    model: String::new(),
                    payload: WirePayload::Spikes {
                        timesteps: 1 + rng.next_below(16) as u32,
                        words: (0..rng.next_below(64) as usize)
                            .map(|_| rng.next_u64())
                            .collect(),
                    },
                },
            },
            2 => WireRequest {
                id: rng.next_u64() >> 1,
                body: RequestBody::Metrics,
            },
            _ => WireRequest {
                id: rng.next_u64() >> 1,
                body: RequestBody::Info { model: String::new() },
            },
        };
        // The v1 bytes pass through the same reader the gateway uses…
        let f1 = req.encode_v1().expect("v1 encode");
        let (ver, body) =
            read_frame(&mut Cursor::new(&f1), KIND_REQUEST)
                .expect("read").expect("not eof");
        assert_eq!(ver, V1);
        let decoded =
            WireRequest::decode_body(ver, &body).expect("v1 decode");
        // …and the decoded selector is the empty string = the
        // registry's default model.
        assert_eq!(decoded, req);
        match &decoded.body {
            RequestBody::Infer { model, .. }
            | RequestBody::Info { model } => {
                assert!(model.is_empty(),
                        "v1 frames must route to the default model");
            }
            _ => {}
        }
        // The v2 encoding of the same request also roundtrips.
        rt_req(&req);
    }
}

/// The version byte is what separates the dialects: the same
/// model-less body bytes decode under both versions (v2 Infer/Info
/// bodies differ from v1 only by the selector bytes).
#[test]
fn v1_and_v2_bodies_differ_exactly_by_the_selector() {
    let req = WireRequest {
        id: 42,
        body: RequestBody::Infer {
            net: 0,
            model: String::new(),
            payload: WirePayload::Pixels(vec![9; 16]),
        },
    };
    let f1 = req.encode_v1().unwrap();
    let f2 = req.encode().unwrap();
    // v2 carries exactly one extra byte here: the zero-length model
    // selector.
    assert_eq!(f2.len(), f1.len() + 1);
    // A v1 body fed to the v2 decoder must NOT parse (the selector
    // byte is missing → the payload shifts → typed error or wrong
    // value, never a panic). Verify it errors: the first payload byte
    // is consumed as the selector length.
    let (_, body1) = read_frame(&mut Cursor::new(&f1), KIND_REQUEST)
        .unwrap().unwrap();
    let as_v2 = WireRequest::decode_body(V2, &body1);
    assert!(as_v2.is_err() || as_v2.unwrap() != req,
            "decoding v1 bytes as v2 must not silently yield the \
             original request");
}

#[test]
fn every_truncation_of_a_v2_frame_is_a_typed_error() {
    let f = WireRequest {
        id: 77,
        body: RequestBody::Infer {
            net: 0,
            model: "segmenter".into(),
            payload: WirePayload::Spikes {
                timesteps: 4,
                words: vec![0xDEAD_BEEF; 32],
            },
        },
    }.encode().unwrap();
    for cut in 0..f.len() {
        match read_frame(&mut Cursor::new(&f[..cut]), KIND_REQUEST) {
            Ok(None) => assert_eq!(cut, 0, "clean EOF only at 0 bytes"),
            Ok(Some(_)) => panic!("prefix of {cut} bytes decoded"),
            Err(ProtoError::Truncated) => {}
            Err(e) => panic!("unexpected error at cut {cut}: {e}"),
        }
    }
    // Body-level truncation (whole frame read, selector or payload
    // bytes missing inside) is typed, never a panic.
    let (ver, body) = read_frame(&mut Cursor::new(&f), KIND_REQUEST)
        .unwrap().unwrap();
    for cut in 0..body.len() {
        assert!(WireRequest::decode_body(ver, &body[..cut]).is_err());
    }
}

#[test]
fn truncated_v1_infer_body_is_typed_too() {
    let f = WireRequest {
        id: 5,
        body: RequestBody::Infer {
            net: 1,
            model: String::new(),
            payload: WirePayload::Pixels(vec![3; 40]),
        },
    }.encode_v1().unwrap();
    let (ver, body) = read_frame(&mut Cursor::new(&f), KIND_REQUEST)
        .unwrap().unwrap();
    assert_eq!(ver, V1);
    for cut in 0..body.len() {
        assert!(WireRequest::decode_body(ver, &body[..cut]).is_err());
    }
}

#[test]
fn bad_magic_is_fatal() {
    let mut f = WireRequest { id: 1, body: RequestBody::Metrics }
        .encode().unwrap();
    f[2] = b'?';
    let err = read_frame(&mut Cursor::new(&f), KIND_REQUEST)
        .unwrap_err();
    assert!(matches!(err, ProtoError::BadMagic(_)), "{err}");
    assert!(err.is_fatal());
}

#[test]
fn unknown_version_is_fatal() {
    let mut f = WireRequest { id: 1, body: RequestBody::Metrics }
        .encode().unwrap();
    for bad in [0u8, 3, 7, 255] {
        f[4] = bad;
        let err = read_frame(&mut Cursor::new(&f), KIND_REQUEST)
            .unwrap_err();
        assert!(matches!(err, ProtoError::BadVersion(v) if v == bad),
                "{err}");
        assert!(err.is_fatal());
    }
}

#[test]
fn oversized_length_is_fatal_and_allocates_nothing() {
    let mut hdr = Vec::new();
    hdr.extend_from_slice(&MAGIC);
    hdr.push(V2);
    hdr.push(KIND_REQUEST);
    hdr.extend_from_slice(&(u32::MAX).to_le_bytes());
    assert_eq!(hdr.len(), HEADER_LEN);
    let err =
        read_frame(&mut Cursor::new(&hdr), KIND_REQUEST).unwrap_err();
    match err {
        ProtoError::Oversized(n) => {
            assert!(n > MAX_BODY);
        }
        e => panic!("expected Oversized, got {e}"),
    }
}

#[test]
fn random_garbage_never_panics() {
    let mut rng = SplitMix64::new(0xBAD);
    for round in 0..1000 {
        let n = rng.next_below(64) as usize;
        let mut buf: Vec<u8> =
            (0..n).map(|_| rng.next_below(256) as u8).collect();
        // Half the time, start with valid magic (and alternate a valid
        // version byte) so deeper decode paths are reached too.
        if rng.next_below(2) == 0 && buf.len() >= 5 {
            buf[..4].copy_from_slice(&MAGIC);
            buf[4] = if round % 2 == 0 { V1 } else { V2 };
        }
        // Must return, not panic; success is fine if the bytes happen
        // to form a frame.
        let _ = read_frame(&mut Cursor::new(&buf), KIND_REQUEST);
        for ver in [V1, V2] {
            let _ = WireRequest::decode_body(ver, &buf);
            let _ = WireRequest::decode_body_ext(ver, &buf);
            let _ = WireResponse::decode_body(ver, &buf);
            let _ = WireResponse::decode_body_ext(ver, &buf);
        }
    }
}

/// Fuzz specifically the v2 selector bytes: a selector length that
/// overruns the body, and garbage behind a valid selector, are typed
/// errors.
#[test]
fn v2_selector_field_fuzz_is_typed() {
    let req = WireRequest {
        id: 8,
        body: RequestBody::Info { model: "classifier".into() },
    };
    let f = req.encode().unwrap();
    let (ver, body) = read_frame(&mut Cursor::new(&f), KIND_REQUEST)
        .unwrap().unwrap();
    // Body layout: id(8) op(1) len(1) name(10). Corrupt the length to
    // every possible value — overruns must be Truncated/Malformed.
    for bad_len in 0..=255u8 {
        let mut b = body.clone();
        b[9] = bad_len;
        match WireRequest::decode_body(ver, &b) {
            Ok(decoded) => {
                // Only the true length can decode, and only to the
                // original name.
                assert_eq!(bad_len as usize, 10);
                assert_eq!(decoded, req);
            }
            Err(ProtoError::Truncated)
            | Err(ProtoError::Malformed(_)) => {}
            Err(e) => panic!("unexpected error for len {bad_len}: {e}"),
        }
    }
    // Trailing garbage after a well-formed selector is malformed.
    let mut b = body.clone();
    b.push(0xAB);
    assert!(matches!(WireRequest::decode_body(ver, &b),
                     Err(ProtoError::Malformed(_))));
}

#[test]
fn trailing_bytes_rejected_but_recoverable() {
    let f = WireRequest {
        id: 3,
        body: RequestBody::Info { model: String::new() },
    }.encode().unwrap();
    let (ver, mut body) = read_frame(&mut Cursor::new(&f), KIND_REQUEST)
        .unwrap().unwrap();
    body.push(0x00);
    let err = WireRequest::decode_body(ver, &body).unwrap_err();
    assert!(matches!(err, ProtoError::Malformed(_)));
    assert!(!err.is_fatal(), "body-level damage keeps the connection");
}

// ------------------------------------------- v2 heartbeat (cluster)

#[test]
fn heartbeat_frames_roundtrip_v2() {
    rt_req(&WireRequest { id: 99, body: RequestBody::Heartbeat });
    let mut rng = SplitMix64::new(0x48EA);
    for &n in &[0usize, 1, 3, 17] {
        let models: Vec<ModelLoad> = (0..n)
            .map(|i| ModelLoad {
                name: if i == 0 {
                    String::new() // default-model slot
                } else {
                    rand_model(&mut rng)
                },
                cost_depth: rng.next_u64(),
                // Exercise the "uncapped" sentinel too.
                cost_capacity: if i % 2 == 0 {
                    u64::MAX
                } else {
                    rng.next_u64()
                },
                depth: rng.next_u64() as u32,
                capacity: rng.next_u64() as u32,
            })
            .collect();
        rt_resp(&WireResponse {
            id: rng.next_u64(),
            body: ResponseBody::Heartbeat { models },
        });
    }
    // A maximum-length model name survives.
    rt_resp(&WireResponse {
        id: 1,
        body: ResponseBody::Heartbeat {
            models: vec![ModelLoad {
                name: "m".repeat(MAX_MODEL_NAME),
                cost_depth: 0,
                cost_capacity: 0,
                depth: 0,
                capacity: 0,
            }],
        },
    });
}

#[test]
fn heartbeat_is_v2_only_in_both_directions() {
    // Encoding: a heartbeat request is not expressible in v1.
    let req = WireRequest { id: 7, body: RequestBody::Heartbeat };
    assert!(req.encode_v1().is_err());
    // Decoding: op 4 under the v1 dialect is malformed, not a
    // surprise variant — an old gateway answers BAD_REQUEST and the
    // connection survives.
    let mut body = Vec::new();
    body.extend_from_slice(&7u64.to_le_bytes());
    body.push(4);
    let err = WireRequest::decode_body(V1, &body).unwrap_err();
    assert!(matches!(err, ProtoError::Malformed(_)), "{err}");
    assert!(!err.is_fatal());
    // Same for response tag 5.
    let mut rbody = Vec::new();
    rbody.extend_from_slice(&7u64.to_le_bytes());
    rbody.push(5);
    rbody.push(0); // zero models
    assert!(WireResponse::decode_body(V2, &rbody).is_ok());
    let err = WireResponse::decode_body(V1, &rbody).unwrap_err();
    assert!(matches!(err, ProtoError::Malformed(_)), "{err}");
}

#[test]
fn every_truncation_of_a_heartbeat_response_is_typed() {
    let f = WireResponse {
        id: 11,
        body: ResponseBody::Heartbeat {
            models: vec![
                ModelLoad {
                    name: "classifier".into(),
                    cost_depth: 120_000,
                    cost_capacity: u64::MAX,
                    depth: 12,
                    capacity: 256,
                },
                ModelLoad {
                    name: "segmenter".into(),
                    cost_depth: 50_000,
                    cost_capacity: 2_560_000,
                    depth: 5,
                    capacity: 256,
                },
            ],
        },
    }
    .encode(V2);
    for cut in 0..f.len() {
        match read_frame(&mut Cursor::new(&f[..cut]), KIND_RESPONSE) {
            Ok(None) => assert_eq!(cut, 0),
            Ok(Some(_)) => panic!("prefix of {cut} bytes decoded"),
            Err(ProtoError::Truncated) => {}
            Err(e) => panic!("unexpected error at cut {cut}: {e}"),
        }
    }
    let (ver, body) = read_frame(&mut Cursor::new(&f), KIND_RESPONSE)
        .unwrap().unwrap();
    for cut in 0..body.len() {
        assert!(WireResponse::decode_body(ver, &body[..cut]).is_err());
    }
    // Trailing garbage after the last model is malformed.
    let mut b = body.clone();
    b.push(0x77);
    assert!(matches!(WireResponse::decode_body(ver, &b),
                     Err(ProtoError::Malformed(_))));
}

/// Fuzz the heartbeat response's count and name-length bytes: every
/// corruption is a typed error or a valid (different) value — never
/// a panic, never an over-read.
#[test]
fn heartbeat_count_and_name_len_fuzz_is_typed() {
    let f = WireResponse {
        id: 4,
        body: ResponseBody::Heartbeat {
            models: vec![ModelLoad {
                name: "cls".into(),
                cost_depth: 1,
                cost_capacity: 2,
                depth: 3,
                capacity: 4,
            }],
        },
    }
    .encode(V2);
    let (ver, body) = read_frame(&mut Cursor::new(&f), KIND_RESPONSE)
        .unwrap().unwrap();
    // Body layout: id(8) tag(1) nmodels(1) [len(1) name …].
    for bad in 0..=255u8 {
        let mut b = body.clone();
        b[9] = bad; // model count
        let _ = WireResponse::decode_body(ver, &b);
        let mut b = body.clone();
        b[10] = bad; // name length
        let _ = WireResponse::decode_body(ver, &b);
    }
    let mut rng = SplitMix64::new(0xFEED);
    for _ in 0..500 {
        let mut b = body.clone();
        let i = rng.next_below(b.len() as u64) as usize;
        b[i] = rng.next_below(256) as u8;
        let _ = WireResponse::decode_body(ver, &b);
    }
}

// --------------------------------------- v2 trace context (tracing)

fn traced_infer() -> WireRequest {
    WireRequest {
        id: 31,
        body: RequestBody::Infer {
            net: 0,
            model: "classifier".into(),
            payload: WirePayload::Pixels(vec![7; 24]),
        },
    }
}

#[test]
fn trace_context_roundtrips_v2() {
    let ctx = TraceContext {
        trace_id: [0xAB; 16],
        parent_span: 0x1234_5678_9ABC_DEF0,
    };
    let req = traced_infer();
    let f = req.encode_with_trace(Some(&ctx)).unwrap();
    let (ver, body) = read_frame(&mut Cursor::new(&f), KIND_REQUEST)
        .unwrap().unwrap();
    assert_eq!(ver, V2);
    let (dec, got) =
        WireRequest::decode_body_ext(ver, &body).unwrap();
    assert_eq!(dec, req);
    assert_eq!(got.trace, Some(ctx));
    assert_eq!(got.priority, None);
    // The strict entry point treats the extension as trailing
    // garbage — old decode paths never silently eat it.
    assert!(matches!(WireRequest::decode_body(ver, &body),
                     Err(ProtoError::Malformed(_))));
    // An extension-free frame decodes identically through both entry
    // points, and `encode()` is byte-exactly `encode_with_trace(None)`.
    let f0 = req.encode().unwrap();
    assert_eq!(f0, req.encode_with_trace(None).unwrap());
    let (_, b0) = read_frame(&mut Cursor::new(&f0), KIND_REQUEST)
        .unwrap().unwrap();
    let (d0, none) = WireRequest::decode_body_ext(V2, &b0).unwrap();
    assert_eq!(d0, req);
    assert!(none.is_empty());
}

#[test]
fn trace_context_is_infer_and_v2_only() {
    let ctx = TraceContext { trace_id: [1; 16], parent_span: 9 };
    // Not expressible on any other op: encode error, nothing on the
    // wire.
    for body in [RequestBody::Metrics, RequestBody::Shutdown,
                 RequestBody::Heartbeat, RequestBody::Trace,
                 RequestBody::Info { model: String::new() }] {
        assert!(WireRequest { id: 1, body }
                    .encode_with_trace(Some(&ctx)).is_err());
    }
    // v1 never parses extensions: the same trailing bytes after a v1
    // infer body stay malformed even through the traced entry point.
    let req = WireRequest {
        id: 5,
        body: RequestBody::Infer {
            net: 0,
            model: String::new(),
            payload: WirePayload::Pixels(vec![3; 8]),
        },
    };
    let f1 = req.encode_v1().unwrap();
    let (ver, mut body) =
        read_frame(&mut Cursor::new(&f1), KIND_REQUEST)
            .unwrap().unwrap();
    assert_eq!(ver, V1);
    body.push(EXT_TRACE);
    body.extend_from_slice(&[0u8; 16]);
    body.extend_from_slice(&0u64.to_le_bytes());
    assert!(WireRequest::decode_body_ext(V1, &body).is_err());
}

#[test]
fn every_truncation_of_a_trace_extension_is_typed() {
    let ctx = TraceContext { trace_id: [0x5A; 16], parent_span: 42 };
    let f = traced_infer().encode_with_trace(Some(&ctx)).unwrap();
    let (ver, body) = read_frame(&mut Cursor::new(&f), KIND_REQUEST)
        .unwrap().unwrap();
    // Extension layout: tag(1) trace_id(16) parent(8) = 25 trailing
    // bytes. Every cut inside it is a typed error, never a panic.
    let ext_start = body.len() - 25;
    for cut in ext_start + 1..body.len() {
        assert!(WireRequest::decode_body_ext(ver, &body[..cut])
                    .is_err(),
                "cut at {cut} decoded");
    }
    // An unknown extension tag is malformed (forward-compat stays
    // explicit, not silent).
    let mut b = body.clone();
    b[ext_start] = 0xEE;
    assert!(matches!(WireRequest::decode_body_ext(ver, &b),
                     Err(ProtoError::Malformed(_))));
    // Fuzz the extension bytes: typed errors or different values only.
    let mut rng = SplitMix64::new(0x7E57);
    for _ in 0..300 {
        let mut b = body.clone();
        let i = ext_start
            + rng.next_below((b.len() - ext_start) as u64) as usize;
        b[i] = rng.next_below(256) as u8;
        let _ = WireRequest::decode_body_ext(ver, &b);
    }
}

#[test]
fn trace_dump_op_roundtrips_v2_only() {
    rt_req(&WireRequest { id: 6, body: RequestBody::Trace });
    rt_resp(&WireResponse {
        id: 6,
        body: ResponseBody::Trace {
            json: "{\"traceEvents\":[]}".into(),
        },
    });
    // Not expressible in v1.
    assert!(WireRequest { id: 6, body: RequestBody::Trace }
                .encode_v1().is_err());
}

#[test]
fn pipelined_mixed_version_frames_parse_in_sequence() {
    // Several frames back to back on one stream — alternating protocol
    // versions, as when a proxy funnels old and new clients into one
    // buffer — the reader must consume exactly one frame per call and
    // report each frame's own version.
    let reqs: Vec<(u8, WireRequest)> = (0..10u64)
        .map(|i| {
            let req = WireRequest {
                id: i,
                body: RequestBody::Infer {
                    net: 0,
                    model: String::new(),
                    payload: WirePayload::Pixels(vec![i as u8;
                                                      i as usize]),
                },
            };
            ((if i % 2 == 0 { V1 } else { V2 }), req)
        })
        .collect();
    let mut stream = Vec::new();
    for (ver, r) in &reqs {
        let f = if *ver == V1 {
            r.encode_v1().unwrap()
        } else {
            r.encode().unwrap()
        };
        stream.extend_from_slice(&f);
    }
    let mut cur = Cursor::new(&stream);
    for (want_ver, want) in &reqs {
        let (ver, body) =
            read_frame(&mut cur, KIND_REQUEST).unwrap().unwrap();
        assert_eq!(ver, *want_ver);
        assert_eq!(&WireRequest::decode_body(ver, &body).unwrap(),
                   want);
    }
    assert!(matches!(read_frame(&mut cur, KIND_REQUEST), Ok(None)));
}
