//! Property-based tests over randomized inputs (in-crate generator on
//! SplitMix64 — the build is offline, so no proptest crate; same
//! shrink-free randomized-invariant methodology, 256 cases per property;
//! the full-frame temporal-kernel parity property runs 48 heavier cases).

use skydiver::coordinator::{BoundedQueue, LatencyHistogram, Priority,
                            WFQ_WEIGHTS};
use skydiver::data::SplitMix64;
use skydiver::schedule::baselines::{Contiguous, Oracle, Random,
                                    RoundRobin, SparTen};
use skydiver::schedule::cbws::cbws_assign;
use skydiver::schedule::{Partition, Scheduler};
use skydiver::sim::{layer_timing, ArchConfig};
use skydiver::snn::{transpose_dense, ConvGeom, DenseGeom,
                    FunctionalNet, LayerWeights, NetworkWeights,
                    SpikeMap, TemporalSpikeMap, WeightsMeta};

const CASES: usize = 256;

fn rand_workload(rng: &mut SplitMix64, k: usize, scale: u64) -> Vec<f64> {
    (0..k).map(|_| (rng.next_below(scale) as f64)
        * if rng.next_below(4) == 0 { 10.0 } else { 1.0 })
        .collect()
}

// ---------------- CBWS / Partition invariants ----------------

#[test]
fn prop_cbws_partitions_exactly() {
    let mut rng = SplitMix64::new(0xC85);
    for _ in 0..CASES {
        let k = 1 + rng.next_below(64) as usize;
        let n = 1 + rng.next_below(16) as usize;
        let w = rand_workload(&mut rng, k, 1000);
        let iters = rng.next_below(100) as usize;
        let p = cbws_assign(&w, n, iters);
        assert!(p.validate(k), "k={k} n={n} iters={iters}");
        assert_eq!(p.groups.len(), n);
    }
}

#[test]
fn prop_cbws_at_least_as_good_as_contiguous_on_predictions() {
    // On the *predicted* workload itself, CBWS must never lose to the
    // contiguous baseline (it optimises exactly this quantity).
    let mut rng = SplitMix64::new(0xC85 + 1);
    for _ in 0..CASES {
        let k = 2 + rng.next_below(48) as usize;
        let n = 1 + rng.next_below(12) as usize;
        let w = rand_workload(&mut rng, k, 500);
        let cbws = cbws_assign(&w, n, 64).balance_ratio(&w);
        let cont = Contiguous.assign(&w, n).balance_ratio(&w);
        assert!(cbws >= cont - 1e-9,
                "cbws {cbws} < contiguous {cont} (k={k}, n={n}, w={w:?})");
    }
}

#[test]
fn prop_oracle_within_lpt_bound_of_all() {
    // Oracle is greedy longest-processing-time, a 4/3-approximation of
    // the optimal makespan — so any scheduler may beat it by at most
    // that factor on the balance ratio.
    let mut rng = SplitMix64::new(0xC85 + 2);
    let zoo: Vec<Box<dyn Scheduler>> = vec![
        Box::new(Contiguous), Box::new(RoundRobin),
        Box::new(Random { seed: 7 }), Box::new(SparTen),
    ];
    for _ in 0..CASES {
        let k = 2 + rng.next_below(32) as usize;
        let n = 1 + rng.next_below(8) as usize;
        let w = rand_workload(&mut rng, k, 300);
        let oracle = Oracle.assign(&w, n).balance_ratio(&w);
        for s in &zoo {
            let b = s.assign(&w, n).balance_ratio(&w);
            assert!(oracle >= b * 0.75 - 1e-9,
                    "{} {b} beats oracle {oracle} beyond the LPT bound",
                    s.name());
        }
        let cbws = cbws_assign(&w, n, 64).balance_ratio(&w);
        assert!(oracle >= cbws * 0.75 - 1e-9,
                "cbws {cbws} beats oracle {oracle} beyond the LPT bound");
    }
}

#[test]
fn prop_balance_ratio_in_unit_interval() {
    let mut rng = SplitMix64::new(0xC85 + 3);
    for _ in 0..CASES {
        let k = 1 + rng.next_below(40) as usize;
        let n = 1 + rng.next_below(10) as usize;
        let w = rand_workload(&mut rng, k, 100);
        for p in [cbws_assign(&w, n, 16),
                  Contiguous.assign(&w, n),
                  RoundRobin.assign(&w, n)] {
            let b = p.balance_ratio(&w);
            assert!((0.0..=1.0 + 1e-12).contains(&b), "ratio {b}");
        }
    }
}

// ---------------- cost-balanced batch assembly ----------------

#[test]
fn prop_cost_batches_never_exceed_twice_ideal_max_bin() {
    // Greedy LPT batch assembly (`pop_batch_cost`) hands each pull at
    // most `max(costliest_item, queued_cost / consumers)` of predicted
    // cost — within 2x the ideal max-bin cost
    // `max(max_item, total / consumers)`, the classic greedy bound.
    // Drained single-threaded so every batch is observable.
    let mut rng = SplitMix64::new(0xBA7C);
    for _ in 0..CASES {
        let n = 1 + rng.next_below(64) as usize;
        let k = 1 + rng.next_below(8) as usize;
        let costs: Vec<u64> = (0..n)
            .map(|_| {
                // Heavy-tailed: occasional 50x items, like the skewed
                // traffic mode.
                let c = 1 + rng.next_below(100);
                if rng.next_below(8) == 0 { c * 50 } else { c }
            })
            .collect();
        let q: BoundedQueue<usize> = BoundedQueue::new(n);
        q.add_consumers(k);
        for (i, &c) in costs.iter().enumerate() {
            q.try_push_cost(i, c).unwrap();
        }
        let total: u64 = costs.iter().sum();
        let max_item = *costs.iter().max().unwrap();
        let ideal = (total as f64 / k as f64).max(max_item as f64);
        let mut seen = 0usize;
        while q.stats().depth > 0 {
            let batch = q
                .pop_batch_cost(n, std::time::Duration::ZERO)
                .expect("queue is non-empty");
            assert!(!batch.is_empty());
            let batch_cost: u64 =
                batch.iter().map(|&i| costs[i]).sum();
            assert!(batch_cost as f64 <= 2.0 * ideal + 1e-9,
                    "batch cost {batch_cost} > 2x ideal {ideal} \
                     (n={n}, k={k})");
            seen += batch.len();
        }
        assert_eq!(seen, n, "every item must be handed out exactly once");
        assert_eq!(q.stats().cost_popped, total);
    }
}

// ---------------- WFQ priority-lane invariants ----------------

#[test]
fn prop_wfq_starvation_bound_and_lane_fifo() {
    // The bounded-starvation guarantee the priority tier rests on:
    // while a class stays backlogged, the number of *other* pulls
    // between two of its consecutive services never exceeds one full
    // WRR round minus its own share (`sum(WFQ_WEIGHTS) -
    // WFQ_WEIGHTS[k]`), whatever mix floods the other lanes. Each lane
    // stays FIFO within itself, and any aligned full round in which
    // every lane holds at least its share is split *exactly* by
    // weight.
    let total: u64 = WFQ_WEIGHTS.iter().sum();
    let mut rng = SplitMix64::new(0x3FA1);
    for _ in 0..CASES {
        let per: Vec<usize> = (0..3)
            .map(|_| 1 + rng.next_below(40) as usize)
            .collect();
        let n: usize = per.iter().sum();
        let q: BoundedQueue<(usize, usize)> = BoundedQueue::new(n);
        q.add_consumers(1);
        // Random arrival interleaving of the three classes.
        let mut remaining = per.clone();
        let mut seq = [0usize; 3];
        while remaining.iter().any(|&r| r > 0) {
            let k = loop {
                let k = rng.next_below(3) as usize;
                if remaining[k] > 0 {
                    break k;
                }
            };
            q.try_push_cost_pri((k, seq[k]), 1,
                                Priority::from_u8(k as u8).unwrap())
                .unwrap();
            seq[k] += 1;
            remaining[k] -= 1;
        }
        // Drain one pull at a time, recording the service order.
        let mut order: Vec<(usize, usize)> = Vec::with_capacity(n);
        while q.stats().depth > 0 {
            let b = q.pop_batch(1).expect("queue is non-empty");
            assert_eq!(b.len(), 1);
            order.push(b[0]);
        }
        assert_eq!(order.len(), n);
        // Per-lane FIFO.
        let mut next = [0usize; 3];
        for &(k, s) in &order {
            assert_eq!(s, next[k], "lane {k} served out of order");
            next[k] += 1;
        }
        // Starvation bound: while lane k still has items queued, the
        // gap to its next service is at most one round of everyone
        // else's credit.
        for k in 0..3 {
            let bound = (total - WFQ_WEIGHTS[k]) as usize;
            let positions: Vec<usize> = order
                .iter()
                .enumerate()
                .filter(|(_, &(c, _))| c == k)
                .map(|(i, _)| i)
                .collect();
            assert_eq!(positions.len(), per[k]);
            assert!(positions[0] <= bound,
                    "class {k} first served at {} > {bound} \
                     (per={per:?})", positions[0]);
            for w in positions.windows(2) {
                let gap = w[1] - w[0] - 1;
                assert!(gap <= bound,
                        "class {k} starved for {gap} pulls (> {bound}) \
                         between {} and {} (per={per:?})", w[0], w[1]);
            }
        }
        // Aligned full rounds split exactly by weight while every
        // lane holds at least its share at the round boundary.
        let mut left = per.clone();
        for round in order.chunks(total as usize) {
            let precondition = (0..3)
                .all(|k| left[k] >= WFQ_WEIGHTS[k] as usize);
            if !precondition || round.len() < total as usize {
                break;
            }
            for k in 0..3 {
                let got =
                    round.iter().filter(|&&(c, _)| c == k).count();
                assert_eq!(got, WFQ_WEIGHTS[k] as usize,
                           "round served {got} of class {k} \
                            (per={per:?})");
            }
            for &(k, _) in round {
                left[k] -= 1;
            }
        }
    }
}

// ---------------- windowed-percentile invariants ----------------

#[test]
fn prop_windowed_percentile_tracks_window_not_history() {
    // `percentile_since` must reflect only the samples recorded after
    // the baseline snapshot — however much differently-shaped history
    // preceded it — to bucketed resolution (≤ ~6.25% relative error).
    // This is the read the autoscaler's p99-SLO trigger is built on.
    let mut rng = SplitMix64::new(0x99A7);
    for _ in 0..CASES {
        let mut h = LatencyHistogram::default();
        // History skewed far below the window's value range.
        for _ in 0..rng.next_below(2000) {
            h.record(1 + rng.next_below(100));
        }
        let base = h.clone();
        let win_n = 1 + rng.next_below(600) as usize;
        let mut window = Vec::with_capacity(win_n);
        for _ in 0..win_n {
            let v = 10_000 + rng.next_below(1_000_000);
            window.push(v);
            h.record(v);
        }
        window.sort_unstable();
        for p in [0.0, 50.0, 99.0, 100.0] {
            let got = h.percentile_since(&base, p) as f64;
            let exact =
                skydiver::metrics::percentile(&window, p) as f64;
            assert!((got - exact).abs() <= exact * 0.0665 + 1.0,
                    "p{p}: window-exact {exact} vs diffed {got} \
                     (n={win_n})");
        }
        // A later snapshot is not a valid baseline, and an empty
        // window reports 0, not stale history.
        assert_eq!(base.percentile_since(&h, 99.0), 0);
        assert_eq!(h.percentile_since(&h.clone(), 99.0), 0);
    }
}

#[test]
fn windowed_percentile_concurrent_with_recording() {
    // The live autoscale read pattern: a control thread snapshots the
    // histogram under the stats lock and diffs consecutive windows
    // while worker threads keep recording through the same lock.
    // Every windowed read must be internally consistent — zero
    // exactly for empty windows, otherwise inside the recorded value
    // range — with no panics across thousands of interleavings.
    use std::sync::atomic::{AtomicBool, Ordering};
    use std::sync::{Arc, Mutex};
    let h = Arc::new(Mutex::new(LatencyHistogram::default()));
    let stop = Arc::new(AtomicBool::new(false));
    let writers: Vec<_> = (0..3)
        .map(|w| {
            let h = Arc::clone(&h);
            let stop = Arc::clone(&stop);
            std::thread::spawn(move || {
                let mut rng = SplitMix64::new(0xBEEF ^ w as u64);
                let mut n = 0u64;
                while !stop.load(Ordering::Relaxed) {
                    h.lock().unwrap()
                        .record(50 + rng.next_below(10_000));
                    n += 1;
                    if n % 64 == 0 {
                        std::thread::yield_now();
                    }
                }
                n
            })
        })
        .collect();
    let mut base = h.lock().unwrap().clone();
    let mut nonempty_windows = 0u32;
    for _ in 0..200 {
        std::thread::sleep(std::time::Duration::from_millis(1));
        let snap = h.lock().unwrap().clone();
        let p99 = snap.percentile_since(&base, 99.0);
        if snap.count() == base.count() {
            assert_eq!(p99, 0, "empty window reported {p99}");
        } else {
            nonempty_windows += 1;
            assert!(p99 >= 50 && p99 <= snap.max(),
                    "window p99 {p99} outside [50, {}]", snap.max());
        }
        base = snap;
    }
    stop.store(true, Ordering::Relaxed);
    let recorded: u64 =
        writers.into_iter().map(|w| w.join().unwrap()).sum();
    assert!(recorded > 0);
    assert!(nonempty_windows > 0, "no window ever saw traffic");
}

// ---------------- SpikeMap invariants ----------------

#[test]
fn prop_spikemap_roundtrip_and_counts() {
    let mut rng = SplitMix64::new(0x5B1);
    for _ in 0..CASES {
        let c = 1 + rng.next_below(8) as usize;
        let h = 1 + rng.next_below(20) as usize;
        let w = 1 + rng.next_below(20) as usize;
        let mut dense = vec![0.0f32; c * h * w];
        let spikes = rng.next_below((c * h * w) as u64 + 1) as usize;
        for _ in 0..spikes {
            let i = rng.next_below((c * h * w) as u64) as usize;
            dense[i] = 1.0;
        }
        let m = SpikeMap::from_f32(c, h, w, &dense);
        // Roundtrip.
        assert_eq!(m.to_f32(), dense);
        // Counts agree in three independent ways.
        let by_channel: usize = m.nnz_per_channel().iter().sum();
        let by_events = m.iter_events().count();
        let by_dense = dense.iter().filter(|&&v| v >= 0.5).count();
        assert_eq!(m.nnz(), by_channel);
        assert_eq!(m.nnz(), by_events);
        assert_eq!(m.nnz(), by_dense);
    }
}

// ---------------- TemporalSpikeMap invariants ----------------

/// T values the time-major layout must handle: single step, one bit
/// short of a word, exactly one word, one bit over (straddle), two
/// words — plus a random length per case.
const T_EDGES: [usize; 5] = [1, 63, 64, 65, 128];

#[test]
fn prop_temporal_map_roundtrips_per_step_maps() {
    // Pack -> unpack must be bit-identical to the per-timestep maps,
    // and `from_packed_steps` must mask stray spatial straddle bits
    // (possible in client-packed wire payloads) exactly like the
    // per-timestep decode path does.
    let mut rng = SplitMix64::new(0x7E40);
    for case in 0..CASES {
        let c = 1 + rng.next_below(4) as usize;
        let h = 1 + rng.next_below(12) as usize;
        let w = 1 + rng.next_below(12) as usize;
        let t = if case % 2 == 0 {
            T_EDGES[(case / 2) % T_EDGES.len()]
        } else {
            1 + rng.next_below(130) as usize
        };
        let wpc = (h * w).div_ceil(64);
        let rem = (h * w) % 64;
        let mut words = vec![0u64; t * c * wpc];
        for step in 0..t {
            for ch in 0..c {
                for i in 0..h * w {
                    if rng.next_below(100) < 35 {
                        words[step * c * wpc + ch * wpc + i / 64] |=
                            1u64 << (i % 64);
                    }
                }
            }
        }
        // Garbage in every spatial straddle bit: the decoder must
        // drop it, not count or propagate it.
        if rem != 0 {
            for step in 0..t {
                for ch in 0..c {
                    words[step * c * wpc + ch * wpc + wpc - 1] |=
                        !((1u64 << rem) - 1);
                }
            }
        }
        let tm = TemporalSpikeMap::from_packed_steps(c, h, w, t,
                                                     &words);
        let steps: Vec<SpikeMap> = (0..t)
            .map(|s| {
                let mut chunk =
                    words[s * c * wpc..(s + 1) * c * wpc].to_vec();
                if rem != 0 {
                    for ch in 0..c {
                        chunk[ch * wpc + wpc - 1] &=
                            (1u64 << rem) - 1;
                    }
                }
                SpikeMap::from_words(c, h, w, chunk)
            })
            .collect();
        assert_eq!(tm, TemporalSpikeMap::from_steps(&steps),
                   "wire decode != per-step pack (c={c} h={h} w={w} \
                    t={t})");
        assert_eq!(tm.to_steps(), steps,
                   "unpack not bit-identical (c={c} h={h} w={w} \
                    t={t})");
        let per_step: usize = steps.iter().map(|s| s.nnz()).sum();
        assert_eq!(tm.nnz(), per_step);
    }
}

/// Small random conv(+dense) net with deterministic pseudo-random
/// weights, built the way the bench's synthetic nets are.
fn rand_net(rng: &mut SplitMix64) -> NetworkWeights {
    let c0 = 1 + rng.next_below(3) as usize;
    let h0 = 4 + rng.next_below(6) as usize;
    let w0 = 4 + rng.next_below(6) as usize;
    let nconv = 1 + rng.next_below(2) as usize;
    let pad = if rng.next_below(2) == 0 { 1 } else { 2 };
    let mut layers = Vec::new();
    let mut feat = Vec::new();
    let (mut c, mut h, mut w) = (c0, h0, w0);
    for _ in 0..nconv {
        let cout = 1 + rng.next_below(6) as usize;
        let eh = h + 2 * pad - 3 + 1;
        let ew = w + 2 * pad - 3 + 1;
        let wts: Vec<f32> = (0..cout * c * 9)
            .map(|_| rng.next_below(1000) as f32 / 1000.0 * 0.6 - 0.25)
            .collect();
        layers.push(LayerWeights::Conv {
            geom: ConvGeom { cin: c, cout, r: 3, pad, h, w, eh, ew },
            w: wts,
        });
        feat.push(format!("[{cout}, {eh}, {ew}]"));
        c = cout;
        h = eh;
        w = ew;
    }
    let dense_out = if rng.next_below(2) == 0 {
        let fin = c * h * w;
        let fout = 2 + rng.next_below(6) as usize;
        let dw: Vec<f32> = (0..fout * fin)
            .map(|_| rng.next_below(1000) as f32 / 1000.0 * 0.4 - 0.15)
            .collect();
        let wt = transpose_dense(&dw, fout, fin);
        let b: Vec<f32> = (0..fout)
            .map(|_| rng.next_below(1000) as f32 / 1000.0 * 0.05)
            .collect();
        layers.push(LayerWeights::Dense {
            geom: DenseGeom { fin, fout, src_channels: c },
            w: dw, wt, b,
        });
        format!("{fout}")
    } else {
        "null".into()
    };
    let meta = WeightsMeta::parse(&format!(r#"{{
        "name": "prop", "aprc": true, "pad": {pad}, "vth": 0.4,
        "timesteps": 8, "in_shape": [{c0}, {h0}, {w0}],
        "feature_sizes": [{}], "dense_out": {dense_out},
        "total_floats": 0, "lambdas": [],
        "layers": [], "blob_fnv1a64": "0"
    }}"#, feat.join(", "))).expect("prop meta");
    NetworkWeights { meta, layers }
}

#[test]
fn prop_temporal_kernels_match_per_timestep_oracle() {
    // The parity claim the whole temporal path rests on: random nets
    // (conv chains, optional dense head), both paddings, T straddling
    // every word boundary — every layer's output spikes bit-identical
    // to the per-timestep oracle at every timestep, and the
    // accumulated predictions identical. Fewer cases than the cheap
    // properties: each case runs two full frames.
    let mut rng = SplitMix64::new(0x7E41);
    let t_choices = [1usize, 5, 63, 64, 65, 128];
    for case in 0..48 {
        let net = rand_net(&mut rng);
        let t = t_choices[case % t_choices.len()];
        let (c, h, w) = net.layer_input_shape(0);
        let steps: Vec<SpikeMap> = (0..t)
            .map(|_| {
                let mut m = SpikeMap::zeros(c, h, w);
                for ch in 0..c {
                    for i in 0..h * w {
                        if rng.next_below(100) < 30 {
                            m.set(ch, i);
                        }
                    }
                }
                m
            })
            .collect();
        let packed = TemporalSpikeMap::from_steps(&steps);
        let mut oracle = FunctionalNet::new(&net);
        let mut temporal = FunctionalNet::new(&net);
        assert_eq!(temporal.run_frame_counts_temporal(&packed),
                   oracle.run_frame_counts(&steps),
                   "predictions diverged (case {case}, t={t})");
        let touts: Vec<Vec<SpikeMap>> = temporal
            .run_frame_temporal(&packed)
            .iter()
            .map(|m| m.to_steps())
            .collect();
        oracle.reset();
        for (tt, s) in steps.iter().enumerate() {
            let louts = oracle.step_reuse(s);
            for (li, lm) in louts.iter().enumerate() {
                assert_eq!(&touts[li][tt], lm,
                           "layer {li} spikes diverged at t={tt} \
                            (case {case}, t={t})");
            }
        }
    }
}

// ---------------- Timing-model invariants ----------------

fn rand_conv(rng: &mut SplitMix64) -> LayerWeights {
    let cin = 1 + rng.next_below(16) as usize;
    let cout = 1 + rng.next_below(32) as usize;
    let h = 4 + rng.next_below(24) as usize;
    let w = 4 + rng.next_below(24) as usize;
    let r = 3;
    let pad = if rng.next_below(2) == 0 { 1 } else { 2 };
    LayerWeights::Conv {
        geom: ConvGeom { cin, cout, r, pad, h, w,
                         eh: h + 2 * pad - r + 1,
                         ew: w + 2 * pad - r + 1 },
        w: vec![],
    }
}

#[test]
fn prop_timing_monotone_in_workload() {
    // Adding spikes can never reduce cycles or ops.
    let mut rng = SplitMix64::new(0x71E);
    let arch = ArchConfig::default();
    for _ in 0..CASES {
        let layer = rand_conv(&mut rng);
        let cin = match &layer {
            LayerWeights::Conv { geom, .. } => geom.cin,
            _ => unreachable!(),
        };
        let nnz: Vec<usize> = (0..cin)
            .map(|_| rng.next_below(50) as usize).collect();
        let mut more = nnz.clone();
        let idx = rng.next_below(cin as u64) as usize;
        more[idx] += 1 + rng.next_below(20) as usize;
        let p = RoundRobin.assign(&vec![1.0; cin], 8);
        let t1 = layer_timing(&arch, &layer, &p, &nnz);
        let t2 = layer_timing(&arch, &layer, &p, &more);
        assert!(t2.cycles >= t1.cycles);
        assert!(t2.synops > t1.synops);
    }
}

#[test]
fn prop_timing_balance_matches_partition_ratio() {
    // The timing model's balance must equal Partition::balance_ratio on
    // the same workload.
    let mut rng = SplitMix64::new(0x71E + 1);
    let arch = ArchConfig::default();
    for _ in 0..CASES {
        let layer = rand_conv(&mut rng);
        let cin = match &layer {
            LayerWeights::Conv { geom, .. } => geom.cin,
            _ => unreachable!(),
        };
        let nnz: Vec<usize> = (0..cin)
            .map(|_| rng.next_below(40) as usize).collect();
        let wl: Vec<f64> = nnz.iter().map(|&x| x as f64).collect();
        let p: Partition = SparTen.assign(&wl, 4);
        let t = layer_timing(&arch, &layer, &p, &nnz);
        let expect = p.balance_ratio(&wl);
        assert!((t.balance - expect).abs() < 1e-9,
                "timing {} vs partition {}", t.balance, expect);
    }
}

#[test]
fn prop_better_balance_never_slower() {
    // For the same total workload and geometry, a partition with higher
    // balance ratio must not take more compute cycles.
    let mut rng = SplitMix64::new(0x71E + 2);
    let arch = ArchConfig::default();
    for _ in 0..CASES {
        let layer = rand_conv(&mut rng);
        let cin = match &layer {
            LayerWeights::Conv { geom, .. } => geom.cin,
            _ => unreachable!(),
        };
        let nnz: Vec<usize> = (0..cin)
            .map(|_| rng.next_below(60) as usize).collect();
        let wl: Vec<f64> = nnz.iter().map(|&x| x as f64).collect();
        let a = Oracle.assign(&wl, 8);
        let b = Contiguous.assign(&wl, 8);
        let ta = layer_timing(&arch, &layer, &a, &nnz);
        let tb = layer_timing(&arch, &layer, &b, &nnz);
        if ta.balance >= tb.balance {
            assert!(ta.cycles <= tb.cycles,
                    "higher balance but more cycles");
        }
    }
}

// ---------------- cluster placement / health invariants ----------------

use skydiver::cluster::{pick_backend, BackendView, HealthPolicy,
                        HealthState, Transition};

fn rand_views(rng: &mut SplitMix64, models: &[&str])
              -> Vec<BackendView> {
    let n = 1 + rng.next_below(8) as usize;
    (0..n)
        .map(|_| {
            let mounted: Vec<(String, u64)> = models
                .iter()
                .filter(|_| rng.next_below(3) > 0)
                .map(|m| (m.to_string(), rng.next_below(1_000_000)))
                .collect();
            BackendView {
                live: rng.next_below(4) > 0,
                models: mounted,
                inflight_cost: rng.next_below(1_000_000),
            }
        })
        .collect()
}

#[test]
fn prop_placement_never_selects_ejected_or_nonmounting() {
    // The router invariant the chaos test leans on: whatever the
    // load snapshot looks like, an ejected backend or one that does
    // not mount the model is never chosen, the pick minimises
    // cost_depth + inflight_cost, and None is returned exactly when
    // no live backend mounts the model.
    let mut rng = SplitMix64::new(0xC1A5);
    for _ in 0..CASES {
        let views = rand_views(&mut rng, &["cls", "seg"]);
        for model in ["cls", "seg", "", "ghost"] {
            let candidates: Vec<usize> = views
                .iter()
                .enumerate()
                .filter(|(_, v)| v.live && v.mounts(model))
                .map(|(i, _)| i)
                .collect();
            match pick_backend(&views, model) {
                Some(i) => {
                    let v = &views[i];
                    assert!(v.live, "picked an ejected backend");
                    assert!(v.mounts(model),
                            "picked a backend not mounting '{model}'");
                    let key = |j: &usize| {
                        let u = &views[*j];
                        u.cost_for(model)
                            .unwrap()
                            .saturating_add(u.inflight_cost)
                    };
                    let best = candidates.iter().map(key).min()
                        .expect("a pick implies a candidate");
                    assert_eq!(key(&i), best,
                               "pick is not minimal-cost");
                }
                None => assert!(
                    candidates.is_empty(),
                    "returned None with live candidates for \
                     '{model}'"),
            }
        }
    }
}

#[test]
fn prop_health_automaton_invariants() {
    // Random observation sequences: the automaton must (a) only
    // eject on the configured consecutive-failure count, (b) only
    // readmit on the configured consecutive-success count, and
    // (c) emit transitions exactly when `live()` flips.
    let mut rng = SplitMix64::new(0x4EA1);
    for _ in 0..CASES {
        let policy = HealthPolicy {
            heartbeat_every: std::time::Duration::from_millis(10),
            eject_after: 1 + rng.next_below(5) as u32,
            readmit_after: 1 + rng.next_below(5) as u32,
        };
        let mut h = HealthState::new();
        let mut fail_streak = 0u32;
        let mut ok_streak = 0u32;
        for _ in 0..200 {
            let was_live = h.live();
            let tr = if rng.next_below(2) == 0 {
                fail_streak += 1;
                ok_streak = 0;
                h.on_failure(&policy)
            } else {
                ok_streak += 1;
                fail_streak = 0;
                h.on_success(&policy)
            };
            match tr {
                Some(Transition::Ejected) => {
                    assert!(was_live && !h.live());
                    assert!(fail_streak >= policy.eject_after);
                }
                Some(Transition::Readmitted) => {
                    assert!(!was_live && h.live());
                    assert!(ok_streak >= policy.readmit_after);
                }
                None => assert_eq!(was_live, h.live(),
                                   "liveness flipped silently"),
            }
            // A live backend is always short of the ejection
            // threshold — hitting it would have ejected it.
            if h.live() {
                assert!(h.strikes() < policy.eject_after);
            }
        }
    }
}
