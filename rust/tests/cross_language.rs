//! Cross-language determinism: the rust ports of the dataset generators
//! and the phased encoder must match the python originals byte-for-byte.
//! The python side records FNV-1a hashes in `artifacts/meta.json` at
//! `make artifacts` time; we regenerate and compare.

use std::path::PathBuf;

use skydiver::data::{fnv1a64, gen_digits, gen_road_scenes};
use skydiver::snn::encode_phased_u8;
use skydiver::util::Json;

fn artifacts() -> PathBuf {
    skydiver::artifacts_dir()
}

fn meta() -> Option<Json> {
    let text = std::fs::read_to_string(artifacts().join("meta.json")).ok()?;
    Some(Json::parse(&text).expect("meta.json parses"))
}

#[test]
fn digits_hash_matches_python() {
    let Some(meta) = meta() else {
        panic!("meta.json missing — run `make artifacts`");
    };
    let d = meta.field("datasets").unwrap().field("digits").unwrap();
    let seed = d.field("test_seed").unwrap().as_usize().unwrap() as u64;
    let expect = d.field("test_hash16").unwrap().as_str().unwrap();
    let (imgs, labels) = gen_digits(seed, 16);
    let mut blob = imgs.clone();
    blob.extend_from_slice(&labels);
    assert_eq!(format!("{:016x}", fnv1a64(&blob)), expect,
               "digit generator diverged from python");
}

#[test]
fn roads_hash_matches_python() {
    let Some(meta) = meta() else {
        panic!("meta.json missing — run `make artifacts`");
    };
    let d = meta.field("datasets").unwrap().field("roads").unwrap();
    let seed = d.field("test_seed").unwrap().as_usize().unwrap() as u64;
    let expect = d.field("test_hash2").unwrap().as_str().unwrap();
    let (imgs, masks) = gen_road_scenes(seed, 2);
    let mut blob = imgs.clone();
    blob.extend_from_slice(&masks);
    assert_eq!(format!("{:016x}", fnv1a64(&blob)), expect,
               "road generator diverged from python");
}

#[test]
fn encoder_matches_python() {
    let Some(meta) = meta() else {
        panic!("meta.json missing — run `make artifacts`");
    };
    let e = meta.field("encoding_crosscheck").unwrap();
    let seed = e.field("image_seed").unwrap().as_usize().unwrap() as u64;
    let t = e.field("timesteps").unwrap().as_usize().unwrap();
    let expect_count = e.field("spike_count").unwrap().as_usize().unwrap();
    let expect_hash = e.field("fnv1a64").unwrap().as_str().unwrap();

    let (imgs, _) = gen_digits(seed, 1);
    let maps = encode_phased_u8(&imgs[..28 * 28], 1, 28, 28, t);
    // Python hashed the (T, 1, 28, 28) u8 spike tensor.
    let mut blob = Vec::with_capacity(t * 28 * 28);
    let mut count = 0usize;
    for m in &maps {
        for i in 0..28 * 28 {
            let s = m.get(0, i) as u8;
            count += s as usize;
            blob.push(s);
        }
    }
    assert_eq!(count, expect_count, "total spike count diverged");
    assert_eq!(format!("{:016x}", fnv1a64(&blob)), expect_hash,
               "phased encoder diverged from python");
}

#[test]
fn weights_blob_hashes_verify() {
    // NetworkWeights::load verifies the fnv hash internally; loading all
    // four variants is the cross-check.
    let dir = artifacts();
    for name in ["classifier_aprc", "classifier_plain", "segmenter_aprc",
                 "segmenter_plain"] {
        let net = skydiver::snn::NetworkWeights::load(&dir, name)
            .unwrap_or_else(|e| panic!("{name}: {e}"));
        assert!(net.num_layers() >= 3);
    }
}
