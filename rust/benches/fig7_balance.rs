//! Fig. 7 regeneration as a benchmark: measures how fast the simulator
//! reproduces the balance-ratio experiment (all four configurations on a
//! segmentation frame) and prints the resulting ratios — the bench
//! doubles as the figure's data source.

#[path = "harness.rs"]
mod harness;

use harness::bench;
use skydiver::experiments::{fig7, ExperimentCtx};

fn main() {
    let mut ctx = ExperimentCtx::new(skydiver::artifacts_dir());
    ctx.frames = 1;
    let it = if harness::quick() { 1 } else { 3 };
    let mut last = None;
    let r = bench("fig7 (4 configs x 2 nets, 1 frame)", 0, it, || {
        last = Some(fig7::run(&ctx).expect("artifacts built"));
    });
    if let Some(res) = last {
        println!("\nseg averages: {:?}",
                 res.segmenter.iter()
                     .map(|c| format!("{}={:.1}%", c.label,
                                      100.0 * c.average_balance))
                     .collect::<Vec<_>>());
    }
    harness::write_json(&[r]);
}
