//! Tiny benchmark harness (the build is offline — no criterion).
//! Measures wall time over warmup + timed iterations and prints
//! mean / p50 / p95 per iteration plus derived throughput, and counts
//! heap allocations per iteration through a counting global allocator.
//!
//! Besides the human-readable stdout lines, results are merged into a
//! machine-readable `BENCH_sim.json` (schema documented in PERF.md;
//! path overridable via `BENCH_SIM_JSON`) so every PR's numbers are
//! comparable to the last.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::{Duration, Instant};

use skydiver::util::Json;

/// Counting wrapper around the system allocator: lets benches report
/// allocations-per-iteration (the quantity the allocation-free hot
/// path is measured by — see PERF.md).
pub struct CountingAlloc;

static ALLOCS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, l: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(l)
    }

    unsafe fn dealloc(&self, p: *mut u8, l: Layout) {
        System.dealloc(p, l)
    }

    unsafe fn realloc(&self, p: *mut u8, l: Layout, n: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.realloc(p, l, n)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

/// Heap allocations since process start (monotonic).
#[allow(dead_code)]
pub fn alloc_count() -> u64 {
    ALLOCS.load(Ordering::Relaxed)
}

pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean: Duration,
    pub p50: Duration,
    pub p95: Duration,
    pub p99: Duration,
    /// Mean heap allocations per measured iteration.
    pub allocs_per_iter: f64,
    /// Work items (frames) completed per iteration — 1 unless the
    /// bench processes a batch per call.
    pub items_per_iter: f64,
}

impl BenchResult {
    pub fn print(&self) {
        println!("{:<44} iters={:<4} mean={:>12?} p50={:>12?} \
                  p95={:>12?} allocs/iter={:<9.1} items/s={:.1}",
                 self.name, self.iters, self.mean, self.p50, self.p95,
                 self.allocs_per_iter, self.per_sec());
    }

    /// Work items per second (frames/sec when items are frames).
    pub fn per_sec(&self) -> f64 {
        self.items_per_iter / self.mean.as_secs_f64()
    }

    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("name", Json::str(self.name.as_str())),
            ("iters", Json::num(self.iters as f64)),
            ("mean_ns", Json::num(self.mean.as_nanos() as f64)),
            ("p50_ns", Json::num(self.p50.as_nanos() as f64)),
            ("p95_ns", Json::num(self.p95.as_nanos() as f64)),
            ("p99_ns", Json::num(self.p99.as_nanos() as f64)),
            ("frames_per_sec", Json::num(self.per_sec())),
            ("allocs_per_iter", Json::num(self.allocs_per_iter)),
            // Methodology markers: a --quick (CI smoke) row is not
            // comparable to a full run, and throughput rows depend on
            // the host's core count.
            ("quick", Json::Bool(quick())),
            ("threads", Json::num(
                std::thread::available_parallelism()
                    .map(|n| n.get()).unwrap_or(1) as f64)),
        ])
    }
}

/// Run `f` for `warmup` unmeasured + `iters` measured iterations.
pub fn bench<R>(name: &str, warmup: usize, iters: usize,
                f: impl FnMut() -> R) -> BenchResult {
    bench_items(name, warmup, iters, 1.0, f)
}

/// [`bench`] for batch workloads: `items_per_iter` work items (e.g.
/// frames in a sweep) complete per call, so `per_sec` reports item
/// throughput.
pub fn bench_items<R>(name: &str, warmup: usize, iters: usize,
                      items_per_iter: f64, mut f: impl FnMut() -> R)
                      -> BenchResult {
    for _ in 0..warmup {
        std::hint::black_box(f());
    }
    let mut samples = Vec::with_capacity(iters);
    let a0 = alloc_count();
    for _ in 0..iters {
        let t0 = Instant::now();
        std::hint::black_box(f());
        samples.push(t0.elapsed());
    }
    let allocs_per_iter = (alloc_count() - a0) as f64 / iters as f64;
    samples.sort();
    let mean = samples.iter().sum::<Duration>() / iters as u32;
    let r = BenchResult {
        name: name.to_string(),
        iters,
        mean,
        p50: samples[iters / 2],
        p95: samples[(iters * 95 / 100).min(iters - 1)],
        p99: samples[(iters * 99 / 100).min(iters - 1)],
        allocs_per_iter,
        items_per_iter,
    };
    r.print();
    r
}

/// `--quick` on the command line shrinks iteration counts (CI).
pub fn quick() -> bool {
    std::env::args().any(|a| a == "--quick")
}

/// Merge `results` into the tracked benchmark file (`BENCH_sim.json`,
/// or `$BENCH_SIM_JSON`): entries are keyed by name, so re-running one
/// bench binary updates its rows and leaves the others' in place.
#[allow(dead_code)]
pub fn write_json(results: &[BenchResult]) {
    let path = std::env::var("BENCH_SIM_JSON")
        .unwrap_or_else(|_| "BENCH_sim.json".into());
    write_json_to(&path, results);
}

/// [`write_json`] targeting an explicit file — the serving bench
/// tracks its own `BENCH_serving.json` next to `BENCH_sim.json`, in
/// the same `skydiver-bench-v1` schema.
#[allow(dead_code)]
pub fn write_json_to(path: &str, results: &[BenchResult]) {
    let mut entries: Vec<Json> = std::fs::read_to_string(path).ok()
        .and_then(|t| Json::parse(&t).ok())
        .and_then(|v| v.field("results").ok().map(|r| r.clone()))
        .and_then(|r| r.as_arr().ok().map(|a| a.to_vec()))
        .unwrap_or_default();
    for r in results {
        entries.retain(|e| {
            e.get("name").and_then(|n| n.as_str().ok())
                != Some(r.name.as_str())
        });
        entries.push(r.to_json());
    }
    let n_entries = entries.len();
    let doc = Json::obj(vec![
        ("schema", Json::str("skydiver-bench-v1")),
        ("results", Json::Arr(entries)),
    ]);
    match std::fs::write(&path, doc.to_string()) {
        Ok(()) => println!("\nwrote {path} ({n_entries} result entries, \
                            {} updated)", results.len()),
        Err(e) => eprintln!("bench: could not write {path}: {e}"),
    }
}

#[allow(dead_code)]
fn main() {
    unreachable!("harness is included by the bench binaries");
}
