//! Tiny benchmark harness (the build is offline — no criterion).
//! Measures wall time over warmup + timed iterations and prints
//! mean / p50 / p95 per iteration plus derived throughput.

use std::time::{Duration, Instant};

pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean: Duration,
    pub p50: Duration,
    pub p95: Duration,
}

impl BenchResult {
    pub fn print(&self) {
        println!("{:<44} iters={:<4} mean={:>12?} p50={:>12?} p95={:>12?}",
                 self.name, self.iters, self.mean, self.p50, self.p95);
    }

    pub fn per_sec(&self) -> f64 {
        1.0 / self.mean.as_secs_f64()
    }
}

/// Run `f` for `warmup` unmeasured + `iters` measured iterations.
pub fn bench<R>(name: &str, warmup: usize, iters: usize,
                mut f: impl FnMut() -> R) -> BenchResult {
    for _ in 0..warmup {
        std::hint::black_box(f());
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        std::hint::black_box(f());
        samples.push(t0.elapsed());
    }
    samples.sort();
    let mean = samples.iter().sum::<Duration>() / iters as u32;
    let r = BenchResult {
        name: name.to_string(),
        iters,
        mean,
        p50: samples[iters / 2],
        p95: samples[(iters * 95 / 100).min(iters - 1)],
    };
    r.print();
    r
}

/// `--quick` on the command line shrinks iteration counts (CI).
pub fn quick() -> bool {
    std::env::args().any(|a| a == "--quick")
}

#[allow(dead_code)]
fn main() {
    unreachable!("harness is included by the bench binaries");
}
