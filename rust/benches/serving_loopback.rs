//! Loopback serving benchmark: a real `Gateway` on an ephemeral
//! 127.0.0.1 port, driven over actual TCP — so the tracked numbers
//! include the wire protocol, admission control, and router, not just
//! the simulator. Fully hermetic (synthetic artifacts; no
//! `make artifacts`).
//!
//! Emits thirteen rows into `BENCH_serving.json` (`skydiver-bench-v1`
//! schema, path overridable via `BENCH_SERVING_JSON` — see PERF.md):
//!
//! * `serving_loopback_rtt` — single-connection, window-1 round-trip
//!   latency (one request fully served per iteration).
//! * `serving_loopback_e2e` — 4 connections x window 8 pipelined
//!   throughput; `frames_per_sec` is the measured end-to-end FPS and
//!   mean/p50/p95/p99 are client-side per-request latencies.
//! * `serving_mixed_classifier` / `serving_mixed_segmenter` — the
//!   multi-model scenario: one registry-backed gateway mounts both
//!   synthetic nets, and two loadgen runs drive them concurrently
//!   (interleaved mixed traffic at the gateway), one row per model.
//! * `serving_skewed_fifo` / `serving_skewed_cost` — the same
//!   heavy-tailed (`--traffic skewed`) workload served under FIFO
//!   pull vs cost-aware LPT dispatch; the per-mode host/cost balance
//!   ratios are printed alongside the rows.
//! * `serving_c10k` — 4096 concurrent pipelined connections (1024 in
//!   `--quick`) multiplexed through the sharded reactor, two frames
//!   in flight per connection; the row tracks per-request latency and
//!   aggregate FPS at connection counts no thread-per-connection
//!   gateway could reach. The fd soft limit is raised in-process; if
//!   the hard limit is too low the connection count is clamped (and
//!   said so on stdout).
//! * `serving_cluster` — the fault-tolerant cluster tier: skewed
//!   pipelined traffic through a front `Router` balancing two gateway
//!   backends by heartbeat-reported queue cost, so the row prices the
//!   extra hop plus placement against a single gateway
//!   (`serving_skewed_fifo` is the closest single-backend row).
//! * `serving_pipelined` / `serving_traced` — the identical pipelined
//!   workload against one gateway, back-to-back with span tracing off
//!   then on (same seed/conns/window), so the pair prices the tracing
//!   layer end to end. The off leg also asserts the span call sites
//!   are allocation-free while tracing is disabled.
//! * `serving_temporal_off` — the `serving_loopback_e2e` workload
//!   served with `--temporal-kernels off` (per-timestep functional
//!   path). The e2e row is the temporal-on leg — serving defaults to
//!   the bit-parallel kernels — so the pair prices the time-major
//!   compute path end to end; outputs are bit-identical either way.
//! * `serving_degraded` — a deliberately starved pool (1 worker,
//!   cap-8 queue) with `--degrade reduce-t`: overload serves at
//!   reduced T instead of shedding, and the row prices that degraded
//!   serving path (the reduced-T share is printed alongside).
//! * `serving_autoscale` — an elastic pool (1 worker growing to 4)
//!   under a skewed burst with a fast control loop: the row prices
//!   serving *while* the autoscaler reacts, the post-run gauge/event
//!   counts are printed from the metrics endpoint.

#[path = "harness.rs"]
mod harness;

use std::thread;
use std::time::Duration;

use harness::{bench, BenchResult};
use skydiver::coordinator::{DispatchMode, ModelRegistry, ModelSpec,
                            Policy, ServiceConfig, WorkerConfig};
use skydiver::power::EnergyModel;
use skydiver::server::{loadgen, Client, Gateway, GatewayConfig,
                       LoadGenConfig, LoadGenReport, TrafficMode};
use skydiver::sim::ArchConfig;
use skydiver::snn::NetKind;

const SIDE: usize = 32;
const SEG_SIDE: usize = 16;

fn worker_cfg(dir: &std::path::Path, kind: NetKind) -> WorkerConfig {
    WorkerConfig {
        artifacts: dir.to_path_buf(),
        kind,
        aprc: true,
        policy: Policy::Cbws,
        arch: ArchConfig::default(),
        energy: EnergyModel::default(),
        use_runtime: false,
        timesteps: None,
        sweep_threads: 1,
        temporal: true,
    }
}

fn service_cfg() -> ServiceConfig {
    ServiceConfig {
        workers: 2,
        workers_max: 0,
        batch_max: 8,
        queue_cap: 256,
        batch_wait: Duration::from_millis(2),
        dispatch: DispatchMode::WorkQueue,
        cost_cap: None,
    }
}

/// Turn one loadgen report into a tracked bench row: latencies are
/// client-side per-request, `frames_per_sec` reproduces the measured
/// end-to-end throughput (see the e2e row note below).
fn loadgen_row(name: &str, rep: &LoadGenReport, allocs: f64)
               -> BenchResult {
    let mean = Duration::from_nanos((rep.mean_us * 1000.0) as u64)
        .max(Duration::from_nanos(1));
    BenchResult {
        name: name.into(),
        iters: rep.ok as usize,
        mean,
        p50: Duration::from_micros(rep.p50_us),
        p95: Duration::from_micros(rep.p95_us),
        p99: Duration::from_micros(rep.p99_us),
        allocs_per_iter: allocs,
        // per_sec() = items_per_iter / mean — pick items so this row's
        // frames_per_sec equals the measured end-to-end throughput
        // (mean latency alone would understate pipelined FPS).
        items_per_iter: rep.fps * mean.as_secs_f64(),
    }
}

fn main() {
    let quick = harness::quick();
    let dir = std::env::temp_dir()
        .join(format!("skydiver-servbench-{}", std::process::id()));
    skydiver::data::write_synthetic_classifier(&dir, SIDE)
        .expect("synthetic classifier artifacts");
    skydiver::data::write_synthetic_segmenter(&dir, SEG_SIDE)
        .expect("synthetic segmenter artifacts");

    let gw = Gateway::start_single(
        GatewayConfig::default(), service_cfg(),
        worker_cfg(&dir, NetKind::Classifier))
        .expect("gateway start");
    let addr = gw.local_addr().to_string();

    // 1. Single-connection round-trip latency (window = 1): protocol
    // encode + TCP + admission + simulate + route + decode.
    let mut client = Client::connect(&addr).expect("connect");
    let info = client.info().expect("info");
    let pixels: Vec<u8> = (0..info.pixels_len())
        .map(|i| (i * 37 % 256) as u8)
        .collect();
    let (warm, iters) = if quick { (5, 50) } else { (20, 400) };
    let mut id = 0u64;
    let rtt = bench("serving_loopback_rtt", warm, iters, || {
        id += 1;
        client.infer_pixels(id, "", pixels.clone()).expect("infer")
    });
    drop(client);

    // 2. Multi-connection pipelined throughput — the configuration the
    // acceptance loopback test uses (4 conns, window 8).
    let frames = if quick { 200 } else { 2000 };
    let cfg = LoadGenConfig {
        addr: addr.clone(),
        model: String::new(),
        conns: 4,
        frames,
        window: 8,
        spikes: false,
        retry_busy: true,
        traffic: TrafficMode::Mixed,
        priority: None,
        seed: 0xBE7C,
    };
    let a0 = harness::alloc_count();
    let rep = loadgen::run(&cfg).expect("loadgen");
    let allocs =
        (harness::alloc_count() - a0) as f64 / rep.ok.max(1) as f64;
    assert_eq!(rep.errors, 0, "loadgen frames failed");
    assert_eq!(rep.ok as usize, frames, "not all frames served");
    let e2e = loadgen_row("serving_loopback_e2e", &rep, allocs);
    e2e.print();
    println!("loadgen: ok={} busy={} errors={} fps={:.1}",
             rep.ok, rep.busy, rep.errors, rep.fps);

    // Graceful drain through the wire, like a real operator would.
    Client::connect(&addr).expect("connect for shutdown")
        .shutdown_server().expect("shutdown");
    let report = gw.wait().expect("gateway wait");
    println!("server: served={} busy={} p50={}us balance={:.2}",
             report.counters.served, report.counters.busy,
             report.default_model().serving.p50_us,
             report.default_model().serving.host_balance_ratio);

    // 3. Mixed multi-model traffic: one registry-backed gateway mounts
    // classifier + segmenter; two loadgen runs drive both models at
    // the same time, so the gateway interleaves genuinely different
    // workloads. One additive row per model.
    let registry = ModelRegistry::start(vec![
        ModelSpec {
            name: "classifier".into(),
            scfg: service_cfg(),
            wcfg: worker_cfg(&dir, NetKind::Classifier),
        },
        ModelSpec {
            name: "segmenter".into(),
            scfg: service_cfg(),
            wcfg: worker_cfg(&dir, NetKind::Segmenter),
        },
    ]).expect("registry start");
    let gw2 = Gateway::start(GatewayConfig::default(), registry)
        .expect("mixed gateway start");
    let addr2 = gw2.local_addr().to_string();
    let mixed_frames = if quick { 100 } else { 1000 };
    let mk_cfg = |model: &str, seed: u64| LoadGenConfig {
        addr: addr2.clone(),
        model: model.into(),
        conns: 2,
        frames: mixed_frames,
        window: 8,
        spikes: false,
        retry_busy: true,
        traffic: TrafficMode::Mixed,
        priority: None,
        seed,
    };
    let cls_cfg = mk_cfg("classifier", 0xC1A5);
    let seg_cfg = mk_cfg("segmenter", 0x5E65);
    let a1 = harness::alloc_count();
    let (cls_rep, seg_rep) = thread::scope(|s| {
        let ch = s.spawn(|| loadgen::run(&cls_cfg));
        let sh = s.spawn(|| loadgen::run(&seg_cfg));
        (ch.join().expect("classifier loadgen thread")
             .expect("classifier loadgen"),
         sh.join().expect("segmenter loadgen thread")
             .expect("segmenter loadgen"))
    });
    // One process-wide allocation figure across both concurrent runs,
    // attributed per served frame (the counter is global).
    let mixed_allocs = (harness::alloc_count() - a1) as f64
        / (cls_rep.ok + seg_rep.ok).max(1) as f64;
    assert_eq!(cls_rep.errors + seg_rep.errors, 0,
               "mixed loadgen frames failed");
    let mixed_cls =
        loadgen_row("serving_mixed_classifier", &cls_rep, mixed_allocs);
    let mixed_seg =
        loadgen_row("serving_mixed_segmenter", &seg_rep, mixed_allocs);
    mixed_cls.print();
    mixed_seg.print();
    println!("mixed: classifier fps={:.1} segmenter fps={:.1}",
             cls_rep.fps, seg_rep.fps);
    Client::connect(&addr2).expect("connect for mixed shutdown")
        .shutdown_server().expect("mixed shutdown");
    let report2 = gw2.wait().expect("mixed gateway wait");
    for m in &report2.models {
        println!("mixed model '{}': served={} busy={}",
                 m.name, m.counters.served, m.counters.busy);
    }

    // 4. Skewed-density traffic, FIFO pull vs cost-aware LPT dispatch
    // on the identical workload — the request-level APRC scenario.
    // One gateway per mode (a service's dispatch mode is fixed at
    // start), same loadgen seed, so the *only* variable is batch
    // assembly; the printed balance ratios are the paper-style
    // comparison, the rows track throughput/latency per mode.
    let skew_frames = if quick { 150 } else { 1200 };
    let run_skewed = |row: &str, dispatch: DispatchMode| {
        let scfg = ServiceConfig { dispatch, ..service_cfg() };
        let gw = Gateway::start(
            GatewayConfig::default(),
            ModelRegistry::single(
                "classifier", scfg,
                worker_cfg(&dir, NetKind::Classifier))
                .expect("skewed registry start"))
            .expect("skewed gateway start");
        let addr = gw.local_addr().to_string();
        let cfg = LoadGenConfig {
            addr: addr.clone(),
            model: String::new(),
            conns: 2,
            frames: skew_frames,
            window: 16,
            spikes: false,
            retry_busy: true,
            traffic: TrafficMode::Skewed,
            priority: None,
            seed: 0x5EED,
        };
        let a = harness::alloc_count();
        let rep = loadgen::run(&cfg).expect("skewed loadgen");
        let allocs =
            (harness::alloc_count() - a) as f64 / rep.ok.max(1) as f64;
        assert_eq!(rep.errors, 0, "skewed loadgen frames failed");
        Client::connect(&addr).expect("connect for skewed shutdown")
            .shutdown_server().expect("skewed shutdown");
        let report = gw.wait().expect("skewed gateway wait");
        let serving = &report.default_model().serving;
        println!("skewed [{}]: fps={:.1} host_balance={:.3} \
                  cost_balance={:.3} calib_err={:.3}",
                 dispatch.as_str(), rep.fps,
                 serving.host_balance_ratio,
                 serving.cost_balance_ratio,
                 serving.cost_calibration_error);
        let r = loadgen_row(row, &rep, allocs);
        r.print();
        r
    };
    let skew_fifo = run_skewed("serving_skewed_fifo",
                               DispatchMode::WorkQueue);
    let skew_cost = run_skewed("serving_skewed_cost",
                               DispatchMode::CostAware);

    // 5. c10k: thousands of concurrent pipelined connections through
    // the sharded reactor — the scale the transport rewrite exists
    // for. The loadgen multiplexes all connections over one thread;
    // the gateway holds them all with O(shards + models) threads.
    let want_conns: usize = if quick { 1024 } else { 4096 };
    let conns = match skydiver::server::reactor::raise_nofile_limit(
        32 * 1024) {
        // Client + server ends share this process: ~2 fds per
        // connection plus slack for artifacts/listeners.
        Ok(limit) => {
            let fit = ((limit.saturating_sub(512)) / 2) as usize;
            if fit < want_conns {
                println!("c10k: fd limit {limit} clamps connections \
                          {want_conns} -> {fit}");
            }
            fit.min(want_conns).max(64)
        }
        Err(e) => {
            println!("c10k: cannot raise fd limit ({e}); using 64 \
                      connections");
            64
        }
    };
    let gw_c10k = Gateway::start_single(
        GatewayConfig {
            max_conns: 2 * conns,
            drain_timeout: Duration::from_secs(60),
            ..GatewayConfig::default()
        },
        ServiceConfig {
            queue_cap: 2 * conns,
            ..service_cfg()
        },
        worker_cfg(&dir, NetKind::Classifier))
        .expect("c10k gateway start");
    let addr_c10k = gw_c10k.local_addr().to_string();
    let c10k_cfg = LoadGenConfig {
        addr: addr_c10k.clone(),
        model: String::new(),
        conns,
        frames: conns * 2, // two pipelined frames per connection
        window: 2,
        spikes: false,
        retry_busy: true,
        traffic: TrafficMode::Skewed,
        priority: None,
        seed: 0xC10C,
    };
    let a2 = harness::alloc_count();
    let c10k_rep = loadgen::run(&c10k_cfg).expect("c10k loadgen");
    let c10k_allocs = (harness::alloc_count() - a2) as f64
        / c10k_rep.ok.max(1) as f64;
    assert_eq!(c10k_rep.errors, 0, "c10k loadgen frames failed");
    assert_eq!(c10k_rep.ok as usize, conns * 2,
               "not all c10k frames served");
    let c10k = loadgen_row("serving_c10k", &c10k_rep, c10k_allocs);
    c10k.print();
    println!("c10k: conns={} shards={} ok={} busy={} fps={:.1}",
             conns, gw_c10k.shard_count(), c10k_rep.ok, c10k_rep.busy,
             c10k_rep.fps);
    Client::connect(&addr_c10k).expect("connect for c10k shutdown")
        .shutdown_server().expect("c10k shutdown");
    let report_c10k = gw_c10k.wait().expect("c10k gateway wait");
    assert_eq!(report_c10k.counters.internal, 0);
    println!("c10k server: accepted={} served={} shed={}",
             report_c10k.counters.conns_accepted,
             report_c10k.counters.served,
             report_c10k.counters.conns_shed);

    // 6. The cluster tier: two gateway backends behind a front
    // router, skewed pipelined traffic placed by heartbeat-reported
    // queue cost. Compared against the single-gateway skewed rows,
    // this prices the extra hop + placement machinery.
    let mk_backend = || {
        Gateway::start_single(
            GatewayConfig::default(), service_cfg(),
            worker_cfg(&dir, NetKind::Classifier))
            .expect("cluster backend start")
    };
    let (bk0, bk1) = (mk_backend(), mk_backend());
    let router = skydiver::cluster::Router::start(
        skydiver::cluster::RouterConfig {
            addr: "127.0.0.1:0".into(),
            backends: vec![bk0.local_addr().to_string(),
                           bk1.local_addr().to_string()],
            heartbeat_every: Duration::from_millis(50),
            ..skydiver::cluster::RouterConfig::default()
        }).expect("router start");
    let cluster_frames = if quick { 150 } else { 1200 };
    let cluster_cfg = LoadGenConfig {
        addr: router.local_addr().to_string(),
        model: String::new(),
        conns: 4,
        frames: cluster_frames,
        window: 8,
        spikes: false,
        retry_busy: true,
        traffic: TrafficMode::Skewed,
        priority: None,
        seed: 0x5EED,
    };
    let a3 = harness::alloc_count();
    let cluster_rep =
        loadgen::run(&cluster_cfg).expect("cluster loadgen");
    let cluster_allocs = (harness::alloc_count() - a3) as f64
        / cluster_rep.ok.max(1) as f64;
    assert_eq!(cluster_rep.errors, 0, "cluster loadgen frames failed");
    assert_eq!(cluster_rep.ok as usize, cluster_frames,
               "not all cluster frames served");
    let cluster = loadgen_row("serving_cluster", &cluster_rep,
                              cluster_allocs);
    cluster.print();
    Client::connect(router.local_addr().to_string())
        .expect("connect for router shutdown")
        .shutdown_server().expect("router shutdown");
    let rr = router.wait().expect("router wait");
    println!("cluster: fps={:.1} served={} retries={} failed={} \
              dispatched=[{}, {}]",
             cluster_rep.fps, rr.served, rr.retries, rr.failed,
             rr.backends[0].dispatched, rr.backends[1].dispatched);
    for bk in [bk0, bk1] {
        bk.stop_and_wait().expect("cluster backend stop");
    }

    // 7. The tracing tax: the same pipelined workload twice against
    // one gateway — span recording off, then on. The off leg is the
    // baseline the tracing layer must not move; the on leg prices a
    // full per-request span timeline (admission, cost-predict, queue,
    // batch, compute, encode, write) plus the flight recorder.
    {
        use skydiver::obs::trace;
        // The disabled path must be branch-cheap and allocation-free
        // at the recording call sites themselves.
        trace::set_enabled(false);
        let a_off = harness::alloc_count();
        for i in 0..1000u64 {
            trace::span([0u8; 16], 0, trace::Stage::Compute, 0, i,
                        false, 0, 0);
        }
        assert_eq!(harness::alloc_count(), a_off,
                   "disabled tracing allocated on the span path");
    }
    let gw_tr = Gateway::start_single(
        GatewayConfig::default(), service_cfg(),
        worker_cfg(&dir, NetKind::Classifier))
        .expect("traced gateway start");
    let addr_tr = gw_tr.local_addr().to_string();
    let tr_frames = if quick { 200 } else { 2000 };
    let mk_tr_cfg = || LoadGenConfig {
        addr: addr_tr.clone(),
        model: String::new(),
        conns: 4,
        frames: tr_frames,
        window: 8,
        spikes: false,
        retry_busy: true,
        traffic: TrafficMode::Mixed,
        priority: None,
        seed: 0x72ACE,
    };
    let run_leg = |row: &str| {
        let cfg = mk_tr_cfg();
        let a = harness::alloc_count();
        let rep = loadgen::run(&cfg).expect("traced-pair loadgen");
        let allocs =
            (harness::alloc_count() - a) as f64 / rep.ok.max(1) as f64;
        assert_eq!(rep.errors, 0, "traced-pair loadgen frames failed");
        assert_eq!(rep.ok as usize, tr_frames,
                   "not all traced-pair frames served");
        let r = loadgen_row(row, &rep, allocs);
        r.print();
        r
    };
    let pipelined = run_leg("serving_pipelined");
    skydiver::obs::trace::set_enabled(true);
    let traced = run_leg("serving_traced");
    skydiver::obs::trace::set_enabled(false);
    println!("tracing tax: off mean={:?} on mean={:?} ({:+.2}%)",
             pipelined.mean, traced.mean,
             100.0 * (traced.mean.as_secs_f64()
                      / pipelined.mean.as_secs_f64() - 1.0));
    // The traced leg must have actually produced a flight-recorder
    // dump worth the name.
    let dump = Client::connect(&addr_tr)
        .expect("connect for trace dump")
        .trace_dump().expect("trace dump");
    assert!(dump.contains("\"traceEvents\""), "dump not chrome JSON");
    assert!(dump.contains("compute"), "dump records no compute spans");
    println!("trace dump: {} bytes", dump.len());
    Client::connect(&addr_tr).expect("connect for traced shutdown")
        .shutdown_server().expect("traced shutdown");
    gw_tr.wait().expect("traced gateway wait");

    // 8. The temporal-kernel dividend: the `serving_loopback_e2e`
    // workload (same seed/conns/window) served with the temporal
    // kernels off — the per-timestep functional path the worker used
    // before the time-major rewrite. The e2e row above is the
    // temporal-on leg, so the pair prices the bit-parallel compute
    // path end to end over real TCP.
    let gw_off = Gateway::start_single(
        GatewayConfig::default(), service_cfg(),
        WorkerConfig {
            temporal: false,
            ..worker_cfg(&dir, NetKind::Classifier)
        })
        .expect("temporal-off gateway start");
    let addr_off = gw_off.local_addr().to_string();
    let off_frames = if quick { 200 } else { 2000 };
    let off_cfg = LoadGenConfig {
        addr: addr_off.clone(),
        model: String::new(),
        conns: 4,
        frames: off_frames,
        window: 8,
        spikes: false,
        retry_busy: true,
        traffic: TrafficMode::Mixed,
        priority: None,
        seed: 0xBE7C,
    };
    let a4 = harness::alloc_count();
    let off_rep = loadgen::run(&off_cfg).expect("temporal-off loadgen");
    let off_allocs = (harness::alloc_count() - a4) as f64
        / off_rep.ok.max(1) as f64;
    assert_eq!(off_rep.errors, 0, "temporal-off loadgen frames failed");
    assert_eq!(off_rep.ok as usize, off_frames,
               "not all temporal-off frames served");
    let temporal_off =
        loadgen_row("serving_temporal_off", &off_rep, off_allocs);
    temporal_off.print();
    println!("temporal kernels: on fps={:.1} off fps={:.1}",
             rep.fps, off_rep.fps);
    Client::connect(&addr_off)
        .expect("connect for temporal-off shutdown")
        .shutdown_server().expect("temporal-off shutdown");
    gw_off.wait().expect("temporal-off gateway wait");

    // 9. Graceful degradation under overload: a deliberately starved
    // pool (1 worker, cap-8 queue) with `--degrade reduce-t` on,
    // pushed far past capacity. Requests past the pressure knee serve
    // at reduced T instead of bouncing as BUSY, so the row prices the
    // degraded serving path; the printed split shows how much of the
    // load the policy absorbed.
    let gw_deg = Gateway::start_single(
        GatewayConfig {
            degrade_reduce_t: true,
            degrade_floor_t: 2,
            ..GatewayConfig::default()
        },
        ServiceConfig {
            workers: 1,
            batch_max: 1,
            queue_cap: 8,
            ..service_cfg()
        },
        worker_cfg(&dir, NetKind::Classifier))
        .expect("degraded gateway start");
    let addr_deg = gw_deg.local_addr().to_string();
    let deg_frames = if quick { 150 } else { 1200 };
    let deg_cfg = LoadGenConfig {
        addr: addr_deg.clone(),
        model: String::new(),
        conns: 2,
        frames: deg_frames,
        window: 32,
        spikes: false,
        retry_busy: true,
        traffic: TrafficMode::Skewed,
        priority: None,
        seed: 0xDE64,
    };
    let a5 = harness::alloc_count();
    let deg_rep = loadgen::run(&deg_cfg).expect("degraded loadgen");
    let deg_allocs = (harness::alloc_count() - a5) as f64
        / deg_rep.ok.max(1) as f64;
    assert_eq!(deg_rep.errors, 0, "degraded loadgen frames failed");
    assert_eq!(deg_rep.ok as usize, deg_frames,
               "not all degraded-leg frames served");
    assert!(deg_rep.degraded > 0,
            "an overloaded cap-8 queue with --degrade reduce-t must \
             serve some frames at reduced T");
    let degraded = loadgen_row("serving_degraded", &deg_rep,
                               deg_allocs);
    degraded.print();
    println!("degraded: ok={} of which reduced-T={} busy-retries={}",
             deg_rep.ok, deg_rep.degraded, deg_rep.busy);
    Client::connect(&addr_deg)
        .expect("connect for degraded shutdown")
        .shutdown_server().expect("degraded shutdown");
    gw_deg.wait().expect("degraded gateway wait");

    // 10. Elastic-pool serving: the same starved-start shape but with
    // runtime headroom (1 worker growing to 4) and a fast autoscale
    // loop. The row prices serving while the controller is scaling;
    // the printed gauge/event counts come from the live metrics
    // endpoint right after the run.
    let gw_as = Gateway::start_single(
        GatewayConfig {
            autoscale: skydiver::coordinator::AutoscaleConfig {
                min: 1,
                max: 4,
                tick: Duration::from_millis(10),
                sustain_ticks: 2,
                cooldown_ticks: 1,
                ..skydiver::coordinator::AutoscaleConfig::default()
            },
            ..GatewayConfig::default()
        },
        ServiceConfig {
            workers: 1,
            workers_max: 4,
            queue_cap: 64,
            ..service_cfg()
        },
        worker_cfg(&dir, NetKind::Classifier))
        .expect("autoscale gateway start");
    let addr_as = gw_as.local_addr().to_string();
    let as_frames = if quick { 200 } else { 2000 };
    let as_cfg = LoadGenConfig {
        addr: addr_as.clone(),
        model: String::new(),
        conns: 4,
        frames: as_frames,
        window: 16,
        spikes: false,
        retry_busy: true,
        traffic: TrafficMode::Skewed,
        priority: None,
        seed: 0x5CA1E,
    };
    let a6 = harness::alloc_count();
    let as_rep = loadgen::run(&as_cfg).expect("autoscale loadgen");
    let as_allocs = (harness::alloc_count() - a6) as f64
        / as_rep.ok.max(1) as f64;
    assert_eq!(as_rep.errors, 0, "autoscale loadgen frames failed");
    assert_eq!(as_rep.ok as usize, as_frames,
               "not all autoscale-leg frames served");
    let autoscale = loadgen_row("serving_autoscale", &as_rep,
                                as_allocs);
    autoscale.print();
    {
        let mut mc = Client::connect(&addr_as)
            .expect("connect for autoscale metrics");
        let text = mc.metrics().expect("autoscale metrics");
        let sample = |name: &str| -> String {
            let prefix =
                format!("{name}{{model=\"classifier\"}} ");
            text.lines()
                .find_map(|l| l.strip_prefix(prefix.as_str()))
                .unwrap_or("?")
                .to_string()
        };
        println!("autoscale: workers={} events={} fps={:.1}",
                 sample("skydiver_autoscale_workers"),
                 sample("skydiver_autoscale_events_total"),
                 as_rep.fps);
    }
    Client::connect(&addr_as)
        .expect("connect for autoscale shutdown")
        .shutdown_server().expect("autoscale shutdown");
    gw_as.wait().expect("autoscale gateway wait");

    let path = std::env::var("BENCH_SERVING_JSON")
        .unwrap_or_else(|_| "BENCH_serving.json".into());
    harness::write_json_to(
        &path, &[rtt, e2e, mixed_cls, mixed_seg, skew_fifo, skew_cost,
                 c10k, cluster, pipelined, traced, temporal_off,
                 degraded, autoscale]);
}
