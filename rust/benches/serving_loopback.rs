//! Loopback serving benchmark: a real `Gateway` on an ephemeral
//! 127.0.0.1 port, driven over actual TCP — so the tracked numbers
//! include the wire protocol, admission control, and router, not just
//! the simulator. Fully hermetic (synthetic artifacts; no
//! `make artifacts`).
//!
//! Emits two rows into `BENCH_serving.json` (`skydiver-bench-v1`
//! schema, path overridable via `BENCH_SERVING_JSON` — see PERF.md):
//!
//! * `serving_loopback_rtt` — single-connection, window-1 round-trip
//!   latency (one request fully served per iteration).
//! * `serving_loopback_e2e` — 4 connections x window 8 pipelined
//!   throughput; `frames_per_sec` is the measured end-to-end FPS and
//!   mean/p50/p95/p99 are client-side per-request latencies.

#[path = "harness.rs"]
mod harness;

use std::time::Duration;

use harness::{bench, BenchResult};
use skydiver::coordinator::{DispatchMode, Policy, ServiceConfig,
                            WorkerConfig};
use skydiver::power::EnergyModel;
use skydiver::server::{loadgen, Client, Gateway, GatewayConfig,
                       LoadGenConfig};
use skydiver::sim::ArchConfig;
use skydiver::snn::NetKind;

const SIDE: usize = 32;

fn main() {
    let quick = harness::quick();
    let dir = std::env::temp_dir()
        .join(format!("skydiver-servbench-{}", std::process::id()));
    skydiver::data::write_synthetic_classifier(&dir, SIDE)
        .expect("synthetic artifacts");

    let wcfg = WorkerConfig {
        artifacts: dir.clone(),
        kind: NetKind::Classifier,
        aprc: true,
        policy: Policy::Cbws,
        arch: ArchConfig::default(),
        energy: EnergyModel::default(),
        use_runtime: false,
        timesteps: None,
        sweep_threads: 1,
    };
    let scfg = ServiceConfig {
        workers: 2,
        batch_max: 8,
        queue_cap: 256,
        batch_wait: Duration::from_millis(2),
        dispatch: DispatchMode::WorkQueue,
    };
    let gw = Gateway::start(GatewayConfig::default(), scfg, wcfg)
        .expect("gateway start");
    let addr = gw.local_addr().to_string();

    // 1. Single-connection round-trip latency (window = 1): protocol
    // encode + TCP + admission + simulate + route + decode.
    let mut client = Client::connect(&addr).expect("connect");
    let info = client.info().expect("info");
    let pixels: Vec<u8> = (0..info.pixels_len())
        .map(|i| (i * 37 % 256) as u8)
        .collect();
    let (warm, iters) = if quick { (5, 50) } else { (20, 400) };
    let mut id = 0u64;
    let rtt = bench("serving_loopback_rtt", warm, iters, || {
        id += 1;
        client.infer_pixels(id, NetKind::Classifier, pixels.clone())
            .expect("infer")
    });
    drop(client);

    // 2. Multi-connection pipelined throughput — the configuration the
    // acceptance loopback test uses (4 conns, window 8).
    let frames = if quick { 200 } else { 2000 };
    let cfg = LoadGenConfig {
        addr: addr.clone(),
        conns: 4,
        frames,
        window: 8,
        spikes: false,
        retry_busy: true,
        seed: 0xBE7C,
    };
    let a0 = harness::alloc_count();
    let rep = loadgen::run(&cfg).expect("loadgen");
    let allocs =
        (harness::alloc_count() - a0) as f64 / rep.ok.max(1) as f64;
    assert_eq!(rep.errors, 0, "loadgen frames failed");
    assert_eq!(rep.ok as usize, frames, "not all frames served");
    let mean = Duration::from_nanos((rep.mean_us * 1000.0) as u64)
        .max(Duration::from_nanos(1));
    let e2e = BenchResult {
        name: "serving_loopback_e2e".into(),
        iters: rep.ok as usize,
        mean,
        p50: Duration::from_micros(rep.p50_us),
        p95: Duration::from_micros(rep.p95_us),
        p99: Duration::from_micros(rep.p99_us),
        allocs_per_iter: allocs,
        // per_sec() = items_per_iter / mean — pick items so this row's
        // frames_per_sec equals the measured end-to-end throughput
        // (mean latency alone would understate pipelined FPS).
        items_per_iter: rep.fps * mean.as_secs_f64(),
    };
    e2e.print();
    println!("loadgen: ok={} busy={} errors={} fps={:.1}",
             rep.ok, rep.busy, rep.errors, rep.fps);

    // Graceful drain through the wire, like a real operator would.
    Client::connect(&addr).expect("connect for shutdown")
        .shutdown_server().expect("shutdown");
    let report = gw.wait().expect("gateway wait");
    println!("server: served={} busy={} p50={}us balance={:.2}",
             report.counters.served, report.counters.busy,
             report.serving.p50_us, report.serving.host_balance_ratio);

    let path = std::env::var("BENCH_SERVING_JSON")
        .unwrap_or_else(|_| "BENCH_serving.json".into());
    harness::write_json_to(&path, &[rtt, e2e]);
}
