//! Microbenchmarks of the simulator hot path (PERF.md): spike-map
//! construction, event iteration, per-layer timing, the allocation-free
//! functional step, and the frame-parallel sweep (serial vs parallel on
//! the same synthetic workload). Trained-network benches run too when
//! the artifacts are built; the synthetic ones always run, so
//! `BENCH_sim.json` is populated on any host.

#[path = "harness.rs"]
mod harness;

use harness::{bench, bench_items};
use skydiver::coordinator::default_input_rates;
use skydiver::data::SplitMix64;
use skydiver::schedule::cbws::Cbws;
use skydiver::schedule::{AprcPredictor, Scheduler};
use skydiver::sim::{layer_timing, sweep, ArchConfig, Simulator,
                    TraceSource};
use skydiver::snn::{encode_phased, encode_phased_u8, ConvGeom,
                    FunctionalNet, LayerWeights, NetworkWeights,
                    SpikeMap, WeightsMeta};

fn rand_map(rng: &mut SplitMix64, c: usize, h: usize, w: usize,
            rate_pct: u64) -> SpikeMap {
    let mut m = SpikeMap::zeros(c, h, w);
    for ch in 0..c {
        for i in 0..h * w {
            if rng.next_below(100) < rate_pct {
                m.set(ch, i);
            }
        }
    }
    m
}

/// Synthetic 3-conv-layer network (segmenter-shaped, smaller): lets the
/// hot-path and sweep benches run without `make artifacts`.
fn synthetic_net(rng: &mut SplitMix64) -> NetworkWeights {
    let (h, w) = (40usize, 80usize);
    let chans = [3usize, 8, 16, 8];
    let pad = 2;
    let mut layers = Vec::new();
    let (mut lh, mut lw) = (h, w);
    let mut feat = Vec::new();
    for l in 0..3 {
        let (cin, cout) = (chans[l], chans[l + 1]);
        let eh = lh + 2 * pad - 3 + 1;
        let ew = lw + 2 * pad - 3 + 1;
        let weights: Vec<f32> = (0..cout * cin * 9)
            .map(|_| (rng.next_below(1000) as f32 / 1000.0 - 0.3) * 0.2)
            .collect();
        layers.push(LayerWeights::Conv {
            geom: ConvGeom { cin, cout, r: 3, pad, h: lh, w: lw, eh, ew },
            w: weights,
        });
        feat.push(format!("[{cout}, {eh}, {ew}]"));
        lh = eh;
        lw = ew;
    }
    let meta = WeightsMeta::parse(&format!(r#"{{
        "name": "synthetic", "aprc": true, "pad": {pad}, "vth": 0.4,
        "timesteps": 8, "in_shape": [3, {h}, {w}],
        "feature_sizes": [{}], "dense_out": null,
        "total_floats": 0, "lambdas": [],
        "layers": [], "blob_fnv1a64": "0"
    }}"#, feat.join(", "))).expect("synthetic meta");
    NetworkWeights { meta, layers }
}

/// Encoded synthetic frames with varied content.
fn synthetic_frames(rng: &mut SplitMix64, net: &NetworkWeights, n: usize)
                    -> Vec<Vec<SpikeMap>> {
    let (c, h, w) = (net.meta.in_shape[0], net.meta.in_shape[1],
                     net.meta.in_shape[2]);
    (0..n).map(|_| {
        let img: Vec<f32> = (0..c * h * w)
            .map(|_| rng.next_below(1000) as f32 / 1000.0 * 0.4)
            .collect();
        encode_phased(&img, c, h, w, net.meta.timesteps)
    }).collect()
}

fn main() {
    let (wu, it) = if harness::quick() { (1, 10) } else { (3, 50) };
    let mut rng = SplitMix64::new(0xBE7C);
    let mut results = Vec::new();

    // Event iteration at segmentation-layer scale (32ch, 88x168, 8%).
    let map = rand_map(&mut rng, 32, 88, 168, 8);
    results.push(bench("iter_events 32x88x168 @8%", wu, it * 10, || {
        map.iter_events().count()
    }));
    results.push(bench("nnz_per_channel 32x88x168", wu, it * 10, || {
        map.nnz_per_channel()
    }));

    // Timing-model kernel.
    let arch = ArchConfig::default();
    let layer = skydiver::snn::LayerWeights::Conv {
        geom: skydiver::snn::ConvGeom {
            cin: 32, cout: 32, r: 3, pad: 2, h: 86, w: 166,
            eh: 88, ew: 168 },
        w: vec![],
    };
    let pred = vec![1.0; 32];
    let part = Cbws::default().assign(&pred, 8);
    let nnz = map.nnz_per_channel();
    results.push(bench("layer_timing conv32->32", wu, it * 100, || {
        layer_timing(&arch, &layer, &part, &nnz)
    }));

    // Allocation-free functional step on the synthetic net: after
    // warmup the scratch has grown to peak activity, so allocs/iter
    // must read ~0 here.
    let net = synthetic_net(&mut rng);
    let trains = synthetic_frames(&mut rng, &net, 16);
    let mut fnet = FunctionalNet::new(&net);
    let step_input = trains[0][2].clone();
    results.push(bench("functional step synthetic (reuse)", wu.max(2),
                       it * 10, || {
        fnet.step_reuse(&step_input).len()
    }));
    results.push(bench("functional frame synthetic (T=8)", wu, it, || {
        fnet.run_frame_counts(&trains[0])
    }));

    // Frame-parallel sweep: the same 16-frame fig7-style workload,
    // serial vs all-cores (the ratio is the sweep engine's speedup).
    let rates = vec![0.2f64; 3];
    let predictor = AprcPredictor::from_network(&net, &rates);
    let sim = Simulator::new(arch, &net, &Cbws::default(), &predictor);
    let nf = trains.len() as f64;
    results.push(bench_items("sweep 16 frames serial", 1,
                             if harness::quick() { 3 } else { 10 }, nf,
                             || {
        sweep::run_frames_functional(&sim, &trains, 1).unwrap().len()
    }));
    // Stable name (no thread count): the JSON entry records the host's
    // `threads` separately, so rows stay comparable across hosts.
    let threads = sweep::default_threads();
    println!("(parallel sweep width: {threads})");
    results.push(bench_items(
        "sweep 16 frames parallel", 1,
        if harness::quick() { 3 } else { 10 }, nf, || {
            sweep::run_frames_functional(&sim, &trains, threads)
                .unwrap().len()
        }));

    // Full functional frames on the trained networks (if built).
    let dir = skydiver::artifacts_dir();
    if let Ok(net) = NetworkWeights::load(&dir, "classifier_aprc") {
        let (imgs, _) = skydiver::data::gen_digits(1, 1);
        let inputs = encode_phased_u8(&imgs[..784], 1, 28, 28,
                                      net.meta.timesteps);
        results.push(bench("functional frame classifier (T=24)", wu, it,
                           || {
            FunctionalNet::new(&net).run_frame_counts(&inputs)
        }));
        let rates = default_input_rates(&net);
        let predictor = AprcPredictor::from_network(&net, &rates);
        let sim = Simulator::new(arch, &net, &Cbws::default(), &predictor);
        results.push(bench("sim frame classifier (functional trace)", wu,
                           it, || {
            sim.run_frame(&inputs, &TraceSource::Functional).unwrap()
        }));
    }
    if let Ok(net) = NetworkWeights::load(&dir, "segmenter_aprc") {
        let (imgs, _) = skydiver::data::gen_road_scenes(1, 1);
        let (h, w) = (skydiver::data::ROAD_H, skydiver::data::ROAD_W);
        let mut chw = vec![0u8; 3 * h * w];
        for y in 0..h {
            for x in 0..w {
                for c in 0..3 {
                    chw[c * h * w + y * w + x] = imgs[(y * w + x) * 3 + c];
                }
            }
        }
        let inputs = encode_phased_u8(&chw, 3, h, w, net.meta.timesteps);
        let seg_it = if harness::quick() { 3 } else { 10 };
        results.push(bench("functional frame segmenter (T=50)", 1, seg_it,
                           || {
            FunctionalNet::new(&net).run_frame_counts(&inputs)
        }));
    }

    harness::write_json(&results);
}
