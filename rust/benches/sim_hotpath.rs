//! Microbenchmarks of the simulator hot path (DESIGN.md §8 L3):
//! spike-map construction, event iteration, per-layer timing, and a full
//! functional frame of each network.

#[path = "harness.rs"]
mod harness;

use harness::bench;
use skydiver::coordinator::default_input_rates;
use skydiver::data::SplitMix64;
use skydiver::schedule::cbws::Cbws;
use skydiver::schedule::{AprcPredictor, Scheduler};
use skydiver::sim::{layer_timing, ArchConfig, Simulator, TraceSource};
use skydiver::snn::{encode_phased_u8, FunctionalNet, NetworkWeights,
                    SpikeMap};

fn rand_map(rng: &mut SplitMix64, c: usize, h: usize, w: usize,
            rate_pct: u64) -> SpikeMap {
    let mut m = SpikeMap::zeros(c, h, w);
    for ch in 0..c {
        for i in 0..h * w {
            if rng.next_below(100) < rate_pct {
                m.set(ch, i);
            }
        }
    }
    m
}

fn main() {
    let (wu, it) = if harness::quick() { (1, 10) } else { (3, 50) };
    let mut rng = SplitMix64::new(0xBE7C);

    // Event iteration at segmentation-layer scale (32ch, 88x168, 8%).
    let map = rand_map(&mut rng, 32, 88, 168, 8);
    bench("iter_events 32x88x168 @8%", wu, it * 10, || {
        map.iter_events().count()
    });
    bench("nnz_per_channel 32x88x168", wu, it * 10, || {
        map.nnz_per_channel()
    });

    // Timing-model kernel.
    let arch = ArchConfig::default();
    let layer = skydiver::snn::LayerWeights::Conv {
        geom: skydiver::snn::ConvGeom {
            cin: 32, cout: 32, r: 3, pad: 2, h: 86, w: 166,
            eh: 88, ew: 168 },
        w: vec![],
    };
    let pred = vec![1.0; 32];
    let part = Cbws::default().assign(&pred, 8);
    let nnz = map.nnz_per_channel();
    bench("layer_timing conv32->32", wu, it * 100, || {
        layer_timing(&arch, &layer, &part, &nnz)
    });

    // Full functional frames on the trained networks (if built).
    let dir = skydiver::artifacts_dir();
    if let Ok(net) = NetworkWeights::load(&dir, "classifier_aprc") {
        let (imgs, _) = skydiver::data::gen_digits(1, 1);
        let inputs = encode_phased_u8(&imgs[..784], 1, 28, 28,
                                      net.meta.timesteps);
        bench("functional frame classifier (T=24)", wu, it, || {
            FunctionalNet::new(&net).run_frame_counts(&inputs)
        });
        let rates = default_input_rates(&net);
        let predictor = AprcPredictor::from_network(&net, &rates);
        let sim = Simulator::new(arch, &net, &Cbws::default(), &predictor);
        bench("sim frame classifier (functional trace)", wu, it, || {
            sim.run_frame(&inputs, &TraceSource::Functional).unwrap()
        });
    }
    if let Ok(net) = NetworkWeights::load(&dir, "segmenter_aprc") {
        let (imgs, _) = skydiver::data::gen_road_scenes(1, 1);
        let (h, w) = (skydiver::data::ROAD_H, skydiver::data::ROAD_W);
        let mut chw = vec![0u8; 3 * h * w];
        for y in 0..h {
            for x in 0..w {
                for c in 0..3 {
                    chw[c * h * w + y * w + x] = imgs[(y * w + x) * 3 + c];
                }
            }
        }
        let inputs = encode_phased_u8(&chw, 3, h, w, net.meta.timesteps);
        let seg_it = if harness::quick() { 3 } else { 10 };
        bench("functional frame segmenter (T=50)", 1, seg_it, || {
            FunctionalNet::new(&net).run_frame_counts(&inputs)
        });
    }
}
