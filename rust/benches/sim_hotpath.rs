//! Microbenchmarks of the simulator hot path (PERF.md): spike-map
//! construction, event iteration, per-layer timing, the allocation-free
//! functional step, the frame-parallel sweep (serial vs parallel on
//! the same synthetic workload), and the bit-parallel temporal kernels
//! (`sim_temporal_{conv,dense,frame}` vs their per-timestep oracles at
//! T=64, counts asserted identical and the frame row asserted
//! allocation-free). Trained-network benches run too when the
//! artifacts are built; the synthetic ones always run, so
//! `BENCH_sim.json` is populated on any host.

#[path = "harness.rs"]
mod harness;

use harness::{bench, bench_items};
use skydiver::coordinator::default_input_rates;
use skydiver::data::SplitMix64;
use skydiver::schedule::cbws::Cbws;
use skydiver::schedule::{AprcPredictor, Scheduler};
use skydiver::sim::{layer_timing, sweep, ArchConfig, Simulator,
                    TraceSource};
use skydiver::snn::{encode_phased, encode_phased_u8, transpose_dense,
                    ConvGeom, DenseGeom, FunctionalNet, LayerWeights,
                    NetworkWeights, SpikeMap, TemporalSpikeMap,
                    WeightsMeta};

fn rand_map(rng: &mut SplitMix64, c: usize, h: usize, w: usize,
            rate_pct: u64) -> SpikeMap {
    let mut m = SpikeMap::zeros(c, h, w);
    for ch in 0..c {
        for i in 0..h * w {
            if rng.next_below(100) < rate_pct {
                m.set(ch, i);
            }
        }
    }
    m
}

/// Synthetic 3-conv-layer network (segmenter-shaped, smaller): lets the
/// hot-path and sweep benches run without `make artifacts`.
fn synthetic_net(rng: &mut SplitMix64) -> NetworkWeights {
    let (h, w) = (40usize, 80usize);
    let chans = [3usize, 8, 16, 8];
    let pad = 2;
    let mut layers = Vec::new();
    let (mut lh, mut lw) = (h, w);
    let mut feat = Vec::new();
    for l in 0..3 {
        let (cin, cout) = (chans[l], chans[l + 1]);
        let eh = lh + 2 * pad - 3 + 1;
        let ew = lw + 2 * pad - 3 + 1;
        let weights: Vec<f32> = (0..cout * cin * 9)
            .map(|_| (rng.next_below(1000) as f32 / 1000.0 - 0.3) * 0.2)
            .collect();
        layers.push(LayerWeights::Conv {
            geom: ConvGeom { cin, cout, r: 3, pad, h: lh, w: lw, eh, ew },
            w: weights,
        });
        feat.push(format!("[{cout}, {eh}, {ew}]"));
        lh = eh;
        lw = ew;
    }
    let meta = WeightsMeta::parse(&format!(r#"{{
        "name": "synthetic", "aprc": true, "pad": {pad}, "vth": 0.4,
        "timesteps": 8, "in_shape": [3, {h}, {w}],
        "feature_sizes": [{}], "dense_out": null,
        "total_floats": 0, "lambdas": [],
        "layers": [], "blob_fnv1a64": "0"
    }}"#, feat.join(", "))).expect("synthetic meta");
    NetworkWeights { meta, layers }
}

/// Encoded synthetic frames with varied content.
fn synthetic_frames(rng: &mut SplitMix64, net: &NetworkWeights, n: usize)
                    -> Vec<Vec<SpikeMap>> {
    let (c, h, w) = (net.meta.in_shape[0], net.meta.in_shape[1],
                     net.meta.in_shape[2]);
    (0..n).map(|_| {
        let img: Vec<f32> = (0..c * h * w)
            .map(|_| rng.next_below(1000) as f32 / 1000.0 * 0.4)
            .collect();
        encode_phased(&img, c, h, w, net.meta.timesteps)
    }).collect()
}

/// Single-conv-layer net for the temporal conv kernel row.
fn conv_only_net(rng: &mut SplitMix64) -> NetworkWeights {
    let (cin, cout, h, w, pad) = (8usize, 16usize, 32usize, 64usize,
                                  2usize);
    let eh = h + 2 * pad - 3 + 1;
    let ew = w + 2 * pad - 3 + 1;
    let weights: Vec<f32> = (0..cout * cin * 9)
        .map(|_| (rng.next_below(1000) as f32 / 1000.0 - 0.3) * 0.2)
        .collect();
    let meta = WeightsMeta::parse(&format!(r#"{{
        "name": "conv_only", "aprc": true, "pad": {pad}, "vth": 0.4,
        "timesteps": 64, "in_shape": [{cin}, {h}, {w}],
        "feature_sizes": [[{cout}, {eh}, {ew}]], "dense_out": null,
        "total_floats": 0, "lambdas": [],
        "layers": [], "blob_fnv1a64": "0"
    }}"#)).expect("conv-only meta");
    NetworkWeights {
        meta,
        layers: vec![LayerWeights::Conv {
            geom: ConvGeom { cin, cout, r: 3, pad, h, w, eh, ew },
            w: weights,
        }],
    }
}

/// Single-dense-layer net for the temporal dense kernel row.
fn dense_only_net(rng: &mut SplitMix64) -> NetworkWeights {
    let (src, per, fout) = (8usize, 64usize, 128usize);
    let fin = src * per;
    let w: Vec<f32> = (0..fout * fin)
        .map(|_| (rng.next_below(1000) as f32 / 1000.0 - 0.3) * 0.05)
        .collect();
    let wt = transpose_dense(&w, fout, fin);
    let b: Vec<f32> = (0..fout)
        .map(|_| rng.next_below(1000) as f32 / 1000.0 * 0.01)
        .collect();
    let meta = WeightsMeta::parse(&format!(r#"{{
        "name": "dense_only", "aprc": true, "pad": 0, "vth": 0.4,
        "timesteps": 64, "in_shape": [{src}, 1, {per}],
        "feature_sizes": [], "dense_out": {fout},
        "total_floats": 0, "lambdas": [],
        "layers": [], "blob_fnv1a64": "0"
    }}"#)).expect("dense-only meta");
    NetworkWeights {
        meta,
        layers: vec![LayerWeights::Dense {
            geom: DenseGeom { fin, fout, src_channels: src },
            w, wt, b,
        }],
    }
}

/// One encoded frame at an explicit timestep count.
fn train_at(rng: &mut SplitMix64, c: usize, h: usize, w: usize,
            t: usize) -> Vec<SpikeMap> {
    let img: Vec<f32> = (0..c * h * w)
        .map(|_| rng.next_below(1000) as f32 / 1000.0 * 0.4)
        .collect();
    encode_phased(&img, c, h, w, t)
}

fn main() {
    let (wu, it) = if harness::quick() { (1, 10) } else { (3, 50) };
    let mut rng = SplitMix64::new(0xBE7C);
    let mut results = Vec::new();

    // Event iteration at segmentation-layer scale (32ch, 88x168, 8%).
    let map = rand_map(&mut rng, 32, 88, 168, 8);
    results.push(bench("iter_events 32x88x168 @8%", wu, it * 10, || {
        map.iter_events().count()
    }));
    results.push(bench("nnz_per_channel 32x88x168", wu, it * 10, || {
        map.nnz_per_channel()
    }));

    // Timing-model kernel.
    let arch = ArchConfig::default();
    let layer = skydiver::snn::LayerWeights::Conv {
        geom: skydiver::snn::ConvGeom {
            cin: 32, cout: 32, r: 3, pad: 2, h: 86, w: 166,
            eh: 88, ew: 168 },
        w: vec![],
    };
    let pred = vec![1.0; 32];
    let part = Cbws::default().assign(&pred, 8);
    let nnz = map.nnz_per_channel();
    results.push(bench("layer_timing conv32->32", wu, it * 100, || {
        layer_timing(&arch, &layer, &part, &nnz)
    }));

    // Allocation-free functional step on the synthetic net: after
    // warmup the scratch has grown to peak activity, so allocs/iter
    // must read ~0 here.
    let net = synthetic_net(&mut rng);
    let trains = synthetic_frames(&mut rng, &net, 16);
    let mut fnet = FunctionalNet::new(&net);
    let step_input = trains[0][2].clone();
    results.push(bench("functional step synthetic (reuse)", wu.max(2),
                       it * 10, || {
        fnet.step_reuse(&step_input).len()
    }));
    results.push(bench("functional frame synthetic (T=8)", wu, it, || {
        fnet.run_frame_counts(&trains[0])
    }));

    // Frame-parallel sweep: the same 16-frame fig7-style workload,
    // serial vs all-cores (the ratio is the sweep engine's speedup).
    let rates = vec![0.2f64; 3];
    let predictor = AprcPredictor::from_network(&net, &rates);
    let sim = Simulator::new(arch, &net, &Cbws::default(), &predictor);
    let nf = trains.len() as f64;
    results.push(bench_items("sweep 16 frames serial", 1,
                             if harness::quick() { 3 } else { 10 }, nf,
                             || {
        sweep::run_frames_functional(&sim, &trains, 1).unwrap().len()
    }));
    // Stable name (no thread count): the JSON entry records the host's
    // `threads` separately, so rows stay comparable across hosts.
    let threads = sweep::default_threads();
    println!("(parallel sweep width: {threads})");
    results.push(bench_items(
        "sweep 16 frames parallel", 1,
        if harness::quick() { 3 } else { 10 }, nf, || {
            sweep::run_frames_functional(&sim, &trains, threads)
                .unwrap().len()
        }));

    // Bit-parallel temporal kernels: the per-timestep oracle vs the
    // time-major word-wide path on identical frames. T=64 packs one
    // whole train into a single u64 per neuron — the layout's sweet
    // spot and the acceptance point for the >=2x serial-path speedup
    // (PERF.md). Counts are asserted equal before timing, so the
    // temporal rows measure the same computation, not an
    // approximation; the frame row is additionally asserted
    // allocation-free in steady state.
    let t64 = 64usize;
    let fit = if harness::quick() { 3 } else { 15 };

    let conv_net = conv_only_net(&mut rng);
    let conv_train = train_at(&mut rng, 8, 32, 64, t64);
    let conv_packed = TemporalSpikeMap::from_steps(&conv_train);
    let mut conv_o = FunctionalNet::new(&conv_net);
    let mut conv_t = FunctionalNet::new(&conv_net);
    assert_eq!(conv_t.run_frame_counts_temporal(&conv_packed),
               conv_o.run_frame_counts(&conv_train),
               "temporal conv kernel diverged from the oracle");
    let oracle_conv = bench("sim_oracle_conv", wu, fit, || {
        conv_o.run_frame_counts(&conv_train).len()
    });
    let temporal_conv = bench("sim_temporal_conv", wu.max(2), fit, || {
        conv_t.run_frame_temporal(&conv_packed).len()
    });
    println!("(temporal conv speedup: {:.2}x)",
             oracle_conv.mean.as_secs_f64()
             / temporal_conv.mean.as_secs_f64().max(1e-12));
    results.push(oracle_conv);
    results.push(temporal_conv);

    let dense_net = dense_only_net(&mut rng);
    let dense_train = train_at(&mut rng, 8, 1, 64, t64);
    let dense_packed = TemporalSpikeMap::from_steps(&dense_train);
    let mut dense_o = FunctionalNet::new(&dense_net);
    let mut dense_t = FunctionalNet::new(&dense_net);
    assert_eq!(dense_t.run_frame_counts_temporal(&dense_packed),
               dense_o.run_frame_counts(&dense_train),
               "temporal dense kernel diverged from the oracle");
    let oracle_dense = bench("sim_oracle_dense", wu, it, || {
        dense_o.run_frame_counts(&dense_train).len()
    });
    let temporal_dense = bench("sim_temporal_dense", wu.max(2), it, || {
        dense_t.run_frame_temporal(&dense_packed).len()
    });
    println!("(temporal dense speedup: {:.2}x)",
             oracle_dense.mean.as_secs_f64()
             / temporal_dense.mean.as_secs_f64().max(1e-12));
    results.push(oracle_dense);
    results.push(temporal_dense);

    // Full synthetic frame (3 conv layers) at T=64 — the row the
    // baseline gate tracks for the serial-path speedup.
    let frame_train = train_at(&mut rng, 3, 40, 80, t64);
    let frame_packed = TemporalSpikeMap::from_steps(&frame_train);
    let mut frame_o = FunctionalNet::new(&net);
    let mut frame_t = FunctionalNet::new(&net);
    assert_eq!(frame_t.run_frame_counts_temporal(&frame_packed),
               frame_o.run_frame_counts(&frame_train),
               "temporal frame path diverged from the oracle");
    let oracle_frame = bench("sim_oracle_frame", wu, fit, || {
        frame_o.run_frame_counts(&frame_train).len()
    });
    let temporal_frame = bench("sim_temporal_frame", wu.max(2), fit,
                               || {
        frame_t.run_frame_temporal(&frame_packed).len()
    });
    println!("(temporal frame speedup: {:.2}x)",
             oracle_frame.mean.as_secs_f64()
             / temporal_frame.mean.as_secs_f64().max(1e-12));
    assert_eq!(temporal_frame.allocs_per_iter, 0.0,
               "run_frame_temporal must be allocation-free in steady \
                state");
    results.push(oracle_frame);
    results.push(temporal_frame);

    // Full functional frames on the trained networks (if built).
    let dir = skydiver::artifacts_dir();
    if let Ok(net) = NetworkWeights::load(&dir, "classifier_aprc") {
        let (imgs, _) = skydiver::data::gen_digits(1, 1);
        let inputs = encode_phased_u8(&imgs[..784], 1, 28, 28,
                                      net.meta.timesteps);
        results.push(bench("functional frame classifier (T=24)", wu, it,
                           || {
            FunctionalNet::new(&net).run_frame_counts(&inputs)
        }));
        let rates = default_input_rates(&net);
        let predictor = AprcPredictor::from_network(&net, &rates);
        let sim = Simulator::new(arch, &net, &Cbws::default(), &predictor);
        results.push(bench("sim frame classifier (functional trace)", wu,
                           it, || {
            sim.run_frame(&inputs, &TraceSource::Functional).unwrap()
        }));
    }
    if let Ok(net) = NetworkWeights::load(&dir, "segmenter_aprc") {
        let (imgs, _) = skydiver::data::gen_road_scenes(1, 1);
        let (h, w) = (skydiver::data::ROAD_H, skydiver::data::ROAD_W);
        let mut chw = vec![0u8; 3 * h * w];
        for y in 0..h {
            for x in 0..w {
                for c in 0..3 {
                    chw[c * h * w + y * w + x] = imgs[(y * w + x) * 3 + c];
                }
            }
        }
        let inputs = encode_phased_u8(&chw, 3, h, w, net.meta.timesteps);
        let seg_it = if harness::quick() { 3 } else { 10 };
        results.push(bench("functional frame segmenter (T=50)", 1, seg_it,
                           || {
            FunctionalNet::new(&net).run_frame_counts(&inputs)
        }));
    }

    harness::write_json(&results);
}
