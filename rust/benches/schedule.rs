//! Scheduler microbenchmarks: CBWS (Algorithm 1) cost vs baselines at
//! realistic channel counts, plus the fine-tune loop's cost scaling.
//! CBWS runs offline in the paper, but the coordinator re-plans per
//! deployment, so planning cost still matters.

#[path = "harness.rs"]
mod harness;

use harness::bench;
use skydiver::data::SplitMix64;
use skydiver::schedule::cbws::cbws_assign;
use skydiver::schedule::{all_schedulers, Scheduler};

fn main() {
    let (wu, it) = if harness::quick() { (2, 20) } else { (5, 200) };
    let mut rng = SplitMix64::new(0x5C4ED);
    let mut results = Vec::new();

    for k in [16usize, 64, 512] {
        let w: Vec<f64> = (0..k)
            .map(|_| rng.next_below(10_000) as f64).collect();
        for s in all_schedulers() {
            results.push(bench(&format!("{} k={k} n=8", s.name()), wu, it,
                               || {
                s.assign(&w, 8)
            }));
        }
        results.push(bench(&format!("cbws k={k} n=8 finetune=1024"), wu,
                           it, || {
            cbws_assign(&w, 8, 1024)
        }));
    }
    harness::write_json(&results);
}
