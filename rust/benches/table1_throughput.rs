//! Table I regeneration as a benchmark: end-to-end simulated frames of
//! both tasks (encode -> functional trace -> cycle model -> energy),
//! printing the paper-table rows and the wall-clock cost of producing
//! them.

#[path = "harness.rs"]
mod harness;

use harness::bench;
use skydiver::experiments::{table1, ExperimentCtx};

fn main() {
    let mut ctx = ExperimentCtx::new(skydiver::artifacts_dir());
    ctx.frames = if harness::quick() { 2 } else { 4 };
    let it = if harness::quick() { 1 } else { 3 };
    let mut last = None;
    let r = bench("table1 (classif + seg rows)", 0, it, || {
        last = Some(table1::run(&ctx).expect("artifacts built"));
    });
    if let Some(res) = last {
        for row in &res.rows {
            println!("{}: {:.1} FPS, {:.3} GSOp/s, {:.1} uJ/frame",
                     row.task, row.fps, row.gsops,
                     row.energy_per_frame_j * 1e6);
        }
    }
    harness::write_json(&[r]);
}
