//! Skydiver CLI — leader entrypoint (in-crate arg parsing; offline build).
//!
//! ```bash
//! skydiver report                      # artifact inventory + metrics
//! skydiver run --net classifier       # serve frames end-to-end
//! skydiver trace --net segmenter      # one-frame per-layer trace
//! skydiver experiment fig7            # regenerate a paper artifact
//! skydiver experiment all
//! ```

use std::path::PathBuf;
use std::time::Duration;

use anyhow::{anyhow, bail, Result};

use skydiver::coordinator::{DispatchMode, Policy, Service, ServiceConfig,
                            WorkerConfig};
use skydiver::experiments::{self, ExperimentCtx};
use skydiver::metrics::Table;
use skydiver::power::EnergyModel;
use skydiver::sim::ArchConfig;
use skydiver::snn::{NetKind, NetworkWeights};

const USAGE: &str = "\
skydiver — Skydiver (TCAD'22) reproduction

USAGE:
  skydiver [--artifacts DIR] <command> [options]

COMMANDS:
  report                           artifact inventory + eval metrics
  run        [--net classifier|segmenter] [--plain] [--policy P]
             [--frames N] [--workers N] [--golden]
             [--dispatch queue|rr] [--queue-cap N] [--batch-max N]
             [--sweep-threads N]   (frame-parallel width per worker)
  trace      [--net classifier|segmenter] [--plain] [--policy P] [--golden]
  experiment <id> [--frames N] [--golden]
             ids: fig2 fig4c fig6 fig7 table1 table2 gains accuracy
                  ablation timesteps all

POLICIES: contiguous round_robin random sparten cbws (default cbws)
";

/// Tiny flag parser: `--key value` and boolean `--key`.
struct Args {
    positional: Vec<String>,
    flags: Vec<(String, Option<String>)>,
}

impl Args {
    fn parse(argv: &[String]) -> Self {
        let mut positional = Vec::new();
        let mut flags = Vec::new();
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(name) = a.strip_prefix("--") {
                let has_val = i + 1 < argv.len()
                    && !argv[i + 1].starts_with("--");
                if has_val && !is_bool_flag(name) {
                    flags.push((name.to_string(),
                                Some(argv[i + 1].clone())));
                    i += 2;
                } else {
                    flags.push((name.to_string(), None));
                    i += 1;
                }
            } else {
                positional.push(a.clone());
                i += 1;
            }
        }
        Self { positional, flags }
    }

    fn get(&self, name: &str) -> Option<&str> {
        self.flags.iter().rev()
            .find(|(k, _)| k == name)
            .and_then(|(_, v)| v.as_deref())
    }

    fn has(&self, name: &str) -> bool {
        self.flags.iter().any(|(k, _)| k == name)
    }

    fn get_usize(&self, name: &str, default: usize) -> Result<usize> {
        match self.get(name) {
            Some(v) => Ok(v.parse()?),
            None => Ok(default),
        }
    }
}

fn is_bool_flag(name: &str) -> bool {
    matches!(name, "plain" | "golden" | "help" | "version")
}

fn parse_net(args: &Args) -> Result<NetKind> {
    match args.get("net").unwrap_or("classifier") {
        "classifier" => Ok(NetKind::Classifier),
        "segmenter" => Ok(NetKind::Segmenter),
        other => bail!("unknown --net {other}"),
    }
}

fn parse_policy(args: &Args) -> Result<Policy> {
    let s = args.get("policy").unwrap_or("cbws");
    Policy::parse(s).ok_or_else(|| anyhow!("unknown policy {s}"))
}

fn main() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = Args::parse(&argv);
    if args.has("help") || argv.is_empty() {
        print!("{USAGE}");
        return Ok(());
    }
    if args.has("version") {
        println!("skydiver {}", env!("CARGO_PKG_VERSION"));
        return Ok(());
    }
    let artifacts = args.get("artifacts").map(PathBuf::from)
        .unwrap_or_else(skydiver::artifacts_dir);

    match args.positional.first().map(|s| s.as_str()) {
        Some("report") => report(&artifacts),
        Some("run") => run_serve(&artifacts, &args),
        Some("trace") => trace(&artifacts, &args),
        Some("experiment") => {
            let id = args.positional.get(1)
                .ok_or_else(|| anyhow!("experiment needs an id"))?;
            let mut ctx = ExperimentCtx::new(artifacts);
            ctx.frames = args.get_usize("frames", 0)?;
            ctx.golden = args.has("golden");
            experiment(&ctx, id)
        }
        Some(other) => bail!("unknown command {other}\n{USAGE}"),
        None => {
            print!("{USAGE}");
            Ok(())
        }
    }
}

fn report(artifacts: &PathBuf) -> Result<()> {
    let mut t = Table::new(
        format!("Artifacts in {}", artifacts.display()),
        &["variant", "layers", "T", "pad", "metric", "params"]);
    for name in ["classifier_aprc", "classifier_plain", "segmenter_aprc",
                 "segmenter_plain"] {
        match NetworkWeights::load(artifacts, name) {
            Ok(net) => {
                t.row(&[name.into(), net.num_layers().to_string(),
                        net.meta.timesteps.to_string(),
                        net.meta.pad.to_string(),
                        net.meta.snn_metric
                            .map(|m| format!("{m:.4}")).unwrap_or_default(),
                        net.meta.total_floats.to_string()]);
            }
            Err(e) => t.row(&[name.into(), format!("missing: {e}"),
                              String::new(), String::new(), String::new(),
                              String::new()]),
        }
    }
    t.print();
    Ok(())
}

fn make_frames(kind: NetKind, n: usize) -> Vec<Vec<u8>> {
    match kind {
        NetKind::Classifier => {
            let (imgs, _) = skydiver::data::gen_digits(0x5E12E, n);
            imgs.chunks(28 * 28).map(|c| c.to_vec()).collect()
        }
        NetKind::Segmenter => {
            let (imgs, _) = skydiver::data::gen_road_scenes(0x5E12E, n);
            let (h, w) = (skydiver::data::ROAD_H, skydiver::data::ROAD_W);
            imgs.chunks(h * w * 3)
                .map(|img| {
                    let mut chw = vec![0u8; 3 * h * w];
                    for y in 0..h {
                        for x in 0..w {
                            for c in 0..3 {
                                chw[c * h * w + y * w + x] =
                                    img[(y * w + x) * 3 + c];
                            }
                        }
                    }
                    chw
                })
                .collect()
        }
    }
}

fn run_serve(artifacts: &PathBuf, args: &Args) -> Result<()> {
    let kind = parse_net(args)?;
    let aprc = !args.has("plain");
    let policy = parse_policy(args)?;
    let frames = args.get_usize("frames", 32)?;
    let workers = args.get_usize("workers", 2)?;
    let golden = args.has("golden");
    let dispatch = match args.get("dispatch") {
        None => DispatchMode::WorkQueue,
        Some(s) => DispatchMode::parse(s)
            .ok_or_else(|| anyhow!("unknown --dispatch {s}"))?,
    };

    let wcfg = WorkerConfig {
        artifacts: artifacts.clone(),
        kind,
        aprc,
        policy,
        arch: ArchConfig::default(),
        energy: EnergyModel::default(),
        use_runtime: golden,
        timesteps: None,
        sweep_threads: args.get_usize("sweep-threads", 1)?,
    };
    let scfg = ServiceConfig {
        workers,
        batch_max: args.get_usize("batch-max", 8)?,
        queue_cap: args.get_usize("queue-cap", 256)?,
        batch_wait: Duration::from_millis(2),
        dispatch,
    };
    println!("serving {} frames of {} ({}) with {} workers, policy {:?}, \
              dispatch {:?}",
             frames, wcfg.variant_name(),
             if golden { "golden/PJRT" } else { "functional" },
             workers, policy, dispatch);
    let service = Service::start(scfg, wcfg)?;
    for (i, px) in make_frames(kind, frames).into_iter().enumerate() {
        service.submit(i as u64, px)?;
    }
    let (_, rep) = service.collect(frames, skydiver::CLOCK_HZ)?;
    service.shutdown()?;

    let mut t = Table::new("Serving report", &["metric", "value"]);
    t.row(&["frames".into(), rep.frames.to_string()]);
    t.row(&["host throughput (fps)".into(),
            format!("{:.1}", rep.served_fps)]);
    t.row(&["latency p50/p95/p99 (us)".into(),
            format!("{}/{}/{}", rep.p50_us, rep.p95_us, rep.p99_us)]);
    t.row(&["sim cycles/frame".into(),
            format!("{:.0}", rep.mean_sim_cycles)]);
    t.row(&["sim accelerator FPS".into(), format!("{:.1}", rep.sim_fps)]);
    t.row(&["sim energy/frame (uJ)".into(),
            format!("{:.2}", rep.mean_energy_uj)]);
    t.row(&["per-worker frames".into(), format!("{:?}", rep.per_worker)]);
    t.row(&["per-worker busy (us)".into(),
            format!("{:?}", rep.per_worker_busy_us)]);
    t.row(&["host balance ratio".into(),
            format!("{:.2}%", 100.0 * rep.host_balance_ratio)]);
    t.row(&["queue depth max/cap".into(),
            format!("{}/{}", rep.queue_max_depth, rep.queue_capacity)]);
    if !rep.worker_failures.is_empty() {
        t.row(&["worker failures".into(),
                rep.worker_failures.join("; ")]);
    }
    t.print();
    Ok(())
}

fn trace(artifacts: &PathBuf, args: &Args) -> Result<()> {
    let kind = match args.get("net").unwrap_or("segmenter") {
        "classifier" => NetKind::Classifier,
        "segmenter" => NetKind::Segmenter,
        other => bail!("unknown --net {other}"),
    };
    let aprc = !args.has("plain");
    let policy = parse_policy(args)?;
    let golden = args.has("golden");
    let name = kind.variant_name(aprc);
    let net = NetworkWeights::load(artifacts, name)?;
    let rates = skydiver::coordinator::default_input_rates(&net);
    let predictor =
        skydiver::schedule::AprcPredictor::from_network(&net, &rates);
    let scheduler = policy.build();
    let arch = ArchConfig::default();
    let sim = skydiver::sim::Simulator::new(arch, &net, scheduler.as_ref(),
                                            &predictor);

    let pixels = make_frames(kind, 1).remove(0);
    let (c, h, w) = (net.meta.in_shape[0], net.meta.in_shape[1],
                     net.meta.in_shape[2]);
    let inputs = skydiver::snn::encode_phased_u8(&pixels, c, h, w,
                                                 net.meta.timesteps);
    let mut ctx = ExperimentCtx::new(artifacts.clone());
    ctx.golden = golden;
    let trace = experiments::trace_for(&ctx, &net, &inputs)?;
    let rep = sim.run_frame(&inputs, &trace)?;

    let mut t = Table::new(
        format!("Trace: {name} (policy {policy:?})"),
        &["layer", "cycles", "events", "synops", "balance(w)"]);
    for l in &rep.layers {
        t.row(&[format!("L{}", l.layer + 1), l.cycles.to_string(),
                l.events.to_string(), l.synops.to_string(),
                format!("{:.2}%", 100.0 * l.balance_weighted)]);
    }
    t.row(&["total".into(), rep.total_cycles.to_string(),
            rep.events.to_string(), rep.synops.to_string(),
            format!("{:.2}%",
                    100.0 * rep.balance_weighted(arch.n_spes))]);
    t.print();
    let e = EnergyModel::default().frame_energy(&rep, arch.clock_hz);
    println!("fps={:.1} gsops={:.3} energy={:.1}uJ power={:.2}W",
             rep.fps(arch.clock_hz), rep.gsops(arch.clock_hz),
             e.total_j * 1e6, e.mean_w);
    Ok(())
}

fn experiment(ctx: &ExperimentCtx, id: &str) -> Result<()> {
    match id {
        "fig2" => { experiments::fig2::run(ctx)?; }
        "fig4c" => { experiments::fig4c::run()?; }
        "fig6" => { experiments::fig6::run(ctx)?; }
        "fig7" => { experiments::fig7::run(ctx)?; }
        "table1" => { experiments::table1::run(ctx)?; }
        "table2" => { experiments::table2::run(&ArchConfig::default())?; }
        "gains" => { experiments::gains::run(ctx)?; }
        "accuracy" => { experiments::accuracy::run(ctx)?; }
        "ablation" => { experiments::ablation::run(ctx)?; }
        "timesteps" => { experiments::ablation::timestep_sweep(ctx)?; }
        "all" => {
            for id in ["fig4c", "table2", "fig2", "fig6", "fig7", "gains",
                       "table1", "accuracy", "ablation"] {
                println!("\n########## experiment {id} ##########");
                experiment(ctx, id)?;
            }
        }
        other => bail!("unknown experiment {other}"),
    }
    Ok(())
}
