//! Skydiver CLI — leader entrypoint (in-crate arg parsing; offline build).
//!
//! ```bash
//! skydiver report                      # artifact inventory + metrics
//! skydiver run --net classifier       # serve frames end-to-end
//! skydiver serve --addr 127.0.0.1:0   # TCP gateway over the coordinator
//! skydiver serve --model classifier --model segmenter   # multi-model
//! skydiver loadgen --addr HOST:PORT --model segmenter   # drive one model
//! skydiver trace --net segmenter      # one-frame per-layer trace
//! skydiver experiment fig7            # regenerate a paper artifact
//! skydiver experiment all
//! ```

use std::path::{Path, PathBuf};
use std::time::Duration;

use anyhow::{anyhow, bail, ensure, Result};

use skydiver::coordinator::{AutoscaleConfig, DispatchMode, FrameSpec,
                            ModelRegistry, ModelSpec, Policy, Priority,
                            Service, ServiceConfig, ServingReport,
                            WorkerConfig};
use skydiver::data::SplitMix64;
use skydiver::experiments::{self, ExperimentCtx};
use skydiver::metrics::Table;
use skydiver::power::EnergyModel;
use skydiver::cluster::{FaultPlan, FaultProxy, Router, RouterConfig,
                        RouterReport};
use skydiver::server::{Client, Gateway, GatewayConfig, GatewayReport,
                       LoadGenConfig, TrafficMode};
use skydiver::sim::ArchConfig;
use skydiver::snn::{NetKind, NetworkWeights};

const USAGE: &str = "\
skydiver — Skydiver (TCAD'22) reproduction

USAGE:
  skydiver [--artifacts DIR] <command> [options]

COMMANDS:
  report                           artifact inventory + eval metrics
  run        [--net classifier|segmenter | --model NAME[=KIND]]
             [--plain] [--policy P] [--frames N] [--workers N]
             [--golden] [--dispatch queue|cost|rr] [--queue-cap N]
             [--batch-max N] [--batch-wait-ms N] [--queue-cost-cap N]
             [--sweep-threads N] [--temporal-kernels on|off]
  serve      [--addr HOST:PORT] [--max-conns N] [--port-file PATH]
             [--reactor-shards N] [--drain-ms N]
             [--net ... | --model NAME[=KIND] (repeatable)]
             [--plain] [--policy P] [--golden] [--workers N]
             [--workers-min N] [--workers-max N]
             [--autoscale-tick-ms N] [--autoscale-slo-us N]
             [--degrade off|reduce-t] [--degrade-floor-t N]
             [--dispatch queue|cost|rr] [--queue-cap N] [--batch-max N]
             [--batch-wait-ms N] [--queue-cost-cap N]
             [--sweep-threads N] [--temporal-kernels on|off]
             TCP gateway; --addr defaults to 127.0.0.1:7878, port 0
             picks an ephemeral port (written to --port-file).
             --temporal-kernels (default on) serves functional frames
             through the bit-parallel time-major kernels — outputs are
             bit-identical to the per-timestep path, so 'off' exists
             only for A/B timing; the golden path ignores it.
             --reactor-shards sets the event-loop shard count
             (0 = auto: one per core, max 8); connections are
             multiplexed over the shards, so thread count stays
             O(shards + models) no matter how many clients connect.
             Repeat --model to mount several models behind one port
             (the first is the default model v1 clients route to),
             e.g. --model classifier --model segmenter or
             --model fast=classifier.
             --dispatch cost enables request-level APRC: predicted-
             cost-balanced batches + cost-denominated shedding
             (--queue-cost-cap, in cost units; default queue-cap x
             10000; 0 = uncapped). --batch-wait-ms sets the batch
             grouping window (default 2). --drain-ms bounds the
             shutdown drain (default 10000): requests still queued
             when it expires fail with SHUTTING_DOWN instead of
             wedging shutdown behind a stuck worker.
             --workers-max N (> --workers) enables per-model pool
             autoscaling: sustained queue pressure (or a p99 over
             --autoscale-slo-us, when set) doubles the pool toward N;
             sustained quiet decays it one worker at a time back to
             --workers-min (default: the initial --workers). The
             control loop ticks every --autoscale-tick-ms (default
             100). --degrade reduce-t serves reduced-timestep
             inference instead of BUSY once a queue passes half full
             (never below --degrade-floor-t; default 0 = T/4);
             responses carry a degrade notice with the served T and
             energy, so work is degraded, not lost.
  route      --backend HOST:PORT (repeatable) [--addr HOST:PORT]
             [--heartbeat-ms N] [--eject-after N] [--readmit-after N]
             [--retry-max N] [--max-conns N] [--port-file PATH]
             cluster front router: places each request on the live
             backend that mounts the target model with the least
             reported queue cost (heartbeat load reports), ejects a
             backend after N consecutive heartbeat failures, fails
             its in-flight requests over to survivors (capped
             jittered retry, --retry-max attempts), and readmits it
             after N consecutive successful probes. --addr defaults
             to 127.0.0.1:7979; stops on a wire Shutdown like serve.
  metrics    --addr HOST:PORT
             fetch and print Prometheus-style metrics from a gateway
             or router
  loadgen    --addr HOST:PORT [--model NAME] [--conns N] [--frames N]
             [--window N] [--traffic mixed|skewed]
             [--priority high|normal|low] [--spikes]
             [--no-retry] [--shutdown]
             drive a gateway; --model targets a mounted model (default:
             the server's default model); --traffic skewed sends
             heavy-tailed input spike densities (the cost-aware
             dispatch scenario); --priority tags every request with a
             wire priority class (default: none sent, the server
             assumes normal); --shutdown sends a drain request
             after
  synth      [--out DIR] [--side N] [--net classifier|segmenter|both]
             write synthetic artifacts (serve/test without
             `make artifacts`)
  trace      [--net classifier|segmenter] [--plain] [--policy P] [--golden]
             one-frame per-layer simulator trace; OR, with --addr:
  trace      --addr HOST:PORT [--chrome] [--out FILE]
             fetch the flight-recorder span dump from a live gateway
             or router started with --trace (or SKYDIVER_TRACE=1).
             Default output is a human span tree; --chrome emits
             Chrome trace-event JSON (load in chrome://tracing or
             Perfetto); --out writes to a file instead of stdout.
  experiment <id> [--frames N] [--golden]
             ids: fig2 fig4c fig6 fig7 table1 table2 gains accuracy
                  ablation timesteps all

GLOBAL:
  --log-level error|warn|info|debug   stderr diagnostics (default
             warn; SKYDIVER_LOG equivalent)
  --trace    enable span tracing in serve/route (SKYDIVER_TRACE=1
             equivalent); dump with `skydiver trace --addr ...`

POLICIES: contiguous round_robin random sparten cbws (default cbws)
";

/// Every flag the CLI understands, with whether it takes a value.
/// `Args::parse` rejects anything not listed — a typo must be an
/// error, not a silently applied default.
const FLAG_SPECS: &[(&str, bool)] = &[
    ("artifacts", true),
    ("net", true),
    ("model", true),
    ("policy", true),
    ("frames", true),
    ("workers", true),
    ("workers-min", true),
    ("workers-max", true),
    ("autoscale-tick-ms", true),
    ("autoscale-slo-us", true),
    ("degrade", true),
    ("degrade-floor-t", true),
    ("priority", true),
    ("dispatch", true),
    ("queue-cap", true),
    ("batch-max", true),
    ("batch-wait-ms", true),
    ("queue-cost-cap", true),
    ("traffic", true),
    ("sweep-threads", true),
    ("temporal-kernels", true),
    ("addr", true),
    ("max-conns", true),
    ("reactor-shards", true),
    ("port-file", true),
    ("drain-ms", true),
    ("inject-faults", true),
    ("backend", true),
    ("heartbeat-ms", true),
    ("eject-after", true),
    ("readmit-after", true),
    ("retry-max", true),
    ("conns", true),
    ("window", true),
    ("out", true),
    ("side", true),
    ("log-level", true),
    ("plain", false),
    ("golden", false),
    ("trace", false),
    ("chrome", false),
    ("spikes", false),
    ("no-retry", false),
    ("shutdown", false),
    ("help", false),
    ("version", false),
];

fn flag_spec(name: &str) -> Option<bool> {
    FLAG_SPECS.iter()
        .find(|(n, _)| *n == name)
        .map(|&(_, takes_value)| takes_value)
}

/// Two-row Levenshtein distance for typo suggestions.
fn edit_distance(a: &str, b: &str) -> usize {
    let a: Vec<char> = a.chars().collect();
    let b: Vec<char> = b.chars().collect();
    let mut prev: Vec<usize> = (0..=b.len()).collect();
    let mut cur = vec![0usize; b.len() + 1];
    for i in 1..=a.len() {
        cur[0] = i;
        for j in 1..=b.len() {
            let cost = usize::from(a[i - 1] != b[j - 1]);
            cur[j] = (prev[j] + 1)
                .min(cur[j - 1] + 1)
                .min(prev[j - 1] + cost);
        }
        std::mem::swap(&mut prev, &mut cur);
    }
    prev[b.len()]
}

/// Closest known flag within edit distance 2, if any.
fn suggest(name: &str) -> Option<&'static str> {
    FLAG_SPECS.iter()
        .map(|&(n, _)| (edit_distance(name, n), n))
        .min()
        .filter(|&(d, _)| d <= 2)
        .map(|(_, n)| n)
}

/// Tiny strict flag parser: `--key value` and boolean `--key`.
/// Unknown flags and missing values are errors (with a usage hint),
/// never silently ignored. Valued flags may repeat (`--model a
/// --model b`); `get` returns the last occurrence, `get_all` all of
/// them in order.
struct Args {
    positional: Vec<String>,
    flags: Vec<(String, Option<String>)>,
}

impl Args {
    fn parse(argv: &[String]) -> Result<Self> {
        let mut positional = Vec::new();
        let mut flags = Vec::new();
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(name) = a.strip_prefix("--") {
                let takes_value = match flag_spec(name) {
                    Some(tv) => tv,
                    None => {
                        let hint = match suggest(name) {
                            Some(s) => format!(" (did you mean --{s}?)"),
                            None => String::new(),
                        };
                        bail!("unknown flag --{name}{hint}\n\
                               run `skydiver --help` for usage");
                    }
                };
                if takes_value {
                    let val = argv.get(i + 1)
                        .filter(|v| !v.starts_with("--"))
                        .ok_or_else(|| anyhow!(
                            "flag --{name} requires a value\n\
                             run `skydiver --help` for usage"))?;
                    flags.push((name.to_string(), Some(val.clone())));
                    i += 2;
                } else {
                    flags.push((name.to_string(), None));
                    i += 1;
                }
            } else {
                positional.push(a.clone());
                i += 1;
            }
        }
        Ok(Self { positional, flags })
    }

    fn get(&self, name: &str) -> Option<&str> {
        self.flags.iter().rev()
            .find(|(k, _)| k == name)
            .and_then(|(_, v)| v.as_deref())
    }

    /// Every occurrence of a repeatable valued flag, in order.
    fn get_all(&self, name: &str) -> Vec<&str> {
        self.flags.iter()
            .filter(|(k, _)| k == name)
            .filter_map(|(_, v)| v.as_deref())
            .collect()
    }

    fn has(&self, name: &str) -> bool {
        self.flags.iter().any(|(k, _)| k == name)
    }

    fn get_usize(&self, name: &str, default: usize) -> Result<usize> {
        match self.get(name) {
            Some(v) => v.parse().map_err(|_| anyhow!(
                "flag --{name}: '{v}' is not a non-negative integer")),
            None => Ok(default),
        }
    }
}

fn parse_net(args: &Args) -> Result<NetKind> {
    let s = args.get("net").unwrap_or("classifier");
    NetKind::parse(s)
        .ok_or_else(|| anyhow!("unknown --net {s} \
                                (classifier|segmenter)"))
}

fn parse_policy(args: &Args) -> Result<Policy> {
    let s = args.get("policy").unwrap_or("cbws");
    Policy::parse(s).ok_or_else(|| anyhow!("unknown policy {s}"))
}

/// A `--model` spec: `NAME` (a net kind, mounted under its own name)
/// or `NAME=KIND` (a custom registry name over a net kind) — e.g.
/// `segmenter`, `fast=classifier`.
fn parse_model_spec(s: &str) -> Result<(String, NetKind)> {
    let (name, kind_str) = match s.split_once('=') {
        Some((n, k)) => (n, k),
        None => (s, s),
    };
    ensure!(!name.is_empty(), "model spec '{s}' has an empty name");
    let kind = NetKind::parse(kind_str).ok_or_else(|| anyhow!(
        "model spec '{s}': unknown net kind '{kind_str}' \
         (classifier|segmenter)"))?;
    Ok((name.to_string(), kind))
}

fn main() -> Result<()> {
    skydiver::obs::init_from_env();
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = Args::parse(&argv)?;
    if let Some(v) = args.get("log-level") {
        let l = skydiver::obs::log::parse_level(v)
            .ok_or_else(|| anyhow!("unknown --log-level {v} \
                                    (error|warn|info|debug)"))?;
        skydiver::obs::log::set_level(l);
    }
    if args.has("trace") {
        skydiver::obs::trace::set_enabled(true);
    }
    if args.has("help") || argv.is_empty() {
        print!("{USAGE}");
        return Ok(());
    }
    if args.has("version") {
        println!("skydiver {}", env!("CARGO_PKG_VERSION"));
        return Ok(());
    }
    let artifacts = args.get("artifacts").map(PathBuf::from)
        .unwrap_or_else(skydiver::artifacts_dir);

    match args.positional.first().map(|s| s.as_str()) {
        Some("report") => report(&artifacts),
        Some("run") => run_serve(&artifacts, &args),
        Some("serve") => serve_cmd(&artifacts, &args),
        Some("route") => route_cmd(&args),
        Some("metrics") => metrics_cmd(&args),
        Some("loadgen") => loadgen_cmd(&args),
        Some("synth") => synth_cmd(&args),
        Some("trace") => trace(&artifacts, &args),
        Some("experiment") => {
            let id = args.positional.get(1)
                .ok_or_else(|| anyhow!("experiment needs an id"))?;
            let mut ctx = ExperimentCtx::new(artifacts);
            ctx.frames = args.get_usize("frames", 0)?;
            ctx.golden = args.has("golden");
            experiment(&ctx, id)
        }
        Some(other) => bail!("unknown command {other}\n{USAGE}"),
        None => {
            print!("{USAGE}");
            Ok(())
        }
    }
}

fn report(artifacts: &Path) -> Result<()> {
    let mut t = Table::new(
        format!("Artifacts in {}", artifacts.display()),
        &["variant", "layers", "T", "pad", "metric", "params"]);
    for name in ["classifier_aprc", "classifier_plain", "segmenter_aprc",
                 "segmenter_plain"] {
        match NetworkWeights::load(artifacts, name) {
            Ok(net) => {
                t.row(&[name.into(), net.num_layers().to_string(),
                        net.meta.timesteps.to_string(),
                        net.meta.pad.to_string(),
                        net.meta.snn_metric
                            .map(|m| format!("{m:.4}")).unwrap_or_default(),
                        net.meta.total_floats.to_string()]);
            }
            Err(e) => t.row(&[name.into(), format!("missing: {e}"),
                              String::new(), String::new(), String::new(),
                              String::new()]),
        }
    }
    t.print();
    Ok(())
}

/// Deterministic frames for an arbitrary `(c, h, w)` contract: the
/// canonical datasets when the shape matches them, otherwise a
/// synthetic mixed workload (every 4th frame dense-random, the rest
/// sparse) — so `run` works against synthetic artifacts of any shape,
/// not just the trained 28x28 / road-scene nets.
fn make_frames(c: usize, h: usize, w: usize, n: usize) -> Vec<Vec<u8>> {
    if (c, h, w) == (1, skydiver::data::DIGIT_H, skydiver::data::DIGIT_W)
    {
        let (imgs, _) = skydiver::data::gen_digits(0x5E12E, n);
        return imgs.chunks(h * w).map(|ch| ch.to_vec()).collect();
    }
    if (c, h, w) == (3, skydiver::data::ROAD_H, skydiver::data::ROAD_W) {
        let (imgs, _) = skydiver::data::gen_road_scenes(0x5E12E, n);
        return imgs.chunks(h * w * 3)
            .map(|img| {
                let mut chw = vec![0u8; 3 * h * w];
                for y in 0..h {
                    for x in 0..w {
                        for ch in 0..3 {
                            chw[ch * h * w + y * w + x] =
                                img[(y * w + x) * 3 + ch];
                        }
                    }
                }
                chw
            })
            .collect();
    }
    (0..n as u64)
        .map(|id| {
            let mut rng =
                SplitMix64::new(0x5E12E ^ id.wrapping_mul(0x9E37));
            let dense = id % 4 == 0;
            (0..c * h * w)
                .map(|_| {
                    if dense || rng.next_below(100) < 10 {
                        rng.next_below(256) as u8
                    } else {
                        0
                    }
                })
                .collect()
        })
        .collect()
}

fn make_frames_for(spec: &FrameSpec, n: usize) -> Vec<Vec<u8>> {
    make_frames(spec.c, spec.h, spec.w, n)
}

/// The gateway-side autoscale knobs: `--workers-min` defaults to the
/// initial `--workers` size (the decay target after a burst), and
/// autoscaling engages only when `--workers-max` raises the ceiling
/// above it. `--autoscale-slo-us 0` (the default) scales on queue
/// pressure alone.
fn autoscale_cfg(args: &Args) -> Result<AutoscaleConfig> {
    let workers = args.get_usize("workers", 2)?;
    let min = args.get_usize("workers-min", workers)?;
    let max = args.get_usize("workers-max", 0)?;
    ensure!(min >= 1, "--workers-min must be at least 1");
    ensure!(max == 0 || max >= min,
            "--workers-max ({max}) must be at least --workers-min \
             ({min})");
    Ok(AutoscaleConfig {
        min,
        max,
        tick: Duration::from_millis(
            args.get_usize("autoscale-tick-ms", 100)? as u64),
        p99_slo_us: args.get_usize("autoscale-slo-us", 0)? as u64,
        ..AutoscaleConfig::default()
    })
}

/// The `--degrade` policy: `(reduce_t, floor)`; `off` keeps the
/// BUSY-shedding baseline behaviour.
fn degrade_cfg(args: &Args) -> Result<(bool, usize)> {
    let reduce_t = match args.get("degrade").unwrap_or("off") {
        "off" => false,
        "reduce-t" => true,
        other => bail!("unknown --degrade {other} (off|reduce-t)"),
    };
    Ok((reduce_t, args.get_usize("degrade-floor-t", 0)?))
}

/// The coordinator-side knobs shared by every mounted model.
fn service_cfg(args: &Args) -> Result<ServiceConfig> {
    let dispatch = match args.get("dispatch") {
        None => DispatchMode::WorkQueue,
        Some(s) => DispatchMode::parse(s)
            .ok_or_else(|| anyhow!("unknown --dispatch {s} \
                                    (queue|cost|rr)"))?,
    };
    let cost_cap = match args.get("queue-cost-cap") {
        None => None,
        Some(v) => Some(v.parse::<u64>().map_err(|_| anyhow!(
            "flag --queue-cost-cap: '{v}' is not a non-negative \
             integer"))?),
    };
    Ok(ServiceConfig {
        workers: args.get_usize("workers", 2)?,
        workers_max: args.get_usize("workers-max", 0)?,
        batch_max: args.get_usize("batch-max", 8)?,
        queue_cap: args.get_usize("queue-cap", 256)?,
        batch_wait: Duration::from_millis(
            args.get_usize("batch-wait-ms", 2)? as u64),
        dispatch,
        cost_cap,
    })
}

/// The worker pipeline knobs for one net kind.
fn worker_cfg(artifacts: &Path, args: &Args, kind: NetKind)
              -> Result<WorkerConfig> {
    let temporal = match args.get("temporal-kernels").unwrap_or("on") {
        "on" => true,
        "off" => false,
        other => bail!("unknown --temporal-kernels {other} (on|off)"),
    };
    Ok(WorkerConfig {
        artifacts: artifacts.to_path_buf(),
        kind,
        aprc: !args.has("plain"),
        policy: parse_policy(args)?,
        arch: ArchConfig::default(),
        energy: EnergyModel::default(),
        use_runtime: args.has("golden"),
        timesteps: None,
        sweep_threads: args.get_usize("sweep-threads", 1)?,
        temporal,
    })
}

/// The models to mount: every `--model NAME[=KIND]` in order (the
/// first is the default model), or the single `--net` when no
/// `--model` is given.
fn model_specs(artifacts: &Path, args: &Args) -> Result<Vec<ModelSpec>> {
    let scfg = service_cfg(args)?;
    let flags = args.get_all("model");
    if flags.is_empty() {
        let kind = parse_net(args)?;
        return Ok(vec![ModelSpec {
            name: kind.as_str().to_string(),
            scfg,
            wcfg: worker_cfg(artifacts, args, kind)?,
        }]);
    }
    flags.iter()
        .map(|s| {
            let (name, kind) = parse_model_spec(s)?;
            Ok(ModelSpec {
                name,
                scfg: scfg.clone(),
                wcfg: worker_cfg(artifacts, args, kind)?,
            })
        })
        .collect()
}

fn print_serving_report(rep: &ServingReport) {
    let mut t = Table::new("Serving report", &["metric", "value"]);
    t.row(&["frames".into(), rep.frames.to_string()]);
    t.row(&["host throughput (fps)".into(),
            format!("{:.1}", rep.served_fps)]);
    t.row(&["latency p50/p95/p99 (us)".into(),
            format!("{}/{}/{}", rep.p50_us, rep.p95_us, rep.p99_us)]);
    t.row(&["sim cycles/frame".into(),
            format!("{:.0}", rep.mean_sim_cycles)]);
    t.row(&["sim accelerator FPS".into(), format!("{:.1}", rep.sim_fps)]);
    t.row(&["sim energy/frame (uJ)".into(),
            format!("{:.2}", rep.mean_energy_uj)]);
    t.row(&["per-worker frames".into(), format!("{:?}", rep.per_worker)]);
    t.row(&["per-worker busy (us)".into(),
            format!("{:?}", rep.per_worker_busy_us)]);
    t.row(&["host balance ratio".into(),
            format!("{:.2}%", 100.0 * rep.host_balance_ratio)]);
    t.row(&["cost balance ratio".into(),
            format!("{:.2}%", 100.0 * rep.cost_balance_ratio)]);
    t.row(&["mean predicted cost".into(),
            format!("{:.0}", rep.mean_predicted_cost)]);
    t.row(&["cost calibration err".into(),
            format!("{:.1}%", 100.0 * rep.cost_calibration_error)]);
    t.row(&["queue depth max/cap".into(),
            format!("{}/{}", rep.queue_max_depth, rep.queue_capacity)]);
    if !rep.worker_failures.is_empty() {
        t.row(&["worker failures".into(),
                rep.worker_failures.join("; ")]);
    }
    t.print();
}

fn run_serve(artifacts: &Path, args: &Args) -> Result<()> {
    // `run` is the in-process single-model path; `--model NAME[=KIND]`
    // is accepted as an alias for picking the net.
    let kind = match args.get("model") {
        Some(spec) => parse_model_spec(spec)?.1,
        None => parse_net(args)?,
    };
    let wcfg = worker_cfg(artifacts, args, kind)?;
    let scfg = service_cfg(args)?;
    let frames = args.get_usize("frames", 32)?;
    println!("serving {} frames of {} ({}) with {} workers, policy {:?}, \
              dispatch {:?}",
             frames, wcfg.variant_name(),
             if wcfg.use_runtime { "golden/PJRT" } else { "functional" },
             scfg.workers, wcfg.policy, scfg.dispatch);
    let service = Service::start(scfg, wcfg)?;
    let spec = *service.frame_spec();
    for (i, px) in make_frames_for(&spec, frames).into_iter().enumerate()
    {
        service.submit(i as u64, px)?;
    }
    let (_, rep) = service.collect(frames, skydiver::CLOCK_HZ)?;
    service.shutdown()?;
    print_serving_report(&rep);
    Ok(())
}

/// `skydiver serve`: the TCP gateway. Mounts every `--model` (or the
/// single `--net`) behind one port and blocks until a client sends a
/// `Shutdown` frame (e.g. `skydiver loadgen --shutdown`), then drains
/// and prints the final per-model serving reports.
fn serve_cmd(artifacts: &Path, args: &Args) -> Result<()> {
    let specs = model_specs(artifacts, args)?;
    let requested_addr =
        args.get("addr").unwrap_or("127.0.0.1:7878").to_string();
    // Undocumented chaos knob: interpose a deterministic
    // fault-injection proxy (cluster::faults) between clients and
    // the gateway. The gateway binds an ephemeral port; the proxy
    // takes the requested address, so clients (and --port-file
    // readers) see the faulty path.
    let fault_plan = match args.get("inject-faults") {
        Some(spec) => Some(FaultPlan::parse(spec)?),
        None => None,
    };
    let autoscale = autoscale_cfg(args)?;
    let (degrade_reduce_t, degrade_floor_t) = degrade_cfg(args)?;
    let gcfg = GatewayConfig {
        addr: if fault_plan.is_some() {
            "127.0.0.1:0".to_string()
        } else {
            requested_addr.clone()
        },
        max_conns: args.get_usize("max-conns", 64)?,
        drain_timeout: Duration::from_millis(
            args.get_usize("drain-ms", 10_000)? as u64),
        reactor_shards: args.get_usize("reactor-shards", 0)?,
        autoscale,
        degrade_reduce_t,
        degrade_floor_t,
        ..GatewayConfig::default()
    };
    let names: Vec<String> =
        specs.iter().map(|s| {
            format!("{} ({})", s.name, s.wcfg.variant_name())
        }).collect();
    println!("starting gateway with {} model(s): {} — {} worker(s) \
              and queue cap {} each",
             specs.len(), names.join(", "),
             specs[0].scfg.workers, specs[0].scfg.queue_cap);
    if gcfg.autoscale.active() {
        println!("autoscale: {}..{} workers per model, tick {:?}, \
                  p99 SLO {}",
                 gcfg.autoscale.min, gcfg.autoscale.max,
                 gcfg.autoscale.tick,
                 if gcfg.autoscale.p99_slo_us == 0 {
                     "off".to_string()
                 } else {
                     format!("{}us", gcfg.autoscale.p99_slo_us)
                 });
    }
    if gcfg.degrade_reduce_t {
        println!("degradation: reduce-T under overload (floor {})",
                 if gcfg.degrade_floor_t == 0 {
                     "auto T/4".to_string()
                 } else {
                     gcfg.degrade_floor_t.to_string()
                 });
    }
    let registry = ModelRegistry::start(specs)?;
    println!("default model: {}", registry.default_name());
    let gw = Gateway::start(gcfg, registry)?;
    let addr = gw.local_addr();
    let proxy = match &fault_plan {
        Some(plan) => {
            let p = FaultProxy::start(&requested_addr,
                                      &addr.to_string(),
                                      plan.clone())?;
            println!("fault injection: {} -> {addr} ({plan:?})",
                     p.addr());
            Some(p)
        }
        None => None,
    };
    let public_addr = proxy.as_ref()
        .map(|p| p.addr().to_string())
        .unwrap_or_else(|| addr.to_string());
    println!("listening on {public_addr} ({} reactor shard(s))",
             gw.shard_count());
    println!("stop with: skydiver loadgen --addr {public_addr} \
              --frames 0 --shutdown");
    if let Some(pf) = args.get("port-file") {
        std::fs::write(pf, &public_addr)?;
    }
    let report = gw.wait()?;
    drop(proxy);
    print_gateway_report(&report);
    Ok(())
}

/// `skydiver route`: the cluster front router. Fans client requests
/// out to health-checked backend gateways and blocks until a wire
/// `Shutdown` (backends keep running — they have their own
/// lifecycle).
fn route_cmd(args: &Args) -> Result<()> {
    let backends: Vec<String> = args.get_all("backend")
        .iter().map(|s| s.to_string()).collect();
    ensure!(!backends.is_empty(),
            "route needs at least one --backend HOST:PORT");
    let cfg = RouterConfig {
        addr: args.get("addr").unwrap_or("127.0.0.1:7979").to_string(),
        backends,
        heartbeat_every: Duration::from_millis(
            args.get_usize("heartbeat-ms", 200)? as u64),
        eject_after: args.get_usize("eject-after", 3)? as u32,
        readmit_after: args.get_usize("readmit-after", 2)? as u32,
        retry_max: args.get_usize("retry-max", 8)? as u32,
        max_conns: args.get_usize("max-conns", 1024)?,
        ..RouterConfig::default()
    };
    println!("starting router over {} backend(s): {}",
             cfg.backends.len(), cfg.backends.join(", "));
    println!("heartbeat every {:?}, eject after {} failure(s), \
              readmit after {} probe(s), retry max {}",
             cfg.heartbeat_every, cfg.eject_after, cfg.readmit_after,
             cfg.retry_max);
    let router = Router::start(cfg)?;
    let addr = router.local_addr();
    println!("routing on {addr}");
    println!("stop with: skydiver loadgen --addr {addr} --frames 0 \
              --shutdown");
    if let Some(pf) = args.get("port-file") {
        std::fs::write(pf, addr.to_string())?;
    }
    let report = router.wait()?;
    print_router_report(&report);
    Ok(())
}

fn print_router_report(r: &RouterReport) {
    let mut t = Table::new("Router", &["metric", "value"]);
    t.row(&["requests".into(), r.requests.to_string()]);
    t.row(&["served".into(), r.served.to_string()]);
    t.row(&["busy (shed)".into(), r.busy.to_string()]);
    t.row(&["failed".into(), r.failed.to_string()]);
    t.row(&["retries".into(), r.retries.to_string()]);
    t.print();
    for b in &r.backends {
        println!("--- backend {}: {} | dispatched {} | ejections {} \
                  | readmissions {} | failovers {} | heartbeats \
                  ok/fail {}/{}",
                 b.addr,
                 if b.live { "live" } else { "ejected" },
                 b.dispatched, b.ejections, b.readmissions,
                 b.failovers, b.heartbeats_ok, b.heartbeat_failures);
    }
}

/// `skydiver metrics`: fetch and print the Prometheus exposition
/// from a gateway or router (scriptable health/monitoring hook).
fn metrics_cmd(args: &Args) -> Result<()> {
    let addr = args.get("addr")
        .ok_or_else(|| anyhow!("metrics needs --addr HOST:PORT"))?;
    let mut client = Client::connect(addr)?;
    print!("{}", client.metrics()?);
    Ok(())
}

fn print_gateway_report(report: &GatewayReport) {
    let c = &report.counters;
    let mut t = Table::new("Gateway", &["metric", "value"]);
    t.row(&["models mounted".into(), report.models.len().to_string()]);
    t.row(&["connections accepted/rejected".into(),
            format!("{}/{}", c.conns_accepted, c.conns_rejected)]);
    t.row(&["requests".into(), c.requests.to_string()]);
    t.row(&["served".into(), c.served.to_string()]);
    t.row(&["busy (shed)".into(), c.busy.to_string()]);
    t.row(&["bad request".into(), c.bad_request.to_string()]);
    t.row(&["shutting down".into(), c.shutting_down.to_string()]);
    t.row(&["internal errors".into(), c.internal.to_string()]);
    t.print();
    for m in &report.models {
        let mc = &m.counters;
        println!("--- model '{}': {} served, {} busy, {} bad request",
                 m.name, mc.served, mc.busy, mc.bad_request);
        print_serving_report(&m.serving);
    }
}

/// `skydiver loadgen`: drive a gateway over the wire and report
/// client-side throughput + latency.
fn loadgen_cmd(args: &Args) -> Result<()> {
    let addr = args.get("addr")
        .ok_or_else(|| anyhow!("loadgen needs --addr HOST:PORT"))?
        .to_string();
    let traffic = match args.get("traffic") {
        None => TrafficMode::Mixed,
        Some(s) => TrafficMode::parse(s)
            .ok_or_else(|| anyhow!("unknown --traffic {s} \
                                    (mixed|skewed)"))?,
    };
    let priority = match args.get("priority") {
        None => None,
        Some(s) => Some(Priority::parse(s).ok_or_else(|| anyhow!(
            "unknown --priority {s} (high|normal|low)"))? as u8),
    };
    let cfg = LoadGenConfig {
        addr: addr.clone(),
        model: args.get("model").unwrap_or("").to_string(),
        conns: args.get_usize("conns", 4)?,
        frames: args.get_usize("frames", 1000)?,
        window: args.get_usize("window", 8)?,
        spikes: args.has("spikes"),
        retry_busy: !args.has("no-retry"),
        traffic,
        priority,
        seed: 0x10AD,
    };
    let mut failed = 0u64;
    if cfg.frames > 0 {
        println!("loadgen: {} frames over {} connections (window {}, \
                  {} payload, {} traffic, model '{}') against {}",
                 cfg.frames, cfg.conns, cfg.window,
                 if cfg.spikes { "spike" } else { "pixel" },
                 cfg.traffic.as_str(),
                 if cfg.model.is_empty() { "<default>" } else {
                     &cfg.model
                 },
                 cfg.addr);
        let rep = skydiver::server::loadgen::run(&cfg)?;
        let mut t = Table::new("Loadgen report", &["metric", "value"]);
        t.row(&["sent (incl. retries)".into(), rep.sent.to_string()]);
        t.row(&["ok".into(), rep.ok.to_string()]);
        t.row(&["busy (shed)".into(), rep.busy.to_string()]);
        t.row(&["degraded (reduced T)".into(),
                rep.degraded.to_string()]);
        t.row(&["errors".into(), rep.errors.to_string()]);
        t.row(&["wall (s)".into(), format!("{:.3}", rep.wall_secs)]);
        t.row(&["throughput (fps)".into(), format!("{:.1}", rep.fps)]);
        t.row(&["latency p50/p95/p99 (us)".into(),
                format!("{}/{}/{}", rep.p50_us, rep.p95_us,
                        rep.p99_us)]);
        t.row(&["per-conn ok".into(), format!("{:?}", rep.per_conn_ok)]);
        t.print();
        failed = rep.errors;
    }
    if args.has("shutdown") {
        let mut client = Client::connect(&addr)?;
        client.shutdown_server()?;
        println!("server acknowledged shutdown");
    }
    if failed > 0 {
        bail!("{failed} frame(s) failed terminally");
    }
    Ok(())
}

/// `skydiver synth`: write synthetic artifacts so serve / tests / CI
/// run without the python `make artifacts` step. `--net both` writes
/// the classifier and the segmenter into one directory — the
/// multi-model smoke topology.
fn synth_cmd(args: &Args) -> Result<()> {
    let out = PathBuf::from(args.get("out").unwrap_or("artifacts"));
    let side = args.get_usize("side", 32)?;
    let net = args.get("net").unwrap_or("classifier");
    match net {
        "classifier" => {
            skydiver::data::write_synthetic_classifier(&out, side)?;
            println!("wrote synthetic classifier_aprc ({side}x{side}) \
                      to {}", out.display());
        }
        "segmenter" => {
            skydiver::data::write_synthetic_segmenter(&out, side)?;
            println!("wrote synthetic segmenter_aprc (3x{side}x{side}) \
                      to {}", out.display());
        }
        "both" => {
            skydiver::data::write_synthetic_classifier(&out, side)?;
            skydiver::data::write_synthetic_segmenter(&out, side)?;
            println!("wrote synthetic classifier_aprc ({side}x{side}) \
                      + segmenter_aprc (3x{side}x{side}) to {}",
                     out.display());
        }
        other => bail!("unknown --net {other} \
                        (classifier|segmenter|both)"),
    }
    Ok(())
}

/// `skydiver trace --addr HOST:PORT`: pull the flight-recorder span
/// dump off a live server. The default rendering is the terminal
/// span tree; `--chrome` passes the raw Chrome trace-event JSON
/// through (for chrome://tracing / Perfetto), `--out` redirects
/// either form to a file.
fn trace_fetch(addr: &str, args: &Args) -> Result<()> {
    let mut client = Client::connect(addr)?;
    let json = client.trace_dump()?;
    let text = if args.has("chrome") {
        json
    } else {
        skydiver::obs::recorder::render_tree(&json)?
    };
    match args.get("out") {
        Some(path) => {
            std::fs::write(path, &text)?;
            println!("wrote {} bytes to {path}", text.len());
        }
        None => print!("{text}"),
    }
    Ok(())
}

fn trace(artifacts: &Path, args: &Args) -> Result<()> {
    if let Some(addr) = args.get("addr") {
        return trace_fetch(addr, args);
    }
    let kind = match args.get("net") {
        None => NetKind::Segmenter,
        Some(s) => NetKind::parse(s)
            .ok_or_else(|| anyhow!("unknown --net {s}"))?,
    };
    let aprc = !args.has("plain");
    let policy = parse_policy(args)?;
    let golden = args.has("golden");
    let name = kind.variant_name(aprc);
    let net = NetworkWeights::load(artifacts, name)?;
    let rates = skydiver::coordinator::default_input_rates(&net);
    let predictor =
        skydiver::schedule::AprcPredictor::from_network(&net, &rates);
    let scheduler = policy.build();
    let arch = ArchConfig::default();
    let sim = skydiver::sim::Simulator::new(arch, &net, scheduler.as_ref(),
                                            &predictor);

    let (c, h, w) = (net.meta.in_shape[0], net.meta.in_shape[1],
                     net.meta.in_shape[2]);
    let pixels = make_frames(c, h, w, 1).remove(0);
    let inputs = skydiver::snn::encode_phased_u8(&pixels, c, h, w,
                                                 net.meta.timesteps);
    let mut ctx = ExperimentCtx::new(artifacts.to_path_buf());
    ctx.golden = golden;
    let trace = experiments::trace_for(&ctx, &net, &inputs)?;
    let rep = sim.run_frame(&inputs, &trace)?;

    let mut t = Table::new(
        format!("Trace: {name} (policy {policy:?})"),
        &["layer", "cycles", "events", "synops", "balance(w)"]);
    for l in &rep.layers {
        t.row(&[format!("L{}", l.layer + 1), l.cycles.to_string(),
                l.events.to_string(), l.synops.to_string(),
                format!("{:.2}%", 100.0 * l.balance_weighted)]);
    }
    t.row(&["total".into(), rep.total_cycles.to_string(),
            rep.events.to_string(), rep.synops.to_string(),
            format!("{:.2}%",
                    100.0 * rep.balance_weighted(arch.n_spes))]);
    t.print();
    let e = EnergyModel::default().frame_energy(&rep, arch.clock_hz);
    println!("fps={:.1} gsops={:.3} energy={:.1}uJ power={:.2}W",
             rep.fps(arch.clock_hz), rep.gsops(arch.clock_hz),
             e.total_j * 1e6, e.mean_w);
    Ok(())
}

fn experiment(ctx: &ExperimentCtx, id: &str) -> Result<()> {
    match id {
        "fig2" => { experiments::fig2::run(ctx)?; }
        "fig4c" => { experiments::fig4c::run()?; }
        "fig6" => { experiments::fig6::run(ctx)?; }
        "fig7" => { experiments::fig7::run(ctx)?; }
        "table1" => { experiments::table1::run(ctx)?; }
        "table2" => { experiments::table2::run(&ArchConfig::default())?; }
        "gains" => { experiments::gains::run(ctx)?; }
        "accuracy" => { experiments::accuracy::run(ctx)?; }
        "ablation" => { experiments::ablation::run(ctx)?; }
        "timesteps" => { experiments::ablation::timestep_sweep(ctx)?; }
        "all" => {
            for id in ["fig4c", "table2", "fig2", "fig6", "fig7", "gains",
                       "table1", "accuracy", "ablation"] {
                println!("\n########## experiment {id} ##########");
                experiment(ctx, id)?;
            }
        }
        other => bail!("unknown experiment {other}"),
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sv(args: &[&str]) -> Vec<String> {
        args.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn unknown_flag_rejected_with_suggestion() {
        // The motivating bug: `--quue-cap 4` used to fall through to
        // the default queue capacity with no warning at all.
        let err = Args::parse(&sv(&["run", "--quue-cap", "4"]))
            .unwrap_err()
            .to_string();
        assert!(err.contains("--quue-cap"), "{err}");
        assert!(err.contains("--queue-cap"), "{err}");
    }

    #[test]
    fn typoed_bool_flag_is_an_error_not_a_token_swallow() {
        // Pre-fix, a typoed bool flag was parsed as a *valued* flag
        // and silently consumed the next token.
        assert!(Args::parse(&sv(&["--golde", "trace"])).is_err());
        assert!(Args::parse(&sv(&["--plian"])).is_err());
    }

    #[test]
    fn valued_flag_requires_a_value() {
        assert!(Args::parse(&sv(&["run", "--queue-cap"])).is_err());
        // A following flag is not a value.
        assert!(Args::parse(&sv(&["run", "--queue-cap", "--golden"]))
                .is_err());
    }

    #[test]
    fn valid_flags_parse() {
        let a = Args::parse(&sv(&[
            "run", "--net", "classifier", "--golden", "--workers", "4",
        ])).unwrap();
        assert_eq!(a.positional, vec!["run".to_string()]);
        assert_eq!(a.get("net"), Some("classifier"));
        assert!(a.has("golden"));
        assert_eq!(a.get_usize("workers", 2).unwrap(), 4);
        assert_eq!(a.get_usize("queue-cap", 256).unwrap(), 256);
    }

    #[test]
    fn repeated_model_flags_collect_in_order() {
        let a = Args::parse(&sv(&[
            "serve", "--model", "classifier", "--model",
            "seg=segmenter",
        ])).unwrap();
        assert_eq!(a.get_all("model"),
                   vec!["classifier", "seg=segmenter"]);
        // `get` keeps last-wins semantics for single-valued flags.
        assert_eq!(a.get("model"), Some("seg=segmenter"));
        assert!(a.get_all("net").is_empty());
    }

    #[test]
    fn model_specs_parse() {
        assert_eq!(parse_model_spec("classifier").unwrap(),
                   ("classifier".to_string(), NetKind::Classifier));
        assert_eq!(parse_model_spec("fast=classifier").unwrap(),
                   ("fast".to_string(), NetKind::Classifier));
        assert_eq!(parse_model_spec("roads=segmenter").unwrap(),
                   ("roads".to_string(), NetKind::Segmenter));
        assert!(parse_model_spec("fast=nope").is_err());
        assert!(parse_model_spec("nope").is_err());
        assert!(parse_model_spec("=classifier").is_err());
    }

    #[test]
    fn dispatch_and_traffic_flags_parse() {
        let a = Args::parse(&sv(&[
            "serve", "--dispatch", "cost", "--batch-wait-ms", "7",
            "--queue-cost-cap", "123456",
        ])).unwrap();
        let scfg = service_cfg(&a).unwrap();
        assert_eq!(scfg.dispatch, DispatchMode::CostAware);
        assert_eq!(scfg.batch_wait, Duration::from_millis(7));
        assert_eq!(scfg.cost_cap, Some(123456));
        // Defaults: FIFO pull, 2 ms window, no cost cap override.
        let d = service_cfg(&Args::parse(&sv(&["serve"])).unwrap())
            .unwrap();
        assert_eq!(d.dispatch, DispatchMode::WorkQueue);
        assert_eq!(d.batch_wait, Duration::from_millis(2));
        assert_eq!(d.cost_cap, None);
        // Bad values are errors, not silent defaults.
        let bad = Args::parse(&sv(&[
            "serve", "--queue-cost-cap", "lots",
        ])).unwrap();
        assert!(service_cfg(&bad).is_err());
        assert!(TrafficMode::parse("skewed").is_some());
        assert!(TrafficMode::parse("bursty").is_none());
    }

    #[test]
    fn autoscale_flags_parse() {
        let a = Args::parse(&sv(&[
            "serve", "--workers", "2", "--workers-max", "8",
            "--autoscale-tick-ms", "50", "--autoscale-slo-us", "9000",
        ])).unwrap();
        let ac = autoscale_cfg(&a).unwrap();
        assert!(ac.active());
        assert_eq!((ac.min, ac.max), (2, 8)); // min defaults to --workers
        assert_eq!(ac.tick, Duration::from_millis(50));
        assert_eq!(ac.p99_slo_us, 9000);
        // The pool reserves the slots the controller may scale into.
        assert_eq!(service_cfg(&a).unwrap().workers_max, 8);
        // Without --workers-max, scaling is off and the pool is fixed.
        let off = Args::parse(&sv(&["serve", "--workers", "4"])).unwrap();
        assert!(!autoscale_cfg(&off).unwrap().active());
        assert_eq!(service_cfg(&off).unwrap().workers_max, 0);
        // An inverted range is a startup error, not a frozen pool.
        let bad = Args::parse(&sv(&[
            "serve", "--workers-min", "8", "--workers-max", "2",
        ])).unwrap();
        assert!(autoscale_cfg(&bad).is_err());
        assert_eq!(suggest("workers-mx"), Some("workers-max"));
    }

    #[test]
    fn degrade_flags_parse() {
        let off = Args::parse(&sv(&["serve"])).unwrap();
        assert_eq!(degrade_cfg(&off).unwrap(), (false, 0));
        let on = Args::parse(&sv(&[
            "serve", "--degrade", "reduce-t", "--degrade-floor-t", "4",
        ])).unwrap();
        assert_eq!(degrade_cfg(&on).unwrap(), (true, 4));
        // An unknown policy is a startup error, not silent shedding.
        let bad = Args::parse(&sv(&[
            "serve", "--degrade", "reduce-accuracy",
        ])).unwrap();
        assert!(degrade_cfg(&bad).is_err());
        assert_eq!(suggest("degrad"), Some("degrade"));
    }

    #[test]
    fn loadgen_priority_flag_parses() {
        for (s, code) in [("high", 0u8), ("normal", 1), ("low", 2)] {
            assert_eq!(Priority::parse(s).map(|p| p as u8), Some(code));
        }
        assert!(Priority::parse("urgent").is_none());
        let a = Args::parse(&sv(&[
            "loadgen", "--addr", "127.0.0.1:7878", "--priority", "low",
        ])).unwrap();
        assert_eq!(a.get("priority"), Some("low"));
        assert_eq!(suggest("priorty"), Some("priority"));
    }

    #[test]
    fn route_flags_parse() {
        let a = Args::parse(&sv(&[
            "route", "--backend", "127.0.0.1:7001", "--backend",
            "127.0.0.1:7002", "--heartbeat-ms", "100",
            "--eject-after", "2", "--readmit-after", "3",
            "--retry-max", "5",
        ])).unwrap();
        assert_eq!(a.get_all("backend"),
                   vec!["127.0.0.1:7001", "127.0.0.1:7002"]);
        assert_eq!(a.get_usize("heartbeat-ms", 200).unwrap(), 100);
        assert_eq!(a.get_usize("eject-after", 3).unwrap(), 2);
        assert_eq!(a.get_usize("readmit-after", 2).unwrap(), 3);
        assert_eq!(a.get_usize("retry-max", 8).unwrap(), 5);
        // Typos near the new flags still suggest correctly.
        assert_eq!(suggest("backnd"), Some("backend"));
        assert_eq!(suggest("drain-m"), Some("drain-ms"));
    }

    #[test]
    fn serve_drain_and_fault_flags_parse() {
        let a = Args::parse(&sv(&[
            "serve", "--drain-ms", "50", "--inject-faults",
            "busy=0.1,seed=7",
        ])).unwrap();
        assert_eq!(a.get_usize("drain-ms", 10_000).unwrap(), 50);
        let plan = FaultPlan::parse(a.get("inject-faults").unwrap())
            .unwrap();
        assert_eq!(plan.busy, 0.1);
        assert_eq!(plan.seed, 7);
        // A bad plan is a startup error, not a silent no-op.
        assert!(FaultPlan::parse("busy=2.0").is_err());
    }

    #[test]
    fn observability_flags_parse() {
        let a = Args::parse(&sv(&[
            "serve", "--trace", "--log-level", "debug",
        ])).unwrap();
        assert!(a.has("trace"));
        assert_eq!(a.get("log-level"), Some("debug"));
        assert!(skydiver::obs::log::parse_level("debug").is_some());
        // The fetch form of the trace subcommand.
        let f = Args::parse(&sv(&[
            "trace", "--addr", "127.0.0.1:7878", "--chrome",
            "--out", "/tmp/spans.json",
        ])).unwrap();
        assert_eq!(f.positional, vec!["trace".to_string()]);
        assert!(f.has("chrome"));
        assert_eq!(f.get("addr"), Some("127.0.0.1:7878"));
        assert_eq!(f.get("out"), Some("/tmp/spans.json"));
        // Typos near the new flags still suggest correctly.
        assert_eq!(suggest("lg-level"), Some("log-level"));
        assert_eq!(suggest("chrme"), Some("chrome"));
    }

    #[test]
    fn temporal_kernels_flag_parses() {
        let dir = Path::new("unused");
        // Default: on.
        let a = Args::parse(&sv(&["serve"])).unwrap();
        assert!(worker_cfg(dir, &a, NetKind::Classifier).unwrap()
                .temporal);
        let off = Args::parse(&sv(&[
            "serve", "--temporal-kernels", "off",
        ])).unwrap();
        assert!(!worker_cfg(dir, &off, NetKind::Classifier).unwrap()
                .temporal);
        let on = Args::parse(&sv(&[
            "serve", "--temporal-kernels", "on",
        ])).unwrap();
        assert!(worker_cfg(dir, &on, NetKind::Classifier).unwrap()
                .temporal);
        // A bad value is a startup error, not a silent default.
        let bad = Args::parse(&sv(&[
            "serve", "--temporal-kernels", "maybe",
        ])).unwrap();
        assert!(worker_cfg(dir, &bad, NetKind::Classifier).is_err());
        // Typos near the new flag still suggest correctly.
        assert_eq!(suggest("temporal-kernel"),
                   Some("temporal-kernels"));
    }

    #[test]
    fn bool_flag_does_not_consume_positional() {
        let a = Args::parse(&sv(&["--golden", "trace"])).unwrap();
        assert!(a.has("golden"));
        assert_eq!(a.positional, vec!["trace".to_string()]);
    }

    #[test]
    fn bad_integer_value_is_an_error() {
        let a = Args::parse(&sv(&["run", "--workers", "two"])).unwrap();
        assert!(a.get_usize("workers", 2).is_err());
    }

    #[test]
    fn suggestions_use_edit_distance() {
        assert_eq!(suggest("quue-cap"), Some("queue-cap"));
        assert_eq!(suggest("gloden"), Some("golden"));
        assert_eq!(suggest("zzzzzzzzzz"), None);
        assert_eq!(edit_distance("abc", "abc"), 0);
        assert_eq!(edit_distance("abc", "abd"), 1);
        assert_eq!(edit_distance("", "abc"), 3);
    }

    #[test]
    fn synthetic_frames_match_arbitrary_shapes() {
        let frames = make_frames(1, 24, 24, 8);
        assert_eq!(frames.len(), 8);
        assert!(frames.iter().all(|f| f.len() == 24 * 24));
        // Deterministic: the same id regenerates identical bytes.
        assert_eq!(make_frames(1, 24, 24, 8), frames);
        // Canonical digit shape routes to the dataset generator.
        let digits = make_frames(
            1, skydiver::data::DIGIT_H, skydiver::data::DIGIT_W, 2);
        assert!(digits.iter().all(
            |f| f.len() == skydiver::data::DIGIT_H
                * skydiver::data::DIGIT_W));
    }
}
