//! # Skydiver — SNN accelerator exploiting spatio-temporal workload balance
//!
//! Reproduction of *"Skydiver: A Spiking Neural Network Accelerator
//! Exploiting Spatio-Temporal Workload Balance"* (Chen, Gao, Fang, Luan —
//! IEEE TCAD 2022, DOI 10.1109/TCAD.2022.3158834) as a three-layer
//! Rust + JAX + Pallas stack.
//!
//! The paper's testbed is a Xilinx XC7Z045 FPGA; per DESIGN.md §2 the
//! silicon is substituted by a **cycle-level simulator** ([`sim`]) of the
//! exact published microarchitecture (spike scheduler, filter-based SPE
//! clusters, channel-based SPEs, 4 output streams + adder trees, banked
//! memories, DMA, controller), while the paper's algorithmic
//! contributions — **APRC** workload prediction and the **CBWS** balanced
//! channel schedule (Algorithm 1) — live in [`schedule`].
//!
//! ## Layer map
//!
//! * **L3 (this crate)** — the network [`server`] (wire protocol, TCP
//!   gateway, client, load generator), the [`cluster`] tier (front
//!   router with health-checked backends, failover retry and a
//!   fault-injection harness), the serving [`coordinator`], the
//!   accelerator [`sim`], the [`schedule`] zoo, [`power`] models and the
//!   experiment harness ([`experiments`]) that regenerates every table
//!   and figure of the paper.
//! * **L2 (python/compile/model.py)** — the JAX definitions of the
//!   paper's classifier (`28x28-16c-32c-8c-10`) and segmenter
//!   (`160x80x3-8C3-...-1C3`), AOT-lowered once to HLO text.
//! * **L1 (python/compile/kernels/)** — Pallas kernels for the spiking
//!   conv / dense timestep, lowered inline into the same HLO.
//!
//! Python never runs at request time: [`runtime`] loads the HLO text via
//! the PJRT C API and executes it natively.
//!
//! ## Quickstart
//!
//! ```bash
//! make artifacts            # one-time python AOT build
//! cargo run --release -- run --net classifier --frames 64
//! cargo run --release -- experiment fig7
//! ```

pub mod cluster;
pub mod coordinator;
pub mod data;
pub mod experiments;
pub mod metrics;
pub mod obs;
pub mod power;
pub mod runtime;
pub mod schedule;
pub mod server;
pub mod sim;
pub mod snn;
pub mod util;

/// The paper's FPGA clock: 200 MHz (§IV). FPS = CLOCK_HZ / cycles-per-frame.
pub const CLOCK_HZ: f64 = 200.0e6;

/// Default artifacts directory produced by `make artifacts`.
pub fn artifacts_dir() -> std::path::PathBuf {
    std::env::var_os("SKYDIVER_ARTIFACTS")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|| {
            // Walk up from CWD until a directory containing `artifacts/`.
            let mut d = std::env::current_dir().unwrap_or_default();
            loop {
                let cand = d.join("artifacts");
                if cand.is_dir() {
                    return cand;
                }
                if !d.pop() {
                    return std::path::PathBuf::from("artifacts");
                }
            }
        })
}
