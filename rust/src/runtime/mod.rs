//! PJRT runtime — loads the AOT-compiled JAX/Pallas step functions and
//! executes them natively. Python is never on this path.
//!
//! The interchange format is HLO *text* (see `python/compile/aot.py` and
//! /opt/xla-example/README.md): `HloModuleProto::from_text_file` ->
//! `XlaComputation::from_proto` -> `PjRtClient::compile` once at load;
//! per-timestep execution is `PjRtLoadedExecutable::execute`.
//!
//! The step signature (argument order fixed by `aot.export_step_hlo`):
//!
//! ```text
//! inputs : s_in, vmem_0..vmem_L, conv_w_0..[, dense_w, dense_b]
//! outputs: (spikes_0..spikes_L, vmem'_0..vmem'_L)   -- one tuple
//! ```
//!
//! [`SnnRunner`] drives T timesteps, keeping membrane state as host
//! literals between steps, and harvests per-layer spike traces — the
//! golden workload the cycle-level simulator consumes.

use std::path::Path;

use anyhow::{ensure, anyhow, Result};

use crate::snn::{NetworkWeights, SpikeMap};

/// A compiled SNN step function + its weight literals.
pub struct StepExecutable {
    exe: xla::PjRtLoadedExecutable,
    /// Weight literals in export order (conv..., dense_w, dense_b).
    weights: Vec<xla::Literal>,
    /// (C, H, W) of the network input.
    in_shape: (usize, usize, usize),
    /// Flattened vmem lengths per layer.
    vmem_lens: Vec<usize>,
    /// Pristine zero membrane buffers, one per layer, built once at
    /// load: `SnnRunner::reset` wraps these as literals instead of
    /// allocating fresh `vec![0.0; n]` zeros every frame.
    zero_vmems: Vec<Vec<f32>>,
    /// Output-spike shapes per layer (C, H, W).
    out_shapes: Vec<(usize, usize, usize)>,
}

/// Shared PJRT client (CPU).
pub struct Runtime {
    client: xla::PjRtClient,
}

impl Runtime {
    pub fn cpu() -> Result<Self> {
        let client = xla::PjRtClient::cpu()
            .map_err(|e| anyhow!("PJRT cpu client: {e}"))?;
        Ok(Self { client })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile `<dir>/<name>.step.hlo.txt` for `net`.
    pub fn load_step(&self, dir: &Path, net: &NetworkWeights)
                     -> Result<StepExecutable> {
        let path = dir.join(format!("{}.step.hlo.txt", net.meta.name));
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?)
            .map_err(|e| anyhow!("parsing {path:?}: {e} — run `make artifacts`"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp)
            .map_err(|e| anyhow!("compiling {path:?}: {e}"))?;

        // Weight literals in export order.
        let mut weights = Vec::new();
        for layer in &net.layers {
            match layer {
                crate::snn::LayerWeights::Conv { geom, w } => {
                    weights.push(literal_4d(w, geom.cout, geom.cin,
                                            geom.r, geom.r)?);
                }
                crate::snn::LayerWeights::Dense { geom, w, b, .. } => {
                    weights.push(literal_2d(w, geom.fout, geom.fin)?);
                    weights.push(literal_1d(b)?);
                }
            }
        }
        let in_shape = (net.meta.in_shape[0], net.meta.in_shape[1],
                        net.meta.in_shape[2]);
        let vmem_lens: Vec<usize> = (0..net.layers.len())
            .map(|l| {
                let (c, h, w) = net.layer_output_shape(l);
                c * h * w
            })
            .collect();
        let zero_vmems = vmem_lens.iter().map(|&n| vec![0.0f32; n])
            .collect();
        let out_shapes = (0..net.layers.len())
            .map(|l| net.layer_output_shape(l))
            .collect();
        Ok(StepExecutable { exe, weights, in_shape, vmem_lens, zero_vmems,
                            out_shapes })
    }
}

fn literal_1d(data: &[f32]) -> Result<xla::Literal> {
    Ok(xla::Literal::vec1(data))
}

fn literal_2d(data: &[f32], d0: usize, d1: usize) -> Result<xla::Literal> {
    xla::Literal::vec1(data)
        .reshape(&[d0 as i64, d1 as i64])
        .map_err(|e| anyhow!("reshape2d: {e}"))
}

fn literal_3d(data: &[f32], d: (usize, usize, usize))
              -> Result<xla::Literal> {
    xla::Literal::vec1(data)
        .reshape(&[d.0 as i64, d.1 as i64, d.2 as i64])
        .map_err(|e| anyhow!("reshape3d: {e}"))
}

fn literal_4d(data: &[f32], d0: usize, d1: usize, d2: usize, d3: usize)
              -> Result<xla::Literal> {
    xla::Literal::vec1(data)
        .reshape(&[d0 as i64, d1 as i64, d2 as i64, d3 as i64])
        .map_err(|e| anyhow!("reshape4d: {e}"))
}

/// Per-layer spike maps for every timestep of one frame: `trace[t][l]`.
pub type GoldenTrace = Vec<Vec<SpikeMap>>;

/// Drives a [`StepExecutable`] over timesteps for one frame.
pub struct SnnRunner<'a> {
    step: &'a StepExecutable,
    /// Membrane state literals between steps.
    vmems: Vec<xla::Literal>,
    /// Reused dense-f32 staging buffer for the input spike map
    /// (`SpikeMap::to_f32_into` — one allocation per runner, not per
    /// timestep).
    in_f32: Vec<f32>,
}

impl<'a> SnnRunner<'a> {
    pub fn new(step: &'a StepExecutable) -> Result<Self> {
        let vmems = Self::zero_literals(step)?;
        Ok(Self { step, vmems, in_f32: Vec::new() })
    }

    /// Wrap the executable's pristine zero buffers as fresh literals —
    /// no host-side zero vector is allocated per frame (the buffers are
    /// built once at load; see `StepExecutable::zero_vmems`).
    fn zero_literals(step: &StepExecutable) -> Result<Vec<xla::Literal>> {
        step.zero_vmems.iter()
            .map(|z| Ok(xla::Literal::vec1(z)))
            .collect()
    }

    pub fn reset(&mut self) -> Result<()> {
        self.vmems = Self::zero_literals(self.step)?;
        Ok(())
    }

    /// Execute one timestep; returns per-layer output spike maps.
    pub fn step(&mut self, input: &SpikeMap) -> Result<Vec<SpikeMap>> {
        let (c, h, w) = self.step.in_shape;
        ensure!((input.c, input.h, input.w) == (c, h, w),
                "input shape mismatch");
        let nl = self.step.vmem_lens.len();

        // `execute` wants a slice of Borrow<Literal>; build owned refs
        // is not possible without clones, so use a small shim that
        // borrows. &Literal implements Borrow<Literal>.
        let mut args: Vec<&xla::Literal> = Vec::with_capacity(
            1 + nl + self.step.weights.len());
        input.to_f32_into(&mut self.in_f32);
        let in_lit = literal_3d(&self.in_f32, (c, h, w))?;
        args.push(&in_lit);
        for v in &self.vmems {
            args.push(v);
        }
        for wl in &self.step.weights {
            args.push(wl);
        }

        let result = self.step.exe.execute::<&xla::Literal>(&args)
            .map_err(|e| anyhow!("execute: {e}"))?;
        let out = result[0][0].to_literal_sync()
            .map_err(|e| anyhow!("to_literal: {e}"))?;
        let parts = out.to_tuple().map_err(|e| anyhow!("untuple: {e}"))?;
        ensure!(parts.len() == 2 * nl,
                "expected {} outputs, got {}", 2 * nl, parts.len());

        let mut spikes = Vec::with_capacity(nl);
        let mut iter = parts.into_iter();
        for l in 0..nl {
            let lit = iter.next().unwrap();
            let data: Vec<f32> = lit.to_vec()
                .map_err(|e| anyhow!("spikes[{l}] to_vec: {e}"))?;
            let (oc, oh, ow) = self.step.out_shapes[l];
            spikes.push(SpikeMap::from_f32(oc, oh, ow, &data));
        }
        // Remaining literals are the new membrane state.
        self.vmems = iter.collect();
        Ok(spikes)
    }

    /// Run a whole frame; returns the golden per-layer trace.
    pub fn run_frame(&mut self, inputs: &[SpikeMap]) -> Result<GoldenTrace> {
        self.reset()?;
        inputs.iter().map(|i| self.step(i)).collect()
    }

    /// Run a frame and return only the accumulated output counts.
    pub fn run_frame_counts(&mut self, inputs: &[SpikeMap])
                            -> Result<Vec<u32>> {
        let trace = self.run_frame(inputs)?;
        let (oc, oh, ow) = *self.step.out_shapes.last().unwrap();
        let mut counts = vec![0u32; oc * oh * ow];
        for step in &trace {
            let last = step.last().unwrap();
            for (ch, idx) in last.iter_events() {
                counts[ch * oh * ow + idx] += 1;
            }
        }
        Ok(counts)
    }
}
