//! Minimal JSON parser/writer — enough for the weights/meta interchange
//! with the python side (objects, arrays, strings, f64 numbers, bools,
//! null; UTF-8 pass-through, `\uXXXX` escapes on read).

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{anyhow, bail, Result};

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    // ---------------- accessors ----------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Object field lookup that errors with the key name.
    pub fn field(&self, key: &str) -> Result<&Json> {
        self.get(key).ok_or_else(|| anyhow!("missing field '{key}'"))
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(n) => Ok(*n),
            _ => bail!("not a number: {self:?}"),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        let f = self.as_f64()?;
        if f < 0.0 || f.fract() != 0.0 {
            bail!("not a non-negative integer: {f}");
        }
        Ok(f as usize)
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            _ => bail!("not a string: {self:?}"),
        }
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Json::Bool(b) => Ok(*b),
            _ => bail!("not a bool: {self:?}"),
        }
    }

    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(v) => Ok(v),
            _ => bail!("not an array: {self:?}"),
        }
    }

    pub fn is_null(&self) -> bool {
        matches!(self, Json::Null)
    }

    /// `[1,2,3]` -> `Vec<usize>`.
    pub fn usize_vec(&self) -> Result<Vec<usize>> {
        self.as_arr()?.iter().map(|v| v.as_usize()).collect()
    }

    pub fn f64_vec(&self) -> Result<Vec<f64>> {
        self.as_arr()?.iter().map(|v| v.as_f64()).collect()
    }

    // ---------------- parse ----------------

    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            bail!("trailing characters at offset {}", p.i);
        }
        Ok(v)
    }

    // ---------------- write ----------------

    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\t' => out.push_str("\\t"),
                        '\r' => out.push_str("\\r"),
                        c if (c as u32) < 0x20 => {
                            let _ = write!(out, "\\u{:04x}", c as u32);
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(v) => {
                out.push('[');
                for (i, e) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    e.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Json::Str(k.clone()).write(out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

/// Builder helpers for report emission.
impl Json {
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter()
            .map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn num(v: f64) -> Json {
        Json::Num(v)
    }

    pub fn str(v: impl Into<String>) -> Json {
        Json::Str(v.into())
    }

    pub fn arr_f64(v: &[f64]) -> Json {
        Json::Arr(v.iter().map(|&x| Json::Num(x)).collect())
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len()
            && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.b.get(self.i).copied()
            .ok_or_else(|| anyhow!("unexpected end of input"))
    }

    fn eat(&mut self, c: u8) -> Result<()> {
        if self.peek()? != c {
            bail!("expected '{}' at offset {}, found '{}'",
                  c as char, self.i, self.peek()? as char);
        }
        self.i += 1;
        Ok(())
    }

    fn value(&mut self) -> Result<Json> {
        self.skip_ws();
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            _ => self.number(),
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            bail!("invalid literal at offset {}", self.i);
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek()? {
                b',' => self.i += 1,
                b'}' => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                c => bail!("expected ',' or '}}' found '{}'", c as char),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.eat(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            v.push(self.value()?);
            self.skip_ws();
            match self.peek()? {
                b',' => self.i += 1,
                b']' => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                c => bail!("expected ',' or ']' found '{}'", c as char),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b't' => s.push('\t'),
                        b'r' => s.push('\r'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                bail!("bad \\u escape");
                            }
                            let hex = std::str::from_utf8(
                                &self.b[self.i..self.i + 4])?;
                            let cp = u32::from_str_radix(hex, 16)?;
                            self.i += 4;
                            s.push(char::from_u32(cp)
                                .unwrap_or('\u{fffd}'));
                        }
                        _ => bail!("bad escape \\{}", e as char),
                    }
                }
                _ => {
                    // Copy the full UTF-8 sequence.
                    let start = self.i - 1;
                    let len = utf8_len(c);
                    self.i = start + len;
                    if self.i > self.b.len() {
                        bail!("truncated UTF-8");
                    }
                    s.push_str(std::str::from_utf8(
                        &self.b[start..self.i])?);
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i],
                        b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E') {
            self.i += 1;
        }
        let txt = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Json::Num(txt.parse::<f64>()
            .map_err(|e| anyhow!("bad number '{txt}': {e}"))?))
    }
}

fn utf8_len(b: u8) -> usize {
    if b < 0x80 {
        1
    } else if b >> 5 == 0b110 {
        2
    } else if b >> 4 == 0b1110 {
        3
    } else {
        4
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_nested() {
        let src = r#"{"a": [1, 2.5, -3e2], "b": {"c": true, "d": null},
                      "s": "x\ny\"z"}"#;
        let v = Json::parse(src).unwrap();
        assert_eq!(v.field("a").unwrap().f64_vec().unwrap(),
                   vec![1.0, 2.5, -300.0]);
        assert!(v.field("b").unwrap().field("c").unwrap()
            .as_bool().unwrap());
        assert!(v.field("b").unwrap().field("d").unwrap().is_null());
        assert_eq!(v.field("s").unwrap().as_str().unwrap(), "x\ny\"z");
        // Write + reparse = same value.
        let v2 = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn parses_python_json_dump() {
        // Shape of what `json.dumps(..., indent=1)` emits.
        let src = "{\n \"name\": \"x\",\n \"lambdas\": [0.5, 1.25],\n \"n\": 42\n}";
        let v = Json::parse(src).unwrap();
        assert_eq!(v.field("n").unwrap().as_usize().unwrap(), 42);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("{\"a\" 1}").is_err());
        assert!(Json::parse("12 34").is_err());
    }

    #[test]
    fn unicode_passthrough() {
        let v = Json::parse("\"caf\u{e9} \\u00e9\"").unwrap();
        assert_eq!(v.as_str().unwrap(), "café é");
    }

    #[test]
    fn integers_write_clean() {
        assert_eq!(Json::Num(42.0).to_string(), "42");
        assert_eq!(Json::Num(0.5).to_string(), "0.5");
    }
}
