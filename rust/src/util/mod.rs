//! In-crate utilities replacing external dependencies (the build is
//! fully offline; see Cargo.toml).

pub mod json;

pub use json::Json;
