//! Fig. 4(c) — the worked APRC example: two 3x3 filters with magnitudes
//! 2.7 and 0.9 (ratio 3) full-pad convolved over an 8x8 input produce
//! summed membrane updates 16.2 and 5.4 (the same ratio), and spike
//! counts in approximately that ratio.
//!
//! We reproduce it twice: analytically (Eq. 5) and empirically with the
//! functional model on the actual geometry.

use anyhow::Result;


use crate::metrics::Table;
use crate::schedule::aprc::fig4c_example;
use crate::snn::{ConvGeom, FunctionalNet, LayerWeights, NetworkWeights,
                 SpikeMap, WeightsMeta};

#[derive(Debug, Clone)]
pub struct Fig4cResult {
    pub magnitudes: [f64; 2],
    pub analytic_sums: [f64; 2],
    /// Empirical summed membrane updates from the functional model.
    pub empirical_sums: [f64; 2],
    /// Empirical spike counts per output channel.
    pub spikes: [u64; 2],
    pub ratio_error: f64,
}

fn example_net() -> NetworkWeights {
    // Two 3x3 single-input-channel filters with magnitudes 2.7 / 0.9.
    let w0 = 2.7f32 / 9.0;
    let w1 = 0.9f32 / 9.0;
    let mut w = vec![w0; 9];
    w.extend(std::iter::repeat(w1).take(9));
    let meta = WeightsMeta::parse(r#"{
        "name": "fig4c", "aprc": true, "pad": 2, "vth": 1.0,
        "timesteps": 1, "in_shape": [1, 8, 8],
        "feature_sizes": [[2, 10, 10]], "dense_out": null,
        "total_floats": 18, "lambdas": [], "layers": [],
        "blob_fnv1a64": "0"
    }"#).unwrap();
    NetworkWeights {
        meta,
        layers: vec![LayerWeights::Conv {
            geom: ConvGeom { cin: 1, cout: 2, r: 3, pad: 2, h: 8, w: 8,
                             eh: 10, ew: 10 },
            w,
        }],
    }
}

pub fn run() -> Result<Fig4cResult> {
    let (s0, s1, mag_ratio, sum_ratio) = fig4c_example();

    // Empirical: 6 input spikes on the 8x8 map (input sum = 6, as in
    // the paper's 16.2 / 2.7).
    let net = example_net();
    let mut input = SpikeMap::zeros(1, 8, 8);
    for &i in &[9usize, 18, 27, 36, 45, 54] {
        input.set(0, i);
    }
    // Pass 1 (vth = 1.0 > any single-step update): nothing fires, so the
    // membrane sums ARE the dV sums of Eq. 5.
    let mut f = FunctionalNet::new(&net);
    let out = f.step(&input);
    assert_eq!(out[0].spikes.nnz(), 0);
    let per = 10 * 10;
    let emp: Vec<f64> = (0..2).map(|m| {
        f.vmem(0)[m * per..(m + 1) * per].iter()
            .map(|&v| v as f64).sum()
    }).collect();
    // Pass 2: accumulate the same input over several timesteps so the
    // LIF threshold is actually crossed; output spike counts then track
    // the filter-magnitude ratio (the paper's 6-vs-2 picture).
    let mut f2 = FunctionalNet::new(&net);
    let mut spikes = [0u64; 2];
    for _ in 0..12 {
        let o = f2.step(&input);
        spikes[0] += o[0].spikes.nnz_channel(0) as u64;
        spikes[1] += o[0].spikes.nnz_channel(1) as u64;
    }

    let ratio_error = ((emp[0] / emp[1]) - mag_ratio).abs() / mag_ratio;
    let res = Fig4cResult {
        magnitudes: [2.7, 0.9],
        analytic_sums: [s0, s1],
        empirical_sums: [emp[0], emp[1]],
        spikes,
        ratio_error,
    };

    let mut t = Table::new("Fig 4(c): APRC worked example",
                           &["quantity", "channel0", "channel1", "ratio"]);
    t.row(&["filter magnitude".into(), "2.7".into(), "0.9".into(),
            format!("{mag_ratio:.2}")]);
    t.row(&["analytic dV sum".into(), format!("{s0:.2}"),
            format!("{s1:.2}"), format!("{sum_ratio:.2}")]);
    t.row(&["empirical dV sum".into(), format!("{:.2}", emp[0]),
            format!("{:.2}", emp[1]),
            format!("{:.2}", emp[0] / emp[1])]);
    t.row(&["spikes".into(), res.spikes[0].to_string(),
            res.spikes[1].to_string(),
            format!("{:.2}", res.spikes[0] as f64
                / res.spikes[1].max(1) as f64)]);
    t.print();
    Ok(res)
}
