//! Shared experiment plumbing.

use std::path::{Path, PathBuf};

use anyhow::Result;

use crate::runtime::{Runtime, SnnRunner};
use crate::sim::{sweep, FrameReport, Simulator, TraceSource};
use crate::snn::{encode_phased_u8, NetworkWeights, SpikeMap,
                 TemporalSpikeMap};

/// Context every experiment receives.
#[derive(Debug, Clone)]
pub struct ExperimentCtx {
    pub artifacts: PathBuf,
    /// Use the PJRT golden trace (true) or the functional model (false).
    pub golden: bool,
    /// Frame budget knob (experiments pick sensible defaults when 0).
    pub frames: usize,
}

impl ExperimentCtx {
    pub fn new(artifacts: PathBuf) -> Self {
        Self { artifacts, golden: false, frames: 0 }
    }

    pub fn frames_or(&self, default: usize) -> usize {
        if self.frames == 0 { default } else { self.frames }
    }
}

pub fn load_net(dir: &Path, name: &str) -> Result<NetworkWeights> {
    NetworkWeights::load(dir, name)
}

/// Encoded digit frames + labels: `(spike trains, labels)`.
pub fn classifier_frames(seed: u64, n: usize, timesteps: usize)
                         -> (Vec<Vec<SpikeMap>>, Vec<u8>) {
    let (imgs, labels) = crate::data::gen_digits(seed, n);
    let trains = imgs.chunks(28 * 28)
        .map(|img| encode_phased_u8(img, 1, 28, 28, timesteps))
        .collect();
    (trains, labels)
}

/// Encoded road frames + masks: `(spike trains, masks)`.
pub fn segmenter_frames(seed: u64, n: usize, timesteps: usize)
                        -> (Vec<Vec<SpikeMap>>, Vec<Vec<u8>>) {
    let (imgs, masks) = crate::data::gen_road_scenes(seed, n);
    let (h, w) = (crate::data::ROAD_H, crate::data::ROAD_W);
    let trains = imgs.chunks(h * w * 3)
        .map(|img| {
            // HWC u8 -> CHW u8
            let mut chw = vec![0u8; 3 * h * w];
            for y in 0..h {
                for x in 0..w {
                    for c in 0..3 {
                        chw[c * h * w + y * w + x] = img[(y * w + x) * 3 + c];
                    }
                }
            }
            encode_phased_u8(&chw, 3, h, w, timesteps)
        })
        .collect();
    let masks = masks.chunks(h * w).map(|m| m.to_vec()).collect();
    (trains, masks)
}

/// Produce the trace source for one frame: PJRT golden when requested
/// (and available), otherwise functional.
pub fn trace_for(ctx: &ExperimentCtx, net: &NetworkWeights,
                 inputs: &[SpikeMap]) -> Result<TraceSource> {
    if !ctx.golden {
        return Ok(TraceSource::Functional);
    }
    let rt = Runtime::cpu()?;
    let step = rt.load_step(&ctx.artifacts, net)?;
    let mut runner = SnnRunner::new(&step)?;
    Ok(TraceSource::Golden(runner.run_frame(inputs)?))
}

/// Pack per-timestep spike trains into the time-major layout the
/// temporal kernels consume (one map per frame).
pub fn pack_trains(trains: &[Vec<SpikeMap>]) -> Vec<TemporalSpikeMap> {
    trains.iter().map(|t| TemporalSpikeMap::from_steps(t)).collect()
}

/// Simulate many frames of one configuration. Functional mode packs
/// the frames time-major and fans them out across the frame-parallel
/// sweep engine (`sim::sweep`) on the bit-parallel temporal kernels —
/// reports come back in frame order, bit-identical to the per-timestep
/// serial loop (the kernels are an exact oracle match; see PERF.md).
/// Golden mode keeps the old interleaved serial loop: the PJRT client
/// is not thread-safe, trace generation dominates the cost anyway, and
/// interleaving keeps trace memory at one frame instead of all frames.
pub fn sweep_run(ctx: &ExperimentCtx, net: &NetworkWeights,
                 sim: &Simulator, trains: &[Vec<SpikeMap>])
                 -> Result<Vec<FrameReport>> {
    if ctx.golden {
        return trains.iter()
            .map(|t| sim.run_frame(t, &trace_for(ctx, net, t)?))
            .collect();
    }
    let packed = pack_trains(trains);
    sweep::run_frames_temporal(sim, &packed, sweep::default_threads())
}

/// Pearson correlation of two equal-length series.
pub fn pearson(a: &[f64], b: &[f64]) -> f64 {
    let n = a.len() as f64;
    if a.len() != b.len() || a.len() < 2 {
        return f64::NAN;
    }
    let ma = a.iter().sum::<f64>() / n;
    let mb = b.iter().sum::<f64>() / n;
    let mut cov = 0.0;
    let mut va = 0.0;
    let mut vb = 0.0;
    for (&x, &y) in a.iter().zip(b) {
        cov += (x - ma) * (y - mb);
        va += (x - ma) * (x - ma);
        vb += (y - mb) * (y - mb);
    }
    if va <= 0.0 || vb <= 0.0 {
        return f64::NAN;
    }
    cov / (va.sqrt() * vb.sqrt())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pearson_perfect() {
        let a = [1.0, 2.0, 3.0, 4.0];
        let b = [2.0, 4.0, 6.0, 8.0];
        assert!((pearson(&a, &b) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn pearson_anticorrelated() {
        let a = [1.0, 2.0, 3.0];
        let b = [3.0, 2.0, 1.0];
        assert!((pearson(&a, &b) + 1.0).abs() < 1e-12);
    }

    #[test]
    fn classifier_frames_shapes() {
        let (trains, labels) = classifier_frames(1, 3, 5);
        assert_eq!(trains.len(), 3);
        assert_eq!(labels.len(), 3);
        assert_eq!(trains[0].len(), 5);
        assert_eq!(trains[0][0].c, 1);
    }

    #[test]
    fn segmenter_frames_shapes() {
        let (trains, masks) = segmenter_frames(2, 1, 4);
        assert_eq!(trains[0].len(), 4);
        assert_eq!(trains[0][0].c, 3);
        assert_eq!(masks[0].len(), 80 * 160);
    }
}
