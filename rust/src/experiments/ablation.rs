//! Ablations DESIGN.md §5 calls out:
//!
//! * SPE count N in {2, 4, 8, 16} x scheduler zoo — balance + FPS
//!   (+ whether the configuration still fits the XC7Z045);
//! * CBWS fine-tune iteration budget T_ft in {0, 4, 64};
//! * timestep count T sensitivity for the classifier.

use anyhow::Result;


use super::common::{classifier_frames, segmenter_frames, ExperimentCtx};
use crate::coordinator::default_input_rates;
use crate::metrics::Table;
use crate::power::ResourceModel;
use crate::schedule::cbws::{cbws_assign, Cbws};
use crate::schedule::{all_schedulers, AprcPredictor, Partition,
                      Scheduler};
use crate::sim::{sweep, ArchConfig, RunSummary, Simulator};
use crate::snn::NetworkWeights;

#[derive(Debug, Clone)]
pub struct SweepPoint {
    pub scheduler: String,
    pub n_spes: usize,
    pub balance: f64,
    pub fps: f64,
    pub fits_device: bool,
}

#[derive(Debug, Clone)]
pub struct FinetunePoint {
    pub iters: usize,
    pub balance: f64,
}

#[derive(Debug, Clone)]
pub struct AblationResult {
    pub spe_sweep: Vec<SweepPoint>,
    pub finetune: Vec<FinetunePoint>,
    pub oracle_balance: f64,
}

pub fn run(ctx: &ExperimentCtx) -> Result<AblationResult> {
    let net = NetworkWeights::load(&ctx.artifacts, "segmenter_aprc")?;
    let (trains, _) = segmenter_frames(0xAB1A, ctx.frames_or(1),
                                       net.meta.timesteps);
    let rates = default_input_rates(&net);
    let predictor = AprcPredictor::from_network(&net, &rates);
    let rm = ResourceModel::default();

    // Pack once, reuse across every (N, scheduler) point: the temporal
    // kernels report bit-identically to the per-timestep path.
    let packed = super::common::pack_trains(&trains);
    let mut spe_sweep = Vec::new();
    for n in [2usize, 4, 8, 16] {
        let mut arch = ArchConfig::default();
        arch.n_spes = n;
        for s in all_schedulers() {
            let sim = Simulator::new(arch, &net, s.as_ref(), &predictor);
            let frames = sweep::run_frames_temporal(
                &sim, &packed, sweep::default_threads())?;
            let sum = RunSummary::from_frames(&frames, arch.clock_hz, n);
            spe_sweep.push(SweepPoint {
                scheduler: s.name().into(),
                n_spes: n,
                balance: sum.mean_balance_weighted,
                fps: sum.mean_fps,
                fits_device: rm.estimate(&arch).fits_xc7z045(),
            });
        }
    }

    // Fine-tune budget: measured directly on one timestep's workload.
    let arch = ArchConfig::default();
    // Use the actual spike counts of a mid-network layer as workload.
    let mut f = crate::snn::FunctionalNet::new(&net);
    let outs = f.run_frame(&trains[0]);
    let mid = 2usize;
    let workload: Vec<f64> = (0..net.layer_input_shape(mid + 1).0)
        .map(|c| outs.iter()
            .map(|step| step[mid].spikes.nnz_channel(c) as f64)
            .sum())
        .collect();
    let finetune = [0usize, 4, 64].iter().map(|&iters| {
        let p = cbws_assign(predictor.layer(mid + 1), arch.n_spes, iters);
        FinetunePoint { iters, balance: p.balance_ratio(&workload) }
    }).collect::<Vec<_>>();

    // Oracle upper bound on the same workload.
    let oracle_p: Partition = crate::schedule::baselines::Oracle
        .assign(&workload, arch.n_spes);
    let oracle_balance = oracle_p.balance_ratio(&workload);

    let res = AblationResult { spe_sweep, finetune, oracle_balance };

    let mut t = Table::new(
        "Ablation: scheduler x SPE count (segmenter)",
        &["scheduler", "N", "balance", "FPS", "fits XC7Z045"]);
    for p in &res.spe_sweep {
        t.row(&[p.scheduler.clone(), p.n_spes.to_string(),
                format!("{:.2}%", 100.0 * p.balance),
                format!("{:.1}", p.fps),
                if p.fits_device { "yes".into() } else { "NO".into() }]);
    }
    t.print();

    let mut t2 = Table::new(
        format!("Ablation: CBWS fine-tune budget (layer {} workload; oracle {:.2}%)",
                3, 100.0 * res.oracle_balance),
        &["iters", "balance"]);
    for p in &res.finetune {
        t2.row(&[p.iters.to_string(), format!("{:.2}%", 100.0 * p.balance)]);
    }
    t2.print();
    Ok(res)
}

/// Classifier timestep sensitivity: accuracy + FPS vs T (uses the
/// functional model; exported separately because it is slower).
#[derive(Debug, Clone)]
pub struct TimestepPoint {
    pub timesteps: usize,
    pub accuracy: f64,
    pub fps: f64,
}

pub fn timestep_sweep(ctx: &ExperimentCtx) -> Result<Vec<TimestepPoint>> {
    let net = NetworkWeights::load(&ctx.artifacts, "classifier_aprc")?;
    let arch = ArchConfig::default();
    let rates = default_input_rates(&net);
    let predictor = AprcPredictor::from_network(&net, &rates);
    let sim = Simulator::new(arch, &net, &Cbws::default(), &predictor);
    let n = ctx.frames_or(64);
    let mut out = Vec::new();
    for t_steps in [8usize, 16, 24, 32] {
        let (trains, labels) =
            classifier_frames(super::accuracy::DIGITS_TEST_SEED, n, t_steps);
        let packed = super::common::pack_trains(&trains);
        let frames = sweep::run_frames_temporal(
            &sim, &packed, sweep::default_threads())?;
        let mut correct = 0usize;
        for (rep, &label) in frames.iter().zip(&labels) {
            let pred = rep.output_counts.iter().enumerate()
                .max_by_key(|(_, &c)| c).map(|(i, _)| i).unwrap_or(0);
            correct += (pred == label as usize) as usize;
        }
        let sum = RunSummary::from_frames(&frames, arch.clock_hz,
                                          arch.n_spes);
        out.push(TimestepPoint {
            timesteps: t_steps,
            accuracy: correct as f64 / n as f64,
            fps: sum.mean_fps,
        });
    }
    let mut t = Table::new("Ablation: classifier timesteps",
                           &["T", "accuracy", "FPS"]);
    for p in &out {
        t.row(&[p.timesteps.to_string(), format!("{:.4}", p.accuracy),
                format!("{:.0}", p.fps)]);
    }
    t.print();
    Ok(out)
}
