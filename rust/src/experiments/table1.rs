//! Table I — "This work" row: frequency, on-chip power, prediction
//! energy, KFPS, GSOp/s, efficiency, for both tasks; printed alongside
//! the published prior-work rows for the comparison the paper makes.

use anyhow::Result;


use super::common::{classifier_frames, segmenter_frames, sweep_run,
                    ExperimentCtx};
use crate::metrics::{si, Table};
use crate::power::EnergyModel;
use crate::schedule::cbws::Cbws;
use crate::schedule::AprcPredictor;
use crate::sim::{ArchConfig, Simulator};
use crate::snn::{NetworkWeights, SpikeMap};

#[derive(Debug, Clone)]
pub struct TaskRow {
    pub task: String,
    pub fps: f64,
    pub gsops: f64,
    pub energy_per_frame_j: f64,
    pub mean_power_w: f64,
    pub efficiency_gsops_w: f64,
}

#[derive(Debug, Clone)]
pub struct Table1Result {
    pub freq_mhz: f64,
    pub rows: Vec<TaskRow>,
}

fn task_row(ctx: &ExperimentCtx, net: &NetworkWeights, task: &str,
            trains: &[Vec<SpikeMap>]) -> Result<TaskRow> {
    let arch = ArchConfig::default();
    let energy = EnergyModel::default();
    // Deployment config: CBWS on the offline profiled prediction (the
    // best realizable schedule; see fig7).
    let calib: Vec<_> = if net.meta.in_shape[0] == 1 {
        super::common::classifier_frames(0xCA11B0, 4, net.meta.timesteps).0
    } else {
        super::common::segmenter_frames(0xCA11B0, 1, net.meta.timesteps).0
    };
    let predictor = AprcPredictor::from_profile(net, &calib);
    let sim = Simulator::new(arch, net, &Cbws::default(), &predictor);

    let mut cycles = 0u64;
    let mut synops = 0u64;
    let mut joules = 0.0;
    for rep in sweep_run(ctx, net, &sim, trains)? {
        cycles += rep.total_cycles;
        synops += rep.synops;
        joules += energy.frame_energy(&rep, arch.clock_hz).total_j;
    }
    let n = trains.len() as f64;
    let secs = cycles as f64 / arch.clock_hz;
    let fps = n / secs;
    let gsops = synops as f64 / secs / 1e9;
    let energy_per_frame = joules / n;
    let mean_power = joules / secs;
    Ok(TaskRow {
        task: task.into(),
        fps,
        gsops,
        energy_per_frame_j: energy_per_frame,
        mean_power_w: mean_power,
        efficiency_gsops_w: gsops / mean_power,
    })
}

/// Published rows of Table I for display (platform, net, task, freq MHz,
/// power W, energy mJ/frame, KFPS, GSOp/s, GSOp/s/W).
pub fn prior_work_rows() -> Vec<[String; 7]> {
    let r = |a: &str, b: &str, c: &str, d: &str, e: &str, f: &str,
             g: &str| -> [String; 7] {
        [a.into(), b.into(), c.into(), d.into(), e.into(), f.into(),
         g.into()]
    };
    vec![
        r("TCAS-I'21 [13]", "VC707", "100", "1.6", "5.04", "0.32", "-"),
        r("ICCAD'20 [8]", "XCZU9EG", "125", "4.5", "2.34/33.84",
          "1.92/0.13", "-"),
        r("ASSCC'19 [14]", "XC7VX690T", "-", "0.7", "0.77", "0.91",
          "0.95"),
        r("NeuralComp'20 [10]", "ZCU102", "100", "4.6", "30", "0.16",
          "-"),
    ]
}

pub fn run(ctx: &ExperimentCtx) -> Result<Table1Result> {
    let clf = NetworkWeights::load(&ctx.artifacts, "classifier_aprc")?;
    let seg = NetworkWeights::load(&ctx.artifacts, "segmenter_aprc")?;
    let (clf_trains, _) = classifier_frames(0x7AB1, ctx.frames_or(8),
                                            clf.meta.timesteps);
    let (seg_trains, _) = segmenter_frames(0x7AB1_5, ctx.frames_or(2),
                                           seg.meta.timesteps);

    let rows = vec![
        task_row(ctx, &clf, "classification", &clf_trains)?,
        task_row(ctx, &seg, "segmentation", &seg_trains)?,
    ];
    let res = Table1Result { freq_mhz: 200.0, rows };

    let mut t = Table::new(
        "Table I: comparison with previous works",
        &["work", "platform", "MHz", "W", "mJ/frame", "KFPS", "GSOp/s/W"]);
    for r in prior_work_rows() {
        t.row(&r);
    }
    for row in &res.rows {
        t.row(&[format!("This work ({})", row.task),
                "XC7Z045(sim)".into(),
                format!("{:.0}", res.freq_mhz),
                format!("{:.2}", row.mean_power_w),
                format!("{:.3}", row.energy_per_frame_j * 1e3),
                format!("{:.2}", row.fps / 1e3),
                format!("{:.2}", row.efficiency_gsops_w)]);
    }
    t.print();

    let mut t2 = Table::new(
        "This work detail (paper: 22.6 KFPS / 42.4 uJ classif., 110 FPS / 0.91 mJ seg.)",
        &["task", "FPS", "GSOp/s", "uJ/frame", "W"]);
    for row in &res.rows {
        t2.row(&[row.task.clone(), si(row.fps), format!("{:.3}", row.gsops),
                 format!("{:.1}", row.energy_per_frame_j * 1e6),
                 format!("{:.2}", row.mean_power_w)]);
    }
    t2.print();
    Ok(res)
}
