//! Table II — XC7Z045 resource utilisation of the default configuration
//! (from the analytical model calibrated in `power::resources`).

use anyhow::Result;


use crate::metrics::Table;
use crate::power::resource_table;
use crate::sim::ArchConfig;

#[derive(Debug, Clone)]
pub struct Table2Result {
    pub rows: Vec<(String, u64, u64, f64)>,
}

pub fn run(arch: &ArchConfig) -> Result<Table2Result> {
    let rows = resource_table(arch);
    let mut t = Table::new(
        format!("Table II: XC7Z045 utilisation (M={}, N={}, {} streams)",
                arch.m_clusters, arch.n_spes, arch.streams),
        &["metric", "available", "used", "percent"]);
    for (name, avail, used, pct) in &rows {
        t.row(&[name.clone(), avail.to_string(), used.to_string(),
                format!("{pct:.2}%")]);
    }
    t.print();
    Ok(Table2Result { rows })
}
