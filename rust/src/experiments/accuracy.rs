//! Accuracy reproduction — the paper's 98.5 % MNIST claim, evaluated on
//! the synthetic test split with the rust inference stack end-to-end
//! (encode -> golden/functional SNN -> argmax of output spike counts),
//! plus segmentation IoU.

use anyhow::Result;


use super::common::{classifier_frames, segmenter_frames, ExperimentCtx};
use crate::metrics::Table;
use crate::runtime::{Runtime, SnnRunner};
use crate::sim::sweep;
use crate::snn::{FunctionalNet, NetworkWeights};

/// Seeds must match `python/compile/train.py`.
pub const DIGITS_TEST_SEED: u64 = 0x7E57D161;
pub const ROADS_TEST_SEED: u64 = 0x7E570AD5;

#[derive(Debug, Clone)]
pub struct AccuracyResult {
    pub classifier_accuracy: f64,
    pub classifier_frames: usize,
    pub python_snn_metric: Option<f64>,
    pub segmenter_iou: f64,
    pub segmenter_frames: usize,
}

pub fn run(ctx: &ExperimentCtx) -> Result<AccuracyResult> {
    let net = NetworkWeights::load(&ctx.artifacts, "classifier_aprc")?;
    let n = ctx.frames_or(256);
    let (trains, labels) = classifier_frames(DIGITS_TEST_SEED, n,
                                             net.meta.timesteps);

    // Optional golden path (PJRT); functional otherwise.
    let runtime = if ctx.golden { Some(Runtime::cpu()?) } else { None };
    let step = match &runtime {
        Some(rt) => Some(rt.load_step(&ctx.artifacts, &net)?),
        None => None,
    };

    // Golden frames run serially (one PJRT runner, reused across
    // frames); functional frames fan out over the frame-parallel sweep.
    let all_counts: Vec<Vec<u32>> = match &step {
        Some(s) => {
            let mut runner = SnnRunner::new(s)?;
            trains.iter().map(|t| runner.run_frame_counts(t))
                .collect::<Result<_>>()?
        }
        None => sweep::parallel_map(
            &trains, sweep::default_threads(),
            |_, train| FunctionalNet::new(&net).run_frame_counts(train)),
    };
    let mut correct = 0usize;
    for (counts, &label) in all_counts.iter().zip(&labels) {
        let pred = counts.iter().enumerate()
            .max_by_key(|(_, &c)| c).map(|(i, _)| i).unwrap_or(0);
        if pred == label as usize {
            correct += 1;
        }
    }
    let acc = correct as f64 / n as f64;

    // Segmentation IoU.
    let seg = NetworkWeights::load(&ctx.artifacts, "segmenter_aprc")?;
    let n_seg = ctx.frames_or(256).min(8).max(2);
    let (seg_trains, masks) = segmenter_frames(ROADS_TEST_SEED, n_seg,
                                               seg.meta.timesteps);
    let thr = seg.meta.seg_rate_threshold.unwrap_or(0.5);
    let t_steps = seg.meta.timesteps as f64;
    let (oc, oh, ow) = seg.layer_output_shape(seg.layers.len() - 1);
    assert_eq!(oc, 1);
    let (ih, iw) = (crate::data::ROAD_H, crate::data::ROAD_W);
    let (dh, dw) = ((oh - ih) / 2, (ow - iw) / 2);
    let seg_counts = sweep::parallel_map(
        &seg_trains, sweep::default_threads(),
        |_, train| FunctionalNet::new(&seg).run_frame_counts(train));
    let mut iou_sum = 0.0;
    for (counts, mask) in seg_counts.iter().zip(&masks) {
        let mut inter = 0usize;
        let mut union = 0usize;
        for y in 0..ih {
            for x in 0..iw {
                let rate = counts[(y + dh) * ow + (x + dw)] as f64 / t_steps;
                let pred = rate >= thr;
                let gt = mask[y * iw + x] == 1;
                inter += (pred && gt) as usize;
                union += (pred || gt) as usize;
            }
        }
        iou_sum += inter as f64 / union.max(1) as f64;
    }
    let iou = iou_sum / n_seg as f64;

    let res = AccuracyResult {
        classifier_accuracy: acc,
        classifier_frames: n,
        python_snn_metric: net.meta.snn_metric,
        segmenter_iou: iou,
        segmenter_frames: n_seg,
    };
    let mut t = Table::new(
        "Accuracy (paper: 98.5% MNIST classification)",
        &["metric", "value", "frames", "python-side"]);
    t.row(&["classifier accuracy".into(), format!("{:.4}", acc),
            n.to_string(),
            res.python_snn_metric.map(|v| format!("{v:.4}"))
                .unwrap_or_default()]);
    t.row(&["segmentation IoU".into(), format!("{iou:.4}"),
            n_seg.to_string(),
            seg.meta.snn_metric.map(|v| format!("{v:.4}"))
                .unwrap_or_default()]);
    t.print();
    Ok(res)
}
