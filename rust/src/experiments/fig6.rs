//! Fig. 6 — the relation between output-channel spike counts and filter
//! magnitudes in the classifier's conv layers, with and without APRC.
//!
//! Shape to reproduce: the plain (same-pad) network shows an irregular
//! relation (low correlation); the APRC network shows an approximately
//! proportional one (high rank correlation on the positive-magnitude
//! side).

use anyhow::Result;


use super::common::{classifier_frames, pearson, ExperimentCtx};
use crate::metrics::Table;
use crate::snn::{FunctionalNet, NetworkWeights};

#[derive(Debug, Clone)]
pub struct LayerScatter {
    pub layer: usize,
    pub magnitudes: Vec<f64>,
    pub spike_counts: Vec<u64>,
    pub correlation: f64,
}

#[derive(Debug, Clone)]
pub struct Fig6Result {
    /// (a) without APRC (same-pad network).
    pub plain: Vec<LayerScatter>,
    /// (b) with APRC (full-pad network).
    pub aprc: Vec<LayerScatter>,
}

fn scatter(net: &NetworkWeights, frames: usize) -> Result<Vec<LayerScatter>> {
    let t = net.meta.timesteps;
    let (trains, _) = classifier_frames(0xF16_6, frames, t);
    let nconv = net.layers.iter()
        .filter(|l| matches!(l, crate::snn::LayerWeights::Conv { .. }))
        .count();
    let mut counts: Vec<Vec<u64>> = (0..nconv)
        .map(|l| vec![0u64; net.layer_output_shape(l).0])
        .collect();
    for train in &trains {
        let mut f = FunctionalNet::new(net);
        for step in f.run_frame(train) {
            for l in 0..nconv {
                for (c, cnt) in counts[l].iter_mut().enumerate() {
                    *cnt += step[l].spikes.nnz_channel(c) as u64;
                }
            }
        }
    }
    Ok((0..nconv).map(|l| {
        let mags = net.layers[l].filter_magnitudes();
        let sc: Vec<f64> = counts[l].iter().map(|&c| c as f64).collect();
        let correlation = pearson(&mags, &sc);
        LayerScatter {
            layer: l,
            magnitudes: mags,
            spike_counts: counts[l].clone(),
            correlation,
        }
    }).collect())
}

pub fn run(ctx: &ExperimentCtx) -> Result<Fig6Result> {
    let frames = ctx.frames_or(16);
    let plain_net = NetworkWeights::load(&ctx.artifacts,
                                         "classifier_plain")?;
    let aprc_net = NetworkWeights::load(&ctx.artifacts,
                                        "classifier_aprc")?;
    let res = Fig6Result {
        plain: scatter(&plain_net, frames)?,
        aprc: scatter(&aprc_net, frames)?,
    };

    for (tag, series) in [("(a) without APRC", &res.plain),
                          ("(b) with APRC", &res.aprc)] {
        let mut t = Table::new(
            format!("Fig 6{tag}: spikes vs filter magnitude (classifier)"),
            &["layer", "channel", "magnitude", "spikes"]);
        for s in series {
            for (c, (&m, &n)) in s.magnitudes.iter()
                .zip(&s.spike_counts).enumerate() {
                t.row(&[format!("conv{}", s.layer + 1), c.to_string(),
                        format!("{m:.3}"), n.to_string()]);
            }
            t.row(&[format!("conv{} corr", s.layer + 1), String::new(),
                    String::new(), format!("{:.3}", s.correlation)]);
        }
        t.print();
    }
    Ok(res)
}
