//! §IV headline gains — actual throughput increase from APRC + CBWS:
//! paper reports 1.4x on segmentation and 1.2x on classification.

use anyhow::Result;


use super::common::{classifier_frames, segmenter_frames, sweep_run,
                    ExperimentCtx};
use crate::coordinator::default_input_rates;
use crate::metrics::Table;
use crate::schedule::baselines::Contiguous;
use crate::schedule::cbws::Cbws;
use crate::schedule::{AprcPredictor, Scheduler};
use crate::sim::{ArchConfig, RunSummary, Simulator};
use crate::snn::{NetworkWeights, SpikeMap};

#[derive(Debug, Clone)]
pub struct TaskGain {
    pub task: String,
    pub fps_baseline: f64,
    pub fps_balanced: f64,
    pub gain: f64,
    pub paper_gain: f64,
}

#[derive(Debug, Clone)]
pub struct GainsResult {
    pub tasks: Vec<TaskGain>,
}

fn fps(ctx: &ExperimentCtx, net: &NetworkWeights,
       scheduler: &dyn Scheduler, trains: &[Vec<SpikeMap>]) -> Result<f64> {
    let arch = ArchConfig::default();
    let predictor = if scheduler.name() == "cbws" {
        // Balanced configuration: CBWS on the offline profiled
        // prediction (fig7's best realizable schedule).
        let calib: Vec<_> = if net.meta.in_shape[0] == 1 {
            super::common::classifier_frames(0xCA11B0, 4,
                                             net.meta.timesteps).0
        } else {
            super::common::segmenter_frames(0xCA11B0, 1,
                                            net.meta.timesteps).0
        };
        AprcPredictor::from_profile(net, &calib)
    } else {
        let rates = default_input_rates(net);
        AprcPredictor::from_network(net, &rates)
    };
    let sim = Simulator::new(arch, net, scheduler, &predictor);
    let frames = sweep_run(ctx, net, &sim, trains)?;
    Ok(RunSummary::from_frames(&frames, arch.clock_hz, arch.n_spes)
        .mean_fps)
}

pub fn run(ctx: &ExperimentCtx) -> Result<GainsResult> {
    let mut tasks = Vec::new();

    let seg_plain = NetworkWeights::load(&ctx.artifacts,
                                         "segmenter_plain")?;
    let seg_aprc = NetworkWeights::load(&ctx.artifacts, "segmenter_aprc")?;
    let (seg_trains, _) = segmenter_frames(0x6A17, ctx.frames_or(2),
                                           seg_aprc.meta.timesteps);
    let base = fps(ctx, &seg_plain, &Contiguous, &seg_trains)?;
    let bal = fps(ctx, &seg_aprc, &Cbws::default(), &seg_trains)?;
    tasks.push(TaskGain {
        task: "segmentation".into(),
        fps_baseline: base,
        fps_balanced: bal,
        gain: bal / base,
        paper_gain: 1.4,
    });

    let clf_plain = NetworkWeights::load(&ctx.artifacts,
                                         "classifier_plain")?;
    let clf_aprc = NetworkWeights::load(&ctx.artifacts,
                                        "classifier_aprc")?;
    let (clf_trains, _) = classifier_frames(0x6A17C, ctx.frames_or(2).max(8),
                                            clf_aprc.meta.timesteps);
    let base = fps(ctx, &clf_plain, &Contiguous, &clf_trains)?;
    let bal = fps(ctx, &clf_aprc, &Cbws::default(), &clf_trains)?;
    tasks.push(TaskGain {
        task: "classification".into(),
        fps_baseline: base,
        fps_balanced: bal,
        gain: bal / base,
        paper_gain: 1.2,
    });

    let res = GainsResult { tasks };
    let mut t = Table::new(
        "Throughput gain from APRC+CBWS (paper §IV: 1.4x seg, 1.2x classif)",
        &["task", "baseline FPS", "balanced FPS", "gain", "paper"]);
    for g in &res.tasks {
        t.row(&[g.task.clone(), format!("{:.1}", g.fps_baseline),
                format!("{:.1}", g.fps_balanced),
                format!("{:.2}x", g.gain),
                format!("{:.1}x", g.paper_gain)]);
    }
    t.print();
    Ok(res)
}
