//! Fig. 7 — balance ratio per layer of the segmentation network under
//! the four configurations the paper evaluates:
//!
//! * neither (plain conv + contiguous assignment)      — paper avg 69.19%
//! * CBWS only (plain conv + CBWS on plain magnitudes)  — paper avg 54.37%
//! * APRC only (full-pad conv + contiguous)             — plotted, no avg
//! * APRC + CBWS                                        — paper avg 95.69%
//!
//! Shape to reproduce: APRC+CBWS >> all others (>90%), CBWS-alone can be
//! *worse* than doing nothing (mispredicted magnitudes actively skew).
//! Also reports the classifier's pair (paper: 79.63% -> 94.14%).

use anyhow::Result;


use super::common::{classifier_frames, segmenter_frames, sweep_run,
                    ExperimentCtx};
use crate::metrics::Table;
use crate::schedule::baselines::Contiguous;
use crate::schedule::cbws::Cbws;
use crate::schedule::{AprcPredictor, Scheduler};
use crate::sim::{ArchConfig, RunSummary, Simulator};
use crate::snn::{NetworkWeights, SpikeMap};

#[derive(Debug, Clone)]
pub struct ConfigResult {
    pub label: String,
    pub per_layer_balance: Vec<f64>,
    pub average_balance: f64,
    pub mean_fps: f64,
}

#[derive(Debug, Clone)]
pub struct Fig7Result {
    pub segmenter: Vec<ConfigResult>,
    pub classifier: Vec<ConfigResult>,
}

fn run_config(ctx: &ExperimentCtx, net: &NetworkWeights,
              scheduler: &dyn Scheduler, label: &str,
              trains: &[Vec<SpikeMap>], arch: ArchConfig)
              -> Result<ConfigResult> {
    let rates = crate::coordinator::worker::default_input_rates(net);
    let predictor = AprcPredictor::from_network(net, &rates);
    let sim = Simulator::new(arch, net, scheduler, &predictor);
    let frames = sweep_run(ctx, net, &sim, trains)?;
    let summary = RunSummary::from_frames(&frames, arch.clock_hz,
                                          arch.n_spes);
    Ok(ConfigResult {
        label: label.into(),
        per_layer_balance: summary.per_layer_balance,
        average_balance: summary.mean_balance_weighted,
        mean_fps: summary.mean_fps,
    })
}

fn run_profiled(ctx: &ExperimentCtx, net: &NetworkWeights,
                trains: &[Vec<SpikeMap>], arch: ArchConfig)
                -> Result<ConfigResult> {
    // Offline calibration profile (distinct frames from the eval set).
    let calib: Vec<Vec<SpikeMap>> = if net.meta.in_shape[0] == 1 {
        super::common::classifier_frames(0xCA11B0, 4,
                                         net.meta.timesteps).0
    } else {
        super::common::segmenter_frames(0xCA11B0, 1,
                                        net.meta.timesteps).0
    };
    let predictor = AprcPredictor::from_profile(net, &calib);
    let sim = Simulator::new(arch, net, &Cbws::default(), &predictor);
    let frames = sweep_run(ctx, net, &sim, trains)?;
    let summary = RunSummary::from_frames(&frames, arch.clock_hz,
                                          arch.n_spes);
    Ok(ConfigResult {
        label: "profiled+cbws".into(),
        per_layer_balance: summary.per_layer_balance,
        average_balance: summary.mean_balance_weighted,
        mean_fps: summary.mean_fps,
    })
}

fn net_sweep(ctx: &ExperimentCtx, plain: &NetworkWeights,
             aprc: &NetworkWeights, trains_plain: &[Vec<SpikeMap>],
             trains_aprc: &[Vec<SpikeMap>]) -> Result<Vec<ConfigResult>> {
    let arch = ArchConfig::default();
    let cbws = Cbws::default();
    Ok(vec![
        run_config(ctx, plain, &Contiguous, "neither", trains_plain, arch)?,
        run_config(ctx, plain, &cbws, "cbws_only", trains_plain, arch)?,
        run_config(ctx, aprc, &Contiguous, "aprc_only", trains_aprc, arch)?,
        run_config(ctx, aprc, &cbws, "aprc+cbws", trains_aprc, arch)?,
        run_rectified(ctx, aprc, trains_aprc, arch)?,
        run_profiled(ctx, aprc, trains_aprc, arch)?,
    ])
}

/// Our rectified-Gaussian APRC extension (weight-only, zero profiling).
fn run_rectified(ctx: &ExperimentCtx, net: &NetworkWeights,
                 trains: &[Vec<SpikeMap>], arch: ArchConfig)
                 -> Result<ConfigResult> {
    let rates = crate::coordinator::worker::default_input_rates(net);
    let predictor = AprcPredictor::from_network_rectified(net, &rates, 0.1);
    let sim = Simulator::new(arch, net, &Cbws::default(), &predictor);
    let frames = sweep_run(ctx, net, &sim, trains)?;
    let summary = RunSummary::from_frames(&frames, arch.clock_hz,
                                          arch.n_spes);
    Ok(ConfigResult {
        label: "aprc-rg+cbws".into(),
        per_layer_balance: summary.per_layer_balance,
        average_balance: summary.mean_balance_weighted,
        mean_fps: summary.mean_fps,
    })
}

pub fn run(ctx: &ExperimentCtx) -> Result<Fig7Result> {
    let seg_plain = NetworkWeights::load(&ctx.artifacts,
                                         "segmenter_plain")?;
    let seg_aprc = NetworkWeights::load(&ctx.artifacts, "segmenter_aprc")?;
    let n_seg = ctx.frames_or(2);
    let (seg_trains, _) = segmenter_frames(0xF16_7, n_seg,
                                           seg_aprc.meta.timesteps);
    let segmenter = net_sweep(ctx, &seg_plain, &seg_aprc, &seg_trains,
                              &seg_trains)?;

    let clf_plain = NetworkWeights::load(&ctx.artifacts,
                                         "classifier_plain")?;
    let clf_aprc = NetworkWeights::load(&ctx.artifacts,
                                        "classifier_aprc")?;
    let n_clf = ctx.frames_or(2).max(8);
    let (clf_trains, _) = classifier_frames(0xF16_7C, n_clf,
                                            clf_aprc.meta.timesteps);
    let classifier = net_sweep(ctx, &clf_plain, &clf_aprc, &clf_trains,
                               &clf_trains)?;

    let res = Fig7Result { segmenter, classifier };
    for (name, series) in [("segmentation", &res.segmenter),
                           ("classification", &res.classifier)] {
        let nl = series[0].per_layer_balance.len();
        let mut headers: Vec<String> = vec!["config".into()];
        headers.extend((0..nl).map(|l| format!("L{}", l + 1)));
        headers.push("avg".into());
        let hdr_refs: Vec<&str> =
            headers.iter().map(|s| s.as_str()).collect();
        let mut t = Table::new(
            format!("Fig 7: balance ratio per layer ({name})"), &hdr_refs);
        for cfg in series {
            let mut row = vec![cfg.label.clone()];
            row.extend(cfg.per_layer_balance.iter()
                .map(|b| format!("{:.1}%", 100.0 * b)));
            row.push(format!("{:.2}%", 100.0 * cfg.average_balance));
            t.row(&row);
        }
        t.print();
    }
    Ok(res)
}
