//! Experiment harness — regenerates every table and figure of the paper
//! (DESIGN.md §5 maps experiment ids to paper artifacts).
//!
//! Each experiment prints the same rows/series the paper reports and
//! returns a serde-serializable struct so tests and benches can assert
//! on shapes (who wins, by what factor) rather than absolute numbers.

mod common;
pub mod fig2;
pub mod fig4c;
pub mod fig6;
pub mod fig7;
pub mod gains;
pub mod table1;
pub mod table2;
pub mod accuracy;
pub mod ablation;

pub use common::{load_net, classifier_frames, segmenter_frames,
                 sweep_run, trace_for, ExperimentCtx};
