//! Fig. 2 — motivation: spatio-temporal sparsity and channel imbalance.
//!
//! (a) per-layer spikerates of the segmentation network on one frame;
//! (b) per-channel spike summations of the representative 16-channel
//!     layer over 50 timesteps;
//! (c) the spike-rate distribution of those channels.
//!
//! Paper shape to reproduce: rates range roughly 2-18% with average
//! <8%; channel sums spread over orders of magnitude.

use anyhow::Result;


use super::common::{segmenter_frames, ExperimentCtx};
use crate::metrics::Table;
use crate::snn::{FunctionalNet, NetworkWeights};

#[derive(Debug, Clone)]
pub struct Fig2Result {
    /// (a) mean spikerate per spiking layer.
    pub layer_rates: Vec<f64>,
    /// (b) spike summation per channel of the 16-channel layer.
    pub channel_sums: Vec<u64>,
    /// (c) per-channel rates of that layer.
    pub channel_rates: Vec<f64>,
    /// max/min channel-sum ratio (the "orders of magnitude" claim).
    pub imbalance_ratio: f64,
}

/// Index of the representative 16-channel layer in the segmenter
/// (8-16-32-32-16-1: the 5th conv, index 4).
pub const REP_LAYER: usize = 4;

pub fn run(ctx: &ExperimentCtx) -> Result<Fig2Result> {
    let net = NetworkWeights::load(&ctx.artifacts, "segmenter_aprc")?;
    let t = net.meta.timesteps;
    let (trains, _) = segmenter_frames(0xF16_2, ctx.frames_or(1), t);

    let nl = net.layers.len();
    let mut spikes_per_layer = vec![0u64; nl];
    let mut neurons_per_layer = vec![0usize; nl];
    let (rep_c, rep_h, rep_w) = net.layer_output_shape(REP_LAYER);
    let mut channel_sums = vec![0u64; rep_c];

    for train in &trains {
        let mut f = FunctionalNet::new(&net);
        for step in f.run_frame(train) {
            for (l, out) in step.iter().enumerate() {
                spikes_per_layer[l] += out.spikes.nnz() as u64;
                neurons_per_layer[l] = out.spikes.len();
                if l == REP_LAYER {
                    for (c, s) in channel_sums.iter_mut().enumerate() {
                        *s += out.spikes.nnz_channel(c) as u64;
                    }
                }
            }
        }
    }

    let frames = trains.len() as f64;
    let layer_rates: Vec<f64> = (0..nl)
        .map(|l| spikes_per_layer[l] as f64
            / (neurons_per_layer[l] as f64 * t as f64 * frames))
        .collect();
    let channel_rates: Vec<f64> = channel_sums.iter()
        .map(|&s| s as f64 / (rep_h as f64 * rep_w as f64 * t as f64
            * frames))
        .collect();
    let max = *channel_sums.iter().max().unwrap() as f64;
    let min = *channel_sums.iter().min().unwrap() as f64;
    let res = Fig2Result {
        layer_rates,
        channel_sums,
        channel_rates,
        imbalance_ratio: max / min.max(1.0),
    };

    let mut ta = Table::new(
        "Fig 2(a): spikerate per spiking layer (segmenter)",
        &["layer", "spikerate"]);
    for (l, r) in res.layer_rates.iter().enumerate() {
        ta.row(&[format!("conv{}", l + 1), format!("{:.4}", r)]);
    }
    ta.row(&["average".into(),
             format!("{:.4}", res.layer_rates.iter().sum::<f64>()
                 / res.layer_rates.len() as f64)]);
    ta.print();

    let mut tb = Table::new(
        format!("Fig 2(b,c): channel spike sums, layer {} ({} ch, {} steps)",
                REP_LAYER + 1, rep_c, t),
        &["channel", "spike_sum", "rate"]);
    for c in 0..rep_c {
        tb.row(&[c.to_string(), res.channel_sums[c].to_string(),
                 format!("{:.5}", res.channel_rates[c])]);
    }
    tb.row(&["max/min".into(),
             format!("{:.1}x", res.imbalance_ratio), String::new()]);
    tb.print();
    Ok(res)
}
