//! Reporting helpers: fixed-width tables, SI formatting, serving stats.



/// A printable fixed-width table (the experiment harness prints the same
/// rows/series the paper's tables and figures report).
#[derive(Debug, Clone, Default)]
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: impl Into<String>,
               headers: &[&str]) -> Self {
        Self {
            title: title.into(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        self.rows.push(cells.to_vec());
    }

    /// Render with per-column widths.
    pub fn render(&self) -> String {
        let ncol = self.headers.len();
        let mut widths: Vec<usize> =
            self.headers.iter().map(|h| h.len()).collect();
        for r in &self.rows {
            for (i, c) in r.iter().enumerate().take(ncol) {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        out.push_str(&format!("== {} ==\n", self.title));
        let line = |cells: &[String], widths: &[usize]| -> String {
            cells.iter().zip(widths)
                .map(|(c, w)| format!("{c:<w$}"))
                .collect::<Vec<_>>()
                .join("  ")
        };
        out.push_str(&line(&self.headers, &widths));
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>()
            + 2 * (ncol - 1)));
        out.push('\n');
        for r in &self.rows {
            out.push_str(&line(r, &widths));
            out.push('\n');
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }
}

/// Format a value with SI prefix, trailing zeros trimmed (22600 ->
/// "22.6K", 42 -> "42") — padded zeros would misreport precision in
/// experiment tables and `ServingReport` summaries.
pub fn si(v: f64) -> String {
    let (scaled, suffix) = if v.abs() >= 1e9 {
        (v / 1e9, "G")
    } else if v.abs() >= 1e6 {
        (v / 1e6, "M")
    } else if v.abs() >= 1e3 {
        (v / 1e3, "K")
    } else {
        (v, "")
    };
    let num = format!("{scaled:.3}");
    let num = num.trim_end_matches('0').trim_end_matches('.');
    format!("{num}{suffix}")
}

/// Latency percentile helper for the serving coordinator. The input
/// must already be sorted ascending (debug-asserted): a percentile of
/// an unsorted vector is a silent lie.
pub fn percentile(sorted_us: &[u64], p: f64) -> u64 {
    debug_assert!(
        sorted_us.windows(2).all(|w| w[0] <= w[1]),
        "percentile() input must be sorted ascending"
    );
    if sorted_us.is_empty() {
        return 0;
    }
    let idx = ((sorted_us.len() - 1) as f64 * p / 100.0).round() as usize;
    sorted_us[idx.min(sorted_us.len() - 1)]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new("demo", &["a", "bbbb"]);
        t.row(&["xxx".into(), "1".into()]);
        let s = t.render();
        assert!(s.contains("demo"));
        assert!(s.contains("xxx  1"));
    }

    #[test]
    fn si_prefixes_trim_trailing_zeros() {
        assert_eq!(si(22_600.0), "22.6K");
        assert_eq!(si(0.11e9), "110M");
        assert_eq!(si(2.26e10), "22.6G");
        assert_eq!(si(42.0), "42");
        assert_eq!(si(1_234.0), "1.234K");
        assert_eq!(si(0.5), "0.5");
        assert_eq!(si(0.0), "0");
    }

    #[test]
    fn percentile_bounds() {
        let v = vec![1, 2, 3, 4, 100];
        assert_eq!(percentile(&v, 0.0), 1);
        assert_eq!(percentile(&v, 100.0), 100);
        assert_eq!(percentile(&v, 50.0), 3);
        assert_eq!(percentile(&[], 50.0), 0);
    }
}
