//! The cluster front router: one listener, N health-checked gateway
//! backends, cost-balanced placement, failover retry.
//!
//! # Shape
//!
//! ```text
//!  clients ──► client_loop (poll reactor, one thread)
//!                 │  Pending{conn, client_id, …} keyed by a fresh
//!                 │  router-internal id
//!                 ▼
//!             dispatch ──► per-backend IO thread ──► gateway
//!                 ▲            │  persistent pipelined connection,
//!                 │            │  heartbeats ride the same stream
//!             retry_loop ◄─────┘  (failures, ejection, failover)
//! ```
//!
//! * **Placement** is the paper's cost-balanced workload selection
//!   lifted to host granularity ([`super::placement`]): each request
//!   goes to the live backend mounting the target model with the
//!   least `cost_depth + inflight_cost`, where `cost_depth` comes
//!   from the backend's last heartbeat (protocol v2 `Heartbeat`
//!   frames, `coordinator/cost.rs` units) and `inflight_cost` is the
//!   router's own estimate of work it has sent but not yet seen
//!   answered.
//! * **Health**: every backend gets a heartbeat each
//!   `heartbeat_every` on its data connection; a heartbeat that goes
//!   unanswered for a full period, a connect error, or a lost
//!   connection is a strike ([`super::health`]). `eject_after`
//!   consecutive strikes eject the backend: it leaves the placement
//!   set, its in-flight requests fail over to survivors, and a probe
//!   loop readmits it after `readmit_after` consecutive successful
//!   probes.
//! * **Failover** re-dispatches under a *fresh* internal id (the old
//!   id is forgotten while the pending table is locked), so a
//!   delayed response from a presumed-dead backend finds no entry
//!   and is dropped instead of racing the retry into a duplicate
//!   client response. Requests are pure functions of their payload,
//!   so executing one twice is safe — the client still gets exactly
//!   one response. A killed backend therefore costs latency, never a
//!   lost request; after `retry_max` failed attempts the client gets
//!   an explicit `INTERNAL` error.
//!
//! The router speaks v1 and v2 on the client side (responses are
//! re-encoded at each client's version) and always v2 upstream.

use std::cmp::Reverse;
use std::collections::{BTreeMap, BinaryHeap, HashMap, VecDeque};
use std::io::{self, Write};
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream,
               ToSocketAddrs};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread;
use std::time::{Duration, Instant};

use anyhow::{ensure, Context, Result};

use crate::coordinator::NOMINAL_FRAME_COST;
use crate::data::SplitMix64;
use crate::obs::recorder::{self, TraceMeta};
use crate::obs::trace::{self, Stage};
use crate::server::client::Client;
use crate::server::loadgen::busy_backoff;
use crate::server::protocol::{parse_frame, DegradeInfo, ErrorCode,
                              ModelLoad, RequestBody, RequestExts,
                              ResponseBody, TraceContext, WireRequest,
                              WireResponse, CONN_ERR_ID, HEADER_LEN,
                              KIND_REQUEST, KIND_RESPONSE, V1, V2};
use crate::{log_error, log_info, log_warn};
use crate::server::reactor::{fd_of, poll, raise_nofile_limit, PollFd,
                             RecvBuf, Waker, POLLIN, POLLOUT};

use super::health::{HealthPolicy, HealthState, Transition};
use super::placement::{mounted_anywhere, pick_backend, BackendView};

/// "Not currently dispatched to any backend."
const UNASSIGNED: usize = usize::MAX;
/// Per-client-connection write backlog cap; a reader this far behind
/// is pathological and gets dropped rather than ballooning memory.
const WRITE_BUF_CAP: usize = 8 << 20;

/// Router tuning. `addr` may use port 0; see
/// [`Router::local_addr`].
#[derive(Debug, Clone)]
pub struct RouterConfig {
    pub addr: String,
    /// Backend gateway addresses (`HOST:PORT`), index-stable for the
    /// life of the router.
    pub backends: Vec<String>,
    pub heartbeat_every: Duration,
    /// Consecutive heartbeat failures before ejection.
    pub eject_after: u32,
    /// Consecutive probe successes before readmission.
    pub readmit_after: u32,
    /// Dispatch attempts per request before it fails with
    /// `INTERNAL` (failover and no-live-backend retries both count).
    pub retry_max: u32,
    pub max_conns: usize,
    pub connect_timeout: Duration,
    /// Seeds the retry-backoff jitter.
    pub seed: u64,
}

impl Default for RouterConfig {
    fn default() -> Self {
        Self {
            addr: "127.0.0.1:7979".into(),
            backends: Vec::new(),
            heartbeat_every: Duration::from_millis(200),
            eject_after: 3,
            readmit_after: 2,
            retry_max: 8,
            max_conns: 1024,
            connect_timeout: Duration::from_secs(1),
            seed: 0xFA11,
        }
    }
}

/// One admitted client request, keyed by router-internal id.
struct Pending {
    conn: u64,
    /// The id the client used; restored on the response.
    client_id: u64,
    /// Client's protocol version; responses re-encode at this.
    version: u8,
    body: RequestBody,
    model: String,
    attempts: u32,
    backend: usize,
    /// Predicted cost charged to `inflight_cost` while dispatched.
    cost: u64,
    /// Raw priority-class byte from the client's `EXT_PRIORITY`
    /// extension, forwarded verbatim on every dispatch (the backend
    /// validates it — a nonsense byte comes back as `BAD_REQUEST`).
    priority: Option<u8>,
    /// Tracing baggage, present only for `Infer` requests admitted
    /// while span recording was enabled.
    trace: Option<RouteTrace>,
}

/// Per-request tracing state. The root `route` span covers client
/// arrival to reply and is recorded when the request finishes
/// ([`finish_trace`]); each dispatch opens an `attempt` span whose id
/// is pre-allocated (it rides upstream as the [`TraceContext`]
/// parent, so backend-side spans nest under the attempt) and recorded
/// once the attempt resolves — success at [`route_response`], failure
/// at failover.
#[derive(Clone, Copy)]
struct RouteTrace {
    trace_id: [u8; 16],
    /// Parent of the root span (from the client's wire context; 0
    /// when the router originated the trace).
    parent: u64,
    /// Pre-allocated id of the root `route` span.
    root_span: u64,
    t_arrival_ns: u64,
    /// Open attempt's pre-allocated span id (0 = none open).
    attempt_span: u64,
    t_attempt_ns: u64,
    /// Interned model slot ([`trace::intern_model`]).
    model: u32,
}

#[derive(Default)]
struct BackendCounters {
    ejections: AtomicU64,
    readmissions: AtomicU64,
    failovers: AtomicU64,
    heartbeats_ok: AtomicU64,
    heartbeat_failures: AtomicU64,
    dispatched: AtomicU64,
    last_heartbeat_us: AtomicU64,
}

struct BackendShared {
    addr: String,
    live: AtomicBool,
    health: Mutex<HealthState>,
    /// Last heartbeat's per-model load report.
    loads: Mutex<Vec<ModelLoad>>,
    /// Cost dispatched but not yet answered — the between-heartbeats
    /// correction term for placement.
    inflight_cost: AtomicU64,
    counters: BackendCounters,
    /// Encoded frames awaiting the backend IO thread.
    outq: Mutex<VecDeque<Vec<u8>>>,
    waker: Waker,
}

struct RouterShared {
    policy: HealthPolicy,
    retry_max: u32,
    connect_timeout: Duration,
    backends: Vec<BackendShared>,
    pending: Mutex<HashMap<u64, Pending>>,
    /// Internal ids for upstream frames (requests *and* heartbeats
    /// share the space, so they can never collide).
    next_id: AtomicU64,
    /// Responses headed back to client connections, drained by the
    /// client loop.
    mailbox: Mutex<VecDeque<(u64, Vec<u8>)>>,
    client_waker: Waker,
    /// Min-heap of (due, internal id) redispatches.
    retry: Mutex<BinaryHeap<Reverse<(Instant, u64)>>>,
    retry_cv: Condvar,
    backoff_rng: Mutex<SplitMix64>,
    stop: AtomicBool,
    /// Set after worker threads join: tells the client loop to fail
    /// leftovers, flush, and exit.
    teardown: AtomicBool,
    stop_mu: Mutex<bool>,
    stop_cv: Condvar,
    requests: AtomicU64,
    served: AtomicU64,
    busy: AtomicU64,
    failed: AtomicU64,
    retries: AtomicU64,
}

impl RouterShared {
    fn stopping(&self) -> bool {
        self.stop.load(Ordering::SeqCst)
    }

    fn trigger_stop(&self) {
        if self.stop.swap(true, Ordering::SeqCst) {
            return;
        }
        for b in &self.backends {
            b.waker.wake();
        }
        self.client_waker.wake();
        self.retry_cv.notify_all();
        let mut stopped = self.stop_mu.lock().unwrap();
        *stopped = true;
        self.stop_cv.notify_all();
    }

    /// Queue a frame for a client connection and nudge the client
    /// loop.
    fn reply(&self, conn: u64, frame: Vec<u8>) {
        self.mailbox.lock().unwrap().push_back((conn, frame));
        self.client_waker.wake();
    }

    fn reply_error(&self, p: &Pending, code: ErrorCode, detail: &str) {
        let f = WireResponse {
            id: p.client_id,
            body: ResponseBody::Error {
                code,
                detail: detail.to_string(),
            },
        }
        .encode(p.version);
        self.reply(p.conn, f);
    }
}

/// Close out a traced request as the router answers the client:
/// record any still-open `attempt` span, the root `route` span, and
/// the flight-recorder completion. `error` marks both the root span
/// and the open attempt.
fn finish_trace(p: &Pending, error: bool) {
    let Some(t) = p.trace else { return };
    let now = trace::now_ns();
    if t.attempt_span != 0 {
        trace::record(&trace::SpanRecord {
            trace_id: t.trace_id,
            span_id: t.attempt_span,
            parent_span: t.root_span,
            start_ns: t.t_attempt_ns,
            end_ns: now,
            stage: Stage::Attempt,
            model: t.model,
            error,
            attr_a: p.backend as u64,
            attr_b: p.attempts as u64 + 1,
        });
    }
    trace::record(&trace::SpanRecord {
        trace_id: t.trace_id,
        span_id: t.root_span,
        parent_span: t.parent,
        start_ns: t.t_arrival_ns,
        end_ns: now,
        stage: Stage::Route,
        model: t.model,
        error,
        attr_a: p.attempts as u64 + 1,
        attr_b: 0,
    });
    recorder::complete(TraceMeta {
        trace_id: t.trace_id,
        model: t.model,
        latency_us: now.saturating_sub(t.t_arrival_ns) / 1_000,
        error,
    });
}

// ---------------------------------------------------- placement core

/// Place one pending request on a backend, or schedule a retry /
/// reject it. Called from the client loop (fresh requests), the
/// retry thread (redispatches) and backend threads (failover).
fn dispatch(shared: &Arc<RouterShared>, internal: u64) {
    let model = {
        let pending = shared.pending.lock().unwrap();
        match pending.get(&internal) {
            Some(p) => p.model.clone(),
            // Already answered (client gone, overflowed, …).
            None => return,
        }
    };
    let views: Vec<BackendView> = shared
        .backends
        .iter()
        .map(|b| BackendView {
            live: b.live.load(Ordering::SeqCst),
            models: b
                .loads
                .lock()
                .unwrap()
                .iter()
                .map(|m| (m.name.clone(), m.cost_depth))
                .collect(),
            inflight_cost: b.inflight_cost.load(Ordering::SeqCst),
        })
        .collect();
    match pick_backend(&views, &model) {
        Some(bi) => {
            let mut pending = shared.pending.lock().unwrap();
            let Some(p) = pending.get_mut(&internal) else {
                return;
            };
            p.backend = bi;
            let cost = p.cost;
            // Open this dispatch's `attempt` span: the id is chosen
            // now so it can ride upstream as the backend's parent;
            // the span itself is recorded when the attempt resolves.
            let ctx = p.trace.as_mut().map(|t| {
                t.attempt_span = trace::next_span_id();
                t.t_attempt_ns = trace::now_ns();
                TraceContext {
                    trace_id: t.trace_id,
                    parent_span: t.attempt_span,
                }
            });
            let enc = WireRequest {
                id: internal,
                body: p.body.clone(),
            }
            .encode_with_exts(&RequestExts {
                trace: ctx,
                priority: p.priority,
            });
            match enc {
                Ok(frame) => {
                    drop(pending);
                    let b = &shared.backends[bi];
                    b.inflight_cost.fetch_add(cost, Ordering::SeqCst);
                    b.counters.dispatched.fetch_add(1, Ordering::SeqCst);
                    b.outq.lock().unwrap().push_back(frame);
                    b.waker.wake();
                }
                Err(e) => {
                    let p = pending.remove(&internal).unwrap();
                    drop(pending);
                    shared.failed.fetch_add(1, Ordering::SeqCst);
                    finish_trace(&p, true);
                    shared.reply_error(
                        &p,
                        ErrorCode::BadRequest,
                        &format!("unroutable request: {e}"),
                    );
                }
            }
        }
        None => {
            // Distinguish "model unknown everywhere" (reject now)
            // from "no live backend right now" (retry) — but only
            // once at least one load report exists, else we would
            // reject everything in the startup gap.
            let loads_known =
                views.iter().any(|v| !v.models.is_empty());
            if loads_known && !mounted_anywhere(&views, &model) {
                let removed =
                    shared.pending.lock().unwrap().remove(&internal);
                if let Some(p) = removed {
                    shared.failed.fetch_add(1, Ordering::SeqCst);
                    finish_trace(&p, true);
                    shared.reply_error(
                        &p,
                        ErrorCode::BadRequest,
                        &format!(
                            "unknown model '{}' (no backend mounts \
                             it)",
                            p.model
                        ),
                    );
                }
                return;
            }
            schedule_retry(shared, internal, "no live backend");
        }
    }
}

/// Book a redispatch after a capped jittered backoff, or fail the
/// request once it is out of attempts.
fn schedule_retry(shared: &Arc<RouterShared>, internal: u64,
                  why: &str) {
    let attempts;
    {
        let mut pending = shared.pending.lock().unwrap();
        let Some(p) = pending.get_mut(&internal) else {
            return;
        };
        p.attempts += 1;
        p.backend = UNASSIGNED;
        attempts = p.attempts;
        if attempts > shared.retry_max {
            let p = pending.remove(&internal).unwrap();
            drop(pending);
            shared.failed.fetch_add(1, Ordering::SeqCst);
            log_error!("cluster",
                       "request for model '{}' failed after \
                        {attempts} attempts: {why}", p.model);
            finish_trace(&p, true);
            shared.reply_error(
                &p,
                ErrorCode::Internal,
                &format!(
                    "request failed after {attempts} attempts: {why}"
                ),
            );
            return;
        }
    }
    let delay = busy_backoff(
        &mut shared.backoff_rng.lock().unwrap(),
        attempts,
    );
    shared.retries.fetch_add(1, Ordering::SeqCst);
    shared
        .retry
        .lock()
        .unwrap()
        .push(Reverse((Instant::now() + delay, internal)));
    shared.retry_cv.notify_all();
}

/// Pops due redispatches off the backoff heap.
fn retry_loop(shared: Arc<RouterShared>) {
    loop {
        let due_id = {
            let mut heap = shared.retry.lock().unwrap();
            loop {
                if shared.stopping() {
                    return;
                }
                let now = Instant::now();
                let head = heap.peek().map(|r| {
                    let Reverse((t, id)) = *r;
                    (t, id)
                });
                match head {
                    None => {
                        let (h, _) = shared
                            .retry_cv
                            .wait_timeout(
                                heap,
                                Duration::from_millis(200),
                            )
                            .unwrap();
                        heap = h;
                    }
                    Some((due, _)) if due > now => {
                        let (h, _) = shared
                            .retry_cv
                            .wait_timeout(heap, due - now)
                            .unwrap();
                        heap = h;
                    }
                    Some((_, id)) => {
                        heap.pop();
                        break id;
                    }
                }
            }
        };
        dispatch(&shared, due_id);
    }
}

// -------------------------------------------------- health/failover

/// One strike against a backend; ejects (and fails over) on the
/// threshold strike.
fn note_failure(shared: &Arc<RouterShared>, bi: usize, why: &str) {
    let b = &shared.backends[bi];
    b.counters.heartbeat_failures.fetch_add(1, Ordering::SeqCst);
    let tr = b.health.lock().unwrap().on_failure(&shared.policy);
    if tr == Some(Transition::Ejected) {
        b.live.store(false, Ordering::SeqCst);
        b.counters.ejections.fetch_add(1, Ordering::SeqCst);
        log_warn!("cluster", "backend {} ejected ({why})", b.addr);
        failover_inflight(shared, bi, why);
    }
}

/// Move every request assigned to backend `bi` back to the retry
/// path under a *fresh* internal id, so a late response from the old
/// backend can never produce a duplicate client response.
fn failover_inflight(shared: &Arc<RouterShared>, bi: usize,
                     why: &str) {
    let b = &shared.backends[bi];
    b.outq.lock().unwrap().clear();
    b.inflight_cost.store(0, Ordering::SeqCst);
    let moved: Vec<u64> = {
        let mut pending = shared.pending.lock().unwrap();
        let ids: Vec<u64> = pending
            .iter()
            .filter(|(_, p)| p.backend == bi)
            .map(|(id, _)| *id)
            .collect();
        let mut new_ids = Vec::with_capacity(ids.len());
        for id in ids {
            let mut p = pending.remove(&id).unwrap();
            // The open attempt died with the backend: record it as an
            // errored sibling under the root `route` span, so the
            // failover shows up as attempt[n] (error) next to the
            // eventual attempt[n+1] on a survivor.
            if let Some(t) = p.trace.as_mut() {
                if t.attempt_span != 0 {
                    trace::record(&trace::SpanRecord {
                        trace_id: t.trace_id,
                        span_id: t.attempt_span,
                        parent_span: t.root_span,
                        start_ns: t.t_attempt_ns,
                        end_ns: trace::now_ns(),
                        stage: Stage::Attempt,
                        model: t.model,
                        error: true,
                        attr_a: bi as u64,
                        attr_b: p.attempts as u64 + 1,
                    });
                    t.attempt_span = 0;
                }
            }
            p.backend = UNASSIGNED;
            let nid = shared.next_id.fetch_add(1, Ordering::SeqCst);
            pending.insert(nid, p);
            new_ids.push(nid);
        }
        new_ids
    };
    if moved.is_empty() {
        return;
    }
    log_warn!("cluster",
              "failing over {} in-flight request(s) from backend {} \
               ({why})", moved.len(), b.addr);
    b.counters
        .failovers
        .fetch_add(moved.len() as u64, Ordering::SeqCst);
    for nid in moved {
        schedule_retry(shared, nid, why);
    }
}

/// Probe an ejected backend each period until it earns readmission.
fn probe_until_readmitted(shared: &Arc<RouterShared>, bi: usize) {
    let period = shared.policy.heartbeat_every;
    let read_timeout = period.max(Duration::from_millis(50));
    let b = &shared.backends[bi];
    while !shared.stopping() {
        sleep_interruptible(shared, period);
        if shared.stopping() {
            return;
        }
        let started = Instant::now();
        match probe_once(&b.addr, shared.connect_timeout, read_timeout)
        {
            Ok(models) => {
                b.counters.last_heartbeat_us.store(
                    started.elapsed().as_micros() as u64,
                    Ordering::SeqCst,
                );
                b.counters
                    .heartbeats_ok
                    .fetch_add(1, Ordering::SeqCst);
                *b.loads.lock().unwrap() = models;
                let tr =
                    b.health.lock().unwrap().on_success(&shared.policy);
                if tr == Some(Transition::Readmitted) {
                    b.live.store(true, Ordering::SeqCst);
                    b.counters
                        .readmissions
                        .fetch_add(1, Ordering::SeqCst);
                    log_info!("cluster", "backend {} readmitted",
                              b.addr);
                    return;
                }
            }
            Err(_) => {
                b.counters
                    .heartbeat_failures
                    .fetch_add(1, Ordering::SeqCst);
                let _ =
                    b.health.lock().unwrap().on_failure(&shared.policy);
            }
        }
    }
}

/// One fresh-connection heartbeat probe (used for readmission checks
/// and the startup load seed).
fn probe_once(addr: &str, connect_timeout: Duration,
              read_timeout: Duration) -> Result<Vec<ModelLoad>> {
    let mut c = Client::connect_timeout(addr, connect_timeout)?;
    c.set_read_timeout(Some(read_timeout))?;
    c.heartbeat()
}

fn sleep_interruptible(shared: &RouterShared, d: Duration) {
    let deadline = Instant::now() + d;
    while !shared.stopping() {
        let left = deadline.saturating_duration_since(Instant::now());
        if left.is_zero() {
            return;
        }
        thread::sleep(left.min(Duration::from_millis(20)));
    }
}

// ------------------------------------------------- backend IO thread

fn connect_upstream(addr: &str, timeout: Duration)
                    -> io::Result<TcpStream> {
    let mut last: Option<io::Error> = None;
    for sa in addr.to_socket_addrs()? {
        match TcpStream::connect_timeout(&sa, timeout) {
            Ok(s) => {
                let _ = s.set_nodelay(true);
                s.set_nonblocking(true)?;
                return Ok(s);
            }
            Err(e) => last = Some(e),
        }
    }
    Err(last.unwrap_or_else(|| {
        io::Error::new(
            io::ErrorKind::AddrNotAvailable,
            "address resolved to no candidates",
        )
    }))
}

/// Drain the backend's outq into the socket (partial-write aware).
fn write_outq(b: &BackendShared, mut s: &TcpStream,
              wr: &mut Option<(Vec<u8>, usize)>) -> io::Result<()> {
    loop {
        if wr.is_none() {
            match b.outq.lock().unwrap().pop_front() {
                Some(f) => *wr = Some((f, 0)),
                None => return Ok(()),
            }
        }
        let done = {
            let (buf, pos) = wr.as_mut().unwrap();
            match s.write(&buf[*pos..]) {
                Ok(0) => {
                    return Err(io::ErrorKind::WriteZero.into())
                }
                Ok(n) => {
                    *pos += n;
                    *pos == buf.len()
                }
                Err(e)
                    if e.kind() == io::ErrorKind::WouldBlock =>
                {
                    return Ok(())
                }
                Err(e)
                    if e.kind() == io::ErrorKind::Interrupted =>
                {
                    false
                }
                Err(e) => return Err(e),
            }
        };
        if done {
            *wr = None;
        }
    }
}

/// Read and route whatever the backend has sent. `Err` means the
/// connection is broken (EOF, IO damage, or framing damage).
fn read_upstream(shared: &Arc<RouterShared>, bi: usize,
                 mut s: &TcpStream, recv: &mut RecvBuf,
                 hb: &mut Option<(u64, Instant)>) -> io::Result<()> {
    match recv.fill_from(&mut s) {
        Ok(0) => return Err(io::ErrorKind::UnexpectedEof.into()),
        Ok(_) => {}
        Err(e) if e.kind() == io::ErrorKind::WouldBlock => {
            return Ok(())
        }
        Err(e) if e.kind() == io::ErrorKind::Interrupted => {
            return Ok(())
        }
        Err(e) => return Err(e),
    }
    loop {
        match parse_frame(recv.data(), KIND_RESPONSE) {
            Ok(Some((ver, total))) => {
                let body = recv.data()[HEADER_LEN..total].to_vec();
                recv.consume(total);
                handle_upstream_frame(shared, bi, ver, &body, hb);
            }
            Ok(None) => return Ok(()),
            Err(_) => {
                return Err(io::ErrorKind::InvalidData.into())
            }
        }
    }
}

fn handle_upstream_frame(shared: &Arc<RouterShared>, bi: usize,
                         ver: u8, body: &[u8],
                         hb: &mut Option<(u64, Instant)>) {
    let (resp, degrade) =
        match WireResponse::decode_body_ext(ver, body) {
        Ok(r) => r,
        // Undecodable body in a well-framed response: drop the one
        // frame, keep the stream.
        Err(_) => return,
    };
    if let Some((hb_id, sent)) = *hb {
        if resp.id == hb_id {
            *hb = None;
            let b = &shared.backends[bi];
            match resp.body {
                ResponseBody::Heartbeat { models } => {
                    b.counters.last_heartbeat_us.store(
                        sent.elapsed().as_micros() as u64,
                        Ordering::SeqCst,
                    );
                    b.counters
                        .heartbeats_ok
                        .fetch_add(1, Ordering::SeqCst);
                    *b.loads.lock().unwrap() = models;
                    // Any success clears the strike count.
                    let _ = b
                        .health
                        .lock()
                        .unwrap()
                        .on_success(&shared.policy);
                }
                // A v1 backend answers BAD_REQUEST: it cannot report
                // load and counts as unhealthy for cluster duty.
                _ => note_failure(
                    shared,
                    bi,
                    "heartbeat rejected by backend",
                ),
            }
            return;
        }
    }
    route_response(shared, bi, resp, degrade);
}

/// Hand a backend response back to the owning client connection,
/// re-encoded at the client's protocol version. A degrade notice from
/// the backend rides through untouched (and silently vanishes for v1
/// clients, exactly as it would talking to the gateway directly).
fn route_response(shared: &Arc<RouterShared>, bi: usize,
                  resp: WireResponse,
                  degrade: Option<DegradeInfo>) {
    let p = match shared.pending.lock().unwrap().remove(&resp.id) {
        Some(p) => p,
        // Stale: the request failed over (new id) or the client
        // vanished. The retry path owns it now; drop this copy.
        None => return,
    };
    if p.backend == bi {
        let b = &shared.backends[bi];
        let _ = b.inflight_cost.fetch_update(
            Ordering::SeqCst,
            Ordering::SeqCst,
            |v| Some(v.saturating_sub(p.cost)),
        );
    }
    let is_err = matches!(resp.body, ResponseBody::Error { .. });
    match &resp.body {
        ResponseBody::Error { code: ErrorCode::Busy, .. } => {
            shared.busy.fetch_add(1, Ordering::SeqCst);
        }
        ResponseBody::Error { .. } => {
            shared.failed.fetch_add(1, Ordering::SeqCst);
        }
        _ => {
            shared.served.fetch_add(1, Ordering::SeqCst);
        }
    }
    finish_trace(&p, is_err);
    let f = WireResponse { id: p.client_id, body: resp.body }
        .encode_with_degrade(p.version, degrade.as_ref());
    shared.reply(p.conn, f);
}

fn backend_loop(shared: Arc<RouterShared>, bi: usize) {
    let period = shared.policy.heartbeat_every;
    let b = &shared.backends[bi];
    let mut conn: Option<TcpStream> = None;
    let mut recv = RecvBuf::new();
    let mut wr: Option<(Vec<u8>, usize)> = None;
    let mut hb_inflight: Option<(u64, Instant)> = None;
    let mut next_hb = Instant::now();
    while !shared.stopping() {
        if !b.live.load(Ordering::SeqCst) {
            // Ejected: forget all connection state (any response
            // still in flight is orphaned — failover already
            // re-issued those requests) and probe until readmitted.
            if let Some(s) = conn.take() {
                let _ = s.shutdown(Shutdown::Both);
            }
            wr = None;
            hb_inflight = None;
            recv = RecvBuf::new();
            b.outq.lock().unwrap().clear();
            b.inflight_cost.store(0, Ordering::SeqCst);
            probe_until_readmitted(&shared, bi);
            next_hb = Instant::now() + period;
            continue;
        }
        if conn.is_none() {
            match connect_upstream(&b.addr, shared.connect_timeout) {
                Ok(s) => {
                    conn = Some(s);
                    recv = RecvBuf::new();
                    wr = None;
                    hb_inflight = None;
                    // Heartbeat immediately on a fresh connection.
                    next_hb = Instant::now();
                }
                Err(_) => {
                    note_failure(&shared, bi, "connect failed");
                    sleep_interruptible(&shared, period);
                    continue;
                }
            }
        }
        let now = Instant::now();
        if now >= next_hb {
            if hb_inflight.is_some() {
                // Previous heartbeat went a full period unanswered.
                hb_inflight = None;
                note_failure(&shared, bi, "heartbeat timed out");
                next_hb = now + period;
                if !b.live.load(Ordering::SeqCst) {
                    continue;
                }
            } else {
                let hb_id =
                    shared.next_id.fetch_add(1, Ordering::SeqCst);
                if let Ok(f) = (WireRequest {
                    id: hb_id,
                    body: RequestBody::Heartbeat,
                })
                .encode()
                {
                    b.outq.lock().unwrap().push_back(f);
                    hb_inflight = Some((hb_id, now));
                }
                next_hb = now + period;
            }
        }
        let Some(s) = conn.as_ref() else {
            continue;
        };
        let want_write =
            wr.is_some() || !b.outq.lock().unwrap().is_empty();
        let mut ev = POLLIN;
        if want_write {
            ev |= POLLOUT;
        }
        let mut fds = [
            PollFd::new(fd_of(s), ev),
            PollFd::new(b.waker.fd(), POLLIN),
        ];
        let timeout = next_hb
            .saturating_duration_since(Instant::now())
            .max(Duration::from_millis(1));
        let _ = poll(&mut fds, Some(timeout));
        b.waker.drain();
        let mut broken = write_outq(b, s, &mut wr).is_err();
        if !broken && fds[0].readable() {
            broken = read_upstream(
                &shared,
                bi,
                s,
                &mut recv,
                &mut hb_inflight,
            )
            .is_err();
        }
        if broken {
            if let Some(s) = conn.take() {
                let _ = s.shutdown(Shutdown::Both);
            }
            wr = None;
            hb_inflight = None;
            recv = RecvBuf::new();
            // Responses for anything in flight on this connection
            // can never arrive now — re-issue immediately, and let
            // the strike counter decide about ejection.
            failover_inflight(&shared, bi, "upstream connection lost");
            note_failure(&shared, bi, "upstream connection lost");
        }
    }
    if let Some(s) = conn {
        let _ = s.shutdown(Shutdown::Both);
    }
}

// ------------------------------------------------- client-side loop

struct CConn {
    stream: TcpStream,
    recv: RecvBuf,
    out: VecDeque<Vec<u8>>,
    out_bytes: usize,
    /// Bytes of `out.front()` already written.
    front_pos: usize,
    /// Last version seen from this client (errors pre-decode use it).
    ver: u8,
    /// Stop reading; close once the write backlog drains.
    closing: bool,
    dead: bool,
}

fn err_frame(ver: u8, id: u64, code: ErrorCode, detail: &str)
             -> Vec<u8> {
    WireResponse {
        id,
        body: ResponseBody::Error {
            code,
            detail: detail.to_string(),
        },
    }
    .encode(ver)
}

fn push_frame_c(c: &mut CConn, f: Vec<u8>) {
    if c.out_bytes + f.len() > WRITE_BUF_CAP {
        c.dead = true;
        return;
    }
    c.out_bytes += f.len();
    c.out.push_back(f);
    // Opportunistic flush so small replies don't wait a poll cycle.
    flush_conn(c);
}

fn flush_conn(c: &mut CConn) {
    loop {
        let Some(front) = c.out.front() else { return };
        let res = (&c.stream).write(&front[c.front_pos..]);
        let flen = front.len();
        match res {
            Ok(0) => {
                c.dead = true;
                return;
            }
            Ok(n) => {
                c.front_pos += n;
                c.out_bytes -= n;
                if c.front_pos == flen {
                    c.out.pop_front();
                    c.front_pos = 0;
                }
            }
            Err(e) if e.kind() == io::ErrorKind::WouldBlock => return,
            Err(e) if e.kind() == io::ErrorKind::Interrupted => {}
            Err(_) => {
                c.dead = true;
                return;
            }
        }
    }
}

fn read_client(shared: &Arc<RouterShared>, cid: u64, c: &mut CConn) {
    {
        let mut r = &c.stream;
        match c.recv.fill_from(&mut r) {
            Ok(0) => {
                c.dead = true;
                return;
            }
            Ok(_) => {}
            Err(e) if e.kind() == io::ErrorKind::WouldBlock => {}
            Err(e) if e.kind() == io::ErrorKind::Interrupted => {}
            Err(_) => {
                c.dead = true;
                return;
            }
        }
    }
    loop {
        match parse_frame(c.recv.data(), KIND_REQUEST) {
            Ok(Some((ver, total))) => {
                let body = c.recv.data()[HEADER_LEN..total].to_vec();
                c.recv.consume(total);
                c.ver = ver;
                on_client_request(shared, cid, c, ver, &body);
                if c.dead || c.closing {
                    return;
                }
            }
            Ok(None) => return,
            Err(e) => {
                // Framing damage: one typed error, then drop once
                // the backlog drains.
                let f = err_frame(
                    c.ver,
                    CONN_ERR_ID,
                    ErrorCode::BadRequest,
                    &format!("bad frame: {e}"),
                );
                push_frame_c(c, f);
                c.closing = true;
                return;
            }
        }
    }
}

fn on_client_request(shared: &Arc<RouterShared>, cid: u64,
                     c: &mut CConn, ver: u8, body: &[u8]) {
    let (req, exts) =
        match WireRequest::decode_body_ext(ver, body) {
        Ok(r) => r,
        Err(e) => {
            let f = err_frame(
                ver,
                CONN_ERR_ID,
                ErrorCode::BadRequest,
                &format!("undecodable request: {e}"),
            );
            push_frame_c(c, f);
            return;
        }
    };
    if req.id == CONN_ERR_ID {
        let f = err_frame(
            ver,
            CONN_ERR_ID,
            ErrorCode::BadRequest,
            "request id reserved for connection errors",
        );
        push_frame_c(c, f);
        return;
    }
    match req.body {
        // Answered by the router itself: the aggregated cluster
        // picture, not any single backend's.
        RequestBody::Metrics => {
            let text =
                render_cluster_metrics(&snapshot_report(shared));
            let f = WireResponse {
                id: req.id,
                body: ResponseBody::Metrics { text },
            }
            .encode(ver);
            push_frame_c(c, f);
        }
        RequestBody::Heartbeat => {
            let models = aggregate_loads(shared);
            let f = WireResponse {
                id: req.id,
                body: ResponseBody::Heartbeat { models },
            }
            .encode(ver);
            push_frame_c(c, f);
        }
        // Stops the router only; backends have their own lifecycle.
        RequestBody::Shutdown => {
            let f = WireResponse {
                id: req.id,
                body: ResponseBody::ShutdownAck,
            }
            .encode(ver);
            push_frame_c(c, f);
            shared.trigger_stop();
        }
        // The router's own flight-recorder dump (route/attempt
        // spans). Backend-side spans live in each backend's dump.
        RequestBody::Trace => {
            let f = WireResponse {
                id: req.id,
                body: ResponseBody::Trace {
                    json: recorder::dump_chrome_json(),
                },
            }
            .encode(ver);
            push_frame_c(c, f);
        }
        body @ (RequestBody::Infer { .. }
        | RequestBody::Info { .. }) => {
            if shared.stopping() {
                let f = err_frame(
                    ver,
                    req.id,
                    ErrorCode::ShuttingDown,
                    "router shutting down",
                );
                push_frame_c(c, f);
                return;
            }
            shared.requests.fetch_add(1, Ordering::SeqCst);
            let (model, cost) = match &body {
                RequestBody::Infer { model, .. } => {
                    (model.clone(), NOMINAL_FRAME_COST)
                }
                RequestBody::Info { model } => (model.clone(), 0),
                _ => unreachable!(),
            };
            // Adopt the client's trace context (its spans become our
            // root's parent) or start a fresh trace. Only `Infer`
            // carries the context upstream, so only it is traced.
            let tr = if trace::enabled()
                && matches!(body, RequestBody::Infer { .. })
            {
                let cx = exts.trace.unwrap_or(TraceContext {
                    trace_id: trace::gen_trace_id(),
                    parent_span: 0,
                });
                Some(RouteTrace {
                    trace_id: cx.trace_id,
                    parent: cx.parent_span,
                    root_span: trace::next_span_id(),
                    t_arrival_ns: trace::now_ns(),
                    attempt_span: 0,
                    t_attempt_ns: 0,
                    model: trace::intern_model(&model),
                })
            } else {
                None
            };
            let internal =
                shared.next_id.fetch_add(1, Ordering::SeqCst);
            shared.pending.lock().unwrap().insert(
                internal,
                Pending {
                    conn: cid,
                    client_id: req.id,
                    version: ver,
                    body,
                    model,
                    attempts: 0,
                    backend: UNASSIGNED,
                    cost,
                    priority: exts.priority,
                    trace: tr,
                },
            );
            dispatch(shared, internal);
        }
    }
}

/// Forget a vanished client's pending requests (their responses have
/// nowhere to go; in-flight cost is un-charged). Not counted as
/// failures — the router did not fail them, the client left.
fn purge_conn(shared: &Arc<RouterShared>, cid: u64) {
    let removed: Vec<Pending> = {
        let mut pending = shared.pending.lock().unwrap();
        let ids: Vec<u64> = pending
            .iter()
            .filter(|(_, p)| p.conn == cid)
            .map(|(id, _)| *id)
            .collect();
        ids.into_iter()
            .map(|id| pending.remove(&id).unwrap())
            .collect()
    };
    for p in removed {
        if p.backend != UNASSIGNED {
            if let Some(b) = shared.backends.get(p.backend) {
                let _ = b.inflight_cost.fetch_update(
                    Ordering::SeqCst,
                    Ordering::SeqCst,
                    |v| Some(v.saturating_sub(p.cost)),
                );
            }
        }
    }
}

/// Over-cap / shutdown shedding: one blocking best-effort error
/// frame, then close.
fn shed(s: TcpStream, stopping: bool) {
    let _ = s.set_nonblocking(false);
    let _ = s.set_write_timeout(Some(Duration::from_millis(200)));
    let (code, detail) = if stopping {
        (ErrorCode::ShuttingDown, "router shutting down")
    } else {
        (ErrorCode::Busy, "router connection cap reached")
    };
    let f = err_frame(V1, CONN_ERR_ID, code, detail);
    let mut s = s;
    let _ = s.write_all(&f);
    let _ = s.shutdown(Shutdown::Both);
}

fn final_flush(mut c: CConn) {
    let _ = c.stream.set_nonblocking(false);
    let _ = c
        .stream
        .set_write_timeout(Some(Duration::from_millis(500)));
    let mut first = true;
    while let Some(front) = c.out.pop_front() {
        let start = if first { c.front_pos } else { 0 };
        first = false;
        if c.stream.write_all(&front[start..]).is_err() {
            break;
        }
    }
    let _ = c.stream.shutdown(Shutdown::Both);
}

fn client_loop(shared: Arc<RouterShared>, listener: TcpListener,
               max_conns: usize) {
    let _ = listener.set_nonblocking(true);
    let mut conns: HashMap<u64, CConn> = HashMap::new();
    let mut next_conn: u64 = 1;
    loop {
        // Deliver queued responses to their connections.
        {
            let mut mail = shared.mailbox.lock().unwrap();
            while let Some((cid, f)) = mail.pop_front() {
                if let Some(c) = conns.get_mut(&cid) {
                    push_frame_c(c, f);
                }
            }
        }
        if shared.teardown.load(Ordering::SeqCst) {
            break;
        }
        let mut fds = Vec::with_capacity(conns.len() + 2);
        fds.push(PollFd::new(shared.client_waker.fd(), POLLIN));
        fds.push(PollFd::new(fd_of(&listener), POLLIN));
        let mut order: Vec<u64> = Vec::with_capacity(conns.len());
        for (&cid, c) in &conns {
            let mut ev = 0i16;
            if !c.closing && !c.dead {
                ev |= POLLIN;
            }
            if c.out_bytes > 0 {
                ev |= POLLOUT;
            }
            fds.push(PollFd::new(fd_of(&c.stream), ev));
            order.push(cid);
        }
        let _ =
            poll(&mut fds, Some(Duration::from_millis(100)));
        shared.client_waker.drain();
        if fds[1].readable() {
            loop {
                match listener.accept() {
                    Ok((s, _)) => {
                        if shared.stopping() {
                            shed(s, true);
                        } else if conns.len() >= max_conns {
                            shed(s, false);
                        } else {
                            let _ = s.set_nodelay(true);
                            let _ = s.set_nonblocking(true);
                            conns.insert(
                                next_conn,
                                CConn {
                                    stream: s,
                                    recv: RecvBuf::new(),
                                    out: VecDeque::new(),
                                    out_bytes: 0,
                                    front_pos: 0,
                                    ver: V2,
                                    closing: false,
                                    dead: false,
                                },
                            );
                            next_conn += 1;
                        }
                    }
                    Err(e)
                        if e.kind()
                            == io::ErrorKind::WouldBlock =>
                    {
                        break
                    }
                    Err(e)
                        if e.kind()
                            == io::ErrorKind::Interrupted => {}
                    Err(_) => break,
                }
            }
        }
        for (i, cid) in order.iter().enumerate() {
            let fd = &fds[i + 2];
            let Some(c) = conns.get_mut(cid) else { continue };
            if fd.writable() {
                flush_conn(c);
            }
            if !c.dead && !c.closing && fd.readable() {
                read_client(&shared, *cid, c);
            }
        }
        let gone: Vec<u64> = conns
            .iter()
            .filter(|(_, c)| {
                c.dead || (c.closing && c.out_bytes == 0)
            })
            .map(|(id, _)| *id)
            .collect();
        for cid in gone {
            if let Some(c) = conns.remove(&cid) {
                let _ = c.stream.shutdown(Shutdown::Both);
            }
            purge_conn(&shared, cid);
        }
    }
    // Teardown: every request still pending gets an explicit
    // SHUTTING_DOWN error, then backlogs flush blockingly.
    let leftovers: Vec<(u64, Vec<u8>)> = {
        let mut pending = shared.pending.lock().unwrap();
        pending
            .drain()
            .map(|(_, p)| {
                (
                    p.conn,
                    WireResponse {
                        id: p.client_id,
                        body: ResponseBody::Error {
                            code: ErrorCode::ShuttingDown,
                            detail: "router shutting down".into(),
                        },
                    }
                    .encode(p.version),
                )
            })
            .collect()
    };
    for (cid, f) in leftovers {
        shared.failed.fetch_add(1, Ordering::SeqCst);
        if let Some(c) = conns.get_mut(&cid) {
            c.out_bytes += f.len();
            c.out.push_back(f);
        }
    }
    {
        let mut mail = shared.mailbox.lock().unwrap();
        while let Some((cid, f)) = mail.pop_front() {
            if let Some(c) = conns.get_mut(&cid) {
                c.out_bytes += f.len();
                c.out.push_back(f);
            }
        }
    }
    for (_cid, c) in conns {
        final_flush(c);
    }
}

// ------------------------------------------------ reports & metrics

/// One backend's externally visible state.
#[derive(Debug, Clone)]
pub struct BackendSnapshot {
    pub addr: String,
    pub live: bool,
    pub ejections: u64,
    pub readmissions: u64,
    pub failovers: u64,
    pub heartbeats_ok: u64,
    pub heartbeat_failures: u64,
    pub dispatched: u64,
    /// Latency of the most recent successful heartbeat/probe.
    pub last_heartbeat_us: u64,
    pub inflight_cost: u64,
    pub models: Vec<ModelLoad>,
}

/// Router-wide counters plus per-backend snapshots.
#[derive(Debug, Clone)]
pub struct RouterReport {
    /// Infer/Info requests admitted (Metrics/Heartbeat/Shutdown are
    /// answered locally and not counted).
    pub requests: u64,
    pub served: u64,
    pub busy: u64,
    pub failed: u64,
    /// Redispatches booked (failover and no-live-backend retries).
    pub retries: u64,
    pub backends: Vec<BackendSnapshot>,
}

fn snapshot_report(shared: &RouterShared) -> RouterReport {
    RouterReport {
        requests: shared.requests.load(Ordering::SeqCst),
        served: shared.served.load(Ordering::SeqCst),
        busy: shared.busy.load(Ordering::SeqCst),
        failed: shared.failed.load(Ordering::SeqCst),
        retries: shared.retries.load(Ordering::SeqCst),
        backends: shared
            .backends
            .iter()
            .map(|b| BackendSnapshot {
                addr: b.addr.clone(),
                live: b.live.load(Ordering::SeqCst),
                ejections: b.counters.ejections.load(Ordering::SeqCst),
                readmissions: b
                    .counters
                    .readmissions
                    .load(Ordering::SeqCst),
                failovers: b.counters.failovers.load(Ordering::SeqCst),
                heartbeats_ok: b
                    .counters
                    .heartbeats_ok
                    .load(Ordering::SeqCst),
                heartbeat_failures: b
                    .counters
                    .heartbeat_failures
                    .load(Ordering::SeqCst),
                dispatched: b
                    .counters
                    .dispatched
                    .load(Ordering::SeqCst),
                last_heartbeat_us: b
                    .counters
                    .last_heartbeat_us
                    .load(Ordering::SeqCst),
                inflight_cost: b.inflight_cost.load(Ordering::SeqCst),
                models: b.loads.lock().unwrap().clone(),
            })
            .collect(),
    }
}

/// Cluster-wide load picture a client `Heartbeat` gets back: per
/// model, summed over *live* backends.
fn aggregate_loads(shared: &RouterShared) -> Vec<ModelLoad> {
    let mut agg: BTreeMap<String, ModelLoad> = BTreeMap::new();
    for b in &shared.backends {
        if !b.live.load(Ordering::SeqCst) {
            continue;
        }
        for m in b.loads.lock().unwrap().iter() {
            let e = agg.entry(m.name.clone()).or_insert_with(|| {
                ModelLoad {
                    name: m.name.clone(),
                    cost_depth: 0,
                    cost_capacity: 0,
                    depth: 0,
                    capacity: 0,
                }
            });
            e.cost_depth = e.cost_depth.saturating_add(m.cost_depth);
            e.cost_capacity =
                e.cost_capacity.saturating_add(m.cost_capacity);
            e.depth = e.depth.saturating_add(m.depth);
            e.capacity = e.capacity.saturating_add(m.capacity);
        }
    }
    agg.into_values().collect()
}

/// Prometheus-style plaintext exposition of a [`RouterReport`] —
/// per-backend series labelled `{backend="host:port"}`, cluster
/// totals, and per-model rollups over live backends. Same format as
/// the gateway's `/metrics` equivalent (the `Metrics` request).
pub fn render_cluster_metrics(r: &RouterReport) -> String {
    use std::fmt::Write as _;
    fn series(out: &mut String, name: &str, kind: &str,
              backends: &[BackendSnapshot],
              f: &dyn Fn(&BackendSnapshot) -> f64) {
        use std::fmt::Write as _;
        let _ = writeln!(out, "# TYPE {name} {kind}");
        for b in backends {
            let _ = writeln!(
                out,
                "{name}{{backend=\"{}\"}} {}",
                b.addr,
                f(b)
            );
        }
    }
    let mut out = String::with_capacity(4096);
    series(&mut out, "skydiver_backend_state", "gauge", &r.backends,
           &|b| if b.live { 1.0 } else { 0.0 });
    series(&mut out, "skydiver_backend_ejections_total", "counter",
           &r.backends, &|b| b.ejections as f64);
    series(&mut out, "skydiver_backend_readmissions_total", "counter",
           &r.backends, &|b| b.readmissions as f64);
    series(&mut out, "skydiver_backend_failovers_total", "counter",
           &r.backends, &|b| b.failovers as f64);
    series(&mut out, "skydiver_backend_heartbeats_ok_total",
           "counter", &r.backends, &|b| b.heartbeats_ok as f64);
    series(&mut out, "skydiver_backend_heartbeat_failures_total",
           "counter", &r.backends,
           &|b| b.heartbeat_failures as f64);
    series(&mut out, "skydiver_backend_heartbeat_latency_us", "gauge",
           &r.backends, &|b| b.last_heartbeat_us as f64);
    series(&mut out, "skydiver_backend_dispatched_total", "counter",
           &r.backends, &|b| b.dispatched as f64);
    series(&mut out, "skydiver_backend_inflight_cost", "gauge",
           &r.backends, &|b| b.inflight_cost as f64);
    let live = r.backends.iter().filter(|b| b.live).count();
    let _ = writeln!(out, "# TYPE skydiver_cluster_backends_live \
                           gauge");
    let _ = writeln!(out, "skydiver_cluster_backends_live {live}");
    for (name, v) in [
        ("skydiver_cluster_requests_total", r.requests),
        ("skydiver_cluster_served_total", r.served),
        ("skydiver_cluster_busy_total", r.busy),
        ("skydiver_cluster_failed_total", r.failed),
        ("skydiver_cluster_retries_total", r.retries),
    ] {
        let _ = writeln!(out, "# TYPE {name} counter");
        let _ = writeln!(out, "{name} {v}");
    }
    let mut agg: BTreeMap<&str, (u64, u64)> = BTreeMap::new();
    for b in r.backends.iter().filter(|b| b.live) {
        for m in &b.models {
            let e = agg.entry(m.name.as_str()).or_insert((0, 0));
            e.0 = e.0.saturating_add(m.cost_depth);
            e.1 = e.1.saturating_add(m.depth as u64);
        }
    }
    let _ = writeln!(out, "# TYPE skydiver_cluster_model_cost_depth \
                           gauge");
    for (name, (cd, _)) in &agg {
        let _ = writeln!(
            out,
            "skydiver_cluster_model_cost_depth{{model=\"{name}\"}} \
             {cd}"
        );
    }
    let _ = writeln!(out, "# TYPE skydiver_cluster_model_queue_depth \
                           gauge");
    for (name, (_, d)) in &agg {
        let _ = writeln!(
            out,
            "skydiver_cluster_model_queue_depth{{model=\"{name}\"}} \
             {d}"
        );
    }
    crate::obs::render_build_info(&mut out);
    trace::render_stage_metrics(&mut out);
    out
}

// ------------------------------------------------------- public API

/// A running router. Threads: one client reactor, one IO thread per
/// backend, one retry timer.
pub struct Router {
    shared: Arc<RouterShared>,
    local_addr: SocketAddr,
    client: Option<thread::JoinHandle<()>>,
    backends: Vec<thread::JoinHandle<()>>,
    retry: Option<thread::JoinHandle<()>>,
}

/// Clonable handle that can stop the router from another thread.
pub struct RouterStop {
    shared: Arc<RouterShared>,
}

impl RouterStop {
    pub fn trigger(&self) {
        self.shared.trigger_stop();
    }
}

impl Clone for RouterStop {
    fn clone(&self) -> Self {
        Self { shared: self.shared.clone() }
    }
}

impl Router {
    pub fn start(cfg: RouterConfig) -> Result<Self> {
        ensure!(
            !cfg.backends.is_empty(),
            "router needs at least one backend address"
        );
        let listener = TcpListener::bind(&cfg.addr)
            .with_context(|| format!("binding router to {}", cfg.addr))?;
        let local_addr = listener.local_addr()?;
        let _ = raise_nofile_limit(
            (cfg.max_conns as u64 + cfg.backends.len() as u64 + 64)
                .max(1024),
        );
        let policy = HealthPolicy {
            heartbeat_every: cfg.heartbeat_every,
            eject_after: cfg.eject_after,
            readmit_after: cfg.readmit_after,
        };
        let mut backends = Vec::with_capacity(cfg.backends.len());
        for addr in &cfg.backends {
            backends.push(BackendShared {
                addr: addr.clone(),
                live: AtomicBool::new(true),
                health: Mutex::new(HealthState::new()),
                loads: Mutex::new(Vec::new()),
                inflight_cost: AtomicU64::new(0),
                counters: BackendCounters::default(),
                outq: Mutex::new(VecDeque::new()),
                waker: Waker::new()
                    .context("creating backend waker")?,
            });
        }
        let shared = Arc::new(RouterShared {
            policy,
            retry_max: cfg.retry_max,
            connect_timeout: cfg.connect_timeout,
            backends,
            pending: Mutex::new(HashMap::new()),
            next_id: AtomicU64::new(1),
            mailbox: Mutex::new(VecDeque::new()),
            client_waker: Waker::new()
                .context("creating client waker")?,
            retry: Mutex::new(BinaryHeap::new()),
            retry_cv: Condvar::new(),
            backoff_rng: Mutex::new(SplitMix64::new(cfg.seed)),
            stop: AtomicBool::new(false),
            teardown: AtomicBool::new(false),
            stop_mu: Mutex::new(false),
            stop_cv: Condvar::new(),
            requests: AtomicU64::new(0),
            served: AtomicU64::new(0),
            busy: AtomicU64::new(0),
            failed: AtomicU64::new(0),
            retries: AtomicU64::new(0),
        });
        // Best-effort synchronous load seed, so the very first
        // requests have somewhere to go instead of waiting out a
        // heartbeat period. A backend that isn't up yet stays
        // unreported (and unplaceable) until its first heartbeat.
        for b in &shared.backends {
            if let Ok(models) = probe_once(
                &b.addr,
                cfg.connect_timeout,
                cfg.heartbeat_every.max(Duration::from_millis(50)),
            ) {
                *b.loads.lock().unwrap() = models;
            }
        }
        let client = {
            let sh = shared.clone();
            let max_conns = cfg.max_conns;
            thread::Builder::new()
                .name("router-client".into())
                .spawn(move || client_loop(sh, listener, max_conns))
                .context("spawning router client thread")?
        };
        let mut bthreads = Vec::with_capacity(shared.backends.len());
        for bi in 0..shared.backends.len() {
            let sh = shared.clone();
            bthreads.push(
                thread::Builder::new()
                    .name(format!("router-backend-{bi}"))
                    .spawn(move || backend_loop(sh, bi))
                    .context("spawning router backend thread")?,
            );
        }
        let retry = {
            let sh = shared.clone();
            thread::Builder::new()
                .name("router-retry".into())
                .spawn(move || retry_loop(sh))
                .context("spawning router retry thread")?
        };
        log_info!("cluster",
                  "router listening on {local_addr} ({} backend(s), \
                   tracing {})", shared.backends.len(),
                  if trace::enabled() { "on" } else { "off" });
        Ok(Self {
            shared,
            local_addr,
            client: Some(client),
            backends: bthreads,
            retry: Some(retry),
        })
    }

    /// The bound client-facing address (resolves port 0).
    pub fn local_addr(&self) -> SocketAddr {
        self.local_addr
    }

    pub fn stop_handle(&self) -> RouterStop {
        RouterStop { shared: self.shared.clone() }
    }

    /// Point-in-time counters; safe to call while serving.
    pub fn snapshot(&self) -> RouterReport {
        snapshot_report(&self.shared)
    }

    /// Block until something stops the router (a wire `Shutdown`, a
    /// [`RouterStop`], Ctrl-C handling in the CLI), then join the
    /// threads and return the final report.
    pub fn wait(mut self) -> Result<RouterReport> {
        {
            let mut stopped = self.shared.stop_mu.lock().unwrap();
            while !*stopped {
                stopped = self.shared.stop_cv.wait(stopped).unwrap();
            }
        }
        self.join_all();
        Ok(snapshot_report(&self.shared))
    }

    pub fn stop_and_wait(self) -> Result<RouterReport> {
        self.shared.trigger_stop();
        self.wait()
    }

    fn join_all(&mut self) {
        for h in self.backends.drain(..) {
            let _ = h.join();
        }
        if let Some(h) = self.retry.take() {
            let _ = h.join();
        }
        // Workers are quiesced; now the client loop can fail
        // leftovers and flush without racing new responses.
        self.shared.teardown.store(true, Ordering::SeqCst);
        self.shared.client_waker.wake();
        if let Some(h) = self.client.take() {
            let _ = h.join();
        }
    }
}

impl Drop for Router {
    fn drop(&mut self) {
        if self.client.is_none()
            && self.retry.is_none()
            && self.backends.is_empty()
        {
            return;
        }
        self.shared.trigger_stop();
        self.join_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn report() -> RouterReport {
        RouterReport {
            requests: 10,
            served: 7,
            busy: 2,
            failed: 1,
            retries: 3,
            backends: vec![
                BackendSnapshot {
                    addr: "127.0.0.1:7001".into(),
                    live: true,
                    ejections: 0,
                    readmissions: 0,
                    failovers: 0,
                    heartbeats_ok: 12,
                    heartbeat_failures: 0,
                    dispatched: 6,
                    last_heartbeat_us: 250,
                    inflight_cost: 10_000,
                    models: vec![ModelLoad {
                        name: "cls".into(),
                        cost_depth: 40_000,
                        cost_capacity: u64::MAX,
                        depth: 4,
                        capacity: 64,
                    }],
                },
                BackendSnapshot {
                    addr: "127.0.0.1:7002".into(),
                    live: false,
                    ejections: 1,
                    readmissions: 0,
                    failovers: 5,
                    heartbeats_ok: 3,
                    heartbeat_failures: 4,
                    dispatched: 5,
                    last_heartbeat_us: 300,
                    inflight_cost: 0,
                    models: vec![ModelLoad {
                        name: "cls".into(),
                        cost_depth: 999,
                        cost_capacity: u64::MAX,
                        depth: 1,
                        capacity: 64,
                    }],
                },
            ],
        }
    }

    #[test]
    fn metrics_exposition_has_the_advertised_series() {
        let text = render_cluster_metrics(&report());
        for needle in [
            "# TYPE skydiver_backend_state gauge",
            "skydiver_backend_state{backend=\"127.0.0.1:7001\"} 1",
            "skydiver_backend_state{backend=\"127.0.0.1:7002\"} 0",
            "skydiver_backend_ejections_total{backend=\
             \"127.0.0.1:7002\"} 1",
            "skydiver_backend_failovers_total{backend=\
             \"127.0.0.1:7002\"} 5",
            "skydiver_backend_heartbeat_latency_us{backend=\
             \"127.0.0.1:7001\"} 250",
            "skydiver_cluster_backends_live 1",
            "skydiver_cluster_requests_total 10",
            "skydiver_cluster_retries_total 3",
        ] {
            assert!(text.contains(needle), "missing: {needle}");
        }
        // Model rollups only sum over live backends: the ejected
        // backend's 999 must not leak in.
        assert!(text.contains(
            "skydiver_cluster_model_cost_depth{model=\"cls\"} 40000"
        ));
    }

    #[test]
    fn default_config_is_sane() {
        let cfg = RouterConfig::default();
        assert!(cfg.retry_max >= 1);
        assert!(cfg.eject_after >= 1);
        assert!(cfg.readmit_after >= 1);
        assert!(!cfg.heartbeat_every.is_zero());
    }

    #[test]
    fn start_refuses_zero_backends() {
        let cfg = RouterConfig {
            addr: "127.0.0.1:0".into(),
            ..RouterConfig::default()
        };
        assert!(Router::start(cfg).is_err());
    }
}
