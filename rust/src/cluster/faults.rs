//! Deterministic fault injection for the serving plane.
//!
//! [`FaultProxy`] is a frame-aware TCP proxy that sits between a
//! client (or the cluster router) and one gateway and injects
//! failures drawn from a seeded [`FaultPlan`] — the same seed and the
//! same connection order reproduce the same fault sequence, so chaos
//! tests are debuggable instead of flaky. It is used two ways:
//!
//! * hermetically, from `rust/tests/integration_cluster.rs`, where
//!   [`FaultProxy::kill`]/[`FaultProxy::revive`] simulate a
//!   SIGKILL'd-and-restarted backend without spawning processes;
//! * operationally, behind the hidden `serve --inject-faults SPEC`
//!   flag, which interposes the proxy in front of a real gateway for
//!   manual resilience drills.
//!
//! Faults are applied per frame (the proxy parses the protocol in
//! both directions), so a plan can shed *requests* with BUSY storms
//! while leaving the byte stream intact, or corrupt the stream
//! itself with truncation:
//!
//! * `drop` — probability a fresh connection is closed at accept;
//! * `busy` — probability an `Infer` request is answered locally
//!   with `BUSY` instead of being forwarded (a busy storm);
//! * `blackhole` — probability a response is swallowed (request
//!   delivered and executed, answer never arrives — what a client
//!   read timeout exists for);
//! * `delay_ms`/`delay_p` — probability a response is delayed by a
//!   fixed amount before forwarding;
//! * `truncate` — probability a response frame is cut mid-frame and
//!   both connections torn down (framing damage).

use std::io::Write;
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::thread;
use std::time::Duration;

use anyhow::{bail, Context, Result};

use crate::data::SplitMix64;
use crate::server::protocol::{read_frame, ErrorCode, ResponseBody,
                              WireResponse, KIND_REQUEST,
                              KIND_RESPONSE, MAGIC};

/// Seeded fault probabilities. Parsed from a `key=value` comma list,
/// e.g. `busy=0.1,drop=0.05,blackhole=0.01,delay_ms=5,delay_p=0.2,
/// truncate=0.01,seed=7`. Omitted keys default to "off".
#[derive(Debug, Clone, PartialEq)]
pub struct FaultPlan {
    pub seed: u64,
    /// P(close a fresh connection at accept).
    pub conn_drop: f64,
    /// P(answer an `Infer` request with BUSY instead of forwarding).
    pub busy: f64,
    /// P(swallow a response frame).
    pub blackhole: f64,
    /// P(cut a response frame mid-frame and drop the connection).
    pub truncate: f64,
    /// Fixed response delay, applied with probability `delay_p`.
    pub delay: Duration,
    pub delay_p: f64,
}

impl FaultPlan {
    /// The all-off plan (a transparent proxy).
    pub fn none() -> Self {
        Self {
            seed: 0,
            conn_drop: 0.0,
            busy: 0.0,
            blackhole: 0.0,
            truncate: 0.0,
            delay: Duration::ZERO,
            delay_p: 0.0,
        }
    }

    pub fn is_noop(&self) -> bool {
        self.conn_drop == 0.0
            && self.busy == 0.0
            && self.blackhole == 0.0
            && self.truncate == 0.0
            && (self.delay.is_zero() || self.delay_p == 0.0)
    }

    /// Parse a `key=value,key=value` spec. Unknown keys and
    /// out-of-range probabilities are errors — a typo'd fault plan
    /// silently injecting nothing would defeat the drill.
    pub fn parse(spec: &str) -> Result<Self> {
        let mut plan = Self::none();
        for part in spec.split(',') {
            let part = part.trim();
            if part.is_empty() {
                continue;
            }
            let (k, v) = part.split_once('=').with_context(|| {
                format!("fault spec part '{part}' is not key=value")
            })?;
            match k.trim() {
                "seed" => {
                    plan.seed = v.trim().parse().with_context(|| {
                        format!("fault seed '{v}' is not a u64")
                    })?;
                }
                "drop" => plan.conn_drop = prob(k, v)?,
                "busy" => plan.busy = prob(k, v)?,
                "blackhole" => plan.blackhole = prob(k, v)?,
                "truncate" => plan.truncate = prob(k, v)?,
                "delay_p" => plan.delay_p = prob(k, v)?,
                "delay_ms" => {
                    let ms: u64 =
                        v.trim().parse().with_context(|| {
                            format!("delay_ms '{v}' is not a u64")
                        })?;
                    plan.delay = Duration::from_millis(ms);
                }
                other => bail!(
                    "unknown fault key '{other}' (known: drop, busy, \
                     blackhole, truncate, delay_ms, delay_p, seed)"),
            }
        }
        Ok(plan)
    }
}

fn prob(key: &str, v: &str) -> Result<f64> {
    let p: f64 = v.trim().parse().with_context(|| {
        format!("fault probability {key}='{v}' is not a number")
    })?;
    if !(0.0..=1.0).contains(&p) {
        bail!("fault probability {key}={p} outside [0, 1]");
    }
    Ok(p)
}

/// One biased coin flip.
fn hit(rng: &mut SplitMix64, p: f64) -> bool {
    if p <= 0.0 {
        return false;
    }
    if p >= 1.0 {
        return true;
    }
    (rng.next_below(1_000_000) as f64) < p * 1e6
}

struct ProxyShared {
    plan: FaultPlan,
    upstream: String,
    /// Simulated total failure: every proxied connection is severed
    /// and fresh accepts are closed immediately.
    down: AtomicBool,
    stop: AtomicBool,
    /// Live proxied sockets (both halves), registered so
    /// [`FaultProxy::kill`] can sever them all at once.
    conns: Mutex<Vec<TcpStream>>,
    conn_seq: AtomicU64,
}

/// A running fault-injection proxy (one listener, thread per proxied
/// connection). Dropping it stops the listener and severs every
/// proxied connection.
pub struct FaultProxy {
    addr: SocketAddr,
    shared: Arc<ProxyShared>,
    accept: Option<thread::JoinHandle<()>>,
}

impl FaultProxy {
    /// Listen on `listen` (e.g. `127.0.0.1:0`) and forward to the
    /// gateway at `upstream`, injecting faults per `plan`.
    pub fn start(listen: &str, upstream: &str, plan: FaultPlan)
                 -> Result<Self> {
        let listener = TcpListener::bind(listen).with_context(|| {
            format!("binding fault proxy to {listen}")
        })?;
        let addr = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let shared = Arc::new(ProxyShared {
            plan,
            upstream: upstream.to_string(),
            down: AtomicBool::new(false),
            stop: AtomicBool::new(false),
            conns: Mutex::new(Vec::new()),
            conn_seq: AtomicU64::new(0),
        });
        let accept = {
            let shared = shared.clone();
            thread::spawn(move || accept_loop(listener, shared))
        };
        Ok(Self { addr, shared, accept: Some(accept) })
    }

    /// The address clients (or the router) should connect to.
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Simulate a SIGKILL of the backend *as seen through this
    /// proxy*: sever every proxied connection mid-stream and refuse
    /// (accept-then-close) new ones until [`revive`](Self::revive).
    /// The upstream gateway itself keeps running.
    pub fn kill(&self) {
        self.shared.down.store(true, Ordering::SeqCst);
        let mut conns = self.shared.conns.lock().unwrap();
        for s in conns.drain(..) {
            let _ = s.shutdown(Shutdown::Both);
        }
    }

    /// End a simulated outage: fresh connections proxy again.
    pub fn revive(&self) {
        self.shared.down.store(false, Ordering::SeqCst);
    }

    /// Stop the proxy: sever everything and join the accept loop.
    pub fn shutdown(mut self) {
        self.stop_inner();
    }

    fn stop_inner(&mut self) {
        self.shared.stop.store(true, Ordering::SeqCst);
        self.kill();
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
    }
}

impl Drop for FaultProxy {
    fn drop(&mut self) {
        self.stop_inner();
    }
}

fn accept_loop(listener: TcpListener, shared: Arc<ProxyShared>) {
    while !shared.stop.load(Ordering::SeqCst) {
        match listener.accept() {
            Ok((s, _)) => {
                if shared.down.load(Ordering::SeqCst) {
                    // "Backend is dead": the TCP handshake may
                    // complete (kernel backlog), but the connection
                    // dies immediately.
                    let _ = s.shutdown(Shutdown::Both);
                    continue;
                }
                let shared = shared.clone();
                thread::spawn(move || proxy_conn(s, shared));
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                thread::sleep(Duration::from_millis(10));
            }
            Err(e)
                if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(_) => thread::sleep(Duration::from_millis(10)),
        }
    }
}

/// Rebuild the frame bytes for a (version, kind, body) triple —
/// byte-identical to what the peer sent, since decode validated it.
fn reframe(ver: u8, kind: u8, body: &[u8]) -> Vec<u8> {
    let mut f = Vec::with_capacity(10 + body.len());
    f.extend_from_slice(&MAGIC);
    f.push(ver);
    f.push(kind);
    f.extend_from_slice(&(body.len() as u32).to_le_bytes());
    f.extend_from_slice(body);
    f
}

fn proxy_conn(client: TcpStream, shared: Arc<ProxyShared>) {
    let cid = shared.conn_seq.fetch_add(1, Ordering::SeqCst);
    let base = shared.plan.seed
        ^ cid.wrapping_mul(0x9E37_79B9_7F4A_7C15);
    let mut rng_conn = SplitMix64::new(base);
    if hit(&mut rng_conn, shared.plan.conn_drop) {
        let _ = client.shutdown(Shutdown::Both);
        return;
    }
    let upstream = match TcpStream::connect(&shared.upstream) {
        Ok(u) => u,
        Err(_) => {
            let _ = client.shutdown(Shutdown::Both);
            return;
        }
    };
    let _ = client.set_nodelay(true);
    let _ = upstream.set_nodelay(true);
    // Register both halves for kill().
    {
        let mut conns = shared.conns.lock().unwrap();
        if shared.down.load(Ordering::SeqCst) {
            let _ = client.shutdown(Shutdown::Both);
            let _ = upstream.shutdown(Shutdown::Both);
            return;
        }
        if let (Ok(c2), Ok(u2)) =
            (client.try_clone(), upstream.try_clone())
        {
            conns.push(c2);
            conns.push(u2);
        }
    }
    // The client's write half is shared: the request thread answers
    // BUSY storms on it while the response thread forwards real
    // responses — whole frames only, under the lock.
    let client_w = match client.try_clone() {
        Ok(w) => Arc::new(Mutex::new(w)),
        Err(_) => return,
    };
    let up_w = match upstream.try_clone() {
        Ok(w) => w,
        Err(_) => return,
    };
    let plan = shared.plan.clone();
    let req_thread = {
        let client_w = client_w.clone();
        let plan = plan.clone();
        let rng = SplitMix64::new(base ^ 0xA11C_E5EED);
        thread::spawn(move || {
            forward_requests(client, up_w, client_w, plan, rng)
        })
    };
    let rng = SplitMix64::new(base ^ 0xB0B5_1ED);
    forward_responses(upstream, client_w, plan, rng);
    let _ = req_thread.join();
}

/// Client → upstream direction: parse request frames, answer BUSY
/// storms locally, forward the rest.
fn forward_requests(client_r: TcpStream, mut up_w: TcpStream,
                    client_w: Arc<Mutex<TcpStream>>, plan: FaultPlan,
                    mut rng: SplitMix64) {
    let mut r = &client_r;
    loop {
        match read_frame(&mut r, KIND_REQUEST) {
            Ok(Some((ver, body))) => {
                // Request body layout: id u64 LE, op u8, …
                let op = body.get(8).copied().unwrap_or(0xFF);
                if op == 0 && hit(&mut rng, plan.busy) {
                    let id = u64::from_le_bytes(
                        body[0..8].try_into().unwrap());
                    let f = WireResponse {
                        id,
                        body: ResponseBody::Error {
                            code: ErrorCode::Busy,
                            detail: "fault injection: busy storm"
                                .into(),
                        },
                    }.encode(ver);
                    let mut w = client_w.lock().unwrap();
                    if w.write_all(&f).is_err() {
                        break;
                    }
                    continue;
                }
                let f = reframe(ver, KIND_REQUEST, &body);
                if up_w.write_all(&f).is_err() {
                    break;
                }
            }
            Ok(None) | Err(_) => break,
        }
    }
    // Client went away (or stream damage): signal EOF upstream so
    // the gateway drains; the response direction forwards whatever
    // is still in flight until the gateway closes.
    let _ = up_w.shutdown(Shutdown::Write);
}

/// Upstream → client direction: parse response frames, inject
/// blackhole / delay / truncation.
fn forward_responses(up_r: TcpStream,
                     client_w: Arc<Mutex<TcpStream>>, plan: FaultPlan,
                     mut rng: SplitMix64) {
    let mut r = &up_r;
    loop {
        match read_frame(&mut r, KIND_RESPONSE) {
            Ok(Some((ver, body))) => {
                if hit(&mut rng, plan.blackhole) {
                    continue;
                }
                if !plan.delay.is_zero()
                    && hit(&mut rng, plan.delay_p)
                {
                    thread::sleep(plan.delay);
                }
                let f = reframe(ver, KIND_RESPONSE, &body);
                if hit(&mut rng, plan.truncate) {
                    // Cut the frame mid-body: framing damage the
                    // client must treat as a dead connection.
                    let cut = (f.len() / 2).max(1);
                    let mut w = client_w.lock().unwrap();
                    let _ = w.write_all(&f[..cut]);
                    let _ = w.shutdown(Shutdown::Both);
                    let _ = up_r.shutdown(Shutdown::Both);
                    break;
                }
                let mut w = client_w.lock().unwrap();
                if w.write_all(&f).is_err() {
                    break;
                }
            }
            Ok(None) | Err(_) => {
                // Upstream closed (or stream damage): sever the
                // client too — from its point of view the backend
                // just died.
                let _ = client_w
                    .lock()
                    .unwrap()
                    .shutdown(Shutdown::Both);
                break;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_full_spec() {
        let plan = FaultPlan::parse(
            "busy=0.1, drop=0.05,blackhole=0.01,truncate=0.02,\
             delay_ms=5,delay_p=0.2,seed=7")
            .unwrap();
        assert_eq!(plan.seed, 7);
        assert_eq!(plan.busy, 0.1);
        assert_eq!(plan.conn_drop, 0.05);
        assert_eq!(plan.blackhole, 0.01);
        assert_eq!(plan.truncate, 0.02);
        assert_eq!(plan.delay, Duration::from_millis(5));
        assert_eq!(plan.delay_p, 0.2);
        assert!(!plan.is_noop());
    }

    #[test]
    fn parse_empty_is_noop() {
        assert!(FaultPlan::parse("").unwrap().is_noop());
        assert_eq!(FaultPlan::parse("").unwrap(), FaultPlan::none());
    }

    #[test]
    fn parse_rejects_garbage() {
        assert!(FaultPlan::parse("busy").is_err());
        assert!(FaultPlan::parse("warp=0.5").is_err());
        assert!(FaultPlan::parse("busy=1.5").is_err());
        assert!(FaultPlan::parse("busy=-0.1").is_err());
        assert!(FaultPlan::parse("delay_ms=abc").is_err());
        assert!(FaultPlan::parse("seed=-3").is_err());
    }

    #[test]
    fn hit_is_deterministic_and_respects_extremes() {
        let mut rng = SplitMix64::new(42);
        for _ in 0..100 {
            assert!(!hit(&mut rng, 0.0));
            assert!(hit(&mut rng, 1.0));
        }
        let mut a = SplitMix64::new(7);
        let mut b = SplitMix64::new(7);
        for _ in 0..1000 {
            assert_eq!(hit(&mut a, 0.3), hit(&mut b, 0.3));
        }
        // A 30% coin lands roughly 30% of the time.
        let mut rng = SplitMix64::new(9);
        let hits = (0..10_000)
            .filter(|_| hit(&mut rng, 0.3))
            .count();
        assert!((2_500..3_500).contains(&hits), "hits={hits}");
    }
}
