//! Backend health state machine: strike-based ejection, probe-based
//! readmission.
//!
//! Kept as a pure `(state, event) -> transition` machine — the router
//! drives it from heartbeat outcomes, tests drive it directly. The
//! policy is deliberately simple and explainable:
//!
//! * **Live** backends accumulate *strikes* on consecutive heartbeat
//!   failures (timeout, connect error, connection loss, rejected
//!   probe); any success resets the count. `eject_after` consecutive
//!   strikes eject the backend.
//! * **Ejected** backends accumulate *probe successes*; any failure
//!   resets the count. `readmit_after` consecutive successes readmit
//!   it.
//!
//! Requiring consecutive successes to readmit keeps a flapping
//! backend (up for one probe, down the next) out of the placement set
//! instead of oscillating traffic onto it.

use std::time::Duration;

/// Health-check tuning knobs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct HealthPolicy {
    /// Heartbeat (and probe) period.
    pub heartbeat_every: Duration,
    /// Consecutive failures before a live backend is ejected.
    pub eject_after: u32,
    /// Consecutive probe successes before an ejected backend is
    /// readmitted.
    pub readmit_after: u32,
}

impl Default for HealthPolicy {
    fn default() -> Self {
        Self {
            heartbeat_every: Duration::from_millis(200),
            eject_after: 3,
            readmit_after: 2,
        }
    }
}

/// State change produced by an observation.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Transition {
    /// Live → ejected: stop placing, fail over in-flight requests,
    /// start probing.
    Ejected,
    /// Ejected → live: resume placing.
    Readmitted,
}

/// One backend's health automaton.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct HealthState {
    live: bool,
    strikes: u32,
    probe_successes: u32,
}

impl Default for HealthState {
    fn default() -> Self {
        Self::new()
    }
}

impl HealthState {
    /// Backends start live: they get `eject_after` chances before
    /// traffic shifts away.
    pub fn new() -> Self {
        Self { live: true, strikes: 0, probe_successes: 0 }
    }

    pub fn live(&self) -> bool {
        self.live
    }

    /// Current consecutive-failure count (0 when healthy).
    pub fn strikes(&self) -> u32 {
        self.strikes
    }

    /// A heartbeat/probe succeeded. Returns
    /// [`Transition::Readmitted`] when this flips an ejected backend
    /// back to live.
    pub fn on_success(&mut self, policy: &HealthPolicy)
                      -> Option<Transition> {
        if self.live {
            self.strikes = 0;
            return None;
        }
        self.probe_successes += 1;
        if self.probe_successes >= policy.readmit_after.max(1) {
            self.live = true;
            self.strikes = 0;
            self.probe_successes = 0;
            return Some(Transition::Readmitted);
        }
        None
    }

    /// A heartbeat/probe failed. Returns [`Transition::Ejected`] when
    /// this is the strike that ejects a live backend.
    pub fn on_failure(&mut self, policy: &HealthPolicy)
                      -> Option<Transition> {
        if self.live {
            self.strikes += 1;
            if self.strikes >= policy.eject_after.max(1) {
                self.live = false;
                self.probe_successes = 0;
                return Some(Transition::Ejected);
            }
            return None;
        }
        self.probe_successes = 0;
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn policy(eject: u32, readmit: u32) -> HealthPolicy {
        HealthPolicy {
            heartbeat_every: Duration::from_millis(50),
            eject_after: eject,
            readmit_after: readmit,
        }
    }

    #[test]
    fn ejects_only_after_consecutive_failures() {
        let p = policy(3, 2);
        let mut h = HealthState::new();
        assert_eq!(h.on_failure(&p), None);
        assert_eq!(h.on_failure(&p), None);
        // A success in between resets the count …
        assert_eq!(h.on_success(&p), None);
        assert!(h.live());
        assert_eq!(h.strikes(), 0);
        // … so ejection needs three *consecutive* failures again.
        assert_eq!(h.on_failure(&p), None);
        assert_eq!(h.on_failure(&p), None);
        assert_eq!(h.on_failure(&p), Some(Transition::Ejected));
        assert!(!h.live());
    }

    #[test]
    fn readmits_only_after_consecutive_successes() {
        let p = policy(1, 3);
        let mut h = HealthState::new();
        assert_eq!(h.on_failure(&p), Some(Transition::Ejected));
        assert_eq!(h.on_success(&p), None);
        assert_eq!(h.on_success(&p), None);
        // A failed probe resets the streak.
        assert_eq!(h.on_failure(&p), None);
        assert_eq!(h.on_success(&p), None);
        assert_eq!(h.on_success(&p), None);
        assert_eq!(h.on_success(&p), Some(Transition::Readmitted));
        assert!(h.live());
        // Readmitted with a clean slate.
        assert_eq!(h.strikes(), 0);
    }

    #[test]
    fn no_double_transitions() {
        let p = policy(2, 1);
        let mut h = HealthState::new();
        assert_eq!(h.on_failure(&p), None);
        assert_eq!(h.on_failure(&p), Some(Transition::Ejected));
        // Further failures while ejected produce no second ejection.
        assert_eq!(h.on_failure(&p), None);
        assert_eq!(h.on_failure(&p), None);
        assert_eq!(h.on_success(&p), Some(Transition::Readmitted));
        // Further successes while live produce no second readmission.
        assert_eq!(h.on_success(&p), None);
        assert_eq!(h.on_success(&p), None);
    }

    #[test]
    fn zero_thresholds_behave_like_one() {
        let p = policy(0, 0);
        let mut h = HealthState::new();
        assert_eq!(h.on_failure(&p), Some(Transition::Ejected));
        assert_eq!(h.on_success(&p), Some(Transition::Readmitted));
    }
}
