//! Fault-tolerant cluster tier: a front [`Router`] process fanning
//! client requests out to N health-checked gateway backends.
//!
//! This sits one layer above `server/`: gateways stay single-process
//! multi-model servers; the router adds host-level scale-out with
//! the paper's cost-balanced placement ([`placement`]), strike-based
//! health checking ([`health`]), failover retry so a killed backend
//! costs latency rather than lost requests ([`router`]), and a
//! deterministic fault-injection proxy for chaos testing
//! ([`faults`]). Everything is std-only, reusing the
//! `server/reactor` poll primitives and the v2 wire protocol's
//! `Heartbeat` load reports.

pub mod faults;
pub mod health;
pub mod placement;
pub mod router;

pub use faults::{FaultPlan, FaultProxy};
pub use health::{HealthPolicy, HealthState, Transition};
pub use placement::{mounted_anywhere, pick_backend, BackendView};
pub use router::{render_cluster_metrics, BackendSnapshot, Router,
                 RouterConfig, RouterReport, RouterStop};
