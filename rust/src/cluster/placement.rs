//! Cost-aware backend placement — CBWS lifted to host granularity.
//!
//! The paper's CBWS balances *channel* workloads inside one
//! accelerator by predicted cost, not by count; the cluster router
//! applies the identical idea across gateway processes: each request
//! goes to the live backend that mounts the target model and carries
//! the least predicted queue cost (the backend's reported
//! `cost_depth` plus the router's own estimate for requests it has
//! dispatched but not yet seen answered).
//!
//! Pure functions over a snapshot — no IO, no locks — so the
//! invariants ("never an ejected backend", "never a backend that
//! doesn't mount the model") are directly property-testable.

/// One backend as the placement decision sees it.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct BackendView {
    /// Health-check verdict: ejected backends are never placed on.
    pub live: bool,
    /// `(model name, cost_depth)` pairs from the last heartbeat. An
    /// empty list means no load report has landed yet (the backend
    /// is not placeable until one does).
    pub models: Vec<(String, u64)>,
    /// Router-side estimate: summed cost of requests dispatched to
    /// this backend whose responses have not arrived yet — the
    /// correction term between heartbeats.
    pub inflight_cost: u64,
}

impl BackendView {
    /// Cost depth for `model` (`""` selects the backend's default —
    /// its first reported model); `None` if the backend doesn't
    /// mount it.
    pub fn cost_for(&self, model: &str) -> Option<u64> {
        if model.is_empty() {
            self.models.first().map(|(_, d)| *d)
        } else {
            self.models.iter()
                .find(|(n, _)| n == model)
                .map(|(_, d)| *d)
        }
    }

    /// Whether this backend is known to mount `model` (`""` = any
    /// model at all).
    pub fn mounts(&self, model: &str) -> bool {
        self.cost_for(model).is_some()
    }
}

/// Pick the backend for one request on `model` (`""` = default):
/// among **live** backends that **mount** the model, minimize
/// `cost_depth + inflight_cost`; ties break to the lowest index so
/// the choice is deterministic given a snapshot. `None` when no live
/// backend qualifies (all ejected, or none mounts the model) — the
/// caller retries or rejects.
pub fn pick_backend(views: &[BackendView], model: &str)
                    -> Option<usize> {
    let mut best: Option<(u64, usize)> = None;
    for (i, v) in views.iter().enumerate() {
        if !v.live {
            continue;
        }
        let Some(depth) = v.cost_for(model) else {
            continue;
        };
        let key = depth.saturating_add(v.inflight_cost);
        match best {
            Some((bk, _)) if bk <= key => {}
            _ => best = Some((key, i)),
        }
    }
    best.map(|(_, i)| i)
}

/// Whether *any* backend — live or ejected — is known to mount
/// `model`. Distinguishes "unknown model, reject now" from "mounted
/// only on a currently-ejected backend, worth retrying".
pub fn mounted_anywhere(views: &[BackendView], model: &str) -> bool {
    views.iter().any(|v| v.mounts(model))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn view(live: bool, models: &[(&str, u64)], inflight: u64)
            -> BackendView {
        BackendView {
            live,
            models: models.iter()
                .map(|(n, d)| (n.to_string(), *d))
                .collect(),
            inflight_cost: inflight,
        }
    }

    #[test]
    fn picks_least_loaded_by_cost() {
        let views = [
            view(true, &[("cls", 500)], 0),
            view(true, &[("cls", 100)], 0),
            view(true, &[("cls", 300)], 0),
        ];
        assert_eq!(pick_backend(&views, "cls"), Some(1));
        assert_eq!(pick_backend(&views, ""), Some(1));
    }

    #[test]
    fn inflight_cost_counts_toward_load() {
        let views = [
            view(true, &[("cls", 100)], 500),
            view(true, &[("cls", 300)], 0),
        ];
        // 100 + 500 > 300 + 0.
        assert_eq!(pick_backend(&views, "cls"), Some(1));
    }

    #[test]
    fn never_picks_ejected_or_nonmounting() {
        let views = [
            view(false, &[("cls", 0)], 0),
            view(true, &[("seg", 0)], 0),
            view(true, &[("cls", 9999)], 9999),
        ];
        assert_eq!(pick_backend(&views, "cls"), Some(2));
        assert_eq!(pick_backend(&views, "seg"), Some(1));
        assert_eq!(pick_backend(&views, "nope"), None);
        assert!(mounted_anywhere(&views, "cls"));
        assert!(!mounted_anywhere(&views, "nope"));
    }

    #[test]
    fn empty_selector_uses_first_reported_model() {
        let views = [
            view(true, &[("seg", 700), ("cls", 1)], 0),
            view(true, &[("seg", 100)], 0),
        ];
        // "" compares each backend's *first* model: 700 vs 100.
        assert_eq!(pick_backend(&views, ""), Some(1));
    }

    #[test]
    fn unreported_backend_is_not_placeable() {
        let views = [view(true, &[], 0), view(false, &[("cls", 0)], 0)];
        assert_eq!(pick_backend(&views, "cls"), None);
        assert_eq!(pick_backend(&views, ""), None);
    }

    #[test]
    fn ties_break_to_lowest_index() {
        let views = [
            view(true, &[("cls", 50)], 0),
            view(true, &[("cls", 50)], 0),
        ];
        assert_eq!(pick_backend(&views, "cls"), Some(0));
    }

    #[test]
    fn saturates_instead_of_overflowing() {
        let views = [
            view(true, &[("cls", u64::MAX)], u64::MAX),
            view(true, &[("cls", 5)], 0),
        ];
        assert_eq!(pick_backend(&views, "cls"), Some(1));
    }

    #[test]
    fn no_live_backend_means_none() {
        let views = [
            view(false, &[("cls", 0)], 0),
            view(false, &[("cls", 0)], 0),
        ];
        assert_eq!(pick_backend(&views, "cls"), None);
    }
}
