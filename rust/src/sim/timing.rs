//! Per-(layer, timestep) timing + op accounting — the simulator kernel.
//!
//! Pure function of (geometry, partition, per-channel spike counts); the
//! engine calls it once per layer per timestep, so this is the hot path
//! (see PERF.md and benches/sim_hotpath.rs) — it must not allocate.



use super::ArchConfig;
use crate::schedule::Partition;
use crate::snn::LayerWeights;

/// Timing/op result for one (layer, timestep).
#[derive(Debug, Clone, Default)]
pub struct LayerTiming {
    /// Total accelerator cycles charged to this layer-step.
    pub cycles: u64,
    /// Cycles the slowest SPE computed (per pass, before x passes).
    pub critical_spe_cycles: u64,
    /// Spike-scheduler scan cycles (overlapped with compute).
    pub scan_cycles: u64,
    /// Number of output-channel passes.
    pub passes: u32,
    /// Synaptic operations actually performed (adds).
    pub synops: u64,
    /// Input events (spikes) consumed.
    pub events: u64,
    /// Weight-memory words fetched.
    pub weight_reads: u64,
    /// VMEM read-modify-writes.
    pub vmem_rmw: u64,
    /// Neuron-state words scanned.
    pub state_reads: u64,
    /// Balance ratio of this layer-step: `total / (N * max_group)`.
    pub balance: f64,
    /// Numerator/denominator for workload-weighted aggregation.
    pub work_total: u64,
    pub work_max: u64,
}

/// The timing model of `sim::mod` docs, for one layer-step.
///
/// `nnz` is the per-input-channel spike count of this timestep;
/// `partition` is the CBWS (or baseline) channel-to-SPE assignment.
/// `row_events`: when the layer has fewer input channels than SPEs the
/// cluster falls back to *row-interleaved* splitting (each SPE takes the
/// rows `r % N == spe` of every channel — the same intra-channel spatial
/// partitioning the 4 output streams already use, paper §III-C); the
/// engine passes the measured per-SPE event counts here and the channel
/// partition is ignored.
pub fn layer_timing_with_rows(arch: &ArchConfig, layer: &LayerWeights,
                              partition: &Partition, nnz: &[usize],
                              row_events: Option<&[u64]>) -> LayerTiming {
    let (cout, synops_per_event, in_neurons) = match layer {
        LayerWeights::Conv { geom, .. } => (
            geom.cout,
            geom.r * geom.r,
            geom.cin * geom.h * geom.w,
        ),
        LayerWeights::Dense { geom, .. } => (geom.fout, 1, geom.fin),
    };
    // Sum + max over the per-group event counts without materialising
    // the group vector (this runs per layer per timestep).
    let (events, max_events) = match row_events {
        Some(re) => (re.iter().sum::<u64>(),
                     re.iter().copied().max().unwrap_or(0)),
        None => {
            let mut total = 0u64;
            let mut max = 0u64;
            for g in &partition.groups {
                let e: u64 = g.iter().map(|&c| nnz[c] as u64).sum();
                total += e;
                max = max.max(e);
            }
            (total, max)
        }
    };

    // Cycles per event on one SPE: RxR window over `streams` lanes.
    let ev_cycles = (synops_per_event + arch.streams - 1) / arch.streams;
    let spe_max = max_events * ev_cycles as u64;
    let passes = (cout + arch.m_clusters - 1) / arch.m_clusters;
    let pass_overhead = (arch.adder_depth() + arch.pipe_fill) as u64;
    let compute = passes as u64 * (spe_max + pass_overhead);
    let scan = ((in_neurons + arch.scan_width - 1) / arch.scan_width) as u64;
    let cycles = compute.max(scan) + arch.setup_cycles as u64;

    // Ops: every event is applied once per output channel.
    let synops = events * (synops_per_event * cout) as u64;
    let n = match row_events {
        Some(re) => re.len().max(1) as u64,
        None => partition.groups.len().max(1) as u64,
    };
    let balance = if max_events == 0 {
        1.0
    } else {
        events as f64 / (n * max_events) as f64
    };

    LayerTiming {
        cycles,
        critical_spe_cycles: spe_max,
        scan_cycles: scan,
        passes: passes as u32,
        synops,
        events,
        weight_reads: synops, // one weight word per add (worst case)
        vmem_rmw: synops,     // read-modify-write per touched output
        state_reads: scan,
        balance,
        work_total: events,
        work_max: max_events,
    }
}

/// Channel-partitioned timing (no row fallback) — see
/// [`layer_timing_with_rows`].
pub fn layer_timing(arch: &ArchConfig, layer: &LayerWeights,
                    partition: &Partition, nnz: &[usize]) -> LayerTiming {
    layer_timing_with_rows(arch, layer, partition, nnz, None)
}

/// DMA cycles to move `bytes` over the AXI stream.
pub fn dma_cycles(arch: &ArchConfig, bytes: usize) -> u64 {
    ((bytes + arch.dma_bytes_per_cycle - 1) / arch.dma_bytes_per_cycle) as u64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::snn::ConvGeom;

    fn conv_layer(cin: usize, cout: usize) -> LayerWeights {
        LayerWeights::Conv {
            geom: ConvGeom { cin, cout, r: 3, pad: 2, h: 8, w: 8,
                             eh: 10, ew: 10 },
            w: vec![0.0; cout * cin * 9],
        }
    }

    fn contiguous(k: usize, n: usize) -> Partition {
        let per = (k + n - 1) / n;
        Partition {
            groups: (0..n)
                .map(|g| (g * per..((g + 1) * per).min(k)).collect())
                .collect(),
        }
    }

    #[test]
    fn balanced_input_fully_utilises() {
        let arch = ArchConfig::default();
        let layer = conv_layer(8, 8);
        let p = contiguous(8, 8);
        let t = layer_timing(&arch, &layer, &p, &[10; 8]);
        assert!((t.balance - 1.0).abs() < 1e-12);
        assert_eq!(t.events, 80);
        assert_eq!(t.synops, 80 * 9 * 8);
        assert_eq!(t.passes, 1);
        // 10 events x ceil(9/4)=3 cycles
        assert_eq!(t.critical_spe_cycles, 30);
    }

    #[test]
    fn imbalance_slows_down() {
        let arch = ArchConfig::default();
        let layer = conv_layer(8, 8);
        let p = contiguous(8, 8);
        let balanced = layer_timing(&arch, &layer, &p, &[10; 8]);
        // Same total work, all in one channel.
        let mut skew = vec![0usize; 8];
        skew[0] = 80;
        let skewed = layer_timing(&arch, &layer, &p, &skew);
        assert_eq!(balanced.synops, skewed.synops);
        assert!(skewed.cycles > balanced.cycles);
        assert!((skewed.balance - 0.125).abs() < 1e-9);
    }

    #[test]
    fn passes_scale_with_cout() {
        let arch = ArchConfig::default();
        let p = contiguous(4, 8);
        let t8 = layer_timing(&arch, &conv_layer(4, 8), &p, &[5; 4]);
        let t32 = layer_timing(&arch, &conv_layer(4, 32), &p, &[5; 4]);
        assert_eq!(t8.passes, 1);  // ceil(8 / 16 clusters)
        assert_eq!(t32.passes, 2); // ceil(32 / 16 clusters)
        assert!(t32.cycles > t8.cycles);
        assert_eq!(t32.synops, 4 * t8.synops);
    }

    #[test]
    fn scan_bound_when_nearly_silent() {
        let arch = ArchConfig::default();
        // Huge quiet layer: scanning dominates.
        let layer = LayerWeights::Conv {
            geom: ConvGeom { cin: 32, cout: 8, r: 3, pad: 1, h: 64, w: 64,
                             eh: 64, ew: 64 },
            w: vec![],
        };
        let p = contiguous(32, 8);
        let t = layer_timing(&arch, &layer, &p, &[0; 32]);
        assert_eq!(t.events, 0);
        assert_eq!(t.scan_cycles, (32 * 64 * 64) as u64 / 64);
        assert_eq!(t.cycles, t.scan_cycles + arch.setup_cycles as u64);
        assert_eq!(t.balance, 1.0);
    }

    #[test]
    fn dense_one_op_per_event() {
        let arch = ArchConfig::default();
        let layer = LayerWeights::Dense {
            geom: crate::snn::DenseGeom { fin: 64, fout: 10,
                                          src_channels: 8 },
            w: vec![0.0; 640],
            wt: vec![0.0; 640],
            b: vec![0.0; 10],
        };
        let p = contiguous(8, 8);
        let t = layer_timing(&arch, &layer, &p, &[4; 8]);
        assert_eq!(t.events, 32);
        assert_eq!(t.synops, 32 * 10);
        assert_eq!(t.passes, 1); // ceil(10 / 16 clusters)
    }

    #[test]
    fn dma_rounds_up() {
        let arch = ArchConfig::default();
        assert_eq!(dma_cycles(&arch, 0), 0);
        assert_eq!(dma_cycles(&arch, 1), 1);
        assert_eq!(dma_cycles(&arch, 8), 1);
        assert_eq!(dma_cycles(&arch, 9), 2);
    }
}
