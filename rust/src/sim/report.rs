//! Aggregated simulation results: per-layer, per-frame, per-run.



use super::timing::LayerTiming;

/// Per-layer aggregation over the timesteps of one frame.
/// (`PartialEq` so parity tests can assert the parallel sweep is
/// bit-identical to the serial path.)
#[derive(Debug, Clone, Default, PartialEq)]
pub struct LayerStats {
    pub layer: usize,
    pub cycles: u64,
    pub synops: u64,
    pub events: u64,
    pub weight_reads: u64,
    pub vmem_rmw: u64,
    pub state_reads: u64,
    /// Workload-weighted balance: `sum_t total_t / (N * sum_t max_t)` —
    /// equals achieved/ideal event throughput over the frame, the
    /// operational quantity behind Fig. 7.
    pub balance_weighted: f64,
    /// Plain mean of per-timestep ratios (for comparison).
    pub balance_mean: f64,
    /// Scratch accumulators (serialized for auditability).
    pub work_total: u64,
    pub work_max: u64,
    pub steps: u64,
    pub balance_sum: f64,
}

impl LayerStats {
    pub fn absorb(&mut self, t: &LayerTiming, n_spes: usize) {
        self.cycles += t.cycles;
        self.synops += t.synops;
        self.events += t.events;
        self.weight_reads += t.weight_reads;
        self.vmem_rmw += t.vmem_rmw;
        self.state_reads += t.state_reads;
        self.work_total += t.work_total;
        self.work_max += t.work_max;
        self.steps += 1;
        self.balance_sum += t.balance;
        self.balance_mean = self.balance_sum / self.steps as f64;
        self.balance_weighted = if self.work_max == 0 {
            1.0
        } else {
            self.work_total as f64 / (n_spes as f64 * self.work_max as f64)
        };
    }
}

/// One frame through the accelerator.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct FrameReport {
    pub layers: Vec<LayerStats>,
    /// Compute cycles summed over layers and timesteps.
    pub compute_cycles: u64,
    /// DMA-in / DMA-out cycles (not overlapped with compute).
    pub dma_cycles: u64,
    /// Total frame latency in cycles.
    pub total_cycles: u64,
    pub synops: u64,
    pub events: u64,
    pub weight_reads: u64,
    pub vmem_rmw: u64,
    pub state_reads: u64,
    pub dma_bytes: u64,
    pub timesteps: usize,
    /// Output spike counts of the last layer (argmax = class,
    /// thresholded = segmentation mask).
    pub output_counts: Vec<u32>,
}

impl FrameReport {
    /// Frames per second at `clock_hz`.
    pub fn fps(&self, clock_hz: f64) -> f64 {
        clock_hz / self.total_cycles.max(1) as f64
    }

    /// Giga synaptic operations per second.
    pub fn gsops(&self, clock_hz: f64) -> f64 {
        let secs = self.total_cycles.max(1) as f64 / clock_hz;
        self.synops as f64 / secs / 1e9
    }

    /// Workload-weighted balance over all layers.
    pub fn balance_weighted(&self, n_spes: usize) -> f64 {
        let total: u64 = self.layers.iter().map(|l| l.work_total).sum();
        let max: u64 = self.layers.iter().map(|l| l.work_max).sum();
        if max == 0 {
            1.0
        } else {
            total as f64 / (n_spes as f64 * max as f64)
        }
    }
}

/// Aggregation over many frames (a run / benchmark).
#[derive(Debug, Clone, Default)]
pub struct RunSummary {
    pub frames: usize,
    pub total_cycles: u64,
    pub synops: u64,
    pub mean_balance_weighted: f64,
    pub mean_fps: f64,
    pub mean_gsops: f64,
    /// Per-layer weighted balance averaged over frames (Fig. 7 series).
    pub per_layer_balance: Vec<f64>,
}

impl RunSummary {
    pub fn from_frames(frames: &[FrameReport], clock_hz: f64,
                       n_spes: usize) -> Self {
        if frames.is_empty() {
            return Self::default();
        }
        let nl = frames[0].layers.len();
        let mut per_layer = vec![0.0f64; nl];
        for f in frames {
            for (i, l) in f.layers.iter().enumerate() {
                per_layer[i] += l.balance_weighted;
            }
        }
        per_layer.iter_mut().for_each(|b| *b /= frames.len() as f64);
        let total_cycles: u64 = frames.iter().map(|f| f.total_cycles).sum();
        let synops: u64 = frames.iter().map(|f| f.synops).sum();
        Self {
            frames: frames.len(),
            total_cycles,
            synops,
            mean_balance_weighted: frames.iter()
                .map(|f| f.balance_weighted(n_spes)).sum::<f64>()
                / frames.len() as f64,
            mean_fps: clock_hz * frames.len() as f64 / total_cycles as f64,
            mean_gsops: synops as f64
                / (total_cycles as f64 / clock_hz) / 1e9,
            per_layer_balance: per_layer,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn absorb_weighted_balance() {
        let mut ls = LayerStats::default();
        let mut t = LayerTiming { work_total: 80, work_max: 10,
                                  balance: 1.0, ..Default::default() };
        ls.absorb(&t, 8);
        assert!((ls.balance_weighted - 1.0).abs() < 1e-12);
        // Second step fully imbalanced: total 80 in one group of 8.
        t.work_total = 80;
        t.work_max = 80;
        t.balance = 0.125;
        ls.absorb(&t, 8);
        // weighted: 160 / (8 * 90) = 0.2222; mean: (1.0+0.125)/2
        assert!((ls.balance_weighted - 160.0 / 720.0).abs() < 1e-9);
        assert!((ls.balance_mean - 0.5625).abs() < 1e-9);
    }

    #[test]
    fn fps_and_gsops() {
        let f = FrameReport { total_cycles: 200_000, synops: 1_000_000,
                              ..Default::default() };
        assert!((f.fps(200e6) - 1000.0).abs() < 1e-9);
        assert!((f.gsops(200e6) - 1.0).abs() < 1e-9);
    }
}
