//! Cycle-level model of the Skydiver accelerator (paper Fig. 3 + Fig. 5).
//!
//! This module substitutes the paper's XC7Z045 FPGA (DESIGN.md §2): it
//! models the published microarchitecture faithfully enough that balance
//! ratio, cycles/frame, and the APRC/CBWS gains are measured, not
//! asserted.
//!
//! # Microarchitecture (paper §III-A)
//!
//! * **Spike scheduler** — scans the neuron-state memory (bit-packed, 64
//!   neurons/word/cycle) and emits (channel, position) events plus weight
//!   addresses. Scan overlaps compute; a layer is bounded by
//!   `max(scan, compute)`.
//! * **SPE clusters** — `M` filter-based clusters; each owns one *output*
//!   channel per pass (its filter lives in a private weight bank). A
//!   layer with `cout` output channels takes `ceil(cout / M)` passes, all
//!   clusters replaying the same event stream.
//! * **Channel-based SPEs** — `N` per cluster; SPE `n` processes the
//!   events of its assigned *input* channels (the partition CBWS
//!   computes). One input spike fans out to an `RxR` window, executed on
//!   `streams` parallel lanes: `ceil(R*R / streams)` cycles per event.
//! * **Adder trees** — one per stream; pipeline depth `ceil(log2 N)`,
//!   counted as pass drain.
//! * **Memories** — neuron state (bit-packed spikes), VMEM (membrane
//!   potentials, read-modify-write per touched output), weight banks.
//!   Widths/sizes feed the BRAM model in [`crate::power`].
//! * **DMA** — input spike train in / output spikes out over a 64-bit
//!   AXI-style stream, `dma_bytes_per_cycle` per cycle.
//!
//! # Timing model
//!
//! For layer `l`, timestep `t`, with per-input-channel spike counts
//! `nnz_c` and partition groups `g_0..g_{N-1}`:
//!
//! ```text
//! events_n   = sum_{c in g_n} nnz_c
//! spe_n      = events_n * ceil(R^2 / streams)          (conv)
//!            = events_n * ceil(1   / streams) = events (dense)
//! pass       = max_n spe_n + ceil(log2 N) + pipe_fill
//! compute    = ceil(cout / M) * pass
//! scan       = ceil(C*H*W / 64)
//! layer(t,l) = max(compute, scan) + setup
//! ```
//!
//! The balance ratio of `(l, t)` is `sum_n events_n / (N * max_n
//! events_n)` — Spartus's [15] definition, the quantity Fig. 7 plots.

mod engine;
mod report;
pub mod sweep;
mod timing;

pub use engine::{Simulator, TraceSource};
pub use report::{FrameReport, LayerStats, RunSummary};
pub use sweep::{parallel_map, FrameJob};
pub use timing::{layer_timing, LayerTiming};



/// Architecture parameters of a Skydiver instance.
///
/// Defaults reproduce the paper's XC7Z045 configuration (Table II):
/// `M = 16` clusters x `N = 4` SPEs x 4 streams at 200 MHz (64 SPEs,
/// 256 accumulate lanes). The paper does not state (M, N); N = 4 is the
/// value consistent with its >90% channel-grain balance on layers with
/// as few as 8 input channels (see EXPERIMENTS.md fig7 notes).
#[derive(Debug, Clone, Copy)]
pub struct ArchConfig {
    /// Filter-based SPE clusters (parallel output channels).
    pub m_clusters: usize,
    /// Channel-based SPEs per cluster (the CBWS partition width).
    pub n_spes: usize,
    /// Parallel accumulate lanes per SPE ("four streams", §III-C).
    pub streams: usize,
    /// Spike-scheduler scan width (neurons per cycle).
    pub scan_width: usize,
    /// DMA payload bytes per cycle (64-bit AXI).
    pub dma_bytes_per_cycle: usize,
    /// Pipeline fill cycles charged per pass.
    pub pipe_fill: usize,
    /// Controller setup cycles charged per (layer, timestep).
    pub setup_cycles: usize,
    /// Clock in Hz (paper: 200 MHz).
    pub clock_hz: f64,
}

impl Default for ArchConfig {
    fn default() -> Self {
        Self {
            m_clusters: 16,
            n_spes: 4,
            streams: 4,
            scan_width: 64,
            dma_bytes_per_cycle: 8,
            pipe_fill: 8,
            setup_cycles: 16,
            clock_hz: crate::CLOCK_HZ,
        }
    }
}

impl ArchConfig {
    /// Peak synaptic ops per cycle (all lanes busy).
    pub fn peak_ops_per_cycle(&self) -> usize {
        self.m_clusters * self.n_spes * self.streams
    }

    /// Adder-tree pipeline depth for N partial-sum inputs.
    pub fn adder_depth(&self) -> usize {
        (usize::BITS - (self.n_spes.max(1) - 1).leading_zeros()) as usize
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_matches_paper_config() {
        let a = ArchConfig::default();
        assert_eq!(a.m_clusters, 16);
        assert_eq!(a.n_spes, 4);
        assert_eq!(a.streams, 4);
        assert_eq!(a.peak_ops_per_cycle(), 256);
        assert!((a.clock_hz - 200e6).abs() < 1.0);
    }

    #[test]
    fn adder_depth_log2() {
        let mut a = ArchConfig::default();
        assert_eq!(a.adder_depth(), 2);
        a.n_spes = 16;
        assert_eq!(a.adder_depth(), 4);
        a.n_spes = 1;
        assert_eq!(a.adder_depth(), 0);
    }
}
