//! Frame-parallel sweep engine: run many independent frames of one
//! configured [`Simulator`] across scoped threads (std-only; the build
//! is offline, so no rayon).
//!
//! The parallel grain is the whole frame. Frames share nothing mutable
//! — each worker gets its own [`FunctionalNet`](crate::snn::FunctionalNet)
//! scratch via `Simulator::run_frame` — so there is no synchronization
//! inside the hot loop, and per-*step* channel threading (tried and
//! reverted, see PERF.md) is not needed. Output ordering is
//! deterministic: result `i` always corresponds to input `i`, and each
//! frame's arithmetic is untouched, so a parallel sweep is bit-identical
//! to the serial one (asserted by `rust/tests/parallel_sweep.rs`).
//!
//! Golden (PJRT) traces must be produced *before* the sweep — the PJRT
//! client is not thread-safe — and are then consumed read-only by any
//! number of workers.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

use anyhow::Result;

use super::engine::{Simulator, TraceSource};
use super::report::FrameReport;
use crate::snn::{SpikeMap, TemporalSpikeMap};

/// Sweep width: `SKYDIVER_SWEEP_THREADS` if set, else the machine's
/// available parallelism, else 1.
pub fn default_threads() -> usize {
    if let Some(n) = std::env::var("SKYDIVER_SWEEP_THREADS").ok()
        .and_then(|v| v.parse::<usize>().ok()) {
        return n.max(1);
    }
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Deterministic parallel map over a slice: `f(i, &items[i])` for every
/// item, on up to `threads` scoped threads pulling indices from a
/// shared atomic counter (work-conserving — the host-side analogue of
/// the pull-based worker queue). Results come back in input order
/// regardless of completion order. `threads <= 1` (or a single item)
/// degenerates to a plain serial loop with no thread machinery at all.
pub fn parallel_map<T, R, F>(items: &[T], threads: usize, f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(usize, &T) -> R + Sync,
{
    let n = items.len();
    let threads = threads.max(1).min(n.max(1));
    if threads <= 1 || n <= 1 {
        return items.iter().enumerate().map(|(i, it)| f(i, it)).collect();
    }
    let next = AtomicUsize::new(0);
    let slots: Vec<Mutex<Option<R>>> =
        (0..n).map(|_| Mutex::new(None)).collect();
    std::thread::scope(|s| {
        for _ in 0..threads {
            s.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let r = f(i, &items[i]);
                *slots[i].lock().unwrap() = Some(r);
            });
        }
    });
    slots.into_iter()
        .map(|m| m.into_inner().unwrap().expect("every slot filled"))
        .collect()
}

/// One frame of a sweep: the encoded spike train plus where its
/// per-layer activity comes from.
pub struct FrameJob<'a> {
    pub inputs: &'a [SpikeMap],
    pub trace: &'a TraceSource,
}

/// Simulate every job on up to `threads` threads; reports come back in
/// job order. The first frame error aborts the result (remaining frames
/// may still have been simulated — their reports are dropped).
pub fn run_frames(sim: &Simulator, jobs: &[FrameJob], threads: usize)
                  -> Result<Vec<FrameReport>> {
    parallel_map(jobs, threads, |_, j| sim.run_frame(j.inputs, j.trace))
        .into_iter()
        .collect()
}

/// Functional-trace convenience: sweep over many encoded frames.
pub fn run_frames_functional(sim: &Simulator, trains: &[Vec<SpikeMap>],
                             threads: usize) -> Result<Vec<FrameReport>> {
    parallel_map(trains, threads,
                 |_, t| sim.run_frame(t, &TraceSource::Functional))
        .into_iter()
        .collect()
}

/// Temporal-kernel sweep over time-major frames: same determinism and
/// frame-grain parallelism as [`run_frames_functional`], reports
/// bit-identical to it (see `Simulator::run_frame_temporal`).
pub fn run_frames_temporal(sim: &Simulator,
                           trains: &[TemporalSpikeMap],
                           threads: usize) -> Result<Vec<FrameReport>> {
    parallel_map(trains, threads, |_, t| sim.run_frame_temporal(t))
        .into_iter()
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parallel_map_preserves_order() {
        let items: Vec<usize> = (0..100).collect();
        let got = parallel_map(&items, 4, |i, &v| {
            assert_eq!(i, v);
            v * 3
        });
        assert_eq!(got, (0..100).map(|v| v * 3).collect::<Vec<_>>());
    }

    #[test]
    fn parallel_map_serial_degenerate() {
        let items = [1usize, 2, 3];
        assert_eq!(parallel_map(&items, 1, |_, &v| v + 1), vec![2, 3, 4]);
        assert_eq!(parallel_map(&items, 0, |_, &v| v + 1), vec![2, 3, 4]);
        let empty: Vec<usize> = Vec::new();
        assert!(parallel_map(&empty, 8, |_, &v: &usize| v).is_empty());
    }

    #[test]
    fn parallel_map_more_threads_than_items() {
        let items = [10usize, 20];
        assert_eq!(parallel_map(&items, 16, |_, &v| v), vec![10, 20]);
    }

    #[test]
    fn default_threads_at_least_one() {
        assert!(default_threads() >= 1);
    }
}
