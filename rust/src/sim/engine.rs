//! The simulation engine: drives the timing model over full frames.
//!
//! Two trace sources:
//! * **Functional** — the event-driven f32 model ([`FunctionalNet`])
//!   computes every layer's spikes; no PJRT needed. Used by schedule
//!   sweeps, ablations, property tests.
//! * **Golden** — per-layer spike traces produced by the PJRT runtime
//!   executing the AOT-compiled JAX step function; authoritative for the
//!   experiments (DESIGN.md §5).

use anyhow::{ensure, Result};

use super::report::{FrameReport, LayerStats};
use super::timing::{dma_cycles, layer_timing_with_rows};
use super::ArchConfig;
use crate::schedule::{Partition, Scheduler};
use crate::schedule::aprc::AprcPredictor;
use crate::snn::{FunctionalNet, NetworkWeights, SpikeMap,
                 TemporalSpikeMap};

/// Where the per-layer spike activity comes from.
pub enum TraceSource {
    /// Compute spikes with the in-crate functional model.
    Functional,
    /// Pre-computed per-timestep per-layer output maps
    /// (`trace[t][l]` = output spikes of layer `l` at step `t`).
    Golden(Vec<Vec<SpikeMap>>),
}

/// A configured accelerator instance: architecture + per-layer channel
/// partitions (the offline CBWS output loaded at "bitstream" time).
pub struct Simulator<'a> {
    pub arch: ArchConfig,
    pub net: &'a NetworkWeights,
    pub partitions: Vec<Partition>,
}

impl<'a> Simulator<'a> {
    /// Build with a scheduling policy + workload predictor
    /// (`scheduler.assign(predictor.layer(l), N)` per layer).
    pub fn new(arch: ArchConfig, net: &'a NetworkWeights,
               scheduler: &dyn Scheduler, predictor: &AprcPredictor)
               -> Self {
        let partitions = (0..net.layers.len())
            .map(|l| scheduler.assign(predictor.layer(l), arch.n_spes))
            .collect();
        Self { arch, net, partitions }
    }

    /// Build with explicit partitions (ablations, oracle replay).
    pub fn with_partitions(arch: ArchConfig, net: &'a NetworkWeights,
                           partitions: Vec<Partition>) -> Result<Self> {
        ensure!(partitions.len() == net.layers.len(),
                "need one partition per layer");
        Ok(Self { arch, net, partitions })
    }

    /// Simulate one frame given the encoded input spike train.
    pub fn run_frame(&self, inputs: &[SpikeMap], trace: &TraceSource)
                     -> Result<FrameReport> {
        let nl = self.net.layers.len();
        ensure!(nl > 0, "cannot simulate a zero-layer network");
        let mut report = FrameReport {
            layers: (0..nl).map(|l| LayerStats { layer: l,
                                                 ..Default::default() })
                .collect(),
            timesteps: inputs.len(),
            ..Default::default()
        };
        let last = nl - 1;
        let (oc, ohh, oww) = self.net.layer_output_shape(last);
        report.output_counts = vec![0u32; oc * ohh * oww];

        let mut functional = match trace {
            TraceSource::Functional => Some(FunctionalNet::new(self.net)),
            TraceSource::Golden(t) => {
                ensure!(t.len() == inputs.len(),
                        "trace length {} != timesteps {}", t.len(),
                        inputs.len());
                None
            }
        };

        // Per-step scratch, reused across the whole frame: the timestep
        // loop below performs no heap allocation (see PERF.md) — the
        // functional model steps into its own retained buffers, golden
        // traces are borrowed, and the per-layer count vectors live
        // here.
        let mut nnz: Vec<usize> = Vec::new();
        let mut row_buf: Vec<u64> = Vec::new();
        for (t, input) in inputs.iter().enumerate() {
            // Per-layer outputs at this timestep (borrowed, not cloned).
            let outs: &[SpikeMap] = match (&mut functional, trace) {
                (Some(f), _) => f.step_reuse(input),
                (None, TraceSource::Golden(tr)) => tr[t].as_slice(),
                _ => unreachable!(),
            };
            ensure!(outs.len() == nl, "trace has {} layers, net {}",
                    outs.len(), nl);

            for l in 0..nl {
                let in_map = if l == 0 { input } else { &outs[l - 1] };
                in_map.nnz_per_channel_into(&mut nnz);
                // Sub-channel fallbacks (paper §III-C stream
                // partitioning): conv layers with fewer input channels
                // than SPEs split by interleaved rows; the dense layer
                // always splits by interleaved input neuron (its weight
                // rows are per-neuron, so the channel grain is
                // artificial there).
                let rows: Option<&[u64]> = match &self.net.layers[l] {
                    crate::snn::LayerWeights::Dense { .. } => {
                        in_map.nnz_index_interleaved_into(
                            self.arch.n_spes, &mut row_buf);
                        Some(&row_buf)
                    }
                    _ if in_map.c < self.arch.n_spes => {
                        in_map.nnz_row_interleaved_into(
                            self.arch.n_spes, &mut row_buf);
                        Some(&row_buf)
                    }
                    _ => None,
                };
                let timing = layer_timing_with_rows(
                    &self.arch, &self.net.layers[l], &self.partitions[l],
                    &nnz, rows);
                report.layers[l].absorb(&timing, self.arch.n_spes);
                report.compute_cycles += timing.cycles;
                report.synops += timing.synops;
                report.events += timing.events;
                report.weight_reads += timing.weight_reads;
                report.vmem_rmw += timing.vmem_rmw;
                report.state_reads += timing.state_reads;
            }
            for (ch, idx) in outs[last].iter_events() {
                report.output_counts[ch * ohh * oww + idx] += 1;
            }
        }

        // DMA: input spike words in, output spike words out.
        let in_bytes: usize = inputs.iter()
            .map(|m| m.scan_words() * 8).sum();
        let out_bytes = report.output_counts.len() * 4;
        report.dma_bytes = (in_bytes + out_bytes) as u64;
        report.dma_cycles = dma_cycles(&self.arch, in_bytes)
            + dma_cycles(&self.arch, out_bytes);
        report.total_cycles = report.compute_cycles + report.dma_cycles;
        Ok(report)
    }

    /// Simulate with the functional model (convenience).
    pub fn run_frame_functional(&self, inputs: &[SpikeMap])
                                -> Result<FrameReport> {
        self.run_frame(inputs, &TraceSource::Functional)
    }

    /// Simulate one frame from a time-major input via the bit-parallel
    /// temporal kernels. Produces a [`FrameReport`] bit-identical to
    /// [`run_frame`](Self::run_frame) with `TraceSource::Functional`
    /// over the unpacked timesteps: the temporal kernels are an exact
    /// oracle match, the per-timestep activity counts the timing model
    /// consumes are extracted from the packed maps in one pass per
    /// layer, and the stats are absorbed in the same (timestep outer,
    /// layer inner) order — the per-layer balance accumulation is f64
    /// and order-sensitive.
    pub fn run_frame_temporal(&self, input: &TemporalSpikeMap)
                              -> Result<FrameReport> {
        let nl = self.net.layers.len();
        ensure!(nl > 0, "cannot simulate a zero-layer network");
        let t_total = input.t;
        ensure!(t_total > 0, "cannot simulate a zero-timestep frame");
        let mut report = FrameReport {
            layers: (0..nl).map(|l| LayerStats { layer: l,
                                                 ..Default::default() })
                .collect(),
            timesteps: t_total,
            ..Default::default()
        };
        let last = nl - 1;
        let (oc, ohh, oww) = self.net.layer_output_shape(last);
        report.output_counts = vec![0u32; oc * ohh * oww];

        let mut functional = FunctionalNet::new(self.net);
        let outs = functional.run_frame_temporal(input);
        outs[last].counts_into(&mut report.output_counts);

        // Per-layer per-timestep activity, one pass over each packed
        // map (instead of T per-timestep scans).
        let n = self.arch.n_spes;
        let mut nnz_t: Vec<Vec<usize>> = Vec::with_capacity(nl);
        let mut rows_t: Vec<Option<Vec<u64>>> = Vec::with_capacity(nl);
        for l in 0..nl {
            let in_map = if l == 0 { input } else { &outs[l - 1] };
            let mut nnz = Vec::new();
            in_map.nnz_per_channel_t_into(&mut nnz);
            nnz_t.push(nnz);
            rows_t.push(match &self.net.layers[l] {
                crate::snn::LayerWeights::Dense { .. } => {
                    let mut r = Vec::new();
                    in_map.nnz_index_interleaved_t_into(n, &mut r);
                    Some(r)
                }
                _ if in_map.c < n => {
                    let mut r = Vec::new();
                    in_map.nnz_row_interleaved_t_into(n, &mut r);
                    Some(r)
                }
                _ => None,
            });
        }

        for t in 0..t_total {
            for l in 0..nl {
                let c = if l == 0 { input.c } else { outs[l - 1].c };
                let nnz = &nnz_t[l][t * c..(t + 1) * c];
                let rows = rows_t[l].as_deref()
                    .map(|r| &r[t * n..(t + 1) * n]);
                let timing = layer_timing_with_rows(
                    &self.arch, &self.net.layers[l], &self.partitions[l],
                    nnz, rows);
                report.layers[l].absorb(&timing, n);
                report.compute_cycles += timing.cycles;
                report.synops += timing.synops;
                report.events += timing.events;
                report.weight_reads += timing.weight_reads;
                report.vmem_rmw += timing.vmem_rmw;
                report.state_reads += timing.state_reads;
            }
        }

        // DMA identical to the per-timestep path: the wire format is
        // still T spatial maps of `c * ceil(h*w/64)` words each.
        let step_words = input.c * (input.h * input.w).div_ceil(64);
        let in_bytes = t_total * step_words * 8;
        let out_bytes = report.output_counts.len() * 4;
        report.dma_bytes = (in_bytes + out_bytes) as u64;
        report.dma_cycles = dma_cycles(&self.arch, in_bytes)
            + dma_cycles(&self.arch, out_bytes);
        report.total_cycles = report.compute_cycles + report.dma_cycles;
        Ok(report)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::schedule::baselines::Contiguous;
    use crate::snn::{ConvGeom, LayerWeights, WeightsMeta};

    fn tiny_net() -> NetworkWeights {
        let meta = WeightsMeta::parse(r#"{
            "name": "tiny", "aprc": true, "pad": 2, "vth": 0.5,
            "timesteps": 4, "in_shape": [2, 6, 6],
            "feature_sizes": [[4, 8, 8]], "dense_out": null,
            "total_floats": 0, "lambdas": [], "layers": [],
            "blob_fnv1a64": "0"
        }"#).unwrap();
        NetworkWeights {
            meta,
            layers: vec![LayerWeights::Conv {
                geom: ConvGeom { cin: 2, cout: 4, r: 3, pad: 2, h: 6, w: 6,
                                 eh: 8, ew: 8 },
                w: vec![0.3; 4 * 2 * 9],
            }],
        }
    }

    fn encoded_inputs(rate: f32, t: usize) -> Vec<SpikeMap> {
        let img = vec![rate; 2 * 6 * 6];
        crate::snn::encode_phased(&img, 2, 6, 6, t)
    }

    #[test]
    fn frame_report_consistency() {
        let net = tiny_net();
        let pred = AprcPredictor::uniform(&net);
        let sim = Simulator::new(ArchConfig::default(), &net,
                                 &Contiguous, &pred);
        let inputs = encoded_inputs(0.5, 4);
        let r = sim.run_frame_functional(&inputs).unwrap();
        assert_eq!(r.layers.len(), 1);
        assert_eq!(r.timesteps, 4);
        assert!(r.total_cycles > 0);
        assert!(r.synops > 0, "0.5-rate input must trigger work");
        assert_eq!(r.synops, r.events * 9 * 4);
        assert!(r.total_cycles >= r.compute_cycles);
        assert_eq!(r.output_counts.len(), 4 * 8 * 8);
    }

    #[test]
    fn golden_trace_equals_functional() {
        let net = tiny_net();
        let pred = AprcPredictor::uniform(&net);
        let sim = Simulator::new(ArchConfig::default(), &net,
                                 &Contiguous, &pred);
        let inputs = encoded_inputs(0.7, 3);
        // Build a golden trace with the functional model itself.
        let mut f = FunctionalNet::new(&net);
        let trace: Vec<Vec<SpikeMap>> = inputs.iter()
            .map(|i| f.step(i).into_iter().map(|o| o.spikes).collect())
            .collect();
        let a = sim.run_frame_functional(&inputs).unwrap();
        let b = sim.run_frame(&inputs, &TraceSource::Golden(trace)).unwrap();
        assert_eq!(a.total_cycles, b.total_cycles);
        assert_eq!(a.synops, b.synops);
        assert_eq!(a.output_counts, b.output_counts);
    }

    #[test]
    fn silent_input_costs_only_scan_and_overheads() {
        let net = tiny_net();
        let pred = AprcPredictor::uniform(&net);
        let sim = Simulator::new(ArchConfig::default(), &net,
                                 &Contiguous, &pred);
        let inputs = encoded_inputs(0.0, 4);
        let r = sim.run_frame_functional(&inputs).unwrap();
        assert_eq!(r.events, 0);
        assert_eq!(r.synops, 0);
        assert!(r.total_cycles > 0, "scan + setup still cost");
    }

    #[test]
    fn zero_layer_network_rejected_not_panicking() {
        // Regression: `let last = nl - 1` used to underflow and panic.
        let meta = WeightsMeta::parse(r#"{
            "name": "empty", "aprc": true, "pad": 2, "vth": 0.5,
            "timesteps": 4, "in_shape": [2, 6, 6],
            "feature_sizes": [], "dense_out": null,
            "total_floats": 0, "lambdas": [], "layers": [],
            "blob_fnv1a64": "0"
        }"#).unwrap();
        let net = NetworkWeights { meta, layers: vec![] };
        let sim = Simulator::with_partitions(ArchConfig::default(), &net,
                                             vec![]).unwrap();
        let inputs = encoded_inputs(0.5, 4);
        let err = sim.run_frame_functional(&inputs);
        assert!(err.is_err(), "zero-layer net must Err, not panic");
    }

    #[test]
    fn temporal_report_equals_per_timestep_report() {
        // The whole FrameReport — cycles, per-layer stats including the
        // f64 balance accumulators, output counts, DMA — must be
        // bit-identical between the temporal path and the per-timestep
        // path, at T values straddling the 64-bit word.
        let net = tiny_net();
        let pred = AprcPredictor::uniform(&net);
        let sim = Simulator::new(ArchConfig::default(), &net,
                                 &Contiguous, &pred);
        for t in [1usize, 4, 63, 64, 65] {
            let inputs = encoded_inputs(0.37, t);
            let packed = TemporalSpikeMap::from_steps(&inputs);
            let a = sim.run_frame_functional(&inputs).unwrap();
            let b = sim.run_frame_temporal(&packed).unwrap();
            assert_eq!(a, b, "T={t}");
        }
    }

    #[test]
    fn temporal_rejects_degenerate_frames() {
        let meta = WeightsMeta::parse(r#"{
            "name": "empty", "aprc": true, "pad": 2, "vth": 0.5,
            "timesteps": 4, "in_shape": [2, 6, 6],
            "feature_sizes": [], "dense_out": null,
            "total_floats": 0, "lambdas": [], "layers": [],
            "blob_fnv1a64": "0"
        }"#).unwrap();
        let net = NetworkWeights { meta, layers: vec![] };
        let sim = Simulator::with_partitions(ArchConfig::default(), &net,
                                             vec![]).unwrap();
        let packed = TemporalSpikeMap::zeros(2, 6, 6, 4);
        assert!(sim.run_frame_temporal(&packed).is_err(),
                "zero-layer net must Err, not panic");
        let net2 = tiny_net();
        let pred = AprcPredictor::uniform(&net2);
        let sim2 = Simulator::new(ArchConfig::default(), &net2,
                                  &Contiguous, &pred);
        let empty = TemporalSpikeMap::zeros(2, 6, 6, 0);
        assert!(sim2.run_frame_temporal(&empty).is_err(),
                "zero-timestep frame must Err, not panic");
    }

    #[test]
    fn trace_length_mismatch_rejected() {
        let net = tiny_net();
        let pred = AprcPredictor::uniform(&net);
        let sim = Simulator::new(ArchConfig::default(), &net,
                                 &Contiguous, &pred);
        let inputs = encoded_inputs(0.5, 4);
        let err = sim.run_frame(&inputs, &TraceSource::Golden(vec![]));
        assert!(err.is_err());
    }
}
