//! Observability: span tracing, flight recorder, leveled logging,
//! and build/uptime identity metrics.
//!
//! Submodules:
//! * [`trace`] — per-thread lock-free span rings, the monotonic
//!   process epoch, model interning, and `skydiver_stage_us`
//!   histograms. Disabled by default; `--trace` / `SKYDIVER_TRACE=1`
//!   turns it on.
//! * [`recorder`] — flight recorder of recent / slowest / errored
//!   traces, Chrome trace-event dump, terminal tree renderer.
//! * [`log`] — leveled stderr logger behind the crate-root
//!   `log_warn!`-family macros; `SKYDIVER_LOG` / `--log-level`.

pub mod log;
pub mod recorder;
pub mod trace;

pub use trace::uptime_secs;

/// Crate version baked at compile time.
pub const VERSION: &str = env!("CARGO_PKG_VERSION");

/// Git revision if the build exported `SKYDIVER_GIT_SHA`.
pub const GIT_SHA: &str = match option_env!("SKYDIVER_GIT_SHA") {
    Some(s) => s,
    None => "unknown",
};

/// Read `SKYDIVER_LOG` and `SKYDIVER_TRACE` once at process start.
/// CLI flags (`--log-level`, `--trace`) override afterwards.
pub fn init_from_env() {
    if let Ok(v) = std::env::var("SKYDIVER_LOG") {
        if let Some(l) = log::parse_level(&v) {
            log::set_level(l);
        }
    }
    if let Ok(v) = std::env::var("SKYDIVER_TRACE") {
        if v == "1" || v.eq_ignore_ascii_case("true") {
            trace::set_enabled(true);
        }
    }
}

/// Append `skydiver_build_info` and `skydiver_uptime_seconds` to a
/// Prometheus exposition. Shared by the gateway and the router so
/// multi-process cluster scrapes attribute samples to a binary.
pub fn render_build_info(out: &mut String) {
    use std::fmt::Write as _;
    let _ = writeln!(out, "# TYPE skydiver_build_info gauge");
    let _ = writeln!(
        out,
        "skydiver_build_info{{version=\"{VERSION}\",git=\"{GIT_SHA}\"}} 1"
    );
    let _ = writeln!(out, "# TYPE skydiver_uptime_seconds gauge");
    let _ = writeln!(
        out,
        "skydiver_uptime_seconds {:.3}",
        uptime_secs()
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn build_info_exposition_is_well_formed() {
        let mut out = String::new();
        render_build_info(&mut out);
        assert!(out.contains("skydiver_build_info{version=\""));
        assert!(out.contains("skydiver_uptime_seconds "));
        assert!(!VERSION.is_empty());
        assert!(!GIT_SHA.is_empty());
    }
}
