//! Leveled structured logger for the serving/cluster tiers.
//!
//! One static level gate (`SKYDIVER_LOG=error|warn|info|debug` or
//! `--log-level`), monotonic timestamps from the shared trace epoch,
//! and a `target` field so CI smoke logs are greppable per subsystem:
//!
//! ```text
//! [12.041633 WARN cluster::router] backend 127.0.0.1:4012 ejected after 2 misses
//! ```
//!
//! Use through the crate-root macros:
//!
//! ```ignore
//! log_warn!("cluster::router", "backend {addr} ejected after {n} misses");
//! ```
//!
//! The macros check [`enabled`] before building `format_args`, so a
//! disabled level costs one relaxed atomic load — cheap enough for
//! event sites, though per-request hot paths should not log at all.

use std::io::Write as _;
use std::sync::atomic::{AtomicU8, Ordering};

#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
#[repr(u8)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
}

impl Level {
    pub fn as_str(self) -> &'static str {
        match self {
            Level::Error => "ERROR",
            Level::Warn => "WARN",
            Level::Info => "INFO",
            Level::Debug => "DEBUG",
        }
    }
}

/// Default: warnings and errors only (quiet tests / CI logs).
static LEVEL: AtomicU8 = AtomicU8::new(Level::Warn as u8);

/// Parse a `SKYDIVER_LOG` / `--log-level` value (case-insensitive).
pub fn parse_level(s: &str) -> Option<Level> {
    Some(match s.to_ascii_lowercase().as_str() {
        "error" => Level::Error,
        "warn" | "warning" => Level::Warn,
        "info" => Level::Info,
        "debug" => Level::Debug,
        _ => return None,
    })
}

pub fn set_level(l: Level) {
    LEVEL.store(l as u8, Ordering::Relaxed);
}

pub fn level() -> Level {
    match LEVEL.load(Ordering::Relaxed) {
        0 => Level::Error,
        1 => Level::Warn,
        2 => Level::Info,
        _ => Level::Debug,
    }
}

/// Would a record at `l` be emitted right now?
#[inline]
pub fn enabled(l: Level) -> bool {
    (l as u8) <= LEVEL.load(Ordering::Relaxed)
}

/// Emit one record to stderr. Called by the `log_*!` macros after
/// their level check; the line is formatted into a small buffer first
/// so concurrent threads do not interleave mid-record.
pub fn write(l: Level, target: &str, args: std::fmt::Arguments<'_>) {
    let t = super::trace::uptime_secs();
    let line = format!("[{t:.6} {} {target}] {args}\n", l.as_str());
    let _ = std::io::stderr().write_all(line.as_bytes());
}

#[macro_export]
macro_rules! log_error {
    ($target:expr, $($arg:tt)*) => {
        if $crate::obs::log::enabled($crate::obs::log::Level::Error) {
            $crate::obs::log::write(
                $crate::obs::log::Level::Error,
                $target,
                format_args!($($arg)*),
            );
        }
    };
}

#[macro_export]
macro_rules! log_warn {
    ($target:expr, $($arg:tt)*) => {
        if $crate::obs::log::enabled($crate::obs::log::Level::Warn) {
            $crate::obs::log::write(
                $crate::obs::log::Level::Warn,
                $target,
                format_args!($($arg)*),
            );
        }
    };
}

#[macro_export]
macro_rules! log_info {
    ($target:expr, $($arg:tt)*) => {
        if $crate::obs::log::enabled($crate::obs::log::Level::Info) {
            $crate::obs::log::write(
                $crate::obs::log::Level::Info,
                $target,
                format_args!($($arg)*),
            );
        }
    };
}

#[macro_export]
macro_rules! log_debug {
    ($target:expr, $($arg:tt)*) => {
        if $crate::obs::log::enabled($crate::obs::log::Level::Debug) {
            $crate::obs::log::write(
                $crate::obs::log::Level::Debug,
                $target,
                format_args!($($arg)*),
            );
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn levels_parse_case_insensitively() {
        assert_eq!(parse_level("WARN"), Some(Level::Warn));
        assert_eq!(parse_level("debug"), Some(Level::Debug));
        assert_eq!(parse_level("Info"), Some(Level::Info));
        assert_eq!(parse_level("warning"), Some(Level::Warn));
        assert_eq!(parse_level("trace"), None);
    }

    #[test]
    fn gate_respects_ordering() {
        let prev = level();
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        assert!(!enabled(Level::Debug));
        set_level(Level::Debug);
        assert!(enabled(Level::Debug));
        set_level(prev);
    }
}
