//! Span recording: per-thread lock-free rings of fixed-size span
//! records, a process-wide monotonic clock, model-name interning, and
//! per-(stage, model) explicit-bucket latency histograms.
//!
//! Design rules:
//!
//! * **Disabled is free.** [`enabled`] is one relaxed atomic load;
//!   every instrumentation point checks it before taking timestamps
//!   or touching a ring. No allocation ever happens on the disabled
//!   path (enforced by the alloc-counting bench harness).
//! * **Recording never blocks.** A span is recorded *at its end* as
//!   one fixed-size [`SpanRecord`] into the recording thread's own
//!   ring. Slots are seqlock-versioned arrays of atomics: the single
//!   writer bumps the slot sequence to odd, stores the words, bumps
//!   it back to even; a concurrent dump that observes a mid-write or
//!   changed sequence skips the slot. No locks, no unsafe.
//! * **Strings stay off the hot path.** Models are interned once (at
//!   gateway startup or first sight) to a `u32` index; span records
//!   carry the index, dumps resolve it back.
//!
//! The ring is a bounded history (newest [`RING_CAP`] spans per
//! thread): a flight-recorder dump reconstructs *recent* traces
//! best-effort — spans older than one ring lap are gone, which is the
//! point of a flight recorder.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::Instant;

/// Pipeline stages a span can describe, in hot-path order. The
/// `as_str` names are the wire/dump/metrics vocabulary — `PERF.md`
/// maps each to the code it measures.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
#[repr(u8)]
pub enum Stage {
    /// Gateway request validation + model resolution (`handle_infer`
    /// entry to cost prediction).
    Admission = 0,
    /// Request-level APRC cost prediction (`predict_cost`).
    CostPredict = 1,
    /// Bounded-queue residency: submit to worker pull.
    QueueWait = 2,
    /// Batch assembly + intra-batch wait: worker pull to this
    /// request's compute start.
    Batch = 3,
    /// Worker compute: encode + simulate (sim cycles and predicted
    /// cost ride along as attributes).
    Compute = 4,
    /// Response encoding in the gateway router thread.
    Encode = 5,
    /// Reactor write: response frame queued on the connection until
    /// fully written to the socket.
    Write = 6,
    /// Cluster router: whole client-request residency in the router.
    Route = 7,
    /// Cluster router: one dispatch attempt against one backend;
    /// failover produces sibling attempts under the same parent.
    Attempt = 8,
    /// Autoscaler scale event: the control-loop tick that resized a
    /// model's worker pool (attrs = old and new pool size). Root span
    /// under its own generated trace id — not tied to any request.
    Scale = 9,
}

/// Number of [`Stage`] variants (histogram table dimension).
pub const N_STAGES: usize = 10;

impl Stage {
    pub fn as_str(self) -> &'static str {
        match self {
            Stage::Admission => "admission",
            Stage::CostPredict => "cost_predict",
            Stage::QueueWait => "queue",
            Stage::Batch => "batch",
            Stage::Compute => "compute",
            Stage::Encode => "encode",
            Stage::Write => "write",
            Stage::Route => "route",
            Stage::Attempt => "attempt",
            Stage::Scale => "scale",
        }
    }

    pub fn from_u8(v: u8) -> Option<Stage> {
        Some(match v {
            0 => Stage::Admission,
            1 => Stage::CostPredict,
            2 => Stage::QueueWait,
            3 => Stage::Batch,
            4 => Stage::Compute,
            5 => Stage::Encode,
            6 => Stage::Write,
            7 => Stage::Route,
            8 => Stage::Attempt,
            9 => Stage::Scale,
            _ => return None,
        })
    }
}

/// Model index meaning "no model attribution" (framing errors, router
/// spans for Info requests, …).
pub const MODEL_NONE: u32 = u32::MAX;

/// One completed span, fixed-size (packs into `SLOT_WORDS` u64s).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SpanRecord {
    pub trace_id: [u8; 16],
    pub span_id: u64,
    /// 0 = root (no parent).
    pub parent_span: u64,
    /// Monotonic ns since this process's [`epoch`].
    pub start_ns: u64,
    pub end_ns: u64,
    pub stage: Stage,
    /// Interned model index ([`intern_model`]) or [`MODEL_NONE`].
    pub model: u32,
    pub error: bool,
    /// Stage-specific: sim cycles (compute), backend index (attempt).
    pub attr_a: u64,
    /// Stage-specific: predicted cost (compute), attempt number
    /// (attempt).
    pub attr_b: u64,
}

impl SpanRecord {
    pub fn duration_us(&self) -> u64 {
        self.end_ns.saturating_sub(self.start_ns) / 1_000
    }

    /// Lowercase-hex trace id (the dump/wire spelling).
    pub fn trace_hex(&self) -> String {
        trace_id_hex(&self.trace_id)
    }

    fn pack(&self) -> [u64; SLOT_WORDS] {
        [
            u64::from_le_bytes(self.trace_id[..8].try_into().unwrap()),
            u64::from_le_bytes(self.trace_id[8..].try_into().unwrap()),
            self.span_id,
            self.parent_span,
            self.start_ns,
            self.end_ns,
            (self.stage as u64)
                | ((self.error as u64) << 8)
                | ((self.model as u64) << 16),
            self.attr_a,
            self.attr_b,
        ]
    }

    fn unpack(w: &[u64; SLOT_WORDS]) -> Option<SpanRecord> {
        let mut trace_id = [0u8; 16];
        trace_id[..8].copy_from_slice(&w[0].to_le_bytes());
        trace_id[8..].copy_from_slice(&w[1].to_le_bytes());
        Some(SpanRecord {
            trace_id,
            span_id: w[2],
            parent_span: w[3],
            start_ns: w[4],
            end_ns: w[5],
            stage: Stage::from_u8((w[6] & 0xFF) as u8)?,
            error: (w[6] >> 8) & 1 == 1,
            model: (w[6] >> 16) as u32,
            attr_a: w[7],
            attr_b: w[8],
        })
    }
}

/// Render a 16-byte trace id as 32 lowercase hex chars.
pub fn trace_id_hex(id: &[u8; 16]) -> String {
    let mut s = String::with_capacity(32);
    for b in id {
        use std::fmt::Write as _;
        let _ = write!(s, "{b:02x}");
    }
    s
}

/// Parse the hex spelling back (dump stitching in tests/tools).
pub fn trace_id_from_hex(s: &str) -> Option<[u8; 16]> {
    if s.len() != 32 {
        return None;
    }
    let mut id = [0u8; 16];
    for (i, chunk) in s.as_bytes().chunks(2).enumerate() {
        let hx = std::str::from_utf8(chunk).ok()?;
        id[i] = u8::from_str_radix(hx, 16).ok()?;
    }
    Some(id)
}

// -------------------------------------------------------- global state

static ENABLED: AtomicBool = AtomicBool::new(false);
static NEXT_SPAN: AtomicU64 = AtomicU64::new(1);
static TRACE_CTR: AtomicU64 = AtomicU64::new(0);

/// Is span recording on? One relaxed load — the whole cost of the
/// disabled path.
#[inline]
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Flip span recording (CLI `--trace`, `SKYDIVER_TRACE=1`, benches).
pub fn set_enabled(on: bool) {
    ENABLED.store(on, Ordering::SeqCst);
}

/// The process's span clock origin. First use pins it; all span
/// timestamps are ns since this instant (monotonic, never wall time).
pub fn epoch() -> Instant {
    static EPOCH: OnceLock<Instant> = OnceLock::new();
    *EPOCH.get_or_init(Instant::now)
}

/// Monotonic ns since [`epoch`].
#[inline]
pub fn now_ns() -> u64 {
    epoch().elapsed().as_nanos() as u64
}

/// Seconds since [`epoch`] (uptime metric, log timestamps).
pub fn uptime_secs() -> f64 {
    epoch().elapsed().as_secs_f64()
}

/// Fresh process-unique span id (0 is reserved for "no parent").
pub fn next_span_id() -> u64 {
    NEXT_SPAN.fetch_add(1, Ordering::Relaxed)
}

fn splitmix64(mut x: u64) -> u64 {
    x = x.wrapping_add(0x9E37_79B9_7F4A_7C15);
    x = (x ^ (x >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    x = (x ^ (x >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    x ^ (x >> 31)
}

/// Fresh 16-byte trace id: a per-process random seed (wall clock ^
/// pid, so two processes started together still diverge) mixed with a
/// counter — unique within a process, collision-negligible across the
/// cluster.
pub fn gen_trace_id() -> [u8; 16] {
    static SEED: OnceLock<u64> = OnceLock::new();
    let seed = *SEED.get_or_init(|| {
        let t = std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .map(|d| d.as_nanos() as u64)
            .unwrap_or(0);
        splitmix64(t ^ ((std::process::id() as u64) << 32))
    });
    let n = TRACE_CTR.fetch_add(1, Ordering::Relaxed);
    let a = splitmix64(seed ^ n);
    let b = splitmix64(a ^ n.rotate_left(32));
    let mut id = [0u8; 16];
    id[..8].copy_from_slice(&a.to_le_bytes());
    id[8..].copy_from_slice(&b.to_le_bytes());
    id
}

// ------------------------------------------------------ span rings

/// Spans retained per recording thread (power of two).
pub const RING_CAP: usize = 4096;
const SLOT_WORDS: usize = 9;

struct Slot {
    /// Seqlock: odd while the writer is mid-store; a reader that sees
    /// the value change (or odd) discards the slot.
    seq: AtomicU64,
    words: [AtomicU64; SLOT_WORDS],
}

/// One thread's span history. Written only by the owning thread,
/// snapshot from any thread.
pub struct SpanRing {
    slots: Vec<Slot>,
    head: AtomicU64,
}

impl SpanRing {
    fn new() -> Self {
        let slots = (0..RING_CAP)
            .map(|_| Slot {
                seq: AtomicU64::new(0),
                words: std::array::from_fn(|_| AtomicU64::new(0)),
            })
            .collect();
        Self { slots, head: AtomicU64::new(0) }
    }

    fn push(&self, rec: &SpanRecord) {
        let h = self.head.fetch_add(1, Ordering::Relaxed);
        let slot = &self.slots[(h as usize) & (RING_CAP - 1)];
        let seq = slot.seq.load(Ordering::Relaxed);
        slot.seq.store(seq.wrapping_add(1), Ordering::Release);
        for (w, v) in slot.words.iter().zip(rec.pack()) {
            w.store(v, Ordering::Release);
        }
        slot.seq.store(seq.wrapping_add(2), Ordering::Release);
    }

    fn snapshot_into(&self, out: &mut Vec<SpanRecord>) {
        for slot in &self.slots {
            let s1 = slot.seq.load(Ordering::Acquire);
            if s1 == 0 || s1 & 1 == 1 {
                continue; // never written, or mid-write
            }
            let mut words = [0u64; SLOT_WORDS];
            for (d, w) in words.iter_mut().zip(&slot.words) {
                *d = w.load(Ordering::Acquire);
            }
            if slot.seq.load(Ordering::Acquire) != s1 {
                continue; // overwritten while reading
            }
            if let Some(rec) = SpanRecord::unpack(&words) {
                out.push(rec);
            }
        }
    }
}

fn registry() -> &'static Mutex<Vec<Arc<SpanRing>>> {
    static REG: OnceLock<Mutex<Vec<Arc<SpanRing>>>> = OnceLock::new();
    REG.get_or_init(|| Mutex::new(Vec::new()))
}

thread_local! {
    static LOCAL_RING: Arc<SpanRing> = {
        let ring = Arc::new(SpanRing::new());
        registry().lock().unwrap().push(ring.clone());
        ring
    };
}

/// Record one completed span into the calling thread's ring and fold
/// its duration into the stage histograms. No-op while disabled.
pub fn record(rec: &SpanRecord) {
    if !enabled() {
        return;
    }
    LOCAL_RING.with(|r| r.push(rec));
    observe_stage(rec.stage, rec.model, rec.duration_us());
}

/// Record one completed span `[start_ns, now]` in one call and return
/// its fresh span id (0 when tracing is disabled — callers hand the
/// returned id to child spans as `parent_span`). The argument list
/// mirrors [`SpanRecord`] minus the ids/end, which this fills in.
#[allow(clippy::too_many_arguments)]
pub fn span(trace_id: [u8; 16], parent_span: u64, stage: Stage,
            model: u32, start_ns: u64, error: bool, attr_a: u64,
            attr_b: u64) -> u64 {
    if !enabled() {
        return 0;
    }
    let span_id = next_span_id();
    record(&SpanRecord {
        trace_id,
        span_id,
        parent_span,
        start_ns,
        end_ns: now_ns(),
        stage,
        model,
        error,
        attr_a,
        attr_b,
    });
    span_id
}

/// Copy every live span out of every thread's ring (dump path only —
/// walks all rings under the registry lock).
pub fn snapshot_all() -> Vec<SpanRecord> {
    let rings: Vec<Arc<SpanRing>> =
        registry().lock().unwrap().clone();
    let mut out = Vec::new();
    for ring in rings {
        ring.snapshot_into(&mut out);
    }
    out
}

// --------------------------------------------------- model interning

/// Model-name slots with their own histogram row (index
/// `MAX_MODEL_SLOTS - 1` is the shared overflow row, labelled
/// `_other`).
const MAX_MODEL_SLOTS: usize = 17;

fn models() -> &'static Mutex<Vec<String>> {
    static M: OnceLock<Mutex<Vec<String>>> = OnceLock::new();
    M.get_or_init(|| Mutex::new(Vec::new()))
}

/// Intern a model name to a stable index. Call at mount time, not per
/// request (takes a lock, may allocate).
pub fn intern_model(name: &str) -> u32 {
    let mut m = models().lock().unwrap();
    if let Some(i) = m.iter().position(|n| n == name) {
        return i as u32;
    }
    m.push(name.to_string());
    (m.len() - 1) as u32
}

/// Resolve an interned index back to its name.
pub fn model_name(idx: u32) -> Option<String> {
    if idx == MODEL_NONE {
        return None;
    }
    models().lock().unwrap().get(idx as usize).cloned()
}

// ----------------------------------------------------- stage histograms

/// Explicit bucket bounds (µs) for `skydiver_stage_us`.
pub const BUCKETS_US: [u64; 16] = [
    1, 2, 5, 10, 25, 50, 100, 250, 500, 1_000, 2_500, 5_000, 10_000,
    25_000, 50_000, 100_000,
];

struct Hist {
    /// One counter per bound plus the `+Inf` overflow.
    buckets: [AtomicU64; BUCKETS_US.len() + 1],
    sum_us: AtomicU64,
    count: AtomicU64,
}

impl Hist {
    fn new() -> Self {
        Self {
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
            sum_us: AtomicU64::new(0),
            count: AtomicU64::new(0),
        }
    }

    fn observe(&self, us: u64) {
        let i = BUCKETS_US
            .iter()
            .position(|&b| us <= b)
            .unwrap_or(BUCKETS_US.len());
        self.buckets[i].fetch_add(1, Ordering::Relaxed);
        self.sum_us.fetch_add(us, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
    }
}

/// `hists()[stage][model_slot]`; the last model slot aggregates
/// everything beyond `MAX_MODEL_SLOTS - 1` interned models.
fn hists() -> &'static Vec<Vec<Hist>> {
    static H: OnceLock<Vec<Vec<Hist>>> = OnceLock::new();
    H.get_or_init(|| {
        (0..N_STAGES)
            .map(|_| (0..MAX_MODEL_SLOTS).map(|_| Hist::new()).collect())
            .collect()
    })
}

fn model_slot(model: u32) -> usize {
    if model == MODEL_NONE {
        MAX_MODEL_SLOTS - 1
    } else {
        (model as usize).min(MAX_MODEL_SLOTS - 1)
    }
}

/// Fold one stage duration into its `skydiver_stage_us` histogram.
/// (Called by [`record`]; callers that bypass rings can call it
/// directly.)
pub fn observe_stage(stage: Stage, model: u32, dur_us: u64) {
    hists()[stage as usize][model_slot(model)].observe(dur_us);
}

/// Append the `skydiver_stage_us` Prometheus histogram exposition
/// (cumulative buckets, `_sum`, `_count`) for every (stage, model)
/// pair that has observations. Shared by the gateway and the router.
pub fn render_stage_metrics(out: &mut String) {
    use std::fmt::Write as _;
    let h = hists();
    let _ = writeln!(out, "# TYPE skydiver_stage_us histogram");
    for stage_idx in 0..N_STAGES {
        let stage = Stage::from_u8(stage_idx as u8).unwrap();
        for slot in 0..MAX_MODEL_SLOTS {
            let hist = &h[stage_idx][slot];
            let count = hist.count.load(Ordering::Relaxed);
            if count == 0 {
                continue;
            }
            let model = if slot == MAX_MODEL_SLOTS - 1 {
                "_other".to_string()
            } else {
                model_name(slot as u32)
                    .unwrap_or_else(|| "_other".to_string())
            };
            let stage_s = stage.as_str();
            let mut cum = 0u64;
            for (i, &le) in BUCKETS_US.iter().enumerate() {
                cum += hist.buckets[i].load(Ordering::Relaxed);
                let _ = writeln!(
                    out,
                    "skydiver_stage_us_bucket{{stage=\"{stage_s}\",\
                     model=\"{model}\",le=\"{le}\"}} {cum}"
                );
            }
            let _ = writeln!(
                out,
                "skydiver_stage_us_bucket{{stage=\"{stage_s}\",\
                 model=\"{model}\",le=\"+Inf\"}} {count}"
            );
            let _ = writeln!(
                out,
                "skydiver_stage_us_sum{{stage=\"{stage_s}\",\
                 model=\"{model}\"}} {}",
                hist.sum_us.load(Ordering::Relaxed)
            );
            let _ = writeln!(
                out,
                "skydiver_stage_us_count{{stage=\"{stage_s}\",\
                 model=\"{model}\"}} {count}"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(stage: Stage, span: u64) -> SpanRecord {
        SpanRecord {
            trace_id: [7; 16],
            span_id: span,
            parent_span: 0,
            start_ns: 100,
            end_ns: 2_100,
            stage,
            model: MODEL_NONE,
            error: false,
            attr_a: 42,
            attr_b: 7,
        }
    }

    #[test]
    fn span_record_packs_and_unpacks() {
        let mut r = rec(Stage::Compute, 9);
        r.model = 3;
        r.error = true;
        let w = r.pack();
        assert_eq!(SpanRecord::unpack(&w), Some(r));
    }

    #[test]
    fn disabled_recording_is_invisible() {
        set_enabled(false);
        let before = snapshot_all().len();
        record(&rec(Stage::Admission, next_span_id()));
        assert_eq!(snapshot_all().len(), before);
    }

    #[test]
    fn enabled_recording_lands_in_a_snapshot() {
        set_enabled(true);
        let span = next_span_id();
        record(&rec(Stage::QueueWait, span));
        set_enabled(false);
        assert!(snapshot_all().iter().any(|r| r.span_id == span));
    }

    #[test]
    fn ring_keeps_only_the_newest_lap() {
        let ring = SpanRing::new();
        for i in 0..(RING_CAP as u64 + 10) {
            ring.push(&rec(Stage::Write, i));
        }
        let mut out = Vec::new();
        ring.snapshot_into(&mut out);
        assert_eq!(out.len(), RING_CAP);
        // Span 0..9 were lapped; the newest survive.
        assert!(out.iter().all(|r| r.span_id >= 10));
    }

    #[test]
    fn trace_ids_are_unique_and_hex_roundtrips() {
        let a = gen_trace_id();
        let b = gen_trace_id();
        assert_ne!(a, b);
        assert_eq!(trace_id_from_hex(&trace_id_hex(&a)), Some(a));
        assert_eq!(trace_id_from_hex("zz"), None);
    }

    #[test]
    fn stage_histogram_buckets_are_cumulative() {
        observe_stage(Stage::Encode, MODEL_NONE, 3);
        observe_stage(Stage::Encode, MODEL_NONE, 400);
        observe_stage(Stage::Encode, MODEL_NONE, 9_999_999);
        let mut out = String::new();
        render_stage_metrics(&mut out);
        assert!(out.contains("# TYPE skydiver_stage_us histogram"));
        assert!(out.contains(
            "skydiver_stage_us_bucket{stage=\"encode\",\
             model=\"_other\",le=\"+Inf\"}"
        ));
        // +Inf count equals _count for the same series.
        let inf: u64 = out
            .lines()
            .find(|l| {
                l.starts_with(
                    "skydiver_stage_us_bucket{stage=\"encode\"",
                ) && l.contains("le=\"+Inf\"")
            })
            .and_then(|l| l.rsplit(' ').next())
            .and_then(|v| v.parse().ok())
            .unwrap();
        let count: u64 = out
            .lines()
            .find(|l| {
                l.starts_with(
                    "skydiver_stage_us_count{stage=\"encode\"",
                )
            })
            .and_then(|l| l.rsplit(' ').next())
            .and_then(|v| v.parse().ok())
            .unwrap();
        assert_eq!(inf, count);
        assert!(count >= 3);
    }
}
