//! Flight recorder: which recent traces are worth looking at.
//!
//! The span rings ([`super::trace`]) hold raw spans; the recorder
//! indexes *completed requests* — the last [`LAST_N`] plus, per
//! model, the [`TOP_K`] slowest and the [`TOP_K`] most recent errors
//! — so a dump surfaces the interesting traces instead of whatever
//! happens to be newest. `complete()` runs at reply time (once per
//! request, off the per-frame hot path) and takes a brief mutex; a
//! dump walks the reservoirs, snapshots every ring, and emits Chrome
//! trace-event JSON (`chrome://tracing` / Perfetto loadable).

use std::collections::{HashMap, VecDeque};
use std::sync::{Mutex, OnceLock};

use anyhow::{bail, Result};

use super::trace::{self, SpanRecord};
use crate::util::json::Json;

/// Completed traces retained in arrival order.
pub const LAST_N: usize = 128;
/// Slowest / most-recent-error traces retained per model.
pub const TOP_K: usize = 16;

/// Identity + verdict of one completed request.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TraceMeta {
    pub trace_id: [u8; 16],
    /// Interned model index ([`trace::intern_model`]).
    pub model: u32,
    pub latency_us: u64,
    pub error: bool,
}

#[derive(Default)]
struct ModelReservoir {
    /// Sorted descending by latency, truncated to [`TOP_K`].
    slowest: Vec<TraceMeta>,
    /// Most recent errors, oldest popped first.
    errors: VecDeque<TraceMeta>,
}

#[derive(Default)]
struct Inner {
    last: VecDeque<TraceMeta>,
    per_model: HashMap<u32, ModelReservoir>,
}

fn inner() -> &'static Mutex<Inner> {
    static R: OnceLock<Mutex<Inner>> = OnceLock::new();
    R.get_or_init(|| Mutex::new(Inner::default()))
}

/// Note a completed request. Call once, at reply time, only when
/// tracing is enabled (the caller already holds a trace id).
pub fn complete(meta: TraceMeta) {
    let mut r = inner().lock().unwrap();
    if r.last.len() == LAST_N {
        r.last.pop_front();
    }
    r.last.push_back(meta);
    let res = r.per_model.entry(meta.model).or_default();
    if meta.error {
        if res.errors.len() == TOP_K {
            res.errors.pop_front();
        }
        res.errors.push_back(meta);
    }
    let pos = res
        .slowest
        .binary_search_by(|m| meta.latency_us.cmp(&m.latency_us))
        .unwrap_or_else(|p| p);
    if pos < TOP_K {
        res.slowest.insert(pos, meta);
        res.slowest.truncate(TOP_K);
    }
}

/// All retained trace metadata (last-N window + reservoirs),
/// deduplicated by trace id.
fn retained() -> Vec<TraceMeta> {
    let r = inner().lock().unwrap();
    let mut seen: Vec<TraceMeta> = Vec::new();
    let mut push = |m: &TraceMeta| {
        if !seen.iter().any(|s| s.trace_id == m.trace_id) {
            seen.push(*m);
        }
    };
    for m in &r.last {
        push(m);
    }
    for res in r.per_model.values() {
        for m in &res.slowest {
            push(m);
        }
        for m in &res.errors {
            push(m);
        }
    }
    seen
}

fn span_event(rec: &SpanRecord, tid: usize) -> Json {
    let mut args = vec![
        ("trace", Json::str(rec.trace_hex())),
        ("span", Json::num(rec.span_id as f64)),
        ("parent", Json::num(rec.parent_span as f64)),
        ("error", Json::Bool(rec.error)),
        ("a", Json::num(rec.attr_a as f64)),
        ("b", Json::num(rec.attr_b as f64)),
    ];
    if let Some(name) = trace::model_name(rec.model) {
        args.push(("model", Json::str(name)));
    }
    Json::obj(vec![
        ("name", Json::str(rec.stage.as_str())),
        ("cat", Json::str("skydiver")),
        ("ph", Json::str("X")),
        ("ts", Json::num(rec.start_ns as f64 / 1_000.0)),
        (
            "dur",
            Json::num(
                rec.end_ns.saturating_sub(rec.start_ns) as f64 / 1_000.0,
            ),
        ),
        ("pid", Json::num(std::process::id() as f64)),
        ("tid", Json::num(tid as f64)),
        ("args", Json::obj(args)),
    ])
}

/// Dump every span belonging to a retained trace as Chrome
/// trace-event JSON: `{"traceEvents":[...]}` with complete (`"ph":
/// "X"`) events, `ts`/`dur` in microseconds since the process trace
/// epoch. `tid` is an arbitrary per-dump lane index used to keep
/// overlapping spans visible.
pub fn dump_chrome_json() -> String {
    let keep: Vec<[u8; 16]> =
        retained().iter().map(|m| m.trace_id).collect();
    let mut spans: Vec<SpanRecord> = trace::snapshot_all()
        .into_iter()
        .filter(|s| keep.iter().any(|k| *k == s.trace_id))
        .collect();
    spans.sort_by_key(|s| (s.trace_id, s.start_ns, s.span_id));
    spans.dedup_by_key(|s| (s.trace_id, s.span_id, s.stage as u8));

    // Lane assignment: spans that overlap in time get distinct tids
    // so chrome://tracing stacks rather than hides them.
    let mut lane_end: Vec<u64> = Vec::new();
    let mut events = Vec::with_capacity(spans.len());
    for s in &spans {
        let lane = match lane_end
            .iter()
            .position(|&end| end <= s.start_ns)
        {
            Some(i) => {
                lane_end[i] = s.end_ns;
                i
            }
            None => {
                lane_end.push(s.end_ns);
                lane_end.len() - 1
            }
        };
        events.push(span_event(s, lane));
    }
    Json::obj(vec![("traceEvents", Json::Arr(events))]).to_string()
}

/// Render a Chrome trace-event dump (ours or a compatible one) as an
/// indented per-trace span tree for terminal reading:
///
/// ```text
/// trace 4f2a… (model=classifier)
///   route 812.4us
///     attempt 801.9us [backend=0]
/// ```
pub fn render_tree(json: &str) -> Result<String> {
    struct Node {
        name: String,
        ts: f64,
        dur: f64,
        span: u64,
        parent: u64,
        model: Option<String>,
        error: bool,
        a: f64,
        b: f64,
    }

    let doc = Json::parse(json)?;
    let events = doc.field("traceEvents")?.as_arr()?;
    // trace hex -> nodes
    let mut traces: Vec<(String, Vec<Node>)> = Vec::new();
    for ev in events {
        if ev.get("ph").and_then(|p| p.as_str().ok()) != Some("X") {
            continue;
        }
        let args = ev.field("args")?;
        let trace = args.field("trace")?.as_str()?.to_string();
        let node = Node {
            name: ev.field("name")?.as_str()?.to_string(),
            ts: ev.field("ts")?.as_f64()?,
            dur: ev.field("dur")?.as_f64()?,
            span: args.field("span")?.as_f64()? as u64,
            parent: args.field("parent")?.as_f64()? as u64,
            model: args
                .get("model")
                .and_then(|m| m.as_str().ok())
                .map(str::to_string),
            error: args
                .get("error")
                .and_then(|e| e.as_bool().ok())
                .unwrap_or(false),
            a: args.get("a").and_then(|v| v.as_f64().ok()).unwrap_or(0.0),
            b: args.get("b").and_then(|v| v.as_f64().ok()).unwrap_or(0.0),
        };
        match traces.iter_mut().find(|(t, _)| *t == trace) {
            Some((_, v)) => v.push(node),
            None => traces.push((trace, vec![node])),
        }
    }
    if traces.is_empty() {
        bail!("no complete ('ph':'X') span events in dump");
    }

    fn emit(
        out: &mut String,
        nodes: &[Node],
        parent: u64,
        depth: usize,
    ) {
        use std::fmt::Write as _;
        let mut children: Vec<&Node> =
            nodes.iter().filter(|n| n.parent == parent).collect();
        children.sort_by(|x, y| {
            x.ts.partial_cmp(&y.ts).unwrap_or(std::cmp::Ordering::Equal)
        });
        for c in children {
            let _ = write!(
                out,
                "{:indent$}{} {:.1}us",
                "",
                c.name,
                c.dur,
                indent = 2 + depth * 2
            );
            if c.error {
                out.push_str(" ERROR");
            }
            if c.a != 0.0 || c.b != 0.0 {
                use std::fmt::Write as _;
                let _ = write!(out, " [a={} b={}]", c.a, c.b);
            }
            out.push('\n');
            emit(out, nodes, c.span, depth + 1);
        }
    }

    let mut out = String::new();
    for (trace, nodes) in &traces {
        use std::fmt::Write as _;
        let model = nodes
            .iter()
            .find_map(|n| n.model.as_deref())
            .unwrap_or("-");
        let _ = writeln!(out, "trace {trace} (model={model})");
        // Roots: parent id not present among this trace's spans
        // (covers parent=0 and cross-process parents).
        let mut roots: Vec<&Node> = nodes
            .iter()
            .filter(|n| !nodes.iter().any(|m| m.span == n.parent))
            .collect();
        roots.sort_by(|x, y| {
            x.ts.partial_cmp(&y.ts).unwrap_or(std::cmp::Ordering::Equal)
        });
        for r in roots {
            use std::fmt::Write as _;
            let _ = write!(
                out,
                "  {} {:.1}us",
                r.name, r.dur
            );
            if r.error {
                out.push_str(" ERROR");
            }
            out.push('\n');
            emit(&mut out, nodes, r.span, 1);
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::trace::{
        next_span_id, record, set_enabled, Stage, MODEL_NONE,
    };

    fn meta(id: u8, latency: u64, error: bool) -> TraceMeta {
        TraceMeta {
            trace_id: [id; 16],
            model: MODEL_NONE,
            latency_us: latency,
            error,
        }
    }

    #[test]
    fn slowest_reservoir_keeps_top_k_sorted() {
        for i in 0..(TOP_K as u64 + 40) {
            complete(meta((i % 200) as u8, i * 10, false));
        }
        let r = retained();
        // The slowest request ever seen must still be retained even
        // though the last-N window also covers it here.
        assert!(r.iter().any(|m| m.latency_us
            == (TOP_K as u64 + 39) * 10));
    }

    #[test]
    fn dump_and_tree_roundtrip() {
        set_enabled(true);
        let trace_id = crate::obs::trace::gen_trace_id();
        let root = next_span_id();
        let child = next_span_id();
        record(&SpanRecord {
            trace_id,
            span_id: root,
            parent_span: 0,
            start_ns: 1_000,
            end_ns: 9_000,
            stage: Stage::Route,
            model: MODEL_NONE,
            error: false,
            attr_a: 0,
            attr_b: 0,
        });
        record(&SpanRecord {
            trace_id,
            span_id: child,
            parent_span: root,
            start_ns: 2_000,
            end_ns: 8_000,
            stage: Stage::Attempt,
            model: MODEL_NONE,
            error: false,
            attr_a: 2,
            attr_b: 1,
        });
        set_enabled(false);
        complete(TraceMeta {
            trace_id,
            model: MODEL_NONE,
            latency_us: 8,
            error: false,
        });

        let json = dump_chrome_json();
        let doc = Json::parse(&json).unwrap();
        let events = doc.field("traceEvents").unwrap().as_arr().unwrap();
        let hex = crate::obs::trace::trace_id_hex(&trace_id);
        let ours: Vec<_> = events
            .iter()
            .filter(|e| {
                e.field("args")
                    .and_then(|a| a.field("trace"))
                    .and_then(|t| t.as_str().map(str::to_string))
                    .map(|t| t == hex)
                    .unwrap_or(false)
            })
            .collect();
        assert_eq!(ours.len(), 2);

        let tree = render_tree(&json).unwrap();
        assert!(tree.contains(&format!("trace {hex}")));
        // The attempt is indented under the route root.
        let route_line = tree
            .lines()
            .position(|l| l.trim_start().starts_with("route"))
            .unwrap();
        let attempt_line = tree
            .lines()
            .position(|l| l.trim_start().starts_with("attempt"))
            .unwrap();
        assert!(attempt_line > route_line);
        let indent = |s: &str| s.len() - s.trim_start().len();
        let lines: Vec<&str> = tree.lines().collect();
        assert!(
            indent(lines[attempt_line]) > indent(lines[route_line])
        );
    }

    #[test]
    fn tree_rejects_span_free_dump() {
        assert!(render_tree("{\"traceEvents\":[]}").is_err());
        assert!(render_tree("not json").is_err());
    }
}
