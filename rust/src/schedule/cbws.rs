//! CBWS — Channel-Balanced Workload Schedule (paper Algorithm 1).
//!
//! Given predicted per-channel workloads, produce `N` groups with nearly
//! equal sums:
//!
//! 1. sort workloads descending (list `C`);
//! 2. re-sort piecewise into `C_new`: every second block of `N` elements
//!    keeps descending order, the others are reversed — a zigzag that
//!    makes column sums of the `K/N x N` matrix nearly equal;
//! 3. split round-robin: element `N*i + j` joins sublist `L_j`;
//! 4. fine-tune for at most `T` iterations: move the smallest element of
//!    the heaviest sublist to the lightest sublist while it reduces the
//!    spread (`diff/2 > min(L_max)` in the paper's notation).

use super::{Partition, Scheduler};

/// Algorithm 1 with its fine-tune iteration cap `T` (paper line 18).
#[derive(Debug, Clone)]
pub struct Cbws {
    pub finetune_iters: usize,
}

impl Default for Cbws {
    fn default() -> Self {
        Self { finetune_iters: 64 }
    }
}

impl Scheduler for Cbws {
    fn name(&self) -> &'static str {
        "cbws"
    }

    fn assign(&self, predicted: &[f64], n: usize) -> Partition {
        cbws_assign(predicted, n, self.finetune_iters)
    }
}

/// The paper's Algorithm 1. Channels whose predicted workload ties are
/// ordered by index for determinism.
pub fn cbws_assign(predicted: &[f64], n: usize, finetune_iters: usize)
                   -> Partition {
    let k = predicted.len();
    if n == 0 {
        // Zero groups requested -> zero groups returned; silently
        // handing back one group would hide a misconfigured arch.
        return Partition { groups: Vec::new() };
    }
    if k == 0 {
        return Partition { groups: vec![Vec::new(); n] };
    }
    // Line 1-2: list of (channel, workload) sorted descending.
    let mut c: Vec<usize> = (0..k).collect();
    c.sort_by(|&a, &b| predicted[b].partial_cmp(&predicted[a])
        .unwrap_or(std::cmp::Ordering::Equal)
        .then(a.cmp(&b)));

    // Line 3-10: piecewise zigzag re-sort in blocks of N.
    let mut c_new: Vec<usize> = Vec::with_capacity(k);
    let mut i = 0;
    let mut block = 0usize;
    while i < k {
        let end = (i + n).min(k);
        if block % 2 == 1 {
            // paper: `if mod(i,2)` -> append as-is (already descending
            // from the global sort ... the reversed blocks are the even
            // ones after the first; net effect: alternate directions).
            c_new.extend_from_slice(&c[i..end]);
        } else {
            c_new.extend(c[i..end].iter().rev());
        }
        i = end;
        block += 1;
    }

    // Line 11-16: round-robin split into N sublists.
    let mut groups: Vec<Vec<usize>> = vec![Vec::new(); n];
    for (pos, &ch) in c_new.iter().enumerate() {
        groups[pos % n].push(ch);
    }

    // Line 17-28: greedy fine-tune.
    let mut sums: Vec<f64> = groups.iter()
        .map(|g| g.iter().map(|&ch| predicted[ch]).sum())
        .collect();
    for _ in 0..finetune_iters {
        let (max_i, max_s) = argmax(&sums);
        let (min_i, min_s) = argmin(&sums);
        let diff = max_s - min_s;
        // Smallest element of the heaviest sublist.
        let Some((pos, &ch)) = groups[max_i].iter().enumerate()
            .min_by(|(_, &a), (_, &b)| predicted[a]
                .partial_cmp(&predicted[b])
                .unwrap_or(std::cmp::Ordering::Equal))
        else { break };
        let v = predicted[ch];
        // Paper line 22: move only while it shrinks the spread.
        if diff / 2.0 > v && groups[max_i].len() > 1 {
            groups[max_i].swap_remove(pos);
            groups[min_i].push(ch);
            sums[max_i] -= v;
            sums[min_i] += v;
        } else {
            break; // BreakTimeLoop()
        }
    }
    Partition { groups }
}

fn argmax(v: &[f64]) -> (usize, f64) {
    v.iter().enumerate()
        .fold((0, f64::NEG_INFINITY),
              |acc, (i, &x)| if x > acc.1 { (i, x) } else { acc })
}

fn argmin(v: &[f64]) -> (usize, f64) {
    v.iter().enumerate()
        .fold((0, f64::INFINITY),
              |acc, (i, &x)| if x < acc.1 { (i, x) } else { acc })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn covers_all_channels() {
        let w: Vec<f64> = (0..16).map(|i| (i * 7 % 13) as f64).collect();
        let p = cbws_assign(&w, 4, 64);
        assert!(p.validate(16));
    }

    #[test]
    fn balances_geometric_workloads() {
        // Orders-of-magnitude imbalance, like Fig. 2(b).
        let w: Vec<f64> = (0..16).map(|i| 2f64.powi(i as i32 / 2)).collect();
        let p = cbws_assign(&w, 4, 64);
        let ratio = p.balance_ratio(&w);
        assert!(ratio > 0.80, "cbws ratio {ratio}");
        // Strictly better than contiguous blocks.
        let contiguous = Partition {
            groups: (0..4).map(|g| (g * 4..(g + 1) * 4).collect()).collect(),
        };
        assert!(ratio > contiguous.balance_ratio(&w));
    }

    #[test]
    fn perfect_when_uniform() {
        let w = vec![3.0; 12];
        let p = cbws_assign(&w, 4, 64);
        assert!((p.balance_ratio(&w) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn k_not_multiple_of_n() {
        let w: Vec<f64> = (0..13).map(|i| (i + 1) as f64).collect();
        let p = cbws_assign(&w, 4, 64);
        assert!(p.validate(13));
        assert!(p.balance_ratio(&w) > 0.7);
    }

    #[test]
    fn zero_groups_requested_returns_zero_groups() {
        let p = cbws_assign(&[1.0, 2.0, 3.0], 0, 64);
        assert!(p.groups.is_empty(), "asked for 0 groups, got {:?}",
                p.groups);
        assert!(p.balance_ratio(&[1.0, 2.0, 3.0]).is_finite());
    }

    #[test]
    fn zero_channels_returns_n_empty_groups() {
        let p = cbws_assign(&[], 3, 64);
        assert_eq!(p.groups.len(), 3);
        assert!(p.validate(0));
        assert_eq!(p.balance_ratio(&[]), 1.0);
    }

    #[test]
    fn n_greater_than_k() {
        let w = vec![1.0, 2.0];
        let p = cbws_assign(&w, 8, 64);
        assert!(p.validate(2));
        assert_eq!(p.groups.len(), 8);
    }

    #[test]
    fn finetune_improves_or_keeps() {
        let w: Vec<f64> = (0..32)
            .map(|i| ((i * 2654435761u64 % 97) as f64).powf(1.5))
            .collect();
        let no_ft = cbws_assign(&w, 8, 0).balance_ratio(&w);
        let ft = cbws_assign(&w, 8, 64).balance_ratio(&w);
        assert!(ft >= no_ft - 1e-12, "finetune regressed: {ft} < {no_ft}");
    }

    #[test]
    fn deterministic() {
        let w: Vec<f64> = (0..24).map(|i| (i % 5) as f64).collect();
        assert_eq!(cbws_assign(&w, 6, 64), cbws_assign(&w, 6, 64));
    }
}
