//! Baseline schedulers the paper compares against (explicitly or
//! implicitly): the no-schedule default, classic static policies, the
//! SparTen-style density grouping [16], and the oracle upper bound.

use super::{Partition, Scheduler};
use crate::data::SplitMix64;

/// Contiguous blocks — what a scheduler-less accelerator does (channels
/// 0..K/N to SPE 0, etc). The paper's "without CBWS" configuration.
pub struct Contiguous;

impl Scheduler for Contiguous {
    fn name(&self) -> &'static str {
        "contiguous"
    }

    fn assign(&self, predicted: &[f64], n: usize) -> Partition {
        let k = predicted.len();
        let per = (k + n - 1) / n.max(1);
        let groups = (0..n)
            .map(|g| (g * per..((g + 1) * per).min(k)).collect())
            .collect();
        Partition { groups }
    }
}

/// Round-robin interleave: channel c -> SPE c % N. Ignores workloads but
/// spreads spatially-correlated channels.
pub struct RoundRobin;

impl Scheduler for RoundRobin {
    fn name(&self) -> &'static str {
        "round_robin"
    }

    fn assign(&self, predicted: &[f64], n: usize) -> Partition {
        let mut groups = vec![Vec::new(); n];
        for c in 0..predicted.len() {
            groups[c % n].push(c);
        }
        Partition { groups }
    }
}

/// Uniform random assignment (seeded).
pub struct Random {
    pub seed: u64,
}

impl Scheduler for Random {
    fn name(&self) -> &'static str {
        "random"
    }

    fn assign(&self, predicted: &[f64], n: usize) -> Partition {
        let mut rng = SplitMix64::new(self.seed);
        let mut groups = vec![Vec::new(); n];
        for c in 0..predicted.len() {
            groups[rng.next_below(n as u64) as usize].push(c);
        }
        Partition { groups }
    }
}

/// SparTen-style density grouping [16]: sort channels by predicted
/// density and deal them in descending snake order. SparTen groups
/// *filters* by weight density; applied to our channel-partition problem
/// it becomes snake-order dealing — better than contiguous, but it has no
/// fine-tune step and no APRC-quality prediction of *dynamic* sparsity,
/// which is the gap the paper calls out in §IV.
pub struct SparTen;

impl Scheduler for SparTen {
    fn name(&self) -> &'static str {
        "sparten"
    }

    fn assign(&self, predicted: &[f64], n: usize) -> Partition {
        let k = predicted.len();
        let mut order: Vec<usize> = (0..k).collect();
        order.sort_by(|&a, &b| predicted[b].partial_cmp(&predicted[a])
            .unwrap_or(std::cmp::Ordering::Equal)
            .then(a.cmp(&b)));
        let mut groups = vec![Vec::new(); n];
        for (pos, &c) in order.iter().enumerate() {
            let round = pos / n;
            let j = pos % n;
            let g = if round % 2 == 0 { j } else { n - 1 - j };
            groups[g].push(c);
        }
        Partition { groups }
    }
}

/// Oracle: greedy longest-processing-time assignment using the *actual*
/// workloads of the timestep being scheduled — unrealisable in hardware
/// (the workload is only known after the fact), but it upper-bounds every
/// online policy.
pub struct Oracle;

impl Scheduler for Oracle {
    fn name(&self) -> &'static str {
        "oracle"
    }

    fn assign(&self, actual: &[f64], n: usize) -> Partition {
        let k = actual.len();
        let mut order: Vec<usize> = (0..k).collect();
        order.sort_by(|&a, &b| actual[b].partial_cmp(&actual[a])
            .unwrap_or(std::cmp::Ordering::Equal)
            .then(a.cmp(&b)));
        let mut groups = vec![Vec::new(); n];
        let mut sums = vec![0.0f64; n];
        for &c in &order {
            let (gi, _) = sums.iter().enumerate()
                .min_by(|(_, a), (_, b)| a.partial_cmp(b).unwrap())
                .unwrap();
            groups[gi].push(c);
            sums[gi] += actual[c];
        }
        Partition { groups }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn workload() -> Vec<f64> {
        (0..16).map(|i| ((i * 37 % 11) + 1) as f64).collect()
    }

    #[test]
    fn all_cover() {
        let w = workload();
        for s in [&Contiguous as &dyn Scheduler, &RoundRobin,
                  &Random { seed: 1 }, &SparTen, &Oracle] {
            let p = s.assign(&w, 4);
            assert!(p.validate(16), "{} does not cover", s.name());
        }
    }

    #[test]
    fn oracle_beats_contiguous() {
        // Strongly skewed workload.
        let w: Vec<f64> = (0..16).map(|i| if i < 4 { 100.0 } else { 1.0 })
            .collect();
        let o = Oracle.assign(&w, 4).balance_ratio(&w);
        let c = Contiguous.assign(&w, 4).balance_ratio(&w);
        assert!(o > c, "oracle {o} <= contiguous {c}");
    }

    #[test]
    fn oracle_is_upper_bound_for_zoo() {
        let w = workload();
        let o = Oracle.assign(&w, 4).balance_ratio(&w);
        for s in super::super::all_schedulers() {
            let r = s.assign(&w, 4).balance_ratio(&w);
            assert!(o >= r - 1e-9, "{} beats oracle: {r} > {o}", s.name());
        }
    }

    #[test]
    fn sparten_snake_order() {
        let w = vec![4.0, 3.0, 2.0, 1.0];
        let p = SparTen.assign(&w, 2);
        // Descending snake: g0 gets {4.0, 1.0}, g1 gets {3.0, 2.0}.
        let totals = p.group_totals(&w);
        assert_eq!(totals, vec![5.0, 5.0]);
    }

    #[test]
    fn random_is_seed_deterministic() {
        let w = workload();
        let a = Random { seed: 9 }.assign(&w, 4);
        let b = Random { seed: 9 }.assign(&w, 4);
        assert_eq!(a, b);
    }
}
