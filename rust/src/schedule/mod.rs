//! Workload scheduling — the paper's algorithmic contribution.
//!
//! Layer `l`'s event-driven work is dominated by the number of input
//! spikes per *input channel*; each channel-based SPE of a cluster owns a
//! subset of input channels (Fig. 5), so the slowest SPE bounds the
//! layer's latency. A schedule is therefore a partition of the `K` input
//! channels into `N` groups.
//!
//! * [`aprc`] predicts relative channel workloads offline: with the
//!   APRC-modified convolution, the spikerate of the producing layer's
//!   output channel is approximately proportional to its filter magnitude
//!   (Eq. 5), which is known at compile time.
//! * [`cbws`] is Algorithm 1: zigzag-sort the predicted workloads, split
//!   round-robin into `N` sublists, then greedily fine-tune.
//! * [`baselines`] are the comparison points: contiguous (the no-schedule
//!   default), round-robin, random, a SparTen-style density grouping
//!   [16], and the oracle that sees the true future workloads.
//!
//! Balance ratio (from Spartus [15]): `total / (N * max_group_total)` for
//! one (layer, timestep); 1.0 = perfectly balanced.

pub mod aprc;
pub mod baselines;
pub mod cbws;

pub use aprc::AprcPredictor;

/// A partition of channels `0..k` into `n` groups.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Partition {
    pub groups: Vec<Vec<usize>>,
}

impl Partition {
    /// Validates the partition covers 0..k exactly once.
    pub fn validate(&self, k: usize) -> bool {
        let mut seen = vec![false; k];
        for g in &self.groups {
            for &c in g {
                if c >= k || seen[c] {
                    return false;
                }
                seen[c] = true;
            }
        }
        seen.iter().all(|&s| s)
    }

    /// Channel -> group index lookup table.
    pub fn channel_to_group(&self, k: usize) -> Vec<usize> {
        let mut map = vec![usize::MAX; k];
        for (gi, g) in self.groups.iter().enumerate() {
            for &c in g {
                map[c] = gi;
            }
        }
        map
    }

    /// Per-group totals of `workload`.
    pub fn group_totals(&self, workload: &[f64]) -> Vec<f64> {
        self.groups.iter()
            .map(|g| g.iter().map(|&c| workload[c]).sum())
            .collect()
    }

    /// Balance ratio of this partition under the *actual* workloads:
    /// `total / (n * max_group)`. 1.0 iff perfectly balanced; the paper
    /// reports >90% with APRC+CBWS (Fig. 7).
    pub fn balance_ratio(&self, workload: &[f64]) -> f64 {
        // A partition with zero groups is vacuously balanced (guards
        // the `total / (0 * max)` NaN).
        if self.groups.is_empty() {
            return 1.0;
        }
        let totals = self.group_totals(workload);
        let total: f64 = totals.iter().sum();
        let max = totals.iter().cloned().fold(0.0f64, f64::max);
        if !(max > 0.0) {
            return 1.0;
        }
        total / (self.groups.len() as f64 * max)
    }
}

/// A channel-to-SPE scheduling policy.
pub trait Scheduler: Send + Sync {
    /// Human-readable name for reports.
    fn name(&self) -> &'static str;

    /// Partition `predicted.len()` channels into `n` groups given the
    /// per-channel *predicted* workloads.
    fn assign(&self, predicted: &[f64], n: usize) -> Partition;
}

/// All schedulers in the zoo, for sweep experiments.
pub fn all_schedulers() -> Vec<Box<dyn Scheduler>> {
    vec![
        Box::new(baselines::Contiguous),
        Box::new(baselines::RoundRobin),
        Box::new(baselines::Random { seed: 0x5EED }),
        Box::new(baselines::SparTen),
        Box::new(cbws::Cbws::default()),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn partition_validate_rejects_duplicates() {
        let p = Partition { groups: vec![vec![0, 1], vec![1, 2]] };
        assert!(!p.validate(3));
    }

    #[test]
    fn partition_validate_rejects_missing() {
        let p = Partition { groups: vec![vec![0], vec![2]] };
        assert!(!p.validate(3));
    }

    #[test]
    fn balance_ratio_perfect() {
        let p = Partition { groups: vec![vec![0], vec![1]] };
        assert!((p.balance_ratio(&[5.0, 5.0]) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn balance_ratio_worst_case() {
        // All work in one of two groups: ratio = total/(2*max) = 0.5.
        let p = Partition { groups: vec![vec![0, 1], vec![]] };
        assert!((p.balance_ratio(&[3.0, 7.0]) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn zero_workload_is_balanced() {
        let p = Partition { groups: vec![vec![0], vec![1]] };
        assert_eq!(p.balance_ratio(&[0.0, 0.0]), 1.0);
    }

    #[test]
    fn empty_partition_balance_ratio_is_finite() {
        let p = Partition { groups: Vec::new() };
        let r = p.balance_ratio(&[1.0, 2.0]);
        assert!(r.is_finite(), "zero-group partition gave {r}");
        assert_eq!(r, 1.0);
    }

    #[test]
    fn nan_workload_does_not_poison_ratio() {
        let p = Partition { groups: vec![vec![0], vec![1]] };
        assert!(p.balance_ratio(&[f64::NAN, f64::NAN]).is_finite());
    }
}
