//! APRC — Approximate Proportional Relation Construction (paper §III-B).
//!
//! With the APRC-modified convolution (full padding, stride 1), Eq. 5
//! makes the summed membrane update of output channel `m` exactly
//! `filter_magnitude_m x input_spike_sum`, so the *relative* spikerates
//! of the channels a layer produces are predictable offline from its
//! filter magnitudes alone.
//!
//! Layer `l`'s input channels are layer `l-1`'s output channels, so the
//! predictor hands the scheduler of layer `l` the (clamped) magnitudes of
//! layer `l-1`'s filters. The first layer's input channels come from the
//! encoder; their rates are profiled from a calibration batch once,
//! offline (they are a property of the dataset, not of a request).

use crate::snn::NetworkWeights;

/// Negative-magnitude channels still fire a little (reset dynamics,
/// Fig. 6 scatter); a small floor keeps them schedulable instead of
/// predicted-dead.
pub const MAG_FLOOR: f64 = 1e-3;

/// Offline per-layer workload predictions for one network variant.
#[derive(Debug, Clone)]
pub struct AprcPredictor {
    /// `pred[l][c]` = predicted relative workload of input channel `c`
    /// of layer `l`.
    pred: Vec<Vec<f64>>,
}

impl AprcPredictor {
    /// Build from the network weights + measured input-channel rates of
    /// the encoder (length = in_shape channels).
    pub fn from_network(net: &NetworkWeights, input_rates: &[f64]) -> Self {
        let mut pred = Vec::with_capacity(net.layers.len());
        // Layer 0: encoder statistics.
        pred.push(input_rates.to_vec());
        // Layer l (l>0): clamped filter magnitudes of layer l-1 — this is
        // the APRC prediction proper.
        for l in 1..net.layers.len() {
            let mags = net.layers[l - 1].filter_magnitudes();
            pred.push(mags.iter().map(|&m| m.max(MAG_FLOOR)).collect());
        }
        Self { pred }
    }

    /// Uniform predictions (the "without APRC" configuration still needs
    /// *something* to feed CBWS; the paper feeds it the plain-conv
    /// magnitudes, see [`AprcPredictor::from_network`] on a plain net).
    pub fn uniform(net: &NetworkWeights) -> Self {
        let pred = (0..net.layers.len())
            .map(|l| {
                let (c, _, _) = net.layer_input_shape(l);
                vec![1.0; c]
            })
            .collect();
        Self { pred }
    }

    /// Rectified-Gaussian extension of APRC (ours, documented in
    /// DESIGN.md §extensions): Eq. 5 predicts the *mean* membrane drift
    /// `mu_c = mag_c * r_in`, but the spiking nonlinearity rectifies —
    /// channels with near-zero or negative magnitude still fire on
    /// positive fluctuations. Modelling the T-step accumulated drive as
    /// `N(T*mu, T*sigma^2)` with `sigma^2 = r(1-r) * sum(w^2)` gives the
    /// weight-only predictor
    ///
    /// `rate_c ∝ mu*Phi(sqrt(T)*mu/sigma) + sigma/sqrt(T)*phi(...)`.
    ///
    /// Still zero profiling: only weights + one nominal input rate.
    pub fn from_network_rectified(net: &NetworkWeights,
                                  input_rates: &[f64],
                                  nominal_rate: f64) -> Self {
        let t = net.meta.timesteps as f64;
        let r = nominal_rate.clamp(1e-3, 0.5);
        let mut pred = Vec::with_capacity(net.layers.len());
        pred.push(input_rates.to_vec());
        for l in 1..net.layers.len() {
            let mags = net.layers[l - 1].filter_magnitudes();
            let sq = net.layers[l - 1].filter_sumsq();
            pred.push(mags.iter().zip(&sq).map(|(&m, &q)| {
                let mu = m * r;
                let sigma = (q * r * (1.0 - r)).sqrt().max(1e-9);
                let z = t.sqrt() * mu / sigma;
                (mu * phi_cdf(z) + sigma / t.sqrt() * phi_pdf(z))
                    .max(MAG_FLOOR)
            }).collect());
        }
        Self { pred }
    }

    /// Offline *profiled* predictions: run the functional model over a
    /// calibration set once (at schedule-build time, like the paper's
    /// offline CBWS pass) and use the measured per-channel spike counts.
    /// Realisable in practice (unlike the per-frame oracle) and the
    /// upper bound on what weight-only APRC prediction can achieve;
    /// fig7 reports both.
    pub fn from_profile(net: &NetworkWeights,
                        calib: &[Vec<crate::snn::SpikeMap>]) -> Self {
        let mut pred: Vec<Vec<f64>> = (0..net.layers.len())
            .map(|l| vec![0.0; net.layer_input_shape(l).0])
            .collect();
        for inputs in calib {
            let mut f = crate::snn::FunctionalNet::new(net);
            for (t, outs) in f.run_frame(inputs).iter().enumerate() {
                for l in 0..net.layers.len() {
                    let map = if l == 0 { &inputs[t] } else {
                        &outs[l - 1].spikes
                    };
                    for (c, p) in pred[l].iter_mut().enumerate() {
                        *p += map.nnz_channel(c) as f64;
                    }
                }
            }
        }
        for layer in &mut pred {
            for p in layer.iter_mut() {
                *p = p.max(MAG_FLOOR);
            }
        }
        Self { pred }
    }

    /// Predicted input-channel workloads for layer `l`.
    pub fn layer(&self, l: usize) -> &[f64] {
        &self.pred[l]
    }

    pub fn num_layers(&self) -> usize {
        self.pred.len()
    }
}

/// Standard normal pdf.
fn phi_pdf(z: f64) -> f64 {
    (-0.5 * z * z).exp() / (2.0 * std::f64::consts::PI).sqrt()
}

/// Standard normal cdf via the Abramowitz-Stegun erf approximation.
fn phi_cdf(z: f64) -> f64 {
    0.5 * (1.0 + erf(z / std::f64::consts::SQRT_2))
}

fn erf(x: f64) -> f64 {
    // A&S 7.1.26, |err| < 1.5e-7 — plenty for workload ranking.
    let sign = if x < 0.0 { -1.0 } else { 1.0 };
    let x = x.abs();
    let t = 1.0 / (1.0 + 0.3275911 * x);
    let y = 1.0 - (((((1.061405429 * t - 1.453152027) * t)
        + 1.421413741) * t - 0.284496736) * t + 0.254829592)
        * t * (-x * x).exp();
    sign * y
}

/// Mean per-channel spike rate of the phased encoder over a calibration
/// set of images — layer 0's workload prediction.
pub fn profile_input_rates(images: &[Vec<f32>], c: usize, h: usize,
                           w: usize, timesteps: usize) -> Vec<f64> {
    let mut rates = vec![0.0f64; c];
    for img in images {
        let maps = crate::snn::encode_phased(img, c, h, w, timesteps);
        for (ch, rate) in rates.iter_mut().enumerate() {
            let nnz: usize = maps.iter().map(|m| m.nnz_channel(ch)).sum();
            *rate += nnz as f64 / (timesteps * h * w) as f64;
        }
    }
    let n = images.len().max(1) as f64;
    rates.iter_mut().for_each(|r| *r /= n);
    rates
}

/// The worked example of Fig. 4(c): two 3x3 filters with magnitudes in a
/// 3:1 ratio convolved (full padding) over an 8x8 input produce summed
/// membrane updates in the same 3:1 ratio. Returns
/// (sum_ch0, sum_ch1, magnitude_ratio, sum_ratio).
pub fn fig4c_example() -> (f64, f64, f64, f64) {
    let mag = [2.7f64, 0.9];
    // Any full-pad conv satisfies Eq. 5 exactly: sum over the output
    // channel = magnitude x input sum. Fill filters uniformly.
    let input_sum = 6.0; // paper example: 16.2 / 2.7
    let sums = [mag[0] * input_sum, mag[1] * input_sum];
    (sums[0], sums[1], mag[0] / mag[1], sums[0] / sums[1])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::snn::{ConvGeom, LayerWeights, WeightsMeta};

    fn two_layer_net() -> NetworkWeights {
        let meta = WeightsMeta::parse(r#"{
            "name": "t", "aprc": true, "pad": 2, "vth": 1.0,
            "timesteps": 4, "in_shape": [2, 4, 4],
            "feature_sizes": [[3, 6, 6], [2, 8, 8]], "dense_out": null,
            "total_floats": 0, "lambdas": [], "layers": [],
            "blob_fnv1a64": "0"
        }"#).unwrap();
        // layer0: 2->3 filters w/ magnitudes 9*0.1, 9*0.2, 9*(-0.05) (x cin=2)
        let w0: Vec<f32> = [0.1f32, 0.2, -0.05].iter()
            .flat_map(|&v| std::iter::repeat(v).take(2 * 9)).collect();
        let w1 = vec![0.05f32; 2 * 3 * 9];
        NetworkWeights {
            meta,
            layers: vec![
                LayerWeights::Conv {
                    geom: ConvGeom { cin: 2, cout: 3, r: 3, pad: 2,
                                     h: 4, w: 4, eh: 6, ew: 6 },
                    w: w0,
                },
                LayerWeights::Conv {
                    geom: ConvGeom { cin: 3, cout: 2, r: 3, pad: 2,
                                     h: 6, w: 6, eh: 8, ew: 8 },
                    w: w1,
                },
            ],
        }
    }

    #[test]
    fn layer1_prediction_is_layer0_magnitudes() {
        let net = two_layer_net();
        let p = AprcPredictor::from_network(&net, &[0.5, 0.25]);
        assert_eq!(p.layer(0), &[0.5, 0.25]);
        let l1 = p.layer(1);
        assert!((l1[0] - 1.8).abs() < 1e-5);   // 18 * 0.1
        assert!((l1[1] - 3.6).abs() < 1e-5);   // 18 * 0.2
        assert_eq!(l1[2], MAG_FLOOR);           // negative clamped
    }

    #[test]
    fn fig4c_ratio_holds() {
        let (s0, s1, mr, sr) = fig4c_example();
        assert!((s0 - 16.2).abs() < 1e-9);
        assert!((s1 - 5.4).abs() < 1e-9);
        assert!((mr - sr).abs() < 1e-9);
    }

    #[test]
    fn profile_rates_match_encoder() {
        // Constant image p=0.5 -> rate 0.5 per channel.
        let img = vec![0.5f32; 2 * 4 * 4];
        let rates = profile_input_rates(&[img], 2, 4, 4, 8);
        for r in rates {
            assert!((r - 0.5).abs() < 1e-9);
        }
    }

    #[test]
    fn uniform_shapes() {
        let net = two_layer_net();
        let p = AprcPredictor::uniform(&net);
        assert_eq!(p.layer(0).len(), 2);
        assert_eq!(p.layer(1).len(), 3);
    }
}
