//! Bit-packed binary spike maps.
//!
//! A `SpikeMap` is the unit the spike scheduler scans: one timestep of one
//! layer's (C, H, W) binary activity, packed 64 neurons per word. Packing
//! matters twice: it is the paper's neuron-state-memory layout (the
//! scheduler detects firing neurons by scanning words, §III-A) and it is
//! the simulator hot path (popcount per word instead of per-neuron
//! branches — see DESIGN.md §8).

/// Bit-packed (C, H, W) binary spike map; channel-major, rows packed
/// per-channel so per-channel popcounts never straddle channels.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct SpikeMap {
    pub c: usize,
    pub h: usize,
    pub w: usize,
    /// words_per_channel = ceil(h*w / 64)
    wpc: usize,
    words: Vec<u64>,
}

impl SpikeMap {
    pub fn zeros(c: usize, h: usize, w: usize) -> Self {
        let wpc = (h * w + 63) / 64;
        Self { c, h, w, wpc, words: vec![0; c * wpc] }
    }

    /// Words per channel (packing stride).
    pub fn words_per_channel(&self) -> usize {
        self.wpc
    }

    /// Assemble from pre-packed words (len must be `c * wpc`); used by
    /// the parallel functional model which packs per-channel chunks on
    /// worker threads.
    pub fn from_words(c: usize, h: usize, w: usize, words: Vec<u64>)
                      -> Self {
        let wpc = (h * w + 63) / 64;
        assert_eq!(words.len(), c * wpc);
        Self { c, h, w, wpc, words }
    }

    /// Build from a dense f32 slice (C*H*W, values 0.0/1.0) — the format
    /// the PJRT runtime returns.
    pub fn from_f32(c: usize, h: usize, w: usize, data: &[f32]) -> Self {
        assert_eq!(data.len(), c * h * w);
        let mut m = Self::zeros(c, h, w);
        let per = h * w;
        for ch in 0..c {
            for i in 0..per {
                if data[ch * per + i] >= 0.5 {
                    m.set(ch, i);
                }
            }
        }
        m
    }

    #[inline]
    pub fn set(&mut self, ch: usize, idx: usize) {
        self.words[ch * self.wpc + idx / 64] |= 1u64 << (idx % 64);
    }

    #[inline]
    pub fn get(&self, ch: usize, idx: usize) -> bool {
        (self.words[ch * self.wpc + idx / 64] >> (idx % 64)) & 1 == 1
    }

    /// Words of one channel (the scheduler's scan granularity).
    #[inline]
    pub fn channel_words(&self, ch: usize) -> &[u64] {
        &self.words[ch * self.wpc..(ch + 1) * self.wpc]
    }

    /// Number of spikes in channel `ch` (one popcount per word).
    #[inline]
    pub fn nnz_channel(&self, ch: usize) -> usize {
        self.channel_words(ch).iter()
            .map(|w| w.count_ones() as usize).sum()
    }

    /// Total spikes in the map.
    pub fn nnz(&self) -> usize {
        self.words.iter().map(|w| w.count_ones() as usize).sum()
    }

    /// Per-channel spike counts.
    pub fn nnz_per_channel(&self) -> Vec<usize> {
        (0..self.c).map(|ch| self.nnz_channel(ch)).collect()
    }

    /// Iterate (channel, linear index) of set bits — the event stream the
    /// spike scheduler emits.
    pub fn iter_events(&self) -> impl Iterator<Item = (usize, usize)> + '_ {
        (0..self.c).flat_map(move |ch| {
            self.channel_words(ch).iter().enumerate()
                .flat_map(move |(wi, &word)| {
                    let mut rem = word;
                    std::iter::from_fn(move || {
                        if rem == 0 {
                            return None;
                        }
                        let b = rem.trailing_zeros() as usize;
                        rem &= rem - 1;
                        Some((ch, wi * 64 + b))
                    })
                })
                .filter(move |&(_, idx)| idx < self.h * self.w)
        })
    }

    /// Dense f32 view (for feeding the runtime).
    pub fn to_f32(&self) -> Vec<f32> {
        let per = self.h * self.w;
        let mut out = vec![0.0f32; self.c * per];
        for (ch, idx) in self.iter_events() {
            out[ch * per + idx] = 1.0;
        }
        out
    }

    /// Total number of neurons.
    pub fn len(&self) -> usize {
        self.c * self.h * self.w
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Spike rate over the whole map.
    pub fn rate(&self) -> f64 {
        self.nnz() as f64 / self.len() as f64
    }

    /// Memory words the spike scheduler must scan for this map.
    pub fn scan_words(&self) -> usize {
        self.words.len()
    }

    /// Spike counts per interleaved row-group: counts[g] = spikes in rows
    /// `r` with `r % n == g`, summed over channels. This is the
    /// row-interleaved work split the SPE streams use when a layer has
    /// fewer input channels than SPEs (see sim::timing).
    pub fn nnz_row_interleaved(&self, n: usize) -> Vec<u64> {
        let mut counts = vec![0u64; n];
        for (_, idx) in self.iter_events() {
            let row = idx / self.w;
            counts[row % n] += 1;
        }
        counts
    }

    /// Spike counts per interleaved *neuron* group: counts[g] = spikes at
    /// linear index `ch*h*w + idx` with `index % n == g`. The dense
    /// layer's SPE split: weight rows are per input neuron, so neurons
    /// interleave freely across SPEs.
    pub fn nnz_index_interleaved(&self, n: usize) -> Vec<u64> {
        let per = self.h * self.w;
        let mut counts = vec![0u64; n];
        for (ch, idx) in self.iter_events() {
            counts[(ch * per + idx) % n] += 1;
        }
        counts
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_f32() {
        let mut data = vec![0.0f32; 3 * 5 * 7];
        data[0] = 1.0;
        data[36] = 1.0;
        data[104] = 1.0;
        let m = SpikeMap::from_f32(3, 5, 7, &data);
        assert_eq!(m.nnz(), 3);
        assert_eq!(m.to_f32(), data);
    }

    #[test]
    fn per_channel_counts() {
        let mut m = SpikeMap::zeros(2, 8, 8);
        for i in 0..10 {
            m.set(0, i * 3);
        }
        m.set(1, 63);
        m.set(1, 64 - 1); // same bit, idempotent
        assert_eq!(m.nnz_channel(0), 10);
        assert_eq!(m.nnz_channel(1), 1);
        assert_eq!(m.nnz(), 11);
    }

    #[test]
    fn events_match_bits() {
        let mut m = SpikeMap::zeros(4, 9, 9);
        let idxs = [(0, 0), (0, 80), (2, 13), (3, 64), (3, 65)];
        for &(c, i) in &idxs {
            m.set(c, i);
        }
        let got: Vec<_> = m.iter_events().collect();
        assert_eq!(got, idxs.to_vec());
    }

    #[test]
    fn word_boundary_straddle_excluded() {
        // h*w = 65 means bit 65..127 of the 2nd word must never report.
        let mut m = SpikeMap::zeros(1, 5, 13);
        m.set(0, 64);
        assert_eq!(m.nnz_channel(0), 1);
        assert_eq!(m.iter_events().count(), 1);
    }
}
