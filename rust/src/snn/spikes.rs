//! Bit-packed binary spike maps.
//!
//! A `SpikeMap` is the unit the spike scheduler scans: one timestep of one
//! layer's (C, H, W) binary activity, packed 64 neurons per word. Packing
//! matters twice: it is the paper's neuron-state-memory layout (the
//! scheduler detects firing neurons by scanning words, §III-A) and it is
//! the simulator hot path (popcount per word instead of per-neuron
//! branches — see PERF.md).

/// Bit-packed (C, H, W) binary spike map; channel-major, rows packed
/// per-channel so per-channel popcounts never straddle channels.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct SpikeMap {
    pub c: usize,
    pub h: usize,
    pub w: usize,
    /// words_per_channel = ceil(h*w / 64)
    wpc: usize,
    words: Vec<u64>,
}

impl SpikeMap {
    pub fn zeros(c: usize, h: usize, w: usize) -> Self {
        let wpc = (h * w).div_ceil(64);
        Self { c, h, w, wpc, words: vec![0; c * wpc] }
    }

    /// Words per channel (packing stride).
    pub fn words_per_channel(&self) -> usize {
        self.wpc
    }

    /// Assemble from pre-packed words (len must be `c * wpc`). The
    /// functional model packs in place via [`Self::words_mut`] instead;
    /// this constructor remains for callers that build words externally.
    pub fn from_words(c: usize, h: usize, w: usize, words: Vec<u64>)
                      -> Self {
        let wpc = (h * w).div_ceil(64);
        assert_eq!(words.len(), c * wpc);
        Self { c, h, w, wpc, words }
    }

    /// Build from a dense f32 slice (C*H*W, values 0.0/1.0) — the format
    /// the PJRT runtime returns. Packs 64 neurons per word directly (no
    /// per-bit `set`): this runs once per layer per timestep on the PJRT
    /// boundary in `SnnRunner::step`, so it is hot (see PERF.md).
    pub fn from_f32(c: usize, h: usize, w: usize, data: &[f32]) -> Self {
        assert_eq!(data.len(), c * h * w);
        let per = h * w;
        let wpc = per.div_ceil(64);
        let mut words = vec![0u64; c * wpc];
        for ch in 0..c {
            let src = &data[ch * per..(ch + 1) * per];
            let dst = &mut words[ch * wpc..(ch + 1) * wpc];
            for (wi, chunk) in src.chunks(64).enumerate() {
                let mut word = 0u64;
                for (b, &v) in chunk.iter().enumerate() {
                    word |= ((v >= 0.5) as u64) << b;
                }
                dst[wi] = word;
            }
        }
        Self { c, h, w, wpc, words }
    }

    #[inline]
    pub fn set(&mut self, ch: usize, idx: usize) {
        self.words[ch * self.wpc + idx / 64] |= 1u64 << (idx % 64);
    }

    /// Zero every bit, keeping the allocation (scratch-reuse stepping).
    pub fn clear(&mut self) {
        self.words.iter_mut().for_each(|w| *w = 0);
    }

    /// Mutable word storage for in-place packing by the functional
    /// model's scratch-reuse step (crate-internal: callers must respect
    /// the straddle invariant — bits >= h*w of a channel's last word
    /// stay zero).
    pub(crate) fn words_mut(&mut self) -> &mut [u64] {
        &mut self.words
    }

    #[inline]
    pub fn get(&self, ch: usize, idx: usize) -> bool {
        (self.words[ch * self.wpc + idx / 64] >> (idx % 64)) & 1 == 1
    }

    /// Words of one channel (the scheduler's scan granularity).
    #[inline]
    pub fn channel_words(&self, ch: usize) -> &[u64] {
        &self.words[ch * self.wpc..(ch + 1) * self.wpc]
    }

    /// Number of spikes in channel `ch` (one popcount per word).
    #[inline]
    pub fn nnz_channel(&self, ch: usize) -> usize {
        self.channel_words(ch).iter()
            .map(|w| w.count_ones() as usize).sum()
    }

    /// Total spikes in the map.
    pub fn nnz(&self) -> usize {
        self.words.iter().map(|w| w.count_ones() as usize).sum()
    }

    /// Per-channel spike counts.
    pub fn nnz_per_channel(&self) -> Vec<usize> {
        let mut out = Vec::new();
        self.nnz_per_channel_into(&mut out);
        out
    }

    /// [`nnz_per_channel`](Self::nnz_per_channel) into a reused buffer
    /// (the engine calls this per layer per timestep; see PERF.md).
    pub fn nnz_per_channel_into(&self, out: &mut Vec<usize>) {
        out.clear();
        out.extend((0..self.c).map(|ch| self.nnz_channel(ch)));
    }

    /// Iterate (channel, linear index) of set bits — the event stream the
    /// spike scheduler emits.
    pub fn iter_events(&self) -> impl Iterator<Item = (usize, usize)> + '_ {
        (0..self.c).flat_map(move |ch| {
            self.channel_words(ch).iter().enumerate()
                .flat_map(move |(wi, &word)| {
                    let mut rem = word;
                    std::iter::from_fn(move || {
                        if rem == 0 {
                            return None;
                        }
                        let b = rem.trailing_zeros() as usize;
                        rem &= rem - 1;
                        Some((ch, wi * 64 + b))
                    })
                })
                .filter(move |&(_, idx)| idx < self.h * self.w)
        })
    }

    /// Dense f32 view (for feeding the runtime).
    pub fn to_f32(&self) -> Vec<f32> {
        let mut out = Vec::new();
        self.to_f32_into(&mut out);
        out
    }

    /// Dense f32 view into a reused buffer: zeros it, then writes 1.0
    /// straight from the packed words (no iterator machinery) — the
    /// other half of the per-timestep PJRT boundary.
    pub fn to_f32_into(&self, out: &mut Vec<f32>) {
        let per = self.h * self.w;
        out.clear();
        out.resize(self.c * per, 0.0);
        for ch in 0..self.c {
            let base = ch * per;
            for (wi, &word) in self.channel_words(ch).iter().enumerate() {
                let mut rem = word;
                while rem != 0 {
                    let b = rem.trailing_zeros() as usize;
                    rem &= rem - 1;
                    let idx = wi * 64 + b;
                    if idx < per {
                        out[base + idx] = 1.0;
                    }
                }
            }
        }
    }

    /// Total number of neurons.
    pub fn len(&self) -> usize {
        self.c * self.h * self.w
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Spike rate over the whole map.
    pub fn rate(&self) -> f64 {
        self.nnz() as f64 / self.len() as f64
    }

    /// Memory words the spike scheduler must scan for this map.
    pub fn scan_words(&self) -> usize {
        self.words.len()
    }

    /// Spike counts per interleaved row-group: counts[g] = spikes in rows
    /// `r` with `r % n == g`, summed over channels. This is the
    /// row-interleaved work split the SPE streams use when a layer has
    /// fewer input channels than SPEs (see sim::timing).
    pub fn nnz_row_interleaved(&self, n: usize) -> Vec<u64> {
        let mut counts = Vec::new();
        self.nnz_row_interleaved_into(n, &mut counts);
        counts
    }

    /// [`nnz_row_interleaved`](Self::nnz_row_interleaved) into a reused
    /// buffer.
    pub fn nnz_row_interleaved_into(&self, n: usize, out: &mut Vec<u64>) {
        out.clear();
        out.resize(n, 0);
        for (_, idx) in self.iter_events() {
            let row = idx / self.w;
            out[row % n] += 1;
        }
    }

    /// Spike counts per interleaved *neuron* group: counts[g] = spikes at
    /// linear index `ch*h*w + idx` with `index % n == g`. The dense
    /// layer's SPE split: weight rows are per input neuron, so neurons
    /// interleave freely across SPEs.
    pub fn nnz_index_interleaved(&self, n: usize) -> Vec<u64> {
        let mut counts = Vec::new();
        self.nnz_index_interleaved_into(n, &mut counts);
        counts
    }

    /// [`nnz_index_interleaved`](Self::nnz_index_interleaved) into a
    /// reused buffer.
    pub fn nnz_index_interleaved_into(&self, n: usize, out: &mut Vec<u64>) {
        let per = self.h * self.w;
        out.clear();
        out.resize(n, 0);
        for (ch, idx) in self.iter_events() {
            out[(ch * per + idx) % n] += 1;
        }
    }
}

/// Population count over externally packed channel blocks: `words`
/// holds consecutive blocks of `wpc` words, each covering `neurons`
/// valid bits (the [`SpikeMap`] per-channel layout — a multi-timestep
/// wire payload is just `timesteps * c` such blocks). Stray bits at or
/// beyond `neurons` in a block's tail word are masked off, exactly as
/// the worker masks client-packed spike payloads, so the count matches
/// what the pipeline will actually process. A trailing partial block
/// (malformed payload) is counted unmasked rather than panicking —
/// cost prediction must never be the thing that dies on bad input.
pub fn nnz_packed(words: &[u64], wpc: usize, neurons: usize) -> u64 {
    if wpc == 0 {
        return 0;
    }
    let rem = neurons % 64;
    let mask: u64 = if rem == 0 { !0u64 } else { (1u64 << rem) - 1 };
    let mut total = 0u64;
    let mut chunks = words.chunks_exact(wpc);
    for block in &mut chunks {
        for (i, &w) in block.iter().enumerate() {
            let w = if i + 1 == wpc { w & mask } else { w };
            total += w.count_ones() as u64;
        }
    }
    total
        + chunks.remainder().iter()
            .map(|w| w.count_ones() as u64)
            .sum::<u64>()
}

/// Time-major bit-packed spike storage: the full temporal activity of
/// one neuron lives in consecutive bits (one `u64` word covers 64
/// timesteps), so a kernel reads a synapse's whole spike train with a
/// single load instead of T per-timestep map probes. This is the
/// FireFly-v2-style layout the bit-parallel temporal kernels in
/// `snn::functional` consume (see PERF.md, "Bit-parallel temporal
/// kernels").
///
/// Layout: neuron-major — word index of (ch, idx, timestep word tw) is
/// `(ch*h*w + idx) * wpt + tw` with `wpt = ceil(t/64)`. Straddle
/// invariant: bits >= `t` in a neuron's tail word stay zero (mirrors
/// the [`SpikeMap`] per-channel tail-word invariant).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TemporalSpikeMap {
    pub c: usize,
    pub h: usize,
    pub w: usize,
    /// Timesteps packed per neuron.
    pub t: usize,
    /// words_per_train = ceil(t / 64)
    wpt: usize,
    words: Vec<u64>,
}

impl TemporalSpikeMap {
    pub fn zeros(c: usize, h: usize, w: usize, t: usize) -> Self {
        let wpt = t.div_ceil(64);
        Self { c, h, w, t, wpt, words: vec![0; c * h * w * wpt] }
    }

    /// Words per neuron spike train (packing stride).
    #[inline]
    pub fn words_per_train(&self) -> usize {
        self.wpt
    }

    #[inline]
    pub fn set(&mut self, ch: usize, idx: usize, tt: usize) {
        debug_assert!(tt < self.t);
        let n = ch * self.h * self.w + idx;
        self.words[n * self.wpt + tt / 64] |= 1u64 << (tt % 64);
    }

    #[inline]
    pub fn get(&self, ch: usize, idx: usize, tt: usize) -> bool {
        let n = ch * self.h * self.w + idx;
        (self.words[n * self.wpt + tt / 64] >> (tt % 64)) & 1 == 1
    }

    /// Zero every bit, keeping the allocation (scratch-reuse frames).
    pub fn clear(&mut self) {
        self.words.iter_mut().for_each(|w| *w = 0);
    }

    /// The packed spike train of one neuron (`wpt` words, t ascending).
    #[inline]
    pub fn train(&self, ch: usize, idx: usize) -> &[u64] {
        let n = ch * self.h * self.w + idx;
        &self.words[n * self.wpt..(n + 1) * self.wpt]
    }

    /// Whole word storage, neuron-major (read-side of the temporal
    /// kernels; crate-internal like [`SpikeMap::words_mut`]).
    pub(crate) fn words(&self) -> &[u64] {
        &self.words
    }

    /// Mutable word storage for in-place packing (crate-internal;
    /// callers must respect the straddle invariant — bits >= `t` of a
    /// neuron's tail word stay zero).
    pub(crate) fn words_mut(&mut self) -> &mut [u64] {
        &mut self.words
    }

    /// Pack a per-timestep spike train (the oracle-path representation)
    /// into the time-major layout. Shapes must agree across steps;
    /// `steps.len()` becomes `t`.
    pub fn from_steps(steps: &[SpikeMap]) -> Self {
        assert!(!steps.is_empty(), "from_steps: empty train");
        let (c, h, w) = (steps[0].c, steps[0].h, steps[0].w);
        let mut out = Self::zeros(c, h, w, steps.len());
        let per = h * w;
        for (tt, m) in steps.iter().enumerate() {
            assert_eq!((m.c, m.h, m.w), (c, h, w),
                       "from_steps: shape mismatch at step {tt}");
            let (tw, bit) = (tt / 64, tt % 64);
            for (ch, idx) in m.iter_events() {
                out.words[(ch * per + idx) * out.wpt + tw] |= 1u64 << bit;
            }
        }
        out
    }

    /// Unpack to per-timestep maps — the inverse of
    /// [`Self::from_steps`], used by parity tests and the oracle path.
    pub fn to_steps(&self) -> Vec<SpikeMap> {
        let per = self.h * self.w;
        let mut steps: Vec<SpikeMap> =
            (0..self.t).map(|_| SpikeMap::zeros(self.c, self.h, self.w))
                .collect();
        for ch in 0..self.c {
            for idx in 0..per {
                for (tw, &word) in self.train(ch, idx).iter().enumerate() {
                    let mut rem = word;
                    while rem != 0 {
                        let b = rem.trailing_zeros() as usize;
                        rem &= rem - 1;
                        let tt = tw * 64 + b;
                        if tt < self.t {
                            steps[tt].set(ch, idx);
                        }
                    }
                }
            }
        }
        steps
    }

    /// Pack from the multi-timestep wire layout (`t` consecutive blocks
    /// of `c * ceil(h*w/64)` spatial words — the `FramePayload::Spikes`
    /// format). Spatial straddle bits (>= h*w in a channel's tail word)
    /// are masked off, exactly as the worker masks client-packed
    /// payloads on the per-timestep path.
    pub fn from_packed_steps(c: usize, h: usize, w: usize, t: usize,
                             words: &[u64]) -> Self {
        let per = h * w;
        let wpc = per.div_ceil(64);
        assert_eq!(words.len(), t * c * wpc,
                   "from_packed_steps: bad word count");
        let rem = per % 64;
        let tail: u64 = if rem == 0 { !0u64 } else { (1u64 << rem) - 1 };
        let mut out = Self::zeros(c, h, w, t);
        for tt in 0..t {
            let (tw, bit) = (tt / 64, tt % 64);
            let block = &words[tt * c * wpc..(tt + 1) * c * wpc];
            for ch in 0..c {
                for (wi, &word) in
                    block[ch * wpc..(ch + 1) * wpc].iter().enumerate()
                {
                    let mut w = word;
                    if wi + 1 == wpc {
                        w &= tail;
                    }
                    let mut rem = w;
                    while rem != 0 {
                        let b = rem.trailing_zeros() as usize;
                        rem &= rem - 1;
                        let idx = wi * 64 + b;
                        out.words[(ch * per + idx) * out.wpt + tw] |=
                            1u64 << bit;
                    }
                }
            }
        }
        out
    }

    /// Total spikes across all neurons and timesteps.
    pub fn nnz(&self) -> usize {
        self.words.iter().map(|w| w.count_ones() as usize).sum()
    }

    /// Total number of neurons (one spatial position, all timesteps).
    pub fn len(&self) -> usize {
        self.c * self.h * self.w
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Per-timestep per-channel spike counts in one pass over the
    /// packed words: `out[tt * c + ch]` = spikes of channel `ch` at
    /// timestep `tt`. Equivalent to calling
    /// [`SpikeMap::nnz_per_channel_into`] on each unpacked step, but
    /// without materialising the steps — the temporal engine path
    /// feeds per-timestep timing from this.
    pub fn nnz_per_channel_t_into(&self, out: &mut Vec<usize>) {
        out.clear();
        out.resize(self.t * self.c, 0);
        let per = self.h * self.w;
        for ch in 0..self.c {
            for idx in 0..per {
                for (tw, &word) in self.train(ch, idx).iter().enumerate() {
                    let mut rem = word;
                    while rem != 0 {
                        let b = rem.trailing_zeros() as usize;
                        rem &= rem - 1;
                        out[(tw * 64 + b) * self.c + ch] += 1;
                    }
                }
            }
        }
    }

    /// Per-timestep row-interleaved counts (one pass):
    /// `out[tt * n + g]` = spikes at timestep `tt` in rows `r` with
    /// `r % n == g`, summed over channels — the temporal-path
    /// equivalent of [`SpikeMap::nnz_row_interleaved_into`].
    pub fn nnz_row_interleaved_t_into(&self, n: usize,
                                      out: &mut Vec<u64>) {
        out.clear();
        out.resize(self.t * n, 0);
        let per = self.h * self.w;
        for ch in 0..self.c {
            for idx in 0..per {
                let g = (idx / self.w) % n;
                for (tw, &word) in self.train(ch, idx).iter().enumerate() {
                    let mut rem = word;
                    while rem != 0 {
                        let b = rem.trailing_zeros() as usize;
                        rem &= rem - 1;
                        out[(tw * 64 + b) * n + g] += 1;
                    }
                }
            }
        }
    }

    /// Per-timestep neuron-interleaved counts (one pass):
    /// `out[tt * n + g]` = spikes at timestep `tt` at linear neuron
    /// index `i` with `i % n == g` — the temporal-path equivalent of
    /// [`SpikeMap::nnz_index_interleaved_into`].
    pub fn nnz_index_interleaved_t_into(&self, n: usize,
                                        out: &mut Vec<u64>) {
        out.clear();
        out.resize(self.t * n, 0);
        let per = self.h * self.w;
        for ch in 0..self.c {
            for idx in 0..per {
                let g = (ch * per + idx) % n;
                for (tw, &word) in self.train(ch, idx).iter().enumerate() {
                    let mut rem = word;
                    while rem != 0 {
                        let b = rem.trailing_zeros() as usize;
                        rem &= rem - 1;
                        out[(tw * 64 + b) * n + g] += 1;
                    }
                }
            }
        }
    }

    /// Per-neuron spike totals over the frame: `out[ch*h*w + idx]` =
    /// popcount of that neuron's train. Matches what the per-timestep
    /// path accumulates into `FrameReport::output_counts`.
    pub fn counts_into(&self, out: &mut [u32]) {
        let per = self.h * self.w;
        assert_eq!(out.len(), self.c * per);
        for (n, slot) in out.iter_mut().enumerate() {
            let train = &self.words[n * self.wpt..(n + 1) * self.wpt];
            *slot =
                train.iter().map(|w| w.count_ones()).sum::<u32>();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_f32() {
        let mut data = vec![0.0f32; 3 * 5 * 7];
        data[0] = 1.0;
        data[36] = 1.0;
        data[104] = 1.0;
        let m = SpikeMap::from_f32(3, 5, 7, &data);
        assert_eq!(m.nnz(), 3);
        assert_eq!(m.to_f32(), data);
    }

    #[test]
    fn per_channel_counts() {
        let mut m = SpikeMap::zeros(2, 8, 8);
        for i in 0..10 {
            m.set(0, i * 3);
        }
        m.set(1, 63);
        m.set(1, 64 - 1); // same bit, idempotent
        assert_eq!(m.nnz_channel(0), 10);
        assert_eq!(m.nnz_channel(1), 1);
        assert_eq!(m.nnz(), 11);
    }

    #[test]
    fn events_match_bits() {
        let mut m = SpikeMap::zeros(4, 9, 9);
        let idxs = [(0, 0), (0, 80), (2, 13), (3, 64), (3, 65)];
        for &(c, i) in &idxs {
            m.set(c, i);
        }
        let got: Vec<_> = m.iter_events().collect();
        assert_eq!(got, idxs.to_vec());
    }

    #[test]
    fn from_f32_word_packing_matches_per_bit_set() {
        // Per-neuron ground truth vs the word-packed fast path, at a
        // size whose per-channel tail word is partial (h*w = 65).
        let (c, h, w) = (3usize, 5usize, 13usize);
        let per = h * w;
        let mut data = vec![0.0f32; c * per];
        for i in (0..c * per).step_by(7) {
            data[i] = 1.0;
        }
        data[64] = 1.0; // word boundary
        data[per] = 1.0; // first neuron of channel 1
        let fast = SpikeMap::from_f32(c, h, w, &data);
        let mut slow = SpikeMap::zeros(c, h, w);
        for ch in 0..c {
            for i in 0..per {
                if data[ch * per + i] >= 0.5 {
                    slow.set(ch, i);
                }
            }
        }
        assert_eq!(fast, slow);
        assert_eq!(fast.to_f32(), slow.to_f32());
    }

    #[test]
    fn to_f32_into_reuses_and_zeroes_buffer() {
        let mut m = SpikeMap::zeros(2, 3, 3);
        m.set(0, 4);
        let mut buf = vec![7.0f32; 100]; // stale, oversized
        m.to_f32_into(&mut buf);
        assert_eq!(buf.len(), 18);
        assert_eq!(buf.iter().filter(|&&v| v == 1.0).count(), 1);
        assert!(buf.iter().all(|&v| v == 0.0 || v == 1.0));
        assert_eq!(buf[4], 1.0);
    }

    #[test]
    fn clear_keeps_shape_drops_bits() {
        let mut m = SpikeMap::zeros(2, 4, 4);
        m.set(0, 3);
        m.set(1, 15);
        m.clear();
        assert_eq!(m.nnz(), 0);
        assert_eq!((m.c, m.h, m.w), (2, 4, 4));
    }

    #[test]
    fn into_variants_match_allocating_ones() {
        let mut m = SpikeMap::zeros(3, 6, 5);
        for &(c, i) in &[(0, 0), (0, 29), (1, 7), (2, 13), (2, 14)] {
            m.set(c, i);
        }
        let mut nnz = vec![99usize; 1];
        m.nnz_per_channel_into(&mut nnz);
        assert_eq!(nnz, m.nnz_per_channel());
        let mut rows = vec![99u64; 1];
        m.nnz_row_interleaved_into(4, &mut rows);
        assert_eq!(rows, m.nnz_row_interleaved(4));
        let mut idxs = vec![99u64; 9];
        m.nnz_index_interleaved_into(4, &mut idxs);
        assert_eq!(idxs, m.nnz_index_interleaved(4));
    }

    #[test]
    fn word_boundary_straddle_excluded() {
        // h*w = 65 means bit 65..127 of the 2nd word must never report.
        let mut m = SpikeMap::zeros(1, 5, 13);
        m.set(0, 64);
        assert_eq!(m.nnz_channel(0), 1);
        assert_eq!(m.iter_events().count(), 1);
    }

    #[test]
    fn nnz_packed_matches_spikemap_and_masks_straddle() {
        // Two channels of 5x13 = 65 neurons -> wpc = 2, partial tail.
        let mut m = SpikeMap::zeros(2, 5, 13);
        for &(c, i) in &[(0usize, 0usize), (0, 64), (1, 3), (1, 40)] {
            m.set(c, i);
        }
        let mut words = Vec::new();
        for ch in 0..2 {
            words.extend_from_slice(m.channel_words(ch));
        }
        assert_eq!(nnz_packed(&words, m.words_per_channel(), 65),
                   m.nnz() as u64);
        // Stray bits beyond neuron 65 in a tail word are excluded,
        // matching the worker-side mask on client-packed payloads.
        let mut dirty = words.clone();
        dirty[1] |= 1u64 << 30; // bit 94 of channel 0: out of range
        assert_eq!(nnz_packed(&dirty, 2, 65), m.nnz() as u64);
        // Exact multiple of 64 neurons: no masking applies.
        assert_eq!(nnz_packed(&[!0u64], 1, 64), 64);
        // Degenerate inputs count zero / raw, never panic.
        assert_eq!(nnz_packed(&[], 2, 65), 0);
        assert_eq!(nnz_packed(&[1, 1, 1], 2, 65), 3);
        assert_eq!(nnz_packed(&[7], 0, 65), 0);
    }

    #[test]
    fn temporal_set_get_and_train_words() {
        let mut m = TemporalSpikeMap::zeros(2, 3, 3, 70);
        assert_eq!(m.words_per_train(), 2);
        m.set(0, 4, 0);
        m.set(0, 4, 63);
        m.set(0, 4, 64);
        m.set(1, 8, 69);
        assert!(m.get(0, 4, 0) && m.get(0, 4, 63) && m.get(0, 4, 64));
        assert!(!m.get(0, 4, 1) && !m.get(1, 8, 68));
        assert_eq!(m.train(0, 4), &[(1u64 << 63) | 1, 1]);
        assert_eq!(m.nnz(), 4);
        m.clear();
        assert_eq!(m.nnz(), 0);
    }

    #[test]
    fn temporal_steps_roundtrip() {
        // T = 65 exercises the temporal tail word.
        let t = 65usize;
        let mut steps: Vec<SpikeMap> =
            (0..t).map(|_| SpikeMap::zeros(2, 5, 13)).collect();
        steps[0].set(0, 0);
        steps[0].set(1, 64);
        steps[63].set(0, 12);
        steps[64].set(0, 12);
        steps[64].set(1, 3);
        let m = TemporalSpikeMap::from_steps(&steps);
        assert_eq!((m.c, m.h, m.w, m.t), (2, 5, 13, t));
        assert_eq!(m.nnz(), 5);
        assert!(m.get(0, 12, 63) && m.get(0, 12, 64));
        assert_eq!(m.to_steps(), steps);
    }

    #[test]
    fn temporal_from_packed_steps_masks_spatial_straddle() {
        // 1 channel of 65 neurons -> wpc = 2; wire payload with a
        // stray bit above neuron 65 must be dropped.
        let (c, h, w, t) = (1usize, 5usize, 13usize, 3usize);
        let wpc = (h * w).div_ceil(64);
        let mut wire = vec![0u64; t * c * wpc];
        wire[0] = 1;               // t0: neuron 0
        wire[1] = 1;               // t0: neuron 64
        wire[2 * wpc + 1] = 1 | (1u64 << 30); // t2: neuron 64 + stray
        let m = TemporalSpikeMap::from_packed_steps(c, h, w, t, &wire);
        assert_eq!(m.nnz(), 3);
        assert!(m.get(0, 0, 0) && m.get(0, 64, 0) && m.get(0, 64, 2));
        // Same frame via per-timestep maps agrees bit-for-bit.
        let mut steps: Vec<SpikeMap> =
            (0..t).map(|_| SpikeMap::zeros(c, h, w)).collect();
        steps[0].set(0, 0);
        steps[0].set(0, 64);
        steps[2].set(0, 64);
        assert_eq!(m, TemporalSpikeMap::from_steps(&steps));
    }

    #[test]
    fn temporal_t_extractors_match_per_step_counters() {
        let (c, h, w, t) = (3usize, 4usize, 5usize, 67usize);
        let mut steps: Vec<SpikeMap> =
            (0..t).map(|_| SpikeMap::zeros(c, h, w)).collect();
        // Deterministic scatter touching every timestep word.
        for tt in 0..t {
            for k in 0..=(tt % 4) {
                steps[tt].set((tt + k) % c, (tt * 7 + k * 3) % (h * w));
            }
        }
        let m = TemporalSpikeMap::from_steps(&steps);
        let n = 4usize;
        let (mut pc, mut rows, mut idxs) =
            (Vec::new(), Vec::new(), Vec::new());
        m.nnz_per_channel_t_into(&mut pc);
        m.nnz_row_interleaved_t_into(n, &mut rows);
        m.nnz_index_interleaved_t_into(n, &mut idxs);
        let mut counts = vec![0u32; c * h * w];
        m.counts_into(&mut counts);
        let mut want_counts = vec![0u32; c * h * w];
        for (tt, s) in steps.iter().enumerate() {
            assert_eq!(&pc[tt * c..(tt + 1) * c], s.nnz_per_channel());
            assert_eq!(&rows[tt * n..(tt + 1) * n],
                       s.nnz_row_interleaved(n));
            assert_eq!(&idxs[tt * n..(tt + 1) * n],
                       s.nnz_index_interleaved(n));
            for (ch, idx) in s.iter_events() {
                want_counts[ch * h * w + idx] += 1;
            }
        }
        assert_eq!(counts, want_counts);
    }
}
