//! Loader for the `<name>.weights.{bin,json}` interchange written by
//! `python/compile/train.save_weights`.
//!
//! The `.bin` is raw little-endian f32 in layer order (conv OIHW ...,
//! dense W (K,F), dense b (K)); the `.json` carries shapes/offsets plus
//! the conversion metadata (vth, lambdas, eval metrics). Parsing uses the
//! in-crate [`crate::util::Json`] (the build is offline; no serde).

use std::path::Path;

use anyhow::{anyhow, ensure, Context, Result};

use super::{ConvGeom, DenseGeom};
use crate::data::fnv1a64;
use crate::util::Json;

/// One entry of the json `layers` list.
#[derive(Debug, Clone)]
pub struct LayerEntry {
    pub kind: String,
    pub shape: Vec<usize>,
    pub offset: usize,
    pub layer: usize,
    pub pad: Option<usize>,
}

impl LayerEntry {
    fn from_json(v: &Json) -> Result<Self> {
        Ok(Self {
            kind: v.field("kind")?.as_str()?.to_string(),
            shape: v.field("shape")?.usize_vec()?,
            offset: v.field("offset")?.as_usize()?,
            layer: v.get("layer").map(|x| x.as_usize()).transpose()?
                .unwrap_or(0),
            pad: v.get("pad").filter(|x| !x.is_null())
                .map(|x| x.as_usize()).transpose()?,
        })
    }
}

/// `<name>.weights.json` (see train.save_weights for the writer).
#[derive(Debug, Clone)]
pub struct WeightsMeta {
    pub name: String,
    pub aprc: bool,
    pub pad: usize,
    pub vth: f32,
    pub timesteps: usize,
    pub in_shape: Vec<usize>,
    pub feature_sizes: Vec<Vec<usize>>,
    pub dense_out: Option<usize>,
    pub total_floats: usize,
    pub lambdas: Vec<f64>,
    pub layers: Vec<LayerEntry>,
    pub blob_fnv1a64: String,
    pub ann_metric: Option<f64>,
    pub snn_metric: Option<f64>,
    pub seg_rate_threshold: Option<f64>,
}

impl WeightsMeta {
    /// Parse from JSON text (python `json.dumps` output).
    pub fn parse(text: &str) -> Result<Self> {
        let v = Json::parse(text)?;
        Self::from_json(&v)
    }

    pub fn from_json(v: &Json) -> Result<Self> {
        let opt_f64 = |key: &str| -> Result<Option<f64>> {
            v.get(key).filter(|x| !x.is_null())
                .map(|x| x.as_f64()).transpose()
        };
        Ok(Self {
            name: v.field("name")?.as_str()?.to_string(),
            aprc: v.field("aprc")?.as_bool()?,
            pad: v.field("pad")?.as_usize()?,
            vth: v.field("vth")?.as_f64()? as f32,
            timesteps: v.field("timesteps")?.as_usize()?,
            in_shape: v.field("in_shape")?.usize_vec()?,
            feature_sizes: v.field("feature_sizes")?.as_arr()?.iter()
                .map(|x| x.usize_vec()).collect::<Result<_>>()?,
            dense_out: v.get("dense_out").filter(|x| !x.is_null())
                .map(|x| x.as_usize()).transpose()?,
            total_floats: v.field("total_floats")?.as_usize()?,
            lambdas: v.field("lambdas")?.f64_vec()?,
            layers: v.field("layers")?.as_arr()?.iter()
                .map(LayerEntry::from_json).collect::<Result<_>>()?,
            blob_fnv1a64: v.field("blob_fnv1a64")?.as_str()?.to_string(),
            ann_metric: opt_f64("ann_metric")?,
            snn_metric: opt_f64("snn_metric")?,
            seg_rate_threshold: opt_f64("seg_rate_threshold")?,
        })
    }
}

/// Weights of a single layer.
#[derive(Debug, Clone)]
pub enum LayerWeights {
    /// OIHW conv filters with geometry.
    Conv { geom: ConvGeom, w: Vec<f32> },
    /// Dense (K, F) weights + K bias. `wt` is the input-major (F, K)
    /// transpose of `w`, built once at load (see
    /// [`transpose_dense`]) so the functional model's per-event
    /// scatter reads `fout` contiguous floats instead of striding by
    /// `fin` (see PERF.md).
    Dense { geom: DenseGeom, w: Vec<f32>, wt: Vec<f32>, b: Vec<f32> },
}

/// Transpose (K, F) dense weights to input-major (F, K) — the layout
/// the event-driven scatter wants: one input spike touches one
/// contiguous row of `fout` floats.
pub fn transpose_dense(w: &[f32], fout: usize, fin: usize) -> Vec<f32> {
    debug_assert_eq!(w.len(), fout * fin);
    let mut wt = vec![0.0f32; w.len()];
    for k in 0..fout {
        for f in 0..fin {
            wt[f * fout + k] = w[k * fin + f];
        }
    }
    wt
}

impl LayerWeights {
    /// Number of output channels (filters) of this layer.
    pub fn cout(&self) -> usize {
        match self {
            LayerWeights::Conv { geom, .. } => geom.cout,
            LayerWeights::Dense { geom, .. } => geom.fout,
        }
    }

    /// APRC filter magnitudes: the summed elements of each filter
    /// (paper §III-B). For dense layers, per-output-row sums.
    pub fn filter_magnitudes(&self) -> Vec<f64> {
        match self {
            LayerWeights::Conv { geom, w } => {
                let per = geom.cin * geom.r * geom.r;
                (0..geom.cout)
                    .map(|m| w[m * per..(m + 1) * per].iter()
                        .map(|&x| x as f64).sum())
                    .collect()
            }
            LayerWeights::Dense { geom, w, .. } => (0..geom.fout)
                .map(|k| w[k * geom.fin..(k + 1) * geom.fin].iter()
                    .map(|&x| x as f64).sum())
                .collect(),
        }
    }

    /// Per-filter sum of squared weights — the fluctuation term of the
    /// rectified-Gaussian APRC extension (see `schedule::aprc`).
    pub fn filter_sumsq(&self) -> Vec<f64> {
        match self {
            LayerWeights::Conv { geom, w } => {
                let per = geom.cin * geom.r * geom.r;
                (0..geom.cout)
                    .map(|m| w[m * per..(m + 1) * per].iter()
                        .map(|&x| (x as f64) * (x as f64)).sum())
                    .collect()
            }
            LayerWeights::Dense { geom, w, .. } => (0..geom.fout)
                .map(|k| w[k * geom.fin..(k + 1) * geom.fin].iter()
                    .map(|&x| (x as f64) * (x as f64)).sum())
                .collect(),
        }
    }
}

/// A fully-loaded network variant.
#[derive(Debug, Clone)]
pub struct NetworkWeights {
    pub meta: WeightsMeta,
    pub layers: Vec<LayerWeights>,
}

impl NetworkWeights {
    /// Load `<dir>/<name>.weights.{bin,json}` and verify the blob hash.
    pub fn load(dir: &Path, name: &str) -> Result<Self> {
        let json_path = dir.join(format!("{name}.weights.json"));
        let bin_path = dir.join(format!("{name}.weights.bin"));
        let meta = WeightsMeta::parse(
            &std::fs::read_to_string(&json_path).with_context(
                || format!("reading {json_path:?} — run `make artifacts`"))?)?;
        let blob = std::fs::read(&bin_path)
            .with_context(|| format!("reading {bin_path:?}"))?;
        ensure!(blob.len() == meta.total_floats * 4,
                "blob size {} != {} floats", blob.len(), meta.total_floats);
        let got = format!("{:016x}", fnv1a64(&blob));
        ensure!(got == meta.blob_fnv1a64,
                "weights blob hash mismatch: {got} != {}", meta.blob_fnv1a64);
        let floats: Vec<f32> = blob
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        Self::assemble(meta, &floats)
    }

    /// Build from parsed metadata + raw floats (also used by tests).
    pub fn assemble(meta: WeightsMeta, floats: &[f32]) -> Result<Self> {
        let mut layers = Vec::new();
        let (mut h, mut w) = (meta.in_shape[1], meta.in_shape[2]);
        let mut cin = meta.in_shape[0];
        let mut dense_w: Option<(Vec<usize>, Vec<f32>)> = None;
        let mut dense_b: Option<Vec<f32>> = None;
        for e in &meta.layers {
            let n: usize = e.shape.iter().product();
            ensure!(e.offset + n <= floats.len(),
                    "layer {} out of range", e.kind);
            let data = floats[e.offset..e.offset + n].to_vec();
            match e.kind.as_str() {
                "conv" => {
                    let (cout, ci, r, r2) =
                        (e.shape[0], e.shape[1], e.shape[2], e.shape[3]);
                    ensure!(ci == cin && r == r2,
                            "conv geometry mismatch at layer {}", e.layer);
                    let pad = e.pad.unwrap_or(meta.pad);
                    let eh = h + 2 * pad - r + 1;
                    let ew = w + 2 * pad - r + 1;
                    layers.push(LayerWeights::Conv {
                        geom: ConvGeom { cin, cout, r, pad, h, w, eh, ew },
                        w: data,
                    });
                    cin = cout;
                    h = eh;
                    w = ew;
                }
                "dense_w" => dense_w = Some((e.shape.clone(), data)),
                "dense_b" => dense_b = Some(data),
                other => return Err(anyhow!("unknown layer kind {other}")),
            }
        }
        if let (Some((shape, wdat)), Some(bdat)) = (dense_w, dense_b) {
            let (fout, fin) = (shape[0], shape[1]);
            ensure!(fin == cin * h * w, "dense fin {} != {}", fin,
                    cin * h * w);
            let wt = transpose_dense(&wdat, fout, fin);
            layers.push(LayerWeights::Dense {
                geom: DenseGeom { fin, fout, src_channels: cin },
                w: wdat,
                wt,
                b: bdat,
            });
        }
        Ok(Self { meta, layers })
    }

    pub fn num_layers(&self) -> usize {
        self.layers.len()
    }

    /// Conv geometry of layer `l` (panics on the dense layer).
    pub fn conv_geom(&self, l: usize) -> ConvGeom {
        match &self.layers[l] {
            LayerWeights::Conv { geom, .. } => *geom,
            _ => panic!("layer {l} is not conv"),
        }
    }

    /// Input spike-map shape (C, H, W) seen by layer `l`.
    pub fn layer_input_shape(&self, l: usize) -> (usize, usize, usize) {
        match &self.layers[l] {
            LayerWeights::Conv { geom, .. } => (geom.cin, geom.h, geom.w),
            LayerWeights::Dense { geom, .. } => {
                // Flattened input viewed as (src_channels, 1, per_channel).
                let per = geom.fin / geom.src_channels;
                (geom.src_channels, 1, per)
            }
        }
    }

    /// Output spike-map shape (C, H, W) of layer `l`.
    pub fn layer_output_shape(&self, l: usize) -> (usize, usize, usize) {
        match &self.layers[l] {
            LayerWeights::Conv { geom, .. } => (geom.cout, geom.eh, geom.ew),
            LayerWeights::Dense { geom, .. } => (geom.fout, 1, 1),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_meta() -> WeightsMeta {
        WeightsMeta::parse(r#"{
            "name": "tiny", "aprc": true, "pad": 2, "vth": 1.0,
            "timesteps": 4, "in_shape": [1, 4, 4],
            "feature_sizes": [[2, 6, 6]], "dense_out": 3,
            "total_floats": 237,
            "lambdas": [1.0],
            "layers": [
                {"kind": "conv", "shape": [2,1,3,3], "offset": 0,
                 "layer": 0, "pad": 2},
                {"kind": "dense_w", "shape": [3, 72], "offset": 18,
                 "layer": 1},
                {"kind": "dense_b", "shape": [3], "offset": 234,
                 "layer": 1}
            ],
            "blob_fnv1a64": "0"
        }"#).unwrap()
    }

    #[test]
    fn assemble_tiny() {
        let meta = tiny_meta();
        let floats = vec![0.5f32; meta.total_floats];
        let net = NetworkWeights::assemble(meta, &floats).unwrap();
        assert_eq!(net.num_layers(), 2);
        let g = net.conv_geom(0);
        assert_eq!((g.eh, g.ew), (6, 6));
        assert_eq!(net.layer_output_shape(1), (3, 1, 1));
        // magnitude of a 1x3x3 filter of 0.5s = 4.5
        let mags = net.layers[0].filter_magnitudes();
        assert!((mags[0] - 4.5).abs() < 1e-9);
    }

    #[test]
    fn dense_transpose_built_at_load() {
        let meta = tiny_meta();
        let floats: Vec<f32> =
            (0..meta.total_floats).map(|i| i as f32 * 0.01).collect();
        let net = NetworkWeights::assemble(meta, &floats).unwrap();
        match &net.layers[1] {
            LayerWeights::Dense { geom, w, wt, .. } => {
                assert_eq!(wt.len(), w.len());
                for k in 0..geom.fout {
                    for f in 0..geom.fin {
                        assert_eq!(wt[f * geom.fout + k],
                                   w[k * geom.fin + f]);
                    }
                }
            }
            _ => panic!("layer 1 should be dense"),
        }
    }

    #[test]
    fn dense_input_grouped_by_source_channel() {
        let meta = tiny_meta();
        let floats = vec![0.1f32; meta.total_floats];
        let net = NetworkWeights::assemble(meta, &floats).unwrap();
        assert_eq!(net.layer_input_shape(1), (2, 1, 36));
    }

    #[test]
    fn optional_metrics_parse() {
        let mut src = r#"{
            "name": "m", "aprc": false, "pad": 1, "vth": 1.0,
            "timesteps": 8, "in_shape": [1, 4, 4], "feature_sizes": [],
            "dense_out": null, "total_floats": 0, "lambdas": [],
            "layers": [], "blob_fnv1a64": "0""#.to_string();
        src.push_str(r#", "snn_metric": 0.985, "seg_rate_threshold": null}"#);
        let m = WeightsMeta::parse(&src).unwrap();
        assert_eq!(m.snn_metric, Some(0.985));
        assert_eq!(m.seg_rate_threshold, None);
        assert_eq!(m.dense_out, None);
    }
}
