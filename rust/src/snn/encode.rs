//! Phased rate encoding — port of `model.encode_phased`.
//!
//! Pixel p in [0,1] emits `floor(p*(t+1)) - floor(p*t)` spikes at step t:
//! ~p*T evenly spaced spikes over T steps, fully deterministic. All math
//! is f32 to match the jax lowering bit-for-bit (cross-checked against
//! `meta.json:encoding_crosscheck` in tests/cross_language.rs).

use super::{SpikeMap, TemporalSpikeMap};

/// Encode a (C, H, W) f32 image in [0,1] into T spike maps.
pub fn encode_phased(img: &[f32], c: usize, h: usize, w: usize,
                     timesteps: usize) -> Vec<SpikeMap> {
    assert_eq!(img.len(), c * h * w);
    let mut out = Vec::with_capacity(timesteps);
    for t in 0..timesteps {
        let tf = t as f32;
        let mut m = SpikeMap::zeros(c, h, w);
        let per = h * w;
        for ch in 0..c {
            for i in 0..per {
                let p = img[ch * per + i];
                let s = (p * (tf + 1.0)).floor() - (p * tf).floor();
                if s >= 0.5 {
                    m.set(ch, i);
                }
            }
        }
        out.push(m);
    }
    out
}

/// Convenience: encode a u8 image (scaled by 1/255, matching python).
pub fn encode_phased_u8(img: &[u8], c: usize, h: usize, w: usize,
                        timesteps: usize) -> Vec<SpikeMap> {
    let f: Vec<f32> = img.iter().map(|&v| v as f32 / 255.0).collect();
    encode_phased(&f, c, h, w, timesteps)
}

/// [`encode_phased`] emitting straight into the time-major layout the
/// bit-parallel temporal kernels consume: for each pixel, the whole
/// spike train is produced in one inner loop over `t` (no per-timestep
/// maps, no transpose pass). Per-(pixel, t) arithmetic is the exact
/// f32 expression of [`encode_phased`], so
/// `TemporalSpikeMap::to_steps` of the result is bit-identical to the
/// per-timestep encoder — property-checked in
/// tests/proptest_invariants.rs.
pub fn encode_phased_temporal(img: &[f32], c: usize, h: usize,
                              w: usize, timesteps: usize)
                              -> TemporalSpikeMap {
    assert_eq!(img.len(), c * h * w);
    let mut out = TemporalSpikeMap::zeros(c, h, w, timesteps);
    let wpt = out.words_per_train();
    let words = out.words_mut();
    for (n, &p) in img.iter().enumerate() {
        let train = &mut words[n * wpt..(n + 1) * wpt];
        for t in 0..timesteps {
            let tf = t as f32;
            let s = (p * (tf + 1.0)).floor() - (p * tf).floor();
            if s >= 0.5 {
                train[t / 64] |= 1u64 << (t % 64);
            }
        }
    }
    out
}

/// [`encode_phased_u8`] into the time-major layout (scaled by 1/255,
/// matching python).
pub fn encode_phased_temporal_u8(img: &[u8], c: usize, h: usize,
                                 w: usize, timesteps: usize)
                                 -> TemporalSpikeMap {
    let f: Vec<f32> = img.iter().map(|&v| v as f32 / 255.0).collect();
    encode_phased_temporal(&f, c, h, w, timesteps)
}

/// Spikes [`encode_phased_u8`] emits for one pixel value over `T`
/// steps: the per-step emissions `floor(p*(t+1)) - floor(p*t)`
/// telescope to `floor(p*T)` (computed in f32, exactly like the
/// encoder), so the total is known without building any map. The
/// request-cost predictor (`coordinator::cost`) caches this table
/// once per model and sums it per pixel at admission.
pub fn phased_events_per_level(timesteps: usize) -> [u64; 256] {
    let mut table = [0u64; 256];
    for (v, e) in table.iter_mut().enumerate() {
        *e = ((v as f32 / 255.0) * timesteps as f32).floor() as u64;
    }
    table
}

/// One-shot convenience over [`phased_events_per_level`]: the exact
/// total input-spike count `encode_phased_u8` would produce for this
/// image, without materialising a `SpikeMap`. Rebuilds the 256-entry
/// table per call — fine for tests and tools; the admission hot path
/// goes through the model's cached table instead.
pub fn phased_event_count_u8(img: &[u8], timesteps: usize) -> u64 {
    let table = phased_events_per_level(timesteps);
    img.iter().map(|&v| table[v as usize]).sum()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn total_spikes_approximate_rate() {
        // p = 0.5 over 8 steps -> exactly 4 spikes.
        let img = vec![0.5f32];
        let maps = encode_phased(&img, 1, 1, 1, 8);
        let total: usize = maps.iter().map(|m| m.nnz()).sum();
        assert_eq!(total, 4);
    }

    #[test]
    fn extremes() {
        let maps = encode_phased(&[0.0, 1.0], 2, 1, 1, 10);
        let c0: usize = maps.iter().map(|m| m.nnz_channel(0)).sum();
        let c1: usize = maps.iter().map(|m| m.nnz_channel(1)).sum();
        assert_eq!(c0, 0);
        assert_eq!(c1, 10);
    }

    #[test]
    fn evenly_spaced() {
        // p=0.25 over 8 steps: spikes at t where floor crosses: 4 total? 2.
        let maps = encode_phased(&[0.25f32], 1, 1, 1, 8);
        let pattern: Vec<usize> = maps.iter().map(|m| m.nnz()).collect();
        assert_eq!(pattern.iter().sum::<usize>(), 2);
        // No two consecutive spikes for a rate this low.
        for w in pattern.windows(2) {
            assert!(w[0] + w[1] <= 1);
        }
    }

    #[test]
    fn count_matches_floor_pt() {
        for &p in &[0.1f32, 0.3, 0.7, 0.93] {
            for t in [5usize, 16, 50] {
                let maps = encode_phased(&[p], 1, 1, 1, t);
                let total: usize = maps.iter().map(|m| m.nnz()).sum();
                assert_eq!(total, (p * t as f32).floor() as usize,
                           "p={p} T={t}");
            }
        }
    }

    #[test]
    fn temporal_encoder_matches_per_timestep_encoder() {
        // Straddling T values and a partial spatial tail word: the
        // time-major encoder must agree bit-for-bit with the oracle.
        let img: Vec<f32> =
            (0..2 * 5 * 13).map(|i| (i % 97) as f32 / 96.0).collect();
        for t in [1usize, 8, 63, 64, 65, 128] {
            let steps = encode_phased(&img, 2, 5, 13, t);
            let temporal = encode_phased_temporal(&img, 2, 5, 13, t);
            assert_eq!(temporal, TemporalSpikeMap::from_steps(&steps),
                       "T={t}");
            assert_eq!(temporal.to_steps(), steps, "T={t}");
        }
        let pix: Vec<u8> = (0..=255).collect();
        let a = encode_phased_temporal_u8(&pix, 1, 16, 16, 20);
        let b = TemporalSpikeMap::from_steps(
            &encode_phased_u8(&pix, 1, 16, 16, 20));
        assert_eq!(a, b);
    }

    #[test]
    fn event_count_matches_encoder_exactly() {
        // Every pixel level, several timestep counts: the closed form
        // must equal what the encoder actually emits.
        for t in [1usize, 4, 7, 20] {
            let img: Vec<u8> = (0..=255).collect();
            let maps = encode_phased_u8(&img, 1, 16, 16, t);
            let emitted: u64 =
                maps.iter().map(|m| m.nnz() as u64).sum();
            assert_eq!(phased_event_count_u8(&img, t), emitted, "T={t}");
        }
        assert_eq!(phased_event_count_u8(&[], 8), 0);
    }
}
