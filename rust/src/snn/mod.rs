//! SNN substrate: network descriptions, weights, spike trains, encoding
//! and a functional (f32) LIF model.
//!
//! The network geometry mirrors `python/compile/model.py` exactly; the
//! weights are the ANN->SNN-converted parameters written by
//! `make artifacts` (`<name>.weights.{bin,json}`).

mod encode;
mod functional;
mod spikes;
mod weights;

pub use encode::{encode_phased, encode_phased_temporal,
                 encode_phased_temporal_u8, encode_phased_u8,
                 phased_event_count_u8, phased_events_per_level};
pub use functional::{FunctionalNet, LayerOutput};
pub use spikes::{nnz_packed, SpikeMap, TemporalSpikeMap};
pub use weights::{transpose_dense, LayerWeights, NetworkWeights,
                  WeightsMeta};



/// Which of the paper's two benchmark networks.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum NetKind {
    /// `28x28-16c-32c-8c-10` MNIST-substitute classifier (paper §IV).
    Classifier,
    /// `160x80x3-8C3-16C3-32C3-32C3-16C3-1C3` road segmenter (paper §IV).
    Segmenter,
}

impl NetKind {
    /// Artifact base name for the APRC / plain conv variant.
    pub fn variant_name(self, aprc: bool) -> &'static str {
        match (self, aprc) {
            (NetKind::Classifier, true) => "classifier_aprc",
            (NetKind::Classifier, false) => "classifier_plain",
            (NetKind::Segmenter, true) => "segmenter_aprc",
            (NetKind::Segmenter, false) => "segmenter_plain",
        }
    }

    /// Canonical lower-case name — the CLI `--net`/`--model` spelling,
    /// the default registry model name, and the wire model selector.
    pub fn as_str(self) -> &'static str {
        match self {
            NetKind::Classifier => "classifier",
            NetKind::Segmenter => "segmenter",
        }
    }

    /// Parse the canonical name (inverse of [`NetKind::as_str`]).
    pub fn parse(s: &str) -> Option<Self> {
        Some(match s {
            "classifier" => NetKind::Classifier,
            "segmenter" => NetKind::Segmenter,
            _ => return None,
        })
    }
}

/// Geometry of one conv layer instance inside a concrete network variant.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ConvGeom {
    pub cin: usize,
    pub cout: usize,
    pub r: usize,
    pub pad: usize,
    /// Input feature map height/width.
    pub h: usize,
    pub w: usize,
    /// Output feature map height/width (`h + 2*pad - r + 1`).
    pub eh: usize,
    pub ew: usize,
}

impl ConvGeom {
    /// Synaptic operations triggered by ONE input spike in this layer for
    /// ONE output channel: the spike fans out to an RxR window (clipped at
    /// the borders; we count the unclipped worst case like the paper's SOp
    /// accounting).
    pub fn synops_per_spike(&self) -> usize {
        self.r * self.r
    }
}

/// Geometry of the optional dense output layer.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct DenseGeom {
    pub fin: usize,
    pub fout: usize,
    /// Channel count of the conv layer feeding the flattened input — the
    /// CBWS schedule groups dense inputs by source channel.
    pub src_channels: usize,
}
