//! Functional (f32) LIF model — the event-driven twin of the JAX model.
//!
//! Computes exactly what the accelerator computes, the way the accelerator
//! computes it: *scatter* an RxR weight window into the membrane array per
//! input spike (the SPE dataflow of Fig. 5), then threshold + reset by
//! subtraction (Eq. 1/3). Summation order differs from XLA's conv, so
//! membrane potentials may differ by f32 rounding; spike disagreement is
//! bounded by tests against the PJRT golden trace (<0.1% of neurons).
//!
//! This is the simulator's functional path: it lets sim-only flows
//! (ablations, schedule sweeps) run without a PJRT client, and it
//! produces the per-layer spike traces that the timing model consumes.

use super::{LayerWeights, NetworkWeights, SpikeMap};

/// Output of one layer for one timestep.
#[derive(Debug, Clone)]
pub struct LayerOutput {
    pub spikes: SpikeMap,
}

/// Mutable network state (membrane potentials) + weights reference.
pub struct FunctionalNet<'a> {
    pub net: &'a NetworkWeights,
    /// Per-layer flattened membrane potentials.
    vmem: Vec<Vec<f32>>,
}

impl<'a> FunctionalNet<'a> {
    pub fn new(net: &'a NetworkWeights) -> Self {
        let vmem = net.layers.iter().map(|l| match l {
            LayerWeights::Conv { geom, .. } =>
                vec![0.0; geom.cout * geom.eh * geom.ew],
            LayerWeights::Dense { geom, .. } => vec![0.0; geom.fout],
        }).collect();
        Self { net, vmem }
    }

    pub fn reset(&mut self) {
        for v in &mut self.vmem {
            v.iter_mut().for_each(|x| *x = 0.0);
        }
    }

    /// Read-only view of a layer's membrane potentials.
    pub fn vmem(&self, layer: usize) -> &[f32] {
        &self.vmem[layer]
    }

    /// One timestep: input spikes -> per-layer output spikes.
    pub fn step(&mut self, input: &SpikeMap) -> Vec<LayerOutput> {
        let vth = self.net.meta.vth;
        let mut outs: Vec<LayerOutput> = Vec::with_capacity(self.net.layers.len());
        let mut cur = input;
        for (li, layer) in self.net.layers.iter().enumerate() {
            let spikes = match layer {
                LayerWeights::Conv { geom, w } => {
                    conv_step(cur, geom, w, &mut self.vmem[li], vth)
                }
                LayerWeights::Dense { geom, w, b } => {
                    dense_step(cur, geom.fin, geom.fout, w, b,
                               &mut self.vmem[li], vth)
                }
            };
            outs.push(LayerOutput { spikes });
            cur = &outs[li].spikes;
        }
        outs
    }

    /// Run a full frame: T input maps -> per-layer per-timestep traces,
    /// indexed `[t][layer]`.
    pub fn run_frame(&mut self, inputs: &[SpikeMap]) -> Vec<Vec<LayerOutput>> {
        self.reset();
        inputs.iter().map(|s| self.step(s)).collect()
    }

    /// Accumulated output-layer spike counts over a frame (classification
    /// logits / segmentation mask counts).
    pub fn run_frame_counts(&mut self, inputs: &[SpikeMap]) -> Vec<u32> {
        self.reset();
        let last = self.net.layers.len() - 1;
        let (c, h, w) = self.net.layer_output_shape(last);
        let mut counts = vec![0u32; c * h * w];
        for s in inputs {
            let outs = self.step(s);
            for (ch, idx) in outs[last].spikes.iter_events() {
                counts[ch * h * w + idx] += 1;
            }
        }
        counts
    }
}

/// Event-driven conv + LIF for one timestep.
///
/// Hot path of the whole simulator (see DESIGN.md §8 / EXPERIMENTS.md
/// §Perf): events are decoded once, then the scatter runs output-channel
/// -major (the per-channel membrane block stays cache-resident and the
/// (m, c) weight window is 9 contiguous floats), with a branch-free
/// interior fast path for R = 3. Full-pad (APRC) layers are *always*
/// interior — `oy = y + pad - j` spans `y .. y+2 < eh` — so the paper's
/// own convolution modification also makes the simulator fast.
fn conv_step(input: &SpikeMap, geom: &super::ConvGeom, w: &[f32],
             vmem: &mut [f32], vth: f32) -> SpikeMap {
    let (r, pad) = (geom.r, geom.pad);
    let (eh, ew) = (geom.eh, geom.ew);
    let per_out = eh * ew;
    let r2 = r * r;

    // Classify events once (independent of the output channel): interior
    // events carry a precomputed top-left membrane offset; border events
    // keep coordinates for the clipped path. Full-pad R=3 layers are
    // 100% interior by construction.
    let mut interior: Vec<(u32, u32)> = Vec::new();
    let mut border: Vec<(u32, u32, u32)> = Vec::new();
    for (c, idx) in input.iter_events() {
        let y = idx / geom.w;
        let x = idx % geom.w;
        let (iy, ix) = (y + pad, x + pad);
        if r == 3 && iy >= 2 && iy < eh + 1 && ix >= 2 && ix < ew + 1
            && iy - 2 + 2 < eh && ix - 2 + 2 < ew {
            interior.push((c as u32, ((iy - 2) * ew + (ix - 2)) as u32));
        } else {
            border.push((c as u32, y as u32, x as u32));
        }
    }

    // Scatter + threshold per output channel. (A scoped-thread split
    // over channels was tried and reverted: on the 2-core testbed the
    // per-step spawn overhead dominated the small classifier layers and
    // bought <5% on the segmenter — see EXPERIMENTS.md §Perf.)
    let wpc = (per_out + 63) / 64;
    let mut words = vec![0u64; geom.cout * wpc];
    let cin_r2 = geom.cin * r2;
    for m in 0..geom.cout {
        let vm = &mut vmem[m * per_out..(m + 1) * per_out];
        let wm = &w[m * cin_r2..(m + 1) * cin_r2];
        // Branch-free interior scatter: 3 rows x 3 contiguous adds,
        // kernel mirrored in both axes (oy = y+pad-j). Bounds are
        // guaranteed by the interior classification above.
        for &(c, base) in &interior {
            let b = base as usize;
            unsafe {
                let w9 = wm.get_unchecked(
                    c as usize * 9..c as usize * 9 + 9);
                for j in 0..3usize {
                    let row = b + (2 - j) * ew;
                    *vm.get_unchecked_mut(row) += w9[j * 3 + 2];
                    *vm.get_unchecked_mut(row + 1) += w9[j * 3 + 1];
                    *vm.get_unchecked_mut(row + 2) += w9[j * 3];
                }
            }
        }
        for &(c, y, x) in &border {
            let wc = &wm[c as usize * r2..(c as usize + 1) * r2];
            scatter_clipped(vm, wc, y as usize, x as usize, r, pad, eh, ew);
        }
        // Threshold + reset-by-subtraction, packing spikes directly
        // into this channel's words (cheaper than SpikeMap::set).
        let wout = &mut words[m * wpc..(m + 1) * wpc];
        for i in 0..per_out {
            let v = &mut vm[i];
            if *v >= vth {
                *v -= vth;
                wout[i / 64] |= 1u64 << (i % 64);
            }
        }
    }
    SpikeMap::from_words(geom.cout, eh, ew, words)
}

/// Border-clipped scatter (slow path / generic R).
#[inline(never)]
fn scatter_clipped(vm: &mut [f32], wc: &[f32], y: usize, x: usize,
                   r: usize, pad: usize, eh: usize, ew: usize) {
    let (y, x) = (y as isize, x as isize);
    for j in 0..r {
        let oy = y + pad as isize - j as isize;
        if oy < 0 || oy >= eh as isize {
            continue;
        }
        let row = oy as usize * ew;
        for k in 0..r {
            let ox = x + pad as isize - k as isize;
            if ox < 0 || ox >= ew as isize {
                continue;
            }
            vm[row + ox as usize] += wc[j * r + k];
        }
    }
}

/// Event-driven dense + LIF for one timestep.
fn dense_step(input: &SpikeMap, fin: usize, fout: usize, w: &[f32],
              b: &[f32], vmem: &mut [f32], vth: f32) -> SpikeMap {
    // Input is the flattened previous layer viewed as
    // (src_channels, 1, per): linear index = ch*per + i.
    let per = input.h * input.w;
    debug_assert_eq!(input.c * per, fin);
    for (c, idx) in input.iter_events() {
        let f = c * per + idx;
        for k in 0..fout {
            vmem[k] += w[k * fin + f];
        }
    }
    let mut out = SpikeMap::zeros(fout, 1, 1);
    for k in 0..fout {
        vmem[k] += b[k];
        if vmem[k] >= vth {
            vmem[k] -= vth;
            out.set(k, 0);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::snn::{ConvGeom, DenseGeom, WeightsMeta};

    fn tiny_net(pad: usize) -> NetworkWeights {
        // 1 input channel 4x4, one conv layer 2 filters 3x3, all weights
        // 0.25, vth 1.0.
        let r = 3;
        let eh = 4 + 2 * pad - r + 1;
        let meta = WeightsMeta::parse(&format!(r#"{{
            "name": "tiny", "aprc": {}, "pad": {pad}, "vth": 1.0,
            "timesteps": 4, "in_shape": [1, 4, 4],
            "feature_sizes": [[2, {eh}, {eh}]], "dense_out": null,
            "total_floats": 18, "lambdas": [],
            "layers": [{{"kind": "conv", "shape": [2,1,3,3], "offset": 0,
                        "layer": 0, "pad": {pad}}}],
            "blob_fnv1a64": "0"
        }}"#, pad == 2)).unwrap();
        NetworkWeights {
            meta,
            layers: vec![LayerWeights::Conv {
                geom: ConvGeom { cin: 1, cout: 2, r, pad, h: 4, w: 4,
                                 eh, ew: eh },
                w: vec![0.25; 18],
            }],
        }
    }

    #[test]
    fn single_spike_scatters_full_window() {
        let net = tiny_net(2);
        let mut f = FunctionalNet::new(&net);
        let mut input = SpikeMap::zeros(1, 4, 4);
        input.set(0, 5); // (y=1, x=1)
        let out = f.step(&input);
        // 0.25 < vth: no output spikes, but vmem holds the 3x3 window.
        assert_eq!(out[0].spikes.nnz(), 0);
        let touched = f.vmem[0].iter().filter(|&&v| v != 0.0).count();
        assert_eq!(touched, 2 * 9, "full 3x3 window per output channel");
    }

    #[test]
    fn accumulation_reaches_threshold() {
        let net = tiny_net(2);
        let mut f = FunctionalNet::new(&net);
        let mut input = SpikeMap::zeros(1, 4, 4);
        input.set(0, 5);
        // 4 identical steps x 0.25 = 1.0 >= vth at the 4th.
        for _ in 0..3 {
            assert_eq!(f.step(&input)[0].spikes.nnz(), 0);
        }
        let out = f.step(&input);
        assert_eq!(out[0].spikes.nnz(), 2 * 9);
        // Reset by subtraction: vmem back to ~0.
        assert!(f.vmem[0].iter().all(|&v| v.abs() < 1e-6));
    }

    #[test]
    fn border_clipping_same_pad() {
        let net = tiny_net(1);
        let mut f = FunctionalNet::new(&net);
        let mut input = SpikeMap::zeros(1, 4, 4);
        input.set(0, 0); // corner (0,0)
        f.step(&input);
        // Same-pad: corner spike reaches only a 2x2 output window.
        let touched = f.vmem[0].iter().filter(|&&v| v != 0.0).count();
        assert_eq!(touched, 2 * 4);
    }

    #[test]
    fn dense_step_counts() {
        let mut vmem = vec![0.0f32; 2];
        let w = vec![0.6, 0.0, 0.0, 0.6]; // (2,2) identity-ish
        let b = vec![0.0, 0.0];
        let mut input = SpikeMap::zeros(2, 1, 1);
        input.set(0, 0);
        let out = dense_step(&input, 2, 2, &w, &b, &mut vmem, 1.0);
        assert_eq!(out.nnz(), 0);
        let mut input2 = SpikeMap::zeros(2, 1, 1);
        input2.set(0, 0);
        let out2 = dense_step(&input2, 2, 2, &w, &b, &mut vmem, 1.0);
        assert!(out2.get(0, 0) && !out2.get(1, 0));
    }

    #[test]
    fn eq5_proportionality_full_pad() {
        // APRC exactness (Eq. 5): with full padding, the summed membrane
        // update of output channel m equals filter_magnitude_m x #spikes.
        let net = tiny_net(2);
        let mut f = FunctionalNet::new(&net);
        let mut input = SpikeMap::zeros(1, 4, 4);
        for i in [0usize, 3, 7, 9, 15] {
            input.set(0, i);
        }
        f.step(&input);
        let per = 6 * 6;
        let mag = 9.0 * 0.25;
        for m in 0..2 {
            let sum: f32 = f.vmem[0][m * per..(m + 1) * per].iter().sum();
            assert!((sum - mag * 5.0).abs() < 1e-4,
                    "channel {m}: {sum} != {}", mag * 5.0);
        }
    }

    #[test]
    fn eq5_fails_same_pad() {
        // Border clipping breaks exact proportionality for same-pad.
        let net = tiny_net(1);
        let mut f = FunctionalNet::new(&net);
        let mut input = SpikeMap::zeros(1, 4, 4);
        input.set(0, 0);
        f.step(&input);
        let per = 4 * 4;
        let sum: f32 = f.vmem[0][..per].iter().sum();
        assert!(sum < 9.0 * 0.25, "clipped corner must lose taps");
    }

    #[test]
    fn run_frame_counts_shape() {
        let net = tiny_net(2);
        let mut f = FunctionalNet::new(&net);
        let inputs: Vec<SpikeMap> =
            (0..4).map(|_| SpikeMap::zeros(1, 4, 4)).collect();
        let counts = f.run_frame_counts(&inputs);
        assert_eq!(counts.len(), 2 * 36);
        assert!(counts.iter().all(|&c| c == 0));
    }

    #[test]
    fn dense_geom_consistency() {
        let g = DenseGeom { fin: 72, fout: 3, src_channels: 2 };
        assert_eq!(g.fin / g.src_channels, 36);
    }
}
