//! Functional (f32) LIF model — the event-driven twin of the JAX model.
//!
//! Computes exactly what the accelerator computes, the way the accelerator
//! computes it: *scatter* an RxR weight window into the membrane array per
//! input spike (the SPE dataflow of Fig. 5), then threshold + reset by
//! subtraction (Eq. 1/3). Summation order differs from XLA's conv, so
//! membrane potentials may differ by f32 rounding; spike disagreement is
//! bounded by tests against the PJRT golden trace (<0.1% of neurons).
//!
//! This is the simulator's functional path: it lets sim-only flows
//! (ablations, schedule sweeps) run without a PJRT client, and it
//! produces the per-layer spike traces that the timing model consumes.

use super::{LayerWeights, NetworkWeights, SpikeMap, TemporalSpikeMap};

/// Output of one layer for one timestep.
#[derive(Debug, Clone)]
pub struct LayerOutput {
    pub spikes: SpikeMap,
}

/// Mutable network state (membrane potentials) + weights reference,
/// plus the per-step scratch that makes steady-state stepping
/// allocation-free: per-layer output spike maps and the event
/// classification buffers are allocated once here and reused by every
/// [`step_reuse`](Self::step_reuse) call (see PERF.md).
pub struct FunctionalNet<'a> {
    pub net: &'a NetworkWeights,
    /// Per-layer flattened membrane potentials.
    vmem: Vec<Vec<f32>>,
    /// Per-layer output spike maps, overwritten in place every step.
    outs: Vec<SpikeMap>,
    /// Interior-event scratch: (input channel, top-left vmem offset).
    interior: Vec<(u32, u32)>,
    /// Border-event scratch: (input channel, y, x) for the clipped path.
    border: Vec<(u32, u32, u32)>,
    /// Lazily-built scratch for the bit-parallel temporal kernels
    /// ([`run_frame_temporal`](Self::run_frame_temporal)); `None` until
    /// the first temporal frame.
    temporal: Option<TemporalScratch>,
}

/// Reused state of the time-major frame kernels: transposed weight
/// tables (built once per net) plus the per-frame contribution-sort
/// buffers and per-layer temporal output maps (rebuilt only when the
/// frame length T changes). Steady-state temporal frames allocate
/// nothing (asserted by the counting allocator in benches/sim_hotpath).
struct TemporalScratch {
    /// Frame length the output maps are currently sized for.
    t: usize,
    /// Per-layer time-major outputs, fully overwritten every frame.
    outs: Vec<TemporalSpikeMap>,
    /// Per-layer 8-lane transposed weights. Conv: indexed
    /// `(mb*cin*r*r + widx)*8 + lane` with output channel
    /// `m = mb*8 + lane` (lanes past `cout` are zero). Dense: indexed
    /// `f*fout_pad + k` with `fout_pad = ceil(fout/8)*8`.
    wt8: Vec<Vec<f32>>,
    /// Per-layer zero-padded dense bias (`fout_pad` floats; empty for
    /// conv layers).
    b8: Vec<Vec<f32>>,
    /// Counting-sort bucket offsets (conv: `eh*ew*T + 1`; dense: `T+1`).
    offs: Vec<u32>,
    /// Sorted contribution stream (conv: weight index per cell-hit;
    /// dense: input-neuron index per timestep-hit).
    sorted: Vec<u32>,
}

impl<'a> FunctionalNet<'a> {
    pub fn new(net: &'a NetworkWeights) -> Self {
        let mut vmem = Vec::with_capacity(net.layers.len());
        let mut outs = Vec::with_capacity(net.layers.len());
        for l in &net.layers {
            match l {
                LayerWeights::Conv { geom, .. } => {
                    vmem.push(vec![0.0; geom.cout * geom.eh * geom.ew]);
                    outs.push(SpikeMap::zeros(geom.cout, geom.eh,
                                              geom.ew));
                }
                LayerWeights::Dense { geom, .. } => {
                    vmem.push(vec![0.0; geom.fout]);
                    outs.push(SpikeMap::zeros(geom.fout, 1, 1));
                }
            }
        }
        Self { net, vmem, outs, interior: Vec::new(),
               border: Vec::new(), temporal: None }
    }

    pub fn reset(&mut self) {
        for v in &mut self.vmem {
            v.iter_mut().for_each(|x| *x = 0.0);
        }
    }

    /// Read-only view of a layer's membrane potentials.
    pub fn vmem(&self, layer: usize) -> &[f32] {
        &self.vmem[layer]
    }

    /// One timestep into the retained per-layer scratch maps. Performs
    /// zero heap allocations once the event buffers have grown to the
    /// frame's peak activity (typically after the first step). The
    /// returned maps are overwritten by the next call — clone what must
    /// survive (that is what [`step`](Self::step) does).
    pub fn step_reuse(&mut self, input: &SpikeMap) -> &[SpikeMap] {
        let vth = self.net.meta.vth;
        for li in 0..self.net.layers.len() {
            let (done, rest) = self.outs.split_at_mut(li);
            let cur: &SpikeMap = if li == 0 { input } else { &done[li - 1] };
            let out = &mut rest[0];
            match &self.net.layers[li] {
                LayerWeights::Conv { geom, w } => {
                    conv_step_into(cur, geom, w, &mut self.vmem[li], vth,
                                   &mut self.interior, &mut self.border,
                                   out);
                }
                LayerWeights::Dense { geom, wt, b, .. } => {
                    dense_step_into(cur, geom.fin, geom.fout, wt, b,
                                    &mut self.vmem[li], vth, out);
                }
            }
        }
        &self.outs
    }

    /// One timestep: input spikes -> owned per-layer output spikes
    /// (a cloning convenience over [`step_reuse`](Self::step_reuse)).
    pub fn step(&mut self, input: &SpikeMap) -> Vec<LayerOutput> {
        self.step_reuse(input);
        self.outs.iter()
            .map(|s| LayerOutput { spikes: s.clone() })
            .collect()
    }

    /// Run a full frame: T input maps -> per-layer per-timestep traces,
    /// indexed `[t][layer]`.
    pub fn run_frame(&mut self, inputs: &[SpikeMap]) -> Vec<Vec<LayerOutput>> {
        self.reset();
        inputs.iter().map(|s| self.step(s)).collect()
    }

    /// Accumulated output-layer spike counts over a frame (classification
    /// logits / segmentation mask counts).
    pub fn run_frame_counts(&mut self, inputs: &[SpikeMap]) -> Vec<u32> {
        self.reset();
        let last = self.net.layers.len() - 1;
        let (c, h, w) = self.net.layer_output_shape(last);
        let mut counts = vec![0u32; c * h * w];
        for s in inputs {
            let outs = self.step_reuse(s);
            for (ch, idx) in outs[last].iter_events() {
                counts[ch * h * w + idx] += 1;
            }
        }
        counts
    }

    /// Run a full frame through the bit-parallel temporal kernels: one
    /// time-major input, time-major per-layer outputs (into retained
    /// scratch, overwritten by the next call). Bit-identical to running
    /// [`step_reuse`](Self::step_reuse) over the unpacked timesteps —
    /// spikes AND membrane potentials — because each membrane cell
    /// replays the oracle's exact f32 add sequence (see the kernel docs
    /// below and PERF.md). Steady-state calls perform zero heap
    /// allocations once the scratch has grown to the frame's peak
    /// activity.
    pub fn run_frame_temporal(&mut self, input: &TemporalSpikeMap)
                              -> &[TemporalSpikeMap] {
        assert!(input.t > 0, "run_frame_temporal: zero-timestep frame");
        self.reset();
        self.ensure_temporal(input.t);
        let vth = self.net.meta.vth;
        let layers = &self.net.layers;
        let TemporalScratch { outs, wt8, b8, offs, sorted, .. } =
            self.temporal.as_mut().unwrap();
        for (li, layer) in layers.iter().enumerate() {
            let (done, rest) = outs.split_at_mut(li);
            let cur: &TemporalSpikeMap =
                if li == 0 { input } else { &done[li - 1] };
            let out = &mut rest[0];
            match layer {
                LayerWeights::Conv { geom, .. } => {
                    conv_frame_temporal(cur, geom, &wt8[li],
                                        &mut self.vmem[li], vth, offs,
                                        sorted, out);
                }
                LayerWeights::Dense { geom, .. } => {
                    dense_frame_temporal(cur, geom.fin, geom.fout,
                                         &wt8[li], &b8[li],
                                         &mut self.vmem[li], vth, offs,
                                         sorted, out);
                }
            }
        }
        &self.temporal.as_ref().unwrap().outs
    }

    /// Accumulated output-layer spike counts over a temporal frame —
    /// the time-major equivalent of
    /// [`run_frame_counts`](Self::run_frame_counts) (bit-identical
    /// predictions, one popcount per output neuron).
    pub fn run_frame_counts_temporal(&mut self, input: &TemporalSpikeMap)
                                     -> Vec<u32> {
        let last = self.net.layers.len() - 1;
        let (c, h, w) = self.net.layer_output_shape(last);
        let mut counts = vec![0u32; c * h * w];
        let outs = self.run_frame_temporal(input);
        outs[last].counts_into(&mut counts);
        counts
    }

    /// Build the temporal weight tables once and (re)size the per-layer
    /// output maps when the frame length changes.
    fn ensure_temporal(&mut self, t: usize) {
        if self.temporal.is_none() {
            let mut wt8 = Vec::with_capacity(self.net.layers.len());
            let mut b8 = Vec::with_capacity(self.net.layers.len());
            for l in &self.net.layers {
                match l {
                    LayerWeights::Conv { geom, w } => {
                        let cin_r2 = geom.cin * geom.r * geom.r;
                        let nblocks = geom.cout.div_ceil(8);
                        let mut tbl = vec![0.0f32; nblocks * cin_r2 * 8];
                        for m in 0..geom.cout {
                            let (mb, lane) = (m / 8, m % 8);
                            for widx in 0..cin_r2 {
                                tbl[(mb * cin_r2 + widx) * 8 + lane] =
                                    w[m * cin_r2 + widx];
                            }
                        }
                        wt8.push(tbl);
                        b8.push(Vec::new());
                    }
                    LayerWeights::Dense { geom, wt, b, .. } => {
                        let fout_pad = geom.fout.div_ceil(8) * 8;
                        let mut tbl = vec![0.0f32; geom.fin * fout_pad];
                        for f in 0..geom.fin {
                            tbl[f * fout_pad..f * fout_pad + geom.fout]
                                .copy_from_slice(
                                    &wt[f * geom.fout
                                        ..(f + 1) * geom.fout]);
                        }
                        let mut bias = vec![0.0f32; fout_pad];
                        bias[..geom.fout].copy_from_slice(b);
                        wt8.push(tbl);
                        b8.push(bias);
                    }
                }
            }
            self.temporal = Some(TemporalScratch {
                t: 0,
                outs: Vec::new(),
                wt8,
                b8,
                offs: Vec::new(),
                sorted: Vec::new(),
            });
        }
        let s = self.temporal.as_mut().unwrap();
        if s.t != t {
            s.t = t;
            s.outs.clear();
            for li in 0..self.net.layers.len() {
                let (c, h, w) = self.net.layer_output_shape(li);
                s.outs.push(TemporalSpikeMap::zeros(c, h, w, t));
            }
        }
    }
}

/// Event-driven conv + LIF for one timestep, written into `out`.
///
/// Hot path of the whole simulator (see PERF.md): events are decoded
/// once into the caller's reused `interior`/`border` scratch, then the
/// scatter runs output-channel-major (the per-channel membrane block
/// stays cache-resident and the (m, c) weight window is 9 contiguous
/// floats), with a branch-free interior fast path for R = 3. Spikes are
/// packed straight into `out`'s words — no allocation anywhere on this
/// path. Full-pad (APRC) layers are *always* interior — `oy = y + pad
/// - j` spans `y .. y+2 < eh` — so the paper's own convolution
/// modification also makes the simulator fast.
#[allow(clippy::too_many_arguments)]
fn conv_step_into(input: &SpikeMap, geom: &super::ConvGeom, w: &[f32],
                  vmem: &mut [f32], vth: f32,
                  interior: &mut Vec<(u32, u32)>,
                  border: &mut Vec<(u32, u32, u32)>, out: &mut SpikeMap) {
    let (r, pad) = (geom.r, geom.pad);
    let (eh, ew) = (geom.eh, geom.ew);
    let per_out = eh * ew;
    let r2 = r * r;

    // Classify events once (independent of the output channel): interior
    // events carry a precomputed top-left membrane offset; border events
    // keep coordinates for the clipped path. Full-pad R=3 layers are
    // 100% interior by construction. An event is interior iff the whole
    // 3x3 window lands in-bounds: the scatter touches rows iy-2..=iy
    // and columns ix-2..=ix.
    interior.clear();
    border.clear();
    for (c, idx) in input.iter_events() {
        let y = idx / geom.w;
        let x = idx % geom.w;
        let (iy, ix) = (y + pad, x + pad);
        if r == 3 && iy >= 2 && iy < eh && ix >= 2 && ix < ew {
            interior.push((c as u32, ((iy - 2) * ew + (ix - 2)) as u32));
        } else {
            border.push((c as u32, y as u32, x as u32));
        }
    }

    // Scatter + threshold per output channel. (A scoped-thread split
    // over channels was tried and reverted: on the 2-core testbed the
    // per-step spawn overhead dominated the small classifier layers and
    // bought <5% on the segmenter — see PERF.md. The parallel grain
    // that does pay is whole frames: sim::sweep.)
    debug_assert_eq!((out.c, out.h, out.w), (geom.cout, eh, ew));
    out.clear();
    let wpc = out.words_per_channel();
    let words = out.words_mut();
    let cin_r2 = geom.cin * r2;
    for m in 0..geom.cout {
        let vm = &mut vmem[m * per_out..(m + 1) * per_out];
        let wm = &w[m * cin_r2..(m + 1) * cin_r2];
        // Branch-free interior scatter: 3 rows x 3 contiguous adds,
        // kernel mirrored in both axes (oy = y+pad-j). Bounds are
        // guaranteed by the interior classification above.
        for &(c, base) in interior.iter() {
            let b = base as usize;
            unsafe {
                let w9 = wm.get_unchecked(
                    c as usize * 9..c as usize * 9 + 9);
                for j in 0..3usize {
                    let row = b + (2 - j) * ew;
                    *vm.get_unchecked_mut(row) += w9[j * 3 + 2];
                    *vm.get_unchecked_mut(row + 1) += w9[j * 3 + 1];
                    *vm.get_unchecked_mut(row + 2) += w9[j * 3];
                }
            }
        }
        for &(c, y, x) in border.iter() {
            let wc = &wm[c as usize * r2..(c as usize + 1) * r2];
            scatter_clipped(vm, wc, y as usize, x as usize, r, pad, eh, ew);
        }
        // Threshold + reset-by-subtraction, packing spikes directly
        // into this channel's words (cheaper than SpikeMap::set).
        let wout = &mut words[m * wpc..(m + 1) * wpc];
        for i in 0..per_out {
            let v = &mut vm[i];
            if *v >= vth {
                *v -= vth;
                wout[i / 64] |= 1u64 << (i % 64);
            }
        }
    }
}

/// Border-clipped scatter (slow path / generic R).
#[inline(never)]
fn scatter_clipped(vm: &mut [f32], wc: &[f32], y: usize, x: usize,
                   r: usize, pad: usize, eh: usize, ew: usize) {
    let (y, x) = (y as isize, x as isize);
    for j in 0..r {
        let oy = y + pad as isize - j as isize;
        if oy < 0 || oy >= eh as isize {
            continue;
        }
        let row = oy as usize * ew;
        for k in 0..r {
            let ox = x + pad as isize - k as isize;
            if ox < 0 || ox >= ew as isize {
                continue;
            }
            vm[row + ox as usize] += wc[j * r + k];
        }
    }
}

/// Event-driven dense + LIF for one timestep, written into `out`.
///
/// `wt` is the input-major (fin, fout) transpose built at load
/// ([`crate::snn::transpose_dense`]): one event reads `fout` contiguous
/// floats instead of striding the (fout, fin) matrix by `fin`. The
/// per-output add order is unchanged, so results stay bit-identical to
/// the row-major scatter.
fn dense_step_into(input: &SpikeMap, fin: usize, fout: usize, wt: &[f32],
                   b: &[f32], vmem: &mut [f32], vth: f32,
                   out: &mut SpikeMap) {
    // Input is the flattened previous layer viewed as
    // (src_channels, 1, per): linear index = ch*per + i.
    let per = input.h * input.w;
    debug_assert_eq!(input.c * per, fin);
    debug_assert_eq!(wt.len(), fin * fout);
    for (c, idx) in input.iter_events() {
        let f = c * per + idx;
        let row = &wt[f * fout..(f + 1) * fout];
        for (v, &wv) in vmem.iter_mut().zip(row) {
            *v += wv;
        }
    }
    debug_assert_eq!((out.c, out.h, out.w), (fout, 1, 1));
    out.clear();
    for k in 0..fout {
        vmem[k] += b[k];
        if vmem[k] >= vth {
            vmem[k] -= vth;
            out.set(k, 0);
        }
    }
}

/// Stream the membrane contributions of one classification phase
/// (interior or border) of a conv layer's time-major input, in the
/// per-timestep oracle's event order: neurons ascending (channel,
/// linear index), each neuron's set timesteps ascending.
/// `sink(key, widx)` receives `key = cell*T + t` (cell = flattened
/// output position) and the weight index `widx = c*r*r + j*r + k`.
/// Border contributions are pre-clipped, exactly like
/// [`scatter_clipped`].
fn emit_conv_phase(input: &TemporalSpikeMap, geom: &super::ConvGeom,
                   interior_phase: bool,
                   mut sink: impl FnMut(usize, u32)) {
    let (r, pad) = (geom.r, geom.pad);
    let (eh, ew) = (geom.eh, geom.ew);
    let t_total = input.t;
    let wpt = input.words_per_train();
    let words = input.words();
    let per_in = input.h * input.w;
    let r2 = r * r;
    for ch in 0..input.c {
        for idx in 0..per_in {
            let n = ch * per_in + idx;
            let train = &words[n * wpt..(n + 1) * wpt];
            if train.iter().all(|&w| w == 0) {
                continue;
            }
            let y = idx / input.w;
            let x = idx % input.w;
            let (iy, ix) = (y + pad, x + pad);
            let interior =
                r == 3 && iy >= 2 && iy < eh && ix >= 2 && ix < ew;
            if interior != interior_phase {
                continue;
            }
            if interior {
                let base = (iy - 2) * ew + (ix - 2);
                for (tw, &word) in train.iter().enumerate() {
                    let mut rem = word;
                    while rem != 0 {
                        let b = rem.trailing_zeros() as usize;
                        rem &= rem - 1;
                        let tt = tw * 64 + b;
                        for j in 0..3usize {
                            let row = base + (2 - j) * ew;
                            for d in 0..3usize {
                                sink((row + d) * t_total + tt,
                                     (ch * 9 + j * 3 + (2 - d)) as u32);
                            }
                        }
                    }
                }
            } else {
                for (tw, &word) in train.iter().enumerate() {
                    let mut rem = word;
                    while rem != 0 {
                        let b = rem.trailing_zeros() as usize;
                        rem &= rem - 1;
                        let tt = tw * 64 + b;
                        for j in 0..r {
                            if iy < j || iy - j >= eh {
                                continue;
                            }
                            let row = (iy - j) * ew;
                            for k in 0..r {
                                if ix < k || ix - k >= ew {
                                    continue;
                                }
                                sink((row + (ix - k)) * t_total + tt,
                                     (ch * r2 + j * r + k) as u32);
                            }
                        }
                    }
                }
            }
        }
    }
}

/// Bit-parallel temporal conv + LIF over a whole frame.
///
/// The per-timestep oracle ([`conv_step_into`]) walks the event list
/// once per output channel per timestep; this kernel decodes the
/// time-major input once, counting-sorts the cell contributions by
/// (output cell, timestep), and then replays them with the membrane of
/// 8 output channels held in registers across all T timesteps —
/// word-wide over time, SIMD-wide over output channels. f32 addition
/// is non-associative, but a membrane cell is an independent
/// accumulator: the stable sort keys on `cell*T + t` while emission
/// order is (interior neurons ascending, then border neurons
/// ascending, timesteps ascending per neuron), so each bucket replays
/// the oracle's per-cell add sequence exactly — spikes and membranes
/// come out bit-identical (property-tested in
/// tests/proptest_invariants.rs).
#[allow(clippy::too_many_arguments)]
fn conv_frame_temporal(input: &TemporalSpikeMap, geom: &super::ConvGeom,
                       wt8: &[f32], vmem: &mut [f32], vth: f32,
                       offs: &mut Vec<u32>, sorted: &mut Vec<u32>,
                       out: &mut TemporalSpikeMap) {
    let t_total = input.t;
    let per_out = geom.eh * geom.ew;
    let cin_r2 = geom.cin * geom.r * geom.r;
    let cout = geom.cout;
    debug_assert_eq!((out.c, out.h, out.w, out.t),
                     (cout, geom.eh, geom.ew, t_total));

    // Counting sort: count per (cell, timestep) bucket, prefix-sum,
    // then scatter the weight indices in emission order (stable).
    let nb = per_out * t_total;
    offs.clear();
    offs.resize(nb + 1, 0);
    emit_conv_phase(input, geom, true, |key, _| offs[key + 1] += 1);
    emit_conv_phase(input, geom, false, |key, _| offs[key + 1] += 1);
    for i in 1..=nb {
        offs[i] += offs[i - 1];
    }
    let total = offs[nb] as usize;
    sorted.clear();
    sorted.resize(total, 0);
    emit_conv_phase(input, geom, true, |key, widx| {
        sorted[offs[key] as usize] = widx;
        offs[key] += 1;
    });
    emit_conv_phase(input, geom, false, |key, widx| {
        sorted[offs[key] as usize] = widx;
        offs[key] += 1;
    });
    // offs[key] is now the END of bucket `key`; buckets are consumed
    // strictly in key order below via a moving cursor.

    let wpt = out.words_per_train();
    let out_words = out.words_mut();
    let nblocks = cout.div_ceil(8);
    for mb in 0..nblocks {
        let wtb = &wt8[mb * cin_r2 * 8..(mb + 1) * cin_r2 * 8];
        let mut pos = 0usize;
        for s in 0..per_out {
            // 8 output-channel membranes of this cell, register-resident
            // across the whole frame.
            let mut v = [0.0f32; 8];
            for (lane, vv) in v.iter_mut().enumerate() {
                let m = mb * 8 + lane;
                if m < cout {
                    *vv = vmem[m * per_out + s];
                }
            }
            let mut cur = [0u64; 8];
            let base_key = s * t_total;
            for tt in 0..t_total {
                let end = offs[base_key + tt] as usize;
                while pos < end {
                    let wrow = &wtb[sorted[pos] as usize * 8..][..8];
                    pos += 1;
                    for (vv, &wv) in v.iter_mut().zip(wrow) {
                        *vv += wv;
                    }
                }
                // Threshold + reset-by-subtraction, packing the spike
                // bits of 64 timesteps into one word per lane.
                let bit = tt % 64;
                for (lane, vv) in v.iter_mut().enumerate() {
                    if *vv >= vth {
                        *vv -= vth;
                        cur[lane] |= 1u64 << bit;
                    }
                }
                if bit == 63 || tt + 1 == t_total {
                    let tw = tt / 64;
                    for (lane, cv) in cur.iter_mut().enumerate() {
                        let m = mb * 8 + lane;
                        if m < cout {
                            out_words[(m * per_out + s) * wpt + tw] = *cv;
                        }
                        *cv = 0;
                    }
                }
            }
            for (lane, &vv) in v.iter().enumerate() {
                let m = mb * 8 + lane;
                if m < cout {
                    vmem[m * per_out + s] = vv;
                }
            }
        }
    }
}

/// Stream a dense layer's time-major input as (timestep, input neuron)
/// pairs, neurons ascending — [`emit_conv_phase`]'s flat equivalent.
fn emit_dense(input: &TemporalSpikeMap,
              mut sink: impl FnMut(usize, u32)) {
    let wpt = input.words_per_train();
    let words = input.words();
    for f in 0..input.len() {
        let train = &words[f * wpt..(f + 1) * wpt];
        for (tw, &word) in train.iter().enumerate() {
            let mut rem = word;
            while rem != 0 {
                let b = rem.trailing_zeros() as usize;
                rem &= rem - 1;
                sink(tw * 64 + b, f as u32);
            }
        }
    }
}

/// Bit-parallel temporal dense + LIF over a whole frame: active input
/// neurons are bucketed per timestep (stable — ascending neuron order
/// within a step, matching the oracle's event order), then replayed
/// with 8 output membranes register-resident across all T timesteps.
/// Bit-identical to [`dense_step_into`] per timestep, bias and all.
#[allow(clippy::too_many_arguments)]
fn dense_frame_temporal(input: &TemporalSpikeMap, fin: usize,
                        fout: usize, wt8: &[f32], b8: &[f32],
                        vmem: &mut [f32], vth: f32,
                        offs: &mut Vec<u32>, sorted: &mut Vec<u32>,
                        out: &mut TemporalSpikeMap) {
    let t_total = input.t;
    debug_assert_eq!(input.len(), fin);
    let fout_pad = fout.div_ceil(8) * 8;
    debug_assert_eq!(wt8.len(), fin * fout_pad);
    debug_assert_eq!((out.c, out.t), (fout, t_total));

    offs.clear();
    offs.resize(t_total + 1, 0);
    emit_dense(input, |tt, _| offs[tt + 1] += 1);
    for i in 1..=t_total {
        offs[i] += offs[i - 1];
    }
    let total = offs[t_total] as usize;
    sorted.clear();
    sorted.resize(total, 0);
    emit_dense(input, |tt, f| {
        sorted[offs[tt] as usize] = f;
        offs[tt] += 1;
    });

    let wpt = out.words_per_train();
    let out_words = out.words_mut();
    for kb in 0..fout_pad / 8 {
        let bb = &b8[kb * 8..kb * 8 + 8];
        let mut v = [0.0f32; 8];
        for (lane, vv) in v.iter_mut().enumerate() {
            let k = kb * 8 + lane;
            if k < fout {
                *vv = vmem[k];
            }
        }
        let mut cur = [0u64; 8];
        let mut pos = 0usize;
        for tt in 0..t_total {
            let end = offs[tt] as usize;
            while pos < end {
                let f = sorted[pos] as usize;
                pos += 1;
                let row = &wt8[f * fout_pad + kb * 8..][..8];
                for (vv, &wv) in v.iter_mut().zip(row) {
                    *vv += wv;
                }
            }
            for (vv, &bv) in v.iter_mut().zip(bb) {
                *vv += bv;
            }
            let bit = tt % 64;
            for (lane, vv) in v.iter_mut().enumerate() {
                if *vv >= vth {
                    *vv -= vth;
                    cur[lane] |= 1u64 << bit;
                }
            }
            if bit == 63 || tt + 1 == t_total {
                let tw = tt / 64;
                for (lane, cv) in cur.iter_mut().enumerate() {
                    let k = kb * 8 + lane;
                    if k < fout {
                        out_words[k * wpt + tw] = *cv;
                    }
                    *cv = 0;
                }
            }
        }
        for (lane, &vv) in v.iter().enumerate() {
            let k = kb * 8 + lane;
            if k < fout {
                vmem[k] = vv;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::snn::{ConvGeom, DenseGeom, WeightsMeta};

    fn tiny_net(pad: usize) -> NetworkWeights {
        // 1 input channel 4x4, one conv layer 2 filters 3x3, all weights
        // 0.25, vth 1.0.
        let r = 3;
        let eh = 4 + 2 * pad - r + 1;
        let meta = WeightsMeta::parse(&format!(r#"{{
            "name": "tiny", "aprc": {}, "pad": {pad}, "vth": 1.0,
            "timesteps": 4, "in_shape": [1, 4, 4],
            "feature_sizes": [[2, {eh}, {eh}]], "dense_out": null,
            "total_floats": 18, "lambdas": [],
            "layers": [{{"kind": "conv", "shape": [2,1,3,3], "offset": 0,
                        "layer": 0, "pad": {pad}}}],
            "blob_fnv1a64": "0"
        }}"#, pad == 2)).unwrap();
        NetworkWeights {
            meta,
            layers: vec![LayerWeights::Conv {
                geom: ConvGeom { cin: 1, cout: 2, r, pad, h: 4, w: 4,
                                 eh, ew: eh },
                w: vec![0.25; 18],
            }],
        }
    }

    #[test]
    fn single_spike_scatters_full_window() {
        let net = tiny_net(2);
        let mut f = FunctionalNet::new(&net);
        let mut input = SpikeMap::zeros(1, 4, 4);
        input.set(0, 5); // (y=1, x=1)
        let out = f.step(&input);
        // 0.25 < vth: no output spikes, but vmem holds the 3x3 window.
        assert_eq!(out[0].spikes.nnz(), 0);
        let touched = f.vmem[0].iter().filter(|&&v| v != 0.0).count();
        assert_eq!(touched, 2 * 9, "full 3x3 window per output channel");
    }

    #[test]
    fn accumulation_reaches_threshold() {
        let net = tiny_net(2);
        let mut f = FunctionalNet::new(&net);
        let mut input = SpikeMap::zeros(1, 4, 4);
        input.set(0, 5);
        // 4 identical steps x 0.25 = 1.0 >= vth at the 4th.
        for _ in 0..3 {
            assert_eq!(f.step(&input)[0].spikes.nnz(), 0);
        }
        let out = f.step(&input);
        assert_eq!(out[0].spikes.nnz(), 2 * 9);
        // Reset by subtraction: vmem back to ~0.
        assert!(f.vmem[0].iter().all(|&v| v.abs() < 1e-6));
    }

    #[test]
    fn border_clipping_same_pad() {
        let net = tiny_net(1);
        let mut f = FunctionalNet::new(&net);
        let mut input = SpikeMap::zeros(1, 4, 4);
        input.set(0, 0); // corner (0,0)
        f.step(&input);
        // Same-pad: corner spike reaches only a 2x2 output window.
        let touched = f.vmem[0].iter().filter(|&&v| v != 0.0).count();
        assert_eq!(touched, 2 * 4);
    }

    #[test]
    fn dense_step_counts() {
        let mut vmem = vec![0.0f32; 2];
        let w = vec![0.6, 0.0, 0.0, 0.6]; // (2,2) identity-ish
        let wt = crate::snn::transpose_dense(&w, 2, 2);
        let b = vec![0.0, 0.0];
        let mut out = SpikeMap::zeros(2, 1, 1);
        let mut input = SpikeMap::zeros(2, 1, 1);
        input.set(0, 0);
        dense_step_into(&input, 2, 2, &wt, &b, &mut vmem, 1.0, &mut out);
        assert_eq!(out.nnz(), 0);
        dense_step_into(&input, 2, 2, &wt, &b, &mut vmem, 1.0, &mut out);
        assert!(out.get(0, 0) && !out.get(1, 0));
    }

    #[test]
    fn interior_classification_matches_clipped_scatter() {
        // Same-pad layer: every event through the real step must leave
        // vmem identical to routing *all* events through the clipped
        // (slow-path) scatter. vth is high so thresholding never fires
        // and the accumulated membrane is directly comparable.
        let r = 3;
        let pad = 1;
        let (h, w) = (5usize, 6usize);
        let eh = h + 2 * pad - r + 1;
        let ew = w + 2 * pad - r + 1;
        let meta = WeightsMeta::parse(&format!(r#"{{
            "name": "clip", "aprc": false, "pad": {pad}, "vth": 1000.0,
            "timesteps": 1, "in_shape": [2, {h}, {w}],
            "feature_sizes": [[3, {eh}, {ew}]], "dense_out": null,
            "total_floats": 54, "lambdas": [],
            "layers": [{{"kind": "conv", "shape": [3,2,3,3], "offset": 0,
                        "layer": 0, "pad": {pad}}}],
            "blob_fnv1a64": "0"
        }}"#)).unwrap();
        let weights: Vec<f32> =
            (0..3 * 2 * 9).map(|i| 0.01 + 0.003 * i as f32).collect();
        let net = NetworkWeights {
            meta,
            layers: vec![LayerWeights::Conv {
                geom: ConvGeom { cin: 2, cout: 3, r, pad, h, w, eh, ew },
                w: weights.clone(),
            }],
        };
        // Every corner, every edge midpoint, plus interior spikes.
        let mut input = SpikeMap::zeros(2, h, w);
        for &(c, y, x) in &[(0, 0, 0), (0, 0, w - 1), (0, h - 1, 0),
                            (1, h - 1, w - 1), (1, 0, 3), (1, 2, 0),
                            (0, 2, 3), (1, 3, 4)] {
            input.set(c, y * w + x);
        }
        let mut f = FunctionalNet::new(&net);
        f.step_reuse(&input);

        // Reference: the clipped scatter for every event.
        let per_out = eh * ew;
        let mut want = vec![0.0f32; 3 * per_out];
        for m in 0..3usize {
            let vm = &mut want[m * per_out..(m + 1) * per_out];
            for (c, idx) in input.iter_events() {
                let wc = &weights[m * 2 * 9 + c * 9..m * 2 * 9 + (c + 1) * 9];
                scatter_clipped(vm, wc, idx / w, idx % w, r, pad, eh, ew);
            }
        }
        // Same adds in a different event order: tolerance, not equality.
        for (got, want) in f.vmem(0).iter().zip(&want) {
            assert!((got - want).abs() < 1e-5,
                    "interior/border split diverged: {got} vs {want}");
        }
    }

    #[test]
    fn scratch_reuse_step_matches_fresh_instance() {
        // Stepping a reused instance (after reset) must reproduce a
        // fresh instance's trace bit-for-bit — the scratch carries no
        // state across frames.
        let net = tiny_net(1);
        let inputs: Vec<SpikeMap> = (0..5).map(|t| {
            let mut m = SpikeMap::zeros(1, 4, 4);
            for i in 0..16 {
                if (i + t) % 3 == 0 {
                    m.set(0, i);
                }
            }
            m
        }).collect();
        let mut reused = FunctionalNet::new(&net);
        reused.run_frame(&inputs); // dirty the scratch with frame 0
        let trace_reused = reused.run_frame(&inputs);
        let mut fresh = FunctionalNet::new(&net);
        let trace_fresh = fresh.run_frame(&inputs);
        for (a, b) in trace_reused.iter().flatten()
            .zip(trace_fresh.iter().flatten()) {
            assert_eq!(a.spikes, b.spikes);
        }
        assert_eq!(reused.vmem(0), fresh.vmem(0));
    }

    #[test]
    fn eq5_proportionality_full_pad() {
        // APRC exactness (Eq. 5): with full padding, the summed membrane
        // update of output channel m equals filter_magnitude_m x #spikes.
        let net = tiny_net(2);
        let mut f = FunctionalNet::new(&net);
        let mut input = SpikeMap::zeros(1, 4, 4);
        for i in [0usize, 3, 7, 9, 15] {
            input.set(0, i);
        }
        f.step(&input);
        let per = 6 * 6;
        let mag = 9.0 * 0.25;
        for m in 0..2 {
            let sum: f32 = f.vmem[0][m * per..(m + 1) * per].iter().sum();
            assert!((sum - mag * 5.0).abs() < 1e-4,
                    "channel {m}: {sum} != {}", mag * 5.0);
        }
    }

    #[test]
    fn eq5_fails_same_pad() {
        // Border clipping breaks exact proportionality for same-pad.
        let net = tiny_net(1);
        let mut f = FunctionalNet::new(&net);
        let mut input = SpikeMap::zeros(1, 4, 4);
        input.set(0, 0);
        f.step(&input);
        let per = 4 * 4;
        let sum: f32 = f.vmem[0][..per].iter().sum();
        assert!(sum < 9.0 * 0.25, "clipped corner must lose taps");
    }

    #[test]
    fn run_frame_counts_shape() {
        let net = tiny_net(2);
        let mut f = FunctionalNet::new(&net);
        let inputs: Vec<SpikeMap> =
            (0..4).map(|_| SpikeMap::zeros(1, 4, 4)).collect();
        let counts = f.run_frame_counts(&inputs);
        assert_eq!(counts.len(), 2 * 36);
        assert!(counts.iter().all(|&c| c == 0));
    }

    #[test]
    fn dense_geom_consistency() {
        let g = DenseGeom { fin: 72, fout: 3, src_channels: 2 };
        assert_eq!(g.fin / g.src_channels, 36);
    }

    /// conv(2->3) -> conv(3->2) -> dense(->4) with varied deterministic
    /// weights — exercises interior + border events, multi-channel
    /// weight blocks, a non-multiple-of-8 lane count and the dense
    /// bias, at both paddings.
    fn chain_net(pad: usize) -> NetworkWeights {
        let (h, w) = (5usize, 6usize);
        let eh1 = h + 2 * pad - 2;
        let ew1 = w + 2 * pad - 2;
        let eh2 = eh1 + 2 * pad - 2;
        let ew2 = ew1 + 2 * pad - 2;
        let fin = 2 * eh2 * ew2;
        let total = 112 + 4 * fin;
        let meta = WeightsMeta::parse(&format!(r#"{{
            "name": "chain", "aprc": {}, "pad": {pad}, "vth": 0.4,
            "timesteps": 8, "in_shape": [2, {h}, {w}],
            "feature_sizes": [[3, {eh1}, {ew1}], [2, {eh2}, {ew2}]],
            "dense_out": 4, "total_floats": {total}, "lambdas": [],
            "layers": [
                {{"kind": "conv", "shape": [3,2,3,3], "offset": 0,
                  "layer": 0, "pad": {pad}}},
                {{"kind": "conv", "shape": [2,3,3,3], "offset": 54,
                  "layer": 1, "pad": {pad}}},
                {{"kind": "dense_w", "shape": [4, {fin}],
                  "offset": 108, "layer": 2}},
                {{"kind": "dense_b", "shape": [4],
                  "offset": {}, "layer": 2}}
            ],
            "blob_fnv1a64": "0"
        }}"#, pad == 2, 108 + 4 * fin)).unwrap();
        let floats: Vec<f32> = (0..total)
            .map(|i| ((i * 37 + 11) % 101) as f32 / 101.0 * 0.6 - 0.25)
            .collect();
        NetworkWeights::assemble(meta, &floats).unwrap()
    }

    fn dense_input_pattern(c: usize, h: usize, w: usize, t: usize,
                           salt: usize) -> Vec<SpikeMap> {
        (0..t).map(|tt| {
            let mut m = SpikeMap::zeros(c, h, w);
            for ch in 0..c {
                for i in 0..h * w {
                    if (ch * 31 + i * 7 + tt * 13 + salt) % 3 == 0 {
                        m.set(ch, i);
                    }
                }
            }
            m
        }).collect()
    }

    #[test]
    fn temporal_frame_matches_per_timestep_oracle() {
        // The acceptance invariant of the temporal kernels: output
        // spikes AND membrane potentials bit-identical to the
        // per-timestep oracle, at both paddings and at T values that
        // straddle the 64-bit word (the random-net sweep lives in
        // tests/proptest_invariants.rs).
        for pad in [1usize, 2] {
            let net = chain_net(pad);
            for t in [1usize, 5, 63, 64, 65, 128] {
                let steps = dense_input_pattern(2, 5, 6, t, pad);
                let temporal = TemporalSpikeMap::from_steps(&steps);
                let mut oracle = FunctionalNet::new(&net);
                let want = oracle.run_frame(&steps);
                let mut f = FunctionalNet::new(&net);
                let got: Vec<Vec<SpikeMap>> =
                    f.run_frame_temporal(&temporal).iter()
                        .map(|m| m.to_steps()).collect();
                for l in 0..net.layers.len() {
                    for tt in 0..t {
                        assert_eq!(got[l][tt], want[tt][l].spikes,
                                   "pad={pad} T={t} layer={l} t={tt}");
                    }
                    assert_eq!(f.vmem(l), oracle.vmem(l),
                               "pad={pad} T={t} layer={l} vmem");
                }
            }
        }
    }

    #[test]
    fn temporal_counts_match_oracle_counts() {
        let net = chain_net(1);
        let steps = dense_input_pattern(2, 5, 6, 64, 3);
        let temporal = TemporalSpikeMap::from_steps(&steps);
        let mut a = FunctionalNet::new(&net);
        let mut b = FunctionalNet::new(&net);
        assert_eq!(b.run_frame_counts_temporal(&temporal),
                   a.run_frame_counts(&steps));
    }

    #[test]
    fn temporal_scratch_reuse_and_t_change() {
        // Reusing one instance across frames — including a change of T,
        // which resizes the retained output maps — must match fresh
        // instances bit-for-bit.
        let net = chain_net(2);
        let frames: Vec<Vec<SpikeMap>> = (0..3).map(|salt| {
            dense_input_pattern(2, 5, 6, [64, 7, 65][salt], salt)
        }).collect();
        let mut reused = FunctionalNet::new(&net);
        for steps in &frames {
            let temporal = TemporalSpikeMap::from_steps(steps);
            let got: Vec<TemporalSpikeMap> =
                reused.run_frame_temporal(&temporal).to_vec();
            let mut fresh = FunctionalNet::new(&net);
            assert_eq!(got, fresh.run_frame_temporal(&temporal));
        }
    }
}
