//! Request-level APRC: predict a request's *relative* workload at
//! admission, before any worker touches it.
//!
//! The paper's APRC (§III-B) predicts per-channel workload offline so
//! CBWS can balance the SPEs; this module carries the same idea one
//! level up, to the serving tier. The input-layer event count of a
//! request is knowable *exactly* at the gateway — a pre-encoded spike
//! payload is popcounted ([`crate::snn::nnz_packed`]), a pixel payload
//! telescopes through the phased encoder's closed form (a cached
//! [`crate::snn::phased_events_per_level`] table) — and under APRC every
//! downstream layer's work scales with the events its predecessor
//! emits, so a single per-event gain suffices for *relative* ranking.
//! That constant gain cancels in the normalisation below, which is why
//! the model needs only the [`AprcPredictor`]'s layer-0 profile (the
//! offline calibration the pipeline already owns) to fix its scale.
//!
//! Costs are dimensionless "cost units", normalised so that a frame at
//! the profiled mean input density costs [`NOMINAL_FRAME_COST`]. That
//! gives cost-denominated queue caps a sane default (`queue_cap x
//! NOMINAL_FRAME_COST` admits the same *nominal* traffic as the
//! count-denominated cap, but sheds dense bursts proportionally
//! earlier) and makes the predicted-vs-actual calibration error a
//! scale-free percentage.

use crate::schedule::AprcPredictor;
use crate::snn::{nnz_packed, phased_events_per_level};

use super::worker::FramePayload;

/// Cost of a frame at the profiled mean input density — the unit every
/// cost-denominated knob (queue cost cap, shed accounting, metrics) is
/// expressed in.
pub const NOMINAL_FRAME_COST: u64 = 10_000;

/// Per-request workload predictor, built once per model (alongside the
/// APRC predictor in `SharedPipeline::build`) and shared by every
/// submission path.
#[derive(Debug, Clone)]
pub struct RequestCostModel {
    h: usize,
    w: usize,
    /// `SpikeMap` packing stride of the served shape.
    wpc: usize,
    /// Spikes `encode_phased_u8` emits per pixel level over the run's
    /// timesteps (the exact pixel-path event count, table-driven).
    px_events: [u64; 256],
    /// Cost units per input event, fixed by the layer-0 profile.
    per_event: f64,
    /// Per-frame floor: even a silent frame costs queue slots, scan
    /// words and scheduling work.
    base: f64,
}

impl RequestCostModel {
    /// Calibrate against the model's offline input profile: the
    /// predictor's layer-0 rates are the dataset's mean per-channel
    /// spike fractions, so `sum(rates) * h * w * timesteps` is the
    /// expected event count of a nominal frame.
    pub fn new(c: usize, h: usize, w: usize, timesteps: usize,
               predictor: &AprcPredictor) -> Self {
        let rates = predictor.layer(0);
        debug_assert_eq!(rates.len(), c);
        let nominal_events: f64 = rates.iter().sum::<f64>()
            * (h * w * timesteps) as f64;
        let base = NOMINAL_FRAME_COST as f64 / 16.0;
        let per_event =
            (NOMINAL_FRAME_COST as f64 - base) / nominal_events.max(1.0);
        Self {
            h,
            w,
            wpc: (h * w).div_ceil(64),
            px_events: phased_events_per_level(timesteps),
            per_event,
            base,
        }
    }

    /// Exact input-layer event count of a payload (what the encoder /
    /// spike decoder will hand layer 0). Never panics, even on a
    /// malformed payload — shape errors are the validator's job, and
    /// prediction runs before (or without) validation.
    pub fn input_events(&self, payload: &FramePayload) -> u64 {
        match payload {
            FramePayload::Pixels(px) => {
                px.iter().map(|&v| self.px_events[v as usize]).sum()
            }
            FramePayload::Spikes { words, .. } => {
                nnz_packed(words, self.wpc, self.h * self.w)
            }
        }
    }

    /// Predicted cost in cost units (>= 1): `base + events x
    /// per_event`, i.e. affine in the exact input event count with the
    /// scale fixed by the APRC layer-0 profile.
    pub fn predict(&self, payload: &FramePayload) -> u64 {
        let ev = self.input_events(payload) as f64;
        (self.base + ev * self.per_event).round().max(1.0) as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::snn::{encode_phased_u8, ConvGeom, LayerWeights,
                     NetworkWeights, WeightsMeta};

    const SIDE: usize = 12;
    const T: usize = 8;

    fn tiny_net() -> NetworkWeights {
        let meta = WeightsMeta::parse(&format!(
            r#"{{
            "name": "t", "aprc": true, "pad": 2, "vth": 1.0,
            "timesteps": {T}, "in_shape": [1, {SIDE}, {SIDE}],
            "feature_sizes": [[2, {}, {}]], "dense_out": null,
            "total_floats": 0, "lambdas": [], "layers": [],
            "blob_fnv1a64": "0"
        }}"#, SIDE + 2, SIDE + 2)).unwrap();
        NetworkWeights {
            meta,
            layers: vec![LayerWeights::Conv {
                geom: ConvGeom { cin: 1, cout: 2, r: 3, pad: 2,
                                 h: SIDE, w: SIDE,
                                 eh: SIDE + 2, ew: SIDE + 2 },
                w: vec![0.1f32; 2 * 9],
            }],
        }
    }

    fn model() -> RequestCostModel {
        let net = tiny_net();
        let predictor = AprcPredictor::from_network(&net, &[0.25]);
        RequestCostModel::new(1, SIDE, SIDE, T, &predictor)
    }

    #[test]
    fn pixel_events_match_encoder() {
        let m = model();
        let px: Vec<u8> = (0..SIDE * SIDE)
            .map(|i| (i * 31 % 256) as u8)
            .collect();
        let maps = encode_phased_u8(&px, 1, SIDE, SIDE, T);
        let emitted: u64 = maps.iter().map(|s| s.nnz() as u64).sum();
        assert_eq!(
            m.input_events(&FramePayload::Pixels(px.clone())), emitted);
        // The matching spike payload predicts the identical cost: the
        // two wire encodings of one frame are interchangeable.
        let mut words = Vec::new();
        for map in &maps {
            words.extend_from_slice(map.channel_words(0));
        }
        let spikes = FramePayload::Spikes { timesteps: T, words };
        assert_eq!(m.input_events(&spikes), emitted);
        assert_eq!(m.predict(&spikes),
                   m.predict(&FramePayload::Pixels(px)));
    }

    #[test]
    fn cost_is_monotone_in_density_with_a_floor() {
        let m = model();
        let silent = m.predict(
            &FramePayload::Pixels(vec![0u8; SIDE * SIDE]));
        let mid = m.predict(
            &FramePayload::Pixels(vec![128u8; SIDE * SIDE]));
        let dense = m.predict(
            &FramePayload::Pixels(vec![255u8; SIDE * SIDE]));
        assert!(silent >= 1, "even a silent frame costs something");
        assert!(silent < mid && mid < dense,
                "{silent} < {mid} < {dense} violated");
    }

    #[test]
    fn nominal_density_frame_costs_about_nominal() {
        // The predictor was profiled at rate 0.25; a frame whose
        // pixels emit ~0.25*T spikes each should land near
        // NOMINAL_FRAME_COST. Pixel value 64/255 -> floor(T/4)/T = 2/8.
        let m = model();
        let cost =
            m.predict(&FramePayload::Pixels(vec![64u8; SIDE * SIDE]));
        let lo = NOMINAL_FRAME_COST * 9 / 10;
        let hi = NOMINAL_FRAME_COST * 11 / 10;
        assert!((lo..=hi).contains(&cost),
                "nominal frame cost {cost} outside [{lo}, {hi}]");
    }

    #[test]
    fn malformed_payloads_predict_without_panicking() {
        let m = model();
        let _ = m.predict(&FramePayload::Pixels(vec![7u8; 5]));
        let _ = m.predict(&FramePayload::Spikes {
            timesteps: T,
            words: vec![!0u64; 3], // not a multiple of the stride
        });
    }
}
