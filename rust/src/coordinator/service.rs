//! The service: a shared bounded work queue feeding a pull-based worker
//! pool (std-only; the build is offline).
//!
//! `submit` pushes into the bounded queue (blocking on backpressure;
//! `try_submit` reports `Full` instead), workers pull batches as they
//! free up, and every worker outcome — response or failure — flows back
//! over one event channel so `collect` can always make progress or
//! return an error, never hang. A legacy round-robin whole-batch
//! dispatcher ([`DispatchMode::RoundRobinBatch`]) is kept as the
//! baseline the work-queue mode is measured against.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, ensure, Result};

use super::cost::RequestCostModel;
use super::queue::{BoundedQueue, ConsumerGuard, Priority, QueueStats,
                   SubmitError};
use super::stats::{ServingReport, Stats};
use super::worker::{worker_loop, FramePayload, ReqTrace, Request,
                    Response, SharedPipeline, WorkSource,
                    WorkerConfig, WorkerEvent};
use crate::snn::NetKind;

/// How batches reach the workers.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum DispatchMode {
    /// Workers pull from the shared queue the moment they free up
    /// (work-conserving; the default). Batches form FIFO by request
    /// *count* — the comparison baseline for the cost-aware mode.
    #[default]
    WorkQueue,
    /// Cost-aware pull: workers wait out the `batch_wait` grouping
    /// window, then assemble their fair share of the queued
    /// *predicted cost* with an LPT-style greedy fill
    /// ([`BoundedQueue::pop_batch_cost`]), and admission sheds by
    /// cost units instead of request count — the request-level APRC
    /// path.
    CostAware,
    /// A dispatcher thread forms whole batches and deals them
    /// round-robin to per-worker channels — the pre-rebuild behaviour,
    /// kept as the head-of-line-blocking baseline.
    RoundRobinBatch,
}

impl DispatchMode {
    pub fn parse(s: &str) -> Option<Self> {
        Some(match s {
            "queue" | "workqueue" | "pull" | "fifo" => {
                DispatchMode::WorkQueue
            }
            "cost" | "cost_aware" | "lpt" => DispatchMode::CostAware,
            "rr" | "round_robin_batch" | "batch" => {
                DispatchMode::RoundRobinBatch
            }
            _ => return None,
        })
    }

    /// Canonical short name (CLI spelling, metrics `dispatch` label).
    pub fn as_str(self) -> &'static str {
        match self {
            DispatchMode::WorkQueue => "queue",
            DispatchMode::CostAware => "cost",
            DispatchMode::RoundRobinBatch => "rr",
        }
    }
}

/// Coordinator-level knobs.
#[derive(Debug, Clone)]
pub struct ServiceConfig {
    /// Initial worker-pool size (threads spawned at start).
    pub workers: usize,
    /// Upper bound the pool may be scaled to at runtime
    /// ([`Service::scale_to`]); slots above `workers` start empty. 0
    /// (the default) means "same as `workers`" — a fixed pool.
    /// Shared-queue dispatch modes only; the legacy round-robin
    /// dispatcher keeps its fixed pool.
    pub workers_max: usize,
    /// Max frames a worker pulls (or the legacy dispatcher groups) at
    /// once.
    pub batch_max: usize,
    /// Bounded submission-queue capacity — the backpressure threshold
    /// in request count.
    pub queue_cap: usize,
    /// Batch grouping window: how long the legacy dispatcher — or a
    /// cost-aware pull — waits for a batch to fill after its first
    /// frame arrives (CLI: `--batch-wait-ms`).
    pub batch_wait: Duration,
    pub dispatch: DispatchMode,
    /// Admission cap in predicted-cost units. `None` defaults to
    /// `queue_cap x NOMINAL_FRAME_COST` under
    /// [`DispatchMode::CostAware`] (same nominal traffic as the count
    /// cap, but dense bursts shed proportionally earlier) and to
    /// uncapped otherwise, keeping the baselines' admission behaviour
    /// untouched. `Some(0)` explicitly disables the cost cap (the
    /// metrics convention: 0 = uncapped).
    pub cost_cap: Option<u64>,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        Self {
            workers: 2,
            workers_max: 0,
            batch_max: 8,
            queue_cap: 256,
            batch_wait: Duration::from_millis(2),
            dispatch: DispatchMode::WorkQueue,
            cost_cap: None,
        }
    }
}

/// What one frame of the served network looks like — the contract a
/// network front end validates payloads against *before* submitting,
/// so a malformed request is refused per-request instead of erroring
/// inside a worker.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FrameSpec {
    pub kind: NetKind,
    /// Input shape (channels, height, width).
    pub c: usize,
    pub h: usize,
    pub w: usize,
    /// Timesteps the workers run (config override or weights meta).
    pub timesteps: usize,
}

impl FrameSpec {
    /// Expected byte length of a raw-pixel payload.
    pub fn pixels_len(&self) -> usize {
        self.c * self.h * self.w
    }

    /// Spike words per channel per timestep (the `SpikeMap` stride).
    pub fn words_per_channel(&self) -> usize {
        (self.h * self.w).div_ceil(64)
    }

    /// Expected u64 word count of a pre-encoded spike payload.
    pub fn spike_words_len(&self) -> usize {
        self.timesteps * self.c * self.words_per_channel()
    }

    /// Check a payload against this spec; the error string is suitable
    /// for a wire-level `BAD_REQUEST` detail.
    pub fn validate(&self, payload: &FramePayload)
                    -> std::result::Result<(), String> {
        match payload {
            FramePayload::Pixels(px) => {
                if px.len() == self.pixels_len() {
                    Ok(())
                } else {
                    Err(format!("got {} pixels, expected {} ({}x{}x{})",
                                px.len(), self.pixels_len(), self.c,
                                self.h, self.w))
                }
            }
            FramePayload::Spikes { timesteps, words } => {
                if *timesteps != self.timesteps {
                    Err(format!("spike payload spans {timesteps} \
                                 timesteps, the pipeline runs {}",
                                self.timesteps))
                } else if words.len() != self.spike_words_len() {
                    Err(format!("got {} spike words, expected {}",
                                words.len(), self.spike_words_len()))
                } else {
                    Ok(())
                }
            }
        }
    }
}

/// A cheap, cloneable, `Sync` submission handle onto a running
/// [`Service`] — what the network gateway hands to each connection
/// thread. Submissions flow into the same bounded queue (each tagged
/// with its predicted cost at admission); collection stays with
/// whoever holds the worker event stream.
#[derive(Clone)]
pub struct ServiceHandle {
    queue: Arc<BoundedQueue<Request>>,
    cost_model: Arc<RequestCostModel>,
    spec: FrameSpec,
}

impl ServiceHandle {
    pub fn spec(&self) -> &FrameSpec {
        &self.spec
    }

    /// Predicted cost of a payload in cost units — what admission
    /// would tag it with. Exposed so callers (the gateway) can account
    /// admitted/shed traffic in cost units without predicting twice.
    pub fn predict_cost(&self, payload: &FramePayload) -> u64 {
        self.cost_model.predict(payload)
    }

    /// Non-blocking submit; `SubmitError::Full` is the backpressure
    /// signal (map it to `BUSY` on the wire — shed, never hang).
    pub fn try_submit(&self, id: u64, payload: FramePayload)
                      -> std::result::Result<(), SubmitError> {
        let cost = self.cost_model.predict(&payload);
        self.try_submit_cost(id, payload, cost)
    }

    /// [`try_submit`](Self::try_submit) with a pre-computed cost (from
    /// [`predict_cost`](Self::predict_cost)).
    pub fn try_submit_cost(&self, id: u64, payload: FramePayload,
                           cost: u64)
                           -> std::result::Result<(), SubmitError> {
        self.try_submit_cost_traced(id, payload, cost, None)
    }

    /// [`try_submit_cost`](Self::try_submit_cost) carrying span-
    /// timeline identity: the worker that pulls the request records
    /// its queue/batch/compute spans against it. `None` (every caller
    /// with tracing off) adds one `Option` discriminant — nothing
    /// else.
    pub fn try_submit_cost_traced(&self, id: u64, payload: FramePayload,
                                  cost: u64, trace: Option<ReqTrace>)
                                  -> std::result::Result<(), SubmitError>
    {
        self.try_submit_full(id, payload, cost, trace,
                             Priority::Normal, None)
    }

    /// The full-form non-blocking submit the gateway funnels into:
    /// pre-computed cost, optional span-timeline identity, an explicit
    /// [`Priority`] lane, and the degradation policy's reduced-T
    /// override (`None` = full fidelity).
    pub fn try_submit_full(&self, id: u64, payload: FramePayload,
                           cost: u64, trace: Option<ReqTrace>,
                           pri: Priority, timesteps: Option<usize>)
                           -> std::result::Result<(), SubmitError> {
        self.queue.try_push_cost_pri(Request {
            id,
            payload,
            submitted: Instant::now(),
            cost,
            trace,
            timesteps,
        }, cost, pri)
    }

    /// Blocking submit (backpressure by waiting).
    pub fn submit(&self, id: u64, payload: FramePayload)
                  -> std::result::Result<(), SubmitError> {
        let cost = self.cost_model.predict(&payload);
        self.queue.push_cost(Request {
            id,
            payload,
            submitted: Instant::now(),
            cost,
            trace: None,
            timesteps: None,
        }, cost)
    }

    pub fn queue_stats(&self) -> QueueStats {
        self.queue.stats()
    }
}

/// Everything needed to (re)spawn one shared-queue pool worker — held
/// by the service so [`Service::scale_to`] can grow the pool after
/// start. Keeping a live `events_tx` clone here means the worker event
/// channel only disconnects at shutdown, not when the pool momentarily
/// drains to zero live workers between scale events.
struct PoolCtl {
    shared: SharedPipeline,
    wcfg: WorkerConfig,
    events_tx: mpsc::Sender<WorkerEvent>,
    batch_max: usize,
    lpt_fill: Option<Duration>,
}

/// Shared pool-control state behind [`PoolScaler`]: the worker slot
/// table, the respawn kit, and the live size target. The service and
/// any number of scaler handles point at the same instance, so a
/// control loop can resize the pool while the service keeps serving.
struct PoolInner {
    queue: Arc<BoundedQueue<Request>>,
    /// One slot per possible worker index (`workers_max` of them for
    /// dynamic pools). `None` = never spawned or joined after retire.
    handles: Mutex<Vec<Option<thread::JoinHandle<Result<()>>>>>,
    /// Respawn kit. `None` in round-robin mode (that pool is fixed)
    /// and after shutdown clears it (dropping the retained event
    /// sender so routers see the stream disconnect).
    ctl: Mutex<Option<PoolCtl>>,
    /// Current pool-size target (== the configured size until scaled).
    target: AtomicUsize,
    /// Configured (initial) pool size — the answer fixed pools give.
    fixed: usize,
}

/// A cheap, cloneable, `Sync` handle that resizes a running service's
/// worker pool — what the gateway's autoscale control loop holds. All
/// clones (and the owning [`Service`]) share one slot table, so
/// concurrent calls serialize on it and every call re-reconciles the
/// whole pool.
#[derive(Clone)]
pub struct PoolScaler {
    inner: Arc<PoolInner>,
}

impl PoolScaler {
    /// Retarget the pool to `n` workers (clamped to
    /// `[1, workers_max]`); returns the applied target. Scaling *down*
    /// signals the highest-indexed workers to retire on their next
    /// pull (an in-flight batch always completes); scaling *up*
    /// respawns every empty-or-finished slot below the target,
    /// re-registering its queue consumer slot first. A slot whose old
    /// thread is still draining its final batch is skipped and healed
    /// on a later call — the control loop re-reconciles every tick.
    /// No-op (returns the fixed pool size) in round-robin mode and
    /// after shutdown.
    pub fn scale_to(&self, n: usize) -> usize {
        let ctl = self.inner.ctl.lock().unwrap();
        let Some(pool) = ctl.as_ref() else {
            return self.inner.fixed;
        };
        let mut slots = self.inner.handles.lock().unwrap();
        let n = n.clamp(1, slots.len().max(1));
        self.inner.target.store(n, Ordering::Relaxed);
        self.inner.queue.set_consumer_target(n);
        for (i, slot) in slots.iter_mut().enumerate().take(n) {
            match slot {
                Some(h) if !h.is_finished() => continue,
                Some(_) => {
                    // Retired (or dead) but never joined: reap before
                    // reusing the index.
                    if let Some(h) = slot.take() {
                        let _ = h.join();
                    }
                }
                None => {}
            }
            // Same reserve-then-spawn order as `Service::start`.
            self.inner.queue.add_consumers(1);
            let source = WorkSource::Shared {
                queue: self.inner.queue.clone(),
                batch_max: pool.batch_max,
                lpt_fill: pool.lpt_fill,
            };
            let (wc, sh, tx) = (pool.wcfg.clone(), pool.shared.clone(),
                                pool.events_tx.clone());
            match thread::Builder::new()
                .name(format!("skydiver-worker-{i}"))
                .spawn(move || worker_loop(i, wc, sh, source, tx))
            {
                Ok(h) => *slot = Some(h),
                Err(_) => {
                    // Undo the reservation (adopt-and-drop decrements).
                    drop(ConsumerGuard::adopt(self.inner.queue.clone()));
                }
            }
        }
        n
    }

    /// Current pool-size target (live gauge for the autoscaler and the
    /// metrics endpoint; == the configured size for fixed pools).
    pub fn target(&self) -> usize {
        self.inner.target.load(Ordering::Relaxed)
    }

    /// Upper bound [`scale_to`](Self::scale_to) can reach.
    pub fn max(&self) -> usize {
        self.inner.handles.lock().unwrap().len()
    }
}

/// A running service instance.
pub struct Service {
    queue: Arc<BoundedQueue<Request>>,
    cost_model: Arc<RequestCostModel>,
    /// `Some` until a gateway takes the stream with
    /// [`Service::take_events`]; `collect` needs it present.
    events_rx: Option<mpsc::Receiver<WorkerEvent>>,
    dispatcher: Option<thread::JoinHandle<()>>,
    worker_count: usize,
    /// Worker slot table + respawn kit + live target, shared with
    /// every [`PoolScaler`] handed out by [`Service::scaler`].
    pool: PoolScaler,
    /// True when workers run the golden/PJRT runtime (fixed-T program
    /// — reduced-T degradation unavailable).
    fixed_t: bool,
    spec: FrameSpec,
    dispatch: DispatchMode,
    started: Instant,
}

impl Service {
    /// Load the pipeline once (weights + APRC prediction + CBWS
    /// schedule — artifact problems fail fast here), then spawn the
    /// worker pool sharing it. Each worker still builds its own PJRT
    /// client inside its thread; those failures surface through
    /// `collect`/`shutdown` as errors, not hangs.
    pub fn start(cfg: ServiceConfig, wcfg: WorkerConfig) -> Result<Self> {
        ensure!(cfg.workers > 0, "service needs at least one worker");
        let shared = SharedPipeline::build(&wcfg)?;
        let meta = &shared.net.meta;
        let spec = FrameSpec {
            kind: wcfg.kind,
            c: meta.in_shape[0],
            h: meta.in_shape[1],
            w: meta.in_shape[2],
            timesteps: wcfg.timesteps.unwrap_or(meta.timesteps),
        };
        // Cost-denominated admission: in cost-aware mode the default
        // cap admits `queue_cap` *nominal* frames' worth of predicted
        // work; the baselines stay uncapped-by-cost so their admission
        // behaviour is untouched.
        let cost_cap = cfg.cost_cap.unwrap_or(match cfg.dispatch {
            DispatchMode::CostAware => {
                super::cost::NOMINAL_FRAME_COST
                    .saturating_mul(cfg.queue_cap.max(1) as u64)
            }
            _ => u64::MAX,
        });
        let queue: Arc<BoundedQueue<Request>> =
            Arc::new(BoundedQueue::with_cost_cap(cfg.queue_cap, cost_cap));
        let (events_tx, events_rx) = mpsc::channel::<WorkerEvent>();
        let batch_max = cfg.batch_max.max(1);
        let fixed_t = wcfg.use_runtime;
        let workers_max = match cfg.dispatch {
            DispatchMode::RoundRobinBatch => cfg.workers,
            _ => cfg.workers_max.max(cfg.workers),
        };
        let mut handles: Vec<Option<thread::JoinHandle<Result<()>>>> =
            (0..workers_max).map(|_| None).collect();
        let mut dispatcher = None;
        let mut pool = None;

        match cfg.dispatch {
            DispatchMode::WorkQueue | DispatchMode::CostAware => {
                let lpt_fill = match cfg.dispatch {
                    DispatchMode::CostAware => Some(cfg.batch_wait),
                    _ => None,
                };
                // Reserve consumer slots before any thread runs so a
                // submit can never race ahead of worker startup.
                queue.add_consumers(cfg.workers);
                queue.set_consumer_target(cfg.workers);
                for (i, slot) in
                    handles.iter_mut().enumerate().take(cfg.workers)
                {
                    let source = WorkSource::Shared {
                        queue: queue.clone(),
                        batch_max,
                        lpt_fill,
                    };
                    let (wc, sh, tx) =
                        (wcfg.clone(), shared.clone(), events_tx.clone());
                    *slot = Some(thread::Builder::new()
                        .name(format!("skydiver-worker-{i}"))
                        .spawn(move || worker_loop(i, wc, sh, source, tx))?);
                }
                pool = Some(PoolCtl {
                    shared: shared.clone(),
                    wcfg,
                    events_tx: events_tx.clone(),
                    batch_max,
                    lpt_fill,
                });
            }
            DispatchMode::RoundRobinBatch => {
                let mut worker_txs = Vec::with_capacity(cfg.workers);
                for (i, slot) in handles.iter_mut().enumerate() {
                    let (tx, rx) = mpsc::channel::<Vec<Request>>();
                    worker_txs.push(tx);
                    let source = WorkSource::Private(rx);
                    let (wc, sh, etx) =
                        (wcfg.clone(), shared.clone(), events_tx.clone());
                    *slot = Some(thread::Builder::new()
                        .name(format!("skydiver-worker-{i}"))
                        .spawn(move || worker_loop(i, wc, sh, source, etx))?);
                }
                // The dispatcher is the queue's one consumer.
                queue.add_consumers(1);
                let (q, etx, wait) =
                    (queue.clone(), events_tx.clone(), cfg.batch_wait);
                dispatcher = Some(thread::Builder::new()
                    .name("skydiver-dispatch".into())
                    .spawn(move || {
                        rr_dispatch(q, worker_txs, batch_max, wait, etx)
                    })?);
            }
        }
        drop(events_tx);

        let pool = PoolScaler {
            inner: Arc::new(PoolInner {
                queue: queue.clone(),
                handles: Mutex::new(handles),
                ctl: Mutex::new(pool),
                target: AtomicUsize::new(cfg.workers),
                fixed: cfg.workers,
            }),
        };
        Ok(Self {
            queue,
            cost_model: shared.cost_model.clone(),
            events_rx: Some(events_rx),
            dispatcher,
            worker_count: cfg.workers,
            pool,
            fixed_t,
            spec,
            dispatch: cfg.dispatch,
            started: Instant::now(),
        })
    }

    /// Retarget a dynamic pool to `n` workers — see
    /// [`PoolScaler::scale_to`] for semantics.
    pub fn scale_to(&self, n: usize) -> usize {
        self.pool.scale_to(n)
    }

    /// A cloneable handle onto this pool's scaling controls, for a
    /// control loop that outlives its borrow of the service (the
    /// gateway's autoscaler thread).
    pub fn scaler(&self) -> PoolScaler {
        self.pool.clone()
    }

    /// Current pool-size target (live gauge for the autoscaler and the
    /// metrics endpoint; == the configured size for fixed pools).
    pub fn pool_target(&self) -> usize {
        self.pool.target()
    }

    /// Whether this service can serve reduced-timestep (degraded)
    /// frames: functional/temporal pipelines can (T is a runtime
    /// parameter there); golden/PJRT pipelines cannot (their compiled
    /// step program bakes T in).
    pub fn degrade_capable(&self) -> bool {
        !self.fixed_t
    }

    /// Upper bound [`scale_to`](Self::scale_to) can reach.
    pub fn pool_max(&self) -> usize {
        self.pool.max()
    }

    /// How this service dispatches batches to its workers.
    pub fn dispatch_mode(&self) -> DispatchMode {
        self.dispatch
    }

    /// The request-cost model this service admits against (shared with
    /// every [`ServiceHandle`]).
    pub fn cost_model(&self) -> &RequestCostModel {
        &self.cost_model
    }

    /// The served network's frame contract (shape, timesteps).
    pub fn frame_spec(&self) -> &FrameSpec {
        &self.spec
    }

    /// Number of workers in the pool.
    pub fn worker_count(&self) -> usize {
        self.worker_count
    }

    /// A cloneable, thread-safe submission handle (the gateway's
    /// per-connection entry point).
    pub fn handle(&self) -> ServiceHandle {
        ServiceHandle {
            queue: self.queue.clone(),
            cost_model: self.cost_model.clone(),
            spec: self.spec,
        }
    }

    /// Move the worker event stream out of the service, for a response
    /// router that matches responses to submitters by id (the network
    /// gateway). After this, [`collect`](Self::collect) is unavailable
    /// — exactly one side may own the stream.
    pub fn take_events(&mut self)
                       -> Result<mpsc::Receiver<WorkerEvent>> {
        self.events_rx.take()
            .ok_or_else(|| anyhow!("worker event stream already taken"))
    }

    /// Submit one frame, blocking while the queue is full
    /// (backpressure). Errors if the service is shutting down or every
    /// worker has already died.
    pub fn submit(&self, id: u64, pixels: Vec<u8>) -> Result<()> {
        self.submit_payload(id, FramePayload::Pixels(pixels))
    }

    /// [`submit`](Self::submit) for an arbitrary payload (raw pixels or
    /// a pre-encoded spike train).
    pub fn submit_payload(&self, id: u64, payload: FramePayload)
                          -> Result<()> {
        let cost = self.cost_model.predict(&payload);
        self.queue
            .push_cost(Request {
                id,
                payload,
                submitted: Instant::now(),
                cost,
                trace: None,
                timesteps: None,
            }, cost)
            .map_err(|e| anyhow!("submit frame {id}: {e}"))
    }

    /// Non-blocking submit: `Err(SubmitError::Full)` is the
    /// backpressure signal — shed load or retry later.
    pub fn try_submit(&self, id: u64, pixels: Vec<u8>)
                      -> std::result::Result<(), SubmitError> {
        self.try_submit_payload(id, FramePayload::Pixels(pixels))
    }

    /// [`try_submit`](Self::try_submit) for an arbitrary payload.
    pub fn try_submit_payload(&self, id: u64, payload: FramePayload)
                              -> std::result::Result<(), SubmitError> {
        let cost = self.cost_model.predict(&payload);
        self.queue.try_push_cost(Request {
            id,
            payload,
            submitted: Instant::now(),
            cost,
            trace: None,
            timesteps: None,
        }, cost)
    }

    /// Snapshot of the submission queue (depth, high-water mark, flow
    /// counters).
    pub fn queue_stats(&self) -> QueueStats {
        self.queue.stats()
    }

    /// Collect exactly `n` responses (blocking), then return stats.
    /// Returns an error — instead of hanging — as soon as any accepted
    /// request is lost (a worker died with requests in hand: those
    /// responses will never arrive, even if others still could) or
    /// every worker has exited.
    pub fn collect(&self, n: usize, clock_hz: f64)
                   -> Result<(Vec<Response>, ServingReport)> {
        self.collect_inner(n, clock_hz, None)
    }

    /// [`collect`](Self::collect) with a hard wall-clock bound.
    pub fn collect_within(&self, n: usize, clock_hz: f64,
                          timeout: Duration)
                          -> Result<(Vec<Response>, ServingReport)> {
        self.collect_inner(n, clock_hz, Some(Instant::now() + timeout))
    }

    fn collect_inner(&self, n: usize, clock_hz: f64,
                     deadline: Option<Instant>)
                     -> Result<(Vec<Response>, ServingReport)> {
        let events_rx = match self.events_rx.as_ref() {
            Some(rx) => rx,
            None => bail!("worker event stream was taken (a gateway \
                           owns it); collect is unavailable"),
        };
        let mut stats = Stats::default();
        let mut out = Vec::with_capacity(n);
        let mut failures: Vec<String> = Vec::new();
        // A worker emits `Failed` only as its final event, so once every
        // worker has failed no further responses can ever arrive — even
        // if the legacy dispatcher thread still holds the channel open.
        let mut dead_workers = 0usize;
        while out.len() < n {
            let ev = match deadline {
                None => match events_rx.recv() {
                    Ok(ev) => ev,
                    Err(_) => bail!(
                        "all workers exited after {}/{n} responses{}",
                        out.len(), describe(&failures)),
                },
                Some(d) => {
                    let left = d.saturating_duration_since(Instant::now());
                    match events_rx.recv_timeout(left) {
                        Ok(ev) => ev,
                        Err(mpsc::RecvTimeoutError::Timeout) => bail!(
                            "timed out with {}/{n} responses{}",
                            out.len(), describe(&failures)),
                        Err(mpsc::RecvTimeoutError::Disconnected) => bail!(
                            "all workers exited after {}/{n} responses{}",
                            out.len(), describe(&failures)),
                    }
                }
            };
            match ev {
                WorkerEvent::Served(r) => {
                    stats.record(&r);
                    out.push(r);
                }
                WorkerEvent::Failed { worker, error, lost } => {
                    failures.push(format!("worker {worker}: {error}"));
                    dead_workers += 1;
                    if !lost.is_empty() {
                        bail!("worker {worker} failed with {} \
                               request(s) in hand after {}/{n} \
                               responses: {error}", lost.len(),
                              out.len());
                    }
                    // Build-time failure: surviving workers may still
                    // serve everything; keep collecting — unless none
                    // survive.
                    if dead_workers >= self.worker_count {
                        bail!("every worker failed after {}/{n} \
                               responses{}", out.len(),
                              describe(&failures));
                    }
                }
                WorkerEvent::Undeliverable { lost } => {
                    bail!("{} request(s) undeliverable (no live \
                           workers) after {}/{n} responses{}",
                          lost.len(), out.len(), describe(&failures));
                }
            }
        }
        let mut report = stats.report(
            self.started.elapsed().as_secs_f64(), clock_hz,
            self.worker_count);
        let q = self.queue.stats();
        report.queue_capacity = q.capacity;
        report.queue_max_depth = q.max_depth;
        report.worker_failures = failures;
        Ok((out, report))
    }

    /// Shut down: close the queue (workers drain the remainder and
    /// exit), drop the pool's retained event sender (so a router
    /// holding the event stream sees it disconnect once the last
    /// worker exits), join all threads, and surface the first worker
    /// error.
    pub fn shutdown(mut self) -> Result<()> {
        self.queue.close();
        *self.pool.inner.ctl.lock().unwrap() = None;
        if let Some(d) = self.dispatcher.take() {
            let _ = d.join();
        }
        let mut first_err: Option<anyhow::Error> = None;
        for h in self.pool.inner.handles.lock().unwrap().iter_mut() {
            let Some(h) = h.take() else { continue };
            match h.join() {
                Ok(Ok(())) => {}
                Ok(Err(e)) => {
                    first_err.get_or_insert(e);
                }
                Err(_) => {
                    first_err.get_or_insert(anyhow!("worker panicked"));
                }
            }
        }
        match first_err {
            None => Ok(()),
            Some(e) => Err(e),
        }
    }
}

fn describe(failures: &[String]) -> String {
    if failures.is_empty() {
        String::new()
    } else {
        format!("; failures: [{}]", failures.join("; "))
    }
}

/// Legacy baseline: group whole batches off the shared queue and deal
/// them round-robin to per-worker channels. Unlike the original, a dead
/// worker is pruned from the rotation (its batch goes to the next live
/// one) and a batch with no live worker left is *reported* as lost, so
/// `collect` errors instead of hanging.
fn rr_dispatch(queue: Arc<BoundedQueue<Request>>,
               mut worker_txs: Vec<mpsc::Sender<Vec<Request>>>,
               batch_max: usize, batch_wait: Duration,
               events: mpsc::Sender<WorkerEvent>) {
    let _guard = ConsumerGuard::adopt(queue.clone());
    let mut next = 0usize;
    while let Some(batch) = queue.pop_batch_wait(batch_max, batch_wait) {
        if batch.is_empty() {
            continue;
        }
        let mut undelivered = Some(batch);
        while let Some(b) = undelivered.take() {
            if worker_txs.is_empty() {
                let stranded = queue.drain_now();
                let lost: Vec<u64> = b.iter().map(|r| r.id)
                    .chain(stranded.iter().map(|r| r.id))
                    .collect();
                let _ = events.send(WorkerEvent::Undeliverable { lost });
                return; // guard drops -> submits start failing
            }
            if next >= worker_txs.len() {
                next = 0;
            }
            match worker_txs[next].send(b) {
                Ok(()) => next = (next + 1) % worker_txs.len(),
                Err(mpsc::SendError(b)) => {
                    // Receiver gone: prune and retry on the next one.
                    worker_txs.remove(next);
                    undelivered = Some(b);
                }
            }
        }
    }
    // Queue closed and drained: dropping worker_txs closes the pool.
}
