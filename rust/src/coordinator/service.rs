//! The service: submission queue + batcher + round-robin router over a
//! worker-thread pool (std-only; the build is offline).

use std::sync::mpsc;
use std::thread;
use std::time::{Duration, Instant};

use anyhow::Result;

use super::stats::{ServingReport, Stats};
use super::worker::{worker_loop, Request, Response, WorkerConfig};

/// Coordinator-level knobs.
#[derive(Debug, Clone)]
pub struct ServiceConfig {
    pub workers: usize,
    /// Max frames per dispatched batch.
    pub batch_max: usize,
    /// Max time the batcher waits to fill a batch.
    pub batch_wait: Duration,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        Self {
            workers: 2,
            batch_max: 8,
            batch_wait: Duration::from_millis(2),
        }
    }
}

/// A running service instance.
pub struct Service {
    submit_tx: mpsc::Sender<Request>,
    resp_rx: mpsc::Receiver<Response>,
    handles: Vec<thread::JoinHandle<Result<()>>>,
    batcher_handle: Option<thread::JoinHandle<()>>,
    started: Instant,
}

impl Service {
    /// Spawn workers + batcher. Each worker builds its own pipeline
    /// (PJRT client included) inside its thread.
    pub fn start(cfg: ServiceConfig, wcfg: WorkerConfig) -> Result<Self> {
        let (resp_tx, resp_rx) = mpsc::channel::<Response>();
        let mut worker_txs = Vec::new();
        let mut handles = Vec::new();
        for i in 0..cfg.workers {
            let (tx, rx) = mpsc::channel::<Vec<Request>>();
            worker_txs.push(tx);
            let wc = wcfg.clone();
            let rt = resp_tx.clone();
            handles.push(thread::Builder::new()
                .name(format!("skydiver-worker-{i}"))
                .spawn(move || worker_loop(i, wc, rx, rt))?);
        }
        drop(resp_tx);

        // Batcher: drain the submission queue, group, round-robin
        // dispatch to the worker pool.
        let (submit_tx, submit_rx) = mpsc::channel::<Request>();
        let batch_max = cfg.batch_max;
        let batch_wait = cfg.batch_wait;
        let batcher_handle = thread::Builder::new()
            .name("skydiver-batcher".into())
            .spawn(move || {
                let mut next = 0usize;
                'outer: loop {
                    // Block for the first request of a batch.
                    let Ok(first) = submit_rx.recv() else {
                        break 'outer;
                    };
                    let mut batch = vec![first];
                    let deadline = Instant::now() + batch_wait;
                    while batch.len() < batch_max {
                        let now = Instant::now();
                        if now >= deadline {
                            break;
                        }
                        match submit_rx.recv_timeout(deadline - now) {
                            Ok(r) => batch.push(r),
                            Err(mpsc::RecvTimeoutError::Timeout) => break,
                            Err(mpsc::RecvTimeoutError::Disconnected) => {
                                let _ = worker_txs[next].send(batch);
                                break 'outer;
                            }
                        }
                    }
                    if worker_txs[next].send(batch).is_err() {
                        break 'outer;
                    }
                    next = (next + 1) % worker_txs.len();
                }
                // Dropping worker_txs closes the pool.
            })?;

        Ok(Self {
            submit_tx,
            resp_rx,
            handles,
            batcher_handle: Some(batcher_handle),
            started: Instant::now(),
        })
    }

    /// Submit one frame (non-blocking).
    pub fn submit(&self, id: u64, pixels: Vec<u8>) -> Result<()> {
        self.submit_tx.send(Request {
            id,
            pixels,
            submitted: Instant::now(),
        })?;
        Ok(())
    }

    /// Collect exactly `n` responses (blocking), then return stats.
    pub fn collect(&self, n: usize, clock_hz: f64)
                   -> Result<(Vec<Response>, ServingReport)> {
        let mut stats = Stats::default();
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            let r = self.resp_rx.recv()?;
            stats.record(&r);
            out.push(r);
        }
        let report = stats.report(self.started.elapsed().as_secs_f64(),
                                  clock_hz);
        Ok((out, report))
    }

    /// Shut down: close the queue and join all threads.
    pub fn shutdown(mut self) -> Result<()> {
        drop(self.submit_tx);
        if let Some(b) = self.batcher_handle.take() {
            let _ = b.join();
        }
        for h in self.handles.drain(..) {
            match h.join() {
                Ok(r) => r?,
                Err(_) => anyhow::bail!("worker panicked"),
            }
        }
        Ok(())
    }
}
