//! Serving statistics collection.

use crate::metrics::percentile;

use super::worker::Response;

/// Online accumulator for responses.
#[derive(Debug, Default, Clone)]
pub struct Stats {
    latencies_us: Vec<u64>,
    sim_cycles: Vec<u64>,
    energy_j: f64,
    per_worker: Vec<u64>,
    per_worker_busy_us: Vec<u64>,
}

impl Stats {
    pub fn record(&mut self, r: &Response) {
        self.latencies_us.push(r.latency_us);
        self.sim_cycles.push(r.sim_cycles);
        self.energy_j += r.energy_j;
        if self.per_worker.len() <= r.worker {
            self.per_worker.resize(r.worker + 1, 0);
            self.per_worker_busy_us.resize(r.worker + 1, 0);
        }
        self.per_worker[r.worker] += 1;
        self.per_worker_busy_us[r.worker] += r.service_us;
    }

    pub fn count(&self) -> usize {
        self.latencies_us.len()
    }

    /// Final report; `wall_secs` is the makespan of the run, `workers`
    /// the configured pool size (a worker that served nothing — e.g.
    /// one that died at build time — still counts against balance).
    pub fn report(&self, wall_secs: f64, clock_hz: f64, workers: usize)
                  -> ServingReport {
        let mut lat = self.latencies_us.clone();
        lat.sort_unstable();
        let frames = self.count();
        let sim_total: u64 = self.sim_cycles.iter().sum();
        let mean_sim_cycles = if frames == 0 {
            0.0
        } else {
            sim_total as f64 / frames as f64
        };
        // Guard: zero frames (or an all-zero trace) must report 0.0,
        // not inf/NaN from dividing by a zero mean.
        let sim_fps = if mean_sim_cycles > 0.0 {
            clock_hz / mean_sim_cycles
        } else {
            0.0
        };
        let mut busy = self.per_worker_busy_us.clone();
        if busy.len() < workers {
            busy.resize(workers, 0);
        }
        let mut per_worker = self.per_worker.clone();
        if per_worker.len() < workers {
            per_worker.resize(workers, 0);
        }
        ServingReport {
            frames,
            wall_secs,
            served_fps: frames as f64 / wall_secs.max(1e-9),
            p50_us: percentile(&lat, 50.0),
            p95_us: percentile(&lat, 95.0),
            p99_us: percentile(&lat, 99.0),
            mean_sim_cycles,
            sim_fps,
            mean_energy_uj: if frames == 0 {
                0.0
            } else {
                self.energy_j * 1e6 / frames as f64
            },
            host_balance_ratio: host_balance_ratio(&busy),
            per_worker,
            per_worker_busy_us: busy,
            queue_capacity: 0,
            queue_max_depth: 0,
            worker_failures: Vec::new(),
        }
    }
}

/// Host-side analogue of the simulator's Fig.-7 balance ratio:
/// `total_busy / (workers * max_busy)`. 1.0 iff every worker was busy
/// for the same time; `1/workers` when one worker did everything.
/// An idle pool (no busy time at all) is vacuously balanced: 1.0.
pub fn host_balance_ratio(busy_us: &[u64]) -> f64 {
    let max = busy_us.iter().copied().max().unwrap_or(0);
    if max == 0 || busy_us.is_empty() {
        return 1.0;
    }
    let total: u64 = busy_us.iter().sum();
    total as f64 / (busy_us.len() as f64 * max as f64)
}

/// Summary of a serving run: wall-clock (host) and simulated
/// (accelerator) views.
#[derive(Debug, Clone, Default)]
pub struct ServingReport {
    pub frames: usize,
    pub wall_secs: f64,
    /// Host serving throughput (frames/s of the whole coordinator).
    pub served_fps: f64,
    pub p50_us: u64,
    pub p95_us: u64,
    pub p99_us: u64,
    /// Mean simulated accelerator cycles per frame.
    pub mean_sim_cycles: f64,
    /// Simulated accelerator FPS (the paper's Table I metric); 0.0 when
    /// no frames were recorded.
    pub sim_fps: f64,
    pub mean_energy_uj: f64,
    /// Frames served per worker (padded to the configured pool size).
    pub per_worker: Vec<u64>,
    /// Wall-clock busy time per worker in microseconds.
    pub per_worker_busy_us: Vec<u64>,
    /// `total_busy / (workers * max_busy)` — the host-side counterpart
    /// of the paper's SPE balance ratio (Fig. 7).
    pub host_balance_ratio: f64,
    /// Submission-queue capacity (backpressure threshold).
    pub queue_capacity: usize,
    /// High-water mark of the submission queue during the run.
    pub queue_max_depth: usize,
    /// Human-readable failure reports from workers that died.
    pub worker_failures: Vec<String>,
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Instant;

    fn resp(id: u64, worker: usize, latency_us: u64, service_us: u64)
            -> Response {
        Response {
            id,
            output_counts: vec![],
            sim_cycles: 1000 + id,
            energy_j: 1e-6,
            latency_us,
            service_us,
            worker,
        }
    }

    #[test]
    fn stats_aggregate() {
        let mut s = Stats::default();
        for i in 0..10u64 {
            s.record(&resp(i, (i % 2) as usize, 100 * (i + 1), 50));
        }
        let _ = Instant::now();
        let r = s.report(1.0, 200e6, 2);
        assert_eq!(r.frames, 10);
        assert_eq!(r.per_worker, vec![5, 5]);
        assert_eq!(r.per_worker_busy_us, vec![250, 250]);
        assert!((r.host_balance_ratio - 1.0).abs() < 1e-12);
        assert!((r.mean_energy_uj - 1.0).abs() < 1e-9);
        assert!(r.p99_us >= r.p50_us);
        assert!((r.served_fps - 10.0).abs() < 1e-9);
        assert!(r.sim_fps > 0.0);
    }

    #[test]
    fn zero_frames_report_is_finite() {
        let s = Stats::default();
        let r = s.report(0.5, 200e6, 4);
        assert_eq!(r.frames, 0);
        assert_eq!(r.sim_fps, 0.0);
        assert_eq!(r.mean_sim_cycles, 0.0);
        assert_eq!(r.mean_energy_uj, 0.0);
        assert!(r.served_fps.is_finite());
        assert!(r.host_balance_ratio.is_finite());
        assert_eq!(r.per_worker, vec![0; 4]);
        assert_eq!(r.per_worker_busy_us, vec![0; 4]);
    }

    #[test]
    fn balance_ratio_penalises_skew() {
        // One worker did all the work on a 2-pool: ratio = 1/2.
        let mut s = Stats::default();
        for i in 0..4u64 {
            s.record(&resp(i, 0, 100, 1000));
        }
        let r = s.report(1.0, 200e6, 2);
        assert!((r.host_balance_ratio - 0.5).abs() < 1e-12);
        // Perfectly split busy time: ratio = 1.0.
        assert!((host_balance_ratio(&[300, 300, 300]) - 1.0).abs()
                < 1e-12);
        // Idle pool is vacuously balanced.
        assert_eq!(host_balance_ratio(&[0, 0]), 1.0);
        assert_eq!(host_balance_ratio(&[]), 1.0);
    }

    #[test]
    fn dead_worker_counts_against_balance() {
        // Configured 3 workers, only two ever served.
        let mut s = Stats::default();
        s.record(&resp(0, 0, 100, 600));
        s.record(&resp(1, 1, 100, 600));
        let r = s.report(1.0, 200e6, 3);
        assert_eq!(r.per_worker_busy_us, vec![600, 600, 0]);
        assert!((r.host_balance_ratio - 2.0 / 3.0).abs() < 1e-12);
    }
}
