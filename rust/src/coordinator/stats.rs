//! Serving statistics collection.
//!
//! [`Stats`] is built for a *long-lived* server: every per-response
//! quantity is folded into fixed-size state (a log-bucketed
//! [`LatencyHistogram`], running sums, per-worker counters), so memory
//! never grows with the number of frames served. Small runs still get
//! exact percentiles — the histogram keeps the first
//! [`LatencyHistogram::EXACT_CAP`] raw samples and routes through
//! [`metrics::percentile`] until that capacity is exceeded.

use crate::metrics::percentile;

use super::worker::Response;

/// Sub-bucket resolution: 2^4 = 16 linear sub-buckets per octave, so a
/// bucketed percentile is within ~1/16 (6.25%) of the true value.
const SUB_BITS: u32 = 4;
const SUB: u64 = 1 << SUB_BITS;
/// Bucket count covering the full u64 range at SUB_BITS resolution:
/// indices 0..SUB are exact, then 16 per octave up to 2^63.
const NBUCKETS: usize = ((64 - SUB_BITS as usize) * SUB as usize) + 16;

/// Fixed-memory latency histogram: log-spaced buckets with linear
/// sub-buckets (HdrHistogram-style), plus an exact-sample prefix so
/// short runs report exact percentiles. Total footprint is a few KiB
/// regardless of how many values are recorded.
#[derive(Debug, Clone)]
pub struct LatencyHistogram {
    buckets: Vec<u64>,
    /// First `EXACT_CAP` raw samples (exact small-run percentiles).
    exact: Vec<u64>,
    count: u64,
    sum: u128,
    max: u64,
}

impl Default for LatencyHistogram {
    fn default() -> Self {
        Self {
            buckets: vec![0; NBUCKETS],
            exact: Vec::new(),
            count: 0,
            sum: 0,
            max: 0,
        }
    }
}

impl LatencyHistogram {
    /// Runs at or under this many samples report *exact* percentiles.
    pub const EXACT_CAP: usize = 512;

    /// Bucket index for a value. Values below `SUB` get their own
    /// bucket (exact); above, each power-of-two octave is split into
    /// `SUB` linear sub-buckets.
    fn bucket_index(v: u64) -> usize {
        if v < SUB {
            return v as usize;
        }
        let msb = 63 - v.leading_zeros();
        let octave = (msb - SUB_BITS) as usize + 1;
        let sub = ((v >> (msb - SUB_BITS)) - SUB) as usize;
        octave * SUB as usize + sub
    }

    /// Midpoint of a bucket — the value a bucketed percentile reports.
    fn bucket_mid(index: usize) -> u64 {
        if index < SUB as usize {
            return index as u64;
        }
        let octave = index / SUB as usize;
        let sub = (index % SUB as usize) as u64;
        let width = 1u64 << (octave - 1);
        (SUB + sub) * width + width / 2
    }

    pub fn record(&mut self, v: u64) {
        self.count += 1;
        self.sum += v as u128;
        self.max = self.max.max(v);
        if self.exact.len() < Self::EXACT_CAP {
            self.exact.push(v);
        }
        self.buckets[Self::bucket_index(v)] += 1;
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn max(&self) -> u64 {
        self.max
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum as f64 / self.count as f64
        }
    }

    /// p in [0, 100]. Exact while `count <= EXACT_CAP`; bucketed
    /// (≤ ~6.25% relative error, capped at the observed max) beyond.
    pub fn percentile(&self, p: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        if self.count as usize <= Self::EXACT_CAP {
            let mut sorted = self.exact.clone();
            sorted.sort_unstable();
            return percentile(&sorted, p);
        }
        // Same rank convention as `metrics::percentile`: index
        // round(p/100 * (n-1)) of the sorted samples, i.e. the bucket
        // holding the (rank+1)-th smallest value.
        let rank =
            ((self.count - 1) as f64 * p / 100.0).round() as u64;
        let mut seen = 0u64;
        for (i, &c) in self.buckets.iter().enumerate() {
            seen += c;
            if seen > rank {
                return Self::bucket_mid(i).min(self.max);
            }
        }
        self.max
    }

    /// Windowed percentile: the percentile of only the samples
    /// recorded *since* `base` was cloned off this histogram, computed
    /// by bucket-count difference. This is how the autoscaler reads
    /// p99 over its control interval without resetting (and thereby
    /// racing) the live histogram: clone a baseline under the stats
    /// lock at tick N, diff against the live histogram at tick N+1.
    /// Bucketed resolution only (the exact-sample prefix cannot be
    /// diffed); ≤ ~6.25% relative error, which is ample for a
    /// scale-up/scale-down decision. Returns 0 when the window is
    /// empty or `base` is not an earlier snapshot of `self`.
    pub fn percentile_since(&self, base: &LatencyHistogram, p: f64)
                            -> u64 {
        let count_w = self.count.saturating_sub(base.count);
        if count_w == 0 {
            return 0;
        }
        let rank = ((count_w - 1) as f64 * p / 100.0).round() as u64;
        let mut seen = 0u64;
        for (i, &c) in self.buckets.iter().enumerate() {
            seen += c.saturating_sub(base.buckets[i]);
            if seen > rank {
                return Self::bucket_mid(i).min(self.max);
            }
        }
        self.max
    }

    /// Fixed memory bound in bytes (buckets + exact prefix capacity) —
    /// asserted by tests, independent of `count`.
    pub fn mem_bound_bytes(&self) -> usize {
        self.buckets.len() * 8 + self.exact.capacity() * 8
    }
}

/// Online accumulator for responses — O(1) memory per response.
#[derive(Debug, Default, Clone)]
pub struct Stats {
    latency: LatencyHistogram,
    sim_cycles_sum: u128,
    energy_j: f64,
    per_worker: Vec<u64>,
    per_worker_busy_us: Vec<u64>,
    per_worker_cost: Vec<u64>,
    /// Sum of admitted predicted costs over served responses.
    pred_cost_sum: u128,
    /// Running predicted-vs-actual calibration error (see `record`).
    calib_err_sum: f64,
    calib_n: u64,
}

impl Stats {
    pub fn record(&mut self, r: &Response) {
        self.latency.record(r.latency_us);
        self.sim_cycles_sum += r.sim_cycles as u128;
        self.energy_j += r.energy_j;
        if self.per_worker.len() <= r.worker {
            self.per_worker.resize(r.worker + 1, 0);
            self.per_worker_busy_us.resize(r.worker + 1, 0);
            self.per_worker_cost.resize(r.worker + 1, 0);
        }
        self.per_worker[r.worker] += 1;
        self.per_worker_busy_us[r.worker] += r.service_us;
        self.per_worker_cost[r.worker] =
            self.per_worker_cost[r.worker]
                .saturating_add(r.predicted_cost);
        self.pred_cost_sum += r.predicted_cost as u128;
        // Predicted cost is in dimensionless cost units, actual work
        // in simulated cycles; score prediction *shape* by scaling
        // predictions into cycle units with the running totals (the
        // best online estimate of the unit conversion), then
        // accumulating this response's relative error. Early responses
        // are scored against a coarse scale — acceptable for a
        // monitoring metric that converges with traffic.
        if r.sim_cycles > 0 && self.pred_cost_sum > 0 {
            let scale = self.sim_cycles_sum as f64
                / self.pred_cost_sum as f64;
            let actual = r.sim_cycles as f64;
            self.calib_err_sum +=
                (r.predicted_cost as f64 * scale - actual).abs()
                    / actual;
            self.calib_n += 1;
        }
    }

    pub fn count(&self) -> usize {
        self.latency.count() as usize
    }

    /// The latency distribution (for metrics endpoints that want more
    /// quantiles than the report carries).
    pub fn latency(&self) -> &LatencyHistogram {
        &self.latency
    }

    /// Final report; `wall_secs` is the makespan of the run, `workers`
    /// the configured pool size (a worker that served nothing — e.g.
    /// one that died at build time — still counts against balance).
    pub fn report(&self, wall_secs: f64, clock_hz: f64, workers: usize)
                  -> ServingReport {
        let frames = self.count();
        let mean_sim_cycles = if frames == 0 {
            0.0
        } else {
            self.sim_cycles_sum as f64 / frames as f64
        };
        // Guard: zero frames (or an all-zero trace) must report 0.0,
        // not inf/NaN from dividing by a zero mean.
        let sim_fps = if mean_sim_cycles > 0.0 {
            clock_hz / mean_sim_cycles
        } else {
            0.0
        };
        let mut busy = self.per_worker_busy_us.clone();
        if busy.len() < workers {
            busy.resize(workers, 0);
        }
        let mut per_worker = self.per_worker.clone();
        if per_worker.len() < workers {
            per_worker.resize(workers, 0);
        }
        let mut per_worker_cost = self.per_worker_cost.clone();
        if per_worker_cost.len() < workers {
            per_worker_cost.resize(workers, 0);
        }
        ServingReport {
            frames,
            wall_secs,
            served_fps: frames as f64 / wall_secs.max(1e-9),
            p50_us: self.latency.percentile(50.0),
            p95_us: self.latency.percentile(95.0),
            p99_us: self.latency.percentile(99.0),
            mean_sim_cycles,
            sim_fps,
            mean_energy_uj: if frames == 0 {
                0.0
            } else {
                self.energy_j * 1e6 / frames as f64
            },
            host_balance_ratio: host_balance_ratio(&busy),
            per_worker,
            per_worker_busy_us: busy,
            mean_predicted_cost: if frames == 0 {
                0.0
            } else {
                self.pred_cost_sum as f64 / frames as f64
            },
            cost_calibration_error: if self.calib_n == 0 {
                0.0
            } else {
                self.calib_err_sum / self.calib_n as f64
            },
            cost_balance_ratio: host_balance_ratio(&per_worker_cost),
            per_worker_cost,
            queue_capacity: 0,
            queue_max_depth: 0,
            worker_failures: Vec::new(),
        }
    }
}

/// Host-side analogue of the simulator's Fig.-7 balance ratio:
/// `total_busy / (workers * max_busy)`. 1.0 iff every worker was busy
/// for the same time; `1/workers` when one worker did everything.
/// An idle pool (no busy time at all) is vacuously balanced: 1.0.
pub fn host_balance_ratio(busy_us: &[u64]) -> f64 {
    let max = busy_us.iter().copied().max().unwrap_or(0);
    if max == 0 || busy_us.is_empty() {
        return 1.0;
    }
    let total: u64 = busy_us.iter().sum();
    total as f64 / (busy_us.len() as f64 * max as f64)
}

/// Summary of a serving run: wall-clock (host) and simulated
/// (accelerator) views.
#[derive(Debug, Clone, Default)]
pub struct ServingReport {
    pub frames: usize,
    pub wall_secs: f64,
    /// Host serving throughput (frames/s of the whole coordinator).
    pub served_fps: f64,
    pub p50_us: u64,
    pub p95_us: u64,
    pub p99_us: u64,
    /// Mean simulated accelerator cycles per frame.
    pub mean_sim_cycles: f64,
    /// Simulated accelerator FPS (the paper's Table I metric); 0.0 when
    /// no frames were recorded.
    pub sim_fps: f64,
    pub mean_energy_uj: f64,
    /// Frames served per worker (padded to the configured pool size).
    pub per_worker: Vec<u64>,
    /// Wall-clock busy time per worker in microseconds.
    pub per_worker_busy_us: Vec<u64>,
    /// `total_busy / (workers * max_busy)` — the host-side counterpart
    /// of the paper's SPE balance ratio (Fig. 7).
    pub host_balance_ratio: f64,
    /// Mean admitted predicted cost per served frame (cost units).
    pub mean_predicted_cost: f64,
    /// Mean relative error of predicted cost against simulated cycles
    /// after the online unit-scale fit (0.0 until frames arrive).
    pub cost_calibration_error: f64,
    /// Balance ratio over *predicted cost* served per worker — how
    /// evenly batch assembly spread the predicted work, independent of
    /// host timing noise.
    pub cost_balance_ratio: f64,
    /// Predicted cost served per worker (cost units).
    pub per_worker_cost: Vec<u64>,
    /// Submission-queue capacity (backpressure threshold).
    pub queue_capacity: usize,
    /// High-water mark of the submission queue during the run.
    pub queue_max_depth: usize,
    /// Human-readable failure reports from workers that died.
    pub worker_failures: Vec<String>,
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Instant;

    fn resp(id: u64, worker: usize, latency_us: u64, service_us: u64)
            -> Response {
        Response {
            id,
            output_counts: vec![],
            sim_cycles: 1000 + id,
            energy_j: 1e-6,
            latency_us,
            service_us,
            worker,
            predicted_cost: 100,
            timesteps: 8,
            degraded: false,
        }
    }

    #[test]
    fn stats_aggregate() {
        let mut s = Stats::default();
        for i in 0..10u64 {
            s.record(&resp(i, (i % 2) as usize, 100 * (i + 1), 50));
        }
        let _ = Instant::now();
        let r = s.report(1.0, 200e6, 2);
        assert_eq!(r.frames, 10);
        assert_eq!(r.per_worker, vec![5, 5]);
        assert_eq!(r.per_worker_busy_us, vec![250, 250]);
        assert!((r.host_balance_ratio - 1.0).abs() < 1e-12);
        assert!((r.mean_energy_uj - 1.0).abs() < 1e-9);
        assert!(r.p99_us >= r.p50_us);
        assert!((r.served_fps - 10.0).abs() < 1e-9);
        assert!(r.sim_fps > 0.0);
    }

    #[test]
    fn zero_frames_report_is_finite() {
        let s = Stats::default();
        let r = s.report(0.5, 200e6, 4);
        assert_eq!(r.frames, 0);
        assert_eq!(r.sim_fps, 0.0);
        assert_eq!(r.mean_sim_cycles, 0.0);
        assert_eq!(r.mean_energy_uj, 0.0);
        assert!(r.served_fps.is_finite());
        assert!(r.host_balance_ratio.is_finite());
        assert_eq!(r.per_worker, vec![0; 4]);
        assert_eq!(r.per_worker_busy_us, vec![0; 4]);
    }

    #[test]
    fn balance_ratio_penalises_skew() {
        // One worker did all the work on a 2-pool: ratio = 1/2.
        let mut s = Stats::default();
        for i in 0..4u64 {
            s.record(&resp(i, 0, 100, 1000));
        }
        let r = s.report(1.0, 200e6, 2);
        assert!((r.host_balance_ratio - 0.5).abs() < 1e-12);
        // Perfectly split busy time: ratio = 1.0.
        assert!((host_balance_ratio(&[300, 300, 300]) - 1.0).abs()
                < 1e-12);
        // Idle pool is vacuously balanced.
        assert_eq!(host_balance_ratio(&[0, 0]), 1.0);
        assert_eq!(host_balance_ratio(&[]), 1.0);
    }

    #[test]
    fn cost_accounting_and_calibration() {
        let mut s = Stats::default();
        // Prediction perfectly proportional to actual cycles: the
        // online scale fit should drive the error to ~0.
        for i in 0..8u64 {
            let mut r = resp(i, (i % 2) as usize, 100, 50);
            r.sim_cycles = 500 * (i + 1);
            r.predicted_cost = 5 * (i + 1);
            s.record(&r);
        }
        let rep = s.report(1.0, 200e6, 2);
        assert!((rep.mean_predicted_cost - 5.0 * 4.5).abs() < 1e-9);
        assert!(rep.cost_calibration_error < 1e-9,
                "proportional prediction must calibrate exactly, got \
                 {}", rep.cost_calibration_error);
        // Workers 0 and 1 served costs 5+15+25+35 vs 10+20+30+40.
        assert_eq!(rep.per_worker_cost, vec![80, 100]);
        assert!((rep.cost_balance_ratio - 180.0 / 200.0).abs() < 1e-9);

        // A wildly wrong prediction shows up as a large error.
        let mut s = Stats::default();
        for i in 0..8u64 {
            let mut r = resp(i, 0, 100, 50);
            r.sim_cycles = if i % 2 == 0 { 10_000 } else { 100 };
            r.predicted_cost = 100; // flat guess against 100x spread
            s.record(&r);
        }
        let rep = s.report(1.0, 200e6, 1);
        assert!(rep.cost_calibration_error > 0.5,
                "flat prediction against skewed actuals must score \
                 badly, got {}", rep.cost_calibration_error);
    }

    #[test]
    fn dead_worker_counts_against_balance() {
        // Configured 3 workers, only two ever served.
        let mut s = Stats::default();
        s.record(&resp(0, 0, 100, 600));
        s.record(&resp(1, 1, 100, 600));
        let r = s.report(1.0, 200e6, 3);
        assert_eq!(r.per_worker_busy_us, vec![600, 600, 0]);
        assert!((r.host_balance_ratio - 2.0 / 3.0).abs() < 1e-12);
    }

    // ---------------- histogram ----------------

    #[test]
    fn bucket_index_is_monotone_and_in_range() {
        let mut prev = 0usize;
        // Powers of two and neighbours across the whole range.
        for shift in 0..63u32 {
            for delta in [-1i64, 0, 1] {
                let v = (1u64 << shift).wrapping_add(delta as u64);
                if v == 0 || v == u64::MAX {
                    continue;
                }
                let idx = LatencyHistogram::bucket_index(v);
                assert!(idx < NBUCKETS, "index {idx} for {v}");
                assert!(idx >= prev || v < (1u64 << shift),
                        "bucket index not monotone at {v}");
                prev = prev.max(idx);
            }
        }
        // Exact region: identity.
        for v in 0..SUB {
            assert_eq!(LatencyHistogram::bucket_index(v), v as usize);
        }
        // Midpoint stays within the bucket (relative error bound).
        for v in [17u64, 100, 999, 12_345, 1 << 20, (1 << 40) + 7] {
            let idx = LatencyHistogram::bucket_index(v);
            let mid = LatencyHistogram::bucket_mid(idx);
            let err = (mid as f64 - v as f64).abs() / v as f64;
            assert!(err <= 1.0 / SUB as f64 + 1e-12,
                    "midpoint {mid} too far from {v} (err {err})");
        }
    }

    #[test]
    fn small_runs_are_exact() {
        let mut h = LatencyHistogram::default();
        let vals: Vec<u64> = (1..=100u64).map(|v| v * 37).collect();
        for &v in &vals {
            h.record(v);
        }
        let mut sorted = vals.clone();
        sorted.sort_unstable();
        for p in [0.0, 50.0, 95.0, 99.0, 100.0] {
            assert_eq!(h.percentile(p), percentile(&sorted, p),
                       "exact path diverged at p{p}");
        }
    }

    #[test]
    fn histogram_memory_is_bounded_and_accurate() {
        let mut h = LatencyHistogram::default();
        // A long-lived server's worth of samples: far beyond EXACT_CAP.
        let n = 1_000_000u64;
        for i in 0..n {
            // Deterministic spread over [1, 100_000].
            h.record(1 + (i.wrapping_mul(2654435761) % 100_000));
        }
        assert_eq!(h.count(), n);
        // Fixed footprint: buckets + the capped exact prefix, a few KiB
        // — not 8 MB of raw samples.
        assert!(h.mem_bound_bytes()
                <= (NBUCKETS + LatencyHistogram::EXACT_CAP * 2) * 8,
                "memory bound grew: {} bytes", h.mem_bound_bytes());
        // Accuracy: within the sub-bucket bound of the true quantile
        // of the (near-uniform) distribution.
        let p50 = h.percentile(50.0) as f64;
        let p99 = h.percentile(99.0) as f64;
        assert!((p50 - 50_000.0).abs() / 50_000.0 < 0.10,
                "p50 {p50} too far from 50k");
        assert!((p99 - 99_000.0).abs() / 99_000.0 < 0.10,
                "p99 {p99} too far from 99k");
        assert!(h.percentile(50.0) <= h.percentile(95.0));
        assert!(h.percentile(95.0) <= h.percentile(99.0));
        assert!(h.percentile(100.0) <= h.max());
    }

    #[test]
    fn windowed_percentile_sees_only_new_samples() {
        let mut h = LatencyHistogram::default();
        // Old regime: fast responses.
        for _ in 0..2_000 {
            h.record(100);
        }
        let base = h.clone();
        assert_eq!(h.percentile_since(&base, 99.0), 0,
                   "empty window must read 0, not the lifetime p99");
        // New regime: 10x slower. Lifetime p50 still says "fast"; the
        // window must say "slow" — this is the misdecision the
        // autoscaler would make if it read lifetime percentiles.
        for _ in 0..2_000 {
            h.record(1_000);
        }
        let lifetime_p50 = h.percentile(50.0);
        let window_p50 = h.percentile_since(&base, 50.0);
        assert!(lifetime_p50 < 300, "lifetime p50 {lifetime_p50}");
        assert!((window_p50 as f64 - 1_000.0).abs() / 1_000.0 < 0.10,
                "window p50 {window_p50} must track the new regime");
        // Degenerate: base == self.
        assert_eq!(h.percentile_since(&h.clone(), 99.0), 0);
    }

    #[test]
    fn windowed_reads_race_free_under_concurrent_writers() {
        // Autoscaler-style usage: writers fold responses into a
        // Mutex<Stats> while a control loop snapshots the histogram
        // each tick and diffs windows. Assert every window read is
        // internally consistent (count monotone, percentile within the
        // recorded value range) — no torn or stale-window misdecision.
        use std::sync::{Arc, Mutex};
        use std::thread;
        let stats = Arc::new(Mutex::new(Stats::default()));
        let writers: Vec<_> = (0..4)
            .map(|w| {
                let stats = stats.clone();
                thread::spawn(move || {
                    for i in 0..5_000u64 {
                        let r = resp(i, w, 50 + (i % 400), 10);
                        stats.lock().unwrap().record(&r);
                    }
                })
            })
            .collect();
        let mut base = stats.lock().unwrap().latency().clone();
        let mut last_count = 0u64;
        for _ in 0..50 {
            let snap = stats.lock().unwrap().latency().clone();
            assert!(snap.count() >= last_count,
                    "histogram count went backwards");
            let window = snap.count() - base.count();
            let p99 = snap.percentile_since(&base, 99.0);
            if window == 0 {
                assert_eq!(p99, 0);
            } else {
                // All recorded values lie in [50, 450); the bucketed
                // window p99 must too (within bucket resolution).
                assert!(p99 >= 50 && p99 <= 480,
                        "window p99 {p99} outside recorded range");
            }
            last_count = snap.count();
            base = snap;
            thread::yield_now();
        }
        for h in writers {
            h.join().unwrap();
        }
        let final_snap = stats.lock().unwrap().latency().clone();
        assert_eq!(final_snap.count(), 20_000);
        let empty = LatencyHistogram::default();
        let p99 = final_snap.percentile_since(&empty, 99.0);
        assert!(p99 >= 50 && p99 <= 480, "full-window p99 {p99}");
    }

    #[test]
    fn stats_memory_stays_bounded_across_many_records() {
        let mut s = Stats::default();
        for i in 0..200_000u64 {
            s.record(&resp(i, (i % 4) as usize, 10 + i % 5_000, 3));
        }
        assert_eq!(s.count(), 200_000);
        assert!(s.latency().mem_bound_bytes() < 64 * 1024,
                "latency state must stay a few KiB");
        let r = s.report(10.0, 200e6, 4);
        assert_eq!(r.frames, 200_000);
        assert!(r.p50_us > 0 && r.p50_us <= r.p95_us
                && r.p95_us <= r.p99_us);
    }
}
