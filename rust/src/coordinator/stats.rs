//! Serving statistics collection.



use crate::metrics::percentile;

use super::worker::Response;

/// Online accumulator for responses.
#[derive(Debug, Default, Clone)]
pub struct Stats {
    latencies_us: Vec<u64>,
    sim_cycles: Vec<u64>,
    energy_j: f64,
    per_worker: Vec<u64>,
}

impl Stats {
    pub fn record(&mut self, r: &Response) {
        self.latencies_us.push(r.latency_us);
        self.sim_cycles.push(r.sim_cycles);
        self.energy_j += r.energy_j;
        if self.per_worker.len() <= r.worker {
            self.per_worker.resize(r.worker + 1, 0);
        }
        self.per_worker[r.worker] += 1;
    }

    pub fn count(&self) -> usize {
        self.latencies_us.len()
    }

    /// Final report; `wall_secs` is the makespan of the run.
    pub fn report(&self, wall_secs: f64, clock_hz: f64) -> ServingReport {
        let mut lat = self.latencies_us.clone();
        lat.sort_unstable();
        let n = self.count().max(1);
        let sim_total: u64 = self.sim_cycles.iter().sum();
        ServingReport {
            frames: self.count(),
            wall_secs,
            served_fps: self.count() as f64 / wall_secs.max(1e-9),
            p50_us: percentile(&lat, 50.0),
            p95_us: percentile(&lat, 95.0),
            p99_us: percentile(&lat, 99.0),
            mean_sim_cycles: sim_total as f64 / n as f64,
            sim_fps: clock_hz / (sim_total as f64 / n as f64),
            mean_energy_uj: self.energy_j * 1e6 / n as f64,
            per_worker: self.per_worker.clone(),
        }
    }
}

/// Summary of a serving run: wall-clock (host) and simulated
/// (accelerator) views.
#[derive(Debug, Clone, Default)]
pub struct ServingReport {
    pub frames: usize,
    pub wall_secs: f64,
    /// Host serving throughput (frames/s of the whole coordinator).
    pub served_fps: f64,
    pub p50_us: u64,
    pub p95_us: u64,
    pub p99_us: u64,
    /// Mean simulated accelerator cycles per frame.
    pub mean_sim_cycles: f64,
    /// Simulated accelerator FPS (the paper's Table I metric).
    pub sim_fps: f64,
    pub mean_energy_uj: f64,
    pub per_worker: Vec<u64>,
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Instant;

    #[test]
    fn stats_aggregate() {
        let mut s = Stats::default();
        for i in 0..10u64 {
            s.record(&Response {
                id: i,
                output_counts: vec![],
                sim_cycles: 1000 + i,
                energy_j: 1e-6,
                latency_us: 100 * (i + 1),
                worker: (i % 2) as usize,
            });
        }
        let _ = Instant::now();
        let r = s.report(1.0, 200e6);
        assert_eq!(r.frames, 10);
        assert_eq!(r.per_worker, vec![5, 5]);
        assert!((r.mean_energy_uj - 1.0).abs() < 1e-9);
        assert!(r.p99_us >= r.p50_us);
        assert!((r.served_fps - 10.0).abs() < 1e-9);
    }
}
