//! Bounded multi-producer / multi-consumer work queue (std-only).
//!
//! The serving layer's single point of coordination: submitters push
//! requests in, workers pull batches out as they free up. Capacity is
//! fixed at construction — a full queue is the backpressure signal
//! ([`SubmitError::Full`]) — and the queue tracks its consumer
//! population so producers are never left blocking on a queue nothing
//! will ever drain (every worker exit decrements the count via a
//! [`ConsumerGuard`]; at zero, waiting and future pushes fail with
//! [`SubmitError::NoWorkers`]).

use std::collections::VecDeque;
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

/// Why a submission was rejected.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SubmitError {
    /// The queue is at capacity (non-blocking submit only). Retry later
    /// or shed load — this is the backpressure signal.
    Full { capacity: usize },
    /// The queue was closed (shutdown has begun).
    Closed,
    /// Every consumer (worker) has exited; nothing will drain the
    /// queue, so accepting the item would strand it forever.
    NoWorkers,
}

impl std::fmt::Display for SubmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SubmitError::Full { capacity } => {
                write!(f, "work queue full ({capacity} entries)")
            }
            SubmitError::Closed => write!(f, "work queue closed"),
            SubmitError::NoWorkers => {
                write!(f, "no live workers to drain the queue")
            }
        }
    }
}

impl std::error::Error for SubmitError {}

/// Point-in-time queue counters for reports.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct QueueStats {
    pub capacity: usize,
    /// Items currently enqueued.
    pub depth: usize,
    /// High-water mark of `depth` over the queue's lifetime.
    pub max_depth: usize,
    /// Total items ever accepted.
    pub pushed: u64,
    /// Total items ever handed to a consumer.
    pub popped: u64,
}

struct Inner<T> {
    items: VecDeque<T>,
    closed: bool,
    consumers: usize,
    max_depth: usize,
    pushed: u64,
    popped: u64,
}

/// The queue proper. Shared as `Arc<BoundedQueue<T>>`.
pub struct BoundedQueue<T> {
    inner: Mutex<Inner<T>>,
    not_empty: Condvar,
    not_full: Condvar,
    capacity: usize,
}

impl<T> BoundedQueue<T> {
    pub fn new(capacity: usize) -> Self {
        let capacity = capacity.max(1);
        Self {
            inner: Mutex::new(Inner {
                items: VecDeque::with_capacity(capacity),
                closed: false,
                consumers: 0,
                max_depth: 0,
                pushed: 0,
                popped: 0,
            }),
            not_empty: Condvar::new(),
            not_full: Condvar::new(),
            capacity,
        }
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Register `n` consumers *before* their threads start, so a
    /// producer can never observe a spurious zero between service
    /// construction and worker startup.
    pub fn add_consumers(&self, n: usize) {
        let mut g = self.inner.lock().unwrap();
        g.consumers += n;
    }

    fn consumer_gone(&self) {
        let mut g = self.inner.lock().unwrap();
        g.consumers = g.consumers.saturating_sub(1);
        if g.consumers == 0 {
            // Wake producers blocked on a queue that will never drain
            // and consumers waiting for items that will never matter.
            self.not_full.notify_all();
            self.not_empty.notify_all();
        }
    }

    /// Non-blocking push; [`SubmitError::Full`] is the backpressure
    /// signal.
    pub fn try_push(&self, item: T) -> Result<(), SubmitError> {
        let mut g = self.inner.lock().unwrap();
        if g.closed {
            return Err(SubmitError::Closed);
        }
        if g.consumers == 0 {
            return Err(SubmitError::NoWorkers);
        }
        if g.items.len() >= self.capacity {
            return Err(SubmitError::Full { capacity: self.capacity });
        }
        g.items.push_back(item);
        g.pushed += 1;
        g.max_depth = g.max_depth.max(g.items.len());
        drop(g);
        self.not_empty.notify_one();
        Ok(())
    }

    /// Blocking push: waits for space (backpressure), failing only if
    /// the queue closes or every consumer exits while waiting.
    pub fn push(&self, item: T) -> Result<(), SubmitError> {
        let mut g = self.inner.lock().unwrap();
        loop {
            if g.closed {
                return Err(SubmitError::Closed);
            }
            if g.consumers == 0 {
                return Err(SubmitError::NoWorkers);
            }
            if g.items.len() < self.capacity {
                g.items.push_back(item);
                g.pushed += 1;
                g.max_depth = g.max_depth.max(g.items.len());
                drop(g);
                self.not_empty.notify_one();
                return Ok(());
            }
            g = self.not_full.wait(g).unwrap();
        }
    }

    /// Pull up to `max` items, blocking while the queue is empty.
    /// Returns `None` once the queue is closed *and* drained — the
    /// consumer's signal to exit. Greedy: takes whatever is there
    /// rather than waiting to fill `max`.
    pub fn pop_batch(&self, max: usize) -> Option<Vec<T>> {
        let max = max.max(1);
        let mut g = self.inner.lock().unwrap();
        loop {
            if !g.items.is_empty() {
                let take = g.items.len().min(max);
                let batch: Vec<T> = g.items.drain(..take).collect();
                g.popped += take as u64;
                drop(g);
                self.not_full.notify_all();
                return Some(batch);
            }
            if g.closed {
                return None;
            }
            g = self.not_empty.wait(g).unwrap();
        }
    }

    /// Like [`pop_batch`](Self::pop_batch), but after the first item
    /// arrives keeps waiting up to `fill_wait` for the batch to fill to
    /// `max` — the legacy batcher's grouping window.
    pub fn pop_batch_wait(&self, max: usize, fill_wait: Duration)
                          -> Option<Vec<T>> {
        let max = max.max(1);
        let mut g = self.inner.lock().unwrap();
        // Phase 1: block for the first item (or closure).
        loop {
            if !g.items.is_empty() {
                break;
            }
            if g.closed {
                return None;
            }
            g = self.not_empty.wait(g).unwrap();
        }
        // Phase 2: fill until `max` or the window expires.
        let deadline = Instant::now() + fill_wait;
        while g.items.len() < max && !g.closed {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            let (guard, timeout) =
                self.not_empty.wait_timeout(g, deadline - now).unwrap();
            g = guard;
            if timeout.timed_out() {
                break;
            }
        }
        let take = g.items.len().min(max);
        let batch: Vec<T> = g.items.drain(..take).collect();
        g.popped += take as u64;
        drop(g);
        self.not_full.notify_all();
        Some(batch)
    }

    /// Take everything immediately (no blocking). Used by the legacy
    /// dispatcher to account for stranded requests when its last worker
    /// dies.
    pub fn drain_now(&self) -> Vec<T> {
        let mut g = self.inner.lock().unwrap();
        let n = g.items.len();
        g.popped += n as u64;
        let out: Vec<T> = g.items.drain(..).collect();
        drop(g);
        self.not_full.notify_all();
        out
    }

    /// Close the queue: wakes every waiter; pushes fail from now on,
    /// pops drain the remainder and then return `None`.
    pub fn close(&self) {
        let mut g = self.inner.lock().unwrap();
        g.closed = true;
        drop(g);
        self.not_empty.notify_all();
        self.not_full.notify_all();
    }

    pub fn stats(&self) -> QueueStats {
        let g = self.inner.lock().unwrap();
        QueueStats {
            capacity: self.capacity,
            depth: g.items.len(),
            max_depth: g.max_depth,
            pushed: g.pushed,
            popped: g.popped,
        }
    }
}

/// RAII token for one registered consumer: dropping it (worker exit,
/// normal or by failure/panic) decrements the live-consumer count, which
/// is what converts "all workers died" from an indefinite producer hang
/// into an immediate [`SubmitError::NoWorkers`].
pub struct ConsumerGuard<T> {
    queue: Arc<BoundedQueue<T>>,
}

impl<T> ConsumerGuard<T> {
    /// Adopt a consumer slot previously reserved with
    /// [`BoundedQueue::add_consumers`] (does *not* increment).
    pub fn adopt(queue: Arc<BoundedQueue<T>>) -> Self {
        Self { queue }
    }
}

impl<T> Drop for ConsumerGuard<T> {
    fn drop(&mut self) {
        self.queue.consumer_gone();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::thread;

    #[test]
    fn fifo_order_and_batching() {
        let q = BoundedQueue::new(8);
        q.add_consumers(1);
        for i in 0..5 {
            q.try_push(i).unwrap();
        }
        assert_eq!(q.pop_batch(3), Some(vec![0, 1, 2]));
        assert_eq!(q.pop_batch(3), Some(vec![3, 4]));
    }

    #[test]
    fn full_queue_reports_backpressure() {
        let q = BoundedQueue::new(2);
        q.add_consumers(1);
        q.try_push(1).unwrap();
        q.try_push(2).unwrap();
        assert_eq!(q.try_push(3), Err(SubmitError::Full { capacity: 2 }));
        assert_eq!(q.stats().max_depth, 2);
    }

    #[test]
    fn closed_queue_rejects_and_drains() {
        let q = BoundedQueue::new(4);
        q.add_consumers(1);
        q.try_push(7).unwrap();
        q.close();
        assert_eq!(q.try_push(8), Err(SubmitError::Closed));
        assert_eq!(q.pop_batch(4), Some(vec![7]));
        assert_eq!(q.pop_batch(4), None);
    }

    #[test]
    fn no_consumers_rejects_push() {
        let q: Arc<BoundedQueue<u32>> = Arc::new(BoundedQueue::new(4));
        q.add_consumers(1);
        drop(ConsumerGuard::adopt(q.clone()));
        assert_eq!(q.try_push(1), Err(SubmitError::NoWorkers));
        assert_eq!(q.push(1), Err(SubmitError::NoWorkers));
    }

    #[test]
    fn blocking_push_unblocks_when_last_consumer_dies() {
        let q: Arc<BoundedQueue<u32>> = Arc::new(BoundedQueue::new(1));
        q.add_consumers(1);
        q.try_push(0).unwrap();
        let q2 = q.clone();
        let h = thread::spawn(move || q2.push(1)); // blocks: full
        thread::sleep(Duration::from_millis(20));
        drop(ConsumerGuard::adopt(q.clone())); // consumers -> 0
        assert_eq!(h.join().unwrap(), Err(SubmitError::NoWorkers));
    }

    #[test]
    fn blocking_push_waits_for_space() {
        let q: Arc<BoundedQueue<u32>> = Arc::new(BoundedQueue::new(1));
        q.add_consumers(1);
        q.try_push(0).unwrap();
        let q2 = q.clone();
        let h = thread::spawn(move || q2.push(1));
        thread::sleep(Duration::from_millis(20));
        assert_eq!(q.pop_batch(1), Some(vec![0]));
        assert_eq!(h.join().unwrap(), Ok(()));
        assert_eq!(q.pop_batch(1), Some(vec![1]));
    }

    #[test]
    fn pop_blocks_until_close() {
        let q: Arc<BoundedQueue<u32>> = Arc::new(BoundedQueue::new(2));
        let q2 = q.clone();
        let h = thread::spawn(move || q2.pop_batch(2));
        thread::sleep(Duration::from_millis(20));
        q.close();
        assert_eq!(h.join().unwrap(), None);
    }

    #[test]
    fn fill_window_groups_late_arrivals() {
        let q: Arc<BoundedQueue<u32>> = Arc::new(BoundedQueue::new(8));
        q.add_consumers(1);
        q.try_push(1).unwrap();
        let q2 = q.clone();
        let h = thread::spawn(move || {
            q2.pop_batch_wait(4, Duration::from_millis(200))
        });
        thread::sleep(Duration::from_millis(20));
        q.try_push(2).unwrap();
        q.try_push(3).unwrap();
        q.try_push(4).unwrap();
        assert_eq!(h.join().unwrap(), Some(vec![1, 2, 3, 4]));
    }

    #[test]
    fn stats_count_flow() {
        let q = BoundedQueue::new(4);
        q.add_consumers(1);
        for i in 0..4 {
            q.try_push(i).unwrap();
        }
        let _ = q.pop_batch(2);
        let s = q.stats();
        assert_eq!((s.pushed, s.popped, s.depth, s.max_depth), (4, 2, 2, 4));
    }
}
