//! Bounded multi-producer / multi-consumer work queue (std-only).
//!
//! The serving layer's single point of coordination: submitters push
//! requests in, workers pull batches out as they free up. Capacity is
//! fixed at construction — a full queue is the backpressure signal
//! ([`SubmitError::Full`]) — and the queue tracks its consumer
//! population so producers are never left blocking on a queue nothing
//! will ever drain (every worker exit decrements the count via a
//! [`ConsumerGuard`]; at zero, waiting and future pushes fail with
//! [`SubmitError::NoWorkers`]).
//!
//! Every item carries a *cost* (predicted workload in cost units —
//! see [`super::cost::RequestCostModel`]; the plain `push`/`try_push`
//! helpers tag cost 1). Two things build on it:
//!
//! * **Cost-denominated admission.** A queue built with
//!   [`BoundedQueue::with_cost_cap`] refuses pushes that would take
//!   the queued cost beyond the cap, so backpressure tracks predicted
//!   *work*, not request count — a burst of dense frames sheds
//!   earlier, a stream of near-silent ones later. A single item
//!   costing more than the whole cap is still admitted when the queue
//!   is empty (it could otherwise never run).
//! * **Cost-balanced batch assembly.** [`BoundedQueue::pop_batch_cost`]
//!   hands each idle worker its fair share of the queued cost via an
//!   LPT-style greedy fill, instead of the FIFO count-based
//!   [`BoundedQueue::pop_batch`].
//!
//! ## Priority classes and weighted-fair pulls
//!
//! Every item also carries a [`Priority`] class (`High`/`Normal`/
//! `Low`; the cost-1 and cost-only push helpers tag `Normal`).
//! Internally the queue keeps one FIFO lane per class and serves them
//! by **weighted round-robin** ([`WFQ_WEIGHTS`], high to low): while
//! several classes are backlogged, each round hands class `k` exactly
//! `WFQ_WEIGHTS[k]` pulls, so a flood of high-priority traffic can
//! delay — but never starve — the lower classes (bounded starvation:
//! any backlogged class is served at least `weight` times per
//! `sum(weights)` pulls; property-tested in
//! `proptest_invariants.rs`). With only one class occupied the
//! schedule degenerates to the exact FIFO order of the pre-priority
//! queue — single-class callers observe no behavior change.
//!
//! ## Dynamic consumer population
//!
//! Worker pools can grow and shrink at runtime
//! ([`BoundedQueue::set_consumer_target`]): each pool worker pulls via
//! the `*_as(worker_idx, ..)` variants, and a worker whose index is at
//! or beyond the current target gets `None` on its next pull — the
//! same "exit now" signal as a closed-and-drained queue — letting the
//! autoscaler retire the highest-indexed workers without touching the
//! ones still serving.

use std::collections::VecDeque;
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

/// Priority class of a queued item. Lower discriminant = served more
/// often under backlog ([`WFQ_WEIGHTS`]). The wire protocol carries
/// the same codes in the v2 `EXT_PRIORITY` request extension.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
#[repr(u8)]
pub enum Priority {
    /// Latency-sensitive traffic; largest weighted share.
    High = 0,
    /// The default class — everything that never asked for one.
    Normal = 1,
    /// Batch/backfill traffic; smallest share, still starvation-free.
    Low = 2,
}

/// Number of [`Priority`] classes (lane-array dimension).
pub const N_PRIORITIES: usize = 3;

/// Weighted-round-robin shares per class, [`Priority`] order (high to
/// low): under full backlog each round of `4 + 2 + 1` pulls serves 4
/// high, 2 normal, 1 low.
pub const WFQ_WEIGHTS: [u64; N_PRIORITIES] = [4, 2, 1];

impl Priority {
    pub fn from_u8(v: u8) -> Option<Self> {
        Some(match v {
            0 => Priority::High,
            1 => Priority::Normal,
            2 => Priority::Low,
            _ => return None,
        })
    }

    pub fn as_str(self) -> &'static str {
        match self {
            Priority::High => "high",
            Priority::Normal => "normal",
            Priority::Low => "low",
        }
    }

    /// Parse the CLI/ops spelling (`high`/`normal`/`low`).
    pub fn parse(s: &str) -> Option<Self> {
        Some(match s {
            "high" => Priority::High,
            "normal" => Priority::Normal,
            "low" => Priority::Low,
            _ => return None,
        })
    }
}

/// Why a submission was rejected.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SubmitError {
    /// The queue is at capacity (non-blocking submit only). Retry
    /// later or shed load — this is the backpressure signal.
    /// `by_cost` distinguishes the cost-cap limit from the item-count
    /// limit, so shed errors name the cap that actually fired.
    Full { capacity: usize, by_cost: bool },
    /// The queue was closed (shutdown has begun).
    Closed,
    /// Every consumer (worker) has exited; nothing will drain the
    /// queue, so accepting the item would strand it forever.
    NoWorkers,
}

impl std::fmt::Display for SubmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SubmitError::Full { by_cost: true, .. } => {
                write!(f, "work queue full (predicted-cost cap reached)")
            }
            SubmitError::Full { capacity, .. } => {
                write!(f, "work queue full ({capacity} entries)")
            }
            SubmitError::Closed => write!(f, "work queue closed"),
            SubmitError::NoWorkers => {
                write!(f, "no live workers to drain the queue")
            }
        }
    }
}

impl std::error::Error for SubmitError {}

/// Point-in-time queue counters for reports.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct QueueStats {
    pub capacity: usize,
    /// Items currently enqueued (all classes).
    pub depth: usize,
    /// Items currently enqueued per [`Priority`] class (high to low).
    pub depth_by_class: [usize; N_PRIORITIES],
    /// High-water mark of `depth` over the queue's lifetime.
    pub max_depth: usize,
    /// Total items ever accepted.
    pub pushed: u64,
    /// Total items ever handed to a consumer.
    pub popped: u64,
    /// Admission cap in cost units (`u64::MAX` = uncapped).
    pub cost_capacity: u64,
    /// Predicted cost currently enqueued.
    pub cost_depth: u64,
    /// High-water mark of `cost_depth`.
    pub max_cost_depth: u64,
    /// Total cost ever accepted.
    pub cost_pushed: u64,
    /// Total cost ever handed to a consumer.
    pub cost_popped: u64,
}

struct Inner<T> {
    /// One FIFO lane per [`Priority`] class, high to low; items carry
    /// their predicted cost.
    classes: [VecDeque<(T, u64)>; N_PRIORITIES],
    /// Weighted-round-robin credits left this round, per class.
    credit: [u64; N_PRIORITIES],
    closed: bool,
    consumers: usize,
    /// Pool-size target for indexed consumers: a worker pulling via a
    /// `*_as(idx, ..)` variant retires (gets `None`) once
    /// `idx >= consumer_target`. `usize::MAX` = no target (fixed
    /// pools, non-indexed callers).
    consumer_target: usize,
    max_depth: usize,
    pushed: u64,
    popped: u64,
    cost_depth: u64,
    max_cost_depth: u64,
    cost_pushed: u64,
    cost_popped: u64,
}

impl<T> Inner<T> {
    fn len(&self) -> usize {
        self.classes.iter().map(|c| c.len()).sum()
    }

    fn is_empty(&self) -> bool {
        self.classes.iter().all(|c| c.is_empty())
    }

    /// Next class to serve under weighted round-robin: the highest
    /// class that is backlogged and still holds round credit; when no
    /// backlogged class has credit left, a new round starts (credits
    /// refill to [`WFQ_WEIGHTS`]). `None` iff the queue is empty.
    fn next_class(&mut self) -> Option<usize> {
        if self.is_empty() {
            return None;
        }
        loop {
            for k in 0..N_PRIORITIES {
                if !self.classes[k].is_empty() && self.credit[k] > 0 {
                    return Some(k);
                }
            }
            self.credit = WFQ_WEIGHTS;
        }
    }

    /// Pop the weighted-fair head (the single-item WFQ schedule step).
    /// Does NOT touch the pop counters — callers batch the accounting.
    fn pop_head(&mut self) -> Option<(T, u64)> {
        let k = self.next_class()?;
        self.credit[k] -= 1;
        self.classes[k].pop_front()
    }

    /// Room for one more item of `cost`? `Ok(())` or which limit
    /// refused it (`Full`, with `by_cost` naming the cost cap when the
    /// item-count cap still had slots). The cost cap carries the
    /// single-oversized-item exemption: an empty queue admits any
    /// cost, else an above-cap item could never run.
    fn check_room(&self, capacity: usize, cost_cap: u64, cost: u64)
                  -> Result<(), SubmitError> {
        if self.len() >= capacity {
            return Err(SubmitError::Full { capacity, by_cost: false });
        }
        if !self.is_empty()
            && self.cost_depth.saturating_add(cost) > cost_cap
        {
            return Err(SubmitError::Full { capacity, by_cost: true });
        }
        Ok(())
    }

    /// Remove up to `take` items in weighted-fair order, returning
    /// them with their summed cost and updating the pop counters — the
    /// single accounting path for every schedule-order drain.
    fn take_front(&mut self, take: usize) -> (Vec<T>, u64) {
        let mut batch = Vec::with_capacity(take.min(self.len()));
        let mut cost = 0u64;
        while batch.len() < take {
            let Some((item, c)) = self.pop_head() else { break };
            cost = cost.saturating_add(c);
            batch.push(item);
        }
        self.record_pop(batch.len() as u64, cost);
        (batch, cost)
    }

    fn record_push(&mut self, cost: u64) {
        self.pushed += 1;
        self.max_depth = self.max_depth.max(self.len());
        self.cost_depth = self.cost_depth.saturating_add(cost);
        self.cost_pushed = self.cost_pushed.saturating_add(cost);
        self.max_cost_depth = self.max_cost_depth.max(self.cost_depth);
    }

    fn record_pop(&mut self, n: u64, cost: u64) {
        self.popped += n;
        self.cost_popped = self.cost_popped.saturating_add(cost);
        self.cost_depth = self.cost_depth.saturating_sub(cost);
    }
}

/// The queue proper. Shared as `Arc<BoundedQueue<T>>`.
pub struct BoundedQueue<T> {
    inner: Mutex<Inner<T>>,
    not_empty: Condvar,
    not_full: Condvar,
    capacity: usize,
    cost_cap: u64,
}

impl<T> BoundedQueue<T> {
    pub fn new(capacity: usize) -> Self {
        Self::with_cost_cap(capacity, u64::MAX)
    }

    /// A queue that also refuses pushes beyond `cost_cap` queued cost
    /// units (see the module docs for the oversized-item exemption).
    /// `cost_cap` 0 means **uncapped** — the same convention the
    /// metrics endpoint and the `--queue-cost-cap` flag use.
    pub fn with_cost_cap(capacity: usize, cost_cap: u64) -> Self {
        let capacity = capacity.max(1);
        let cost_cap = if cost_cap == 0 { u64::MAX } else { cost_cap };
        Self {
            inner: Mutex::new(Inner {
                classes: std::array::from_fn(|_| VecDeque::new()),
                credit: WFQ_WEIGHTS,
                closed: false,
                consumers: 0,
                consumer_target: usize::MAX,
                max_depth: 0,
                pushed: 0,
                popped: 0,
                cost_depth: 0,
                max_cost_depth: 0,
                cost_pushed: 0,
                cost_popped: 0,
            }),
            not_empty: Condvar::new(),
            not_full: Condvar::new(),
            capacity,
            cost_cap,
        }
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Admission cap in cost units (`u64::MAX` = uncapped).
    pub fn cost_capacity(&self) -> u64 {
        self.cost_cap
    }

    /// Register `n` consumers *before* their threads start, so a
    /// producer can never observe a spurious zero between service
    /// construction and worker startup.
    pub fn add_consumers(&self, n: usize) {
        let mut g = self.inner.lock().unwrap();
        g.consumers += n;
    }

    /// Set the pool-size target for indexed consumers: workers pulling
    /// via [`pop_batch_wait_as`](Self::pop_batch_wait_as) /
    /// [`pop_batch_cost_as`](Self::pop_batch_cost_as) with
    /// `idx >= target` get `None` on their next pull and exit. Wakes
    /// every waiting consumer so retirement is prompt even on an idle
    /// queue. Scaling *up* is the pool's job (spawn + `add_consumers`);
    /// this only signals the excess.
    pub fn set_consumer_target(&self, target: usize) {
        let mut g = self.inner.lock().unwrap();
        g.consumer_target = target;
        drop(g);
        self.not_empty.notify_all();
    }

    fn consumer_gone(&self) {
        let mut g = self.inner.lock().unwrap();
        g.consumers = g.consumers.saturating_sub(1);
        if g.consumers == 0 {
            // Wake producers blocked on a queue that will never drain
            // and consumers waiting for items that will never matter.
            self.not_full.notify_all();
            self.not_empty.notify_all();
        }
    }

    /// Non-blocking push; [`SubmitError::Full`] is the backpressure
    /// signal. Cost 1, class `Normal` — submit paths that predicted a
    /// real cost use [`try_push_cost`](Self::try_push_cost).
    pub fn try_push(&self, item: T) -> Result<(), SubmitError> {
        self.try_push_cost(item, 1)
    }

    /// [`try_push`](Self::try_push) with an explicit predicted cost
    /// (class `Normal`).
    pub fn try_push_cost(&self, item: T, cost: u64)
                         -> Result<(), SubmitError> {
        self.try_push_cost_pri(item, cost, Priority::Normal)
    }

    /// [`try_push_cost`](Self::try_push_cost) into an explicit
    /// [`Priority`] lane — the full-form submission every admission
    /// path funnels into.
    pub fn try_push_cost_pri(&self, item: T, cost: u64, pri: Priority)
                             -> Result<(), SubmitError> {
        let mut g = self.inner.lock().unwrap();
        if g.closed {
            return Err(SubmitError::Closed);
        }
        if g.consumers == 0 {
            return Err(SubmitError::NoWorkers);
        }
        g.check_room(self.capacity, self.cost_cap, cost)?;
        g.classes[pri as usize].push_back((item, cost));
        g.record_push(cost);
        drop(g);
        self.not_empty.notify_one();
        Ok(())
    }

    /// Blocking push: waits for space (backpressure), failing only if
    /// the queue closes or every consumer exits while waiting. Cost 1,
    /// class `Normal`.
    pub fn push(&self, item: T) -> Result<(), SubmitError> {
        self.push_cost(item, 1)
    }

    /// [`push`](Self::push) with an explicit predicted cost (class
    /// `Normal`).
    pub fn push_cost(&self, item: T, cost: u64)
                     -> Result<(), SubmitError> {
        let mut g = self.inner.lock().unwrap();
        loop {
            if g.closed {
                return Err(SubmitError::Closed);
            }
            if g.consumers == 0 {
                return Err(SubmitError::NoWorkers);
            }
            if g.check_room(self.capacity, self.cost_cap, cost).is_ok() {
                g.classes[Priority::Normal as usize]
                    .push_back((item, cost));
                g.record_push(cost);
                drop(g);
                self.not_empty.notify_one();
                return Ok(());
            }
            g = self.not_full.wait(g).unwrap();
        }
    }

    /// Pull up to `max` items, blocking while the queue is empty.
    /// Returns `None` once the queue is closed *and* drained — the
    /// consumer's signal to exit. Greedy: takes whatever is there
    /// rather than waiting to fill `max`. Weighted-fair order (exact
    /// FIFO when one class is occupied) — the baseline batch assembly
    /// [`pop_batch_cost`](Self::pop_batch_cost) is measured against.
    pub fn pop_batch(&self, max: usize) -> Option<Vec<T>> {
        let max = max.max(1);
        let mut g = self.inner.lock().unwrap();
        loop {
            if !g.is_empty() {
                let take = g.len().min(max);
                let (batch, _) = g.take_front(take);
                drop(g);
                self.not_full.notify_all();
                return Some(batch);
            }
            if g.closed {
                return None;
            }
            g = self.not_empty.wait(g).unwrap();
        }
    }

    /// Like [`pop_batch`](Self::pop_batch), but after the first item
    /// arrives keeps waiting up to `fill_wait` for the batch to fill to
    /// `max` — the legacy batcher's grouping window.
    pub fn pop_batch_wait(&self, max: usize, fill_wait: Duration)
                          -> Option<Vec<T>> {
        self.pop_batch_wait_inner(max, fill_wait, None)
    }

    /// [`pop_batch_wait`](Self::pop_batch_wait) as pool worker `idx`:
    /// additionally returns `None` (retire) once the consumer target
    /// drops to `idx` or below.
    pub fn pop_batch_wait_as(&self, idx: usize, max: usize,
                             fill_wait: Duration) -> Option<Vec<T>> {
        self.pop_batch_wait_inner(max, fill_wait, Some(idx))
    }

    fn pop_batch_wait_inner(&self, max: usize, fill_wait: Duration,
                            idx: Option<usize>) -> Option<Vec<T>> {
        let max = max.max(1);
        let mut g = self.inner.lock().unwrap();
        g = match self.await_first(g, fill_wait, max, idx) {
            Some(g) => g,
            None => return None,
        };
        let take = g.len().min(max);
        let (batch, _) = g.take_front(take);
        drop(g);
        self.not_full.notify_all();
        Some(batch)
    }

    /// Cost-balanced batch assembly: block for the first item, give
    /// late arrivals the same `fill_wait` grouping window as
    /// [`pop_batch_wait`](Self::pop_batch_wait), then assemble this
    /// consumer's fair share of the queued cost — the **weighted-fair
    /// head first** (so every pull advances a lane head and no request
    /// can be bypassed indefinitely by costlier newcomers), then an
    /// LPT-style greedy fill with the costliest remaining items that
    /// keep the batch within `queued_cost / consumers`. Every batch's
    /// cost is therefore bounded by `max(costliest_item,
    /// ceil(queued_cost / consumers))` — within 2x the ideal max-bin
    /// cost (the classic greedy bound; property-tested in
    /// `proptest_invariants.rs`).
    pub fn pop_batch_cost(&self, max: usize, fill_wait: Duration)
                          -> Option<Vec<T>> {
        self.pop_batch_cost_inner(max, fill_wait, None)
    }

    /// [`pop_batch_cost`](Self::pop_batch_cost) as pool worker `idx`:
    /// additionally returns `None` (retire) once the consumer target
    /// drops to `idx` or below.
    pub fn pop_batch_cost_as(&self, idx: usize, max: usize,
                             fill_wait: Duration) -> Option<Vec<T>> {
        self.pop_batch_cost_inner(max, fill_wait, Some(idx))
    }

    fn pop_batch_cost_inner(&self, max: usize, fill_wait: Duration,
                            idx: Option<usize>) -> Option<Vec<T>> {
        let max = max.max(1);
        let mut g = self.inner.lock().unwrap();
        g = match self.await_first(g, fill_wait, max, idx) {
            Some(g) => g,
            None => return None,
        };
        let consumers = g.consumers.max(1) as u64;
        let budget = (g.cost_depth / consumers).max(1);
        let mut batch: Vec<T> = Vec::new();
        let mut batch_cost = 0u64;
        // Anchor: the weighted-fair head, unconditionally. An item at
        // lane position k is served within its lane's weighted share
        // of pulls, whatever its cost.
        if let Some((item, cost)) = g.pop_head() {
            batch.push(item);
            batch_cost = cost;
        }
        while batch.len() < max && !g.is_empty() {
            // LPT fill: the costliest item that keeps the batch within
            // budget; ties go to the higher class, then the oldest,
            // keeping equal-cost single-class traffic FIFO. Fills are
            // opportunistic across lanes and spend no WFQ credit —
            // fairness is enforced at the anchors.
            let mut pick: Option<(usize, usize, u64)> = None;
            for k in 0..N_PRIORITIES {
                for (i, (_, c)) in g.classes[k].iter().enumerate() {
                    if batch_cost.saturating_add(*c) > budget {
                        continue;
                    }
                    let better = match pick {
                        None => true,
                        Some((_, _, best)) => *c > best,
                    };
                    if better {
                        pick = Some((k, i, *c));
                    }
                }
            }
            let Some((k, i, cost)) = pick else { break };
            let (item, _) =
                g.classes[k].remove(i).expect("index in range");
            batch.push(item);
            batch_cost = batch_cost.saturating_add(cost);
        }
        g.record_pop(batch.len() as u64, batch_cost);
        drop(g);
        self.not_full.notify_all();
        Some(batch)
    }

    /// Shared phase-1/phase-2 of the batching pops: block for the
    /// first item (or closure/retirement), then hold the lock loop up
    /// to `fill_wait` while fewer than `max` items are queued. Returns
    /// the guard ready for extraction, or `None` when the queue closed
    /// empty — or, for an indexed consumer, when the consumer target
    /// retired it.
    fn await_first<'a>(&'a self,
                       mut g: std::sync::MutexGuard<'a, Inner<T>>,
                       fill_wait: Duration, max: usize,
                       idx: Option<usize>)
                       -> Option<std::sync::MutexGuard<'a, Inner<T>>> {
        loop {
            if let Some(i) = idx {
                if i >= g.consumer_target {
                    return None;
                }
            }
            if !g.is_empty() {
                break;
            }
            if g.closed {
                return None;
            }
            g = self.not_empty.wait(g).unwrap();
        }
        if !fill_wait.is_zero() {
            let deadline = Instant::now() + fill_wait;
            while g.len() < max && !g.closed {
                let now = Instant::now();
                if now >= deadline {
                    break;
                }
                let (guard, timeout) = self.not_empty
                    .wait_timeout(g, deadline - now).unwrap();
                g = guard;
                if timeout.timed_out() {
                    break;
                }
            }
        }
        Some(g)
    }

    /// Take everything immediately (no blocking). Used by the legacy
    /// dispatcher to account for stranded requests when its last worker
    /// dies.
    pub fn drain_now(&self) -> Vec<T> {
        let mut g = self.inner.lock().unwrap();
        let n = g.len();
        let (out, _) = g.take_front(n);
        drop(g);
        self.not_full.notify_all();
        out
    }

    /// Close the queue: wakes every waiter; pushes fail from now on,
    /// pops drain the remainder and then return `None`.
    pub fn close(&self) {
        let mut g = self.inner.lock().unwrap();
        g.closed = true;
        drop(g);
        self.not_empty.notify_all();
        self.not_full.notify_all();
    }

    pub fn stats(&self) -> QueueStats {
        let g = self.inner.lock().unwrap();
        QueueStats {
            capacity: self.capacity,
            depth: g.len(),
            depth_by_class: std::array::from_fn(|k| g.classes[k].len()),
            max_depth: g.max_depth,
            pushed: g.pushed,
            popped: g.popped,
            cost_capacity: self.cost_cap,
            cost_depth: g.cost_depth,
            max_cost_depth: g.max_cost_depth,
            cost_pushed: g.cost_pushed,
            cost_popped: g.cost_popped,
        }
    }
}

/// RAII token for one registered consumer: dropping it (worker exit,
/// normal or by failure/panic) decrements the live-consumer count, which
/// is what converts "all workers died" from an indefinite producer hang
/// into an immediate [`SubmitError::NoWorkers`].
pub struct ConsumerGuard<T> {
    queue: Arc<BoundedQueue<T>>,
}

impl<T> ConsumerGuard<T> {
    /// Adopt a consumer slot previously reserved with
    /// [`BoundedQueue::add_consumers`] (does *not* increment).
    pub fn adopt(queue: Arc<BoundedQueue<T>>) -> Self {
        Self { queue }
    }
}

impl<T> Drop for ConsumerGuard<T> {
    fn drop(&mut self) {
        self.queue.consumer_gone();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::thread;

    #[test]
    fn fifo_order_and_batching() {
        let q = BoundedQueue::new(8);
        q.add_consumers(1);
        for i in 0..5 {
            q.try_push(i).unwrap();
        }
        assert_eq!(q.pop_batch(3), Some(vec![0, 1, 2]));
        assert_eq!(q.pop_batch(3), Some(vec![3, 4]));
    }

    #[test]
    fn full_queue_reports_backpressure() {
        let q = BoundedQueue::new(2);
        q.add_consumers(1);
        q.try_push(1).unwrap();
        q.try_push(2).unwrap();
        assert_eq!(q.try_push(3),
                   Err(SubmitError::Full { capacity: 2,
                                           by_cost: false }));
        assert_eq!(q.stats().max_depth, 2);
    }

    #[test]
    fn closed_queue_rejects_and_drains() {
        let q = BoundedQueue::new(4);
        q.add_consumers(1);
        q.try_push(7).unwrap();
        q.close();
        assert_eq!(q.try_push(8), Err(SubmitError::Closed));
        assert_eq!(q.pop_batch(4), Some(vec![7]));
        assert_eq!(q.pop_batch(4), None);
    }

    #[test]
    fn no_consumers_rejects_push() {
        let q: Arc<BoundedQueue<u32>> = Arc::new(BoundedQueue::new(4));
        q.add_consumers(1);
        drop(ConsumerGuard::adopt(q.clone()));
        assert_eq!(q.try_push(1), Err(SubmitError::NoWorkers));
        assert_eq!(q.push(1), Err(SubmitError::NoWorkers));
    }

    #[test]
    fn blocking_push_unblocks_when_last_consumer_dies() {
        let q: Arc<BoundedQueue<u32>> = Arc::new(BoundedQueue::new(1));
        q.add_consumers(1);
        q.try_push(0).unwrap();
        let q2 = q.clone();
        let h = thread::spawn(move || q2.push(1)); // blocks: full
        thread::sleep(Duration::from_millis(20));
        drop(ConsumerGuard::adopt(q.clone())); // consumers -> 0
        assert_eq!(h.join().unwrap(), Err(SubmitError::NoWorkers));
    }

    #[test]
    fn blocking_push_waits_for_space() {
        let q: Arc<BoundedQueue<u32>> = Arc::new(BoundedQueue::new(1));
        q.add_consumers(1);
        q.try_push(0).unwrap();
        let q2 = q.clone();
        let h = thread::spawn(move || q2.push(1));
        thread::sleep(Duration::from_millis(20));
        assert_eq!(q.pop_batch(1), Some(vec![0]));
        assert_eq!(h.join().unwrap(), Ok(()));
        assert_eq!(q.pop_batch(1), Some(vec![1]));
    }

    #[test]
    fn pop_blocks_until_close() {
        let q: Arc<BoundedQueue<u32>> = Arc::new(BoundedQueue::new(2));
        let q2 = q.clone();
        let h = thread::spawn(move || q2.pop_batch(2));
        thread::sleep(Duration::from_millis(20));
        q.close();
        assert_eq!(h.join().unwrap(), None);
    }

    #[test]
    fn fill_window_groups_late_arrivals() {
        let q: Arc<BoundedQueue<u32>> = Arc::new(BoundedQueue::new(8));
        q.add_consumers(1);
        q.try_push(1).unwrap();
        let q2 = q.clone();
        let h = thread::spawn(move || {
            q2.pop_batch_wait(4, Duration::from_millis(200))
        });
        thread::sleep(Duration::from_millis(20));
        q.try_push(2).unwrap();
        q.try_push(3).unwrap();
        q.try_push(4).unwrap();
        assert_eq!(h.join().unwrap(), Some(vec![1, 2, 3, 4]));
    }

    #[test]
    fn stats_count_flow() {
        let q = BoundedQueue::new(4);
        q.add_consumers(1);
        for i in 0..4 {
            q.try_push(i).unwrap();
        }
        let _ = q.pop_batch(2);
        let s = q.stats();
        assert_eq!((s.pushed, s.popped, s.depth, s.max_depth), (4, 2, 2, 4));
    }

    // ---------------- cost accounting ----------------

    #[test]
    fn cost_flow_is_tracked() {
        let q = BoundedQueue::new(8);
        q.add_consumers(1);
        q.try_push_cost('a', 10).unwrap();
        q.try_push_cost('b', 30).unwrap();
        q.try_push_cost('c', 5).unwrap();
        let s = q.stats();
        assert_eq!((s.cost_depth, s.cost_pushed, s.max_cost_depth),
                   (45, 45, 45));
        assert_eq!(q.pop_batch(2), Some(vec!['a', 'b']));
        let s = q.stats();
        assert_eq!((s.cost_depth, s.cost_popped), (5, 40));
        assert_eq!(q.drain_now(), vec!['c']);
        assert_eq!(q.stats().cost_depth, 0);
        assert_eq!(q.stats().cost_popped, 45);
    }

    #[test]
    fn cost_cap_sheds_dense_bursts_earlier() {
        let q = BoundedQueue::with_cost_cap(100, 50);
        q.add_consumers(1);
        q.try_push_cost(0, 30).unwrap();
        q.try_push_cost(1, 20).unwrap(); // exactly at the cap
        assert_eq!(q.try_push_cost(2, 1),
                   Err(SubmitError::Full { capacity: 100,
                                           by_cost: true }),
                   "cost cap must reject although 98 item slots remain");
        let _ = q.pop_batch(1); // frees 30 cost units
        q.try_push_cost(2, 25).unwrap();
    }

    #[test]
    fn cost_cap_zero_means_uncapped() {
        // Same convention as the metrics endpoint and the CLI flag.
        let q = BoundedQueue::with_cost_cap(4, 0);
        q.add_consumers(1);
        assert_eq!(q.cost_capacity(), u64::MAX);
        for i in 0..4 {
            q.try_push_cost(i, u64::MAX / 8).unwrap();
        }
    }

    #[test]
    fn oversized_item_admitted_only_into_an_empty_queue() {
        let q = BoundedQueue::with_cost_cap(4, 50);
        q.add_consumers(1);
        q.try_push_cost(0, 10).unwrap();
        assert!(q.try_push_cost(1, 999).is_err(),
                "oversized item must wait for an empty queue");
        assert_eq!(q.pop_batch(1), Some(vec![0]));
        q.try_push_cost(1, 999).unwrap();
        assert_eq!(q.pop_batch(4), Some(vec![1]));
    }

    #[test]
    fn lpt_pop_anchors_on_head_then_fills_costliest() {
        let q = BoundedQueue::new(16);
        q.add_consumers(2);
        // Queued cost 100, 2 consumers -> budget 50 per pull.
        for (i, c) in [(0u32, 10u64), (1, 40), (2, 5), (3, 40), (4, 5)] {
            q.try_push_cost(i, c).unwrap();
        }
        // Head first (id 0, cost 10 — guaranteed progress), then the
        // costliest fit under the remaining 40: id 1 (40).
        assert_eq!(q.pop_batch_cost(16, Duration::ZERO),
                   Some(vec![0, 1]));
        // Remaining cost 50 -> budget 25: head id 2 (5), then the
        // costliest fit under 20 is id 4 (5); the 40 must wait.
        assert_eq!(q.pop_batch_cost(16, Duration::ZERO),
                   Some(vec![2, 4]));
        // The oversized 40 is taken alone (head always ships).
        assert_eq!(q.pop_batch_cost(16, Duration::ZERO), Some(vec![3]));
        assert_eq!(q.stats().cost_popped, 100);
    }

    #[test]
    fn cheap_head_is_never_starved_by_dense_newcomers() {
        // A near-zero-cost item at the head must ship on the next
        // pull even when every other queued item is costlier.
        let q = BoundedQueue::new(16);
        q.add_consumers(1);
        q.try_push_cost(0u32, 1).unwrap();
        for i in 1..8 {
            q.try_push_cost(i, 1000).unwrap();
        }
        let batch = q.pop_batch_cost(16, Duration::ZERO).unwrap();
        assert_eq!(batch[0], 0, "FIFO head must anchor the batch");
    }

    #[test]
    fn cost_pop_respects_max_items_and_close() {
        let q: Arc<BoundedQueue<u32>> = Arc::new(BoundedQueue::new(16));
        q.add_consumers(1);
        for i in 0..6 {
            q.try_push_cost(i, 1).unwrap();
        }
        // Budget 6 but max 4 items: the item cap still binds.
        let batch = q.pop_batch_cost(4, Duration::ZERO).unwrap();
        assert_eq!(batch.len(), 4);
        q.close();
        let rest = q.pop_batch_cost(4, Duration::ZERO).unwrap();
        assert_eq!(rest.len(), 2);
        assert_eq!(q.pop_batch_cost(4, Duration::ZERO), None);
    }

    // ---------------- priorities / WFQ ----------------

    #[test]
    fn priority_codes_roundtrip_and_parse() {
        for p in [Priority::High, Priority::Normal, Priority::Low] {
            assert_eq!(Priority::from_u8(p as u8), Some(p));
            assert_eq!(Priority::parse(p.as_str()), Some(p));
        }
        assert_eq!(Priority::from_u8(3), None);
        assert_eq!(Priority::parse("urgent"), None);
    }

    #[test]
    fn wfq_serves_backlogged_classes_by_weight() {
        let q = BoundedQueue::new(64);
        q.add_consumers(1);
        // 14 of each class: two full WRR rounds of credit per class.
        for i in 0..14u32 {
            q.try_push_cost_pri(100 + i, 1, Priority::High).unwrap();
            q.try_push_cost_pri(200 + i, 1, Priority::Normal).unwrap();
            q.try_push_cost_pri(300 + i, 1, Priority::Low).unwrap();
        }
        // One WRR round = 7 single-item pops: 4 high, 2 normal, 1 low,
        // each lane FIFO within itself.
        let mut round = Vec::new();
        for _ in 0..7 {
            round.extend(q.pop_batch(1).unwrap());
        }
        assert_eq!(round, vec![100, 101, 102, 103, 200, 201, 300]);
        let s = q.stats();
        assert_eq!(s.depth_by_class, [10, 12, 13]);
    }

    #[test]
    fn single_class_is_exact_fifo_whatever_the_class() {
        for pri in [Priority::High, Priority::Normal, Priority::Low] {
            let q = BoundedQueue::new(32);
            q.add_consumers(1);
            for i in 0..9u32 {
                q.try_push_cost_pri(i, 1, pri).unwrap();
            }
            let mut got = Vec::new();
            while let Some(b) = {
                if q.stats().depth == 0 { None }
                else { q.pop_batch(4) }
            } {
                got.extend(b);
            }
            assert_eq!(got, (0..9).collect::<Vec<_>>(),
                       "class {pri:?} must stay FIFO alone");
        }
    }

    #[test]
    fn empty_lane_credit_flows_to_occupied_lanes() {
        let q = BoundedQueue::new(32);
        q.add_consumers(1);
        // Only low-class traffic: it must be served every pull, not
        // once per 7.
        for i in 0..5u32 {
            q.try_push_cost_pri(i, 1, Priority::Low).unwrap();
        }
        assert_eq!(q.pop_batch(5), Some(vec![0, 1, 2, 3, 4]));
    }

    // ---------------- consumer target / retirement ----------------

    #[test]
    fn indexed_pop_retires_at_or_beyond_target() {
        let q: Arc<BoundedQueue<u32>> = Arc::new(BoundedQueue::new(8));
        q.add_consumers(3);
        q.try_push(1).unwrap();
        q.set_consumer_target(1);
        // Worker 2 retires even though items are queued; worker 0
        // keeps pulling.
        assert_eq!(q.pop_batch_wait_as(2, 4, Duration::ZERO), None);
        assert_eq!(q.pop_batch_cost_as(1, 4, Duration::ZERO), None);
        assert_eq!(q.pop_batch_wait_as(0, 4, Duration::ZERO),
                   Some(vec![1]));
    }

    #[test]
    fn target_drop_wakes_idle_indexed_consumer() {
        let q: Arc<BoundedQueue<u32>> = Arc::new(BoundedQueue::new(8));
        q.add_consumers(2);
        let q2 = q.clone();
        let h = thread::spawn(move || {
            q2.pop_batch_cost_as(1, 4, Duration::from_millis(5))
        });
        thread::sleep(Duration::from_millis(20));
        q.set_consumer_target(1); // retire worker 1 while it waits
        assert_eq!(h.join().unwrap(), None);
        // Un-indexed pops never retire.
        q.try_push(9).unwrap();
        assert_eq!(q.pop_batch(1), Some(vec![9]));
    }
}
