//! Worker: one thread owning a complete inference pipeline.
//!
//! Workers are *pull*-based: each loops on the shared bounded queue,
//! taking the next batch the moment it frees up, so a slow frame on one
//! worker never strands queued requests behind it. The heavyweight
//! read-only state — loaded [`NetworkWeights`], the APRC predictor and
//! the CBWS partitions — is built once by the service and shared via
//! [`SharedPipeline`] (`Arc`s); only the PJRT client, which must not
//! cross threads, is constructed inside the worker.
//!
//! A worker that fails — during pipeline construction or mid-request —
//! reports a [`WorkerEvent::Failed`] (with the ids of requests it had
//! in hand that are now lost) before exiting, so the service's
//! `collect` sees the failure instead of blocking forever on responses
//! that will never arrive, and the network gateway's router can fail
//! exactly the affected requests.

use std::path::PathBuf;
use std::sync::mpsc;
use std::sync::Arc;
use std::time::Instant;

use anyhow::{anyhow, Context, Result};

use super::queue::{BoundedQueue, ConsumerGuard};
use super::service::FrameSpec;
use crate::obs::trace::{self, Stage};
use crate::power::{EnergyModel, ResourceModel};
use crate::runtime::{Runtime, SnnRunner};
use crate::schedule::cbws::Cbws;
use crate::schedule::{baselines, AprcPredictor, Partition, Scheduler};
use crate::sim::{sweep, ArchConfig, Simulator, TraceSource};
use crate::snn::{encode_phased_temporal_u8, encode_phased_u8, NetKind,
                 NetworkWeights, SpikeMap, TemporalSpikeMap};

/// What a request carries: either raw pixels (the worker encodes) or a
/// pre-encoded spike train (the network client already ran the phased
/// encoder — the accelerator-side view of the host↔device boundary).
#[derive(Debug, Clone)]
pub enum FramePayload {
    /// u8 pixels, channel-major (C, H, W) flattened.
    Pixels(Vec<u8>),
    /// Bit-packed spike words: `timesteps` frames of
    /// `c * words_per_channel` u64 words each (the [`SpikeMap`] layout),
    /// concatenated in timestep order.
    Spikes { timesteps: usize, words: Vec<u64> },
}

impl FramePayload {
    /// Short human description for error messages.
    pub fn describe(&self) -> String {
        match self {
            FramePayload::Pixels(px) => format!("{} pixels", px.len()),
            FramePayload::Spikes { timesteps, words } => {
                format!("{} spike words over {timesteps} timesteps",
                        words.len())
            }
        }
    }
}

/// Trace identity a traced request carries through the queue into its
/// worker, which records the queue/batch/compute spans against it.
/// `Copy` baggage: the untraced path carries one `Option` discriminant
/// and never allocates.
#[derive(Debug, Clone, Copy)]
pub struct ReqTrace {
    pub trace_id: [u8; 16],
    /// Span all of this request's stage spans hang under (0 = root).
    pub parent: u64,
    /// Monotonic ns (trace epoch) when the request entered the queue.
    pub t_enqueue_ns: u64,
    /// Interned model index ([`crate::obs::trace::intern_model`]).
    pub model: u32,
}

/// One inference request: a raw image frame or a pre-encoded train,
/// tagged at admission with its predicted cost.
#[derive(Debug, Clone)]
pub struct Request {
    pub id: u64,
    pub payload: FramePayload,
    pub submitted: Instant,
    /// Predicted workload in cost units
    /// ([`RequestCostModel`](super::cost::RequestCostModel)) — what
    /// cost-aware batch assembly balances and cost-denominated
    /// admission sheds by.
    pub cost: u64,
    /// Span-timeline identity (`None` when tracing was disabled at
    /// admission).
    pub trace: Option<ReqTrace>,
    /// Reduced-timestep override set by the gateway's graceful-
    /// degradation policy (`--degrade reduce-t`): serve this frame at
    /// `Some(t)` timesteps instead of the model's full T. `None` =
    /// full-fidelity. Functional/temporal paths only — the golden/PJRT
    /// runtime has a fixed-T program and ignores the override.
    pub timesteps: Option<usize>,
}

/// Completed inference.
#[derive(Debug, Clone)]
pub struct Response {
    pub id: u64,
    /// Output spike counts (argmax = class for the classifier;
    /// thresholded = mask for the segmenter).
    pub output_counts: Vec<u32>,
    /// Simulated accelerator cycles for this frame.
    pub sim_cycles: u64,
    /// Simulated energy (J).
    pub energy_j: f64,
    /// Wall-clock service latency in microseconds (submit -> done).
    pub latency_us: u64,
    /// Wall-clock worker processing time in microseconds (the busy-time
    /// share this frame contributed to its worker).
    pub service_us: u64,
    /// Worker that served it.
    pub worker: usize,
    /// The cost the request was admitted at — echoed back so stats can
    /// score prediction against the simulated actuals (`sim_cycles`).
    pub predicted_cost: u64,
    /// Timesteps this frame was actually served at (== the model's T
    /// unless the degradation policy reduced it).
    pub timesteps: u32,
    /// True iff served at reduced T: the response went out cheaper and
    /// earlier than full fidelity; `energy_j` prices the shorter run.
    pub degraded: bool,
}

/// What a worker reports back to the service.
#[derive(Debug, Clone)]
pub enum WorkerEvent {
    /// One frame served successfully.
    Served(Response),
    /// The worker's pipeline failed (at build time or mid-request) and
    /// the worker is exiting. `lost` holds the ids of requests it had
    /// already pulled that will never produce a response (empty for
    /// build-time failures — nothing was pulled yet), so a response
    /// router can fail exactly those requests instead of guessing.
    Failed { worker: usize, error: String, lost: Vec<u64> },
    /// Legacy round-robin dispatch only: a batch was (or had been)
    /// dealt to a worker that cannot serve it — either the dispatcher
    /// found no live worker, or a failed worker drained it from its
    /// private channel. `lost` holds the stranded request ids.
    Undeliverable { lost: Vec<u64> },
}

/// Scheduling policy selector (serde-friendly mirror of the zoo).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Policy {
    Contiguous,
    RoundRobin,
    Random,
    SparTen,
    Cbws,
}

impl Policy {
    pub fn build(self) -> Box<dyn Scheduler> {
        match self {
            Policy::Contiguous => Box::new(baselines::Contiguous),
            Policy::RoundRobin => Box::new(baselines::RoundRobin),
            Policy::Random => Box::new(baselines::Random { seed: 0x5EED }),
            Policy::SparTen => Box::new(baselines::SparTen),
            Policy::Cbws => Box::new(Cbws::default()),
        }
    }

    pub fn parse(s: &str) -> Option<Self> {
        Some(match s {
            "contiguous" => Policy::Contiguous,
            "round_robin" | "roundrobin" => Policy::RoundRobin,
            "random" => Policy::Random,
            "sparten" => Policy::SparTen,
            "cbws" => Policy::Cbws,
            _ => return None,
        })
    }
}

/// Static configuration a worker thread builds its pipeline from.
#[derive(Debug, Clone)]
pub struct WorkerConfig {
    pub artifacts: PathBuf,
    pub kind: NetKind,
    pub aprc: bool,
    pub policy: Policy,
    pub arch: ArchConfig,
    pub energy: EnergyModel,
    /// Drive the simulator from PJRT golden traces (true) or the
    /// functional model (false, no PJRT needed).
    pub use_runtime: bool,
    /// Override timesteps (default: weights meta).
    pub timesteps: Option<usize>,
    /// Frame-parallel sweep width *inside* one worker for functional
    /// batches (`sim::sweep`). 1 = serial: the worker pool is usually
    /// the right parallel grain; raise this only when workers <<
    /// cores (e.g. one worker on a many-core host). Ignored on the
    /// golden/PJRT path — the client is not thread-safe.
    pub sweep_threads: usize,
    /// Serve functional frames through the bit-parallel temporal
    /// kernels (time-major spike storage, 64 timesteps per word) —
    /// bit-identical outputs and reports to the per-timestep path, so
    /// this is a pure speed knob (`--temporal-kernels`, default on).
    /// Ignored on the golden/PJRT path, which needs per-timestep
    /// buffers for the runtime anyway.
    pub temporal: bool,
}

impl WorkerConfig {
    pub fn variant_name(&self) -> &'static str {
        self.kind.variant_name(self.aprc)
    }
}

/// The read-only pipeline state every worker shares: weights loaded
/// once, workloads predicted once, channels scheduled once — and the
/// request-level cost model calibrated from the same APRC profile.
#[derive(Clone)]
pub struct SharedPipeline {
    pub net: Arc<NetworkWeights>,
    pub predictor: Arc<AprcPredictor>,
    /// One CBWS (or baseline) partition per layer.
    pub partitions: Arc<Vec<Partition>>,
    /// Per-request cost predictor (the serving-tier APRC extension).
    pub cost_model: Arc<super::cost::RequestCostModel>,
}

impl SharedPipeline {
    /// Load + schedule once, on the caller's thread: artifact problems
    /// fail fast at `Service::start` instead of inside N workers.
    pub fn build(cfg: &WorkerConfig) -> Result<Self> {
        let net = Arc::new(
            NetworkWeights::load(&cfg.artifacts, cfg.variant_name())
                .with_context(|| format!(
                    "loading weights for {}", cfg.variant_name()))?);
        let rates = default_input_rates(&net);
        let predictor =
            Arc::new(AprcPredictor::from_network(&net, &rates));
        let scheduler = cfg.policy.build();
        let partitions: Vec<Partition> = (0..net.layers.len())
            .map(|l| scheduler.assign(predictor.layer(l), cfg.arch.n_spes))
            .collect();
        let meta = &net.meta;
        let cost_model = Arc::new(super::cost::RequestCostModel::new(
            meta.in_shape[0], meta.in_shape[1], meta.in_shape[2],
            cfg.timesteps.unwrap_or(meta.timesteps), &predictor));
        Ok(Self {
            net,
            predictor,
            partitions: Arc::new(partitions),
            cost_model,
        })
    }
}

/// Where a worker gets its work from.
pub enum WorkSource {
    /// Pull batches from the shared bounded queue (the default,
    /// load-balanced path). With `lpt_fill: Some(window)` the pull is
    /// cost-balanced ([`BoundedQueue::pop_batch_cost`]): the worker
    /// waits out the grouping window, then assembles its fair share of
    /// the queued *predicted cost* LPT-style; `None` keeps the FIFO
    /// count-based pull as the comparison baseline.
    Shared {
        queue: Arc<BoundedQueue<Request>>,
        batch_max: usize,
        lpt_fill: Option<std::time::Duration>,
    },
    /// Receive pre-formed batches from the legacy round-robin
    /// dispatcher.
    Private(mpsc::Receiver<Vec<Request>>),
}

impl WorkSource {
    /// Pull the next batch as worker `idx`. Shared-queue pulls are
    /// indexed so a pool scale-down can retire this worker: once the
    /// queue's consumer target drops to `idx` or below the pull
    /// returns `None` — the same exit signal as a drained closed
    /// queue. Fixed pools never lower the target, so the index is
    /// inert there.
    fn next_batch(&self, idx: usize) -> Option<Vec<Request>> {
        match self {
            WorkSource::Shared { queue, batch_max, lpt_fill } => {
                match lpt_fill {
                    Some(window) => {
                        queue.pop_batch_cost_as(idx, *batch_max, *window)
                    }
                    None => queue.pop_batch_wait_as(
                        idx, *batch_max, std::time::Duration::ZERO),
                }
            }
            WorkSource::Private(rx) => rx.recv().ok(),
        }
    }

    fn consumer_guard(&self) -> Option<ConsumerGuard<Request>> {
        match self {
            WorkSource::Shared { queue, .. } => {
                Some(ConsumerGuard::adopt(queue.clone()))
            }
            WorkSource::Private(_) => None,
        }
    }
}

/// The spec a request is *served* at: the model spec with the
/// degradation policy's reduced-T override applied (clamped to
/// `[1, full T]`). Golden/PJRT workers (`fixed_t`) always serve full
/// fidelity — their compiled step program bakes T in.
fn effective_spec(req: &Request, spec: &FrameSpec, fixed_t: bool)
                  -> FrameSpec {
    let mut espec = *spec;
    if !fixed_t {
        if let Some(t) = req.timesteps {
            espec.timesteps = t.clamp(1, spec.timesteps);
        }
    }
    espec
}

/// Reject malformed frames before encoding — the encoder (or
/// `SpikeMap::from_words`) would assert (panic) and the loss would be
/// silent. Delegates to [`FrameSpec::validate`] — the *same* rules the
/// network gateway applies before submitting — so the two layers can
/// never drift apart; this is the in-process defense.
fn validate_frame(req: &Request, spec: &FrameSpec) -> Result<()> {
    spec.validate(&req.payload)
        .map_err(|e| anyhow!("frame {}: {e}", req.id))
}

/// Turn a validated payload into the per-timestep spike train. Stray
/// bits beyond `h*w` in a channel's last word (possible in
/// client-packed spike payloads) are masked off to keep the packing
/// invariant the popcount paths rely on.
fn encode_request(req: &Request, spec: &FrameSpec) -> Vec<SpikeMap> {
    let (c, h, w) = (spec.c, spec.h, spec.w);
    match &req.payload {
        FramePayload::Pixels(px) => {
            encode_phased_u8(px, c, h, w, spec.timesteps)
        }
        FramePayload::Spikes { timesteps: t, words } => {
            let wpc = spec.words_per_channel();
            let per_frame = c * wpc;
            let rem = (h * w) % 64;
            let mask: u64 = if rem == 0 { !0u64 } else { (1 << rem) - 1 };
            // Serving at reduced T truncates a full-T spike payload:
            // phased encoding orders timesteps most-significant-first,
            // so the prefix is exactly the reduced-precision train.
            let t = (*t).min(spec.timesteps);
            (0..t)
                .map(|step| {
                    let mut chunk = words
                        [step * per_frame..(step + 1) * per_frame]
                        .to_vec();
                    if wpc > 0 {
                        for ch in 0..c {
                            chunk[ch * wpc + wpc - 1] &= mask;
                        }
                    }
                    SpikeMap::from_words(c, h, w, chunk)
                })
                .collect()
        }
    }
}

/// Time-major twin of [`encode_request`]: the same payload lands
/// directly in the [`TemporalSpikeMap`] layout the bit-parallel kernels
/// consume — no per-timestep intermediate, no transpose pass. Stray
/// bits in client-packed spike payloads are masked exactly as in the
/// per-timestep path (`from_packed_steps` applies the spatial mask).
fn encode_request_temporal(req: &Request, spec: &FrameSpec)
                           -> TemporalSpikeMap {
    let (c, h, w) = (spec.c, spec.h, spec.w);
    match &req.payload {
        FramePayload::Pixels(px) => {
            encode_phased_temporal_u8(px, c, h, w, spec.timesteps)
        }
        FramePayload::Spikes { timesteps: t, words } => {
            // Same reduced-T truncation rule as `encode_request`.
            let t = (*t).min(spec.timesteps);
            let wpc = (h * w).div_ceil(64);
            TemporalSpikeMap::from_packed_steps(c, h, w, t,
                                                &words[..t * c * wpc])
        }
    }
}

/// Forward an error to the service before propagating it — the step
/// that turns a dying worker from a silent hang into a reported
/// failure. `lost` names the requests in hand that die with the worker.
fn check<T>(events: &mpsc::Sender<WorkerEvent>, worker: usize,
            lost: &[u64], res: Result<T>) -> Result<T> {
    if let Err(e) = &res {
        let _ = events.send(WorkerEvent::Failed {
            worker,
            error: format!("{e:#}"),
            lost: lost.to_vec(),
        });
    }
    res
}

/// Runs inside the worker thread: build the thread-local half of the
/// pipeline (PJRT lives entirely here), then serve until the work
/// source closes.
pub fn worker_loop(idx: usize, cfg: WorkerConfig, shared: SharedPipeline,
                   source: WorkSource, events: mpsc::Sender<WorkerEvent>)
                   -> Result<()> {
    // Held for the whole loop: its Drop is what tells producers this
    // worker is gone, even if we exit early on error.
    let _guard = source.consumer_guard();
    let res = serve(idx, &cfg, &shared, &source, &events);
    if res.is_err() {
        if let WorkSource::Private(rx) = &source {
            // Legacy round-robin mode: the dispatcher may already have
            // delivered batches into our private channel (and may keep
            // doing so — it only learns of our death if the channel
            // closes). Dropping the receiver here would silently lose
            // them and leave `collect` waiting forever, so keep
            // draining and report every delivered batch as lost until
            // the dispatcher hangs up.
            while let Ok(batch) = rx.recv() {
                let _ = events.send(WorkerEvent::Undeliverable {
                    lost: batch.iter().map(|r| r.id).collect(),
                });
            }
        }
    }
    res
}

fn serve(idx: usize, cfg: &WorkerConfig, shared: &SharedPipeline,
         source: &WorkSource, events: &mpsc::Sender<WorkerEvent>)
         -> Result<()> {
    let net: &NetworkWeights = &shared.net;
    let sim = check(events, idx, &[], Simulator::with_partitions(
        cfg.arch, net, shared.partitions.as_ref().clone()))?;
    let timesteps = cfg.timesteps.unwrap_or(net.meta.timesteps);

    // PJRT client lives entirely inside this thread.
    let runtime = match cfg.use_runtime {
        true => Some(check(events, idx, &[], Runtime::cpu())?),
        false => None,
    };
    let step = match &runtime {
        Some(rt) => Some(check(events, idx, &[],
                               rt.load_step(&cfg.artifacts, net))?),
        None => None,
    };
    // One runner reused for every request (run_frame resets membrane
    // state per frame), instead of a fresh allocation per request.
    let mut runner = match &step {
        Some(s) => Some(check(events, idx, &[], SnnRunner::new(s))?),
        None => None,
    };

    let spec = FrameSpec {
        kind: cfg.kind,
        c: net.meta.in_shape[0],
        h: net.meta.in_shape[1],
        w: net.meta.in_shape[2],
        timesteps,
    };
    while let Some(batch) = source.next_batch(idx) {
        // Queue spans close at pull time: submit -> this worker took
        // the batch. Traced requests only exist while tracing is on,
        // so the disabled path never reads the span clock.
        let t_pull = if trace::enabled() { trace::now_ns() } else { 0 };
        for req in &batch {
            if let Some(rt) = req.trace {
                trace::span(rt.trace_id, rt.parent, Stage::QueueWait,
                            rt.model, rt.t_enqueue_ns, false, 0, 0);
            }
        }
        // Functional batches can fan out over the frame-parallel sweep
        // when the worker is configured wider than 1; responses are
        // still emitted in batch order.
        if runner.is_none() && cfg.sweep_threads > 1 && batch.len() > 1 {
            serve_batch_sweep(idx, cfg, &sim, &spec, batch, t_pull,
                              events)?;
            continue;
        }
        let ids: Vec<u64> = batch.iter().map(|r| r.id).collect();
        let nbatch = ids.len() as u64;
        for (i, req) in batch.into_iter().enumerate() {
            // This request plus the rest of the batch die with us.
            let lost = &ids[i..];
            // Batch span: pull -> this request's compute start (the
            // intra-batch serialization wait); attrs = batch size,
            // position.
            if let Some(rt) = req.trace {
                trace::span(rt.trace_id, rt.parent, Stage::Batch,
                            rt.model, t_pull, false, nbatch, i as u64);
            }
            let t0 = Instant::now();
            let t_compute = if req.trace.is_some() {
                trace::now_ns()
            } else {
                0
            };
            check(events, idx, lost, validate_frame(&req, &spec))?;
            // Graceful degradation: serve at the reduced T the gateway
            // picked, by encoding against a shortened spec (payloads
            // stay validated against the full spec above). The PJRT
            // path has a fixed-T compiled program, so it ignores the
            // override — the gateway never degrades runtime models.
            let espec = effective_spec(&req, &spec, runner.is_some());
            let report = match runner.as_mut() {
                Some(r) => {
                    let inputs = encode_request(&req, &espec);
                    let trace = TraceSource::Golden(check(
                        events, idx, lost, r.run_frame(&inputs))?);
                    check(events, idx, lost,
                          sim.run_frame(&inputs, &trace))?
                }
                None if cfg.temporal => {
                    let tmap = encode_request_temporal(&req, &espec);
                    check(events, idx, lost,
                          sim.run_frame_temporal(&tmap))?
                }
                None => {
                    let inputs = encode_request(&req, &espec);
                    check(events, idx, lost,
                          sim.run_frame(&inputs,
                                        &TraceSource::Functional))?
                }
            };
            if let Some(rt) = req.trace {
                trace::span(rt.trace_id, rt.parent, Stage::Compute,
                            rt.model, t_compute, false,
                            report.total_cycles, req.cost);
            }
            let energy = cfg.energy.frame_energy(&report,
                                                 cfg.arch.clock_hz);
            let resp = Response {
                id: req.id,
                output_counts: report.output_counts.clone(),
                sim_cycles: report.total_cycles,
                energy_j: energy.total_j,
                latency_us: req.submitted.elapsed().as_micros() as u64,
                service_us: t0.elapsed().as_micros() as u64,
                worker: idx,
                predicted_cost: req.cost,
                timesteps: espec.timesteps as u32,
                degraded: espec.timesteps < spec.timesteps,
            };
            if events.send(WorkerEvent::Served(resp)).is_err() {
                return Ok(()); // collector gone; shut down
            }
        }
    }
    Ok(())
}

/// Serve one functional batch through the frame-parallel sweep
/// (`sim::sweep`): encode serially, simulate every frame across
/// `cfg.sweep_threads` scoped threads, then emit responses in batch
/// order — the output ordering is identical to the serial loop. A
/// malformed frame fails exactly like the serial loop: everything
/// before it is served, it and everything after are reported lost. A
/// sweep failure loses the whole batch.
fn serve_batch_sweep(idx: usize, cfg: &WorkerConfig, sim: &Simulator,
                     spec: &FrameSpec, batch: Vec<Request>,
                     t_pull: u64,
                     events: &mpsc::Sender<WorkerEvent>) -> Result<()> {
    let t0 = Instant::now();
    let t_sweep = if trace::enabled() { trace::now_ns() } else { 0 };
    let nbatch = batch.len() as u64;
    for (i, req) in batch.iter().enumerate() {
        // Sweep frames start together: every batch span closes at the
        // sweep launch instead of a per-request compute start.
        if let Some(rt) = req.trace {
            trace::span(rt.trace_id, rt.parent, Stage::Batch,
                        rt.model, t_pull, false, nbatch, i as u64);
        }
    }
    let ids: Vec<u64> = batch.iter().map(|r| r.id).collect();
    let first_bad = batch.iter()
        .position(|r| validate_frame(r, spec).is_err())
        .unwrap_or(batch.len());
    let good = &batch[..first_bad];
    // Per-request effective specs: a sweep batch can mix full-T and
    // degraded frames (the sweep is only ever functional, never PJRT).
    let especs: Vec<FrameSpec> = good.iter()
        .map(|r| effective_spec(r, spec, false))
        .collect();
    let reports = if cfg.temporal {
        let trains: Vec<TemporalSpikeMap> = good.iter().zip(&especs)
            .map(|(r, es)| encode_request_temporal(r, es))
            .collect();
        check(events, idx, &ids,
              sweep::run_frames_temporal(sim, &trains,
                                         cfg.sweep_threads))?
    } else {
        let trains: Vec<Vec<SpikeMap>> = good.iter().zip(&especs)
            .map(|(r, es)| encode_request(r, es))
            .collect();
        check(events, idx, &ids,
              sweep::run_frames_functional(sim, &trains,
                                           cfg.sweep_threads))?
    };
    // Frames ran concurrently: attribute an equal share of the batch
    // wall time to each response's busy-time contribution.
    let per_frame_us =
        (t0.elapsed().as_micros() as u64) / good.len().max(1) as u64;
    for ((req, report), espec) in
        good.iter().zip(&reports).zip(&especs)
    {
        if let Some(rt) = req.trace {
            trace::span(rt.trace_id, rt.parent, Stage::Compute,
                        rt.model, t_sweep, false,
                        report.total_cycles, req.cost);
        }
        let energy = cfg.energy.frame_energy(report, cfg.arch.clock_hz);
        let resp = Response {
            id: req.id,
            output_counts: report.output_counts.clone(),
            sim_cycles: report.total_cycles,
            energy_j: energy.total_j,
            latency_us: req.submitted.elapsed().as_micros() as u64,
            service_us: per_frame_us,
            worker: idx,
            predicted_cost: req.cost,
            timesteps: espec.timesteps as u32,
            degraded: espec.timesteps < spec.timesteps,
        };
        if events.send(WorkerEvent::Served(resp)).is_err() {
            return Ok(()); // collector gone; shut down
        }
    }
    if first_bad < batch.len() {
        check(events, idx, &ids[first_bad..],
              validate_frame(&batch[first_bad], spec))?;
    }
    Ok(())
}

/// Offline input-rate profile for the APRC predictor's first layer: mean
/// channel rates over a small calibration batch of the matching dataset.
pub fn default_input_rates(net: &NetworkWeights) -> Vec<f64> {
    let (c, h, w) = (net.meta.in_shape[0], net.meta.in_shape[1],
                     net.meta.in_shape[2]);
    let t = net.meta.timesteps;
    // `chunks_exact`: a trailing partial image (calibration set not a
    // multiple of this net's input size) would fail the encoder's
    // length assert.
    let images: Vec<Vec<f32>> = if c == 1 {
        let (imgs, _) = crate::data::gen_digits(0xCA11B, 8);
        imgs.chunks_exact(h * w)
            .map(|ch| ch.iter().map(|&v| v as f32 / 255.0).collect())
            .collect()
    } else {
        let (imgs, _) = crate::data::gen_road_scenes(0xCA11B, 4);
        // HWC u8 -> CHW f32
        imgs.chunks_exact(h * w * 3)
            .map(|img| {
                let mut out = vec![0.0f32; 3 * h * w];
                for y in 0..h {
                    for x in 0..w {
                        for ch in 0..3 {
                            out[ch * h * w + y * w + x] =
                                img[(y * w + x) * 3 + ch] as f32 / 255.0;
                        }
                    }
                }
                out
            })
            .collect()
    };
    crate::schedule::aprc::profile_input_rates(&images, c, h, w, t)
}

/// `ResourceModel` sanity check exposed for the service banner.
pub fn fits_device(arch: &ArchConfig) -> bool {
    ResourceModel::default().estimate(arch).fits_xc7z045()
}
