//! Worker: one thread owning a complete inference pipeline.

use std::path::PathBuf;
use std::sync::mpsc;
use std::time::Instant;

use anyhow::Result;

use crate::power::{EnergyModel, ResourceModel};
use crate::runtime::{Runtime, SnnRunner};
use crate::schedule::cbws::Cbws;
use crate::schedule::{baselines, Scheduler};
use crate::sim::{ArchConfig, Simulator, TraceSource};
use crate::snn::{encode_phased_u8, NetKind, NetworkWeights};

/// One inference request: a raw image frame.
#[derive(Debug, Clone)]
pub struct Request {
    pub id: u64,
    /// u8 pixels, channel-major (C, H, W) flattened.
    pub pixels: Vec<u8>,
    pub submitted: Instant,
}

/// Completed inference.
#[derive(Debug, Clone)]
pub struct Response {
    pub id: u64,
    /// Output spike counts (argmax = class for the classifier;
    /// thresholded = mask for the segmenter).
    pub output_counts: Vec<u32>,
    /// Simulated accelerator cycles for this frame.
    pub sim_cycles: u64,
    /// Simulated energy (J).
    pub energy_j: f64,
    /// Wall-clock service latency in microseconds.
    pub latency_us: u64,
    /// Worker that served it.
    pub worker: usize,
}

/// Scheduling policy selector (serde-friendly mirror of the zoo).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Policy {
    Contiguous,
    RoundRobin,
    Random,
    SparTen,
    Cbws,
}

impl Policy {
    pub fn build(self) -> Box<dyn Scheduler> {
        match self {
            Policy::Contiguous => Box::new(baselines::Contiguous),
            Policy::RoundRobin => Box::new(baselines::RoundRobin),
            Policy::Random => Box::new(baselines::Random { seed: 0x5EED }),
            Policy::SparTen => Box::new(baselines::SparTen),
            Policy::Cbws => Box::new(Cbws::default()),
        }
    }

    pub fn parse(s: &str) -> Option<Self> {
        Some(match s {
            "contiguous" => Policy::Contiguous,
            "round_robin" | "roundrobin" => Policy::RoundRobin,
            "random" => Policy::Random,
            "sparten" => Policy::SparTen,
            "cbws" => Policy::Cbws,
            _ => return None,
        })
    }
}

/// Static configuration a worker thread builds its pipeline from.
#[derive(Debug, Clone)]
pub struct WorkerConfig {
    pub artifacts: PathBuf,
    pub kind: NetKind,
    pub aprc: bool,
    pub policy: Policy,
    pub arch: ArchConfig,
    pub energy: EnergyModel,
    /// Drive the simulator from PJRT golden traces (true) or the
    /// functional model (false, no PJRT needed).
    pub use_runtime: bool,
    /// Override timesteps (default: weights meta).
    pub timesteps: Option<usize>,
}

impl WorkerConfig {
    pub fn variant_name(&self) -> &'static str {
        self.kind.variant_name(self.aprc)
    }
}

/// Runs inside the worker thread: build pipeline, serve until the
/// channel closes.
pub fn worker_loop(idx: usize, cfg: WorkerConfig,
                   rx: mpsc::Receiver<Vec<Request>>,
                   tx: mpsc::Sender<Response>) -> Result<()> {
    let net = NetworkWeights::load(&cfg.artifacts, cfg.variant_name())?;
    let rates = default_input_rates(&net);
    let predictor =
        crate::schedule::AprcPredictor::from_network(&net, &rates);
    let scheduler = cfg.policy.build();
    let sim = Simulator::new(cfg.arch, &net, scheduler.as_ref(),
                             &predictor);
    let timesteps = cfg.timesteps.unwrap_or(net.meta.timesteps);

    // PJRT client lives entirely inside this thread.
    let runtime = if cfg.use_runtime {
        Some(Runtime::cpu()?)
    } else {
        None
    };
    let step = match &runtime {
        Some(rt) => Some(rt.load_step(&cfg.artifacts, &net)?),
        None => None,
    };

    let (c, h, w) = (net.meta.in_shape[0], net.meta.in_shape[1],
                     net.meta.in_shape[2]);
    while let Ok(batch) = rx.recv() {
        for req in batch {
            let inputs = encode_phased_u8(&req.pixels, c, h, w, timesteps);
            let trace = match &step {
                Some(s) => {
                    let mut runner = SnnRunner::new(s)?;
                    TraceSource::Golden(runner.run_frame(&inputs)?)
                }
                None => TraceSource::Functional,
            };
            let report = sim.run_frame(&inputs, &trace)?;
            let energy = cfg.energy.frame_energy(&report,
                                                 cfg.arch.clock_hz);
            let resp = Response {
                id: req.id,
                output_counts: report.output_counts.clone(),
                sim_cycles: report.total_cycles,
                energy_j: energy.total_j,
                latency_us: req.submitted.elapsed().as_micros() as u64,
                worker: idx,
            };
            if tx.send(resp).is_err() {
                return Ok(()); // collector gone; shut down
            }
        }
    }
    Ok(())
}

/// Offline input-rate profile for the APRC predictor's first layer: mean
/// channel rates over a small calibration batch of the matching dataset.
pub fn default_input_rates(net: &NetworkWeights) -> Vec<f64> {
    let (c, h, w) = (net.meta.in_shape[0], net.meta.in_shape[1],
                     net.meta.in_shape[2]);
    let t = net.meta.timesteps;
    let images: Vec<Vec<f32>> = if c == 1 {
        let (imgs, _) = crate::data::gen_digits(0xCA11B, 8);
        imgs.chunks(h * w)
            .map(|ch| ch.iter().map(|&v| v as f32 / 255.0).collect())
            .collect()
    } else {
        let (imgs, _) = crate::data::gen_road_scenes(0xCA11B, 4);
        // HWC u8 -> CHW f32
        imgs.chunks(h * w * 3)
            .map(|img| {
                let mut out = vec![0.0f32; 3 * h * w];
                for y in 0..h {
                    for x in 0..w {
                        for ch in 0..3 {
                            out[ch * h * w + y * w + x] =
                                img[(y * w + x) * 3 + ch] as f32 / 255.0;
                        }
                    }
                }
                out
            })
            .collect()
    };
    crate::schedule::aprc::profile_input_rates(&images, c, h, w, t)
}

/// `ResourceModel` sanity check exposed for the service banner.
pub fn fits_device(arch: &ArchConfig) -> bool {
    ResourceModel::default().estimate(arch).fits_xc7z045()
}
