//! Per-model worker-pool autoscaling: the decision logic.
//!
//! This module is the *brain* only — a pure, transport-free hysteresis
//! controller mapping load observations to pool-size targets, so the
//! policy is hermetically unit-testable without threads, sockets or a
//! clock. The gateway owns the *body*: one control thread samples each
//! model's queue ([`QueueStats`](super::QueueStats) depth/cost
//! fractions) and windowed p99 (via
//! [`LatencyHistogram::percentile_since`](super::LatencyHistogram::percentile_since))
//! every tick, feeds an [`AutoscaleObs`] to that model's
//! [`Autoscaler`], and applies any returned target with
//! [`Service::scale_to`](super::Service::scale_to) — emitting
//! `skydiver_autoscale_{workers,events_total}` and a flight-recorder
//! scale span per event.
//!
//! The policy, deliberately boring (an SRE can predict it from the
//! flag names):
//!
//! * **Scale up** (toward `max`, doubling) after `sustain_ticks`
//!   consecutive ticks of breach — queue pressure at or above
//!   `high_load_frac`, or windowed p99 over `p99_slo_us`. Sustained
//!   breach, not a single sample, so one dense frame can't double the
//!   pool.
//! * **Scale down** (toward `min`, one worker at a time) after
//!   `idle_ticks` consecutive quiet ticks — pressure under a quarter
//!   of `high_load_frac` and p99 inside the SLO. Growing is fast,
//!   shrinking is slow: the asymmetry is the hysteresis.
//! * **Cool down** for `cooldown_ticks` after every scale event, so
//!   the controller observes the new pool before judging it.

use std::time::Duration;

/// Control-loop knobs (CLI: `--workers-min/--workers-max` and the
/// `--autoscale-*` family). `min == max` disables scaling.
#[derive(Debug, Clone)]
pub struct AutoscaleConfig {
    /// Pool floor (also the decay target after a burst).
    pub min: usize,
    /// Pool ceiling (`Service::scale_to` clamps to the slots actually
    /// reserved at start).
    pub max: usize,
    /// Control-loop sampling interval.
    pub tick: Duration,
    /// Queue-pressure breach threshold, as a fraction of capacity
    /// (max of item-count and cost-unit fractions).
    pub high_load_frac: f64,
    /// Windowed-p99 SLO in microseconds; 0 disables the latency
    /// trigger (pressure-only scaling).
    pub p99_slo_us: u64,
    /// Consecutive breach ticks required before scaling up.
    pub sustain_ticks: u32,
    /// Ticks to hold decisions after a scale event.
    pub cooldown_ticks: u32,
    /// Consecutive quiet ticks required before scaling down one step.
    pub idle_ticks: u32,
}

impl Default for AutoscaleConfig {
    fn default() -> Self {
        Self {
            min: 1,
            max: 1,
            tick: Duration::from_millis(100),
            high_load_frac: 0.75,
            p99_slo_us: 0,
            sustain_ticks: 2,
            cooldown_ticks: 3,
            idle_ticks: 10,
        }
    }
}

impl AutoscaleConfig {
    /// Whether this config actually scales (a degenerate `min == max`
    /// range never produces a decision).
    pub fn active(&self) -> bool {
        self.min < self.max
    }
}

/// One tick's load sample for one model.
#[derive(Debug, Clone, Copy, Default)]
pub struct AutoscaleObs {
    /// Queue depth as a fraction of item capacity, `[0, 1]`.
    pub depth_frac: f64,
    /// Queued predicted cost as a fraction of the cost cap, `[0, 1]`
    /// (0 when uncapped).
    pub cost_frac: f64,
    /// p99 latency over the last control window in microseconds
    /// (0 = no traffic this window).
    pub p99_us: u64,
    /// Current pool-size target.
    pub current: usize,
}

/// What [`Autoscaler::tick`] decided, with the trigger spelled out for
/// logs/spans.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ScaleDecision {
    /// Grow the pool to `to` (queue pressure or p99 breach sustained).
    Up { to: usize },
    /// Shrink the pool to `to` (sustained quiet).
    Down { to: usize },
}

impl ScaleDecision {
    pub fn target(self) -> usize {
        match self {
            ScaleDecision::Up { to } | ScaleDecision::Down { to } => to,
        }
    }
}

/// Hysteresis state for one model's pool. Feed it one [`AutoscaleObs`]
/// per tick; it returns a [`ScaleDecision`] only when the policy wants
/// the pool resized.
#[derive(Debug)]
pub struct Autoscaler {
    cfg: AutoscaleConfig,
    hot_ticks: u32,
    quiet_ticks: u32,
    cooldown: u32,
}

impl Autoscaler {
    pub fn new(cfg: AutoscaleConfig) -> Self {
        Self { cfg, hot_ticks: 0, quiet_ticks: 0, cooldown: 0 }
    }

    pub fn config(&self) -> &AutoscaleConfig {
        &self.cfg
    }

    /// Advance the control loop by one tick. Pure: no clock, no I/O —
    /// time is whatever cadence the caller invokes this at.
    pub fn tick(&mut self, obs: &AutoscaleObs) -> Option<ScaleDecision> {
        if !self.cfg.active() {
            return None;
        }
        let pressure = obs.depth_frac.max(obs.cost_frac);
        let p99_breach = self.cfg.p99_slo_us > 0
            && obs.p99_us > self.cfg.p99_slo_us;
        let breach = pressure >= self.cfg.high_load_frac || p99_breach;
        let quiet = pressure <= self.cfg.high_load_frac / 4.0
            && !p99_breach;
        if breach {
            self.hot_ticks += 1;
            self.quiet_ticks = 0;
        } else if quiet {
            self.quiet_ticks += 1;
            self.hot_ticks = 0;
        } else {
            // Mid-band: healthy under current capacity; hold.
            self.hot_ticks = 0;
            self.quiet_ticks = 0;
        }
        if self.cooldown > 0 {
            self.cooldown -= 1;
            return None;
        }
        if breach && self.hot_ticks >= self.cfg.sustain_ticks.max(1)
            && obs.current < self.cfg.max
        {
            // Double toward the ceiling: bursts are served in O(log)
            // scale events instead of one worker per sustain window.
            let to = (obs.current * 2).clamp(self.cfg.min.max(1),
                                             self.cfg.max);
            self.arm(ScaleDecision::Up { to })
        } else if quiet
            && self.quiet_ticks >= self.cfg.idle_ticks.max(1)
            && obs.current > self.cfg.min
        {
            // Decay one worker at a time: cheap insurance against the
            // burst returning right after it ended.
            let to = (obs.current - 1).max(self.cfg.min);
            self.arm(ScaleDecision::Down { to })
        } else {
            None
        }
    }

    fn arm(&mut self, d: ScaleDecision) -> Option<ScaleDecision> {
        self.hot_ticks = 0;
        self.quiet_ticks = 0;
        self.cooldown = self.cfg.cooldown_ticks;
        Some(d)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> AutoscaleConfig {
        AutoscaleConfig {
            min: 1,
            max: 8,
            sustain_ticks: 2,
            cooldown_ticks: 3,
            idle_ticks: 4,
            high_load_frac: 0.75,
            p99_slo_us: 10_000,
            ..Default::default()
        }
    }

    fn hot(current: usize) -> AutoscaleObs {
        AutoscaleObs { depth_frac: 0.9, cost_frac: 0.2, p99_us: 500,
                       current }
    }

    fn idle(current: usize) -> AutoscaleObs {
        AutoscaleObs { depth_frac: 0.0, cost_frac: 0.0, p99_us: 100,
                       current }
    }

    #[test]
    fn sustained_pressure_scales_up_doubling() {
        let mut a = Autoscaler::new(cfg());
        assert_eq!(a.tick(&hot(1)), None, "one hot tick is not enough");
        assert_eq!(a.tick(&hot(1)),
                   Some(ScaleDecision::Up { to: 2 }));
    }

    #[test]
    fn alternating_hot_quiet_never_scales() {
        // A flapping signal resets both counters each flip: neither
        // threshold can ever be met.
        let mut a = Autoscaler::new(cfg());
        for _ in 0..10 {
            assert_eq!(a.tick(&hot(2)), None);
            assert_eq!(a.tick(&idle(2)), None);
        }
    }

    #[test]
    fn p99_breach_alone_scales_up() {
        let mut a = Autoscaler::new(cfg());
        let obs = AutoscaleObs { depth_frac: 0.1, cost_frac: 0.1,
                                 p99_us: 50_000, current: 2 };
        assert_eq!(a.tick(&obs), None);
        assert_eq!(a.tick(&obs), Some(ScaleDecision::Up { to: 4 }));
    }

    #[test]
    fn p99_trigger_disabled_when_slo_zero() {
        let mut a = Autoscaler::new(AutoscaleConfig {
            p99_slo_us: 0, ..cfg()
        });
        let obs = AutoscaleObs { depth_frac: 0.1, cost_frac: 0.1,
                                 p99_us: 1_000_000, current: 2 };
        for _ in 0..10 {
            assert_eq!(a.tick(&obs), None);
        }
    }

    #[test]
    fn cooldown_holds_decisions_then_rearms() {
        let mut a = Autoscaler::new(cfg());
        a.tick(&hot(1));
        assert_eq!(a.tick(&hot(1)), Some(ScaleDecision::Up { to: 2 }));
        // cooldown_ticks = 3: the next 3 ticks are held even though
        // pressure persists...
        for _ in 0..3 {
            assert_eq!(a.tick(&hot(2)), None);
        }
        // ...then the (already re-sustained) breach fires again.
        assert_eq!(a.tick(&hot(2)), Some(ScaleDecision::Up { to: 4 }));
    }

    #[test]
    fn scale_up_clamps_at_max() {
        let mut a = Autoscaler::new(cfg());
        a.tick(&hot(6));
        assert_eq!(a.tick(&hot(6)), Some(ScaleDecision::Up { to: 8 }));
        for _ in 0..3 {
            a.tick(&hot(8));
        }
        for _ in 0..10 {
            assert_eq!(a.tick(&hot(8)), None,
                       "at the ceiling nothing more to do");
        }
    }

    #[test]
    fn sustained_quiet_decays_one_step_at_a_time() {
        let mut a = Autoscaler::new(cfg());
        for _ in 0..3 {
            assert_eq!(a.tick(&idle(4)), None);
        }
        assert_eq!(a.tick(&idle(4)),
                   Some(ScaleDecision::Down { to: 3 }));
        // Cooldown (3) then idle accumulation (4) before the next step.
        let mut decisions = Vec::new();
        for _ in 0..16 {
            if let Some(d) = a.tick(&idle(3)) {
                decisions.push(d);
            }
        }
        assert_eq!(decisions, vec![ScaleDecision::Down { to: 2 },
                                   ScaleDecision::Down { to: 1 }]);
        for _ in 0..10 {
            assert_eq!(a.tick(&idle(1)), None, "floor holds");
        }
    }

    #[test]
    fn midband_load_holds_steady() {
        let mut a = Autoscaler::new(cfg());
        let obs = AutoscaleObs { depth_frac: 0.4, cost_frac: 0.3,
                                 p99_us: 2_000, current: 4 };
        for _ in 0..50 {
            assert_eq!(a.tick(&obs), None,
                       "healthy mid-band must not flap");
        }
    }

    #[test]
    fn min_equals_max_is_inert() {
        let mut a = Autoscaler::new(AutoscaleConfig {
            min: 2, max: 2, ..cfg()
        });
        assert!(!a.config().active());
        for _ in 0..10 {
            assert_eq!(a.tick(&hot(2)), None);
        }
    }
}
