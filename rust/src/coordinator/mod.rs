//! Serving coordinator — the L3 request path.
//!
//! vLLM-router-shaped: an async front end accepts frames, a batcher
//! groups them (amortising DMA setup like the paper's host-managed
//! transfers), a round-robin router dispatches batches to a pool of
//! worker threads, each owning a full pipeline (its own PJRT client when
//! golden traces are requested + a configured [`Simulator`]). PJRT
//! handles are constructed *inside* each worker thread, so no Send/Sync
//! requirements leak out of the `xla` crate.

mod service;
mod stats;
pub mod worker;

pub use service::{Service, ServiceConfig};
pub use stats::{ServingReport, Stats};
pub use worker::{default_input_rates, Policy, Request, Response,
                 WorkerConfig};
