//! Serving coordinator — the L3 request path.
//!
//! A front end accepts frames into a shared bounded work queue; a pool
//! of worker threads — each owning a full pipeline (its own PJRT client
//! when golden traces are requested + a configured
//! [`Simulator`](crate::sim::Simulator)) —
//! pulls batches from it the moment they free up. PJRT handles are
//! constructed *inside* each worker thread, so no Send/Sync
//! requirements leak out of the `xla` crate; the heavyweight read-only
//! state (loaded weights, APRC predictions, CBWS partitions) is built
//! once and shared across the pool via `Arc`
//! ([`worker::SharedPipeline`]).
//!
//! ## Serving architecture
//!
//! ```text
//! submit/try_submit --> [ BoundedQueue (cap = queue_cap) ] <-- pull -- worker 0
//!                                                          <-- pull -- worker 1
//!         events: Served | Failed | Undeliverable ------------------------+
//!                                v                                        |
//!                        Service::collect  <------------------------------+
//! ```
//!
//! **Queue & dispatch.** The submission queue is bounded and shared.
//! In the default [`DispatchMode::WorkQueue`], each worker pulls up to
//! `batch_max` frames whenever it is idle — work-conserving, so a slow
//! frame on one worker never strands queued requests behind it (the
//! host-level analogue of the SPE workload balance the paper's CBWS
//! schedule buys, and what `ServingReport::host_balance_ratio`
//! measures). [`DispatchMode::RoundRobinBatch`] preserves the old
//! whole-batch round-robin dealing as a comparison baseline.
//!
//! **Backpressure.** [`Service::submit`] blocks while the queue is at
//! `queue_cap`; [`Service::try_submit`] instead returns
//! [`SubmitError::Full`] so callers can shed load. Both fail fast with
//! [`SubmitError::NoWorkers`] once every worker has exited — a
//! submission that nothing will ever drain is refused, not stranded.
//!
//! **Failure.** A worker that errors — while building its pipeline or
//! mid-request — sends [`worker::WorkerEvent::Failed`] (carrying the
//! ids of requests it had in hand that are now lost) before exiting.
//! [`Service::collect`] therefore always terminates: it returns an
//! error as soon as any accepted request is lost (a worker died
//! holding requests — those responses will never arrive) or every
//! worker has failed or exited, and
//! [`Service::collect_within`] adds a hard wall-clock bound on top.
//! Artifact problems (missing/corrupt weights) fail even earlier, at
//! [`Service::start`], because the pipeline is loaded once up front.
//!
//! **Shutdown.** [`Service::shutdown`] closes the queue; workers drain
//! what remains, exit, and are joined. The first worker error (build
//! failure, serving failure, panic) is returned to the caller.
//!
//! **Multi-model.** [`ModelRegistry`] stacks N named services into one
//! process (each with its own queue, pool and spec — per-model
//! isolation of backpressure and failure); the network gateway routes
//! wire model selectors to registry slots, with entry 0 as the default
//! model legacy v1 clients land on.
//!
//! **Request-level APRC.** Every submission is tagged at admission
//! with a predicted cost ([`cost::RequestCostModel`]: exact input
//! event count x an APRC-profile-calibrated gain).
//! [`DispatchMode::CostAware`] builds on the tags — cost-balanced
//! LPT batch assembly ([`BoundedQueue::pop_batch_cost`]) and
//! cost-denominated admission shedding — while the FIFO
//! [`DispatchMode::WorkQueue`] stays as the measured baseline.
//!
//! **Priorities & fairness.** Every submission also carries a
//! [`Priority`] class; the queue serves its three class lanes by
//! weighted round-robin ([`WFQ_WEIGHTS`]) so a flood in one
//! class delays — but never starves — the others. Single-class traffic
//! is exact FIFO, keeping the pre-priority baselines comparable.
//!
//! **Elastic pools.** Shared-queue pools can be resized at runtime
//! ([`Service::scale_to`], between the configured size and
//! `workers_max`): scale-down retires the highest-indexed workers on
//! their next pull, scale-up respawns empty slots. The decision logic
//! driving it lives in [`autoscale`] — a pure hysteresis controller
//! the gateway ticks against queue pressure and windowed p99
//! ([`LatencyHistogram::percentile_since`]).

pub mod autoscale;
pub mod cost;
mod queue;
mod registry;
mod service;
mod stats;
pub mod worker;

pub use autoscale::{AutoscaleConfig, AutoscaleObs, Autoscaler,
                    ScaleDecision};
pub use cost::{RequestCostModel, NOMINAL_FRAME_COST};
pub use queue::{BoundedQueue, Priority, QueueStats, SubmitError,
                N_PRIORITIES, WFQ_WEIGHTS};
pub use registry::{ModelEntry, ModelRegistry, ModelSpec, MAX_MODELS};
pub use service::{DispatchMode, FrameSpec, PoolScaler, Service,
                  ServiceConfig, ServiceHandle};
pub use stats::{host_balance_ratio, LatencyHistogram, ServingReport,
                Stats};
pub use worker::{default_input_rates, FramePayload, Policy, ReqTrace,
                 Request, Response, SharedPipeline, WorkerConfig,
                 WorkerEvent};
