//! Model registry: N named models behind one process, each a complete
//! [`Service`] (its own worker pool, bounded queue,
//! [`FrameSpec`](super::service::FrameSpec) and per-model stats
//! stream).
//!
//! The registry is the coordinator-side unlock for multi-model
//! serving: the network gateway resolves a wire model selector to a
//! registry slot and submits into *that* model's queue, so admission
//! control, backpressure and worker failure stay isolated per model —
//! an overloaded segmenter sheds segmenter traffic while the
//! classifier keeps serving. Entry 0 is always the **default model**:
//! the one v1 clients (no selector on the wire) and empty-selector v2
//! requests route to.

use anyhow::{bail, Result};

use super::service::{Service, ServiceConfig};
use super::worker::WorkerConfig;

/// Everything needed to mount one named model.
#[derive(Debug, Clone)]
pub struct ModelSpec {
    /// Registry name — what wire selectors and `--model` flags match.
    /// Must be non-empty, unique within the registry, and at most
    /// [`MAX_MODEL_NAME`](crate::server::protocol::MAX_MODEL_NAME)
    /// bytes (the wire selector length cap).
    pub name: String,
    pub scfg: ServiceConfig,
    pub wcfg: WorkerConfig,
}

/// One mounted model: its name and its running [`Service`].
pub struct ModelEntry {
    name: String,
    service: Service,
}

impl ModelEntry {
    pub fn name(&self) -> &str {
        &self.name
    }

    pub fn service(&self) -> &Service {
        &self.service
    }

    /// Mutable service access (the gateway takes each model's worker
    /// event stream through this).
    pub fn service_mut(&mut self) -> &mut Service {
        &mut self.service
    }
}

/// An ordered set of named, running models. Index 0 is the default.
pub struct ModelRegistry {
    entries: Vec<ModelEntry>,
}

/// Wire `nmodels` is a u8, and a registry beyond this is operator
/// error anyway.
pub const MAX_MODELS: usize = u8::MAX as usize;

impl ModelRegistry {
    /// Start every model's service. The first spec becomes the default
    /// model. Any artifact problem fails the whole registry here —
    /// before a port opens — with already-started services shut down.
    pub fn start(specs: Vec<ModelSpec>) -> Result<Self> {
        if specs.is_empty() {
            bail!("model registry needs at least one model");
        }
        if specs.len() > MAX_MODELS {
            bail!("model registry caps at {MAX_MODELS} models \
                   (asked for {})", specs.len());
        }
        for (i, s) in specs.iter().enumerate() {
            if s.name.is_empty() {
                bail!("model {i} has an empty name (the empty selector \
                       is reserved for default-model routing)");
            }
            if s.name.len() > crate::server::protocol::MAX_MODEL_NAME {
                bail!("model name '{}' exceeds the wire selector cap \
                       of {} bytes", s.name,
                      crate::server::protocol::MAX_MODEL_NAME);
            }
            if specs[..i].iter().any(|p| p.name == s.name) {
                bail!("duplicate model name '{}'", s.name);
            }
        }
        let mut entries: Vec<ModelEntry> = Vec::with_capacity(specs.len());
        for ModelSpec { name, scfg, wcfg } in specs {
            match Service::start(scfg, wcfg) {
                Ok(service) => {
                    entries.push(ModelEntry { name, service });
                }
                Err(e) => {
                    // Unwind the ones that already started; their
                    // shutdown errors are secondary to the start error.
                    for entry in entries {
                        let _ = entry.service.shutdown();
                    }
                    return Err(e.context(format!(
                        "starting model '{name}'")));
                }
            }
        }
        Ok(Self { entries })
    }

    /// Single-model registry — the v1 serving topology as a trivial
    /// registry, used by `Gateway::start_single` and the legacy tests.
    pub fn single(name: &str, scfg: ServiceConfig, wcfg: WorkerConfig)
                  -> Result<Self> {
        Self::start(vec![ModelSpec { name: name.to_string(), scfg, wcfg }])
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Model names in registry order (index 0 = default).
    pub fn names(&self) -> Vec<&str> {
        self.entries.iter().map(|e| e.name.as_str()).collect()
    }

    /// The default model's name (entry 0).
    pub fn default_name(&self) -> &str {
        &self.entries[0].name
    }

    /// Resolve a wire selector to a registry slot: the empty string is
    /// the default model, anything else matches by exact name.
    pub fn resolve(&self, selector: &str) -> Option<usize> {
        if selector.is_empty() {
            return Some(0);
        }
        self.entries.iter().position(|e| e.name == selector)
    }

    pub fn entry(&self, idx: usize) -> &ModelEntry {
        &self.entries[idx]
    }

    pub fn entry_mut(&mut self, idx: usize) -> &mut ModelEntry {
        &mut self.entries[idx]
    }

    pub fn iter(&self) -> impl Iterator<Item = &ModelEntry> {
        self.entries.iter()
    }

    /// Shut down every model's service; the first error wins but every
    /// service is still joined.
    pub fn shutdown(self) -> Result<()> {
        let mut first_err: Option<anyhow::Error> = None;
        for entry in self.entries {
            if let Err(e) = entry.service.shutdown() {
                first_err.get_or_insert(e);
            }
        }
        match first_err {
            None => Ok(()),
            Some(e) => Err(e),
        }
    }
}
