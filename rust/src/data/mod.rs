//! Synthetic datasets — rust ports of `python/compile/datasets.py`.
//!
//! The generators are integer-only on top of a [`SplitMix64`] PRNG, so the
//! byte streams match the python side exactly; `tests/cross_language.rs`
//! verifies the FNV-1a hashes recorded in `artifacts/meta.json`.

mod digits;
mod roads;

pub use digits::{gen_digit, gen_digits, DIGIT_H, DIGIT_W};
pub use roads::{gen_road_scene, gen_road_scenes, ROAD_H, ROAD_W};

/// splitmix64 PRNG (identical to `datasets.SplitMix64`).
#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
}

pub const GAMMA: u64 = 0x9E37_79B9_7F4A_7C15;

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(GAMMA);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }

    /// Uniform in `[0, n)` (modulo; bias irrelevant at these ranges and it
    /// keeps the python twin a one-liner).
    #[inline]
    pub fn next_below(&mut self, n: u64) -> u64 {
        self.next_u64() % n
    }

    /// Uniform in `[lo, hi]` inclusive.
    #[inline]
    pub fn next_range(&mut self, lo: i64, hi: i64) -> i64 {
        lo + self.next_below((hi - lo + 1) as u64) as i64
    }
}

/// FNV-1a 64-bit hash (identical to `datasets.fnv1a64`).
pub fn fnv1a64(data: &[u8]) -> u64 {
    let mut h: u64 = 0xCBF2_9CE4_8422_2325;
    for &b in data {
        h = (h ^ b as u64).wrapping_mul(0x0000_0100_0000_01B3);
    }
    h
}

/// Write a tiny synthetic single-conv classifier
/// (`classifier_aprc.weights.{json,bin}`) into `dir`: 8 filters of
/// 1x3x3 with varied magnitudes (so CBWS has real balancing work),
/// input `1 x side x side`, full padding. Shared by the hermetic
/// serving tests, the loopback serving bench, and the `skydiver synth`
/// command, so a gateway can be served (and CI can smoke-test it)
/// without `make artifacts`.
pub fn write_synthetic_classifier(dir: &std::path::Path, side: usize)
                                  -> anyhow::Result<()> {
    std::fs::create_dir_all(dir)?;
    let name = "classifier_aprc";
    let floats: Vec<f32> = (0..8 * 9)
        .map(|i| 0.04 + 0.012 * ((i % 9) as f32) + 0.01 * ((i / 9) as f32))
        .collect();
    let bytes: Vec<u8> =
        floats.iter().flat_map(|f| f.to_le_bytes()).collect();
    let hash = format!("{:016x}", fnv1a64(&bytes));
    let eh = side + 2 * 2 - 3 + 1; // pad 2, r 3
    let json = format!(
        r#"{{
  "name": "{name}", "aprc": true, "pad": 2, "vth": 0.5,
  "timesteps": 6, "in_shape": [1, {side}, {side}],
  "feature_sizes": [[8, {eh}, {eh}]], "dense_out": null,
  "total_floats": 72, "lambdas": [],
  "layers": [
    {{"kind": "conv", "shape": [8, 1, 3, 3], "offset": 0,
      "layer": 0, "pad": 2}}
  ],
  "blob_fnv1a64": "{hash}"
}}"#);
    std::fs::write(dir.join(format!("{name}.weights.json")), json)?;
    std::fs::write(dir.join(format!("{name}.weights.bin")), bytes)?;
    Ok(())
}

/// Write a tiny synthetic single-conv **segmenter**
/// (`segmenter_aprc.weights.{json,bin}`) into `dir`: 4 filters of
/// 3x3x3 with varied magnitudes, RGB input `3 x side x side`, full
/// padding, 4 timesteps (cheaper per frame than the classifier so a
/// mixed-traffic run exercises genuinely unequal workloads). The
/// segmenter twin of [`write_synthetic_classifier`] — multi-model
/// serve, tests, benches and CI smoke stay hermetic without
/// `make artifacts`.
pub fn write_synthetic_segmenter(dir: &std::path::Path, side: usize)
                                 -> anyhow::Result<()> {
    std::fs::create_dir_all(dir)?;
    let name = "segmenter_aprc";
    // 4 x 3 x 3 x 3 = 108 floats; vary within each filter and between
    // filters so CBWS sees a skewed per-channel workload.
    let floats: Vec<f32> = (0..4 * 27)
        .map(|i| {
            0.02 + 0.004 * ((i % 27) as f32) + 0.015 * ((i / 27) as f32)
        })
        .collect();
    let bytes: Vec<u8> =
        floats.iter().flat_map(|f| f.to_le_bytes()).collect();
    let hash = format!("{:016x}", fnv1a64(&bytes));
    let eh = side + 2 * 2 - 3 + 1; // pad 2, r 3
    let json = format!(
        r#"{{
  "name": "{name}", "aprc": true, "pad": 2, "vth": 0.5,
  "timesteps": 4, "in_shape": [3, {side}, {side}],
  "feature_sizes": [[4, {eh}, {eh}]], "dense_out": null,
  "total_floats": 108, "lambdas": [],
  "layers": [
    {{"kind": "conv", "shape": [4, 3, 3, 3], "offset": 0,
      "layer": 0, "pad": 2}}
  ],
  "blob_fnv1a64": "{hash}"
}}"#);
    std::fs::write(dir.join(format!("{name}.weights.json")), json)?;
    std::fs::write(dir.join(format!("{name}.weights.bin")), bytes)?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_reference_stream() {
        // First values for seed 42, cross-checked against the python twin.
        let mut r = SplitMix64::new(42);
        let a = r.next_u64();
        let b = r.next_u64();
        assert_ne!(a, b);
        // Determinism: same seed -> same stream.
        let mut r2 = SplitMix64::new(42);
        assert_eq!(r2.next_u64(), a);
        assert_eq!(r2.next_u64(), b);
    }

    #[test]
    fn next_range_bounds() {
        let mut r = SplitMix64::new(7);
        for _ in 0..1000 {
            let v = r.next_range(-3, 3);
            assert!((-3..=3).contains(&v));
        }
    }

    #[test]
    fn fnv_empty_is_offset_basis() {
        assert_eq!(fnv1a64(&[]), 0xCBF2_9CE4_8422_2325);
    }

    #[test]
    fn fnv_known_vector() {
        // FNV-1a("a") = 0xaf63dc4c8601ec8c
        assert_eq!(fnv1a64(b"a"), 0xaf63_dc4c_8601_ec8c);
    }
}
