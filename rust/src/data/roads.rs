//! Synthetic road scenes — the MLND-Capstone driving-video substitute.
//!
//! Exact port of `datasets.gen_road_scene(s)`: perspective road polygon
//! from a jittered vanishing point, dashed centre lane marking, sky
//! gradient and grass/road noise. Stream structure per scene: 10 header
//! draws then exactly one draw per pixel in (y, x) order.

use super::SplitMix64;

pub const ROAD_H: usize = 80;
pub const ROAD_W: usize = 160;

/// One scene. Returns (rgb `H*W*3`, mask `H*W` in {0,1}).
pub fn gen_road_scene(rng: &mut SplitMix64) -> (Vec<u8>, Vec<u8>) {
    let (h, w) = (ROAD_H as i64, ROAD_W as i64);
    let mut img = vec![0i64; (h * w * 3) as usize];
    let mut mask = vec![0u8; (h * w) as usize];

    let horizon = rng.next_range(20, 30);
    let vx = rng.next_range(60, 100);
    let bl = rng.next_range(10, 40);
    let br = rng.next_range(120, 150);
    let sky_r = rng.next_range(90, 140);
    let sky_g = rng.next_range(130, 180);
    let sky_b = rng.next_range(190, 240);
    let grass_g = rng.next_range(100, 150);
    let road_gray = rng.next_range(90, 130);
    let dash_phase = rng.next_below(12) as i64;

    let denom = (h - 1) - horizon;
    for y in 0..h {
        if y < horizon {
            let fade = (horizon - y) * 40 / horizon;
            for x in 0..w {
                let n = rng.next_below(8) as i64;
                let i = ((y * w + x) * 3) as usize;
                img[i] = sky_r - fade + n;
                img[i + 1] = sky_g - fade + n;
                img[i + 2] = sky_b - fade / 2 + n;
            }
        } else {
            let t = y - horizon;
            // div_euclid = python floor division (numerators go negative).
            let le = vx + ((bl - vx) * t).div_euclid(denom);
            let re = vx + ((br - vx) * t).div_euclid(denom);
            let cx = vx + (((bl + br).div_euclid(2) - vx) * t)
                .div_euclid(denom);
            let lane_w = 1 + t * 3 / denom;
            let dash_on = ((y + dash_phase) / 6) % 2 == 0;
            for x in 0..w {
                let n = rng.next_below(16) as i64;
                let i = ((y * w + x) * 3) as usize;
                if x >= le && x <= re {
                    mask[(y * w + x) as usize] = 1;
                    let mut v = road_gray + n;
                    if dash_on && (x - cx).abs() <= lane_w {
                        v = 220 + n;
                    }
                    if x == le || x == re {
                        v = 200 + n;
                    }
                    img[i] = v;
                    img[i + 1] = v;
                    img[i + 2] = v;
                } else {
                    img[i] = 60 + n;
                    img[i + 1] = grass_g + n;
                    img[i + 2] = 40 + n;
                }
            }
        }
    }
    let rgb = img.iter().map(|&v| v.clamp(0, 255) as u8).collect();
    (rgb, mask)
}

/// `count` scenes. Returns (rgb `count*H*W*3`, masks `count*H*W`).
pub fn gen_road_scenes(seed: u64, count: usize) -> (Vec<u8>, Vec<u8>) {
    let mut rng = SplitMix64::new(seed);
    let mut imgs = Vec::with_capacity(count * ROAD_H * ROAD_W * 3);
    let mut masks = Vec::with_capacity(count * ROAD_H * ROAD_W);
    for _ in 0..count {
        let (i, m) = gen_road_scene(&mut rng);
        imgs.extend_from_slice(&i);
        masks.extend_from_slice(&m);
    }
    (imgs, masks)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let (a, ma) = gen_road_scenes(2, 2);
        let (b, mb) = gen_road_scenes(2, 2);
        assert_eq!(a, b);
        assert_eq!(ma, mb);
    }

    #[test]
    fn mask_is_perspective_wedge() {
        let mut rng = SplitMix64::new(11);
        let (_, mask) = gen_road_scene(&mut rng);
        // Road fraction grows towards the bottom of the frame.
        let row_frac = |y: usize| -> usize {
            mask[y * ROAD_W..(y + 1) * ROAD_W].iter()
                .map(|&v| v as usize).sum()
        };
        assert_eq!(row_frac(0), 0, "sky has no road");
        assert!(row_frac(ROAD_H - 1) > row_frac(40));
        let total: usize = mask.iter().map(|&v| v as usize).sum();
        let frac = total as f64 / (ROAD_H * ROAD_W) as f64;
        assert!((0.05..0.6).contains(&frac), "road fraction {frac}");
    }
}
