//! Synthetic digit glyphs — the MNIST substitute (DESIGN.md §2).
//!
//! Exact port of `datasets.gen_digit(s)`: seven-segment-style strokes with
//! integer affine jitter, per-segment wobble, brightness variation and
//! additive noise. Stream structure per image: 4 header draws + 2 wobble
//! draws per segment + 784 noise draws.

use super::SplitMix64;

pub const DIGIT_H: usize = 28;
pub const DIGIT_W: usize = 28;

/// (y0, x0, y1, x1) endpoints of the seven segments A..G.
const SEG_COORDS: [(i64, i64, i64, i64); 7] = [
    (4, 9, 4, 19),    // A (top)
    (4, 19, 13, 19),  // B (top right)
    (13, 19, 23, 19), // C (bottom right)
    (23, 9, 23, 19),  // D (bottom)
    (13, 9, 23, 9),   // E (bottom left)
    (4, 9, 13, 9),    // F (top left)
    (13, 9, 13, 19),  // G (middle)
];

/// Segment indices (into `SEG_COORDS`) per digit 0..9.
const DIGIT_SEGMENTS: [&[usize]; 10] = [
    &[0, 1, 2, 3, 4, 5],    // 0: ABCDEF
    &[1, 2],                // 1: BC
    &[0, 1, 6, 4, 3],       // 2: ABGED
    &[0, 1, 6, 2, 3],       // 3: ABGCD
    &[5, 6, 1, 2],          // 4: FGBC
    &[0, 5, 6, 2, 3],       // 5: AFGCD
    &[0, 5, 6, 4, 2, 3],    // 6: AFGECD
    &[0, 1, 2],             // 7: ABC
    &[0, 1, 2, 3, 4, 5, 6], // 8: ABCDEFG
    &[0, 1, 2, 3, 5, 6],    // 9: ABCDFG
];

fn draw_thick_line(img: &mut [i64; DIGIT_H * DIGIT_W], y0: i64, x0: i64,
                   y1: i64, x1: i64, thickness: i64, value: i64) {
    let (h, w) = (DIGIT_H as i64, DIGIT_W as i64);
    let t0 = -(thickness / 2);
    let t1 = thickness / 2 + (thickness & 1);
    if y0 == y1 {
        for x in x0.min(x1)..=x0.max(x1) {
            for dy in t0..t1 {
                let y = y0 + dy;
                if (0..h).contains(&y) && (0..w).contains(&x) {
                    let p = &mut img[(y * w + x) as usize];
                    *p = (*p).max(value);
                }
            }
        }
    } else {
        for y in y0.min(y1)..=y0.max(y1) {
            for dx in t0..t1 {
                let x = x0 + dx;
                if (0..h).contains(&y) && (0..w).contains(&x) {
                    let p = &mut img[(y * w + x) as usize];
                    *p = (*p).max(value);
                }
            }
        }
    }
}

/// Render one 28x28 u8 glyph for `label`, consuming the documented PRNG
/// stream from `rng`.
pub fn gen_digit(rng: &mut SplitMix64, label: usize) -> [u8; DIGIT_H * DIGIT_W] {
    let mut img = [0i64; DIGIT_H * DIGIT_W];
    let dy = rng.next_range(-2, 2);
    let dx = rng.next_range(-3, 3);
    let thickness = rng.next_range(2, 3);
    let brightness = rng.next_range(170, 255);
    for &seg in DIGIT_SEGMENTS[label] {
        let (y0, x0, y1, x1) = SEG_COORDS[seg];
        let wy = rng.next_range(-1, 1);
        let wx = rng.next_range(-1, 1);
        draw_thick_line(&mut img, y0 + dy + wy, x0 + dx + wx, y1 + dy + wy,
                        x1 + dx + wx, thickness, brightness);
    }
    let mut out = [0u8; DIGIT_H * DIGIT_W];
    for i in 0..DIGIT_H * DIGIT_W {
        let n = rng.next_below(36) as i64;
        out[i] = (img[i] + n).min(255) as u8;
    }
    out
}

/// Generate `count` images with PRNG-chosen labels.
/// Returns (images flattened `count*784`, labels).
pub fn gen_digits(seed: u64, count: usize) -> (Vec<u8>, Vec<u8>) {
    let mut rng = SplitMix64::new(seed);
    let mut imgs = Vec::with_capacity(count * DIGIT_H * DIGIT_W);
    let mut labels = Vec::with_capacity(count);
    for _ in 0..count {
        let label = rng.next_below(10) as usize;
        labels.push(label as u8);
        imgs.extend_from_slice(&gen_digit(&mut rng, label));
    }
    (imgs, labels)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let (a, la) = gen_digits(1, 4);
        let (b, lb) = gen_digits(1, 4);
        assert_eq!(a, b);
        assert_eq!(la, lb);
    }

    #[test]
    fn glyphs_nonempty_and_bounded() {
        let (imgs, labels) = gen_digits(3, 20);
        for i in 0..20 {
            let img = &imgs[i * 784..(i + 1) * 784];
            let bright = img.iter().filter(|&&v| v > 100).count();
            assert!(bright > 20, "label {} too sparse", labels[i]);
            assert!(bright < 500, "label {} too dense", labels[i]);
        }
    }

    #[test]
    fn labels_cover_all_classes() {
        let (_, labels) = gen_digits(5, 200);
        for d in 0..10u8 {
            assert!(labels.contains(&d), "digit {d} missing");
        }
    }
}
