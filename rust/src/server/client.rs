//! Blocking, pipelining client for the Skydiver wire protocol.
//!
//! The client is deliberately thin: [`Client::send`] queues a request
//! frame (buffered), [`Client::recv`] flushes and blocks for the next
//! response frame. Because the protocol matches responses to requests
//! by id (not by order), a caller may keep any number of requests in
//! flight on one connection — that is the whole point of the
//! pipelined design, and what the load generator exercises.

use std::io::{BufReader, BufWriter, Write};
use std::net::{TcpStream, ToSocketAddrs};
use std::time::Duration;

use anyhow::{anyhow, bail, Context, Result};

use crate::snn::NetKind;

use super::protocol::{net_code, read_frame, write_frame, ErrorCode,
                      RequestBody, ResponseBody, WirePayload,
                      WireRequest, WireResponse, CONN_ERR_ID,
                      HEADER_LEN, KIND_RESPONSE, MAX_BODY};

/// The served network's frame contract, as reported by the `Info`
/// request.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ServerInfo {
    pub net: u8,
    pub c: usize,
    pub h: usize,
    pub w: usize,
    pub timesteps: usize,
}

impl ServerInfo {
    pub fn pixels_len(&self) -> usize {
        self.c * self.h * self.w
    }
}

/// One blocking connection to a gateway.
pub struct Client {
    reader: BufReader<TcpStream>,
    writer: BufWriter<TcpStream>,
}

impl Client {
    pub fn connect(addr: impl ToSocketAddrs) -> Result<Self> {
        let stream = TcpStream::connect(addr)
            .context("connecting to skydiver gateway")?;
        let _ = stream.set_nodelay(true);
        let reader = BufReader::new(
            stream.try_clone().context("cloning stream")?);
        Ok(Self { reader, writer: BufWriter::new(stream) })
    }

    /// Bound how long [`recv`](Self::recv) blocks (None = forever).
    pub fn set_read_timeout(&self, timeout: Option<Duration>)
                            -> Result<()> {
        self.reader.get_ref().set_read_timeout(timeout)?;
        Ok(())
    }

    /// Queue one request frame (buffered; flushed by
    /// [`recv`](Self::recv) or [`flush`](Self::flush)). Refuses a
    /// request whose body would exceed the protocol's `MAX_BODY` (the
    /// server would treat the oversized frame as stream corruption and
    /// drop the whole connection) or that uses the reserved
    /// connection-error id.
    pub fn send(&mut self, req: &WireRequest) -> Result<()> {
        if req.id == CONN_ERR_ID {
            bail!("request id {CONN_ERR_ID} is reserved for \
                   connection-level errors");
        }
        let frame = req.encode();
        if frame.len() - HEADER_LEN > MAX_BODY {
            bail!("request body {} bytes exceeds protocol cap {} — \
                   the server would drop the connection",
                  frame.len() - HEADER_LEN, MAX_BODY);
        }
        write_frame(&mut self.writer, &frame)
            .context("writing request frame")?;
        Ok(())
    }

    pub fn flush(&mut self) -> Result<()> {
        self.writer.flush().context("flushing request frames")?;
        Ok(())
    }

    /// Flush queued requests and block for the next response frame.
    /// Responses may arrive in any order — match on
    /// [`WireResponse::id`].
    pub fn recv(&mut self) -> Result<WireResponse> {
        self.flush()?;
        let body = read_frame(&mut self.reader, KIND_RESPONSE)
            .map_err(|e| anyhow!("reading response frame: {e}"))?
            .ok_or_else(|| anyhow!("server closed the connection"))?;
        WireResponse::decode_body(&body)
            .map_err(|e| anyhow!("decoding response: {e}"))
    }

    /// Convenience: one pixel-frame inference round trip.
    pub fn infer_pixels(&mut self, id: u64, net: NetKind,
                        pixels: Vec<u8>) -> Result<WireResponse> {
        self.send(&WireRequest {
            id,
            body: RequestBody::Infer {
                net: net_code(net),
                payload: WirePayload::Pixels(pixels),
            },
        })?;
        self.recv()
    }

    /// Convenience: one pre-encoded-spike inference round trip.
    pub fn infer_spikes(&mut self, id: u64, net: NetKind,
                        timesteps: u32, words: Vec<u64>)
                        -> Result<WireResponse> {
        self.send(&WireRequest {
            id,
            body: RequestBody::Infer {
                net: net_code(net),
                payload: WirePayload::Spikes { timesteps, words },
            },
        })?;
        self.recv()
    }

    /// Fetch the served net's frame contract.
    pub fn info(&mut self) -> Result<ServerInfo> {
        self.send(&WireRequest { id: 0, body: RequestBody::Info })?;
        match self.recv()?.body {
            ResponseBody::Info { net, c, h, w, timesteps } => {
                Ok(ServerInfo {
                    net,
                    c: c as usize,
                    h: h as usize,
                    w: w as usize,
                    timesteps: timesteps as usize,
                })
            }
            ResponseBody::Error { code, detail } => {
                bail!("info failed: {} {detail}", code.as_str())
            }
            other => bail!("unexpected info response: {other:?}"),
        }
    }

    /// Fetch the Prometheus-style metrics exposition.
    pub fn metrics(&mut self) -> Result<String> {
        self.send(&WireRequest { id: 0, body: RequestBody::Metrics })?;
        match self.recv()?.body {
            ResponseBody::Metrics { text } => Ok(text),
            ResponseBody::Error { code, detail } => {
                bail!("metrics failed: {} {detail}", code.as_str())
            }
            other => bail!("unexpected metrics response: {other:?}"),
        }
    }

    /// Ask the gateway to drain and shut down; returns once the ack
    /// arrives.
    pub fn shutdown_server(&mut self) -> Result<()> {
        self.send(&WireRequest { id: 0, body: RequestBody::Shutdown })?;
        match self.recv()?.body {
            ResponseBody::ShutdownAck => Ok(()),
            ResponseBody::Error { code, detail } => {
                bail!("shutdown refused: {} {detail}", code.as_str())
            }
            other => bail!("unexpected shutdown response: {other:?}"),
        }
    }
}

/// Pull the typed error (if any) out of a response.
pub fn response_error(resp: &WireResponse)
                      -> Option<(ErrorCode, &str)> {
    match &resp.body {
        ResponseBody::Error { code, detail } => {
            Some((*code, detail.as_str()))
        }
        _ => None,
    }
}
