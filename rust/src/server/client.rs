//! Blocking, pipelining client for the Skydiver wire protocol.
//!
//! The client is deliberately thin: [`Client::send`] queues a request
//! frame (buffered), [`Client::recv`] flushes and blocks for the next
//! response frame. Because the protocol matches responses to requests
//! by id (not by order), a caller may keep any number of requests in
//! flight on one connection — that is the whole point of the
//! pipelined design, and what the load generator exercises.
//!
//! The client speaks **protocol v2** by default: inference and info
//! requests carry a model selector (a registry name; the empty string
//! means the server's default model). [`Client::connect_v1`] pins a
//! connection to the legacy v1 encoding — useful for compatibility
//! tests and for talking to pre-v2 servers — in which case requests
//! must not name a model ([`Client::send`] refuses).

use std::io::{BufReader, BufWriter, Write};
use std::net::{TcpStream, ToSocketAddrs};
use std::time::Duration;

use anyhow::{anyhow, bail, Context, Result};

use super::protocol::{read_frame, write_frame, DegradeInfo, ErrorCode,
                      ModelLoad, ProtoError, RequestBody, RequestExts,
                      ResponseBody, WirePayload, WireRequest,
                      WireResponse, CONN_ERR_ID, HEADER_LEN,
                      KIND_RESPONSE, MAX_BODY, NET_ANY, V1, V2};

/// A served model's frame contract, as reported by the `Info` request.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ServerInfo {
    pub net: u8,
    pub c: usize,
    pub h: usize,
    pub w: usize,
    pub timesteps: usize,
    /// Resolved model name (empty when the server answered in v1).
    pub model: String,
    /// How many models the server mounts (1 under v1).
    pub nmodels: usize,
}

impl ServerInfo {
    pub fn pixels_len(&self) -> usize {
        self.c * self.h * self.w
    }
}

/// One blocking connection to a gateway.
pub struct Client {
    reader: BufReader<TcpStream>,
    writer: BufWriter<TcpStream>,
    version: u8,
    /// Net code of the last `Info` response — what a v1-pinned
    /// connection's convenience helpers put in the `net` byte (a v1
    /// server validates it, and `NET_ANY` is a v2-only idiom it would
    /// reject).
    info_net: Option<u8>,
}

impl Client {
    /// Connect speaking the current protocol (v2).
    pub fn connect(addr: impl ToSocketAddrs) -> Result<Self> {
        Self::connect_version(addr, V2)
    }

    /// Connect pinned to the legacy v1 encoding (single-model; no
    /// model selectors on the wire).
    pub fn connect_v1(addr: impl ToSocketAddrs) -> Result<Self> {
        Self::connect_version(addr, V1)
    }

    /// Connect (v2) with a hard connect deadline instead of the OS
    /// default (which can be minutes against a blackholed host). The
    /// deadline applies per resolved address; resolution failures and
    /// exhausted candidates surface as errors, a deadline as
    /// [`ProtoError::TimedOut`] in the chain.
    pub fn connect_timeout(addr: impl ToSocketAddrs,
                           timeout: Duration) -> Result<Self> {
        let mut last: Option<anyhow::Error> = None;
        for sa in addr.to_socket_addrs()
            .context("resolving gateway address")?
        {
            match TcpStream::connect_timeout(&sa, timeout) {
                Ok(stream) => return Self::from_stream(stream, V2),
                Err(e) if e.kind() == std::io::ErrorKind::TimedOut
                    || e.kind() == std::io::ErrorKind::WouldBlock =>
                {
                    last = Some(anyhow::Error::new(ProtoError::TimedOut)
                        .context(format!("connecting to {sa}")));
                }
                Err(e) => {
                    last = Some(anyhow::Error::new(e)
                        .context(format!("connecting to {sa}")));
                }
            }
        }
        Err(last.unwrap_or_else(|| {
            anyhow!("gateway address resolved to no candidates")
        }))
    }

    fn connect_version(addr: impl ToSocketAddrs, version: u8)
                       -> Result<Self> {
        let stream = TcpStream::connect(addr)
            .context("connecting to skydiver gateway")?;
        Self::from_stream(stream, version)
    }

    fn from_stream(stream: TcpStream, version: u8) -> Result<Self> {
        let _ = stream.set_nodelay(true);
        let reader = BufReader::new(
            stream.try_clone().context("cloning stream")?);
        Ok(Self {
            reader,
            writer: BufWriter::new(stream),
            version,
            info_net: None,
        })
    }

    /// The `net` byte the convenience helpers send: `NET_ANY` on v2
    /// (the model selector addresses the net), the last `Info`'d net
    /// code on v1 — fetch [`info`](Self::info) first on a v1-pinned
    /// connection (payload sizing needs it anyway); without it the v1
    /// default is the classifier code, matching pre-v2 deployments.
    fn default_net(&self) -> u8 {
        match self.version {
            V1 => self.info_net.unwrap_or(0),
            _ => NET_ANY,
        }
    }

    /// The protocol version this connection encodes requests with.
    pub fn version(&self) -> u8 {
        self.version
    }

    /// Bound how long [`recv`](Self::recv) blocks (None = forever).
    pub fn set_read_timeout(&self, timeout: Option<Duration>)
                            -> Result<()> {
        self.reader.get_ref().set_read_timeout(timeout)?;
        Ok(())
    }

    /// Queue one request frame (buffered; flushed by
    /// [`recv`](Self::recv) or [`flush`](Self::flush)). Refuses a
    /// request whose body would exceed the protocol's `MAX_BODY` (the
    /// server would treat the oversized frame as stream corruption and
    /// drop the whole connection), that uses the reserved
    /// connection-error id, or — on a v1 connection — that names a
    /// model (not expressible in v1).
    pub fn send(&mut self, req: &WireRequest) -> Result<()> {
        self.send_with_exts(req, &RequestExts::default())
    }

    /// Like [`send`](Self::send), with trailing request extensions
    /// (scheduling priority, trace context). Extensions are v2-only:
    /// a v1-pinned connection refuses a non-empty bundle rather than
    /// silently dropping the caller's intent.
    pub fn send_with_exts(&mut self, req: &WireRequest,
                          exts: &RequestExts) -> Result<()> {
        if req.id == CONN_ERR_ID {
            bail!("request id {CONN_ERR_ID} is reserved for \
                   connection-level errors");
        }
        let frame = match self.version {
            V1 => {
                if !exts.is_empty() {
                    bail!("request extensions are not expressible in \
                           protocol v1");
                }
                req.encode_v1()
            }
            _ => req.encode_with_exts(exts),
        }.map_err(|e: ProtoError| anyhow!("encoding request: {e}"))?;
        if frame.len() - HEADER_LEN > MAX_BODY {
            bail!("request body {} bytes exceeds protocol cap {} — \
                   the server would drop the connection",
                  frame.len() - HEADER_LEN, MAX_BODY);
        }
        write_frame(&mut self.writer, &frame)
            .context("writing request frame")?;
        Ok(())
    }

    pub fn flush(&mut self) -> Result<()> {
        self.writer.flush().context("flushing request frames")?;
        Ok(())
    }

    /// Flush queued requests and block for the next response frame.
    /// Responses may arrive in any order — match on
    /// [`WireResponse::id`]. The typed [`ProtoError`] is preserved as
    /// the error source, so callers can
    /// `err.downcast_ref::<ProtoError>()` — e.g. to tell a
    /// [`ProtoError::TimedOut`] read deadline (set via
    /// [`set_read_timeout`](Self::set_read_timeout)) from hard IO
    /// damage.
    pub fn recv(&mut self) -> Result<WireResponse> {
        self.recv_ext().map(|(resp, _)| resp)
    }

    /// Like [`recv`](Self::recv), also surfacing a trailing
    /// [`DegradeInfo`] extension if the server served this request at
    /// reduced timesteps under overload (`None` for a full-precision
    /// answer or any non-`Infer` response).
    pub fn recv_ext(&mut self)
                    -> Result<(WireResponse, Option<DegradeInfo>)> {
        self.flush()?;
        let (ver, body) = read_frame(&mut self.reader, KIND_RESPONSE)
            .map_err(|e| anyhow::Error::new(e)
                .context("reading response frame"))?
            .ok_or_else(|| anyhow!("server closed the connection"))?;
        WireResponse::decode_body_ext(ver, &body)
            .map_err(|e| anyhow::Error::new(e)
                .context("decoding response"))
    }

    /// Convenience: one pixel-frame inference round trip against
    /// `model` (`""` = the server's default model).
    pub fn infer_pixels(&mut self, id: u64, model: &str,
                        pixels: Vec<u8>) -> Result<WireResponse> {
        self.send(&WireRequest {
            id,
            body: RequestBody::Infer {
                net: self.default_net(),
                model: model.to_string(),
                payload: WirePayload::Pixels(pixels),
            },
        })?;
        self.recv()
    }

    /// Convenience: one pre-encoded-spike inference round trip against
    /// `model` (`""` = default).
    pub fn infer_spikes(&mut self, id: u64, model: &str,
                        timesteps: u32, words: Vec<u64>)
                        -> Result<WireResponse> {
        self.send(&WireRequest {
            id,
            body: RequestBody::Infer {
                net: self.default_net(),
                model: model.to_string(),
                payload: WirePayload::Spikes { timesteps, words },
            },
        })?;
        self.recv()
    }

    /// Fetch the default model's frame contract.
    pub fn info(&mut self) -> Result<ServerInfo> {
        self.info_model("")
    }

    /// Fetch a named model's frame contract (`""` = default).
    pub fn info_model(&mut self, model: &str) -> Result<ServerInfo> {
        self.send(&WireRequest {
            id: 0,
            body: RequestBody::Info { model: model.to_string() },
        })?;
        match self.recv()?.body {
            ResponseBody::Info {
                net, c, h, w, timesteps, model, nmodels,
            } => {
                self.info_net = Some(net);
                Ok(ServerInfo {
                    net,
                    c: c as usize,
                    h: h as usize,
                    w: w as usize,
                    timesteps: timesteps as usize,
                    model,
                    nmodels: nmodels as usize,
                })
            }
            ResponseBody::Error { code, detail } => {
                bail!("info failed: {} {detail}", code.as_str())
            }
            other => bail!("unexpected info response: {other:?}"),
        }
    }

    /// One health/load probe round trip (v2 only): every mounted
    /// model's queue-cost depth, as the cluster router consumes it.
    pub fn heartbeat(&mut self) -> Result<Vec<ModelLoad>> {
        if self.version == V1 {
            bail!("heartbeat requires protocol v2");
        }
        self.send(&WireRequest { id: 0,
                                 body: RequestBody::Heartbeat })?;
        match self.recv()?.body {
            ResponseBody::Heartbeat { models } => Ok(models),
            ResponseBody::Error { code, detail } => {
                bail!("heartbeat failed: {} {detail}", code.as_str())
            }
            other => bail!("unexpected heartbeat response: {other:?}"),
        }
    }

    /// Fetch the peer's flight-recorder dump (v2 only): Chrome
    /// trace-event JSON of recent / slowest / errored request traces
    /// (`{"traceEvents":[]}` when the peer has tracing disabled).
    pub fn trace_dump(&mut self) -> Result<String> {
        if self.version == V1 {
            bail!("trace dump requires protocol v2");
        }
        self.send(&WireRequest { id: 0, body: RequestBody::Trace })?;
        match self.recv()?.body {
            ResponseBody::Trace { json } => Ok(json),
            ResponseBody::Error { code, detail } => {
                bail!("trace dump failed: {} {detail}", code.as_str())
            }
            other => bail!("unexpected trace response: {other:?}"),
        }
    }

    /// Fetch the Prometheus-style metrics exposition.
    pub fn metrics(&mut self) -> Result<String> {
        self.send(&WireRequest { id: 0, body: RequestBody::Metrics })?;
        match self.recv()?.body {
            ResponseBody::Metrics { text } => Ok(text),
            ResponseBody::Error { code, detail } => {
                bail!("metrics failed: {} {detail}", code.as_str())
            }
            other => bail!("unexpected metrics response: {other:?}"),
        }
    }

    /// Ask the gateway to drain and shut down; returns once the ack
    /// arrives.
    pub fn shutdown_server(&mut self) -> Result<()> {
        self.send(&WireRequest { id: 0, body: RequestBody::Shutdown })?;
        match self.recv()?.body {
            ResponseBody::ShutdownAck => Ok(()),
            ResponseBody::Error { code, detail } => {
                bail!("shutdown refused: {} {detail}", code.as_str())
            }
            other => bail!("unexpected shutdown response: {other:?}"),
        }
    }
}

/// Pull the typed error (if any) out of a response.
pub fn response_error(resp: &WireResponse)
                      -> Option<(ErrorCode, &str)> {
    match &resp.body {
        ResponseBody::Error { code, detail } => {
            Some((*code, detail.as_str()))
        }
        _ => None,
    }
}
