//! Minimal std-only readiness primitives for the gateway's sharded
//! event loops: a `poll(2)` wrapper, a self-pipe waker, a growable
//! receive buffer for incremental frame decode, and an in-process
//! fd-limit raise for high-connection-count runs.
//!
//! The repo's dependency policy is std-only (plus `anyhow`/`xla`), so
//! there is no `libc` crate to lean on. On Linux (x86_64 / aarch64 —
//! every target we build in CI) the `ppoll` and `prlimit64` syscalls
//! are issued directly via inline assembly; `ppoll` rather than
//! `poll` because aarch64 never had a plain `poll` syscall, and one
//! entry point keeps both arches on the same code path. Everything
//! else here is safe std.
//!
//! On any other platform the module still compiles: [`poll`] degrades
//! to "sleep ~1ms, report everything ready" (the caller's nonblocking
//! reads then sort out what is actually readable — correct, just
//! busy), and [`raise_nofile_limit`] reports `Unsupported`. The
//! gateway stays functional there; only its idle efficiency degrades.
//!
//! Why `poll` and not `epoll`: the gateway re-polls a per-shard fd set
//! that it already holds in a contiguous `Vec` each loop iteration.
//! At the shard sizes we target (thousands of connections split over
//! N shards) the O(fds) scan per wakeup is noise next to frame
//! decode + inference, and `poll` needs no extra kernel object, no
//! registration bookkeeping, and no fd lifecycle hazards — the
//! cleanest std-only readiness source.

use std::io::{self, Read};
use std::time::Duration;

// --------------------------------------------------------- poll events

/// Readable data (or a peer close, which also flags `POLLHUP`).
pub const POLLIN: i16 = 0x001;
/// Socket writable without blocking.
pub const POLLOUT: i16 = 0x004;
/// Error condition (always polled, never requested).
pub const POLLERR: i16 = 0x008;
/// Peer hung up (always polled, never requested).
pub const POLLHUP: i16 = 0x010;
/// Fd was not open (always polled, never requested).
pub const POLLNVAL: i16 = 0x020;

/// One entry of a poll set — layout-compatible with the kernel's
/// `struct pollfd` on every Linux ABI we target.
#[repr(C)]
#[derive(Debug, Clone, Copy)]
pub struct PollFd {
    pub fd: i32,
    pub events: i16,
    pub revents: i16,
}

impl PollFd {
    pub fn new(fd: i32, events: i16) -> Self {
        Self { fd, events, revents: 0 }
    }

    /// The fd has input (or an error/hangup the owner must consume —
    /// a read on it returns the real condition without blocking).
    pub fn readable(&self) -> bool {
        self.revents & (POLLIN | POLLHUP | POLLERR | POLLNVAL) != 0
    }

    /// The fd accepts writes (or is in an error state a write will
    /// surface without blocking).
    pub fn writable(&self) -> bool {
        self.revents & (POLLOUT | POLLHUP | POLLERR | POLLNVAL) != 0
    }
}

/// True when the real-syscall backend is compiled in (Linux
/// x86_64/aarch64); false on the degraded portability fallback.
pub const HAVE_POLL_SYSCALL: bool = imp::HAVE_SYSCALLS;

/// Block until at least one fd in `fds` is ready, the timeout
/// expires, or a wakeup arrives (`None` = wait forever). Returns how
/// many entries have non-zero `revents`. A signal interruption
/// (`EINTR`) is reported as `Ok(0)` — callers treat every return as a
/// possibly-spurious wakeup anyway. Entries with a negative `fd` are
/// ignored, as in `poll(2)`.
pub fn poll(fds: &mut [PollFd], timeout: Option<Duration>)
            -> io::Result<usize> {
    for f in fds.iter_mut() {
        f.revents = 0;
    }
    imp::poll_impl(fds, timeout)
}

/// The raw fd of a socket for poll sets. On non-unix targets (where
/// the degraded [`poll`] fallback ignores fds anyway) every socket
/// maps to `-1`.
#[cfg(unix)]
pub fn fd_of(s: &impl std::os::unix::io::AsRawFd) -> i32 {
    s.as_raw_fd()
}

#[cfg(not(unix))]
pub fn fd_of<T>(_s: &T) -> i32 {
    -1
}

/// Raise this process's soft `RLIMIT_NOFILE` toward `target` (capped
/// at the hard limit); returns the resulting soft limit. Needed by
/// the c10k bench/tests: default soft limits (often 1024) are far
/// below 4096 connections' worth of sockets. Lowering never happens —
/// a target below the current soft limit is a no-op. `Unsupported`
/// on platforms without the raw syscall path.
pub fn raise_nofile_limit(target: u64) -> io::Result<u64> {
    imp::raise_nofile_impl(target)
}

#[cfg(all(target_os = "linux",
          any(target_arch = "x86_64", target_arch = "aarch64")))]
mod imp {
    use super::PollFd;
    use std::io;
    use std::time::Duration;

    pub const HAVE_SYSCALLS: bool = true;

    const EINTR: i32 = 4;
    const RLIMIT_NOFILE: usize = 7;

    /// `struct timespec` as the kernel expects it on 64-bit Linux.
    #[repr(C)]
    struct Timespec {
        sec: i64,
        nsec: i64,
    }

    /// `struct rlimit64` for `prlimit64`.
    #[repr(C)]
    struct RLimit64 {
        cur: u64,
        max: u64,
    }

    #[cfg(target_arch = "x86_64")]
    const SYS_PPOLL: usize = 271;
    #[cfg(target_arch = "x86_64")]
    const SYS_PRLIMIT64: usize = 302;
    #[cfg(target_arch = "aarch64")]
    const SYS_PPOLL: usize = 73;
    #[cfg(target_arch = "aarch64")]
    const SYS_PRLIMIT64: usize = 261;

    /// Raw x86_64 Linux syscall: number in rax, args in
    /// rdi/rsi/rdx/r10/r8; the instruction clobbers rcx and r11.
    #[cfg(target_arch = "x86_64")]
    unsafe fn syscall5(n: usize, a1: usize, a2: usize, a3: usize,
                       a4: usize, a5: usize) -> isize {
        let ret: isize;
        std::arch::asm!(
            "syscall",
            inlateout("rax") n => ret,
            in("rdi") a1,
            in("rsi") a2,
            in("rdx") a3,
            in("r10") a4,
            in("r8") a5,
            out("rcx") _,
            out("r11") _,
            options(nostack),
        );
        ret
    }

    /// Raw aarch64 Linux syscall: number in x8, args in x0..x4.
    #[cfg(target_arch = "aarch64")]
    unsafe fn syscall5(n: usize, a1: usize, a2: usize, a3: usize,
                       a4: usize, a5: usize) -> isize {
        let ret: isize;
        std::arch::asm!(
            "svc 0",
            inlateout("x0") a1 => ret,
            in("x1") a2,
            in("x2") a3,
            in("x3") a4,
            in("x4") a5,
            in("x8") n,
            options(nostack),
        );
        ret
    }

    pub fn poll_impl(fds: &mut [PollFd], timeout: Option<Duration>)
                     -> io::Result<usize> {
        let ts;
        let ts_ptr = match timeout {
            Some(d) => {
                ts = Timespec {
                    sec: d.as_secs().min(i64::MAX as u64) as i64,
                    nsec: i64::from(d.subsec_nanos()),
                };
                &ts as *const Timespec
            }
            None => std::ptr::null(),
        };
        // Null sigmask: the kernel skips the sigset entirely, so the
        // trailing size argument is ignored.
        let ret = unsafe {
            syscall5(SYS_PPOLL, fds.as_mut_ptr() as usize, fds.len(),
                     ts_ptr as usize, 0, 0)
        };
        if ret >= 0 {
            Ok(ret as usize)
        } else if ret == -(EINTR as isize) {
            Ok(0)
        } else {
            Err(io::Error::from_raw_os_error(-ret as i32))
        }
    }

    pub fn raise_nofile_impl(target: u64) -> io::Result<u64> {
        // pid 0 = the calling process.
        let mut old = RLimit64 { cur: 0, max: 0 };
        let ret = unsafe {
            syscall5(SYS_PRLIMIT64, 0, RLIMIT_NOFILE, 0,
                     &mut old as *mut RLimit64 as usize, 0)
        };
        if ret < 0 {
            return Err(io::Error::from_raw_os_error(-ret as i32));
        }
        let want = target.min(old.max);
        if want <= old.cur {
            return Ok(old.cur);
        }
        let new = RLimit64 { cur: want, max: old.max };
        let ret = unsafe {
            syscall5(SYS_PRLIMIT64, 0, RLIMIT_NOFILE,
                     &new as *const RLimit64 as usize, 0, 0)
        };
        if ret < 0 {
            return Err(io::Error::from_raw_os_error(-ret as i32));
        }
        Ok(want)
    }
}

#[cfg(not(all(target_os = "linux",
              any(target_arch = "x86_64", target_arch = "aarch64"))))]
mod imp {
    use super::PollFd;
    use std::io;
    use std::time::Duration;

    pub const HAVE_SYSCALLS: bool = false;

    /// Degraded portability fallback: no readiness source, so pace
    /// the loop and claim everything ready (even fd-less entries —
    /// this path has no real fds at all) — the caller's nonblocking
    /// reads/writes resolve the truth. Correct but busy; only
    /// non-Linux dev builds ever take this path.
    pub fn poll_impl(fds: &mut [PollFd], timeout: Option<Duration>)
                     -> io::Result<usize> {
        std::thread::sleep(
            timeout.unwrap_or(Duration::from_millis(1))
                .min(Duration::from_millis(1)));
        for f in fds.iter_mut() {
            f.revents = f.events;
        }
        Ok(fds.len())
    }

    pub fn raise_nofile_impl(_target: u64) -> io::Result<u64> {
        Err(io::Error::new(
            io::ErrorKind::Unsupported,
            "raise_nofile_limit: no raw-syscall path on this target",
        ))
    }
}

// --------------------------------------------------------------- waker

/// Self-pipe waker: lets any thread interrupt a [`poll`] that
/// includes [`Waker::fd`] in its set. Built on a nonblocking
/// `UnixStream` pair — wakes coalesce naturally (the pipe holds at
/// most a socket buffer of bytes and [`drain`](Self::drain) empties
/// it in one gulp), and a full pipe means a wake is already pending,
/// which is exactly the semantic we want.
#[cfg(unix)]
pub struct Waker {
    rx: std::os::unix::net::UnixStream,
    tx: std::os::unix::net::UnixStream,
}

#[cfg(unix)]
impl Waker {
    pub fn new() -> io::Result<Self> {
        let (tx, rx) = std::os::unix::net::UnixStream::pair()?;
        tx.set_nonblocking(true)?;
        rx.set_nonblocking(true)?;
        Ok(Self { rx, tx })
    }

    /// The fd to include (with [`POLLIN`]) in a poll set.
    pub fn fd(&self) -> i32 {
        use std::os::unix::io::AsRawFd;
        self.rx.as_raw_fd()
    }

    /// Wake the poller. Never blocks: a full pipe (`WouldBlock`)
    /// already guarantees a pending wakeup.
    pub fn wake(&self) {
        use std::io::Write;
        // One byte either writes fully or WouldBlocks (pipe full =
        // a wake is already pending) — both are success here.
        let _ = (&self.tx).write_all(&[1u8]);
    }

    /// Swallow queued wake bytes after a wakeup.
    pub fn drain(&self) {
        let mut buf = [0u8; 256];
        loop {
            match (&self.rx).read(&mut buf) {
                Ok(0) => return,
                Ok(_) => continue,
                Err(_) => return,
            }
        }
    }
}

/// Non-unix stand-in: no fd to poll (the degraded [`poll`] fallback
/// never blocks long), so waking is a flag with no wire behind it.
#[cfg(not(unix))]
pub struct Waker {
    flag: std::sync::atomic::AtomicBool,
}

#[cfg(not(unix))]
impl Waker {
    pub fn new() -> io::Result<Self> {
        Ok(Self { flag: std::sync::atomic::AtomicBool::new(false) })
    }

    pub fn fd(&self) -> i32 {
        -1
    }

    pub fn wake(&self) {
        self.flag.store(true, std::sync::atomic::Ordering::Release);
    }

    pub fn drain(&self) {
        self.flag.store(false, std::sync::atomic::Ordering::Release);
    }
}

// ------------------------------------------------------- receive buffer

/// How much a single [`RecvBuf::fill_from`] call asks the socket for.
const READ_CHUNK: usize = 16 * 1024;
/// Consumed-prefix size beyond which the buffer compacts.
const COMPACT_AT: usize = 64 * 1024;

/// Growable receive buffer with a consumed-prefix offset, for
/// incremental frame decode over a nonblocking socket: bytes arrive
/// in arbitrary slices across poll rounds, [`data`](Self::data)
/// exposes everything unconsumed, and the decoder
/// [`consume`](Self::consume)s whole frames as they complete. The
/// consumed prefix is reclaimed lazily (cheap `clear` when fully
/// drained — the common case between frames — else an occasional
/// compacting `drain`), so per-byte cost stays amortized O(1).
#[derive(Default)]
pub struct RecvBuf {
    buf: Vec<u8>,
    start: usize,
}

impl RecvBuf {
    pub fn new() -> Self {
        Self::default()
    }

    /// All received, unconsumed bytes.
    pub fn data(&self) -> &[u8] {
        &self.buf[self.start..]
    }

    pub fn len(&self) -> usize {
        self.buf.len() - self.start
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Discard `n` bytes from the front (a decoded frame).
    pub fn consume(&mut self, n: usize) {
        assert!(n <= self.len(), "consume past end of RecvBuf");
        self.start += n;
        if self.start == self.buf.len() {
            self.buf.clear();
            self.start = 0;
        } else if self.start >= COMPACT_AT
            && self.start * 2 >= self.buf.len()
        {
            self.buf.drain(..self.start);
            self.start = 0;
        }
    }

    /// One `read` from `r` appended to the buffer. Returns the byte
    /// count (`Ok(0)` = EOF); `WouldBlock` passes through untouched
    /// so nonblocking callers can tell "drained" from "closed".
    pub fn fill_from(&mut self, r: &mut impl Read) -> io::Result<usize> {
        let old = self.buf.len();
        self.buf.resize(old + READ_CHUNK, 0);
        match r.read(&mut self.buf[old..]) {
            Ok(n) => {
                self.buf.truncate(old + n);
                Ok(n)
            }
            Err(e) => {
                self.buf.truncate(old);
                Err(e)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Instant;

    #[test]
    fn poll_empty_set_times_out() {
        let t0 = Instant::now();
        let n = poll(&mut [], Some(Duration::from_millis(20)))
            .expect("poll");
        assert_eq!(n, 0);
        // Bounded above only loosely — the point is it returns.
        assert!(t0.elapsed() < Duration::from_secs(5));
    }

    #[test]
    fn waker_interrupts_poll_and_drains() {
        let w = Waker::new().expect("waker");
        let mut fds = [PollFd::new(w.fd(), POLLIN)];
        // Nothing pending: a zero timeout comes back not-ready.
        let n = poll(&mut fds, Some(Duration::ZERO)).expect("poll");
        if HAVE_POLL_SYSCALL {
            assert_eq!(n, 0, "waker readable before any wake");
        }
        w.wake();
        w.wake(); // coalesces
        let n = poll(&mut fds, Some(Duration::from_secs(5)))
            .expect("poll");
        assert!(n >= 1, "wake did not make the waker readable");
        assert!(fds[0].readable());
        w.drain();
        let mut fds = [PollFd::new(w.fd(), POLLIN)];
        let n = poll(&mut fds, Some(Duration::ZERO)).expect("poll");
        if HAVE_POLL_SYSCALL {
            assert_eq!(n, 0, "drain left wake bytes behind");
        }
    }

    #[test]
    fn waker_wakes_across_threads() {
        let w = std::sync::Arc::new(Waker::new().expect("waker"));
        let w2 = w.clone();
        let t = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(30));
            w2.wake();
        });
        let mut fds = [PollFd::new(w.fd(), POLLIN)];
        let n = poll(&mut fds, Some(Duration::from_secs(10)))
            .expect("poll");
        assert!(n >= 1);
        t.join().unwrap();
    }

    #[test]
    fn recvbuf_incremental_fill_and_consume() {
        let payload: Vec<u8> = (0..100_000u32)
            .map(|i| (i % 251) as u8)
            .collect();
        let mut rb = RecvBuf::new();
        let mut src: &[u8] = &payload;
        // Drip-feed through arbitrary reads; consume in odd chunks.
        let mut seen = Vec::new();
        while seen.len() < payload.len() {
            if !src.is_empty() {
                rb.fill_from(&mut src).expect("fill");
            }
            while rb.len() >= 7 {
                seen.extend_from_slice(&rb.data()[..7]);
                rb.consume(7);
            }
            if src.is_empty() && rb.len() < 7 {
                seen.extend_from_slice(rb.data());
                let n = rb.len();
                rb.consume(n);
            }
        }
        assert_eq!(seen, payload);
        assert!(rb.is_empty());
    }

    #[test]
    fn recvbuf_eof_and_wouldblock_pass_through() {
        struct WouldBlockReader;
        impl std::io::Read for WouldBlockReader {
            fn read(&mut self, _b: &mut [u8]) -> io::Result<usize> {
                Err(io::Error::from(io::ErrorKind::WouldBlock))
            }
        }
        let mut rb = RecvBuf::new();
        let mut empty: &[u8] = &[];
        assert_eq!(rb.fill_from(&mut empty).expect("eof"), 0);
        let e = rb.fill_from(&mut WouldBlockReader).unwrap_err();
        assert_eq!(e.kind(), io::ErrorKind::WouldBlock);
        assert!(rb.is_empty());
    }

    #[cfg(target_os = "linux")]
    #[test]
    fn nofile_limit_raises_or_reports() {
        match raise_nofile_limit(1) {
            // target 1 is below any sane current soft limit: must be
            // a no-op returning the existing (non-zero) soft limit.
            Ok(cur) => assert!(cur >= 1),
            Err(e) => panic!("prlimit64 read failed: {e}"),
        }
    }
}
