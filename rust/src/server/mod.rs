//! Network serving subsystem — the ingress path in front of the
//! [`coordinator`](crate::coordinator).
//!
//! The paper's headline numbers are *serving* numbers (22.6 KFPS,
//! 42.4 uJ/image on classification); streaming SNN accelerators treat
//! the host↔accelerator boundary as a first-class subsystem. This
//! module is that boundary as real code:
//!
//! * [`protocol`] — versioned, length-prefixed binary wire format
//!   (requests carry raw pixels or pre-encoded spike words; responses
//!   carry prediction + latency + worker id; typed error codes
//!   `BUSY` / `BAD_REQUEST` / `SHUTTING_DOWN` / `INTERNAL`).
//! * [`server`] — the TCP [`Gateway`]: per-connection threads,
//!   pipelined requests, a connection cap, admission control that maps
//!   queue-full onto `BUSY` (shed load, never hang), a
//!   Prometheus-style `metrics` request, and graceful
//!   drain-then-shutdown.
//! * [`client`] — a blocking, pipelining client library.
//! * [`loadgen`] — a multi-connection load generator (the
//!   `skydiver loadgen` CLI and the loopback serving bench).

pub mod client;
pub mod loadgen;
pub mod protocol;
pub mod server;

pub use client::{Client, ServerInfo};
pub use loadgen::{LoadGenConfig, LoadGenReport};
pub use protocol::{ErrorCode, ProtoError, RequestBody, ResponseBody,
                   WirePayload, WireRequest, WireResponse};
pub use server::{CounterSnapshot, Gateway, GatewayConfig,
                 GatewayReport, GatewayStop};
